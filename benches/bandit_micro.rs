//! L3 micro-benchmarks (controller hot path): bandit select/update, arm
//! policies, signal parsing, and full simulated decode sessions. These are
//! the coordinator-side costs that must stay ≪ one PJRT dispatch (~100 µs)
//! — see EXPERIMENTS.md §Perf.
//!
//! Runs under `cargo bench --offline` ([[bench]] harness = false).

use tapout::bandit::{make_bandit, Reward, SeqBandit};
use tapout::harness::{run_method, sim_suite, Backend};
use tapout::policies::pool::default_arms;
use tapout::policies::StopPolicy;
use tapout::signals::TokenSignals;
use tapout::spec::MethodSpec;
use tapout::util::bench::{bench, group};
use tapout::util::Rng;

fn main() {
    let mut rng = Rng::new(1);
    let sig = TokenSignals::from_logits(&[2.0, 1.0, 0.5, 0.0, -0.5, -1.0, -2.0, 0.3]);

    group("bandit select+update (5 arms)");
    for kind in ["ucb1", "ucb-tuned", "ts-gaussian", "ts-beta"] {
        let mut b = make_bandit(kind, 5);
        let mut r = Rng::new(2);
        bench(&format!("{kind}"), 120, || {
            let a = b.select(&mut r);
            b.update(a, 0.7);
        });
    }

    group("stop policies (per-token decision)");
    for (name, mut p) in [
        ("max-conf", Box::new(tapout::policies::MaxConfidence::new(0.8)) as Box<dyn StopPolicy>),
        ("svip", Box::new(tapout::policies::Svip::new(0.6))),
        ("ada-edl", Box::new(tapout::policies::AdaEdl::default())),
        ("logit-margin", Box::new(tapout::policies::LogitMargin::new(0.2))),
    ] {
        bench(name, 80, || {
            std::hint::black_box(p.should_stop(&sig, 3));
        });
    }

    group("seq controller full round (select + 6 decisions + reward)");
    let mut ctrl = SeqBandit::new("ucb1", default_arms(), Reward::Blend(0.5), 128);
    bench("seq-ucb1 round", 120, || {
        ctrl.session_start(&mut rng);
        for i in 0..6 {
            let _ = ctrl.should_stop(&sig, i);
        }
        ctrl.on_verify(4, 6);
    });

    group("signal parsing");
    let flat: Vec<f32> = (0..8 * 16).map(|i| i as f32 * 0.1).collect();
    bench("parse 16 rows", 60, || {
        std::hint::black_box(TokenSignals::parse_rows(&flat, 16));
    });
    bench("from_logits V=96", 60, || {
        let row: Vec<f32> = (0..96).map(|i| ((i * 37) % 13) as f32).collect();
        std::hint::black_box(TokenSignals::from_logits(&row));
    });

    group("simulated end-to-end sessions (controller + session loop only)");
    let items = sim_suite("specbench", 2, 64);
    for m in ["static-6", "seq-ucb1", "token-ts"] {
        let spec = MethodSpec::parse(m, "artifacts").unwrap();
        let backend = Backend::Sim { quality: 0.9, rel_cost: 1.0 / 16.0 };
        bench(&format!("26 prompts x 64 tok [{m}]"), 400, || {
            std::hint::black_box(run_method(&backend, &items, &spec, 128, false).unwrap());
        });
    }
}
