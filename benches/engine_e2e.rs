//! End-to-end benches — one per paper table/figure (DESIGN.md §5) plus the
//! PJRT step-latency ladder that calibrates the analytic cost model.
//!
//! Simulator benches always run; PJRT benches run when `artifacts/` exists
//! (skipped otherwise so `cargo bench` works pre-`make artifacts`).
//! `TAPOUT_BENCH_FAST=1` shrinks everything for CI smoke.

use std::path::Path;

use tapout::harness::{run_method, run_probe, sim_suite, Backend};
use tapout::models::{LanguageModel, Manifest, ModelAssets, PjrtModel};
use tapout::runtime::Runtime;
use tapout::spec::MethodSpec;
use tapout::util::bench::{bench, fmt_ns, group};

fn main() {
    sim_tables();
    pjrt_ladder();
}

/// One bench per paper artifact, on the simulator backend (the controller
/// + session-loop cost of regenerating each table/figure).
fn sim_tables() {
    let backend = || Backend::Sim { quality: 0.9, rel_cost: 1.0 / 16.0 };
    let items = sim_suite("specbench", 1, 48);
    let m = |s: &str| MethodSpec::parse(s, "artifacts").unwrap();

    group("per-paper-artifact regeneration (sim backend, scaled)");
    bench("table2: ucb1 r_simple vs r_blend", 300, || {
        for spec in [m("seq-ucb1:rsimple"), m("seq-ucb1")] {
            std::hint::black_box(run_method(&backend(), &items, &spec, 128, false).unwrap());
        }
    });
    bench("fig4: ucb1 vs ucb-tuned", 300, || {
        for spec in [m("seq-ucb1"), m("seq-ucb-tuned")] {
            std::hint::black_box(run_method(&backend(), &items, &spec, 128, false).unwrap());
        }
    });
    bench("table3/5 row: one method, 13 cats", 300, || {
        std::hint::black_box(run_method(&backend(), &items, &m("seq-ucb1"), 128, false).unwrap());
    });
    bench("fig2: static-16 probe w/ signals", 300, || {
        std::hint::black_box(run_probe(&backend(), &items, &MethodSpec::Static(16), 16).unwrap());
    });
    bench("fig5/6: ucb1 with value tracking", 300, || {
        std::hint::black_box(run_method(&backend(), &items, &m("seq-ucb1"), 128, true).unwrap());
    });
    bench("abl-arms: 13-arm pool", 300, || {
        std::hint::black_box(run_method(&backend(), &items, &m("seq-ucb1:multi"), 128, false).unwrap());
    });
}

/// PJRT dispatch + block-latency ladder: the real hot-path numbers that
/// dominate serving latency (calibrates OVERHEAD_ROWS in the cost model).
fn pjrt_ladder() {
    let dir = Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("\n[pjrt ladder skipped: run `make artifacts` first]");
        return;
    }
    let manifest = Manifest::load(dir).unwrap();
    let runtime = Runtime::cpu().unwrap();

    group("PJRT block latency ladder (real models)");
    for name in ["draft-base", "target-base"] {
        let assets = ModelAssets::load(&runtime, &manifest, name).unwrap();
        let mut model = PjrtModel::new(assets).unwrap();
        let buckets: Vec<usize> = if name.starts_with("draft") {
            vec![1, 4]
        } else {
            vec![1, 8, 32, 128]
        };
        for &k in &buckets {
            // feed k tokens per call, resetting when the KV fills up
            let toks: Vec<u32> = (0..k as u32).map(|i| 3 + (i % 29)).collect();
            model.reset();
            let r = bench(&format!("{name} block{k}"), 500, || {
                if model.cur() + k >= model.max_seq() {
                    model.reset();
                }
                let start = model.cur();
                std::hint::black_box(model.block(&toks, start).unwrap());
            });
            println!(
                "    -> {} per row ({k} rows/call)",
                fmt_ns(r.mean_ns / k as f64)
            );
        }
    }
}
