//! End-to-end benches — one per paper table/figure (DESIGN.md §5) plus the
//! PJRT step-latency ladder that calibrates the analytic cost model.
//!
//! Simulator benches always run; PJRT benches run when `artifacts/` exists
//! (skipped otherwise so `cargo bench` works pre-`make artifacts`).
//! `TAPOUT_BENCH_FAST=1` shrinks everything for CI smoke.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};

use tapout::engine::{
    BackendKind, BatchConfig, Engine, EngineConfig, EngineMode, FinishStatus, HttpConfig,
    HttpServer, Policy, Router, RouterConfig,
};
use tapout::harness::{run_method, run_probe, sim_suite, Backend};
use tapout::models::{
    sim_decode, sim_encode, LanguageModel, Manifest, ModelAssets, PjrtModel, SimModel,
};
use tapout::runtime::Runtime;
use tapout::spec::{greedy, GenConfig, MethodSpec, BOS};
use tapout::util::bench::{bench, fmt_ns, group};
use tapout::util::Json;

/// Machine-readable serving results are appended here so the perf
/// trajectory is tracked across PRs (schema below in `serving_scaling`).
const BENCH_JSON_PATH: &str = "BENCH_serving.json";

/// Workers-vs-Continuous execution-core comparison lands here
/// (`tapout.bench.continuous.v1`, schema below in
/// `continuous_vs_workers`).
const BENCH_CONTINUOUS_JSON_PATH: &str = "BENCH_continuous.json";

/// Prefix-cache on/off comparison on a shared-prefix workload lands here
/// (`tapout.bench.cache.v1`, schema below in `prefix_cache_bench`).
const BENCH_CACHE_JSON_PATH: &str = "BENCH_cache.json";

/// Paged-KV busy-slot comparison (cache off vs PR-5 slot-affinity vs
/// paged sharing) lands here (`tapout.bench.paged.v1`, schema below in
/// `paged_kv_bench`).
const BENCH_PAGED_JSON_PATH: &str = "BENCH_paged.json";

/// Multi-replica router-tier comparison (affinity vs round-robin, 1 vs 2
/// replicas, held concurrent streams) lands here
/// (`tapout.bench.router.v1`, schema below in `router_bench`).
const BENCH_ROUTER_JSON_PATH: &str = "BENCH_router.json";

/// Serialized vs pipelined step-loop comparison on the sim harness's
/// two-lane virtual clock lands here (`tapout.bench.pipeline.v1`,
/// schema below in `pipeline_bench`).
const BENCH_PIPELINE_JSON_PATH: &str = "BENCH_pipeline.json";

/// Hierarchical drafter-pool comparison (outer-bandit selection vs each
/// fixed single drafter on a two-tenant mixed workload) lands here
/// (`tapout.bench.drafters.v1`, schema below in `drafters_bench`).
const BENCH_DRAFTERS_JSON_PATH: &str = "BENCH_drafters.json";

fn main() {
    // TAPOUT_BENCH_ONLY=cache runs just the prefix-cache comparison —
    // the CI gate asserting cached prefill < uncached at slots >= 4
    // without paying for the full bench suite
    if std::env::var("TAPOUT_BENCH_ONLY").as_deref() == Ok("cache") {
        run_cache_bench();
        return;
    }
    // TAPOUT_BENCH_ONLY=paged runs just the paged-KV comparison — the CI
    // gate asserting busy-slot page sharing computes strictly fewer
    // prefill tokens than slot-affinity when concurrency > slots
    if std::env::var("TAPOUT_BENCH_ONLY").as_deref() == Ok("paged") {
        run_paged_bench();
        return;
    }
    // TAPOUT_BENCH_ONLY=router runs just the multi-replica router
    // comparison — the CI gate asserting prefix-affinity placement
    // aggregates strictly more fleet cache hits than round-robin
    if std::env::var("TAPOUT_BENCH_ONLY").as_deref() == Ok("router") {
        run_router_bench();
        return;
    }
    // TAPOUT_BENCH_ONLY=pipeline runs just the serialized-vs-pipelined
    // comparison — the CI gate asserting the two-stage pipeline strictly
    // shortens virtual wall-clock at slots >= 4 with identical replies
    if std::env::var("TAPOUT_BENCH_ONLY").as_deref() == Ok("pipeline") {
        run_pipeline_bench();
        return;
    }
    // TAPOUT_BENCH_ONLY=drafters runs just the drafter-pool comparison —
    // the CI gate asserting outer-bandit selection strictly beats the
    // best fixed single drafter on a two-tenant mixed workload, with the
    // tenants converging to different modal drafters and every run
    // oracle-exact
    if std::env::var("TAPOUT_BENCH_ONLY").as_deref() == Ok("drafters") {
        run_drafters_bench();
        return;
    }
    sim_tables();
    let mut report = Json::obj();
    report.set("schema", "tapout.bench.serving.v1");
    serving_scaling(&mut report);
    overload_shedding(&mut report);
    match std::fs::write(BENCH_JSON_PATH, report.render()) {
        Ok(()) => println!("\n[wrote {BENCH_JSON_PATH}]"),
        Err(e) => eprintln!("\n[failed to write {BENCH_JSON_PATH}: {e}]"),
    }
    let mut creport = Json::obj();
    creport.set("schema", "tapout.bench.continuous.v1");
    continuous_vs_workers(&mut creport);
    match std::fs::write(BENCH_CONTINUOUS_JSON_PATH, creport.render()) {
        Ok(()) => println!("\n[wrote {BENCH_CONTINUOUS_JSON_PATH}]"),
        Err(e) => eprintln!("\n[failed to write {BENCH_CONTINUOUS_JSON_PATH}: {e}]"),
    }
    run_cache_bench();
    run_paged_bench();
    run_router_bench();
    run_pipeline_bench();
    run_drafters_bench();
    pjrt_ladder();
}

fn run_cache_bench() {
    let mut report = Json::obj();
    report.set("schema", "tapout.bench.cache.v1");
    prefix_cache_bench(&mut report);
    match std::fs::write(BENCH_CACHE_JSON_PATH, report.render()) {
        Ok(()) => println!("\n[wrote {BENCH_CACHE_JSON_PATH}]"),
        Err(e) => eprintln!("\n[failed to write {BENCH_CACHE_JSON_PATH}: {e}]"),
    }
}

fn run_paged_bench() {
    let mut report = Json::obj();
    report.set("schema", "tapout.bench.paged.v1");
    paged_kv_bench(&mut report);
    match std::fs::write(BENCH_PAGED_JSON_PATH, report.render()) {
        Ok(()) => println!("\n[wrote {BENCH_PAGED_JSON_PATH}]"),
        Err(e) => eprintln!("\n[failed to write {BENCH_PAGED_JSON_PATH}: {e}]"),
    }
}

fn run_router_bench() {
    let mut report = Json::obj();
    report.set("schema", "tapout.bench.router.v1");
    router_bench(&mut report);
    match std::fs::write(BENCH_ROUTER_JSON_PATH, report.render()) {
        Ok(()) => println!("\n[wrote {BENCH_ROUTER_JSON_PATH}]"),
        Err(e) => eprintln!("\n[failed to write {BENCH_ROUTER_JSON_PATH}: {e}]"),
    }
}

fn run_pipeline_bench() {
    let mut report = Json::obj();
    report.set("schema", "tapout.bench.pipeline.v1");
    pipeline_bench(&mut report);
    match std::fs::write(BENCH_PIPELINE_JSON_PATH, report.render()) {
        Ok(()) => println!("\n[wrote {BENCH_PIPELINE_JSON_PATH}]"),
        Err(e) => eprintln!("\n[failed to write {BENCH_PIPELINE_JSON_PATH}: {e}]"),
    }
}

fn run_drafters_bench() {
    let mut report = Json::obj();
    report.set("schema", "tapout.bench.drafters.v1");
    drafters_bench(&mut report);
    match std::fs::write(BENCH_DRAFTERS_JSON_PATH, report.render()) {
        Ok(()) => println!("\n[wrote {BENCH_DRAFTERS_JSON_PATH}]"),
        Err(e) => eprintln!("\n[failed to write {BENCH_DRAFTERS_JSON_PATH}: {e}]"),
    }
}

/// Two-stage pipeline (docs/ARCHITECTURE.md §16) measured on the sim
/// harness's two-lane *virtual* clock, so the numbers are exact and
/// replayable instead of host-noise-bound: the same seeded
/// continuous-mode plans at slots {4, 8}, serialized and pipelined.
/// Replies are asserted byte-identical (the pipeline is lossless), and
/// the CI gate asserts pipelined virtual wall-clock strictly beats
/// serialized at both slot counts. Deadlines are stripped from the
/// generated plans first: deadline races resolve against absolute
/// virtual time, so compressing the critical path legitimately flips
/// them — reply equality is only meaningful deadline-free. Reported per
/// slot count: virtual wall-clock both ways, virtual tok/s, the overlap
/// ratio (share of draft-lane work hidden under the verify shadow) and
/// the discarded-pre-draft rate.
///
/// Also asserted here (the allocation-churn sweep): a warm pipelined
/// continuous engine's `step.scratch_allocs` counter stays flat across
/// a second identical burst — row buffers and token scratch are reused
/// once the high-water mark is reached, never reallocated per
/// iteration.
fn pipeline_bench(report: &mut Json) {
    use std::sync::atomic::Ordering;
    use tapout::sim_harness::{run_plan, SimOp, SimPlan};
    let fast = std::env::var("TAPOUT_BENCH_FAST").map(|v| v == "1").unwrap_or(false);
    // seeds shared with the runner's own equality test: known to adopt
    // pre-drafts (full-acceptance rounds) within the matrix
    let (seeds, steps): (&[u64], usize) = if fast {
        (&[0, 5, 11, 23], 60)
    } else {
        (&[0, 5, 11, 23, 31, 47], 120)
    };

    group(&format!(
        "pipeline: serialized vs pipelined continuous step loop, {} seeds x {steps} steps \
         (virtual clock, sim harness)",
        seeds.len()
    ));
    let mut rows: Vec<Json> = Vec::new();
    for slots in [4usize, 8] {
        let mut serial_ns = 0u64;
        let mut piped_ns = 0u64;
        let mut draft_busy = 0u64;
        let mut overlap = 0u64;
        let mut attempted = 0u64;
        let mut adopted = 0u64;
        let mut discarded = 0u64;
        let mut tokens = 0u64;
        for &seed in seeds {
            let mut plan = SimPlan::generate(seed, steps);
            plan.mode = "continuous".to_string();
            plan.slots = slots;
            for op in &mut plan.ops {
                if let SimOp::Submit { deadline_ns, .. } = op {
                    *deadline_ns = None;
                }
            }
            let base = run_plan(&plan);
            plan.pipeline = true;
            let piped = run_plan(&plan);
            assert_eq!(base.violation, None, "seed {seed} slots {slots} (serialized)");
            assert_eq!(piped.violation, None, "seed {seed} slots {slots} (pipelined)");
            assert_eq!(
                piped.replies, base.replies,
                "seed {seed} slots {slots}: pipelining moved a byte"
            );
            serial_ns += base.clock_ns;
            piped_ns += piped.clock_ns;
            draft_busy += piped.draft_busy_ns;
            overlap += piped.overlap_ns;
            attempted += piped.spec_attempted;
            adopted += piped.spec_adopted;
            discarded += piped.spec_discarded;
            tokens += base.replies.values().map(|r| r.emitted.len() as u64).sum::<u64>();
        }
        assert!(attempted > 0, "slots {slots}: the pipelined runs must speculate");
        // CI gate: at slots >= 4 the two-stage pipeline must strictly
        // shorten the virtual critical path, with nonzero overlap
        assert!(overlap > 0, "slots {slots}: adopted pre-drafts must hide draft time");
        assert!(
            piped_ns < serial_ns,
            "slots {slots}: pipelined virtual wall-clock must strictly beat serialized \
             ({piped_ns} vs {serial_ns} ns)"
        );
        let serial_tok_s = tokens as f64 / (serial_ns as f64 / 1e9);
        let piped_tok_s = tokens as f64 / (piped_ns as f64 / 1e9);
        let overlap_ratio = overlap as f64 / draft_busy.max(1) as f64;
        let discard_rate = discarded as f64 / attempted.max(1) as f64;
        println!(
            "  slots={slots}: serialized {:.2} ms vs pipelined {:.2} ms virtual  \
             ({:.2}x, {serial_tok_s:.0} -> {piped_tok_s:.0} tok/s)  overlap {overlap_ratio:.2}  \
             discard rate {discard_rate:.2}",
            serial_ns as f64 / 1e6,
            piped_ns as f64 / 1e6,
            serial_ns as f64 / piped_ns as f64,
        );
        let mut row = Json::obj();
        row.set("slots", slots)
            .set("seeds", seeds.len())
            .set("serialized_clock_ms", serial_ns as f64 / 1e6)
            .set("pipelined_clock_ms", piped_ns as f64 / 1e6)
            .set("speedup", serial_ns as f64 / piped_ns as f64)
            .set("serialized_tok_s", serial_tok_s)
            .set("pipelined_tok_s", piped_tok_s)
            .set("overlap_ns", overlap as usize)
            .set("overlap_ratio", overlap_ratio)
            .set("spec_attempted", attempted as usize)
            .set("spec_adopted", adopted as usize)
            .set("spec_discarded", discarded as usize)
            .set("discard_rate", discard_rate);
        rows.push(row);
    }
    report.set("steps", steps).set("slot_rows", rows);

    // --- allocation-churn sweep: warm scratch stays flat ---------------
    // static gamma keeps every round's row shapes identical across
    // bursts, so the second burst's growth events are provably bounded
    // by chunk-width timing (at most one high-water bump per slot), not
    // proportional to iterations
    let slots = 4usize;
    let eng = Engine::start(EngineConfig {
        method: "static-4".into(),
        gamma_max: 8,
        sched: Policy::Fcfs,
        slots,
        workers: 0,
        backend: BackendKind::sim_default(),
        mode: EngineMode::Continuous,
        pipeline: true,
        ..EngineConfig::default()
    })
    .unwrap();
    let burst = || {
        let rxs: Vec<_> =
            (0..8).map(|i| eng.submit(&format!("scratch reuse probe {i}"), 32)).collect();
        for rx in rxs {
            let r = rx.recv().unwrap();
            assert!(r.is_ok(), "{:?}", r.error);
        }
        std::thread::sleep(Duration::from_millis(50)); // let the last flush land
        (
            eng.stats.step.scratch_allocs.load(Ordering::Relaxed),
            eng.stats.step.steps.load(Ordering::Relaxed),
        )
    };
    let (cold_allocs, cold_steps) = burst();
    let (warm_allocs, warm_steps) = burst();
    let grew = warm_allocs - cold_allocs;
    let iters = warm_steps - cold_steps;
    println!(
        "  scratch churn: cold burst {cold_allocs} growths, warm burst +{grew} over {iters} \
         iterations (reuse must hold the high-water mark)"
    );
    assert!(cold_allocs > 0, "the cold burst must have grown the scratch from empty");
    assert!(
        grew <= slots as u64,
        "warm-burst scratch growth must be flat, not per-iteration: +{grew} over {iters} iters"
    );
    eng.shutdown();
    let mut churn = Json::obj();
    churn
        .set("cold_allocs", cold_allocs as usize)
        .set("warm_growth", grew as usize)
        .set("warm_iterations", iters as usize);
    report.set("scratch_churn", churn);
}

/// Hierarchical drafter-pool bandit (docs/ARCHITECTURE.md §17) measured
/// on the sim harness's virtual clock: a two-tenant mixed workload over
/// a pool of two drafters with *opposite* per-tenant acceptance
/// profiles. The runner shards tenants by request-id parity (`t0` =
/// even ids, `t1` = odd), so alternating the category with the parity
/// gives each tenant a pure stream — `t0` sends `coding` requests
/// (pooled preference maps to drafter 0 at n = 2) and `t1` sends `qa`
/// requests (drafter 1). The identical plan runs three ways: hierarchical
/// selection (no pin) and pinned to each fixed single drafter
/// (`run_plan_pinned`), all on the same deterministic virtual clock.
///
/// CI gates, asserted inline:
///   * every run is oracle-exact (violation-free ⇒ each reply
///     byte-equals a fault-free target-only greedy decode) and all
///     requests finish `Done`;
///   * replies are byte-identical across all three runs — drafter
///     selection routes *work*, never output bytes;
///   * the two tenants converge to **different** modal drafters under
///     bandit selection (full-information scoring separates them);
///   * bandit virtual tok/s strictly beats the best fixed single
///     drafter — either pin serves half the workload with the wrong
///     drafter's low acceptance, paying many extra verify rounds.
fn drafters_bench(report: &mut Json) {
    use tapout::sim_harness::{run_plan, run_plan_pinned, SimOp, SimPlan};
    let fast = std::env::var("TAPOUT_BENCH_FAST").map(|v| v == "1").unwrap_or(false);
    let (n_reqs, max_new) = if fast { (16u64, 16usize) } else { (40, 24) };

    group(&format!(
        "drafter pool: two-tenant bandit vs fixed single drafters, {n_reqs} reqs x {max_new} \
         tokens (virtual clock, sim harness)"
    ));
    let mut ops = Vec::new();
    for i in 0..n_reqs {
        let category = if i % 2 == 0 { "coding" } else { "qa" };
        ops.push(SimOp::Submit {
            req: i,
            prompt: format!("pooled tenant workload request {i}"),
            category: category.to_string(),
            max_new,
            deadline_ns: None,
        });
        if i % 4 == 3 {
            ops.push(SimOp::Step { n: 8 });
        }
    }
    let plan = SimPlan {
        seed: 71,
        mode: "continuous".to_string(),
        slots: 4,
        workers: 4,
        gamma_max: 6,
        method: "seq-ucb1".to_string(),
        cache: true,
        sharing: true,
        page_size: 8,
        kv_pages: 0,
        faults: false,
        max_faults: 0,
        sabotage: false,
        replicas: 1,
        affinity: true,
        pipeline: false,
        drafters: 2,
        tenants: 2,
        ops,
    };

    let runs = [
        ("bandit", run_plan(&plan)),
        ("pin0", run_plan_pinned(&plan, Some(0))),
        ("pin1", run_plan_pinned(&plan, Some(1))),
    ];
    let mut rows: Vec<Json> = Vec::new();
    let mut tok_s = [0f64; 3];
    for (k, (label, r)) in runs.iter().enumerate() {
        assert_eq!(r.violation, None, "{label}: drafter run tripped the oracle");
        assert_eq!(r.replies.len(), n_reqs as usize, "{label}: a request never terminated");
        for (req, reply) in &r.replies {
            assert_eq!(
                reply.status,
                FinishStatus::Done,
                "{label} req {req}: fault-free run must finish Done"
            );
        }
        assert_eq!(
            r.replies, runs[0].1.replies,
            "{label}: drafter selection moved an output byte"
        );
        let tokens: u64 = r.replies.values().map(|x| x.emitted.len() as u64).sum();
        tok_s[k] = tokens as f64 / (r.clock_ns as f64 / 1e9);
        println!(
            "  {label:>6}: {:.2} ms virtual  {:.0} tok/s  modes {:?}",
            r.clock_ns as f64 / 1e6,
            tok_s[k],
            r.drafter_modes
        );
        let mut row = Json::obj();
        row.set("selection", *label)
            .set("clock_ms", r.clock_ns as f64 / 1e6)
            .set("tok_s", tok_s[k])
            .set("tokens", tokens as usize);
        let mut modes = Json::obj();
        for (tenant, d) in &r.drafter_modes {
            modes.set(tenant, *d);
        }
        row.set("tenant_modal_drafters", modes);
        rows.push(row);
    }
    // gate: the two pure tenant streams must settle on different modal
    // drafters — full-information scoring separates opposite profiles
    let modes = &runs[0].1.drafter_modes;
    let (t0, t1) = (modes.get("t0"), modes.get("t1"));
    assert!(
        t0.is_some() && t1.is_some() && t0 != t1,
        "tenants must converge to different modal drafters, got {modes:?}"
    );
    // gate: adaptive selection strictly beats the best fixed pin — each
    // pin serves half the tenants with the wrong drafter's acceptance
    let best_fixed = tok_s[1].max(tok_s[2]);
    assert!(
        tok_s[0] > best_fixed,
        "bandit {:.0} tok/s must strictly beat the best fixed drafter {best_fixed:.0} tok/s",
        tok_s[0]
    );
    println!(
        "  bandit beats best fixed single drafter {:.2}x on the virtual clock",
        tok_s[0] / best_fixed
    );
    report
        .set("requests", n_reqs as usize)
        .set("max_new", max_new)
        .set("drafters", 2usize)
        .set("tenants", 2usize)
        .set("bandit_speedup_vs_best_fixed", tok_s[0] / best_fixed)
        .set("rows", rows);
}

/// Paged KV arena on the busy-slot workload slot-affinity cannot serve
/// (docs/ARCHITECTURE.md §13): a shared-prefix burst much wider than the
/// slot count through the Continuous engine at slots 4, under three
/// configurations — cache off, cache on with page sharing off (the PR 5
/// slot-affinity baseline: a hit requires the matching slot to be
/// *free*), and cache on with page sharing (busy slots' prompt pages are
/// adopted copy-on-write). Outputs are asserted byte-identical across
/// all three and against the greedy oracle. The headline quantity is
/// again **prefill tokens computed vs served**: with concurrency > slots
/// the first wave after a cold start finds every matching slot busy, so
/// the paged engine must compute strictly fewer prefill tokens than
/// slot-affinity — the assert CI gates on. Peak pages resident shows the
/// memory side: shared pages are counted once, not per-session.
fn paged_kv_bench(report: &mut Json) {
    use std::sync::atomic::Ordering;
    let fast = std::env::var("TAPOUT_BENCH_FAST").map(|v| v == "1").unwrap_or(false);
    let (n_req, max_new) = if fast { (16, 32) } else { (32, 64) };
    let slots = 4usize;
    let system =
        "system: you are a terse serving assistant; answer from the shared template, cite the \
         shared context, and stop. "
            .repeat(3);
    let prompts: Vec<String> =
        (0..n_req).map(|i| format!("{system}user {i}: question number {i} please")).collect();
    let served_total: u64 = prompts.iter().map(|p| p.len() as u64 + 1).sum();

    let oracle: Vec<Vec<u32>> = prompts
        .iter()
        .map(|text| {
            let mut prompt = vec![BOS];
            prompt.extend(sim_encode(text));
            let mut req = tapout::engine::Request::new(0, text.clone(), max_new);
            req.prompt = prompt.clone();
            let mut target =
                SimModel::target(tapout::models::Scenario::new(req.scenario_seed(), &req.category));
            let cfg = GenConfig { max_new, stop_at_eos: true, ..GenConfig::default() };
            greedy(&mut target, &prompt, &cfg).unwrap().new_tokens().to_vec()
        })
        .collect();

    group(&format!(
        "paged KV: {n_req}-request busy-slot burst ({} shared tokens) through {slots} continuous \
         slots, max_new {max_new} (sim)",
        system.len() + 1
    ));
    let configs =
        [("cache-off", false, false), ("slot-affinity", true, false), ("paged", true, true)];
    let mut computed = [0u64; 3];
    let mut rows: Vec<Json> = Vec::new();
    for (ci, (label, cache, sharing)) in configs.into_iter().enumerate() {
        let eng = Engine::start(EngineConfig {
            method: "seq-ucb1".into(),
            gamma_max: 128,
            sched: Policy::Fcfs,
            slots,
            workers: 0,
            backend: BackendKind::sim_default(),
            mode: EngineMode::Continuous,
            prefix_cache: cache,
            page_sharing: sharing,
            ..EngineConfig::default()
        })
        .unwrap();
        let t0 = Instant::now();
        let rxs: Vec<_> = prompts.iter().map(|p| eng.submit(p, max_new)).collect();
        let outputs: Vec<Vec<u32>> = rxs
            .into_iter()
            .map(|rx| {
                let r = rx.recv().unwrap();
                assert!(r.is_ok(), "{:?}", r.error);
                r.result.new_tokens().to_vec()
            })
            .collect();
        let elapsed_ns = t0.elapsed().as_nanos() as f64;
        assert_eq!(outputs, oracle, "{label}: output diverged from the greedy oracle");
        let cached = eng.cache_stats().cached_tokens.load(Ordering::Relaxed);
        computed[ci] = served_total - cached;
        let pg = eng.page_stats();
        let peak = pg.peak_resident.load(Ordering::Relaxed);
        let shared_hits = pg.shared_hits.load(Ordering::Relaxed);
        let (new_tokens, ttft_p50, ttft_p95) = {
            let mut m = eng.metrics.lock().unwrap();
            (m.new_tokens, m.ttft_ms.percentile(50.0), m.ttft_ms.percentile(95.0))
        };
        let tok_s = new_tokens as f64 / (elapsed_ns / 1e9);
        println!(
            "  {label:<13}: {tok_s:>9.0} tok/s  ttft p50 {ttft_p50:.2} ms  prefill computed \
             {}/{}  peak pages {peak}  shared hits {shared_hits}",
            computed[ci], served_total,
        );
        let mut row = Json::obj();
        row.set("config", label)
            .set("throughput_tok_s", tok_s)
            .set("wall_ms", elapsed_ns / 1e6)
            .set("ttft_p50_ms", ttft_p50)
            .set("ttft_p95_ms", ttft_p95)
            .set("prefill_tokens_served", served_total as usize)
            .set("prefill_tokens_computed", computed[ci] as usize)
            .set("cached_tokens", cached as usize)
            .set("peak_pages_resident", peak as usize)
            .set("pages_total", pg.total.load(Ordering::Relaxed) as usize)
            .set("shared_hits", shared_hits as usize)
            .set("cow_copies", pg.cow_copies.load(Ordering::Relaxed) as usize)
            .set("evictions", pg.evictions.load(Ordering::Relaxed) as usize);
        rows.push(row);
        eng.shutdown();
    }
    println!(
        "    prefill computed: off {} vs affinity {} vs paged {}  (paged {:.2}x fewer than \
         affinity)",
        computed[0],
        computed[1],
        computed[2],
        computed[1] as f64 / computed[2].max(1) as f64
    );
    assert!(
        computed[1] < computed[0],
        "slot-affinity must beat cache-off ({} vs {})",
        computed[1],
        computed[0]
    );
    assert!(
        computed[2] < computed[1],
        "with concurrency > slots the paged engine must compute strictly fewer prefill tokens \
         than slot-affinity ({} paged vs {} affinity)",
        computed[2],
        computed[1]
    );
    report
        .set("requests", n_req)
        .set("max_new", max_new)
        .set("shared_prefix_tokens", system.len() + 1)
        .set("slots", slots)
        .set("configs", rows);
}

/// Prefix-reuse KV cache on a shared-system-prompt workload
/// (docs/ARCHITECTURE.md §12): the same burst — one long shared prefix,
/// short unique suffixes — through the Workers engine at slots {1, 4}
/// and the Continuous engine at slots {4}, each with the cache off and
/// on. Outputs are asserted byte-identical across every configuration
/// and against the target-only greedy oracle (the cache is lossless);
/// the headline quantity is **prefill tokens computed vs served**:
/// served is the prompt tokens each engine was asked to cover, computed
/// is what it actually forwarded after cache hits. At slots ≥ 4 the
/// cache-on engines must compute strictly fewer prefill tokens — the
/// assert CI gates on — and TTFT p50 drops with them (reported in the
/// JSON rows).
fn prefix_cache_bench(report: &mut Json) {
    use std::sync::atomic::Ordering;
    let fast = std::env::var("TAPOUT_BENCH_FAST").map(|v| v == "1").unwrap_or(false);
    let (n_req, max_new) = if fast { (16, 32) } else { (32, 64) };
    let system =
        "system: you are a terse serving assistant; answer from the shared template, cite the \
         shared context, and stop. "
            .repeat(3);
    let prompts: Vec<String> =
        (0..n_req).map(|i| format!("{system}user {i}: question number {i} please")).collect();
    // prompt tokens the engine must cover per request: BOS + one token
    // per byte (the sim codec)
    let served_total: u64 = prompts.iter().map(|p| p.len() as u64 + 1).sum();

    // the greedy oracle per prompt (the lossless reference)
    let oracle: Vec<Vec<u32>> = prompts
        .iter()
        .map(|text| {
            let mut prompt = vec![BOS];
            prompt.extend(sim_encode(text));
            let mut req = tapout::engine::Request::new(0, text.clone(), max_new);
            req.prompt = prompt.clone();
            let mut target =
                SimModel::target(tapout::models::Scenario::new(req.scenario_seed(), &req.category));
            let cfg = GenConfig { max_new, stop_at_eos: true, ..GenConfig::default() };
            greedy(&mut target, &prompt, &cfg).unwrap().new_tokens().to_vec()
        })
        .collect();

    group(&format!(
        "prefix cache: {n_req}-request shared-prefix burst ({} shared tokens), max_new {max_new} (sim)",
        system.len() + 1
    ));
    let mut rows: Vec<Json> = Vec::new();
    for (mode, slots) in
        [(EngineMode::Workers, 1usize), (EngineMode::Workers, 4), (EngineMode::Continuous, 4)]
    {
        let mut computed = [0u64; 2];
        for (ci, cache) in [false, true].into_iter().enumerate() {
            let eng = Engine::start(EngineConfig {
                method: "seq-ucb1".into(),
                gamma_max: 128,
                sched: Policy::Fcfs,
                slots,
                workers: slots,
                backend: BackendKind::sim_default(),
                mode,
                prefix_cache: cache,
                ..EngineConfig::default()
            })
            .unwrap();
            let t0 = Instant::now();
            let rxs: Vec<_> = prompts.iter().map(|p| eng.submit(p, max_new)).collect();
            let outputs: Vec<Vec<u32>> = rxs
                .into_iter()
                .map(|rx| {
                    let r = rx.recv().unwrap();
                    assert!(r.is_ok(), "{:?}", r.error);
                    r.result.new_tokens().to_vec()
                })
                .collect();
            let elapsed_ns = t0.elapsed().as_nanos() as f64;
            assert_eq!(
                outputs, oracle,
                "{} slots={slots} cache={cache}: output diverged from the greedy oracle",
                mode.label()
            );
            let cached = eng.cache_stats().cached_tokens.load(Ordering::Relaxed);
            let hit_rate = eng.cache_stats().hit_rate();
            computed[ci] = served_total - cached;
            let (new_tokens, ttft_p50, ttft_p95) = {
                let mut m = eng.metrics.lock().unwrap();
                (m.new_tokens, m.ttft_ms.percentile(50.0), m.ttft_ms.percentile(95.0))
            };
            let tok_s = new_tokens as f64 / (elapsed_ns / 1e9);
            println!(
                "  {:<10} slots={slots} cache={:<5}: {tok_s:>9.0} tok/s  ttft p50 {ttft_p50:.2} ms  \
                 prefill computed {}/{} (hit rate {hit_rate:.2})",
                mode.label(),
                cache,
                computed[ci],
                served_total,
            );
            let mut row = Json::obj();
            row.set("mode", mode.label())
                .set("slots", slots)
                .set("cache", cache)
                .set("throughput_tok_s", tok_s)
                .set("wall_ms", elapsed_ns / 1e6)
                .set("ttft_p50_ms", ttft_p50)
                .set("ttft_p95_ms", ttft_p95)
                .set("prefill_tokens_served", served_total as usize)
                .set("prefill_tokens_computed", computed[ci] as usize)
                .set("cached_tokens", cached as usize)
                .set("hit_rate", hit_rate);
            rows.push(row);
            eng.shutdown();
        }
        println!(
            "    prefill computed: off {} vs on {}  ({:.2}x fewer)",
            computed[0],
            computed[1],
            computed[0] as f64 / computed[1].max(1) as f64
        );
        if slots >= 4 {
            assert!(
                computed[1] < computed[0],
                "{} slots={slots}: the prefix cache must compute strictly fewer prefill tokens \
                 ({} on vs {} off)",
                mode.label(),
                computed[1],
                computed[0]
            );
        }
    }
    report
        .set("requests", n_req)
        .set("max_new", max_new)
        .set("shared_prefix_tokens", system.len() + 1)
        .set("modes", rows);
}

/// Workers vs Continuous execution core at slots {1, 2, 4, 8} on the sim
/// backend (docs/ARCHITECTURE.md §11): the same request burst through
/// the thread-per-request worker pool and through the continuous-batching
/// step loop. Outputs are asserted byte-identical (lossless greedy
/// speculative decoding), so the comparison isolates the execution
/// model. The headline quantity is the *draft dispatch count*
/// (`engine.draft.forwards`): the step loop coalesces every in-flight
/// session's drafting into one forward per micro-round, so at slots ≥ 4
/// it must dispatch strictly fewer draft forwards than the worker pool —
/// the per-round kernel-launch amortization BanditSpec-style serving
/// loops buy.
fn continuous_vs_workers(report: &mut Json) {
    let fast = std::env::var("TAPOUT_BENCH_FAST").map(|v| v == "1").unwrap_or(false);
    let (n_req, max_new) = if fast { (16, 48) } else { (48, 128) };
    let cats = ["coding", "qa", "writing", "math", "extraction"];
    let prompts: Vec<String> = (0..n_req)
        .map(|i| format!("{} continuous bench request {i} with a moderately long body", cats[i % cats.len()]))
        .collect();

    group(&format!(
        "execution core: Workers vs Continuous, {n_req}-request burst, max_new {max_new} (sim)"
    ));
    let mut reference: Vec<Vec<u32>> = Vec::new();
    let mut rows: Vec<Json> = Vec::new();
    for slots in [1usize, 2, 4, 8] {
        let mut forwards = [0u64; 2];
        for (mi, mode) in [EngineMode::Workers, EngineMode::Continuous].into_iter().enumerate() {
            let eng = Engine::start(EngineConfig {
                method: "seq-ucb1".into(),
                gamma_max: 128,
                sched: Policy::Fcfs,
                slots,
                workers: slots,
                backend: BackendKind::sim_default(),
                verify_batch: BatchConfig::default(),
                mode,
                ..EngineConfig::default()
            })
            .unwrap();
            let t0 = Instant::now();
            let rxs: Vec<_> = prompts.iter().map(|p| eng.submit(p, max_new)).collect();
            let outputs: Vec<Vec<u32>> = rxs
                .into_iter()
                .map(|rx| {
                    let r = rx.recv().unwrap();
                    assert!(r.is_ok(), "{:?}", r.error);
                    r.result.new_tokens().to_vec()
                })
                .collect();
            let elapsed_ns = t0.elapsed().as_nanos() as f64;
            if reference.is_empty() {
                reference = outputs;
            } else {
                assert_eq!(
                    outputs, reference,
                    "{} slots={slots}: output diverged from the reference burst",
                    mode.label()
                );
            }
            let (new_tokens, lat) = {
                let mut m = eng.metrics.lock().unwrap();
                let mut lat = Json::obj();
                lat.set("ttft_p50_ms", m.ttft_ms.percentile(50.0))
                    .set("ttft_p95_ms", m.ttft_ms.percentile(95.0))
                    .set("tpot_p50_ms", m.tpot_ms.percentile(50.0))
                    .set("tpot_p95_ms", m.tpot_ms.percentile(95.0));
                (m.new_tokens, lat)
            };
            use std::sync::atomic::Ordering;
            let fw = eng.stats.draft.forwards.load(Ordering::Relaxed);
            let occ = eng.stats.draft.mean_occupancy();
            forwards[mi] = fw;
            let tok_s = new_tokens as f64 / (elapsed_ns / 1e9);
            println!(
                "  {:<10} slots={slots}: {new_tokens} tokens in {}  -> {tok_s:>9.0} tok/s  \
                 [draft forwards {fw}, occupancy {occ:.2}]",
                mode.label(),
                fmt_ns(elapsed_ns),
            );
            let mut row = Json::obj();
            row.set("mode", mode.label())
                .set("slots", slots)
                .set("throughput_tok_s", tok_s)
                .set("wall_ms", elapsed_ns / 1e6)
                .set("draft_forwards", fw as usize)
                .set("draft_occupancy", occ)
                .set("latency", lat);
            rows.push(row);
            eng.shutdown();
        }
        println!(
            "    draft dispatches: workers {} vs continuous {}  ({:.2}x fewer)",
            forwards[0],
            forwards[1],
            forwards[0] as f64 / forwards[1].max(1) as f64
        );
        if slots >= 4 {
            assert!(
                forwards[1] < forwards[0],
                "slots {slots}: the step loop must dispatch fewer draft forwards \
                 ({} continuous vs {} workers)",
                forwards[1],
                forwards[0]
            );
        }
    }
    report.set("requests", n_req).set("max_new", max_new).set("modes", rows);
}

/// Multi-worker serving throughput, sequential vs batched verification,
/// on the sim backend (runs everywhere): the same request burst through
/// 1, 2, and 4 decode workers sharing one online bandit, once with the
/// batcher off (the PR 1 engine) and once with cross-session batched
/// verification (docs/ARCHITECTURE.md §4). Outputs are asserted
/// byte-identical across every mode and worker count (lossless greedy
/// speculative decoding), so the comparison isolates engine overhead;
/// the batched rows also report target-forward amortization (sessions
/// per forward) — the quantity that buys real hardware batched matmuls.
fn serving_scaling(report: &mut Json) {
    let fast = std::env::var("TAPOUT_BENCH_FAST").map(|v| v == "1").unwrap_or(false);
    let (n_req, max_new) = if fast { (16, 48) } else { (64, 160) };
    let cats = ["coding", "qa", "writing", "math", "extraction"];
    let prompts: Vec<String> = (0..n_req)
        .map(|i| format!("{} benchmark request {i} with a moderately long prompt body", cats[i % cats.len()]))
        .collect();

    group(&format!(
        "engine serving: {n_req}-request burst, max_new {max_new} (sim backend)"
    ));
    let mut baseline_ns = 0.0;
    let mut reference: Vec<Vec<u32>> = Vec::new();
    let mut batched_4w_tok_s = 0.0;
    let mut sequential_4w_tok_s = 0.0;
    let mut mode_rows: Vec<Json> = Vec::new();
    for (label, batch) in [("sequential", BatchConfig::off()), ("batched", BatchConfig::default())]
    {
        for workers in [1usize, 2, 4] {
            let eng = Engine::start(EngineConfig {
                method: "seq-ucb1".into(),
                gamma_max: 128,
                sched: Policy::Fcfs,
                slots: workers,
                workers,
                backend: BackendKind::sim_default(),
                verify_batch: batch,
                ..EngineConfig::default()
            })
            .unwrap();
            let t0 = Instant::now();
            let rxs: Vec<_> = prompts.iter().map(|p| eng.submit(p, max_new)).collect();
            let outputs: Vec<Vec<u32>> = rxs
                .into_iter()
                .map(|rx| {
                    let r = rx.recv().unwrap();
                    assert!(r.is_ok(), "{:?}", r.error);
                    r.result.new_tokens().to_vec()
                })
                .collect();
            let elapsed_ns = t0.elapsed().as_nanos() as f64;
            if reference.is_empty() {
                reference = outputs;
            } else {
                assert_eq!(
                    outputs, reference,
                    "{label} workers={workers}: output diverged from sequential 1-worker"
                );
            }
            // latency distributions for the machine-readable report:
            // TTFT and per-output-token time percentiles per mode/worker
            let (new_tokens, sessions, lat) = {
                let mut m = eng.metrics.lock().unwrap();
                let mut lat = Json::obj();
                lat.set("ttft_p50_ms", m.ttft_ms.percentile(50.0))
                    .set("ttft_p95_ms", m.ttft_ms.percentile(95.0))
                    .set("ttft_p99_ms", m.ttft_ms.percentile(99.0))
                    .set("tpot_p50_ms", m.tpot_ms.percentile(50.0))
                    .set("tpot_p95_ms", m.tpot_ms.percentile(95.0))
                    .set("tpot_p99_ms", m.tpot_ms.percentile(99.0))
                    .set("e2e_p99_ms", m.total_ms.percentile(99.0));
                (m.new_tokens, eng.bandit_sessions(), lat)
            };
            if workers == 1 && batch.max_batch == 0 {
                baseline_ns = elapsed_ns;
            }
            let tok_s = new_tokens as f64 / (elapsed_ns / 1e9);
            if workers == 4 {
                if batch.max_batch == 0 {
                    sequential_4w_tok_s = tok_s;
                } else {
                    batched_4w_tok_s = tok_s;
                }
            }
            let occupancy = {
                use std::sync::atomic::Ordering;
                let b = eng.stats.batch.batches.load(Ordering::Relaxed);
                if b == 0 {
                    String::new()
                } else {
                    format!(
                        "  [occupancy {:.2}, {} forwards for {} sessions, pad waste {:.0}%]",
                        eng.stats.batch.mean_occupancy(),
                        b,
                        eng.stats.batch.coalesced.load(Ordering::Relaxed),
                        eng.stats.batch.pad_waste_frac() * 100.0
                    )
                }
            };
            println!(
                "  {label:<10} workers={workers}: {} in wall {}  -> {:>9.0} tok/s  ({:.2}x vs sequential, {} bandit sessions){occupancy}",
                new_tokens,
                fmt_ns(elapsed_ns),
                tok_s,
                baseline_ns / elapsed_ns,
                sessions,
            );
            let mut row = Json::obj();
            row.set("mode", label)
                .set("workers", workers)
                .set("throughput_tok_s", tok_s)
                .set("wall_ms", elapsed_ns / 1e6)
                .set("latency", lat);
            mode_rows.push(row);
            eng.shutdown();
        }
    }
    println!(
        "  batched/sequential @ 4 workers: {:.2}x  (>= 1.0 expected: coalesced forwards \
         amortize per-call dispatch)",
        batched_4w_tok_s / sequential_4w_tok_s.max(1e-9)
    );
    report
        .set("requests", n_req)
        .set("max_new", max_new)
        .set("modes", mode_rows);
}

/// Shed rate at 2× overload: the engine's admission capacity is the
/// queue bound plus one in-flight request per worker; a burst of twice
/// that must shed roughly half with 429s while everything admitted still
/// completes correctly. The shed rate lands in `BENCH_serving.json`.
fn overload_shedding(report: &mut Json) {
    let workers = 2usize;
    let max_queue = 8usize;
    let capacity = max_queue + workers;
    let burst = 2 * capacity;

    group(&format!(
        "admission control: {burst}-request burst into capacity {capacity} (2x overload)"
    ));
    let eng = Engine::start(EngineConfig {
        method: "seq-ucb1".into(),
        gamma_max: 128,
        sched: Policy::Fcfs,
        slots: workers,
        workers,
        backend: BackendKind::sim_default(),
        verify_batch: BatchConfig::default(),
        max_queue,
        ..EngineConfig::default()
    })
    .unwrap();
    let rxs: Vec<_> = (0..burst)
        .map(|i| eng.submit(&format!("overload burst request {i} body"), 96))
        .collect();
    let mut done = 0usize;
    let mut rejected = 0usize;
    for rx in rxs {
        let r = rx.recv().unwrap();
        match r.status {
            FinishStatus::Rejected => rejected += 1,
            _ if r.is_ok() => done += 1,
            other => panic!("unexpected terminal status under overload: {other:?}"),
        }
    }
    let shed_rate = rejected as f64 / burst as f64;
    println!(
        "  {done} completed, {rejected} shed of {burst}  -> shed rate {:.0}%  \
         (queue bound {max_queue}, {workers} workers)",
        shed_rate * 100.0
    );
    assert_eq!(done + rejected, burst, "every request gets a terminal reply");
    // the queue bound is a hard floor on admissions (workers drain
    // concurrently, so the real count is usually higher)
    assert!(done >= max_queue, "at least the queue bound must be admitted: {done}");
    eng.shutdown();

    let mut o = Json::obj();
    o.set("workers", workers)
        .set("max_queue", max_queue)
        .set("overload_factor", 2.0)
        .set("submitted", burst)
        .set("completed", done)
        .set("rejected", rejected)
        .set("shed_rate", shed_rate);
    report.set("overload", o);
}

/// One bench per paper artifact, on the simulator backend (the controller
/// + session-loop cost of regenerating each table/figure).
fn sim_tables() {
    let backend = || Backend::Sim { quality: 0.9, rel_cost: 1.0 / 16.0 };
    let items = sim_suite("specbench", 1, 48);
    let m = |s: &str| MethodSpec::parse(s, "artifacts").unwrap();

    group("per-paper-artifact regeneration (sim backend, scaled)");
    bench("table2: ucb1 r_simple vs r_blend", 300, || {
        for spec in [m("seq-ucb1:rsimple"), m("seq-ucb1")] {
            std::hint::black_box(run_method(&backend(), &items, &spec, 128, false).unwrap());
        }
    });
    bench("fig4: ucb1 vs ucb-tuned", 300, || {
        for spec in [m("seq-ucb1"), m("seq-ucb-tuned")] {
            std::hint::black_box(run_method(&backend(), &items, &spec, 128, false).unwrap());
        }
    });
    bench("table3/5 row: one method, 13 cats", 300, || {
        std::hint::black_box(run_method(&backend(), &items, &m("seq-ucb1"), 128, false).unwrap());
    });
    bench("fig2: static-16 probe w/ signals", 300, || {
        std::hint::black_box(run_probe(&backend(), &items, &MethodSpec::Static(16), 16).unwrap());
    });
    bench("fig5/6: ucb1 with value tracking", 300, || {
        std::hint::black_box(run_method(&backend(), &items, &m("seq-ucb1"), 128, true).unwrap());
    });
    bench("abl-arms: 13-arm pool", 300, || {
        std::hint::black_box(run_method(&backend(), &items, &m("seq-ucb1:multi"), 128, false).unwrap());
    });
}

/// PJRT dispatch + block-latency ladder: the real hot-path numbers that
/// dominate serving latency (calibrates OVERHEAD_ROWS in the cost model).
fn pjrt_ladder() {
    let dir = Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("\n[pjrt ladder skipped: run `make artifacts` first]");
        return;
    }
    let manifest = Manifest::load(dir).unwrap();
    let runtime = Runtime::cpu().unwrap();

    group("PJRT block latency ladder (real models)");
    for name in ["draft-base", "target-base"] {
        let assets = ModelAssets::load(&runtime, &manifest, name).unwrap();
        let mut model = PjrtModel::new(assets).unwrap();
        let buckets: Vec<usize> = if name.starts_with("draft") {
            vec![1, 4]
        } else {
            vec![1, 8, 32, 128]
        };
        for &k in &buckets {
            // feed k tokens per call, resetting when the KV fills up
            let toks: Vec<u32> = (0..k as u32).map(|i| 3 + (i % 29)).collect();
            model.reset();
            let r = bench(&format!("{name} block{k}"), 500, || {
                if model.cur() + k >= model.max_seq() {
                    model.reset();
                }
                let start = model.cur();
                std::hint::black_box(model.block(&toks, start).unwrap());
            });
            println!(
                "    -> {} per row ({k} rows/call)",
                fmt_ns(r.mean_ns / k as f64)
            );
        }
    }
}

/// Boot one sim-backend replica (prefix cache + page sharing on) behind
/// its own reactor front end, for the router-tier comparison.
fn bench_replica() -> (Arc<Engine>, HttpServer) {
    let eng = Engine::start(EngineConfig {
        method: "seq-ucb1".into(),
        gamma_max: 64,
        sched: Policy::Fcfs,
        slots: 2,
        workers: 2,
        backend: BackendKind::sim_default(),
        prefix_cache: true,
        page_sharing: true,
        ..EngineConfig::default()
    })
    .unwrap();
    let eng = Arc::new(eng);
    let http = HttpServer::start_with(
        eng.clone(),
        0,
        HttpConfig { io_threads: 2, ..HttpConfig::default() },
    )
    .unwrap();
    (eng, http)
}

/// A router over the replicas, waited on until every one probes alive.
fn bench_router(reps: &[(Arc<Engine>, HttpServer)], affinity: bool) -> Router {
    let cfg = RouterConfig {
        replicas: reps.iter().map(|(_, h)| h.addr.clone()).collect(),
        affinity,
        page_size: 16,
        probe_ms: 50,
        io_threads: 2,
        ..RouterConfig::default()
    };
    let router = Router::start(cfg, 0).unwrap();
    for _ in 0..2400 {
        if (0..reps.len()).all(|i| router.replica_alive(i)) {
            return router;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    panic!("replicas never probed alive");
}

/// Target-only greedy text a routed request must reproduce byte-for-byte.
fn bench_oracle_text(text: &str, max_new: usize) -> String {
    let mut prompt = vec![BOS];
    prompt.extend(sim_encode(text));
    let mut req = tapout::engine::Request::new(0, text, max_new);
    req.prompt = prompt.clone();
    let mut target =
        SimModel::target(tapout::models::Scenario::new(req.scenario_seed(), &req.category));
    let cfg = GenConfig { max_new, stop_at_eos: true, ..GenConfig::default() };
    sim_decode(greedy(&mut target, &prompt, &cfg).unwrap().new_tokens())
}

/// Raw-TCP unary generate; panics unless the reply is HTTP 200.
fn bench_unary(addr: &str, prompt: &str, max_new: usize) -> Json {
    let mut s = TcpStream::connect(addr).unwrap();
    let body = format!("{{\"prompt\": \"{prompt}\", \"max_new\": {max_new}}}");
    write!(s, "POST /generate HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{body}", body.len())
        .unwrap();
    let mut raw = String::new();
    s.read_to_string(&mut raw).unwrap();
    assert!(raw.starts_with("HTTP/1.1 200 "), "unary generate failed:\n{raw}");
    let reply = raw.split_once("\r\n\r\n").map(|x| x.1).unwrap_or("");
    Json::parse(reply).unwrap()
}

/// De-chunk a raw SSE response and concatenate its token-event text.
fn bench_sse_text(raw: &str) -> String {
    let body = raw.split_once("\r\n\r\n").map(|x| x.1).unwrap_or("");
    let mut data = String::new();
    let mut rest = body;
    while let Some((size_str, after)) = rest.split_once("\r\n") {
        let Ok(size) = usize::from_str_radix(size_str.trim(), 16) else { break };
        if size == 0 || after.len() < size + 2 {
            break;
        }
        data.push_str(&after[..size]);
        rest = &after[size + 2..];
    }
    data.split("\n\n")
        .filter_map(|ev| ev.trim_end().strip_prefix("data: "))
        .filter_map(|p| Json::parse(p).ok())
        .filter(|j| j.get("done").and_then(|d| d.as_bool()) != Some(true))
        .filter_map(|j| j.get("text").and_then(|t| t.as_str()).map(str::to_string))
        .collect()
}

/// One streaming generate over raw TCP. Returns (client-observed TTFT in
/// ms — first sighting of an SSE data frame — and the concatenated
/// stream text).
fn bench_stream(addr: &str, prompt: &str, max_new: usize) -> (f64, String) {
    let mut s = TcpStream::connect(addr).unwrap();
    let body = format!("{{\"prompt\": \"{prompt}\", \"max_new\": {max_new}, \"stream\": true}}");
    let t0 = Instant::now();
    write!(s, "POST /generate HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{body}", body.len())
        .unwrap();
    let mut raw = String::new();
    let mut buf = [0u8; 4096];
    let mut ttft_ms = 0.0;
    loop {
        match s.read(&mut buf) {
            Ok(0) => break,
            Ok(n) => {
                raw.push_str(&String::from_utf8_lossy(&buf[..n]));
                if ttft_ms == 0.0 && raw.contains("data: ") {
                    ttft_ms = t0.elapsed().as_nanos() as f64 / 1e6;
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => panic!("stream read: {e}"),
        }
    }
    (ttft_ms, bench_sse_text(&raw))
}

/// Multi-replica router tier (docs/ARCHITECTURE.md §15): the same
/// grouped same-prefix workload through two replicas under
/// prefix-affinity placement and under round-robin. Outputs are asserted
/// byte-identical to the greedy oracle under both placements (routing is
/// policy, never correctness). The headline quantity is the aggregate
/// fleet prefix-cache hit count: consistent hashing on the first prompt
/// page keeps each group on one replica so its cache concentrates, and
/// the CI gate asserts affinity aggregates strictly more hits than
/// round-robin. Also reported: throughput + client-observed TTFT at 1 vs
/// 2 replicas under concurrent streaming clients, and a held
/// concurrent-stream row on a single reactor front end.
fn router_bench(report: &mut Json) {
    use std::sync::atomic::Ordering;
    let fast = std::env::var("TAPOUT_BENCH_FAST").map(|v| v == "1").unwrap_or(false);
    let (groups, per_group, max_new) = if fast { (4usize, 4usize, 16usize) } else { (8, 6, 32) };
    // the group tag sits inside the first-page routing window (BOS + 15
    // prompt bytes at page size 16); the request index lands outside it
    let gp = |g: usize, i: usize| format!("g{g} router bench head :: request {i} summarize");

    group(&format!(
        "router tier: {groups}x{per_group}-request same-prefix groups through 2 replicas, \
         affinity vs round-robin (sim)"
    ));
    let mut agg_hits = [0u64; 2];
    let mut placement_rows: Vec<Json> = Vec::new();
    for (ci, (label, affinity)) in
        [("affinity", true), ("round-robin", false)].into_iter().enumerate()
    {
        let reps: Vec<(Arc<Engine>, HttpServer)> = (0..2).map(|_| bench_replica()).collect();
        let router = bench_router(&reps, affinity);
        let t0 = Instant::now();
        for g in 0..groups {
            for i in 0..per_group {
                let p = gp(g, i);
                let j = bench_unary(&router.addr, &p, max_new);
                assert_eq!(j.get("status").and_then(|x| x.as_str()), Some("done"));
                let want = bench_oracle_text(&p, max_new);
                assert_eq!(
                    j.get("text").and_then(|x| x.as_str()),
                    Some(want.as_str()),
                    "{label}: routed output diverged from the greedy oracle"
                );
            }
        }
        let elapsed_ns = t0.elapsed().as_nanos() as f64;
        let mut hits = 0u64;
        let mut lookups = 0u64;
        let mut new_tokens = 0u64;
        for (eng, _) in &reps {
            hits += eng.cache_stats().hits.load(Ordering::Relaxed);
            lookups += eng.cache_stats().lookups.load(Ordering::Relaxed);
            new_tokens += eng.metrics.lock().unwrap().new_tokens;
        }
        agg_hits[ci] = hits;
        let rate = hits as f64 / lookups.max(1) as f64;
        let tok_s = new_tokens as f64 / (elapsed_ns / 1e9);
        println!(
            "  {label:<12}: {tok_s:>9.0} tok/s  fleet cache {hits}/{lookups} (hit rate {rate:.2})"
        );
        let mut row = Json::obj();
        row.set("placement", label)
            .set("replicas", 2usize)
            .set("requests", groups * per_group)
            .set("throughput_tok_s", tok_s)
            .set("wall_ms", elapsed_ns / 1e6)
            .set("cache_hits", hits as usize)
            .set("cache_lookups", lookups as usize)
            .set("hit_rate", rate);
        placement_rows.push(row);
    }
    // CI gate: prefix affinity must concentrate same-prefix groups well
    // enough that the fleet prefix cache beats round-robin placement
    assert!(
        agg_hits[0] > agg_hits[1],
        "prefix-affinity placement must aggregate strictly more fleet cache hits than \
         round-robin ({} vs {})",
        agg_hits[0],
        agg_hits[1]
    );

    // 1 vs 2 replicas under concurrent streaming clients: throughput and
    // client-observed TTFT through the router front end
    let (n_clients, per_client) = if fast { (8usize, 2usize) } else { (16, 3) };
    group(&format!(
        "router scaling: {n_clients} concurrent streaming clients x {per_client} requests, \
         1 vs 2 replicas (sim)"
    ));
    let mut scale_rows: Vec<Json> = Vec::new();
    for n_replicas in [1usize, 2] {
        let reps: Vec<(Arc<Engine>, HttpServer)> =
            (0..n_replicas).map(|_| bench_replica()).collect();
        let router = bench_router(&reps, true);
        let addr = router.addr.clone();
        let t0 = Instant::now();
        let handles: Vec<_> = (0..n_clients)
            .map(|c| {
                let addr = addr.clone();
                std::thread::spawn(move || {
                    let mut ttfts = Vec::new();
                    for r in 0..per_client {
                        let p = format!("c{c:02} scale head :: streamed request {r}");
                        let (ttft_ms, text) = bench_stream(&addr, &p, max_new);
                        assert_eq!(text, bench_oracle_text(&p, max_new), "stream diverged");
                        ttfts.push(ttft_ms);
                    }
                    ttfts
                })
            })
            .collect();
        let mut ttfts: Vec<f64> = handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
        let elapsed_ns = t0.elapsed().as_nanos() as f64;
        ttfts.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pct = |p: f64| ttfts[((ttfts.len() - 1) as f64 * p / 100.0).round() as usize];
        let new_tokens: u64 = reps.iter().map(|(e, _)| e.metrics.lock().unwrap().new_tokens).sum();
        let tok_s = new_tokens as f64 / (elapsed_ns / 1e9);
        println!(
            "  replicas={n_replicas}: {tok_s:>9.0} tok/s  ttft p50 {:.2} ms  p95 {:.2} ms",
            pct(50.0),
            pct(95.0)
        );
        let mut row = Json::obj();
        row.set("replicas", n_replicas)
            .set("clients", n_clients)
            .set("streams", ttfts.len())
            .set("throughput_tok_s", tok_s)
            .set("wall_ms", elapsed_ns / 1e6)
            .set("ttft_p50_ms", pct(50.0))
            .set("ttft_p95_ms", pct(95.0));
        scale_rows.push(row);
    }

    // held concurrent streams against one reactor front end: every
    // stream is submitted before any is drained, so all are in flight
    // at once on a 2-thread I/O pool
    let held = if fast { 32usize } else { 64 };
    group(&format!("router front end: {held} held concurrent SSE streams, 2 I/O threads (sim)"));
    let (eng, http) = bench_replica();
    let t0 = Instant::now();
    let mut socks: Vec<(TcpStream, String)> = Vec::new();
    for i in 0..held {
        let p = format!("s{i:02} held head :: concurrent stream body");
        let mut s = TcpStream::connect(&http.addr).unwrap();
        let body = format!("{{\"prompt\": \"{p}\", \"max_new\": {max_new}, \"stream\": true}}");
        write!(
            s,
            "POST /generate HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        )
        .unwrap();
        socks.push((s, p));
    }
    for (mut s, p) in socks {
        let mut raw = String::new();
        s.read_to_string(&mut raw).unwrap();
        assert_eq!(bench_sse_text(&raw), bench_oracle_text(&p, max_new), "held stream diverged");
    }
    let elapsed_ns = t0.elapsed().as_nanos() as f64;
    let peak_open = http.stats.peak_open.load(Ordering::Relaxed);
    let new_tokens = eng.metrics.lock().unwrap().new_tokens;
    let tok_s = new_tokens as f64 / (elapsed_ns / 1e9);
    println!(
        "  {held} held streams on one reactor (2 I/O threads): {tok_s:>9.0} tok/s  \
         peak open {peak_open}"
    );
    let mut held_row = Json::obj();
    held_row
        .set("streams", held)
        .set("io_threads", 2usize)
        .set("throughput_tok_s", tok_s)
        .set("wall_ms", elapsed_ns / 1e6)
        .set("peak_open_connections", peak_open as usize);
    report
        .set("requests_per_placement", groups * per_group)
        .set("max_new", max_new)
        .set("placement", placement_rows)
        .set("replica_scaling", scale_rows)
        .set("held_streams", held_row);
}
