"""L2: tiny char-level transformer LMs (draft + target zoo) in JAX.

Two views of the same weights:
  * training view — ``forward_train(params, tokens)`` over a params pytree;
  * AOT view — ``block(wflat, world, tokens, start)`` over a *flat* weight
    vector and a *flat* "world" state buffer (KV cache + out region), the
    form lowered to HLO text and executed from rust via PJRT ``execute_b``.

The AOT contract (see DESIGN.md §4):
  * one function family per model: ``block_K`` processes K tokens starting
    at position ``start`` (K=1 is the decode step; K=P is prefill; K≥k is
    verification of k tokens, padded);
  * world = [ kv-cache | out-region ]; the function returns the updated
    world as a single non-tuple root so rust can feed the returned buffer
    straight back without host round-trips;
  * out-region rows: for each of the K positions, the fused L1 stop-signal
    head (kernels/signals.py) writes ``SIG_WIDTH`` floats
    [argmax, top1_p, top2_p, margin, entropy, sqrt_entropy, logsumexp,
    max_logit].
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from .kernels.signals import SIG_WIDTH, signal_head
from . import corpus

MAX_SEQ = 384
K_LADDER = [1, 4, 8, 16, 32, 64, 128, 256, 384]
OUT_ROWS = MAX_SEQ  # out region can hold signals for a full prefill
EPS = 1e-6


@dataclass(frozen=True)
class ModelConfig:
    name: str
    d_model: int
    n_layers: int
    n_heads: int
    vocab: int = corpus.VOCAB_SIZE
    max_seq: int = MAX_SEQ
    train_steps: int = 300
    train_batch: int = 12
    train_seq: int = 128
    lr: float = 3e-3
    corpus_chars: int = 400_000
    corpus_seed: int = 1234
    mix: dict = field(default_factory=dict, hash=False)

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    @property
    def kv_elems(self) -> int:
        return self.n_layers * 2 * self.max_seq * self.d_model

    @property
    def out_elems(self) -> int:
        return OUT_ROWS * SIG_WIDTH

    @property
    def world_elems(self) -> int:
        return self.kv_elems + self.out_elems


# The model zoo (DESIGN.md §3): 2 targets + 3 drafts -> 4 paper-analog pairs.
MODEL_ZOO: dict[str, ModelConfig] = {
    "target-base": ModelConfig("target-base", d_model=128, n_layers=6, n_heads=4,
                               train_steps=320, train_batch=12),
    "target-big": ModelConfig("target-big", d_model=160, n_layers=8, n_heads=5,
                              train_steps=320, train_batch=8),
    "draft-base": ModelConfig("draft-base", d_model=64, n_layers=2, n_heads=2,
                              train_steps=400, train_batch=16),
    "draft-tiny": ModelConfig("draft-tiny", d_model=32, n_layers=1, n_heads=1,
                              train_steps=400, train_batch=16),
    # misaligned draft: trained on a skewed category mixture (OLMo-pair analog)
    "draft-skew": ModelConfig("draft-skew", d_model=64, n_layers=2, n_heads=2,
                              train_steps=400, train_batch=16, corpus_seed=99,
                              mix={"coding": 0.1, "math": 0.1, "translation": 0.1}),
}

# paper-analog model pairs (draft, target)
PAIRS = {
    "pair-a": ("draft-base", "target-base"),   # ~ Llama-3 1B/8B
    "pair-b": ("draft-base", "target-big"),    # ~ Llama-3 1B/70B
    "pair-c": ("draft-tiny", "target-base"),   # ~ Gemma3 270M/27B
    "pair-d": ("draft-skew", "target-big"),    # ~ OLMo-2 1B/32B
}


# --- parameters --------------------------------------------------------------


def init_params(cfg: ModelConfig, seed: int = 0) -> dict:
    k = jax.random.PRNGKey(seed)
    d, v = cfg.d_model, cfg.vocab
    ks = jax.random.split(k, 2 + 6 * cfg.n_layers)
    s = 0.02
    params = {
        "emb": jax.random.normal(ks[0], (v, d)) * s,
        "pos": jax.random.normal(ks[1], (cfg.max_seq, d)) * s,
        "lnf": jnp.ones((d,)),
        "layers": [],
    }
    for i in range(cfg.n_layers):
        b = ks[2 + 6 * i: 8 + 6 * i]
        params["layers"].append({
            "ln1": jnp.ones((d,)),
            "wq": jax.random.normal(b[0], (d, d)) * s,
            "wk": jax.random.normal(b[1], (d, d)) * s,
            "wv": jax.random.normal(b[2], (d, d)) * s,
            "wo": jax.random.normal(b[3], (d, d)) * s,
            "ln2": jnp.ones((d,)),
            "w1": jax.random.normal(b[4], (d, 4 * d)) * s,
            "w2": jax.random.normal(b[5], (4 * d, d)) * s,
        })
    return params


def _leaves(cfg: ModelConfig):
    """Deterministic (name, shape) layout of the flat weight vector."""
    d, v = cfg.d_model, cfg.vocab
    out = [("emb", (v, d)), ("pos", (cfg.max_seq, d)), ("lnf", (d,))]
    for i in range(cfg.n_layers):
        out += [
            (f"l{i}.ln1", (d,)), (f"l{i}.wq", (d, d)), (f"l{i}.wk", (d, d)),
            (f"l{i}.wv", (d, d)), (f"l{i}.wo", (d, d)), (f"l{i}.ln2", (d,)),
            (f"l{i}.w1", (d, 4 * d)), (f"l{i}.w2", (4 * d, d)),
        ]
    return out


def param_count(cfg: ModelConfig) -> int:
    return sum(int(np.prod(s)) for _, s in _leaves(cfg))


def pack_params(cfg: ModelConfig, params: dict) -> np.ndarray:
    flat = {"emb": params["emb"], "pos": params["pos"], "lnf": params["lnf"]}
    for i, l in enumerate(params["layers"]):
        for kname, val in l.items():
            flat[f"l{i}.{kname}"] = val
    chunks = [np.asarray(flat[n], np.float32).reshape(-1) for n, _ in _leaves(cfg)]
    return np.concatenate(chunks)


def unpack_params(cfg: ModelConfig, wflat: jnp.ndarray) -> dict:
    params: dict = {"layers": [{} for _ in range(cfg.n_layers)]}
    off = 0
    for name, shape in _leaves(cfg):
        n = int(np.prod(shape))
        arr = jax.lax.dynamic_slice(wflat, (off,), (n,)).reshape(shape)
        off += n
        if "." in name:
            li, kname = name.split(".")
            params["layers"][int(li[1:])][kname] = arr
        else:
            params[name] = arr
    return params


# --- core ops ----------------------------------------------------------------


def rmsnorm(x, g):
    return x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + EPS) * g


def _mlp(layer, x):
    return jax.nn.gelu(x @ layer["w1"]) @ layer["w2"]


def forward_train(cfg: ModelConfig, params: dict, tokens: jnp.ndarray) -> jnp.ndarray:
    """Full causal forward for training. tokens [B,T] -> logits [B,T,V]."""
    B, T = tokens.shape
    h = params["emb"][tokens] + params["pos"][:T][None]
    mask = jnp.tril(jnp.ones((T, T), bool))
    for layer in params["layers"]:
        x = rmsnorm(h, layer["ln1"])
        q = (x @ layer["wq"]).reshape(B, T, cfg.n_heads, cfg.head_dim)
        k = (x @ layer["wk"]).reshape(B, T, cfg.n_heads, cfg.head_dim)
        v = (x @ layer["wv"]).reshape(B, T, cfg.n_heads, cfg.head_dim)
        att = jnp.einsum("bqhd,bkhd->bhqk", q, k) / np.sqrt(cfg.head_dim)
        att = jnp.where(mask[None, None], att, -1e30)
        att = jax.nn.softmax(att, axis=-1)
        o = jnp.einsum("bhqk,bkhd->bqhd", att, v).reshape(B, T, cfg.d_model)
        h = h + o @ layer["wo"]
        h = h + _mlp(layer, rmsnorm(h, layer["ln2"]))
    return rmsnorm(h, params["lnf"]) @ params["emb"].T


def loss_fn(cfg: ModelConfig, params: dict, tokens: jnp.ndarray) -> jnp.ndarray:
    logits = forward_train(cfg, params, tokens[:, :-1])
    logp = jax.nn.log_softmax(logits, axis=-1)
    tgt = tokens[:, 1:]
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    return nll.mean()


# --- AOT block over the packed world -----------------------------------------


def split_world(cfg: ModelConfig, world: jnp.ndarray):
    kv = world[: cfg.kv_elems].reshape(cfg.n_layers, 2, cfg.max_seq, cfg.d_model)
    out = world[cfg.kv_elems:]
    return kv, out


def join_world(cfg: ModelConfig, kv: jnp.ndarray, out: jnp.ndarray) -> jnp.ndarray:
    return jnp.concatenate([kv.reshape(-1), out])


def block_fn(cfg: ModelConfig, K: int, wflat, world, tokens, start):
    """Process K tokens starting at absolute position ``start``.

    wflat  f32[param_count]  — flat weights (device-resident, loaded once)
    world  f32[world_elems]  — KV cache + out region (device-resident loop)
    tokens i32[K]            — input tokens (may include right padding)
    start  i32[]             — absolute position of tokens[0]

    Returns the updated world. Writes kv[start:start+K] and the signal
    matrix [K, SIG_WIDTH] at the head of the out region.
    """
    params = unpack_params(cfg, wflat)
    kv, _ = split_world(cfg, world)
    S, H, Dh = cfg.max_seq, cfg.n_heads, cfg.head_dim

    positions = start + jnp.arange(K, dtype=jnp.int32)            # [K]
    h = params["emb"][tokens] + params["pos"][positions]          # [K,d]
    cols = jnp.arange(S, dtype=jnp.int32)                         # [S]
    # row i may attend to absolute positions <= start+i
    mask = cols[None, :] <= positions[:, None]                    # [K,S]

    for li, layer in enumerate(params["layers"]):
        x = rmsnorm(h, layer["ln1"])
        q = (x @ layer["wq"]).reshape(K, H, Dh)
        knew = x @ layer["wk"]                                    # [K,d]
        vnew = x @ layer["wv"]
        kv = jax.lax.dynamic_update_slice(kv, knew[None, None], (li, 0, start, 0))
        kv = jax.lax.dynamic_update_slice(kv, vnew[None, None], (li, 1, start, 0))
        kcache = kv[li, 0].reshape(S, H, Dh)
        vcache = kv[li, 1].reshape(S, H, Dh)
        att = jnp.einsum("khd,shd->hks", q, kcache) / np.sqrt(Dh)
        att = jnp.where(mask[None], att, -1e30)
        att = jax.nn.softmax(att, axis=-1)
        o = jnp.einsum("hks,shd->khd", att, vcache).reshape(K, cfg.d_model)
        h = h + o @ layer["wo"]
        h = h + _mlp(layer, rmsnorm(h, layer["ln2"]))

    logits = rmsnorm(h, params["lnf"]) @ params["emb"].T          # [K,V]
    sig = signal_head(logits)                                     # [K,SIG_WIDTH]
    out = jnp.zeros((OUT_ROWS, SIG_WIDTH), jnp.float32)
    out = jax.lax.dynamic_update_slice(out, sig, (0, 0))
    return join_world(cfg, kv, out.reshape(-1))


def make_block(cfg: ModelConfig, K: int):
    def fn(wflat, world, tokens, start):
        return block_fn(cfg, K, wflat, world, tokens, start)
    fn.__name__ = f"{cfg.name}_block{K}"
    return fn


def example_args(cfg: ModelConfig, K: int):
    return (
        jax.ShapeDtypeStruct((param_count(cfg),), jnp.float32),
        jax.ShapeDtypeStruct((cfg.world_elems,), jnp.float32),
        jax.ShapeDtypeStruct((K,), jnp.int32),
        jax.ShapeDtypeStruct((), jnp.int32),
    )
