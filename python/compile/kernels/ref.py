"""Pure-jnp oracle for the fused stop-signal head (kernels/signals.py).

This is the correctness reference: python/tests/test_kernel.py sweeps shapes
and distributions (hypothesis) and asserts allclose against this module.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .signals import SIG_WIDTH  # noqa: F401  (re-exported for tests)


def signal_head_ref(logits: jnp.ndarray) -> jnp.ndarray:
    """logits [K, V] f32 -> signals [K, SIG_WIDTH] f32 (see signals.py)."""
    m = jnp.max(logits, axis=-1)
    idx = jnp.argmax(logits, axis=-1)
    p = jax.nn.softmax(logits, axis=-1)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    top1 = jnp.max(p, axis=-1)
    masked = jnp.where(
        jnp.arange(logits.shape[-1])[None] == idx[:, None], -jnp.inf, logits
    )
    top2 = jnp.exp(jnp.max(masked, axis=-1) - lse)
    # entropy via the numerically-stable identity H = lse - E_p[x]
    ent = jnp.maximum(lse - jnp.sum(p * logits, axis=-1), 0.0)
    return jnp.stack(
        [idx.astype(jnp.float32), top1, top2, top1 - top2, ent, jnp.sqrt(ent), lse, m],
        axis=-1,
    )
