"""L1: fused stop-signal head as a Pallas kernel.

One pass over a row of draft logits produces *every* scalar any TapOut arm
policy needs, so the logits are read exactly once:

  col 0  argmax        (index of top-1 logit, stored as f32)
  col 1  top1_p        p(x = argmax)                       [Max-Confidence]
  col 2  top2_p        p of the runner-up
  col 3  margin        top1_p - top2_p                     [LogitMargin]
  col 4  entropy       H(p) = logsumexp - E_p[logit]       [AdaEDL]
  col 5  sqrt_entropy  sqrt(H)                             [SVIP, SVIP-Diff]
  col 6  logsumexp     m + log sum exp(x - m)
  col 7  max_logit     m

Grid: one program per logits row; the whole row lives in VMEM (V·4 B per
program — see DESIGN.md §7 for the VMEM/MXU accounting). ``interpret=True``
because CPU PJRT cannot execute Mosaic custom-calls; the kernel *structure*
(single read of the row, reduction-only work) is the TPU design.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

SIG_WIDTH = 8


def _signal_kernel(x_ref, o_ref):
    x = x_ref[0, :]                                  # [V] logits row in VMEM
    m = jnp.max(x)
    idx = jnp.argmax(x).astype(jnp.float32)
    e = jnp.exp(x - m)                               # stable exponentials
    s = jnp.sum(e)
    lse = m + jnp.log(s)
    top1 = jnp.max(e) / s
    # runner-up: mask the winning index out, take the next max
    masked = jnp.where(jnp.arange(x.shape[0]) == jnp.argmax(x), -jnp.inf, x)
    top2 = jnp.exp(jnp.max(masked) - m) / s
    # H(p) = logsumexp - E_p[x];  E_p[x] = m + sum(e*(x-m))/s
    ex = m + jnp.sum(e * (x - m)) / s
    ent = jnp.maximum(lse - ex, 0.0)
    o_ref[...] = jnp.stack(
        [idx, top1, top2, top1 - top2, ent, jnp.sqrt(ent), lse, m]
    ).reshape(1, SIG_WIDTH)


@functools.partial(jax.jit, static_argnames=())
def signal_head(logits: jnp.ndarray) -> jnp.ndarray:
    """logits [K, V] f32 -> signals [K, SIG_WIDTH] f32."""
    K, V = logits.shape
    return pl.pallas_call(
        _signal_kernel,
        grid=(K,),
        in_specs=[pl.BlockSpec((1, V), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, SIG_WIDTH), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((K, SIG_WIDTH), jnp.float32),
        interpret=True,
    )(logits)
