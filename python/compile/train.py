"""Build-time training of the tiny model zoo (DESIGN.md §3).

Trains each model in MODEL_ZOO on its TinyBench mixture with hand-rolled
Adam (no optax in the image) and writes flat f32 weights + metadata to
artifacts/weights/. Runs once under `make artifacts`; never on the request
path.

Env knobs:
  TAPOUT_TRAIN_SCALE  float multiplier on train_steps (default 1.0;
                      CI smoke can use 0.05)
"""

from __future__ import annotations

import json
import os
import sys
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from . import corpus, model


def batches(stream: np.ndarray, rng: np.random.RandomState, batch: int, seq: int):
    """Random contiguous windows out of the token stream."""
    hi = len(stream) - seq - 1
    while True:
        idx = rng.randint(0, hi, size=batch)
        yield np.stack([stream[i: i + seq + 1] for i in idx])


def adam_init(params):
    z = jax.tree.map(jnp.zeros_like, params)
    return {"m": z, "v": jax.tree.map(jnp.zeros_like, params), "t": 0}


def adam_update(params, grads, state, lr, b1=0.9, b2=0.99, eps=1e-8, clip=1.0):
    # global-norm gradient clipping
    gnorm = jnp.sqrt(
        sum(jnp.sum(g * g) for g in jax.tree.leaves(grads)) + 1e-12
    )
    scale = jnp.minimum(1.0, clip / gnorm)
    grads = jax.tree.map(lambda g: g * scale, grads)
    t = state["t"] + 1
    m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
    v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads)
    bc1, bc2 = 1 - b1**t, 1 - b2**t
    params = jax.tree.map(
        lambda p, m_, v_: p - lr * (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps),
        params, m, v,
    )
    return params, {"m": m, "v": v, "t": t}


# Which teacher each draft distills from. Acceptance in speculative
# decoding measures argmax agreement with the *target*, not corpus fit, so
# drafts train against the teacher's logits (0.3 CE + 0.7 KL) — the same
# reason production draft models are distilled from their targets.
DISTILL = {
    "draft-base": "target-base",
    "draft-tiny": "target-base",
    "draft-skew": "target-big",
}


def train_model(cfg: model.ModelConfig, out_dir: Path, scale: float = 1.0) -> dict:
    steps = max(20, int(cfg.train_steps * scale))
    stream = np.array(
        corpus.token_stream(cfg.corpus_seed, cfg.corpus_chars, cfg.mix), np.int32
    )
    rng = np.random.RandomState(cfg.corpus_seed + 1)
    gen = batches(stream, rng, cfg.train_batch, cfg.train_seq)

    params = model.init_params(cfg, seed=cfg.corpus_seed)
    opt = adam_init(params)

    teacher = None
    if cfg.name in DISTILL:
        tcfg = model.MODEL_ZOO[DISTILL[cfg.name]]
        tflat = np.fromfile(out_dir / f"{tcfg.name}.bin", "<f4")
        teacher = (tcfg, model.unpack_params(tcfg, jnp.asarray(tflat)))
        print(f"  [{cfg.name}] distilling from {tcfg.name}", flush=True)

    @jax.jit
    def step_fn(params, opt, toks, lr):
        def loss_with_distill(p):
            ce = model.loss_fn(cfg, p, toks)
            if teacher is None:
                return ce
            tcfg, tparams = teacher
            tlogits = jax.lax.stop_gradient(
                model.forward_train(tcfg, tparams, toks[:, :-1])
            )
            dlogits = model.forward_train(cfg, p, toks[:, :-1])
            tp = jax.nn.softmax(tlogits, axis=-1)
            kl = jnp.sum(
                tp * (jax.nn.log_softmax(tlogits, -1) - jax.nn.log_softmax(dlogits, -1)),
                axis=-1,
            ).mean()
            return 0.3 * ce + 0.7 * kl

        loss, grads = jax.value_and_grad(loss_with_distill)(params)
        params, opt = adam_update(params, grads, opt, lr)
        return params, opt, loss

    t0 = time.time()
    first = last = None
    for i in range(steps):
        # cosine decay with a short warmup
        warm = min(1.0, (i + 1) / 20)
        lr = cfg.lr * warm * (0.5 * (1 + np.cos(np.pi * i / steps)) * 0.9 + 0.1)
        params, opt, loss = step_fn(params, opt, jnp.array(next(gen)), lr)
        if i == 0:
            first = float(loss)
        if i % 40 == 0 or i == steps - 1:
            last = float(loss)
            print(f"  [{cfg.name}] step {i:4d}/{steps} loss {last:.3f} "
                  f"({time.time() - t0:.0f}s)", flush=True)

    wflat = model.pack_params(cfg, params)
    out_dir.mkdir(parents=True, exist_ok=True)
    wflat.astype("<f4").tofile(out_dir / f"{cfg.name}.bin")
    meta = {
        "name": cfg.name, "d_model": cfg.d_model, "n_layers": cfg.n_layers,
        "n_heads": cfg.n_heads, "vocab": cfg.vocab, "max_seq": cfg.max_seq,
        "param_count": int(wflat.size), "train_steps": steps,
        "loss_first": first, "loss_final": last,
        "train_seconds": round(time.time() - t0, 1),
    }
    (out_dir / f"{cfg.name}.json").write_text(json.dumps(meta, indent=1))
    print(f"  [{cfg.name}] done: loss {first:.3f} -> {last:.3f}, "
          f"{wflat.size} params, {meta['train_seconds']}s", flush=True)
    return meta


def main() -> None:
    scale = float(os.environ.get("TAPOUT_TRAIN_SCALE", "1.0"))
    out_dir = Path(sys.argv[1] if len(sys.argv) > 1 else "../artifacts/weights")
    only = sys.argv[2].split(",") if len(sys.argv) > 2 else list(model.MODEL_ZOO)
    for name in only:
        cfg = model.MODEL_ZOO[name]
        dst = out_dir / f"{cfg.name}.bin"
        if dst.exists():
            print(f"  [{cfg.name}] cached, skipping", flush=True)
            continue
        train_model(cfg, out_dir, scale)


if __name__ == "__main__":
    main()
