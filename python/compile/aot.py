"""AOT pipeline: lower every (model × K-bucket) block to HLO *text* and
write the artifact manifest the rust coordinator consumes.

HLO text — NOT ``lowered.compiler_ir().serialize()`` — is the interchange
format: the image's xla_extension 0.5.1 rejects jax>=0.5 protos with 64-bit
instruction ids; the text parser reassigns ids (see /opt/xla-example/README).

Outputs under artifacts/:
  hlo/<model>_block<K>.hlo.txt   one executable per shape bucket
  manifest.json                  dims, offsets, ladders, file map
  prompts.json                   TinyBench prompt suites (corpus.py)
  golden/pair-a.json             golden spec-decode traces (refspec.py)
"""

from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

import jax

from jax._src.lib import xla_client as xc

from . import corpus, model

# Shape buckets. Drafts run K=1 steps + prefill; targets also verify.
DRAFT_LADDER = [1, 4, 64, 128, 256, 384]
TARGET_LADDER = [1, 4, 8, 16, 32, 64, 128, 256, 384]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=False
    )
    return comp.as_hlo_text()


def lower_block(cfg: model.ModelConfig, k: int) -> str:
    fn = model.make_block(cfg, k)
    # donate the world argument: the alias reaches the HLO text as
    # input_output_alias={ {}: (1, {}, may-alias) }, letting XLA update the
    # KV cache in place instead of copying the full world through every
    # dynamic-update-slice (≈8x lower fixed cost per call — see
    # EXPERIMENTS.md §Perf)
    lowered = jax.jit(fn, donate_argnums=(1,)).lower(*model.example_args(cfg, k))
    return to_hlo_text(lowered)


def lower_extract(cfg: model.ModelConfig, k: int) -> str:
    """Signal extractor: world -> first k signal rows, flat [k*SIG].

    PJRT CPU (xla_extension 0.5.1) does not implement CopyRawToHost, so the
    rust side cannot offset-read the out-region from the world buffer; it
    instead dispatches this (trivial) slice executable and copies the small
    result via to_literal_sync."""
    import jax.numpy as jnp

    def fn(world):
        return jax.lax.dynamic_slice(world, (cfg.kv_elems,), (k * 8,))

    lowered = jax.jit(fn).lower(
        jax.ShapeDtypeStruct((cfg.world_elems,), jnp.float32)
    )
    return to_hlo_text(lowered)


def ladder_for(name: str) -> list[int]:
    return DRAFT_LADDER if name.startswith("draft") else TARGET_LADDER


def build(out_dir: Path, models: list[str] | None = None, skip_golden: bool = False) -> None:
    out_dir.mkdir(parents=True, exist_ok=True)
    hlo_dir = out_dir / "hlo"
    hlo_dir.mkdir(exist_ok=True)

    names = models or list(model.MODEL_ZOO)
    manifest: dict = {
        "vocab": corpus.VOCAB_SIZE,
        "max_seq": model.MAX_SEQ,
        "sig_width": 8,
        "out_rows": model.OUT_ROWS,
        "pad": corpus.PAD, "bos": corpus.BOS, "eos": corpus.EOS,
        "alphabet": corpus.ALPHABET,
        "models": {},
        "pairs": {k: list(v) for k, v in model.PAIRS.items()},
        "prompts": "prompts.json",
        "specdecpp": "specdecpp.json",
    }

    for name in names:
        cfg = model.MODEL_ZOO[name]
        ladder = ladder_for(name)
        files = {}
        extract_files = {}
        for k in ladder:
            dst = hlo_dir / f"{name}_block{k}.hlo.txt"
            if not dst.exists():
                t0 = time.time()
                dst.write_text(lower_block(cfg, k))
                print(f"  lowered {dst.name} ({time.time() - t0:.1f}s, "
                      f"{dst.stat().st_size // 1024} KiB)", flush=True)
            files[str(k)] = f"hlo/{dst.name}"
            ext = hlo_dir / f"{name}_extract{k}.hlo.txt"
            if not ext.exists():
                ext.write_text(lower_extract(cfg, k))
            extract_files[str(k)] = f"hlo/{ext.name}"
        manifest["models"][name] = {
            "d_model": cfg.d_model, "n_layers": cfg.n_layers,
            "n_heads": cfg.n_heads, "vocab": cfg.vocab, "max_seq": cfg.max_seq,
            "param_count": model.param_count(cfg),
            "kv_elems": cfg.kv_elems, "out_elems": cfg.out_elems,
            "world_elems": cfg.world_elems,
            "weights": f"weights/{name}.bin",
            "ladder": ladder,
            "hlo": files,
            "extract": extract_files,
        }

    prompts = out_dir / "prompts.json"
    if not prompts.exists():
        prompts.write_text(corpus.suites_to_json(corpus.build_suites()))
        print(f"  wrote {prompts}", flush=True)

    (out_dir / "manifest.json").write_text(json.dumps(manifest, indent=1))
    print(f"  wrote manifest ({len(names)} models)", flush=True)

    if not skip_golden:
        from . import refspec
        golden_dir = out_dir / "golden"
        golden_dir.mkdir(exist_ok=True)
        dst = golden_dir / "pair-a.json"
        if not dst.exists():
            dst.write_text(json.dumps(refspec.golden_traces("pair-a", out_dir), indent=1))
            print(f"  wrote {dst}", flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--models", default=None, help="comma-separated subset")
    ap.add_argument("--skip-golden", action="store_true")
    args = ap.parse_args()
    build(Path(args.out), args.models.split(",") if args.models else None,
          args.skip_golden)


if __name__ == "__main__":
    main()
