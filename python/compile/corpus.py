"""TinyBench: a seeded synthetic workload generator standing in for
SpecBench / MT-Bench / HumanEval / Alpaca (see DESIGN.md §3).

13 category grammars mirror SpecBench's categories. Deterministic grammars
(coding, math, extraction, translation, rag, summarization) induce
low-entropy draft continuations; template natural language (writing,
roleplay, humanities, ...) induces high-entropy ones — reproducing the
entropy split the paper exploits (Fig. 2).

Everything is char-level over a fixed 96-symbol alphabet.
"""

from __future__ import annotations

import json
import random
from dataclasses import dataclass

# --- vocabulary -------------------------------------------------------------
# 0 = PAD, 1 = BOS, 2 = EOS, then printable chars.
SPECIALS = ["<pad>", "<bos>", "<eos>"]
ALPHABET = (
    "abcdefghijklmnopqrstuvwxyz"
    "ABCDEFGHIJKLMNOPQRSTUVWXYZ"
    "0123456789"
    " .,:;!?'\"()[]{}<>=+-*/%_#|\n\t&^~@$\\"
)
VOCAB_SIZE = len(SPECIALS) + len(ALPHABET)  # 96
PAD, BOS, EOS = 0, 1, 2
_STOI = {c: i + len(SPECIALS) for i, c in enumerate(ALPHABET)}
_ITOS = {i + len(SPECIALS): c for i, c in enumerate(ALPHABET)}

CATEGORIES = [
    "coding",
    "extraction",
    "humanities",
    "math",
    "math_reasoning",
    "qa",
    "rag",
    "reasoning",
    "roleplay",
    "stem",
    "summarization",
    "translation",
    "writing",
]

CODING_CATEGORIES = {"coding"}  # for the Fig. 2 coding/non-coding split


def encode(text: str) -> list[int]:
    return [_STOI[c] for c in text if c in _STOI]


def decode(ids) -> str:
    return "".join(_ITOS.get(int(i), "") for i in ids if int(i) >= len(SPECIALS))


# --- grammar helpers --------------------------------------------------------

_NAMES = ["alice", "bob", "carol", "dave", "erin", "frank", "grace", "heidi"]
_CITIES = ["rome", "oslo", "lima", "cairo", "kyoto", "quito", "perth", "turin"]
_NOUNS = ["river", "engine", "garden", "signal", "market", "forest", "bridge", "circuit"]
_ADJS = ["quiet", "bright", "ancient", "rapid", "subtle", "dense", "hollow", "vivid"]
_VERBS = ["carries", "shapes", "reveals", "guards", "crosses", "ignites", "mirrors", "binds"]
_TOPICS = ["history", "poetry", "physics", "music", "geometry", "biology", "logic", "ethics"]

# translation dictionary (deterministic word mapping)
_DICT = {
    "red": "roz", "blue": "blu", "cat": "gato", "dog": "kano", "house": "casa",
    "tree": "arbo", "small": "eta", "big": "granda", "runs": "kuras", "sees": "vidas",
    "the": "la", "old": "mala", "new": "nova", "bird": "birdo", "water": "akvo",
}


# Zipfian-weighted choice: like natural text, most slots have a clearly
# most-likely continuation (so a good draft model can track the target's
# argmax) while still carrying real entropy. Uniform choices would make
# argmax ties arbitrary and crush acceptance rates for every method.
_ZIPF = [16.0, 8.0, 4.0, 2.0, 1.0, 0.5, 0.25, 0.125]


def _wchoice(rng: random.Random, items) -> str:
    return rng.choices(items, weights=_ZIPF[: len(items)], k=1)[0]


def _sent(rng: random.Random) -> str:
    return (
        f"the {_wchoice(rng, _ADJS)} {_wchoice(rng, _NOUNS)} {_wchoice(rng, _VERBS)} "
        f"the {_wchoice(rng, _ADJS)} {_wchoice(rng, _NOUNS)}"
    )


# --- category generators ----------------------------------------------------
# Each returns full sample text; prompts are prefixes cut at generation time.


def gen_coding(rng: random.Random) -> str:
    fname = f"f{rng.randrange(10)}"
    a, b = rng.randrange(2, 9), rng.randrange(2, 9)
    op = _wchoice(rng, ["+", "*", "-"])
    lines = [
        f"def {fname}(a, b):",
        f"    r = a {op} b",
        f"    for i in range({a}):",
        f"        r = r + i",
        "    return r",
        f"x = {fname}({a}, {b})",
        f"print(x)",
        "",
        f"def g{rng.randrange(10)}(n):",
        "    if n <= 1:",
        "        return 1",
        "    return n * g(n - 1)",
        "",
    ]
    return "\n".join(lines)


def gen_math(rng: random.Random) -> str:
    parts = []
    for _ in range(6):
        a, b = rng.randrange(2, 30), rng.randrange(2, 30)
        op = _wchoice(rng, ["+", "*", "-"])
        v = a + b if op == "+" else a * b if op == "*" else a - b
        parts.append(f"{a} {op} {b} = {v}")
    return "; ".join(parts) + "."


def gen_math_reasoning(rng: random.Random) -> str:
    x = rng.randrange(2, 9)
    y = x * rng.randrange(2, 5)
    z = y + rng.randrange(1, 9)
    return (
        f"let x = {x}. then y = x * {y // x}, so y = {y}. "
        f"then z = y + {z - y}, so z = {z}. the answer is {z}."
    )


def gen_extraction(rng: random.Random) -> str:
    recs = []
    for _ in range(3):
        n = _wchoice(rng, _NAMES)
        recs.append(f"name: {n}; age: {rng.randrange(20, 60)}; city: {_wchoice(rng, _CITIES)}")
    n2 = recs[1].split("name: ")[1].split(";")[0]
    age2 = recs[1].split("age: ")[1].split(";")[0]
    return " | ".join(recs) + f" || query: age of {n2} -> answer: {age2}."


def gen_translation(rng: random.Random) -> str:
    words = rng.sample(list(_DICT.keys()), 4)
    src = " ".join(words)
    dst = " ".join(_DICT[w] for w in words)
    words2 = rng.sample(list(_DICT.keys()), 4)
    src2 = " ".join(words2)
    dst2 = " ".join(_DICT[w] for w in words2)
    return f"translate: {src} -> {dst} ; translate: {src2} -> {dst2} ."


def gen_summarization(rng: random.Random) -> str:
    s1, s2 = _sent(rng), _sent(rng)
    key1 = s1.split()[2]
    key2 = s2.split()[2]
    return f"text: {s1}. {s2}. again: {s1}. tl;dr: {key1} and {key2}."


def gen_rag(rng: random.Random) -> str:
    n, c = _wchoice(rng, _NAMES), _wchoice(rng, _CITIES)
    fact = f"{n} lives in {c} and studies {_wchoice(rng, _TOPICS)}"
    return f"[doc] {fact}. {_sent(rng)}. [q] where does {n} live? [a] {n} lives in {c}."


def gen_qa(rng: random.Random) -> str:
    n, c, t = _wchoice(rng, _NAMES), _wchoice(rng, _CITIES), _wchoice(rng, _TOPICS)
    return (
        f"q: who works on {t} in {c}? a: {n} works on {t} in {c}. "
        f"q: where is {n}? a: {n} is in {c}."
    )


def gen_reasoning(rng: random.Random) -> str:
    a, b = _wchoice(rng, _NAMES), _wchoice(rng, _NAMES)
    return (
        f"if {a} is taller than {b}, and {b} is taller than carol, "
        f"then {a} is taller than carol. this follows by transitivity."
    )


def _gen_prose(rng: random.Random, opener: str) -> str:
    return f"{opener} {_sent(rng)}. {_sent(rng)}, while {_sent(rng)}. {_sent(rng)}."


def gen_humanities(rng: random.Random) -> str:
    return _gen_prose(rng, f"in the study of {_wchoice(rng, _TOPICS)},")


def gen_stem(rng: random.Random) -> str:
    return _gen_prose(rng, f"in {_wchoice(rng, ['physics', 'biology', 'geometry'])},")


def gen_writing(rng: random.Random) -> str:
    return _gen_prose(rng, "once upon a time,")


def gen_roleplay(rng: random.Random) -> str:
    n = _wchoice(rng, _NAMES)
    return f'"{_sent(rng)}," said {n}. "{_sent(rng)}," came the reply.'


GENERATORS = {
    "coding": gen_coding,
    "extraction": gen_extraction,
    "humanities": gen_humanities,
    "math": gen_math,
    "math_reasoning": gen_math_reasoning,
    "qa": gen_qa,
    "rag": gen_rag,
    "reasoning": gen_reasoning,
    "roleplay": gen_roleplay,
    "stem": gen_stem,
    "summarization": gen_summarization,
    "translation": gen_translation,
    "writing": gen_writing,
}

assert set(GENERATORS) == set(CATEGORIES)


# --- corpus / prompt suites --------------------------------------------------


def sample(category: str, rng: random.Random) -> str:
    return GENERATORS[category](rng)


def token_stream(seed: int, n_chars: int, mix: dict[str, float] | None = None) -> list[int]:
    """Concatenated EOS-separated training stream with a category mixture."""
    rng = random.Random(seed)
    cats = CATEGORIES
    weights = [(mix or {}).get(c, 1.0) for c in cats]
    out: list[int] = []
    while len(out) < n_chars:
        c = rng.choices(cats, weights=weights, k=1)[0]
        out.extend(encode(sample(c, rng)))
        out.append(EOS)
    return out[:n_chars]


@dataclass
class Prompt:
    category: str
    text: str        # the prompt prefix
    max_new: int     # generation budget (chars)


def make_prompt(category: str, rng: random.Random, max_new: int = 160) -> Prompt:
    """A prompt is a prefix of a fresh sample: the model continues in-domain."""
    full = sample(category, rng)
    # keep 35-60% of the sample as the prompt, at least 16 chars
    cut = max(16, int(len(full) * rng.uniform(0.35, 0.6)))
    return Prompt(category=category, text=full[:cut], max_new=max_new)


def build_suites(seed: int = 7, per_cat: int = 8, max_new: int = 160) -> dict:
    """Prompt suites analogous to the paper's datasets."""
    rng = random.Random(seed)
    specbench = [make_prompt(c, rng, max_new) for c in CATEGORIES for _ in range(per_cat)]
    mt_cats = ["writing", "roleplay", "reasoning", "math", "qa", "extraction", "stem", "humanities"]
    mtbench = [make_prompt(c, rng, max_new) for c in mt_cats for _ in range(per_cat)]
    humaneval = [make_prompt("coding", rng, max_new) for _ in range(per_cat * 8)]
    alpaca = [make_prompt(rng.choice(CATEGORIES), rng, max_new) for _ in range(per_cat * 40)]
    return {
        "specbench": specbench,
        "mtbench": mtbench,
        "humaneval": humaneval,
        "alpaca": alpaca,
    }


def suites_to_json(suites: dict) -> str:
    return json.dumps(
        {
            name: [
                {"category": p.category, "text": p.text, "max_new": p.max_new}
                for p in prompts
            ]
            for name, prompts in suites.items()
        },
        indent=1,
    )


if __name__ == "__main__":
    rng = random.Random(0)
    for c in CATEGORIES:
        print(f"--- {c}\n{sample(c, rng)!r}")
