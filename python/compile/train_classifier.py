"""SpecDec++ analog (training-based baseline, paper Table 4).

The paper trains a 4-layer ResNet (SiLU) on target hidden states with BCE
(rejection weight 6) and stops drafting when p(accept) < 0.7. Hidden states
do not cross our AOT boundary, so the classifier consumes the same signal
vector the training-free arms see (a *conservative* substitution for
TapOut — see DESIGN.md §3): [top1, top2, margin, entropy, sqrtH,
draft_position/16, ema_accept].

Trains at build time on spec-decode traces from the alpaca suite (pair-a)
and exports weights to artifacts/specdecpp.json for the rust inference
re-implementation (rust/src/policies/specdecpp.rs).
"""

from __future__ import annotations

import json
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from . import corpus, model, refspec

WIDTH = 32
N_BLOCKS = 3  # input layer + 3 residual blocks = 4 weight layers
REJECTION_WEIGHT = 6.0
THRESHOLD = 0.7
N_FEATURES = 7


def collect_traces(artifacts: Path, n_prompts: int = 32, max_new: int = 96):
    """Run long-draft spec decode on the alpaca suite; label each drafted
    token with accept/reject."""
    dname, tname = model.PAIRS["pair-a"]
    draft = refspec.PyModel.load(dname, artifacts)
    target = refspec.PyModel.load(tname, artifacts)
    suites = corpus.build_suites(seed=7)
    feats, labels = [], []
    for p in suites["alpaca"][:n_prompts]:
        ids = [corpus.BOS] + corpus.encode(p.text)
        ema = 0.7
        _, rounds = refspec.spec_decode(draft, target, ids, max_new=max_new,
                                        stop_after=16)
        for r in rounds:
            for i, (sig, y) in enumerate(zip(r["signals"], r["labels"])):
                # sig = [argmax, top1, top2, margin, entropy, sqrtH, lse, max]
                feats.append([sig[1], sig[2], sig[3], sig[4], sig[5],
                              i / 16.0, ema])
                labels.append(float(y))
            acc = r["accepted"] / max(1, r["drafted"])
            ema = 0.9 * ema + 0.1 * acc
    return np.array(feats, np.float32), np.array(labels, np.float32)


def init_mlp(seed: int = 0):
    k = jax.random.PRNGKey(seed)
    ks = jax.random.split(k, N_BLOCKS + 2)
    s = 0.3
    params = [{"w": jax.random.normal(ks[0], (N_FEATURES, WIDTH)) * s,
               "b": jnp.zeros(WIDTH)}]
    for i in range(N_BLOCKS):
        params.append({"w": jax.random.normal(ks[1 + i], (WIDTH, WIDTH)) * s,
                       "b": jnp.zeros(WIDTH)})
    params.append({"w": jax.random.normal(ks[-1], (WIDTH, 1)) * s,
                   "b": jnp.zeros(1)})
    return params


def mlp_fwd(params, x):
    h = jax.nn.silu(x @ params[0]["w"] + params[0]["b"])
    for blk in params[1:-1]:
        h = h + jax.nn.silu(h @ blk["w"] + blk["b"])  # residual (ResNet-style)
    return (h @ params[-1]["w"] + params[-1]["b"])[..., 0]


def train(feats: np.ndarray, labels: np.ndarray, steps: int = 1500, lr: float = 3e-3):
    mean, std = feats.mean(0), feats.std(0) + 1e-6
    xs = jnp.asarray((feats - mean) / std)
    ys = jnp.asarray(labels)
    # BCE with rejection weight 6 (paper's SpecDec++ setting)
    wts = jnp.where(ys > 0.5, 1.0, REJECTION_WEIGHT)

    params = init_mlp()
    opt = [{k: jnp.zeros_like(v) for k, v in layer.items()} for layer in params]
    opt2 = [{k: jnp.zeros_like(v) for k, v in layer.items()} for layer in params]

    @jax.jit
    def step(params, m, v, t):
        def loss_fn(p):
            logit = mlp_fwd(p, xs)
            l = jnp.maximum(logit, 0) - logit * ys + jnp.log1p(jnp.exp(-jnp.abs(logit)))
            return (wts * l).mean()
        loss, g = jax.value_and_grad(loss_fn)(params)
        new_p, new_m, new_v = [], [], []
        for p_, g_, m_, v_ in zip(params, g, m, v):
            nm = {k: 0.9 * m_[k] + 0.1 * g_[k] for k in p_}
            nv = {k: 0.99 * v_[k] + 0.01 * g_[k] ** 2 for k in p_}
            np_ = {k: p_[k] - lr * (nm[k] / (1 - 0.9 ** t)) /
                   (jnp.sqrt(nv[k] / (1 - 0.99 ** t)) + 1e-8) for k in p_}
            new_p.append(np_), new_m.append(nm), new_v.append(nv)
        return new_p, new_m, new_v, loss

    first = last = None
    for t in range(1, steps + 1):
        params, opt, opt2, loss = step(params, opt, opt2, t)
        if t == 1:
            first = float(loss)
    last = float(loss)

    # training-set accuracy (sanity)
    pred = np.asarray(jax.nn.sigmoid(mlp_fwd(params, xs))) > 0.5
    acc = float((pred == (labels > 0.5)).mean())
    return params, (mean, std), {"loss_first": first, "loss_final": last, "acc": acc}


def export(params, norm, stats, n_samples: int, dst: Path) -> None:
    mean, std = norm
    obj = {
        "arch": "resmlp-silu", "width": WIDTH, "blocks": N_BLOCKS,
        "features": ["top1", "top2", "margin", "entropy", "sqrt_entropy",
                     "pos_over_16", "ema_accept"],
        "rejection_weight": REJECTION_WEIGHT, "threshold": THRESHOLD,
        "n_train_samples": n_samples,
        "mean": np.asarray(mean).tolist(), "std": np.asarray(std).tolist(),
        "layers": [{"w": np.asarray(l["w"]).tolist(),
                    "b": np.asarray(l["b"]).tolist()} for l in params],
        "train_stats": stats,
    }
    dst.write_text(json.dumps(obj))


def main() -> None:
    artifacts = Path(sys.argv[1] if len(sys.argv) > 1 else "../artifacts")
    dst = artifacts / "specdecpp.json"
    if dst.exists():
        print("  [specdecpp] cached, skipping", flush=True)
        return
    print("  [specdecpp] collecting traces...", flush=True)
    feats, labels = collect_traces(artifacts)
    print(f"  [specdecpp] {len(feats)} samples, accept rate {labels.mean():.2f}",
          flush=True)
    params, norm, stats = train(feats, labels)
    export(params, norm, stats, len(feats), dst)
    print(f"  [specdecpp] loss {stats['loss_first']:.3f} -> "
          f"{stats['loss_final']:.3f}, acc {stats['acc']:.2f}", flush=True)


if __name__ == "__main__":
    main()
