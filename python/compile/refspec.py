"""Reference (python) speculative decoder over the exact AOT block functions.

Purposes:
  * the golden-trace generator — rust integration tests replay these traces
    and must match token-for-token (same HLO, same greedy rule);
  * the trace source for train_classifier.py (SpecDec++ analog);
  * the pytest home of the core invariant: greedy speculative decoding must
    emit exactly the target model's greedy continuation.

Position bookkeeping (mirrors rust/src/spec/session.rs):
  `cur` = number of tokens a model has processed as *inputs* (== the next
  input's absolute position). Every call feeds the contiguous block
  committed[cur..]; after verification both models roll `cur` back to the
  committed prefix. Garbage KV beyond `cur` is never read (attention masks
  to <= position) and is overwritten when those positions are re-fed.
"""

from __future__ import annotations

import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from . import corpus, model

SIG = 8  # signal row width (kernels/signals.py)


class PyModel:
    """A model instance driving the packed-world block functions."""

    def __init__(self, cfg: model.ModelConfig, wflat: np.ndarray):
        self.cfg = cfg
        self.w = jnp.asarray(wflat, jnp.float32)
        self.world = jnp.zeros((cfg.world_elems,), jnp.float32)
        self.cur = 0
        self._fns: dict[int, callable] = {}
        self._ladder = sorted(
            model.K_LADDER if cfg.name.startswith("target") else [1, 4, 64, 128, 256, 384]
        )

    @classmethod
    def load(cls, name: str, artifacts: Path) -> "PyModel":
        cfg = model.MODEL_ZOO[name]
        wflat = np.fromfile(artifacts / "weights" / f"{name}.bin", "<f4")
        assert wflat.size == model.param_count(cfg), (name, wflat.size)
        return cls(cfg, wflat)

    def reset(self) -> None:
        self.world = jnp.zeros((self.cfg.world_elems,), jnp.float32)
        self.cur = 0

    def _fn(self, k: int):
        if k not in self._fns:
            self._fns[k] = jax.jit(model.make_block(self.cfg, k))
        return self._fns[k]

    def block(self, tokens: list[int], start: int) -> np.ndarray:
        """Feed `tokens` at absolute position `start`; return signal rows
        [len(tokens), SIG]. Requires start == self.cur (contiguity)."""
        assert start == self.cur, (start, self.cur)
        n = len(tokens)
        K = next(k for k in self._ladder if k >= n)
        toks = np.zeros(K, np.int32)
        toks[:n] = tokens
        self.world = self._fn(K)(self.w, self.world, jnp.asarray(toks), jnp.int32(start))
        self.cur = start + n
        out = np.asarray(self.world[self.cfg.kv_elems:]).reshape(model.OUT_ROWS, SIG)
        return out[:n]


def greedy_decode(m: PyModel, prompt_ids: list[int], max_new: int) -> list[int]:
    """Plain autoregressive greedy decoding (the spec-decode oracle)."""
    m.reset()
    committed = list(prompt_ids)
    limit = min(max_new, m.cfg.max_seq - len(prompt_ids) - 1)
    for _ in range(limit):
        sig = m.block(committed[m.cur:], m.cur)
        nxt = int(sig[-1, 0])
        committed.append(nxt)
        if nxt == corpus.EOS:
            break
    return committed


def spec_decode(
    draft: PyModel,
    target: PyModel,
    prompt_ids: list[int],
    max_new: int,
    stop_after: int = 6,
    gamma_max: int = 128,
):
    """Greedy speculative decoding with a static draft length (Algorithm 1
    with the Static-k policy). Returns (committed, rounds) where rounds is
    a list of dicts with per-session drafting statistics."""
    draft.reset()
    target.reset()
    committed = list(prompt_ids)
    n0 = len(prompt_ids)
    S = min(draft.cfg.max_seq, target.cfg.max_seq)
    rounds = []

    while len(committed) - n0 < max_new and committed[-1] != corpus.EOS:
        C = len(committed)
        headroom = S - C - 2
        if headroom < 1:
            break
        gamma = min(stop_after, gamma_max, headroom)

        # --- draft session: catch up on committed tokens, then propose
        sig = draft.block(committed[draft.cur:], draft.cur)
        proposals: list[int] = []
        sig_rows: list[np.ndarray] = []
        while True:
            nxt = int(sig[-1, 0])
            proposals.append(nxt)
            sig_rows.append(sig[-1].copy())
            if len(proposals) >= gamma:
                break
            sig = draft.block([nxt], C + len(proposals) - 1)

        # --- verification: target processes the un-processed committed
        # suffix plus *all* proposals in one parallel block. Row r predicts
        # the token at absolute position tc+r+1, so row off+i (off = C-1-tc)
        # predicts position C+i: it both checks proposals[i] and supplies
        # the bonus token at the first mismatch (or after full acceptance).
        tc = target.cur
        inputs = committed[tc:] + proposals
        vsig = target.block(inputs, tc)
        preds = vsig[:, 0].astype(int)
        off = C - 1 - tc
        m = 0
        while m < len(proposals) and preds[off + m] == proposals[m]:
            m += 1
        bonus = int(preds[off + m])
        accepted = proposals[:m]
        committed.extend(accepted + [bonus])
        # roll back both models to the committed prefix
        target.cur = min(target.cur, C + m)
        draft.cur = min(draft.cur, C + m)
        rounds.append({
            "drafted": len(proposals),
            "accepted": m,
            "signals": [r.tolist() for r in sig_rows],
            "labels": [1] * m + [0] * (len(proposals) - m),
        })
        if bonus == corpus.EOS:
            break

    return committed, rounds


def golden_traces(pair: str, artifacts: Path, n_prompts: int = 4) -> dict:
    """Golden spec-decode traces for the rust integration tests."""
    dname, tname = model.PAIRS[pair]
    draft = PyModel.load(dname, artifacts)
    target = PyModel.load(tname, artifacts)
    suites = corpus.build_suites(seed=7)
    traces = []
    for p in suites["specbench"][:n_prompts]:
        ids = [corpus.BOS] + corpus.encode(p.text)
        committed, rounds = spec_decode(draft, target, ids, max_new=48, stop_after=6)
        traces.append({
            "category": p.category,
            "prompt_ids": ids,
            "committed": committed,
            "drafted": [r["drafted"] for r in rounds],
            "accepted": [r["accepted"] for r in rounds],
        })
    return {"pair": pair, "draft": dname, "target": tname, "stop_after": 6,
            "max_new": 48, "traces": traces}
