"""L2 model tests: KV-cache block semantics, packing, masking invariants.

Uses the smallest zoo config (draft-tiny) so every test traces in seconds.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import corpus, model

CFG = model.MODEL_ZOO["draft-tiny"]


@pytest.fixture(scope="module")
def setup():
    params = model.init_params(CFG, seed=3)
    w = jnp.asarray(model.pack_params(CFG, params))
    fns = {k: jax.jit(model.make_block(CFG, k)) for k in (1, 4, 8)}
    return params, w, fns


def sig_rows(world, n):
    out = np.asarray(world[CFG.kv_elems:]).reshape(model.OUT_ROWS, 8)
    return out[:n]


def test_pack_unpack_roundtrip(setup):
    params, w, _ = setup
    rec = model.unpack_params(CFG, w)
    np.testing.assert_allclose(np.asarray(rec["emb"]), np.asarray(params["emb"]))
    np.testing.assert_allclose(
        np.asarray(rec["layers"][0]["w2"]), np.asarray(params["layers"][0]["w2"])
    )
    assert w.size == model.param_count(CFG)


def test_block_matches_train_forward(setup):
    """Parallel block over fresh world == training forward (same argmax +
    same distribution stats)."""
    params, w, fns = setup
    toks = jnp.asarray(corpus.encode("q: where is")[:8], jnp.int32)
    world = jnp.zeros((CFG.world_elems,), jnp.float32)
    world = fns[8](w, world, toks, jnp.int32(0))
    sig = sig_rows(world, 8)
    logits = np.asarray(model.forward_train(CFG, params, toks[None])[0])
    np.testing.assert_array_equal(sig[:, 0].astype(int), logits.argmax(-1))
    # entropy of each position matches softmax entropy
    p = jax.nn.softmax(logits, -1)
    ent = -(p * np.log(p + 1e-30)).sum(-1)
    np.testing.assert_allclose(sig[:, 4], ent, atol=1e-3)


def test_incremental_equals_parallel(setup):
    """Feeding tokens one at a time through the KV cache must equal one
    parallel block — the core KV correctness invariant."""
    _, w, fns = setup
    toks = corpus.encode("translate: red cat")[:12]
    world_p = fns[8](w, jnp.zeros((CFG.world_elems,), jnp.float32),
                     jnp.asarray(toks[:8], jnp.int32), jnp.int32(0))
    ref = sig_rows(world_p, 8)

    world = jnp.zeros((CFG.world_elems,), jnp.float32)
    got = []
    for i, t in enumerate(toks[:8]):
        world = fns[1](w, world, jnp.asarray([t], jnp.int32), jnp.int32(i))
        got.append(sig_rows(world, 1)[0])
    np.testing.assert_allclose(np.stack(got), ref, atol=1e-4)


def test_mixed_block_sizes(setup):
    """4 + 1 + 1 + ... split must equal the parallel result too."""
    _, w, fns = setup
    toks = corpus.encode("12 + 34 = 46")[:6]
    world_p = fns[8](w, jnp.zeros((CFG.world_elems,), jnp.float32),
                     jnp.asarray(toks + [0, 0], jnp.int32), jnp.int32(0))
    ref = sig_rows(world_p, 6)

    world = jnp.zeros((CFG.world_elems,), jnp.float32)
    world = fns[4](w, world, jnp.asarray(toks[:4], jnp.int32), jnp.int32(0))
    a = sig_rows(world, 4)
    world = fns[1](w, world, jnp.asarray(toks[4:5], jnp.int32), jnp.int32(4))
    b = sig_rows(world, 1)
    world = fns[1](w, world, jnp.asarray(toks[5:6], jnp.int32), jnp.int32(5))
    c = sig_rows(world, 1)
    np.testing.assert_allclose(np.vstack([a, b[:1], c[:1]]), ref, atol=1e-4)


def test_padding_rows_do_not_affect_prefix(setup):
    """Right padding in a bucket must not change earlier rows (causality)."""
    _, w, fns = setup
    toks = corpus.encode("abc")
    w1 = fns[8](w, jnp.zeros((CFG.world_elems,), jnp.float32),
                jnp.asarray(toks + [0] * 5, jnp.int32), jnp.int32(0))
    w2 = fns[8](w, jnp.zeros((CFG.world_elems,), jnp.float32),
                jnp.asarray(toks + [9] * 5, jnp.int32), jnp.int32(0))
    np.testing.assert_allclose(sig_rows(w1, 3), sig_rows(w2, 3), atol=1e-6)


def test_stale_kv_beyond_cursor_is_harmless(setup):
    """Garbage KV at positions >= the write cursor is never read: rewriting
    positions 2.. after polluting them must give the parallel result."""
    _, w, fns = setup
    toks = corpus.encode("the quiet")[:8]
    ref = sig_rows(
        fns[8](w, jnp.zeros((CFG.world_elems,), jnp.float32),
               jnp.asarray(toks, jnp.int32), jnp.int32(0)), 8)

    world = jnp.zeros((CFG.world_elems,), jnp.float32)
    world = fns[4](w, world, jnp.asarray(toks[:4], jnp.int32), jnp.int32(0))
    # pollute: draft 4 wrong tokens at positions 4..8, then "roll back"
    world = fns[4](w, world, jnp.asarray([17, 18, 19, 20], jnp.int32), jnp.int32(4))
    # re-feed the true continuation at position 4
    world = fns[4](w, world, jnp.asarray(toks[4:8], jnp.int32), jnp.int32(4))
    got = sig_rows(world, 4)
    np.testing.assert_allclose(got, ref[4:8], atol=1e-4)


def test_world_elems_layout():
    assert CFG.world_elems == CFG.kv_elems + model.OUT_ROWS * 8
    assert CFG.kv_elems == CFG.n_layers * 2 * CFG.max_seq * CFG.d_model


def test_zoo_configs_consistent():
    for name, cfg in model.MODEL_ZOO.items():
        assert cfg.name == name
        assert cfg.d_model % cfg.n_heads == 0
        assert cfg.vocab == corpus.VOCAB_SIZE
    for pair, (d, t) in model.PAIRS.items():
        assert d in model.MODEL_ZOO and t in model.MODEL_ZOO
        assert model.param_count(model.MODEL_ZOO[d]) < model.param_count(
            model.MODEL_ZOO[t]
        ), pair


def test_loss_decreases_quickly():
    """Tiny sanity training run: loss must drop on a repetitive stream."""
    import numpy as np
    from compile import train
    cfg = model.ModelConfig("t", d_model=32, n_layers=1, n_heads=1,
                            train_seq=32, train_batch=8)
    stream = np.array(corpus.token_stream(0, 20000), np.int32)
    rng = np.random.RandomState(0)
    gen = train.batches(stream, rng, 8, 32)
    params = model.init_params(cfg, 0)
    opt = train.adam_init(params)
    import jax
    step = jax.jit(lambda p, o, t: (lambda l, g: train.adam_update(p, g, o, 3e-3) + (l,))(
        *jax.value_and_grad(lambda q: model.loss_fn(cfg, q, t))(p)))
    l0 = None
    for i in range(30):
        params, opt, loss = step(params, opt, jnp.asarray(next(gen)))
        if i == 0:
            l0 = float(loss)
    assert float(loss) < l0 * 0.8
