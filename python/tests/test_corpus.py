"""TinyBench corpus generator tests."""

import random

import pytest

from compile import corpus


def test_vocab_size():
    assert corpus.VOCAB_SIZE == len(corpus.SPECIALS) + len(corpus.ALPHABET)
    assert len(set(corpus.ALPHABET)) == len(corpus.ALPHABET)


def test_encode_decode_roundtrip():
    txt = "def f(a, b):\n    return a + b  # 42!"
    assert corpus.decode(corpus.encode(txt)) == txt


@pytest.mark.parametrize("cat", corpus.CATEGORIES)
def test_all_categories_generate_and_encode(cat):
    rng = random.Random(5)
    for _ in range(5):
        s = corpus.sample(cat, rng)
        ids = corpus.encode(s)
        assert len(ids) > 20
        # every char must be representable (encode is lossless here)
        assert corpus.decode(ids) == s


def test_determinism():
    a = corpus.token_stream(42, 5000)
    b = corpus.token_stream(42, 5000)
    assert a == b
    c = corpus.token_stream(43, 5000)
    assert a != c


def test_mix_skews_distribution():
    """A skewed mixture should change the stream content."""
    a = corpus.token_stream(1, 20000)
    b = corpus.token_stream(1, 20000, mix={"coding": 0.0, "math": 0.0})
    # 'def ' appears in coding samples only
    sa = corpus.decode(a)
    sb = corpus.decode(b)
    assert sa.count("def ") > sb.count("def ")


def test_suites_shape():
    suites = corpus.build_suites(seed=7, per_cat=2)
    assert set(suites) == {"specbench", "mtbench", "humaneval", "alpaca"}
    assert len(suites["specbench"]) == 2 * len(corpus.CATEGORIES)
    assert all(p.category == "coding" for p in suites["humaneval"])
    cats = {p.category for p in suites["specbench"]}
    assert cats == set(corpus.CATEGORIES)
    for p in suites["specbench"]:
        assert len(p.text) >= 16
        assert p.max_new > 0


def test_suites_json_roundtrip():
    import json
    suites = corpus.build_suites(seed=7, per_cat=1)
    obj = json.loads(corpus.suites_to_json(suites))
    assert set(obj) == set(suites)
    assert obj["humaneval"][0]["category"] == "coding"


def test_math_grammar_is_consistent():
    """math samples contain correct arithmetic (the low-entropy guarantee)."""
    rng = random.Random(9)
    s = corpus.gen_math(rng)
    for part in s.rstrip(".").split("; "):
        lhs, rhs = part.split(" = ")
        a, op, b = lhs.split()
        v = {"+": int(a) + int(b), "*": int(a) * int(b), "-": int(a) - int(b)}[op]
        assert v == int(rhs)
