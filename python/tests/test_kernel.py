"""L1 correctness: pallas fused stop-signal head vs the pure-jnp oracle.

Hypothesis sweeps shapes/scales/distributions; targeted cases cover ties,
saturated softmax, and tiny vocabularies.
"""

import hypothesis
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from compile.kernels.ref import signal_head_ref
from compile.kernels.signals import SIG_WIDTH, signal_head

COLS = dict(argmax=0, top1=1, top2=2, margin=3, entropy=4, sqrt_entropy=5,
            logsumexp=6, max_logit=7)


def run_both(x: np.ndarray):
    x = jnp.asarray(x, jnp.float32)
    return np.asarray(signal_head(x)), np.asarray(signal_head_ref(x))


@settings(max_examples=40, deadline=None,
          suppress_health_check=[hypothesis.HealthCheck.too_slow])
@given(
    rows=st.integers(1, 12),
    vocab=st.integers(2, 257),
    scale=st.floats(0.01, 30.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_matches_ref(rows, vocab, scale, seed):
    x = np.random.RandomState(seed).randn(rows, vocab).astype(np.float32) * scale
    a, b = run_both(x)
    # sqrt amplifies fp32 cancellation noise near zero entropy: H ~ eps
    # gives sqrt(H) errors of sqrt(eps); the policies' thresholds live at
    # 0.2-0.8 so 2e-2 absolute noise there is immaterial.
    np.testing.assert_allclose(a[:, :5], b[:, :5], atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(a[:, 5], b[:, 5], atol=2e-2, rtol=1e-3)
    np.testing.assert_allclose(a[:, 6:], b[:, 6:], atol=2e-4, rtol=2e-4)


def test_signal_semantics_uniform():
    """Uniform logits: entropy = ln V, top1 = 1/V, margin = 0."""
    v = 96
    a, _ = run_both(np.zeros((3, v), np.float32))
    np.testing.assert_allclose(a[:, COLS["entropy"]], np.log(v), atol=1e-5)
    np.testing.assert_allclose(a[:, COLS["top1"]], 1.0 / v, atol=1e-6)
    np.testing.assert_allclose(a[:, COLS["margin"]], 0.0, atol=1e-6)


def test_signal_semantics_peaked():
    """A huge single logit: entropy -> 0, top1 -> 1, argmax correct."""
    x = np.zeros((1, 50), np.float32)
    x[0, 17] = 60.0
    a, _ = run_both(x)
    assert int(a[0, COLS["argmax"]]) == 17
    assert a[0, COLS["top1"]] > 0.999999
    assert a[0, COLS["entropy"]] < 1e-4
    assert a[0, COLS["sqrt_entropy"]] < 2e-2


def test_two_way_tie():
    """Exact two-way tie: top1 == top2 == ~0.5, margin == 0."""
    x = np.full((1, 8), -5.0, np.float32)
    x[0, 2] = x[0, 5] = 4.0
    a, b = run_both(x)
    np.testing.assert_allclose(a, b, atol=1e-5)
    np.testing.assert_allclose(a[0, COLS["margin"]], 0.0, atol=1e-5)
    assert abs(a[0, COLS["top1"]] - a[0, COLS["top2"]]) < 1e-5


def test_large_negative_shift_invariance():
    """Signals (except lse/max) are shift-invariant in the logits."""
    x = np.random.RandomState(3).randn(4, 96).astype(np.float32)
    a, _ = run_both(x)
    c, _ = run_both(x + 1000.0)
    np.testing.assert_allclose(a[:, :6], c[:, :6], atol=1e-3)


def test_entropy_nonnegative_extremes():
    rs = np.random.RandomState(11)
    x = (rs.randn(16, 96) * 100).astype(np.float32)
    a, _ = run_both(x)
    assert (a[:, COLS["entropy"]] >= 0).all()
    assert (a[:, COLS["sqrt_entropy"]] >= 0).all()
    assert (a[:, COLS["top1"]] <= 1.0 + 1e-6).all()
    assert (a[:, COLS["top2"]] <= a[:, COLS["top1"]] + 1e-6).all()


def test_single_row_vocab96_golden():
    """Pin one concrete case so kernel regressions are loud."""
    rs = np.random.RandomState(0)
    x = rs.randn(1, 96).astype(np.float32) * 2
    a, b = run_both(x)
    np.testing.assert_allclose(a, b, atol=1e-5)
    p = np.exp(x[0] - x[0].max())
    p /= p.sum()
    np.testing.assert_allclose(a[0, COLS["top1"]], p.max(), atol=1e-5)
    np.testing.assert_allclose(
        a[0, COLS["entropy"]], -(p * np.log(p)).sum(), atol=1e-4
    )


@pytest.mark.parametrize("rows", [1, 2, 7, 64])
def test_row_independence(rows):
    """Each row's signals depend only on that row."""
    rs = np.random.RandomState(rows)
    x = rs.randn(rows, 64).astype(np.float32)
    a, _ = run_both(x)
    for i in range(rows):
        ai, _ = run_both(x[i: i + 1])
        np.testing.assert_allclose(a[i], ai[0], atol=1e-5)
