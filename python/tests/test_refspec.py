"""Reference speculative decoder invariants (untrained tiny models — these
tests exercise the algorithm, not the zoo weights; artifact-dependent tests
live in test_artifacts.py)."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import corpus, model, refspec


def make_py_model(seed: int, name: str = "draft-tiny") -> refspec.PyModel:
    cfg = model.MODEL_ZOO[name]
    params = model.init_params(cfg, seed=seed)
    return refspec.PyModel(cfg, model.pack_params(cfg, params))


@pytest.fixture(scope="module")
def pair():
    # untrained but *distinct* models: acceptance is low but well-defined
    return make_py_model(1), make_py_model(2)


@pytest.fixture(scope="module")
def self_pair():
    # identical weights: draft == target, everything must be accepted
    return make_py_model(7), make_py_model(7)


PROMPT = [corpus.BOS] + corpus.encode("q: where is alice? a:")


def test_spec_equals_greedy(pair):
    """THE invariant: greedy spec decode == target-only greedy decode."""
    draft, target = pair
    committed, _ = refspec.spec_decode(draft, target, PROMPT, max_new=24,
                                       stop_after=4)
    oracle_model = make_py_model(2)
    oracle = refspec.greedy_decode(oracle_model, PROMPT, max_new=24)
    n = min(len(committed), len(oracle))
    assert committed[:n] == oracle[:n]


def test_self_speculation_accepts_everything(self_pair):
    """Draft == target => every drafted token accepted in every round."""
    draft, target = self_pair
    committed, rounds = refspec.spec_decode(draft, target, PROMPT, max_new=16,
                                            stop_after=4)
    assert len(rounds) >= 1
    for r in rounds[:-1]:
        assert r["accepted"] == r["drafted"]
    assert len(committed) >= len(PROMPT) + 16


def test_rounds_bookkeeping(pair):
    draft, target = pair
    committed, rounds = refspec.spec_decode(draft, target, PROMPT, max_new=20,
                                            stop_after=5)
    new = len(committed) - len(PROMPT)
    # each round commits accepted + 1 bonus token
    total = sum(r["accepted"] + 1 for r in rounds)
    assert total == new
    for r in rounds:
        assert 0 <= r["accepted"] <= r["drafted"] <= 5
        assert len(r["signals"]) == r["drafted"]
        assert len(r["labels"]) == r["drafted"]
        assert sum(r["labels"]) == r["accepted"]
        # labels are a prefix of accepts followed by rejects
        assert r["labels"] == sorted(r["labels"], reverse=True)


def test_signals_match_policy_semantics(pair):
    """Signal rows carry sane probabilities."""
    draft, target = pair
    _, rounds = refspec.spec_decode(draft, target, PROMPT, max_new=12,
                                    stop_after=6)
    for r in rounds:
        for sig in r["signals"]:
            argmax, top1, top2, margin, ent, sqent = sig[:6]
            assert 0 <= argmax < corpus.VOCAB_SIZE
            assert 0 < top1 <= 1.0 + 1e-6
            assert 0 <= top2 <= top1 + 1e-6
            assert abs(margin - (top1 - top2)) < 1e-5
            assert ent >= -1e-6
            assert abs(sqent - np.sqrt(max(ent, 0))) < 1e-4


def test_max_seq_headroom_respected(pair):
    """Generation near MAX_SEQ must not write beyond the KV buffer."""
    draft, target = pair
    long_prompt = [corpus.BOS] + corpus.encode("x = 1; " * 52)  # ~360 tokens
    committed, rounds = refspec.spec_decode(draft, target, long_prompt,
                                            max_new=64, stop_after=8)
    assert len(committed) <= model.MAX_SEQ
