"""Artifact-pipeline validation: manifest consistency, HLO text format,
golden traces, classifier export. Skipped when `make artifacts` has not run
(the rest of the suite is artifact-independent)."""

import json
from pathlib import Path

import numpy as np
import pytest

from compile import corpus, model

ART = Path(__file__).resolve().parents[1].parent / "artifacts"

pytestmark = pytest.mark.skipif(
    not (ART / "manifest.json").exists(), reason="artifacts not built"
)


@pytest.fixture(scope="module")
def manifest():
    return json.loads((ART / "manifest.json").read_text())


def test_manifest_consistency(manifest):
    assert manifest["vocab"] == corpus.VOCAB_SIZE
    assert manifest["max_seq"] == model.MAX_SEQ
    assert set(manifest["pairs"]) == set(model.PAIRS)
    for name, m in manifest["models"].items():
        cfg = model.MODEL_ZOO[name]
        assert m["param_count"] == model.param_count(cfg)
        assert m["world_elems"] == m["kv_elems"] + m["out_elems"]
        for k, rel in m["hlo"].items():
            assert (ART / rel).exists(), rel
            assert int(k) in m["ladder"]
        for k, rel in m["extract"].items():
            assert (ART / rel).exists(), rel


def test_weights_files_match_param_counts(manifest):
    for name, m in manifest["models"].items():
        w = np.fromfile(ART / m["weights"], "<f4")
        assert w.size == m["param_count"], name
        assert np.isfinite(w).all(), name
        # trained weights are not all zeros / not untouched init
        assert w.std() > 1e-3, name


def test_hlo_is_text_with_alias(manifest):
    """HLO artifacts must be text (xla 0.5.1 interchange) and block modules
    must carry the world-donation alias (the §Perf optimization)."""
    m = manifest["models"]["draft-tiny"]
    txt = (ART / m["hlo"]["1"]).read_text()
    assert txt.startswith("HloModule")
    assert "input_output_alias" in txt.splitlines()[0]
    ext = (ART / m["extract"]["1"]).read_text()
    assert ext.startswith("HloModule")


def test_prompts_suites(manifest):
    prompts = json.loads((ART / "prompts.json").read_text())
    assert set(prompts) == {"specbench", "mtbench", "humaneval", "alpaca"}
    cats = {p["category"] for p in prompts["specbench"]}
    assert cats == set(corpus.CATEGORIES)
    for p in prompts["humaneval"]:
        assert p["category"] == "coding"


def test_golden_traces_are_replayable_in_python():
    """The golden traces must be reproducible by the reference decoder
    (guards against weight/corpus drift without re-running rust)."""
    from compile import refspec

    golden = json.loads((ART / "golden" / "pair-a.json").read_text())
    dname, tname = golden["draft"], golden["target"]
    draft = refspec.PyModel.load(dname, ART)
    target = refspec.PyModel.load(tname, ART)
    t = golden["traces"][0]
    committed, rounds = refspec.spec_decode(
        draft, target, t["prompt_ids"], max_new=golden["max_new"],
        stop_after=golden["stop_after"],
    )
    assert committed == t["committed"]
    assert [r["drafted"] for r in rounds] == t["drafted"]
    assert [r["accepted"] for r in rounds] == t["accepted"]


def test_classifier_export_shape():
    path = ART / "specdecpp.json"
    if not path.exists():
        pytest.skip("classifier not trained")
    c = json.loads(path.read_text())
    n_feat = len(c["features"])
    assert len(c["mean"]) == n_feat == len(c["std"])
    assert len(c["layers"]) == c["blocks"] + 2
    assert np.array(c["layers"][0]["w"]).shape == (n_feat, c["width"])
    assert np.array(c["layers"][-1]["w"]).shape == (c["width"], 1)
    assert 0.0 < c["threshold"] < 1.0
    # trained: accuracy recorded and better than chance on its skewed data
    assert c["train_stats"]["acc"] > 0.6
