//! Offline stub of the `xla` crate — exactly the API surface
//! `rust/src/runtime` and `rust/src/models/pjrt.rs` use, with every
//! constructor failing at *runtime* (never at compile time). The sealed
//! build image has no registry access and no PJRT plugin, so this keeps
//! `cargo build`/`cargo test` green everywhere; the PJRT-dependent tests
//! and benches already self-skip when `artifacts/` is absent, and
//! `Engine::start` on the PJRT backend surfaces the error below. Swapping
//! the path dependency in the root Cargo.toml for the real `xla` crate
//! re-enables the hardware path with no call-site changes.

use std::error::Error as StdError;
use std::fmt;
use std::path::Path;

#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl StdError for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what}: PJRT unavailable (offline `xla` stub — point the root \
         Cargo.toml at the real xla crate to run the PJRT backend)"
    )))
}

#[derive(Clone, Debug)]
pub struct PjRtClient(());

#[derive(Debug)]
pub struct PjRtBuffer(());

#[derive(Debug)]
pub struct PjRtLoadedExecutable(());

#[derive(Debug)]
pub struct HloModuleProto(());

#[derive(Debug)]
pub struct XlaComputation(());

#[derive(Debug)]
pub struct Literal(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "xla-stub".into()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }

    pub fn buffer_from_host_buffer<T: Copy>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        unavailable("PjRtClient::buffer_from_host_buffer")
    }
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute_b")
    }
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

impl Literal {
    pub fn to_vec<T: Copy>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_fails_at_runtime_not_compile_time() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("PJRT unavailable"));
    }
}
