//! Offline stand-in for the `anyhow` crate — the API subset this repo uses
//! (`Error`, `Result`, `Context`, `anyhow!`, `bail!`, `ensure!`), written
//! against std only so the sealed build image needs no registry access.
//! Replace the path dependency in the root Cargo.toml with the crates.io
//! `anyhow` to switch back; no call site changes are required.

use std::error::Error as StdError;
use std::fmt;

/// A chain of error messages, innermost cause first.
pub struct Error {
    /// `chain[0]` is the root cause; the last entry is the outermost
    /// context (what `Display` without `#` prints).
    chain: Vec<String>,
}

impl Error {
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message (used by the `Context` trait).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.push(context.to_string());
        self
    }

    /// The innermost message in the chain.
    pub fn root_cause_msg(&self) -> &str {
        &self.chain[0]
    }

    fn fmt_chain(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for (i, msg) in self.chain.iter().rev().enumerate() {
            if i > 0 {
                write!(f, ": ")?;
            }
            write!(f, "{msg}")?;
        }
        Ok(())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` — the full context chain, outermost first
            self.fmt_chain(f)
        } else {
            write!(f, "{}", self.chain.last().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.last().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for msg in self.chain.iter().rev().skip(1) {
                write!(f, "\n    {msg}")?;
            }
        }
        Ok(())
    }
}

// Like the real anyhow: `Error` deliberately does NOT implement
// `std::error::Error`, which is what makes this blanket conversion legal.
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.insert(0, s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to a `Result` or `Option` (mirrors `anyhow::Context`).
pub trait Context<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T>;
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::Error::msg(concat!(
                "condition failed: ",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn context_chain_formats_outermost_first() {
        let e: Error = std::result::Result::<(), _>::Err(io_err())
            .context("loading weights")
            .unwrap_err();
        assert_eq!(format!("{e}"), "loading weights");
        assert_eq!(format!("{e:#}"), "loading weights: missing file");
        assert!(format!("{e:?}").contains("Caused by:"));
    }

    #[test]
    fn macros_build_errors() {
        let x = 3;
        let e = anyhow!("bad value {x}");
        assert_eq!(format!("{e}"), "bad value 3");
        let e = anyhow!("bucket {} missing", 7);
        assert_eq!(format!("{e}"), "bucket 7 missing");
        let s = String::from("plain");
        let e = anyhow!(s);
        assert_eq!(format!("{e}"), "plain");
    }

    #[test]
    fn ensure_and_bail() {
        fn f(ok: bool) -> Result<u32> {
            ensure!(ok, "wanted {} to hold", "ok");
            Ok(1)
        }
        assert_eq!(f(true).unwrap(), 1);
        assert_eq!(format!("{}", f(false).unwrap_err()), "wanted ok to hold");

        fn g() -> Result<()> {
            bail!("always fails");
        }
        assert!(g().is_err());
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("empty").unwrap_err();
        assert_eq!(format!("{e}"), "empty");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<String> {
            let s = String::from_utf8(vec![0xff])?;
            Ok(s)
        }
        assert!(f().is_err());
    }
}
