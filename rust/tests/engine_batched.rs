//! Batched-verification integration tests on the simulator backend
//! (docs/ARCHITECTURE.md §4) — these run everywhere and pin the batcher's
//! contract:
//!
//!   * per-request output is a pure function of the prompt: a 16-request
//!     burst through the batched engine is byte-identical to the
//!     sequential (batching-off) engine and to the target-only greedy
//!     oracle, at every batch window in {1, 4, 8} and worker count;
//!   * bandit play-count conservation holds unchanged — every drafting
//!     session's reward lands exactly once no matter how sessions were
//!     coalesced into forwards;
//!   * the occupancy/pad-waste gauges observe the batching that happened;
//!   * decode failures still produce explicit error responses.

mod common;

use common::{collect, MAX_NEW, TIMEOUT};
use tapout::engine::{BatchConfig, Engine, EngineConfig};

fn config(workers: usize, slots: usize, batch: BatchConfig) -> EngineConfig {
    EngineConfig { verify_batch: batch, ..common::sim_config(workers, slots) }
}

fn burst_prompts(n: usize) -> Vec<String> {
    common::burst_prompts(n, "batched serving")
}

fn oracle_tokens(text: &str) -> Vec<u32> {
    common::oracle_tokens(text, MAX_NEW)
}

#[test]
fn batched_burst_matches_sequential_engine_at_every_window() {
    let prompts = burst_prompts(16);

    // reference: the sequential engine (batcher off, one worker)
    let seq = Engine::start(config(1, 1, BatchConfig::off())).unwrap();
    let seq_out: Vec<Vec<u32>> = prompts
        .iter()
        .map(|p| {
            let r = seq.submit(p, MAX_NEW).recv_timeout(TIMEOUT).unwrap();
            assert!(r.is_ok(), "{:?}", r.error);
            r.result.new_tokens().to_vec()
        })
        .collect();
    seq.shutdown();

    for max_batch in [1usize, 4, 8] {
        let eng = Engine::start(config(
            4,
            4,
            BatchConfig { max_batch, window_us: 200 },
        ))
        .unwrap();
        let rxs: Vec<_> = prompts.iter().map(|p| eng.submit(p, MAX_NEW)).collect();
        let responses = collect(rxs);

        let mut total_sessions = 0u64;
        for (i, r) in responses.iter().enumerate() {
            assert!(r.is_ok(), "window {max_batch} request {i} failed: {:?}", r.error);
            assert_eq!(
                r.result.new_tokens(),
                &seq_out[i][..],
                "window {max_batch} request {i}: batched output diverged from sequential engine"
            );
            assert_eq!(
                r.result.new_tokens(),
                &oracle_tokens(&prompts[i])[..],
                "window {max_batch} request {i}: output diverged from the greedy oracle"
            );
            total_sessions += r.result.rounds.len() as u64;
        }

        // play-count conservation across the batcher: one select + one
        // update per drafting session, regardless of coalescing
        assert_eq!(eng.bandit_sessions(), total_sessions, "window {max_batch}");
        assert_eq!(eng.bandit_updates(), total_sessions, "window {max_batch}");
        let counts = eng.bandit_counts().expect("seq-ucb1 has a shared bandit");
        assert_eq!(
            counts.iter().sum::<u64>(),
            total_sessions,
            "window {max_batch}: bandit counts must sum to sessions: {counts:?}"
        );

        // every verification round went through the batcher
        use std::sync::atomic::Ordering;
        let batches = eng.stats.batch.batches.load(Ordering::Relaxed);
        let coalesced = eng.stats.batch.coalesced.load(Ordering::Relaxed);
        assert_eq!(coalesced, total_sessions, "window {max_batch}");
        assert!(batches > 0 && batches <= coalesced, "window {max_batch}");
        let peak = eng.stats.batch.peak.load(Ordering::Relaxed);
        assert!(peak <= max_batch, "window {max_batch}: peak {peak} exceeded the window");
        if max_batch == 1 {
            assert_eq!(batches, coalesced, "window 1 must not coalesce");
        }
        assert!(
            eng.stats.batch.padded_rows.load(Ordering::Relaxed)
                >= eng.stats.batch.rows.load(Ordering::Relaxed),
            "padding can only add rows"
        );
        eng.shutdown();
    }
}

#[test]
fn batched_engine_with_more_workers_than_slots() {
    let eng = Engine::start(config(4, 2, BatchConfig::default())).unwrap();
    let prompts = burst_prompts(12);
    let rxs: Vec<_> = prompts.iter().map(|p| eng.submit(p, MAX_NEW)).collect();
    for (i, r) in collect(rxs).iter().enumerate() {
        assert!(r.is_ok(), "request {i} failed: {:?}", r.error);
        assert_eq!(r.result.new_tokens(), &oracle_tokens(&prompts[i])[..]);
    }
    assert_eq!(eng.metrics.lock().unwrap().completed, 12);
    eng.shutdown();
}

#[test]
fn batched_decode_failure_is_an_error_response_not_a_hang() {
    let eng = Engine::start(config(2, 2, BatchConfig::default())).unwrap();
    // the sim KV cache holds 4096 positions; this prompt cannot fit
    let oversized = "y".repeat(5000);
    let r = eng
        .submit(&oversized, 8)
        .recv_timeout(TIMEOUT)
        .expect("failed request must still be answered");
    assert!(!r.is_ok());
    assert!(
        r.error.as_deref().unwrap_or("").contains("prompt too long"),
        "error should explain the failure: {:?}",
        r.error
    );
    // the engine (and its batcher) keep serving afterwards
    let ok = eng.submit("follow-up after failure", MAX_NEW).recv_timeout(TIMEOUT).unwrap();
    assert!(ok.is_ok());
    eng.shutdown();
}

#[test]
fn metrics_json_reports_batch_and_sched_gauges() {
    let eng = Engine::start(config(2, 2, BatchConfig::default())).unwrap();
    collect(burst_prompts(6).iter().map(|p| eng.submit(p, MAX_NEW)).collect());
    let j = eng.metrics_json();
    let engine = j.get("engine").expect("engine object");
    let batch = engine.get("batch").expect("batch gauges");
    assert!(batch.get("batches").unwrap().as_usize().unwrap() > 0);
    assert!(batch.get("mean_occupancy").unwrap().as_f64().unwrap() >= 1.0);
    let sched = j.get("sched").expect("sched ledger");
    assert_eq!(sched.get("in_flight").unwrap().as_usize().unwrap(), 0, "burst fully drained");
    assert_eq!(sched.get("pending_cost").unwrap().as_usize().unwrap(), 0);
    eng.shutdown();
}
