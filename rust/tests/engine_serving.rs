//! Serving-layer integration tests. PJRT-dependent tests self-skip when
//! `make artifacts` has not been run (CI smoke without artifacts), so the
//! suite is green in both states.

mod common;

use std::path::Path;
use std::sync::Arc;

use common::{http_get_json, http_post_json, TIMEOUT};
use tapout::engine::{Engine, EngineConfig, HttpServer, Policy};

fn artifacts_ready() -> bool {
    Path::new("artifacts/manifest.json").exists()
}

fn engine() -> Engine {
    Engine::start(EngineConfig {
        pair: "pair-a".into(),
        method: "seq-ucb1".into(),
        gamma_max: 64,
        sched: Policy::Fcfs,
        slots: 2,
        ..EngineConfig::default()
    })
    .unwrap()
}

#[test]
fn engine_serves_requests_and_records_metrics() {
    if !artifacts_ready() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let eng = engine();
    let rx1 = eng.submit("q: where is alice? a:", 32);
    let rx2 = eng.submit("translate: red cat -> ", 24);
    let r1 = rx1.recv_timeout(TIMEOUT).unwrap();
    let r2 = rx2.recv_timeout(TIMEOUT).unwrap();
    assert!(!r1.result.new_tokens().is_empty());
    assert!(!r2.result.new_tokens().is_empty());
    assert!(!r1.text.is_empty());
    {
        let m = eng.metrics.lock().unwrap();
        assert_eq!(m.completed, 2);
        assert!(m.drafted > 0);
    }
    eng.shutdown();
}

#[test]
fn http_api_round_trip() {
    if !artifacts_ready() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let eng = Arc::new(engine());
    let http = HttpServer::start(eng.clone(), 0).unwrap();
    let addr = http.addr.clone();

    let get = |path: &str| http_get_json(&addr, path);
    let post = |path: &str, body: &str| http_post_json(&addr, path, body);

    let (code, health) = get("/health");
    assert_eq!(code, 200);
    assert_eq!(health.get("ok").unwrap().as_bool(), Some(true));

    let (code, gen) = post("/generate", r#"{"prompt": "12 + 34 = ", "max_new": 16}"#);
    assert_eq!(code, 200, "{gen:?}");
    assert!(gen.get("new_tokens").unwrap().as_usize().unwrap() > 0);
    assert!(gen.get("text").unwrap().as_str().is_some());

    let (code, err) = post("/generate", r#"{"max_new": 4}"#);
    assert_eq!(code, 400, "{err:?}");

    let (code, miss) = get("/nope");
    assert_eq!(code, 404, "{miss:?}");

    let (code, metrics) = get("/metrics");
    assert_eq!(code, 200);
    assert!(metrics.get("completed").unwrap().as_usize().unwrap() >= 1);
}

#[test]
fn pjrt_models_match_python_numerics() {
    // thin re-check of what `tapout selftest` verifies, kept in the test
    // suite so `cargo test` covers the PJRT path when artifacts exist
    if !artifacts_ready() || !Path::new("artifacts/golden/pair-a.json").exists() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let out = std::process::Command::new(env!("CARGO_BIN_EXE_tapout"))
        .arg("selftest")
        .output()
        .unwrap();
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(
        stdout.contains("golden traces replayed exactly"),
        "selftest failed:\n{stdout}\n{}",
        String::from_utf8_lossy(&out.stderr)
    );
}

#[test]
fn specdecpp_classifier_loads_from_artifacts() {
    if !Path::new("artifacts/specdecpp.json").exists() {
        eprintln!("skipping: classifier not trained");
        return;
    }
    let c = tapout::policies::SpecDecPP::load(Path::new("artifacts/specdecpp.json")).unwrap();
    // confident low-entropy token should have a higher accept prob than a
    // maximally-uncertain one
    let hi = tapout::signals::TokenSignals::from_logits(&{
        let mut v = vec![0.0f32; 96];
        v[10] = 12.0;
        v
    });
    let lo = tapout::signals::TokenSignals::from_logits(&vec![0.0f32; 96]);
    assert!(c.predict(&hi, 0) > c.predict(&lo, 0));
}
