//! Fault-path integration tests: the serving engine under injected
//! `LanguageModel` failures (`models::FaultyModel`, docs/TESTING.md), in
//! both execution modes:
//!
//!   * an injected forward error fails exactly the victim request
//!     (`FinishStatus::Failed`, explicit error text) — never a hang, and
//!     never a wrong token;
//!   * the victim's KV slot is released: follow-up requests on the same
//!     1-slot engine keep completing, and once the `max_faults` kill
//!     budget is exhausted replies are byte-identical to the greedy
//!     oracle again;
//!   * a *crash* (sticky-broken model, the panic-equivalent) is healed by
//!     the next request's reseat — the engine never needs a restart;
//!   * lost reuse leases (`retain_prefix`/`adopt_pages` faults) degrade
//!     to fresh prefill and stay lossless with the prefix cache on;
//!   * shared-bandit play-count conservation survives aborted rounds:
//!     sessions == updates == Σ arm counts even when forwards die between
//!     a bandit select and its reward.

mod common;

use common::{collect, oracle_tokens, sim_config, TIMEOUT};
use tapout::engine::{Engine, EngineConfig, EngineMode, FinishStatus};
use tapout::models::FaultPlan;

/// Fault tests use short decodes: the interesting part is the failure
/// handling, not the decode length.
const MAX_NEW: usize = 16;

fn faulty_config(
    mode: EngineMode,
    workers: usize,
    slots: usize,
    faults: FaultPlan,
) -> EngineConfig {
    EngineConfig { mode, faults, ..sim_config(workers, slots) }
}

/// Σ arm counts == updates == sessions: every bandit select got exactly
/// one reward (or an explicit abort settlement), no plays were minted or
/// lost — the conservation law the sim-harness oracle also enforces.
fn assert_play_conservation(eng: &Engine, ctx: &str) {
    let sessions = eng.bandit_sessions();
    let updates = eng.bandit_updates();
    assert_eq!(sessions, updates, "{ctx}: aborted rounds must settle their bandit plays");
    let counts = eng.bandit_counts().expect("seq-ucb1 has a shared bandit");
    assert_eq!(counts.iter().sum::<u64>(), updates, "{ctx}: {counts:?}");
}

#[test]
fn injected_errors_fail_requests_then_engine_heals_in_both_modes() {
    for mode in [EngineMode::Workers, EngineMode::Continuous] {
        // error_rate 1.0: every forward errors while kills remain, so the
        // first request provably fails; the kill budget (max_faults per
        // wrapped model) provably exhausts within 8 failures, so the tail
        // of the burst provably succeeds
        let plan = FaultPlan { seed: 11, error_rate: 1.0, max_faults: 2, ..FaultPlan::default() };
        let eng = Engine::start(faulty_config(mode, 1, 1, plan)).unwrap();

        let mut failed = 0usize;
        let mut done = 0usize;
        let mut last_ok = false;
        for i in 0..12 {
            let text = format!("fault probe number {i}");
            let r = eng
                .submit(&text, MAX_NEW)
                .recv_timeout(TIMEOUT)
                .unwrap_or_else(|_| panic!("{mode:?} request {i}: fault must not hang the engine"));
            match r.status {
                FinishStatus::Failed => {
                    failed += 1;
                    last_ok = false;
                    let msg = r.error.as_deref().unwrap_or("");
                    assert!(msg.contains("injected"), "{mode:?} request {i}: {msg}");
                    if i == 0 {
                        // the very first forward errors: mid-request failure
                        assert!(r.result.new_tokens().is_empty() || !msg.is_empty());
                    }
                }
                FinishStatus::Done => {
                    done += 1;
                    last_ok = true;
                    assert_eq!(
                        r.result.new_tokens(),
                        &oracle_tokens(&text, MAX_NEW)[..],
                        "{mode:?} request {i}: post-fault decode must be byte-exact"
                    );
                }
                other => panic!("{mode:?} request {i}: unexpected status {other:?}"),
            }
            if i == 0 {
                assert_eq!(failed, 1, "{mode:?}: the first forward must error under rate 1.0");
            }
        }
        assert!(last_ok, "{mode:?}: the kill budget must exhaust before the burst ends");
        assert!((1..=8).contains(&failed), "{mode:?}: {failed} failures, budget is 8");
        assert_eq!(failed + done, 12, "{mode:?}");
        {
            let m = eng.metrics.lock().unwrap();
            assert_eq!(m.failed as usize, failed, "{mode:?}");
            assert_eq!(m.completed as usize, done, "{mode:?}");
        }
        assert_play_conservation(&eng, &format!("{mode:?} errors"));
        eng.shutdown();
    }
}

#[test]
fn crash_is_failed_once_and_the_next_request_reseats_the_model() {
    for mode in [EngineMode::Workers, EngineMode::Continuous] {
        // a crash leaves the model sticky-broken; the engine's per-request
        // reseat (begin_request / retain_prefix / adopt_pages) must heal
        // it without restarting anything
        let plan = FaultPlan { seed: 7, crash_rate: 1.0, max_faults: 1, ..FaultPlan::default() };
        let eng = Engine::start(faulty_config(mode, 1, 1, plan)).unwrap();

        let mut crashed = 0usize;
        let mut last_ok = false;
        for i in 0..8 {
            let text = format!("crash probe number {i}");
            let r = eng
                .submit(&text, MAX_NEW)
                .recv_timeout(TIMEOUT)
                .unwrap_or_else(|_| panic!("{mode:?} request {i}: crash must not hang the engine"));
            if r.status == FinishStatus::Failed {
                crashed += 1;
                last_ok = false;
                assert!(
                    r.error.as_deref().unwrap_or("").contains("crash"),
                    "{mode:?} request {i}: {:?}",
                    r.error
                );
            } else {
                last_ok = true;
                assert_eq!(r.status, FinishStatus::Done, "{mode:?} request {i}");
                assert_eq!(r.result.new_tokens(), &oracle_tokens(&text, MAX_NEW)[..]);
            }
        }
        // each wrapped model crashes at most once (max_faults 1), so at
        // most 4 victims; request 0 provably crashes, the tail heals
        assert!((1..=4).contains(&crashed), "{mode:?}: {crashed} crashes");
        assert!(last_ok, "{mode:?}: the engine must fully heal after the crash budget");
        assert_play_conservation(&eng, &format!("{mode:?} crashes"));
        eng.shutdown();
    }
}

#[test]
fn lost_reuse_leases_never_corrupt_output() {
    // every retain_prefix/adopt_pages lease is dropped: the cache can
    // never serve a hit, but outputs must not move by a byte and nothing
    // may fail — the lost lease degrades to fresh prefill (lossless)
    let system = "system prompt shared across the whole burst for reuse. ".repeat(3);
    let prompts: Vec<String> = (0..12).map(|i| format!("{system}user {i}: go")).collect();
    for mode in [EngineMode::Workers, EngineMode::Continuous] {
        let plan = FaultPlan { seed: 3, reuse_loss_rate: 1.0, ..FaultPlan::default() };
        let mut cfg = faulty_config(mode, 2, 2, plan);
        cfg.prefix_cache = true;
        let eng = Engine::start(cfg).unwrap();
        let rxs: Vec<_> = prompts.iter().map(|p| eng.submit(p, MAX_NEW)).collect();
        for (i, r) in collect(rxs).iter().enumerate() {
            assert!(r.is_ok(), "{mode:?} request {i}: lease loss is lossless: {:?}", r.error);
            assert_eq!(
                r.result.new_tokens(),
                &oracle_tokens(&prompts[i], MAX_NEW)[..],
                "{mode:?} request {i}: lost lease corrupted the decode"
            );
        }
        assert_eq!(eng.metrics.lock().unwrap().failed, 0, "{mode:?}");
        assert_play_conservation(&eng, &format!("{mode:?} lost leases"));
        eng.shutdown();
    }
}

#[test]
fn moderate_fault_storm_terminates_conserves_and_recovers() {
    // all fault shapes at once (errors, crashes, slow steps, lost leases)
    // against a concurrent burst: every request reaches a terminal state,
    // successes stay byte-exact, accounting balances, and the engine is
    // provably serviceable again once the kill budgets drain
    let prompts = common::burst_prompts(16, "fault storm");
    for (seed, mode) in [(21u64, EngineMode::Workers), (22, EngineMode::Continuous)] {
        let mut plan = FaultPlan::moderate(seed, 6);
        plan.error_rate = 0.25; // hot enough to fire mid-decode, capped by max_faults
        let mut cfg = faulty_config(mode, 2, 2, plan);
        cfg.prefix_cache = true;
        let eng = Engine::start(cfg).unwrap();

        let rxs: Vec<_> = prompts.iter().map(|p| eng.submit(p, MAX_NEW)).collect();
        let responses = collect(rxs);
        let mut failed = 0usize;
        for (i, r) in responses.iter().enumerate() {
            match r.status {
                FinishStatus::Done => assert_eq!(
                    r.result.new_tokens(),
                    &oracle_tokens(&prompts[i], MAX_NEW)[..],
                    "{mode:?} request {i}: a surviving decode must be byte-exact"
                ),
                FinishStatus::Failed => {
                    failed += 1;
                    assert!(r.error.is_some(), "{mode:?} request {i}: failures carry a reason");
                }
                other => panic!("{mode:?} request {i}: unexpected status {other:?}"),
            }
        }
        {
            let m = eng.metrics.lock().unwrap();
            assert_eq!(m.failed as usize + m.completed as usize, 16, "{mode:?}");
            assert_eq!(m.failed as usize, failed, "{mode:?}");
        }
        assert_play_conservation(&eng, &format!("{mode:?} storm"));

        // liveness: each failure burns one kill from a finite budget
        // (max_faults per wrapped model), so bounded retries must succeed
        let mut recovered = false;
        for attempt in 0..40 {
            let r = eng
                .submit(&format!("recovery probe {attempt}"), MAX_NEW)
                .recv_timeout(TIMEOUT)
                .unwrap();
            if r.is_ok() {
                recovered = true;
                break;
            }
            assert_eq!(r.status, FinishStatus::Failed, "{mode:?} attempt {attempt}");
        }
        assert!(recovered, "{mode:?}: kill budget exhausted yet no request succeeds");
        eng.shutdown();
    }
}
