//! Prefix-reuse KV cache integration tests on the simulator backend
//! (docs/ARCHITECTURE.md §12) — these run everywhere and pin the cache's
//! contract:
//!
//!   * a shared-system-prompt burst is **byte-identical** with the cache
//!     on, off, and against the target-only greedy oracle, across both
//!     execution modes (Workers at workers {1, 4}, Continuous at slots
//!     {1, 4, 8}) and both verification paths (batched + direct) — the
//!     cache only removes redundant prefill forwards;
//!   * a request whose prompt diverges mid-prefix rolls the slot back to
//!     the fork and still reproduces the oracle exactly, as does an
//!     identical repeated prompt (reuse capped at `prompt_len − 1`);
//!   * slot reuse never leaks state between unrelated requests in either
//!     mode, cache on or off (reset-on-checkout is the default, reuse
//!     the explicit exception — the stale-slot regression);
//!   * shared-bandit play-count conservation holds under cache hits
//!     (cached prefill never enters reward accounting);
//!   * the `engine.cache` gauges (lookups/hits/ratio/evictions/served)
//!     observe what actually happened, and `SpecSession::resume` is
//!     byte-identical to a fresh decode at the session level;
//!   * the paged KV arena (docs/ARCHITECTURE.md §13) shares prompt pages
//!     across **busy** slots copy-on-write — a shared-prefix burst wider
//!     than the slot count still hits, the `engine.pages` gauges observe
//!     the sharing, and outputs stay byte-identical with page sharing
//!     on, off, and under an explicit (tight) arena in both modes.

mod common;

use std::sync::atomic::Ordering;

use common::{collect, oracle_tokens, TIMEOUT};
use tapout::engine::{BatchConfig, Engine, EngineConfig, EngineMode};
use tapout::models::{sim_encode, LanguageModel, Scenario, SimModel};
use tapout::spec::{generate, GenConfig, MethodSpec, SpecSession, StepOutcome, BOS};
use tapout::util::Rng;

/// This suite uses a slightly tighter decode budget than the shared
/// [`common::MAX_NEW`]: cache tests repeat every burst several times.
const MAX_NEW: usize = 40;

fn config(mode: EngineMode, workers: usize, slots: usize, cache: bool) -> EngineConfig {
    EngineConfig { mode, prefix_cache: cache, ..common::sim_config(workers, slots) }
}

/// A burst sharing one long system-prompt prefix (the workload the cache
/// exists for) with a short unique suffix per request.
fn shared_prefix_prompts(n: usize) -> Vec<String> {
    let system =
        "system: you are a terse serving assistant; answer from the shared template and stop. "
            .repeat(3);
    (0..n).map(|i| format!("{system}user {i}: question number {i} please")).collect()
}

fn run_burst(cfg: EngineConfig, prompts: &[String]) -> (Vec<Vec<u32>>, Engine) {
    let eng = Engine::start(cfg).unwrap();
    let rxs: Vec<_> = prompts.iter().map(|p| eng.submit(p, MAX_NEW)).collect();
    let out = collect(rxs)
        .into_iter()
        .enumerate()
        .map(|(i, r)| {
            assert!(r.is_ok(), "request {i} failed: {:?}", r.error);
            r.result.new_tokens().to_vec()
        })
        .collect();
    (out, eng)
}

/// (lookups, hits, cached_tokens) snapshot of an engine's cache gauges.
fn cache_counts(eng: &Engine) -> (u64, u64, u64) {
    let c = eng.cache_stats();
    (
        c.lookups.load(Ordering::Relaxed),
        c.hits.load(Ordering::Relaxed),
        c.cached_tokens.load(Ordering::Relaxed),
    )
}

#[test]
fn shared_prefix_burst_is_byte_identical_cache_on_off_and_oracle() {
    let prompts = shared_prefix_prompts(16);

    // reference: cache off, sequential Workers engine
    let (reference, seq) = run_burst(config(EngineMode::Workers, 1, 1, false), &prompts);
    seq.shutdown();
    for (i, out) in reference.iter().enumerate() {
        assert_eq!(
            out,
            &oracle_tokens(&prompts[i], MAX_NEW),
            "request {i}: cache-off reference diverged from the greedy oracle"
        );
    }

    // cache on, Workers mode (batched verification), workers {1, 4}
    for workers in [1usize, 4] {
        let (out, eng) = run_burst(config(EngineMode::Workers, workers, workers, true), &prompts);
        assert_eq!(out, reference, "workers={workers}: cache-on output diverged");
        let (lookups, hits, cached) = cache_counts(&eng);
        assert_eq!(lookups, 16, "workers={workers}: one lookup per request");
        assert!(hits > 0, "workers={workers}: shared prefixes must hit");
        assert!(cached > 0, "workers={workers}: hits must skip prompt tokens");
        eng.shutdown();
    }

    // cache on, Workers mode, direct (batcher-off) verification path
    {
        let mut cfg = config(EngineMode::Workers, 2, 2, true);
        cfg.verify_batch = BatchConfig::off();
        let (out, eng) = run_burst(cfg, &prompts);
        assert_eq!(out, reference, "direct-verify cache-on output diverged");
        assert!(cache_counts(&eng).1 > 0, "direct path must also hit");
        eng.shutdown();
    }

    // cache on, Continuous mode, slots {1, 4, 8}
    for slots in [1usize, 4, 8] {
        let (out, eng) = run_burst(config(EngineMode::Continuous, 0, slots, true), &prompts);
        assert_eq!(out, reference, "continuous slots={slots}: cache-on output diverged");
        let (lookups, hits, cached) = cache_counts(&eng);
        assert_eq!(lookups, 16, "continuous slots={slots}: one lookup per admission");
        assert!(hits > 0, "continuous slots={slots}: shared prefixes must hit");
        assert!(cached > 0, "continuous slots={slots}: hits must skip prompt tokens");
        eng.shutdown();
    }
}

#[test]
fn divergence_mid_prefix_rolls_back_to_the_fork() {
    // 1 worker / 1 slot: request B is forced onto the slot request A just
    // used; their prompts share a long prefix then diverge, so the slot
    // must roll back to the fork and prefill only B's suffix
    let common = "the quick brown fox jumps over the lazy dog again and again and again";
    let a = format!("{common} alpha continuation with extra words");
    let b = format!("{common} beta branch");
    for mode in [EngineMode::Workers, EngineMode::Continuous] {
        let eng = Engine::start(config(mode, 1, 1, true)).unwrap();
        let ra = eng.submit(&a, MAX_NEW).recv_timeout(TIMEOUT).unwrap();
        assert!(ra.is_ok(), "{:?}", ra.error);
        assert_eq!(ra.result.new_tokens(), &oracle_tokens(&a, MAX_NEW)[..], "{mode:?} A");
        assert_eq!(ra.result.cached_prefix, 0, "{mode:?}: first request cannot hit");

        let rb = eng.submit(&b, MAX_NEW).recv_timeout(TIMEOUT).unwrap();
        assert!(rb.is_ok(), "{:?}", rb.error);
        assert_eq!(
            rb.result.new_tokens(),
            &oracle_tokens(&b, MAX_NEW)[..],
            "{mode:?}: post-rollback output diverged from the oracle"
        );
        // BOS + the shared text + the shared leading space of the suffix
        assert!(
            rb.result.cached_prefix > common.len() / 2
                && rb.result.cached_prefix <= common.len() + 2,
            "{mode:?}: B must reuse about the common prefix (got {})",
            rb.result.cached_prefix
        );

        // identical repeated prompt: reuse is capped at prompt_len − 1
        // (the last prompt token is always re-fed), output still exact
        let rb2 = eng.submit(&b, MAX_NEW).recv_timeout(TIMEOUT).unwrap();
        assert!(rb2.is_ok(), "{:?}", rb2.error);
        assert_eq!(rb2.result.new_tokens(), rb.result.new_tokens(), "{mode:?} repeat");
        let b_tokens = sim_encode(&b).len() + 1; // + BOS
        assert_eq!(
            rb2.result.cached_prefix,
            b_tokens - 1,
            "{mode:?}: full-prompt reuse must stop one short of the whole prompt"
        );
        eng.shutdown();
    }
}

#[test]
fn stale_slot_state_never_leaks_between_requests() {
    // the stale-slot regression (reset-on-checkout default): back-to-back
    // unrelated requests through one slot must each match a fresh
    // engine's output, cache on or off, in both modes
    let first = "completely unrelated request about databases and indexes";
    let second = "short poem";
    for mode in [EngineMode::Workers, EngineMode::Continuous] {
        for cache in [false, true] {
            let eng = Engine::start(config(mode, 1, 1, cache)).unwrap();
            let r1 = eng.submit(first, MAX_NEW).recv_timeout(TIMEOUT).unwrap();
            assert!(r1.is_ok(), "{:?}", r1.error);
            let r2 = eng.submit(second, MAX_NEW).recv_timeout(TIMEOUT).unwrap();
            assert!(r2.is_ok(), "{:?}", r2.error);
            assert_eq!(
                r2.result.new_tokens(),
                &oracle_tokens(second, MAX_NEW)[..],
                "{mode:?} cache={cache}: second request observed stale slot state"
            );
            assert_eq!(
                r2.result.cached_prefix, 0,
                "{mode:?} cache={cache}: unrelated prompts must not reuse"
            );
            eng.shutdown();
        }
    }
}

#[test]
fn bandit_play_count_conservation_under_cache_hits() {
    let prompts = shared_prefix_prompts(12);
    let eng = Engine::start(config(EngineMode::Workers, 4, 4, true)).unwrap();
    let rxs: Vec<_> = prompts.iter().map(|p| eng.submit(p, MAX_NEW)).collect();
    let responses = collect(rxs);
    let rounds: u64 = responses
        .iter()
        .map(|r| {
            assert!(r.is_ok(), "{:?}", r.error);
            r.result.rounds.len() as u64
        })
        .sum();
    // one select + one reward per round, cache hits notwithstanding:
    // cached prefill never enters reward accounting (docs/POLICIES.md)
    assert_eq!(eng.bandit_sessions(), rounds);
    assert_eq!(eng.bandit_updates(), rounds);
    let counts = eng.bandit_counts().expect("seq-ucb1 has a shared bandit");
    assert_eq!(counts.iter().sum::<u64>(), rounds, "{counts:?}");
    assert!(cache_counts(&eng).1 > 0, "the burst must actually exercise hits");
    eng.shutdown();
}

#[test]
fn cache_gauges_observe_hits_evictions_and_per_slot_served() {
    let prompts = shared_prefix_prompts(8);
    let (_, eng) = run_burst(config(EngineMode::Workers, 2, 2, true), &prompts);
    let stats = eng.cache_stats();
    let lookups = stats.lookups.load(Ordering::Relaxed);
    let hits = stats.hits.load(Ordering::Relaxed);
    assert_eq!(lookups, 8);
    assert!(hits >= 1 && hits <= lookups);
    let ratio = stats.cached_token_ratio();
    assert!(ratio > 0.0 && ratio < 1.0, "ratio {ratio}");
    let served: u64 = stats.served.iter().map(|c| c.load(Ordering::Relaxed)).sum();
    assert_eq!(served, 8, "every request was served by some slot");

    // /metrics surfaces the same gauges under engine.cache
    let j = eng.metrics_json();
    let cache = j.get("engine").unwrap().get("cache").expect("engine.cache object");
    assert!(cache.get("enabled").unwrap().as_bool().unwrap());
    assert_eq!(cache.get("lookups").unwrap().as_usize().unwrap(), 8);
    assert!(cache.get("hit_rate").unwrap().as_f64().unwrap() > 0.0);
    assert!(cache.get("cached_token_ratio").unwrap().as_f64().unwrap() > 0.0);
    assert!(cache.get("served").is_some());
    eng.shutdown();

    // alternating unrelated prompts on one slot force evictions (their
    // only shared token is BOS, below the minimum-reuse threshold)
    let eng = Engine::start(config(EngineMode::Workers, 1, 1, true)).unwrap();
    for p in ["first topic entirely", "second topic entirely", "third topic entirely"] {
        let r = eng.submit(p, 16).recv_timeout(TIMEOUT).unwrap();
        assert!(r.is_ok(), "{:?}", r.error);
    }
    let ev = eng.cache_stats().evictions.load(Ordering::Relaxed);
    assert!(ev >= 2, "unmatched recorded prefixes must be evicted (got {ev})");
    eng.shutdown();
}

#[test]
fn busy_slot_burst_shares_pages_and_reports_engine_pages_gauges() {
    // 16 shared-prefix requests through 2 continuous slots: at any moment
    // at most 2 sessions are live, so most admissions find the matching
    // registration on a *busy* slot — under slot-affinity (PR 5) those
    // were misses; under the paged arena they adopt the shared pages
    // copy-on-write. Outputs must not move by a byte.
    let prompts = shared_prefix_prompts(16);
    let (reference, seq) = run_burst(config(EngineMode::Workers, 1, 1, false), &prompts);
    seq.shutdown();

    let (out, eng) = run_burst(config(EngineMode::Continuous, 0, 2, true), &prompts);
    assert_eq!(out, reference, "busy-slot page sharing changed the output");
    for (i, o) in out.iter().enumerate() {
        assert_eq!(o, &oracle_tokens(&prompts[i], MAX_NEW), "request {i} vs oracle");
    }

    let p = eng.page_stats();
    assert!(p.enabled, "paging gauges ride the prefix-cache switch");
    let shared_hits = p.shared_hits.load(Ordering::Relaxed);
    let adopted = p.adopted_tokens.load(Ordering::Relaxed);
    assert!(
        shared_hits > 0,
        "a burst wider than the slot count must hit busy-slot registrations"
    );
    assert!(adopted > 0, "shared hits must adopt prompt tokens");
    assert!(
        p.cow_copies.load(Ordering::Relaxed) > 0,
        "unaligned prefix boundaries must be copied, not shared"
    );
    let total = p.total.load(Ordering::Relaxed);
    let free = p.free.load(Ordering::Relaxed);
    assert!(total > 0 && free <= total, "arena gauges must be coherent");
    assert!(p.peak_resident.load(Ordering::Relaxed) <= total);
    // shared hits are regular cache hits too: the tokens they skip are
    // accounted once, in the same cached_tokens gauge
    let (lookups, hits, cached) = cache_counts(&eng);
    assert_eq!(lookups, 16);
    assert!(hits >= shared_hits, "every shared hit is a cache hit");
    assert!(cached >= adopted, "adopted tokens are cached tokens");

    // /metrics surfaces the same gauges under engine.pages
    let j = eng.metrics_json();
    let pages = j.get("engine").unwrap().get("pages").expect("engine.pages object");
    assert!(pages.get("enabled").unwrap().as_bool().unwrap());
    assert_eq!(pages.get("total").unwrap().as_usize().unwrap() as u64, total);
    assert_eq!(pages.get("shared_hits").unwrap().as_usize().unwrap() as u64, shared_hits);
    assert!(pages.get("shared_hit_rate").unwrap().as_f64().unwrap() > 0.0);
    assert!(pages.get("cow_copies").is_some() && pages.get("evictions").is_some());
    eng.shutdown();
}

#[test]
fn page_sharing_on_off_and_tight_arena_are_byte_identical_in_both_modes() {
    // the paging knobs are performance-only: page sharing off (the PR-5
    // slot-affinity baseline), a non-default page size, and an explicit
    // arena small enough to force page-LRU eviction all reproduce the
    // cache-off reference exactly, in both execution modes
    let prompts = shared_prefix_prompts(12);
    let (reference, seq) = run_burst(config(EngineMode::Workers, 1, 1, false), &prompts);
    seq.shutdown();

    for mode in [EngineMode::Workers, EngineMode::Continuous] {
        let workers = if mode == EngineMode::Workers { 4 } else { 0 };
        for sharing in [false, true] {
            let mut cfg = config(mode, workers, 4, true);
            cfg.page_size = 8;
            cfg.page_sharing = sharing;
            let (out, eng) = run_burst(cfg, &prompts);
            assert_eq!(out, reference, "{mode:?} sharing={sharing}: output diverged");
            if !sharing {
                assert_eq!(
                    eng.page_stats().shared_hits.load(Ordering::Relaxed),
                    0,
                    "{mode:?}: sharing off must never adopt busy-slot pages"
                );
            }
            eng.shutdown();
        }
    }

    // tight arena: ~42 pages per live chain at page_size 8, so 96 pages
    // across 2 slots leaves little slack — cached chains get evicted
    // under pressure and the bookkeeping saturates, never the decode
    let mut cfg = config(EngineMode::Continuous, 0, 2, true);
    cfg.page_size = 8;
    cfg.kv_pages = 96;
    let (out, eng) = run_burst(cfg, &prompts);
    assert_eq!(out, reference, "tight-arena output diverged");
    assert_eq!(
        eng.page_stats().total.load(Ordering::Relaxed),
        96,
        "an explicit --kv-pages arena must be honored, not auto-sized"
    );
    eng.shutdown();
}

#[test]
fn session_resume_is_byte_identical_to_fresh_decode() {
    // the session-level contract under the engine integration: resuming
    // over retained state equals a fresh decode token-for-token, with
    // identical round structure (drafted/accepted per round)
    let shared: Vec<u32> =
        std::iter::once(BOS).chain((0..24).map(|i| 3 + (i % 20) as u32)).collect();
    let mut p1 = shared.clone();
    p1.extend([7, 8, 9]);
    let mut p2 = shared.clone();
    p2.extend([10, 11]);
    let cfg = GenConfig { max_new: 32, gamma_max: 32, stop_at_eos: false, collect_signals: false };

    // request 1 leaves resident state on the "slot" models
    let sc1 = Scenario::new(1, "qa");
    let mut draft = SimModel::draft(sc1, 0.9, 0.05);
    let mut target = SimModel::target(sc1);
    let mut ctrl = MethodSpec::parse("seq-ucb1", "artifacts").unwrap().build(32).unwrap();
    let mut rng = Rng::new(7);
    let r1 = generate(&mut draft, &mut target, &mut ctrl, &mut rng, &p1, &cfg).unwrap();
    assert_eq!(r1.cached_prefix, 0, "a fresh generate never reuses");

    // fresh reference decode of request 2
    let sc2 = Scenario::new(2, "qa");
    let mut fdraft = SimModel::draft(sc2, 0.9, 0.05);
    let mut ftarget = SimModel::target(sc2);
    let mut fctrl = MethodSpec::parse("seq-ucb1", "artifacts").unwrap().build(32).unwrap();
    let mut frng = Rng::new(9);
    let want = generate(&mut fdraft, &mut ftarget, &mut fctrl, &mut frng, &p2, &cfg).unwrap();

    // resume request 2 on the used models, retaining the shared prefix
    let lcp = shared.len();
    let resident = draft.retain_prefix(2, "qa", lcp).min(target.retain_prefix(2, "qa", lcp));
    assert_eq!(resident, lcp, "sim retains the full requested prefix");
    let mut rctrl = MethodSpec::parse("seq-ucb1", "artifacts").unwrap().build(32).unwrap();
    let mut rrng = Rng::new(9);
    let mut sess = SpecSession::resume(
        &mut draft,
        &mut target,
        &mut rctrl,
        &mut rrng,
        &p2,
        &cfg,
        resident,
    )
    .unwrap();
    while let StepOutcome::Round(_) = sess.step().unwrap() {}
    let got = sess.finish();
    assert_eq!(got.tokens, want.tokens, "resumed decode diverged from fresh decode");
    assert_eq!(got.cached_prefix, lcp);
    let gr: Vec<_> = got.rounds.iter().map(|r| (r.drafted, r.accepted)).collect();
    let wr: Vec<_> = want.rounds.iter().map(|r| (r.drafted, r.accepted)).collect();
    assert_eq!(gr, wr, "cache hits must not change round structure or acceptance stats");
}

#[test]
fn session_resume_guards_reject_bad_residency() {
    let sc = Scenario::new(3, "qa");
    let mut draft = SimModel::draft(sc, 0.9, 0.05);
    let mut target = SimModel::target(sc);
    let mut ctrl = MethodSpec::parse("static-6", "artifacts").unwrap().build(16).unwrap();
    let mut rng = Rng::new(1);
    let prompt: Vec<u32> = vec![BOS, 5, 6, 7];
    let cfg = GenConfig { max_new: 8, ..GenConfig::default() };

    // fresh models cannot cover a claimed resident prefix
    let err = SpecSession::resume(&mut draft, &mut target, &mut ctrl, &mut rng, &prompt, &cfg, 3);
    assert!(err.is_err(), "fresh cursors cannot satisfy resident=3");
    assert!(format!("{:#}", err.err().unwrap()).contains("resident-prefix contract"));

    // the whole prompt can never be resident (the last token is re-fed)
    let err = SpecSession::resume(&mut draft, &mut target, &mut ctrl, &mut rng, &prompt, &cfg, 4);
    assert!(err.is_err(), "resident == prompt len must be rejected");
}
