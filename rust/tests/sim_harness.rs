//! End-to-end tests for the deterministic engine simulator
//! (`sim_harness/`, docs/TESTING.md): seed-matrix determinism, fault
//! injection across both execution modes, cache/paging reply equality,
//! the sabotage → oracle → shrink → replay pipeline, and replay of every
//! checked-in regression fixture in `rust/tests/sim_regressions/`.

use tapout::engine::FinishStatus;
use tapout::sim_harness::{run_plan, shrink, SimOp, SimPlan};
use tapout::util::Json;

fn submit(req: u64, prompt: &str, max_new: usize) -> SimOp {
    SimOp::Submit {
        req,
        prompt: prompt.to_string(),
        category: "qa".to_string(),
        max_new,
        deadline_ns: None,
    }
}

/// A handcrafted fault-free plan: shared-prefix flood + steps, no
/// cancels/deadlines, so replies must be identical under every
/// cache / sharing / paging configuration.
fn flood_plan() -> SimPlan {
    SimPlan {
        seed: 40,
        mode: "workers".to_string(),
        slots: 2,
        workers: 2,
        gamma_max: 4,
        method: "static-4".to_string(),
        cache: false,
        sharing: false,
        page_size: 8,
        kv_pages: 0,
        faults: false,
        max_faults: 0,
        sabotage: false,
        replicas: 1,
        affinity: true,
        pipeline: false,
        drafters: 1,
        tenants: 1,
        ops: vec![
            submit(0, "shared context block alpha", 8),
            SimOp::Step { n: 4 },
            submit(1, "shared context block beta", 8),
            submit(2, "shared context block gamma", 7),
            SimOp::Step { n: 6 },
            submit(3, "shared context block delta", 6),
        ],
    }
}

/// ISSUE acceptance: same seed ⇒ identical event trace and oracle
/// outcome. Run a seed matrix twice and compare fingerprints.
#[test]
fn seed_matrix_replays_byte_identically() {
    for seed in 0..6u64 {
        let plan = SimPlan::generate(seed, 50);
        let a = run_plan(&plan);
        let b = run_plan(&plan);
        assert_eq!(a.violation, None, "seed {seed} trace:\n{}", a.trace.join("\n"));
        assert_eq!(a.trace, b.trace, "seed {seed}: trace must replay exactly");
        assert_eq!(a.trace_hash, b.trace_hash, "seed {seed}");
        assert_eq!(a.replies, b.replies, "seed {seed}");
        assert_eq!(a.clock_ns, b.clock_ns, "seed {seed}: virtual time is part of the trace");
    }
}

/// Router mode: multi-replica fleet plans (replica kills and drains
/// spliced in) replay byte-identically and keep every invariant, with
/// and without model-level fault injection on top.
#[test]
fn fleet_seed_matrix_replays_byte_identically() {
    for seed in 0..5u64 {
        for faults in [false, true] {
            let mut plan = SimPlan::generate_fleet(seed, 50, 3);
            plan.faults = faults;
            let a = run_plan(&plan);
            let b = run_plan(&plan);
            assert_eq!(
                a.violation,
                None,
                "seed {seed} faults {faults} trace:\n{}",
                a.trace.join("\n")
            );
            assert_eq!(a.trace, b.trace, "seed {seed} faults {faults}");
            assert_eq!(a.replies, b.replies, "seed {seed} faults {faults}");
            // every submitted request still reaches a terminal state,
            // replica faults notwithstanding
            assert_eq!(a.replies.len(), plan.submits(), "seed {seed} faults {faults}");
        }
    }
}

/// Fault injection across both execution cores: the oracle must hold
/// (losslessness, conservation, legal statuses) with errors, crashes,
/// slow steps and lost leases all firing.
#[test]
fn fault_injection_holds_invariants_in_both_modes() {
    for seed in 0..4u64 {
        for mode in ["workers", "continuous"] {
            let mut plan = SimPlan::generate(seed, 50);
            plan.faults = true;
            plan.mode = mode.to_string();
            let r = run_plan(&plan);
            assert_eq!(
                r.violation,
                None,
                "seed {seed} mode {mode} trace:\n{}",
                r.trace.join("\n")
            );
            // every submitted request reached a terminal state
            assert_eq!(r.replies.len(), plan.submits(), "seed {seed} mode {mode}");
        }
    }
}

/// Deterministic fault streams: the same faulted plan replays to the
/// identical trace, fault timing included.
#[test]
fn faulted_runs_are_deterministic_too() {
    let mut plan = SimPlan::generate(2, 40);
    plan.faults = true;
    let a = run_plan(&plan);
    let b = run_plan(&plan);
    assert_eq!(a.trace, b.trace);
    assert_eq!(a.replies, b.replies);
}

/// Losslessness across engine configurations: cache on/off, page sharing
/// on/off, auto-sized vs bounded arena — same plan, byte-identical
/// replies (the oracle already pins each run against target-only greedy;
/// this pins the runs against each other).
#[test]
fn cache_and_paging_config_never_changes_replies() {
    let base = flood_plan();
    let mut cached = base.clone();
    cached.cache = true;
    let mut shared_pages = base.clone();
    shared_pages.cache = true;
    shared_pages.sharing = true;
    let mut bounded = base.clone();
    bounded.cache = true;
    bounded.sharing = true;
    bounded.kv_pages = 96;
    let mut continuous = base.clone();
    continuous.mode = "continuous".to_string();
    let mut pipelined = base.clone();
    pipelined.mode = "continuous".to_string();
    pipelined.pipeline = true;

    let want = run_plan(&base);
    assert_eq!(want.violation, None, "trace:\n{}", want.trace.join("\n"));
    assert_eq!(want.count(FinishStatus::Done), 4);
    for (label, plan) in [
        ("prefix cache", cached),
        ("cache + page sharing", shared_pages),
        ("bounded arena", bounded),
        ("continuous core", continuous),
        ("pipelined continuous core", pipelined),
    ] {
        let got = run_plan(&plan);
        assert_eq!(got.violation, None, "{label} trace:\n{}", got.trace.join("\n"));
        assert_eq!(got.replies, want.replies, "{label}: replies must be config-invariant");
    }
}

/// ISSUE acceptance: an intentionally injected invariant violation (the
/// test-only sabotage hook) is caught by the oracle, shrinks to a ≤20-op
/// trace, and the shrunk plan still reproduces after a JSON round-trip —
/// the exact pipeline that produces `rust/tests/sim_regressions/`.
#[test]
fn sabotage_is_caught_shrunk_and_replayable() {
    let mut plan = SimPlan::generate(5, 40);
    plan.sabotage = true;
    let report = run_plan(&plan);
    let v = report.violation.expect("sabotaged page accounting must be caught");
    assert!(v.what.contains("free-list drift"), "got: {}", v.what);

    let min = shrink(&plan);
    assert!(min.ops.len() <= 20, "shrunk to {} ops", min.ops.len());
    assert!(run_plan(&min).violation.is_some());

    let text = min.to_json().render();
    let back = SimPlan::from_json(&Json::parse(&text).unwrap()).unwrap();
    assert_eq!(back, min, "fixture serialization round-trips");
    assert!(run_plan(&back).violation.is_some(), "violation survives the round-trip");
}

/// Replay every checked-in regression fixture: sabotage fixtures must
/// still trip the oracle (pinning the detection + replay pipeline),
/// all others must run clean (pinning fixed bugs closed).
#[test]
fn regression_fixtures_replay_as_recorded() {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/rust/tests/sim_regressions");
    let mut seen = 0;
    for entry in std::fs::read_dir(dir).expect("sim_regressions/ exists") {
        let path = entry.unwrap().path();
        if path.extension().and_then(|e| e.to_str()) != Some("json") {
            continue;
        }
        let name = path.file_name().unwrap().to_string_lossy().to_string();
        let text = std::fs::read_to_string(&path).unwrap();
        let plan = SimPlan::from_json(&Json::parse(&text).unwrap_or_else(|e| {
            panic!("{name}: bad json: {e}");
        }))
        .unwrap_or_else(|e| panic!("{name}: bad plan: {e}"));
        let r = run_plan(&plan);
        // fixtures predating the two-lane clock carry no `pipeline` key:
        // they must replay with the second lane silent (zero overlap —
        // `advance_round(d, v, 0)` is exactly the old flat `advance`)
        if !plan.pipeline {
            assert_eq!(r.overlap_ns, 0, "{name}: a serialized fixture hid wall-clock time");
            assert_eq!(r.spec_attempted, 0, "{name}: a serialized fixture speculated");
        }
        if plan.sabotage {
            assert!(r.violation.is_some(), "{name}: sabotage fixture no longer trips the oracle");
        } else {
            assert_eq!(
                r.violation,
                None,
                "{name}: regression resurfaced; trace:\n{}",
                r.trace.join("\n")
            );
        }
        seen += 1;
    }
    assert!(seen >= 2, "expected the checked-in fixtures, found {seen}");
}
