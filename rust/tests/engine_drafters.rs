//! Hierarchical drafter-pool integration tests (docs/ARCHITECTURE.md
//! §17) on the simulator backend:
//!
//!   * multi-drafter bursts stay byte-identical to the target-only
//!     greedy oracle across Workers {1, 4} × Continuous slots {1, 4, 8}
//!     × pipeline on/off × faults on/off — the outer selection layer
//!     routes drafting, never output bytes;
//!   * two-layer play-count conservation in every config: rounds ==
//!     policy plays == drafter plays == Σ per-tenant counts, including
//!     mid-decode cancellation and fault-aborted rounds;
//!   * a pool of one is byte-identical to the pool-of-three engine
//!     (and therefore to the pre-pool engine, which existing suites pin);
//!   * tenants accumulate separate posteriors whose ledgers sum to the
//!     global ledger, and `/metrics` reports the `drafters` gauge block.

mod common;

use std::time::Duration;

use common::{collect, oracle_tokens, sim_config, TIMEOUT};
use tapout::engine::{Engine, EngineConfig, EngineMode, FinishStatus, Request, StreamEvent};
use tapout::models::FaultPlan;

/// Short decodes: the interesting part is selection and accounting.
const MAX_NEW: usize = 16;

fn pool_config(mode: EngineMode, workers: usize, slots: usize, drafters: usize) -> EngineConfig {
    EngineConfig { mode, drafters, ..sim_config(workers, slots) }
}

/// The two-layer conservation law: the drafter layer plays at exactly
/// the policy bandit's cadence (one begin per round, one settle per
/// verify/abort), both scopes of the drafter ledger agree, and neither
/// layer mints or loses a play.
fn assert_two_layer_conservation(eng: &Engine, ctx: &str) {
    let d = eng.drafters();
    assert_eq!(
        eng.bandit_sessions(),
        eng.bandit_updates(),
        "{ctx}: policy layer leaked plays"
    );
    assert_eq!(d.sessions(), d.updates(), "{ctx}: drafter layer leaked plays");
    assert_eq!(
        d.sessions(),
        eng.bandit_sessions(),
        "{ctx}: the two layers must play at the same cadence"
    );
    assert_eq!(
        d.plays().iter().sum::<u64>(),
        d.updates(),
        "{ctx}: Σ global drafter plays != settles"
    );
    assert_eq!(
        d.tenant_plays_total(),
        d.updates(),
        "{ctx}: Σ per-tenant drafter plays != settles"
    );
}

/// Submit `n` distinct prompts, tenants alternating tA/tB, and return
/// (prompts, responses).
fn tenant_burst(eng: &Engine, n: usize, label: &str) -> (Vec<String>, Vec<tapout::engine::Response>) {
    let prompts: Vec<String> =
        (0..n).map(|i| format!("{label} pooled request number {i}: summarize")).collect();
    let rxs: Vec<_> = prompts
        .iter()
        .enumerate()
        .map(|(i, p)| {
            let req = Request::new(i as u64, p.as_str(), MAX_NEW)
                .with_tenant(if i % 2 == 0 { "tA" } else { "tB" });
            eng.submit_request(req)
        })
        .collect();
    (prompts, collect(rxs))
}

#[test]
fn multi_drafter_bursts_match_oracle_across_modes_pipeline_and_faults() {
    // (mode, workers, slots, pipeline) — the full execution matrix the
    // acceptance criteria name; pipeline is a continuous-only knob (a
    // documented no-op in Workers mode)
    let matrix: &[(EngineMode, usize, usize, bool)] = &[
        (EngineMode::Workers, 1, 1, false),
        (EngineMode::Workers, 4, 4, false),
        (EngineMode::Continuous, 0, 1, false),
        (EngineMode::Continuous, 0, 4, false),
        (EngineMode::Continuous, 0, 8, false),
        (EngineMode::Continuous, 0, 4, true),
        (EngineMode::Continuous, 0, 8, true),
    ];
    for faults in [false, true] {
        for &(mode, workers, slots, pipeline) in matrix {
            let ctx = format!(
                "{mode:?} workers={workers} slots={slots} pipeline={pipeline} faults={faults}"
            );
            let mut config = pool_config(mode, workers, slots, 3);
            config.pipeline = pipeline;
            if faults {
                // error_rate 1.0 with a tiny kill budget: early requests
                // provably fail, the budget provably exhausts, the tail
                // provably succeeds — every path through abort settling
                config.faults =
                    FaultPlan { seed: 11, error_rate: 1.0, max_faults: 2, ..FaultPlan::default() };
            }
            let eng = Engine::start(config).unwrap();
            let (prompts, responses) = tenant_burst(&eng, 10, &ctx);
            let mut total_rounds = 0u64;
            let mut failed = 0usize;
            for (i, r) in responses.iter().enumerate() {
                total_rounds += r.result.rounds.len() as u64;
                match r.status {
                    FinishStatus::Done => {
                        assert_eq!(
                            r.result.new_tokens(),
                            &oracle_tokens(&prompts[i], MAX_NEW)[..],
                            "{ctx} request {i}: pooled output diverged from the greedy oracle"
                        );
                    }
                    FinishStatus::Failed => {
                        assert!(faults, "{ctx} request {i}: failure without fault injection");
                        failed += 1;
                    }
                    other => panic!("{ctx} request {i}: unexpected terminal {other:?}"),
                }
            }
            if faults {
                assert!(failed > 0, "{ctx}: the kill budget must claim at least one request");
            } else {
                // fault-free runs additionally tie the layer counters to
                // the observable round count
                assert_eq!(eng.drafters().sessions(), total_rounds, "{ctx}");
            }
            assert_two_layer_conservation(&eng, &ctx);
            // the pool actually pooled: three drafters exist, and the
            // per-tenant ledgers cover both tenants
            let d = eng.drafters();
            assert_eq!(d.n(), 3, "{ctx}");
            let snap = d.tenant_snapshot();
            let keys: Vec<&str> = snap.iter().map(|t| t.tenant.as_str()).collect();
            assert!(keys.contains(&"tA") && keys.contains(&"tB"), "{ctx}: {keys:?}");
            eng.shutdown();
        }
    }
}

#[test]
fn pool_of_one_outputs_equal_pool_of_three_outputs() {
    // drafter selection must never touch output bytes: the same burst
    // through a pool-of-one and a pool-of-three engine decodes to the
    // identical replies (existing suites pin pool-of-one == pre-pool)
    let mut outs: Vec<Vec<Vec<u32>>> = Vec::new();
    for drafters in [1usize, 3] {
        let eng = Engine::start(pool_config(EngineMode::Workers, 2, 2, drafters)).unwrap();
        let (_, responses) = tenant_burst(&eng, 8, "pool size invariance");
        outs.push(
            responses
                .iter()
                .map(|r| {
                    assert!(r.is_ok(), "drafters={drafters}: {:?}", r.error);
                    r.result.new_tokens().to_vec()
                })
                .collect(),
        );
        assert_two_layer_conservation(&eng, &format!("drafters={drafters}"));
        if drafters == 1 {
            // a pool of one always selects drafter 0
            let plays = eng.drafters().plays();
            assert_eq!(plays.len(), 1);
            assert_eq!(plays[0], eng.drafters().updates());
        }
        eng.shutdown();
    }
    assert_eq!(outs[0], outs[1], "pool size changed output bytes");
}

#[test]
fn mid_decode_cancel_keeps_both_layers_conserved() {
    let eng = Engine::start(pool_config(EngineMode::Continuous, 0, 1, 3)).unwrap();
    // sim scenarios never emit EOS: this decode would run ~3800 tokens
    let req = Request::new(0, "pooled continuous decode to cancel midway", 3800)
        .with_tenant("tA");
    let flag = req.cancel_flag();
    let rx = eng.submit_request_streaming(req);
    match rx.recv_timeout(TIMEOUT).expect("first event") {
        StreamEvent::Tokens { .. } => flag.cancel(),
        StreamEvent::Done(r) => panic!("decode finished before cancellation: {:?}", r.status),
    }
    let done = loop {
        match rx.recv_timeout(TIMEOUT).expect("stream must terminate") {
            StreamEvent::Tokens { .. } => {}
            StreamEvent::Done(resp) => break *resp,
        }
    };
    assert_eq!(done.status, FinishStatus::Cancelled);

    // the cancelled session's slot is free again and the layers agree:
    // the drafter ledger mirrors the policy ledger exactly, with at most
    // the in-flight round of the cancel settle-less in both
    let ok = eng
        .submit_request(Request::new(1, "follow-up after pooled cancel", MAX_NEW).with_tenant("tB"))
        .recv_timeout(TIMEOUT)
        .unwrap();
    assert!(ok.is_ok(), "{:?}", ok.error);
    // quiesce: the stepper may still be retiring the cancelled session
    std::thread::sleep(Duration::from_millis(20));
    let d = eng.drafters();
    assert_eq!(d.sessions(), eng.bandit_sessions(), "layers diverged under cancel");
    assert_eq!(d.updates(), eng.bandit_updates(), "layers diverged under cancel");
    assert!(d.sessions() - d.updates() <= 1, "cancel may strand at most one play");
    assert_eq!(d.plays().iter().sum::<u64>(), d.updates());
    assert_eq!(d.tenant_plays_total(), d.updates());
    eng.shutdown();
}

#[test]
fn tenants_accumulate_separate_posteriors_that_sum_to_global() {
    let eng = Engine::start(pool_config(EngineMode::Workers, 2, 2, 2)).unwrap();
    let mut rxs = Vec::new();
    for (i, tenant) in [(0u64, Some("tA")), (1, Some("tA")), (2, Some("tB")), (3, None)] {
        let mut req = Request::new(i, format!("tenant ledger probe {i}"), MAX_NEW);
        if let Some(t) = tenant {
            req = req.with_tenant(t);
        }
        rxs.push(eng.submit_request(req));
    }
    for r in collect(rxs) {
        assert!(r.is_ok(), "{:?}", r.error);
    }
    let d = eng.drafters();
    let snap = d.tenant_snapshot();
    let keys: Vec<&str> = snap.iter().map(|t| t.tenant.as_str()).collect();
    // sorted: the untenanted request lands in the global ("") tenant
    assert_eq!(keys, vec!["", "tA", "tB"]);
    let per_tenant: u64 = snap.iter().map(|t| t.plays.iter().sum::<u64>()).sum();
    assert_eq!(per_tenant, d.updates(), "tenant ledgers must partition the global ledger");
    for t in &snap {
        assert!(t.obs > 0, "tenant {:?} saw rounds", t.tenant);
        assert_eq!(t.means.len(), 2);
    }
    assert!(d.modal_drafter("tA").is_some());
    assert!(d.modal_drafter("unseen").is_none());
    assert_two_layer_conservation(&eng, "tenant ledger");
    eng.shutdown();
}

#[test]
fn metrics_json_reports_the_drafter_layer() {
    let eng = Engine::start(pool_config(EngineMode::Workers, 2, 2, 2)).unwrap();
    let (_, responses) = tenant_burst(&eng, 6, "drafter metrics");
    for r in &responses {
        assert!(r.is_ok(), "{:?}", r.error);
    }
    let j = eng.metrics_json();
    let d = j.get("drafters").expect("drafters gauge block is always present");
    assert_eq!(d.get("n").unwrap().as_usize().unwrap(), 2);
    let sessions = d.get("sessions").unwrap().as_usize().unwrap();
    assert_eq!(d.get("updates").unwrap().as_usize().unwrap(), sessions);
    assert!(sessions > 0);
    let tenants = d.get("tenants").expect("per-tenant drafter readout");
    assert!(tenants.get("tA").is_some() && tenants.get("tB").is_some());
    // the policy bandit gained a nested per-tenant view without moving
    // its legacy flat fields (OPERATIONS.md contract)
    let b = j.get("bandit").expect("shared bandit block");
    assert!(b.get("sessions").is_some() && b.get("arm_counts").is_some());
    let bt = b.get("tenants").expect("keyed policy posteriors for tenanted traffic");
    assert!(
        bt.render().contains("tA#"),
        "keyed entries are tenant#drafter: {}",
        bt.render()
    );
    eng.shutdown();
}
