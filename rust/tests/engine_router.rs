//! Multi-replica router-tier integration tests (docs/ARCHITECTURE.md
//! §15): fleet health/metrics aggregation, prefix-affinity placement and
//! its cache-hit-rate edge over round-robin, replica kill mid-stream →
//! honest terminal + failover, draining semantics, slow-loris 408 in
//! both I/O modes, SSE keep-alives, reactor-vs-blocking reply parity,
//! and connection scaling on a fixed I/O-thread pool.

mod common;

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

use common::{http_get_json, http_post_json, oracle_tokens, sim_config, TIMEOUT};
use tapout::engine::{
    Engine, EngineMode, EventSource, Gateway, GenerateStart, HttpConfig, HttpServer, IoStats,
    Reactor, ReactorConfig, ReplicaView, Router, RouterConfig, RouterCore, SourceEvent,
};
use tapout::models::sim_decode;
use tapout::util::Json;

// ---------------------------------------------------------------------------
// scaffolding
// ---------------------------------------------------------------------------

/// One sim-backend replica (prefix cache + COW page sharing on) behind
/// its own reactor front end.
fn replica() -> (Arc<Engine>, HttpServer) {
    let mut cfg = sim_config(2, 2);
    cfg.prefix_cache = true;
    cfg.page_sharing = true;
    let eng = Arc::new(Engine::start(cfg).unwrap());
    let http = HttpServer::start_with(
        eng.clone(),
        0,
        HttpConfig { io_threads: 2, ..HttpConfig::default() },
    )
    .unwrap();
    (eng, http)
}

/// A router over the given replica addresses, probed until every
/// replica has been seen alive (so tests never race the first probe).
fn router_over(replicas: Vec<String>, affinity: bool) -> Router {
    let n = replicas.len();
    let cfg = RouterConfig {
        replicas,
        affinity,
        page_size: 16,
        probe_ms: 50,
        io_threads: 2,
        ..RouterConfig::default()
    };
    let router = Router::start(cfg, 0).unwrap();
    let deadline = Instant::now() + TIMEOUT;
    while !(0..n).all(|i| router.replica_alive(i)) {
        assert!(Instant::now() < deadline, "replicas never probed alive");
        std::thread::sleep(Duration::from_millis(10));
    }
    router
}

/// `n` in-process replicas behind one router.
fn fleet(n: usize, affinity: bool) -> (Vec<(Arc<Engine>, HttpServer)>, Router) {
    let reps: Vec<(Arc<Engine>, HttpServer)> = (0..n).map(|_| replica()).collect();
    let addrs = reps.iter().map(|(_, h)| h.addr.clone()).collect();
    (reps, router_over(addrs, affinity))
}

/// The replica index the affinity policy owns `prompt` to — computed
/// with the live router's own [`RouterCore`], so tests predict
/// placements instead of discovering them.
fn owner_of(prompt: &str, n: usize) -> usize {
    let views = vec![ReplicaView { alive: true, draining: false, queue_wait: 0.0 }; n];
    RouterCore::new(n, 16, true).route(prompt, &views).unwrap().replica
}

/// The target-only greedy text every placement must reproduce.
fn oracle_text(prompt: &str, max_new: usize) -> String {
    sim_decode(&oracle_tokens(prompt, max_new))
}

/// Unary generate via `addr`, asserting 200/done and byte-exact oracle
/// text — placement must never change bytes.
fn generate_ok(addr: &str, prompt: &str, max_new: usize) -> Json {
    let body = format!("{{\"prompt\": \"{prompt}\", \"max_new\": {max_new}}}");
    let (code, j) = http_post_json(addr, "/generate", &body);
    assert_eq!(code, 200, "{j:?}");
    assert_eq!(j.get("status").and_then(|s| s.as_str()), Some("done"), "{j:?}");
    let want = oracle_text(prompt, max_new);
    assert_eq!(j.get("text").and_then(|t| t.as_str()), Some(want.as_str()), "for {prompt:?}");
    j
}

/// Poll the router's fleet `/metrics` until `pred` holds (replica
/// snapshots refresh on the probe cadence, not synchronously).
fn wait_metrics(addr: &str, pred: impl Fn(&Json) -> bool) -> Json {
    let deadline = Instant::now() + TIMEOUT;
    loop {
        let (code, m) = http_get_json(addr, "/metrics");
        if code == 200 && pred(&m) {
            return m;
        }
        assert!(Instant::now() < deadline, "fleet metrics never converged: {}", m.render());
        std::thread::sleep(Duration::from_millis(25));
    }
}

/// Open a streaming generate and return the raw socket (response not
/// yet read).
fn open_stream(addr: &str, prompt: &str, max_new: usize) -> TcpStream {
    let mut s = TcpStream::connect(addr).unwrap();
    let body = format!("{{\"prompt\": \"{prompt}\", \"max_new\": {max_new}, \"stream\": true}}");
    write!(s, "POST /generate HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{body}", body.len())
        .unwrap();
    s
}

/// Read from `s` into `raw` until `marker` appears (or the peer closes).
fn read_until(s: &mut TcpStream, marker: &str, raw: &mut String) {
    s.set_read_timeout(Some(Duration::from_millis(50))).unwrap();
    let deadline = Instant::now() + TIMEOUT;
    let mut buf = [0u8; 4096];
    while !raw.contains(marker) {
        assert!(Instant::now() < deadline, "timed out waiting for {marker:?}; got:\n{raw}");
        match s.read(&mut buf) {
            Ok(0) => return,
            Ok(n) => raw.push_str(&String::from_utf8_lossy(&buf[..n])),
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {}
            Err(e) if e.kind() == std::io::ErrorKind::TimedOut => {}
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => panic!("stream read: {e}"),
        }
    }
}

/// Drain the rest of a response (until close) into `raw`; a reset
/// during server teardown counts as a close.
fn read_to_close(s: &mut TcpStream, raw: &mut String) {
    s.set_read_timeout(Some(TIMEOUT)).unwrap();
    let mut buf = [0u8; 4096];
    loop {
        match s.read(&mut buf) {
            Ok(0) => return,
            Ok(n) => raw.push_str(&String::from_utf8_lossy(&buf[..n])),
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(_) => return,
        }
    }
}

/// De-chunk a raw SSE response and parse its `data:` payloads in order
/// (keep-alive comments are not data events and are skipped).
fn sse_events(raw: &str) -> Vec<Json> {
    let body = raw.split_once("\r\n\r\n").map(|x| x.1).unwrap_or("");
    let mut data = String::new();
    let mut rest = body;
    loop {
        let Some((size_str, after)) = rest.split_once("\r\n") else { break };
        let Ok(size) = usize::from_str_radix(size_str.trim(), 16) else { break };
        if size == 0 || after.len() < size + 2 {
            break;
        }
        data.push_str(&after[..size]);
        rest = &after[size + 2..];
    }
    data.split("\n\n")
        .filter_map(|ev| ev.trim_end().strip_prefix("data: "))
        .filter_map(|p| Json::parse(p).ok())
        .collect()
}

/// Concatenated (ids, text) of a stream's token events plus its
/// terminal `done` event.
fn stream_summary(events: &[Json]) -> (Vec<usize>, String, Json) {
    let mut ids = Vec::new();
    let mut text = String::new();
    let mut done = Json::Null;
    for ev in events {
        if ev.get("done").and_then(|d| d.as_bool()) == Some(true) {
            done = ev.clone();
        } else if let Some(arr) = ev.get("ids").and_then(|i| i.as_arr()) {
            ids.extend(arr.iter().filter_map(|x| x.as_usize()));
            text.push_str(ev.get("text").and_then(|t| t.as_str()).unwrap_or(""));
        }
    }
    (ids, text, done)
}

/// Send raw wire bytes, return the complete raw response.
fn raw_exchange(addr: &str, wire: &str) -> String {
    let mut s = TcpStream::connect(addr).unwrap();
    s.write_all(wire.as_bytes()).unwrap();
    let mut raw = String::new();
    s.read_to_string(&mut raw).unwrap();
    raw
}

// ---------------------------------------------------------------------------
// fleet health + metrics aggregation
// ---------------------------------------------------------------------------

#[test]
fn fleet_health_and_metrics_aggregate_across_replicas() {
    let (_reps, router) = fleet(2, true);

    let (code, h) = http_get_json(&router.addr, "/health");
    assert_eq!(code, 200);
    assert_eq!(h.get("ok").and_then(|x| x.as_bool()), Some(true));
    assert_eq!(h.get("role").and_then(|x| x.as_str()), Some("router"));
    assert_eq!(h.get("replicas").and_then(|x| x.as_usize()), Some(2));
    assert_eq!(h.get("alive").and_then(|x| x.as_usize()), Some(2));
    let members = h.get("fleet").and_then(|f| f.as_arr()).expect("fleet array");
    assert_eq!(members.len(), 2);
    for m in members {
        assert_eq!(m.get("alive").and_then(|x| x.as_bool()), Some(true));
        assert_eq!(m.get("draining").and_then(|x| x.as_bool()), Some(false));
    }

    for i in 0..4 {
        generate_ok(&router.addr, &format!("fleet metrics probe {i} tell me a story"), 8);
    }
    let m = wait_metrics(&router.addr, |m| {
        m.get("fleet").and_then(|f| f.get("completed")).and_then(|x| x.as_usize()) == Some(4)
    });
    assert_eq!(m.get("role").and_then(|x| x.as_str()), Some("router"));
    let r = m.get("router").expect("router stats");
    assert_eq!(r.get("routed").and_then(|x| x.as_usize()), Some(4));
    assert_eq!(r.get("affinity_hits").and_then(|x| x.as_usize()), Some(4));
    assert_eq!(r.get("upstream_errors").and_then(|x| x.as_usize()), Some(0));
    let io = m.get("io").expect("io gauges");
    assert_eq!(io.get("mode").and_then(|x| x.as_str()), Some("router"));
    let fl = m.get("fleet").unwrap();
    assert!(fl.get("new_tokens").and_then(|x| x.as_usize()).unwrap() > 0);
    assert!(fl.get("cache").and_then(|c| c.get("lookups")).is_some());
    assert!(fl.get("pages").and_then(|p| p.get("lookups")).is_some());
    assert_eq!(m.get("replicas").and_then(|x| x.as_arr()).map(|a| a.len()), Some(2));
}

// ---------------------------------------------------------------------------
// prefix affinity vs round-robin
// ---------------------------------------------------------------------------

/// Group prompts share their first KV page (the sim tokenizer is
/// byte-level, so the first 15 bytes + BOS fill a 16-token page); the
/// group tag sits inside that window, the request index outside it.
fn group_prompt(g: usize, i: usize) -> String {
    format!("g{g} affinity shared head :: request {i} summarize the findings")
}

const GROUPS: usize = 3;
const PER_GROUP: usize = 6;

/// Drive the same grouped same-prefix traffic through a fleet; returns
/// the aggregated (cache hits, cache lookups) across its replicas.
fn run_groups(reps: &[(Arc<Engine>, HttpServer)], router_addr: &str) -> (usize, usize) {
    for g in 0..GROUPS {
        for i in 0..PER_GROUP {
            generate_ok(router_addr, &group_prompt(g, i), 8);
        }
    }
    let mut hits = 0;
    let mut lookups = 0;
    for (_, http) in reps {
        let (code, m) = http_get_json(&http.addr, "/metrics");
        assert_eq!(code, 200);
        let cache = m.get("engine").and_then(|e| e.get("cache")).expect("cache gauges");
        hits += cache.get("hits").and_then(|x| x.as_usize()).unwrap();
        lookups += cache.get("lookups").and_then(|x| x.as_usize()).unwrap();
    }
    (hits, lookups)
}

#[test]
fn same_prefix_bursts_concentrate_and_beat_round_robin_hit_rate() {
    let (aff_reps, aff_router) = fleet(2, true);
    let (aff_hits, aff_lookups) = run_groups(&aff_reps, &aff_router.addr);

    // placement really concentrated: each group's replies all completed
    // on the replica the shared RouterCore policy owns that prefix to
    let mut expect = [0u64; 2];
    for g in 0..GROUPS {
        expect[owner_of(&group_prompt(g, 0), 2)] += PER_GROUP as u64;
    }
    for (r, (eng, _)) in aff_reps.iter().enumerate() {
        let done = eng.metrics.lock().unwrap().completed;
        assert_eq!(done, expect[r], "replica {r}: affinity placement drifted");
    }

    // identical traffic, round-robin placement: prefix locality dilutes
    let (rr_reps, rr_router) = fleet(2, false);
    let (rr_hits, rr_lookups) = run_groups(&rr_reps, &rr_router.addr);
    assert_eq!(aff_lookups, rr_lookups, "both fleets saw identical traffic");
    assert!(
        aff_hits > rr_hits,
        "affinity must beat round-robin on cache hits: {aff_hits} vs {rr_hits}"
    );

    // the router's own ledger agrees about how placements were made
    let (_, am) = http_get_json(&aff_router.addr, "/metrics");
    let hits = am.get("router").and_then(|r| r.get("affinity_hits")).and_then(|x| x.as_usize());
    assert_eq!(hits, Some(GROUPS * PER_GROUP));
    let (_, rm) = http_get_json(&rr_router.addr, "/metrics");
    let hits = rm.get("router").and_then(|r| r.get("affinity_hits")).and_then(|x| x.as_usize());
    assert_eq!(hits, Some(0));
}

// ---------------------------------------------------------------------------
// replica kill mid-stream → honest terminal + failover
// ---------------------------------------------------------------------------

/// A replica stand-in whose generate streams one token event and then
/// holds the stream open until the test tears the replica down — the
/// deterministic way to catch a kill exactly mid-stream.
struct HoldingGateway;

struct HoldingSource {
    stage: usize,
}

impl EventSource for HoldingSource {
    fn poll_event(&mut self) -> Option<SourceEvent> {
        match self.stage {
            0 => {
                self.stage = 1;
                Some(SourceEvent::StreamStart)
            }
            1 => {
                self.stage = 2;
                Some(SourceEvent::Data("{\"ids\": [7], \"text\": \"e\"}".to_string()))
            }
            _ => None, // hold the stream open forever
        }
    }

    fn cancel(&mut self) {}
}

impl Gateway for HoldingGateway {
    fn route(&self, method: &str, path: &str, _body: &str) -> (u16, String) {
        match (method, path) {
            ("GET", "/health") => {
                let mut o = Json::obj();
                o.set("ok", true);
                (200, o.render())
            }
            ("GET", "/metrics") => {
                let mut sched = Json::obj();
                sched.set("queue_wait_est_cost", 0.0);
                let mut o = Json::obj();
                o.set("completed", 0usize).set("new_tokens", 0usize).set("sched", sched);
                (200, o.render())
            }
            _ => (404, "{\"error\": \"not found\"}".to_string()),
        }
    }

    fn generate(&self, _body: &str, _tenant: Option<&str>) -> GenerateStart {
        GenerateStart::Source(Box::new(HoldingSource { stage: 0 }))
    }
}

#[test]
fn replica_kill_mid_stream_synthesizes_failed_terminal_and_fails_over() {
    // replica 0: a real engine; replica 1: the holding stand-in
    let (_eng0, http0) = replica();
    let io = Arc::new(IoStats::new("reactor", 1));
    let rcfg = ReactorConfig {
        io_threads: 1,
        header_timeout: Duration::from_secs(10),
        sse_keepalive: Duration::from_secs(10),
    };
    let mut stub = Reactor::start(Arc::new(HoldingGateway), 0, rcfg, io).unwrap();
    let router = router_over(vec![http0.addr.clone(), stub.addr.clone()], true);

    // a prompt the affinity policy owns to the doomed replica (the tag
    // sits inside the first-page routing window, so we can search)
    let prompt = (0..64)
        .map(|i| format!("kill-{i:02} target head :: stream this request please"))
        .find(|p| owner_of(p, 2) == 1)
        .expect("some prompt hashes to replica 1");

    // stream through the router until the first relayed token arrives
    let mut s = open_stream(&router.addr, &prompt, 8);
    let mut raw = String::new();
    read_until(&mut s, "data: ", &mut raw);
    assert!(raw.contains("text/event-stream"), "stream must have started:\n{raw}");

    // kill the replica mid-stream: the router must answer with an honest
    // synthesized terminal, never a silent hangup or a silent retry
    stub.stop();
    read_to_close(&mut s, &mut raw);
    let (_, _, done) = stream_summary(&sse_events(&raw));
    assert_eq!(done.get("done").and_then(|x| x.as_bool()), Some(true), "raw:\n{raw}");
    assert_eq!(done.get("status").and_then(|x| x.as_str()), Some("failed"), "raw:\n{raw}");
    assert_eq!(
        done.get("error").and_then(|x| x.as_str()),
        Some("upstream replica failed mid-stream")
    );

    // new work owned by the dead replica fails over to the survivor and
    // still produces oracle-exact bytes
    generate_ok(&router.addr, &prompt, 8);
    let deadline = Instant::now() + TIMEOUT;
    while router.replica_alive(1) {
        assert!(Instant::now() < deadline, "prober never noticed the dead replica");
        std::thread::sleep(Duration::from_millis(10));
    }
    let (_, h) = http_get_json(&router.addr, "/health");
    assert_eq!(h.get("ok").and_then(|x| x.as_bool()), Some(true), "fleet stays serving");
    assert_eq!(h.get("alive").and_then(|x| x.as_usize()), Some(1));
    let (_, m) = http_get_json(&router.addr, "/metrics");
    let errs = m.get("router").and_then(|r| r.get("upstream_errors")).and_then(|x| x.as_usize());
    assert!(errs >= Some(1), "the mid-stream death must be on the ledger: {m:?}");
}

// ---------------------------------------------------------------------------
// undelivered-body re-dispatch
// ---------------------------------------------------------------------------

/// A replica stand-in that answers health/metrics probes like a healthy
/// engine but dies mid-request-body on every `/generate`: it reads just
/// the request line, then drops the socket while body bytes are still
/// in flight. The unread data turns the close into a hard TCP reset, so
/// the router's next body-chunk write fails with the request provably
/// undelivered — exactly the "owner died before the body finished"
/// shape the bounded re-dispatch path handles.
struct BodyEater {
    addr: String,
    stop: Arc<std::sync::atomic::AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl BodyEater {
    fn start() -> BodyEater {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        listener.set_nonblocking(true).unwrap();
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let stp = stop.clone();
        let handle = std::thread::spawn(move || {
            while !stp.load(Ordering::SeqCst) {
                match listener.accept() {
                    Ok((s, _)) => eater_conn(s),
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(5));
                    }
                    Err(_) => break,
                }
            }
        });
        BodyEater { addr, stop, handle: Some(handle) }
    }
}

impl Drop for BodyEater {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Serve one [`BodyEater`] connection: probes get healthy canned JSON,
/// generates get eaten mid-body (see the struct docs).
fn eater_conn(mut s: TcpStream) {
    let _ = s.set_read_timeout(Some(Duration::from_millis(200)));
    let mut raw = String::new();
    let mut buf = [0u8; 2048];
    while !raw.contains("\r\n") && raw.len() < 2048 {
        match s.read(&mut buf) {
            Ok(0) => return,
            Ok(n) => raw.push_str(&String::from_utf8_lossy(&buf[..n])),
            Err(_) => return,
        }
    }
    if raw.starts_with("POST /generate") {
        // linger long enough for more body chunks to land unread, then
        // drop: the reset fails the router's in-flight delivery
        std::thread::sleep(Duration::from_millis(30));
        return;
    }
    while !raw.contains("\r\n\r\n") {
        match s.read(&mut buf) {
            Ok(0) => break,
            Ok(n) => raw.push_str(&String::from_utf8_lossy(&buf[..n])),
            Err(_) => break,
        }
    }
    let body = if raw.starts_with("GET /metrics") {
        "{\"completed\": 0, \"new_tokens\": 0, \"sched\": {\"queue_wait_est_cost\": 0.0}}"
    } else {
        "{\"ok\": true}"
    };
    let reply = format!(
        "HTTP/1.1 200 OK\r\nContent-Type: application/json\r\nContent-Length: {}\r\n\
         Connection: close\r\n\r\n{body}",
        body.len()
    );
    let _ = s.write_all(reply.as_bytes());
}

#[test]
fn undelivered_body_redispatches_to_next_replica_within_budget() {
    let (_eng, http) = replica();
    let eater = BodyEater::start();
    let router = router_over(vec![eater.addr.clone(), http.addr.clone()], true);

    // a routing head owned by the doomed stand-in; the bulk of the body
    // rides in a padding field the replicas ignore, so it overflows
    // every socket buffer on the wire (forcing a genuinely chunked
    // upstream delivery) while the prompt stays small enough to decode
    // after the re-dispatch
    let head = (0..64)
        .map(|i| format!("redis-{i:02} eater head :: request body"))
        .find(|p| owner_of(p, 2) == 0)
        .expect("some head hashes to replica 0");
    let pad = "x".repeat(1_000_000); // ≫ socket buffering, < MAX_BODY_BYTES
    let body = format!("{{\"prompt\": \"{head}\", \"pad\": \"{pad}\", \"max_new\": 8}}");
    let (code, j) = http_post_json(&router.addr, "/generate", &body);

    // the reply is the survivor's, byte-exact — and since a truncated
    // body could never have parsed as JSON, a 200 done also proves the
    // re-dispatched body arrived complete
    assert_eq!(code, 200, "{j:?}");
    assert_eq!(j.get("status").and_then(|s| s.as_str()), Some("done"), "{j:?}");
    let want = oracle_text(&head, 8);
    assert_eq!(j.get("text").and_then(|t| t.as_str()), Some(want.as_str()));

    // exactly one bounded re-dispatch, of the partial-body kind; the
    // undelivered attempt is not an upstream *error* — the replica never
    // saw a complete request, so nothing was answered on its behalf
    let (_, m) = http_get_json(&router.addr, "/metrics");
    let r = m.get("router").expect("router stats");
    assert_eq!(r.get("routed").and_then(|x| x.as_usize()), Some(1));
    assert_eq!(r.get("failovers").and_then(|x| x.as_usize()), Some(1));
    assert_eq!(r.get("partial_redispatches").and_then(|x| x.as_usize()), Some(1));
    assert_eq!(r.get("upstream_errors").and_then(|x| x.as_usize()), Some(0));

    // the fleet keeps serving follow-up work (the eater may well be
    // probed alive again — every fresh delivery failure just re-runs
    // the same bounded re-dispatch)
    generate_ok(&router.addr, &head, 8);
}

// ---------------------------------------------------------------------------
// draining
// ---------------------------------------------------------------------------

#[test]
fn draining_rejects_new_work_routes_around_and_undrains() {
    let (reps, router) = fleet(2, true);
    let prompt = (0..64)
        .map(|i| format!("drain-{i:02} routing head :: request goes here"))
        .find(|p| owner_of(p, 2) == 0)
        .expect("some prompt hashes to replica 0");

    // drain replica 0 over the admin API; the fleet view reflects it
    let (code, d) = http_post_json(&router.addr, "/admin/drain", "{\"replica\": 0}");
    assert_eq!(code, 200, "{d:?}");
    assert_eq!(d.get("draining").and_then(|x| x.as_bool()), Some(true));
    let (_, h) = http_get_json(&router.addr, "/health");
    let members = h.get("fleet").and_then(|f| f.as_arr()).unwrap();
    assert_eq!(members[0].get("draining").and_then(|x| x.as_bool()), Some(true));

    // new work owned by the draining replica routes to its ring
    // successor — and the bytes don't change
    for i in 0..3 {
        generate_ok(&router.addr, &format!("{prompt} variant {i}"), 8);
    }
    assert_eq!(reps[0].0.metrics.lock().unwrap().completed, 0, "draining replica got new work");
    assert_eq!(reps[1].0.metrics.lock().unwrap().completed, 3);

    // draining is a router-side valve: the replica itself still serves
    // the work it already accepted (here: submitted directly)
    generate_ok(&reps[0].1.addr, &prompt, 8);
    assert_eq!(reps[0].0.metrics.lock().unwrap().completed, 1);

    // with every replica draining there is nowhere to place new work
    router.drain(1, true);
    let body = format!("{{\"prompt\": \"{prompt}\", \"max_new\": 8}}");
    let (code, j) = http_post_json(&router.addr, "/generate", &body);
    assert_eq!(code, 503, "{j:?}");
    assert_eq!(j.get("error").and_then(|x| x.as_str()), Some("no healthy replica"));

    // undrain restores the owner
    let (code, u) = http_post_json(&router.addr, "/admin/undrain", "{\"replica\": 0}");
    assert_eq!(code, 200, "{u:?}");
    generate_ok(&router.addr, &prompt, 8);
    assert_eq!(reps[0].0.metrics.lock().unwrap().completed, 2);
}

// ---------------------------------------------------------------------------
// slow-loris guard (both I/O modes)
// ---------------------------------------------------------------------------

#[test]
fn slow_loris_connections_get_408_in_both_io_modes() {
    for io_threads in [2usize, 0] {
        let eng = Arc::new(Engine::start(sim_config(1, 1)).unwrap());
        let cfg = HttpConfig { io_threads, header_timeout_ms: 150, ..HttpConfig::default() };
        let http = HttpServer::start_with(eng, 0, cfg).unwrap();

        // deliver half a request and stall: the read deadline must
        // answer 408 instead of pinning the connection forever
        let mut s = TcpStream::connect(&http.addr).unwrap();
        write!(s, "POST /generate HTTP/1.1\r\nHost: x\r\nContent-").unwrap();
        let mut raw = String::new();
        s.read_to_string(&mut raw).unwrap();
        assert!(raw.starts_with("HTTP/1.1 408 "), "io_threads={io_threads}: got:\n{raw}");
        assert!(raw.contains("request read timed out"), "io_threads={io_threads}");
        assert_eq!(http.stats.read_timeouts.load(Ordering::Relaxed), 1, "io={io_threads}");

        // a well-formed request on a fresh connection still serves
        generate_ok(&http.addr, "slow loris survivor checks the service", 8);
    }
}

// ---------------------------------------------------------------------------
// reactor vs blocking parity
// ---------------------------------------------------------------------------

#[test]
fn reactor_and_blocking_front_ends_serve_identical_replies() {
    for mode in [EngineMode::Workers, EngineMode::Continuous] {
        let mk = || {
            let mut cfg = sim_config(2, 2);
            cfg.mode = mode;
            cfg.prefix_cache = true;
            cfg.page_sharing = true;
            Arc::new(Engine::start(cfg).unwrap())
        };
        let reactor =
            HttpServer::start_with(mk(), 0, HttpConfig { io_threads: 2, ..HttpConfig::default() })
                .unwrap();
        let blocking =
            HttpServer::start_with(mk(), 0, HttpConfig { io_threads: 0, ..HttpConfig::default() })
                .unwrap();

        // framing and routing errors are timing-free: byte-identical
        let b = "{\"prompt\": \"x\", \"max_new\": 4}";
        let no_prompt = "{\"max_new\": 4}";
        let errors = [
            "GET /nope HTTP/1.1\r\nHost: x\r\n\r\n".to_string(),
            format!(
                "POST /generate HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{no_prompt}",
                no_prompt.len()
            ),
            "POST /generate HTTP/1.1\r\nHost: x\r\n\r\n".to_string(),
            "POST /generate HTTP/1.1\r\nHost: x\r\nContent-Length: banana\r\n\r\n".to_string(),
            "POST /generate HTTP/1.1\r\nHost: x\r\nContent-Length: 9999999\r\n\r\n".to_string(),
            format!(
                "POST /generate HTTP/1.1\r\nHost: x\r\nTransfer-Encoding: chunked\r\n\
                 Content-Length: {}\r\n\r\n{b}",
                b.len()
            ),
        ];
        for wire in &errors {
            let a = raw_exchange(&reactor.addr, wire);
            let bl = raw_exchange(&blocking.addr, wire);
            assert_eq!(a, bl, "mode {mode:?}: raw replies diverged for:\n{wire}");
            assert!(!a.starts_with("HTTP/1.1 200"), "these are all error requests");
        }

        // unary and streaming token output: identical across front ends
        // and byte-exact against the greedy oracle
        for (i, max_new) in [(0usize, 8usize), (1, 16)] {
            let prompt = format!("parity check {i} for mode {mode:?} front ends");
            let want = oracle_text(&prompt, max_new);
            let ja = generate_ok(&reactor.addr, &prompt, max_new);
            let jb = generate_ok(&blocking.addr, &prompt, max_new);
            assert_eq!(
                ja.get("new_tokens").and_then(|x| x.as_usize()),
                jb.get("new_tokens").and_then(|x| x.as_usize())
            );

            let mut raws = Vec::new();
            for addr in [&reactor.addr, &blocking.addr] {
                let mut s = open_stream(addr, &prompt, max_new);
                let mut raw = String::new();
                read_to_close(&mut s, &mut raw);
                assert!(raw.contains("text/event-stream"), "{raw}");
                let (ids, text, done) = stream_summary(&sse_events(&raw));
                assert_eq!(text, want, "stream text must match the oracle");
                assert_eq!(done.get("status").and_then(|x| x.as_str()), Some("done"));
                raws.push((ids, text));
            }
            assert_eq!(raws[0], raws[1], "mode {mode:?}: streams diverged across front ends");
        }
    }
}

// ---------------------------------------------------------------------------
// connection scaling + keep-alives on a fixed I/O pool
// ---------------------------------------------------------------------------

/// Threads in this process right now (`/proc/self/task`); 0 when the
/// platform has no procfs (the scaling assertion is then skipped).
fn task_count() -> usize {
    std::fs::read_dir("/proc/self/task").map(|d| d.count()).unwrap_or(0)
}

#[test]
fn reactor_holds_256_idle_sse_streams_on_a_fixed_pool_with_keepalives() {
    const STREAMS: usize = 256;
    let io = Arc::new(IoStats::new("reactor", 2));
    let rcfg = ReactorConfig {
        io_threads: 2,
        header_timeout: Duration::from_secs(30),
        sse_keepalive: Duration::from_millis(100),
    };
    let mut reactor = Reactor::start(Arc::new(HoldingGateway), 0, rcfg, io.clone()).unwrap();

    let before = task_count();
    let mut conns = Vec::with_capacity(STREAMS);
    for i in 0..STREAMS {
        let mut s = open_stream(&reactor.addr, &format!("idle stream {i}"), 8);
        // wait for the first token event so the stream is truly open
        let mut raw = String::new();
        read_until(&mut s, "data: ", &mut raw);
        conns.push((s, raw));
    }
    let after = task_count();
    if before > 0 {
        // thread-per-connection would add ~256 threads here; the
        // reactor adds none (generous slack because sibling tests in
        // this binary spawn engines concurrently)
        assert!(
            after <= before + 64,
            "I/O pool is not fixed: {before} threads before, {after} after {STREAMS} streams"
        );
    }
    assert!(io.accepted.load(Ordering::Relaxed) >= STREAMS as u64);
    assert!(io.peak_open.load(Ordering::Relaxed) >= STREAMS as u64);

    // idle long enough for at least one keep-alive interval to pass
    std::thread::sleep(Duration::from_millis(300));
    // tearing the server down ends every held stream; each client must
    // have seen its token event and at least one `: ping` comment
    reactor.stop();
    for (mut s, mut raw) in conns {
        read_to_close(&mut s, &mut raw);
        assert!(raw.contains("data: "), "stream never started:\n{raw}");
        assert!(raw.contains(": ping"), "no keep-alive observed:\n{raw}");
    }
    assert!(io.keepalives.load(Ordering::Relaxed) >= STREAMS as u64);
}
