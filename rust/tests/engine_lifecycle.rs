//! Request-lifecycle integration tests on the simulator backend
//! (docs/ARCHITECTURE.md §10) — streaming, cancellation, deadlines, and
//! admission control:
//!
//!   * streamed-token concatenation == the non-streaming reply body ==
//!     the sequential-engine / greedy-oracle output, at workers {1, 4} ×
//!     batch windows {1, 8};
//!   * mid-decode cancellation returns a partial prefix, frees the KV
//!     slot and any pending batch seat (the engine keeps serving and
//!     shuts down cleanly — no batcher deadlock), and preserves bandit
//!     play-count conservation;
//!   * an expired deadline produces an `Expired` reply instead of decode
//!     work;
//!   * a full queue sheds arrivals with `Rejected` (HTTP 429), and the
//!     HTTP layer enforces the 413 body bound, reassembles split bodies,
//!     and streams SSE events that concatenate to the unary reply.

mod common;

use std::io::{Read, Write};
use std::sync::Arc;
use std::time::Duration;

use common::{drain_stream, http_get, http_post, oracle_tokens, parse_http, MAX_NEW, TIMEOUT};
use tapout::engine::{
    BatchConfig, Engine, EngineConfig, FinishStatus, HttpServer, Policy, Request, Response,
    StreamEvent,
};
use tapout::util::Json;

fn config(workers: usize, slots: usize, batch: BatchConfig) -> EngineConfig {
    EngineConfig { verify_batch: batch, ..common::sim_config(workers, slots) }
}

#[test]
fn streamed_tokens_match_body_and_oracle_across_workers_and_windows() {
    let prompts: Vec<String> = (0..8)
        .map(|i| format!("lifecycle streaming request number {i}: describe the outcome"))
        .collect();

    for workers in [1usize, 4] {
        for window in [1usize, 8] {
            let eng = Engine::start(config(
                workers,
                workers,
                BatchConfig { max_batch: window, window_us: 200 },
            ))
            .unwrap();

            // non-streaming replies (the sequential-engine reference at
            // workers=1, and the same engine's own unary path otherwise)
            let body: Vec<Response> = prompts
                .iter()
                .map(|p| {
                    let r = eng.submit(p, MAX_NEW).recv_timeout(TIMEOUT).unwrap();
                    assert!(r.is_ok(), "{:?}", r.error);
                    r
                })
                .collect();

            // streaming replies for the same prompts
            for (i, p) in prompts.iter().enumerate() {
                let rx = eng.submit_request_streaming(Request::new(0, p.clone(), MAX_NEW));
                let (ids, text, done) = drain_stream(rx);
                assert_eq!(done.status, FinishStatus::Done);
                assert_eq!(
                    ids,
                    done.result.new_tokens(),
                    "workers {workers} window {window} req {i}: chunks != terminal body"
                );
                assert_eq!(
                    text, done.text,
                    "workers {workers} window {window} req {i}: chunk text != body text"
                );
                assert_eq!(
                    ids,
                    body[i].result.new_tokens(),
                    "workers {workers} window {window} req {i}: streamed != non-streaming"
                );
                assert_eq!(
                    ids,
                    oracle_tokens(p, MAX_NEW),
                    "workers {workers} window {window} req {i}: diverged from greedy oracle"
                );
            }
            eng.shutdown();
        }
    }
}

#[test]
fn cancelled_before_decode_is_terminal_and_releases_the_ledger() {
    let eng = Engine::start(config(1, 1, BatchConfig::off())).unwrap();
    let req = Request::new(0, "cancel me before anything happens", MAX_NEW);
    let flag = req.cancel_flag();
    flag.cancel();
    let r = eng.submit_request(req).recv_timeout(TIMEOUT).unwrap();
    assert_eq!(r.status, FinishStatus::Cancelled);
    assert!(!r.is_ok());
    assert!(r.result.new_tokens().is_empty(), "nothing decoded");

    // the engine keeps serving, and the scheduler ledger fully drained
    let ok = eng.submit("follow-up after cancellation", MAX_NEW).recv_timeout(TIMEOUT).unwrap();
    assert!(ok.is_ok());
    let j = eng.metrics_json();
    let sched = j.get("sched").unwrap();
    assert_eq!(sched.get("in_flight").unwrap().as_usize().unwrap(), 0);
    assert_eq!(sched.get("pending_cost").unwrap().as_usize().unwrap(), 0);
    let lifecycle = j.get("engine").unwrap().get("lifecycle").unwrap();
    assert_eq!(lifecycle.get("cancelled").unwrap().as_usize().unwrap(), 1);
    eng.shutdown();
}

#[test]
fn mid_decode_cancellation_frees_slot_and_conserves_bandit_counts() {
    // batcher on with a generous window: the cancelled session's pending
    // seat must be dropped, not verified, and nothing may deadlock
    let eng = Engine::start(config(2, 1, BatchConfig { max_batch: 8, window_us: 20_000 })).unwrap();
    // sim scenarios never emit EOS, so this decode would run ~3800 tokens
    // (hundreds of rounds) if nobody cancelled it
    let req = Request::new(0, "very long decode to cancel midway", 3800);
    let flag = req.cancel_flag();
    let rx = eng.submit_request_streaming(req);

    // wait for the first committed round, then cancel mid-decode
    match rx.recv_timeout(TIMEOUT).expect("first event") {
        StreamEvent::Tokens { .. } => flag.cancel(),
        StreamEvent::Done(r) => panic!("decode finished before cancellation: {:?}", r.status),
    }
    let (ids, _text, done) = drain_stream(rx);
    assert_eq!(done.status, FinishStatus::Cancelled);
    assert!(!ids.is_empty(), "tokens before the cancel were streamed");
    assert!(
        done.result.new_tokens().len() < 3800,
        "cancellation must land before the full budget"
    );
    // the partial prefix is still exact: a prefix of the greedy oracle
    let oracle = oracle_tokens("very long decode to cancel midway", 3800);
    assert_eq!(done.result.new_tokens(), &oracle[..done.result.new_tokens().len()]);

    // slot freed: with 1 KV slot, a follow-up can only complete if the
    // cancelled session released its checkout
    let ok = eng.submit("follow-up after mid-decode cancel", MAX_NEW).recv_timeout(TIMEOUT).unwrap();
    assert!(ok.is_ok(), "{:?}", ok.error);
    assert_eq!(ok.result.new_tokens(), &oracle_tokens("follow-up after mid-decode cancel", MAX_NEW)[..]);

    // bandit play-count conservation: every reward landed on exactly one
    // counted play, even though one round's verification was dropped
    let counts = eng.bandit_counts().expect("seq-ucb1 has a shared bandit");
    assert_eq!(counts.iter().sum::<u64>(), eng.bandit_updates());
    assert!(eng.bandit_sessions() >= eng.bandit_updates());
    assert!(
        eng.bandit_sessions() - eng.bandit_updates() <= 1,
        "at most the aborted round may be reward-less"
    );

    use std::sync::atomic::Ordering;
    assert_eq!(eng.stats.lifecycle.cancelled.load(Ordering::Relaxed), 1);
    // shutdown must not hang on the batcher (the dropped seat is gone)
    eng.shutdown();
}

#[test]
fn expired_deadline_yields_expired_response_and_engine_survives() {
    let eng = Engine::start(config(1, 1, BatchConfig::default())).unwrap();
    let req = Request::new(0, "this request is already too late", MAX_NEW).with_deadline_ms(0);
    let r = eng.submit_request(req).recv_timeout(TIMEOUT).unwrap();
    assert_eq!(r.status, FinishStatus::Expired);
    assert!(!r.is_ok());

    let ok = eng.submit("on time", MAX_NEW).recv_timeout(TIMEOUT).unwrap();
    assert!(ok.is_ok());
    use std::sync::atomic::Ordering;
    assert_eq!(eng.stats.lifecycle.expired.load(Ordering::Relaxed), 1);
    assert_eq!(eng.metrics.lock().unwrap().failed, 0, "expiry is not a failure");
    eng.shutdown();
}

#[test]
fn default_deadline_from_config_applies_to_plain_submits() {
    let mut cfg = config(1, 1, BatchConfig::off());
    cfg.default_deadline_ms = 1; // expires almost immediately
    let eng = Engine::start(cfg).unwrap();
    // occupy the only worker so the victim expires in the queue; the
    // occupier carries an explicit generous deadline, which suppresses
    // the server default
    let occupy = eng.submit_request_streaming(
        Request::new(0, "occupying decode", 3800).with_deadline_ms(600_000),
    );
    match occupy.recv_timeout(TIMEOUT).unwrap() {
        StreamEvent::Tokens { .. } => {}
        StreamEvent::Done(r) => panic!("occupier ended early: {:?}", r.status),
    }
    let victim = eng.submit("queued past its deadline", MAX_NEW);
    let r = victim.recv_timeout(TIMEOUT).unwrap();
    assert_eq!(r.status, FinishStatus::Expired);
    let (_ids, _text, done) = drain_stream(occupy);
    assert_eq!(done.status, FinishStatus::Done, "explicit deadline overrides the default");
    eng.shutdown();
}

#[test]
fn overload_sheds_with_rejected_status_and_wait_estimate() {
    let mut cfg = config(1, 1, BatchConfig::off());
    cfg.max_queue = 2;
    let eng = Engine::start(cfg).unwrap();

    // occupy the single worker (streaming, so we know decode started and
    // the queue is empty again)
    let occupy_req = Request::new(0, "occupy the worker for a while", 3800);
    let occupy_flag = occupy_req.cancel_flag();
    let occupy = eng.submit_request_streaming(occupy_req);
    match occupy.recv_timeout(TIMEOUT).unwrap() {
        StreamEvent::Tokens { .. } => {}
        StreamEvent::Done(r) => panic!("occupier ended early: {:?}", r.status),
    }

    // queue capacity is 2: exactly two of these five are admitted
    let rxs: Vec<_> = (0..5).map(|i| eng.submit(&format!("burst item {i}"), 16)).collect();
    let responses: Vec<Response> =
        rxs.into_iter().map(|rx| rx.recv_timeout(TIMEOUT).unwrap()).collect();
    let rejected: Vec<&Response> =
        responses.iter().filter(|r| r.status == FinishStatus::Rejected).collect();
    let done = responses.iter().filter(|r| r.is_ok()).count();
    assert_eq!(rejected.len(), 3, "queue of 2 must shed 3 of 5: {responses:?}");
    assert_eq!(done, 2);
    for r in &rejected {
        let msg = r.error.as_deref().unwrap_or("");
        assert!(msg.contains("queue full"), "shed reason must be explicit: {msg}");
        assert!(msg.contains("queue-wait estimate"), "429 carries the SJF estimate: {msg}");
    }
    use std::sync::atomic::Ordering;
    assert_eq!(eng.stats.lifecycle.rejected.load(Ordering::Relaxed), 3);

    // the occupier either finished on its own while the burst drained or
    // gets cancelled here — both release its slot for shutdown
    occupy_flag.cancel();
    let (_ids, _text, done_resp) = drain_stream(occupy);
    assert!(
        matches!(done_resp.status, FinishStatus::Done | FinishStatus::Cancelled),
        "unexpected occupier exit: {:?}",
        done_resp.status
    );
    eng.shutdown();
}

#[test]
fn dead_queue_entries_do_not_hold_admission_seats() {
    let mut cfg = config(1, 1, BatchConfig::off());
    cfg.max_queue = 1;
    let eng = Engine::start(cfg).unwrap();

    // occupy the single worker, then fill the queue of 1
    let occupy_req = Request::new(0, "occupy the worker for eviction test", 3800);
    let occupy_flag = occupy_req.cancel_flag();
    let occupy = eng.submit_request_streaming(occupy_req);
    match occupy.recv_timeout(TIMEOUT).unwrap() {
        StreamEvent::Tokens { .. } => {}
        StreamEvent::Done(r) => panic!("occupier ended early: {:?}", r.status),
    }
    let seat_holder = Request::new(0, "queued then cancelled", 16);
    let seat_flag = seat_holder.cancel_flag();
    let seat_rx = eng.submit_request(seat_holder);

    // cancel the queued request, then submit another: the dispatcher must
    // evict the dead entry and admit the newcomer instead of shedding it
    seat_flag.cancel();
    let newcomer = eng.submit("admitted after eviction", 16);

    let seat = seat_rx.recv_timeout(TIMEOUT).unwrap();
    assert_eq!(seat.status, FinishStatus::Cancelled, "{:?}", seat.error);
    let r = newcomer.recv_timeout(TIMEOUT).unwrap();
    assert!(r.is_ok(), "evicting the dead entry must admit the newcomer: {:?}", r.error);

    occupy_flag.cancel();
    let (_ids, _text, done) = drain_stream(occupy);
    assert!(matches!(done.status, FinishStatus::Done | FinishStatus::Cancelled));
    eng.shutdown();
}

#[test]
fn long_job_is_not_starved_under_short_job_flood() {
    // Pure SJF starves a long request forever under sustained short-job
    // load: every newcomer outbids it. The aged ordering key
    // (docs/ARCHITECTURE.md §5) guarantees the long job pops within
    // ~cost/SJF_AGING_PER_ARRIVAL further arrivals. Simulate sustained
    // load: one short job arrives for every job served, indefinitely.
    use tapout::engine::Scheduler;
    let mut s = Scheduler::new(Policy::Sjf);
    let mut long = Request::new(1, "x".repeat(500), 500); // cost 1000
    long.category = "qa".into();
    let long_cost = long.cost();
    s.push(long);
    let mut popped_long_at = None;
    for i in 0..4 * (long_cost / 16) {
        let mut short = Request::new(100 + i as u64, "y".repeat(10), 10); // cost 20
        short.category = "qa".into();
        s.push(short);
        let r = s.pop().expect("queue never empty under sustained load");
        s.note_done(r.cost());
        if r.id == 1 {
            popped_long_at = Some(i);
            break;
        }
    }
    let at = popped_long_at.expect("the long job must not starve under a short-job flood");
    assert!(
        at <= long_cost / 16 + 2,
        "aging must promote the long job within ~cost/AGING arrivals, popped at {at}"
    );
    assert!(at > 2, "near-contemporaneous short jobs still win (SJF preserved), popped at {at}");
}

// ---------------------------------------------------------------- HTTP --

#[test]
fn http_header_matching_is_case_insensitive_and_missing_length_is_411() {
    let eng = Arc::new(Engine::start(config(1, 1, BatchConfig::default())).unwrap());
    let http = HttpServer::start(eng.clone(), 0).unwrap();
    let addr = http.addr.clone();
    let body = r#"{"prompt": "header case request", "max_new": 8}"#;

    // RFC 9110 §5.1: header field names are case-insensitive — a
    // lowercase client must decode exactly like a canonical-case one
    let reference = {
        let mut s = std::net::TcpStream::connect(&addr).unwrap();
        write!(
            s,
            "POST /generate HTTP/1.1\r\nhost: x\r\ncontent-length: {}\r\n\r\n{body}",
            body.len()
        )
        .unwrap();
        let mut buf = String::new();
        s.read_to_string(&mut buf).unwrap();
        let (code, reply) = parse_http(&buf);
        assert_eq!(code, 200, "lowercase content-length must be honored: {reply}");
        let j = Json::parse(&reply).unwrap();
        j.get("text").unwrap().as_str().unwrap().to_string()
    };

    // mixed-case client (seen from proxies and hand-rolled clients)
    {
        let mut s = std::net::TcpStream::connect(&addr).unwrap();
        write!(
            s,
            "POST /generate HTTP/1.1\r\nHost: x\r\nCoNtEnT-LeNgTh: {}\r\n\r\n{body}",
            body.len()
        )
        .unwrap();
        let mut buf = String::new();
        s.read_to_string(&mut buf).unwrap();
        let (code, reply) = parse_http(&buf);
        assert_eq!(code, 200, "mixed-case content-length must be honored: {reply}");
        let j = Json::parse(&reply).unwrap();
        assert_eq!(
            j.get("text").unwrap().as_str().unwrap(),
            reference,
            "header casing must not change the decode"
        );
    }

    // a POST with no content-length at all is 411 Length Required — not
    // a misleading "bad json" 400 over an empty body
    {
        let mut s = std::net::TcpStream::connect(&addr).unwrap();
        write!(s, "POST /generate HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        s.shutdown(std::net::Shutdown::Write).unwrap();
        let mut buf = String::new();
        s.read_to_string(&mut buf).unwrap();
        let (code, reply) = parse_http(&buf);
        assert_eq!(code, 411, "{reply}");
        assert!(reply.contains("content-length"), "{reply}");
    }

    // a present-but-malformed content-length is a 400 framing error —
    // not the 411 "missing header" diagnostic (the client did send it)
    {
        let mut s = std::net::TcpStream::connect(&addr).unwrap();
        write!(s, "POST /generate HTTP/1.1\r\nHost: x\r\nContent-Length: 12abc\r\n\r\n").unwrap();
        s.shutdown(std::net::Shutdown::Write).unwrap();
        let mut buf = String::new();
        s.read_to_string(&mut buf).unwrap();
        let (code, reply) = parse_http(&buf);
        assert_eq!(code, 400, "{reply}");
        assert!(reply.contains("invalid content-length"), "{reply}");
    }

    // a chunked body (any Transfer-Encoding casing) is an explicit 501,
    // never parsed as if it were content-length framed
    {
        let mut s = std::net::TcpStream::connect(&addr).unwrap();
        write!(
            s,
            "POST /generate HTTP/1.1\r\nHost: x\r\nTrAnSfEr-EnCoDiNg: Chunked\r\n\r\n\
             5\r\nhello\r\n0\r\n\r\n"
        )
        .unwrap();
        let mut buf = String::new();
        s.read_to_string(&mut buf).unwrap();
        let (code, reply) = parse_http(&buf);
        assert_eq!(code, 501, "{reply}");
        assert!(reply.contains("chunked"), "{reply}");
    }

    // GET routes carry no body and must stay unaffected by the 411 rule
    let (code, reply) = http_get(&addr, "/health");
    assert_eq!(code, 200, "{reply}");
}

#[test]
fn http_streaming_split_bodies_and_413() {
    let eng = Arc::new(Engine::start(config(2, 2, BatchConfig::default())).unwrap());
    let http = HttpServer::start(eng.clone(), 0).unwrap();
    let addr = http.addr.clone();

    // 1) oversize body: declared length alone triggers the 413 — the
    // server must not wait for (or truncate) a megabyte of JSON
    {
        let mut s = std::net::TcpStream::connect(&addr).unwrap();
        write!(s, "POST /generate HTTP/1.1\r\nHost: x\r\nContent-Length: 2000000\r\n\r\n")
            .unwrap();
        let mut buf = String::new();
        s.read_to_string(&mut buf).unwrap();
        let (code, body) = parse_http(&buf);
        assert_eq!(code, 413, "{body}");
        assert!(body.contains("body too large"), "{body}");
    }

    // 2) body split across two TCP writes reassembles (no truncated-JSON
    // decode error)
    let unary_text = {
        let body = r#"{"prompt": "split body request", "max_new": 24}"#;
        let (a, b) = body.split_at(17);
        let mut s = std::net::TcpStream::connect(&addr).unwrap();
        write!(s, "POST /generate HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n", body.len())
            .unwrap();
        s.write_all(a.as_bytes()).unwrap();
        s.flush().unwrap();
        std::thread::sleep(Duration::from_millis(50));
        s.write_all(b.as_bytes()).unwrap();
        s.flush().unwrap();
        let mut buf = String::new();
        s.read_to_string(&mut buf).unwrap();
        let (code, body) = parse_http(&buf);
        assert_eq!(code, 200, "{body}");
        let j = Json::parse(&body).unwrap();
        assert_eq!(j.get("status").unwrap().as_str().unwrap(), "done");
        j.get("text").unwrap().as_str().unwrap().to_string()
    };

    // 3) a declared body that never fully arrives is a 400, not a hang
    {
        let mut s = std::net::TcpStream::connect(&addr).unwrap();
        write!(s, "POST /generate HTTP/1.1\r\nHost: x\r\nContent-Length: 50\r\n\r\nshort")
            .unwrap();
        s.shutdown(std::net::Shutdown::Write).unwrap();
        let mut buf = String::new();
        s.read_to_string(&mut buf).unwrap();
        let (code, body) = parse_http(&buf);
        assert_eq!(code, 400, "{body}");
        assert!(body.contains("content-length"), "{body}");
    }

    // 4) SSE streaming: data events concatenate to the unary reply text
    {
        let body = r#"{"prompt": "split body request", "max_new": 24, "stream": true}"#;
        let (code, raw) = http_post(&addr, "/generate", body);
        assert_eq!(code, 200);
        let mut text = String::new();
        let mut saw_done = false;
        for line in raw.lines() {
            let Some(payload) = line.strip_prefix("data: ") else { continue };
            let j = Json::parse(payload).unwrap_or(Json::Null);
            if j.get("done").and_then(|x| x.as_bool()).unwrap_or(false) {
                saw_done = true;
                assert_eq!(j.get("status").unwrap().as_str().unwrap(), "done");
                assert_eq!(
                    j.get("new_tokens").unwrap().as_usize().unwrap(),
                    unary_text.chars().count(),
                    "terminal event token count"
                );
            } else if let Some(t) = j.get("text").and_then(|x| x.as_str()) {
                text.push_str(t);
            }
        }
        assert!(saw_done, "stream must end with a done event:\n{raw}");
        assert_eq!(text, unary_text, "streamed chunks != unary body");
    }

    // 5) /metrics exposes the lifecycle counters
    let (code, metrics) = http_get(&addr, "/metrics");
    assert_eq!(code, 200);
    let j = Json::parse(&metrics).unwrap();
    assert!(j.path(&["engine", "lifecycle", "rejected"]).is_some());
    assert!(j.get("ttft_p95_ms").is_some());
    assert!(j.get("tpot_p99_ms").is_some());
}

#[test]
fn http_sheds_with_429_when_queue_is_full() {
    let mut cfg = config(1, 1, BatchConfig::off());
    cfg.max_queue = 1;
    let eng = Arc::new(Engine::start(cfg).unwrap());
    let http = HttpServer::start(eng.clone(), 0).unwrap();

    // occupy the worker, then fill the queue of 1
    let occupy_req = Request::new(0, "occupy the worker", 3800);
    let occupy_flag = occupy_req.cancel_flag();
    let occupy = eng.submit_request_streaming(occupy_req);
    match occupy.recv_timeout(TIMEOUT).unwrap() {
        StreamEvent::Tokens { .. } => {}
        StreamEvent::Done(r) => panic!("occupier ended early: {:?}", r.status),
    }
    let queued = eng.submit("sits in the queue", 16);

    let (code, body) =
        http_post(&http.addr, "/generate", r#"{"prompt": "one too many", "max_new": 8}"#);
    assert_eq!(code, 429, "{body}");
    let j = Json::parse(&body).unwrap();
    assert_eq!(j.get("status").unwrap().as_str().unwrap(), "rejected");
    assert!(j.get("error").unwrap().as_str().unwrap().contains("queue-wait estimate"));

    // a *streaming* request shed before any tokens also gets the plain
    // 429 (the status line is held until the first engine event)
    let (code, body) = http_post(
        &http.addr,
        "/generate",
        r#"{"prompt": "one too many, streamed", "max_new": 8, "stream": true}"#,
    );
    assert_eq!(code, 429, "{body}");
    assert!(body.contains("rejected"), "{body}");

    occupy_flag.cancel();
    let (_ids, _t, done) = drain_stream(occupy);
    assert!(
        matches!(done.status, FinishStatus::Done | FinishStatus::Cancelled),
        "unexpected occupier exit: {:?}",
        done.status
    );
    assert!(queued.recv_timeout(TIMEOUT).unwrap().is_ok());
    // the Arc-held engine is leaked at test exit, as in engine_serving.rs
}

#[test]
fn http_tenant_header_and_body_field_reach_the_drafter_ledger() {
    // the tenant travels two ways over the wire (docs/OPERATIONS.md):
    // a "tenant" JSON field or an X-Tapout-Tenant header, body winning
    // when both are present; absent (or empty) both, the request decodes
    // under the global ("") tenant. Asserted end to end over raw TCP
    // against the engine's drafter-layer ledger.
    let mut cfg = config(1, 1, BatchConfig::default());
    cfg.drafters = 2;
    let eng = Arc::new(Engine::start(cfg).unwrap());
    let http = HttpServer::start(eng.clone(), 0).unwrap();
    let addr = http.addr.clone();

    let post = |headers: &str, body: &str| -> (u16, String) {
        let mut s = std::net::TcpStream::connect(&addr).unwrap();
        write!(
            s,
            "POST /generate HTTP/1.1\r\nHost: x\r\n{headers}Content-Length: {}\r\n\r\n{body}",
            body.len()
        )
        .unwrap();
        let mut buf = String::new();
        s.read_to_string(&mut buf).unwrap();
        parse_http(&buf)
    };
    let tenants_seen = || -> Vec<String> {
        eng.drafters().tenant_snapshot().into_iter().map(|t| t.tenant).collect()
    };

    // header only (mixed casing: header names are case-insensitive)
    let (code, reply) = post(
        "x-TaPoUt-tEnAnT: alpha\r\n",
        r#"{"prompt": "tenant via header", "max_new": 6}"#,
    );
    assert_eq!(code, 200, "{reply}");
    assert_eq!(tenants_seen(), vec!["alpha"], "header tenant must reach the ledger");

    // body and header both present: the body field wins
    let (code, reply) = post(
        "X-Tapout-Tenant: beta\r\n",
        r#"{"prompt": "tenant via body", "max_new": 6, "tenant": "gamma"}"#,
    );
    assert_eq!(code, 200, "{reply}");
    let seen = tenants_seen();
    assert!(seen.contains(&"gamma".to_string()), "body tenant must win: {seen:?}");
    assert!(!seen.contains(&"beta".to_string()), "losing header tenant leaked: {seen:?}");

    // neither: the global ("") tenant
    let (code, reply) = post("", r#"{"prompt": "tenant absent", "max_new": 6}"#);
    assert_eq!(code, 200, "{reply}");
    assert!(
        tenants_seen().contains(&String::new()),
        "untenanted traffic lands in the global tenant"
    );

    // an empty-string body tenant is the global tenant too — it must not
    // fall back to the header (the client explicitly said "no tenant")
    let (code, reply) = post(
        "X-Tapout-Tenant: delta\r\n",
        r#"{"prompt": "tenant explicitly empty", "max_new": 6, "tenant": ""}"#,
    );
    assert_eq!(code, 200, "{reply}");
    let seen = tenants_seen();
    assert!(!seen.contains(&"delta".to_string()), "empty body tenant must suppress the header: {seen:?}");

    // the ledger stayed conserved through every variant
    let d = eng.drafters();
    assert_eq!(d.sessions(), d.updates());
    assert_eq!(d.tenant_plays_total(), d.updates());
    // the Arc-held engine is leaked at test exit, as in engine_serving.rs
}
