//! Continuous-batching integration tests on the simulator backend
//! (docs/ARCHITECTURE.md §11) — these run everywhere and pin the step
//! loop's contract:
//!
//!   * a 24-request *staggered-arrival* burst through the continuous
//!     engine is byte-identical to the sequential (1-worker Workers
//!     mode) engine and to the target-only greedy oracle at slots
//!     {1, 4, 8} — admissions landing mid-flight must not perturb any
//!     session already decoding;
//!   * a mid-decode cancellation in continuous mode frees its KV slot
//!     within one iteration (a follow-up on a 1-slot engine completes)
//!     and the partial prefix is exact;
//!   * shared-bandit play-count conservation holds across execution
//!     modes: one select + one update per round in both engines;
//!   * the `engine.step` and `engine.draft` gauges observe the batching
//!     that happened (draft occupancy > 1 at slots ≥ 4 under load);
//!   * a long prompt streams through prefill in page-aligned chunks
//!     (docs/ARCHITECTURE.md §13) without stalling a concurrent short
//!     request, and its output stays byte-identical to the oracle.

mod common;

use std::time::Duration;

use common::{collect, oracle_tokens, MAX_NEW, TIMEOUT};
use tapout::engine::{Engine, EngineConfig, EngineMode, FinishStatus, Request, StreamEvent};
use tapout::models::sim_encode;

fn config(mode: EngineMode, workers: usize, slots: usize) -> EngineConfig {
    EngineConfig { mode, ..common::sim_config(workers, slots) }
}

fn burst_prompts(n: usize) -> Vec<String> {
    common::burst_prompts(n, "continuous batching")
}

#[test]
fn staggered_burst_matches_sequential_engine_and_oracle_across_slot_counts() {
    let prompts = burst_prompts(24);

    // reference: the sequential Workers engine (1 worker, 1 slot)
    let seq = Engine::start(config(EngineMode::Workers, 1, 1)).unwrap();
    let seq_out: Vec<Vec<u32>> = prompts
        .iter()
        .map(|p| {
            let r = seq.submit(p, MAX_NEW).recv_timeout(TIMEOUT).unwrap();
            assert!(r.is_ok(), "{:?}", r.error);
            r.result.new_tokens().to_vec()
        })
        .collect();
    seq.shutdown();

    for slots in [1usize, 4, 8] {
        let eng = Engine::start(config(EngineMode::Continuous, 0, slots)).unwrap();
        // staggered arrivals: three waves, so later admissions land while
        // earlier sessions are mid-decode (iteration-level admission)
        let mut rxs = Vec::new();
        for wave in prompts.chunks(8) {
            for p in wave {
                rxs.push(eng.submit(p, MAX_NEW));
            }
            std::thread::sleep(Duration::from_millis(3));
        }
        let responses = collect(rxs);

        let mut total_rounds = 0u64;
        for (i, r) in responses.iter().enumerate() {
            assert!(r.is_ok(), "slots {slots} request {i} failed: {:?}", r.error);
            assert_eq!(
                r.result.new_tokens(),
                &seq_out[i][..],
                "slots {slots} request {i}: continuous output diverged from sequential engine"
            );
            assert_eq!(
                r.result.new_tokens(),
                &oracle_tokens(&prompts[i], MAX_NEW)[..],
                "slots {slots} request {i}: output diverged from the greedy oracle"
            );
            total_rounds += r.result.rounds.len() as u64;
        }

        // play-count conservation in continuous mode: one select and one
        // update per round, every round's reward landed exactly once
        assert_eq!(eng.bandit_sessions(), total_rounds, "slots {slots}");
        assert_eq!(eng.bandit_updates(), total_rounds, "slots {slots}");
        let counts = eng.bandit_counts().expect("seq-ucb1 has a shared bandit");
        assert_eq!(counts.iter().sum::<u64>(), total_rounds, "slots {slots}: {counts:?}");

        // the step loop observed its own execution
        use std::sync::atomic::Ordering;
        let steps = eng.stats.step.steps.load(Ordering::Relaxed);
        assert!(steps > 0, "slots {slots}: iterations must be counted");
        assert_eq!(
            eng.stats.step.admitted.load(Ordering::Relaxed),
            24,
            "slots {slots}: every request admitted through the stepper"
        );
        assert_eq!(eng.stats.step.retired.load(Ordering::Relaxed), 24, "slots {slots}");
        assert!(
            eng.stats.step.peak_inflight.load(Ordering::Relaxed) <= slots,
            "slots {slots}: in-flight can never exceed the slot count"
        );
        // draft forwards were dispatched and accounted
        let fw = eng.stats.draft.forwards.load(Ordering::Relaxed);
        assert!(fw > 0, "slots {slots}");
        assert!(
            eng.stats.draft.padded_rows.load(Ordering::Relaxed)
                >= eng.stats.draft.rows.load(Ordering::Relaxed),
            "slots {slots}: padding can only add rows"
        );
        if slots >= 4 {
            assert!(
                eng.stats.draft.mean_occupancy() > 1.0,
                "slots {slots}: drafting must coalesce across sessions under load"
            );
        }
        eng.shutdown();
    }
}

#[test]
fn mid_decode_cancel_frees_slot_within_one_iteration() {
    // 1 KV slot: the follow-up can only complete if the cancelled
    // session released its slot at the next iteration boundary
    let eng = Engine::start(config(EngineMode::Continuous, 0, 1)).unwrap();
    // sim scenarios never emit EOS, so this decode would run ~3800 tokens
    let req = Request::new(0, "continuous decode to cancel midway", 3800);
    let flag = req.cancel_flag();
    let rx = eng.submit_request_streaming(req);

    match rx.recv_timeout(TIMEOUT).expect("first event") {
        StreamEvent::Tokens { .. } => flag.cancel(),
        StreamEvent::Done(r) => panic!("decode finished before cancellation: {:?}", r.status),
    }
    let (ids, done) = {
        let mut ids = Vec::new();
        loop {
            match rx.recv_timeout(TIMEOUT).expect("stream must terminate") {
                StreamEvent::Tokens { ids: i, .. } => ids.extend(i),
                StreamEvent::Done(resp) => break (ids, *resp),
            }
        }
    };
    assert_eq!(done.status, FinishStatus::Cancelled);
    assert!(!ids.is_empty(), "tokens before the cancel were streamed");
    assert!(done.result.new_tokens().len() < 3800, "cancel landed before the budget");
    // the partial prefix is still exact: a prefix of the greedy oracle
    let oracle = oracle_tokens("continuous decode to cancel midway", 3800);
    assert_eq!(done.result.new_tokens(), &oracle[..done.result.new_tokens().len()]);

    let ok = eng
        .submit("follow-up after continuous cancel", MAX_NEW)
        .recv_timeout(TIMEOUT)
        .unwrap();
    assert!(ok.is_ok(), "{:?}", ok.error);
    assert_eq!(
        ok.result.new_tokens(),
        &oracle_tokens("follow-up after continuous cancel", MAX_NEW)[..]
    );

    use std::sync::atomic::Ordering;
    assert_eq!(eng.stats.lifecycle.cancelled.load(Ordering::Relaxed), 1);
    // conservation with at most the aborted round reward-less
    let counts = eng.bandit_counts().expect("seq-ucb1 has a shared bandit");
    assert_eq!(counts.iter().sum::<u64>(), eng.bandit_updates());
    assert!(eng.bandit_sessions() - eng.bandit_updates() <= 1);
    eng.shutdown();
}

#[test]
fn play_count_conservation_matches_across_modes() {
    // the same burst through both execution models: each must conserve
    // plays (Σ arm counts == updates == sessions == Σ rounds) — the
    // re-sequenced continuous rounds change *when* rewards land, never
    // whether they land
    let prompts = burst_prompts(12);
    let mut per_mode_rounds = Vec::new();
    for mode in [EngineMode::Workers, EngineMode::Continuous] {
        let eng = Engine::start(config(mode, 4, 4)).unwrap();
        let rxs: Vec<_> = prompts.iter().map(|p| eng.submit(p, MAX_NEW)).collect();
        let responses = collect(rxs);
        let rounds: u64 = responses
            .iter()
            .map(|r| {
                assert!(r.is_ok(), "{:?}", r.error);
                r.result.rounds.len() as u64
            })
            .sum();
        assert_eq!(eng.bandit_sessions(), rounds, "{mode:?}");
        assert_eq!(eng.bandit_updates(), rounds, "{mode:?}");
        let counts = eng.bandit_counts().expect("shared bandit");
        assert_eq!(counts.iter().sum::<u64>(), rounds, "{mode:?}: {counts:?}");
        per_mode_rounds.push((mode, responses));
        eng.shutdown();
    }
    // outputs also agree between the two modes (lossless decoding)
    let (_, workers_out) = &per_mode_rounds[0];
    let (_, continuous_out) = &per_mode_rounds[1];
    for (i, (w, c)) in workers_out.iter().zip(continuous_out).enumerate() {
        assert_eq!(
            w.result.new_tokens(),
            c.result.new_tokens(),
            "request {i}: Workers and Continuous outputs diverged"
        );
    }
}

#[test]
fn long_prompt_streams_prefill_in_chunks_and_stays_byte_identical() {
    // a ~640-token prompt exceeds the chunked-prefill threshold
    // (PREFILL_CHUNK_PAGES × page_size = 128 tokens of catch-up), so its
    // prefill is spread over several iterations of the step loop instead
    // of one monolithic forward — the short request admitted alongside
    // it keeps decoding in those iterations, and both outputs must match
    // the oracle byte-for-byte (discarded prefill rows only populate KV)
    let long = format!(
        "{} now summarize the whole document in one line",
        "a long background document sentence with filler. ".repeat(12)
    );
    assert!(sim_encode(&long).len() > 512, "prompt must exceed several chunks");
    let short = "short concurrent request while the long one prefills";

    let eng = Engine::start(config(EngineMode::Continuous, 0, 2)).unwrap();
    let rx_long = eng.submit(&long, MAX_NEW);
    let rx_short = eng.submit(short, MAX_NEW);
    let rl = rx_long.recv_timeout(TIMEOUT).unwrap();
    let rs = rx_short.recv_timeout(TIMEOUT).unwrap();
    assert!(rl.is_ok(), "{:?}", rl.error);
    assert!(rs.is_ok(), "{:?}", rs.error);
    assert_eq!(
        rl.result.new_tokens(),
        &oracle_tokens(&long, MAX_NEW)[..],
        "chunked prefill changed the long request's output"
    );
    assert_eq!(
        rs.result.new_tokens(),
        &oracle_tokens(short, MAX_NEW)[..],
        "a concurrent chunked prefill perturbed the short request"
    );

    // chunk iterations are not speculative rounds: play-count
    // conservation still holds (no select/reward during prefill)
    let rounds = rl.result.rounds.len() as u64 + rs.result.rounds.len() as u64;
    assert_eq!(eng.bandit_sessions(), rounds);
    assert_eq!(eng.bandit_updates(), rounds);
    eng.shutdown();
}

#[test]
fn continuous_failure_is_an_error_response_and_engine_survives() {
    let eng = Engine::start(config(EngineMode::Continuous, 0, 2)).unwrap();
    // the sim KV cache holds 4096 positions; this prompt cannot fit
    let oversized = "z".repeat(5000);
    let r = eng
        .submit(&oversized, 8)
        .recv_timeout(TIMEOUT)
        .expect("failed request must still be answered");
    assert!(!r.is_ok());
    assert!(
        r.error.as_deref().unwrap_or("").contains("prompt too long"),
        "error should explain the failure: {:?}",
        r.error
    );
    let ok = eng.submit("follow-up after failure", MAX_NEW).recv_timeout(TIMEOUT).unwrap();
    assert!(ok.is_ok(), "{:?}", ok.error);
    eng.shutdown();
}

#[test]
fn metrics_json_reports_step_gauges_in_continuous_mode() {
    let eng = Engine::start(config(EngineMode::Continuous, 0, 4)).unwrap();
    collect(burst_prompts(8).iter().map(|p| eng.submit(p, MAX_NEW)).collect());
    let j = eng.metrics_json();
    let engine = j.get("engine").expect("engine object");
    let step = engine.get("step").expect("step gauges present in continuous mode");
    assert!(step.get("steps").unwrap().as_usize().unwrap() > 0);
    assert_eq!(step.get("admitted").unwrap().as_usize().unwrap(), 8);
    assert!(step.get("admissions_per_step").unwrap().as_f64().unwrap() > 0.0);
    assert!(step.get("in_flight_hist").is_some());
    assert!(step.get("draft_occupancy").unwrap().as_f64().unwrap() >= 1.0);
    let draft = engine.get("draft").expect("draft gauges");
    assert!(draft.get("forwards").unwrap().as_usize().unwrap() > 0);
    // verification went through the window-free batched path
    let batch = engine.get("batch").expect("batch gauges");
    assert!(batch.get("batches").unwrap().as_usize().unwrap() > 0);
    let sched = j.get("sched").expect("sched ledger");
    assert_eq!(sched.get("in_flight").unwrap().as_usize().unwrap(), 0, "burst fully drained");
    eng.shutdown();
}
