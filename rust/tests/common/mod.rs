//! Shared scaffolding for the `engine_*` integration suites: the
//! sim-backend config base, the greedy oracle, burst/collect/stream
//! helpers, and a minimal raw-TCP HTTP client (docs/TESTING.md).
//!
//! Every suite uses a subset, so the helpers carry `#[allow(dead_code)]`
//! — each integration-test binary compiles this module independently.

use std::io::{Read, Write};
use std::time::Duration;

use tapout::engine::{
    BackendKind, EngineConfig, Policy, Request, Response, StreamEvent,
};
use tapout::models::{sim_encode, Scenario, SimModel};
use tapout::spec::{greedy, GenConfig, BOS};
use tapout::util::Json;

/// Default decode budget the suites share.
#[allow(dead_code)]
pub const MAX_NEW: usize = 48;

/// Generous wall-clock bound for any single reply (CI machines vary).
#[allow(dead_code)]
pub const TIMEOUT: Duration = Duration::from_secs(120);

/// The common simulator-backend engine config: suites override mode,
/// batching, cache and paging knobs on the returned value.
#[allow(dead_code)]
pub fn sim_config(workers: usize, slots: usize) -> EngineConfig {
    EngineConfig {
        method: "seq-ucb1".into(),
        gamma_max: 64,
        sched: Policy::Fcfs,
        slots,
        workers,
        backend: BackendKind::sim_default(),
        ..EngineConfig::default()
    }
}

/// `n` distinct prompts labeled per suite (distinct text ⇒ distinct sim
/// scenarios, so cross-suite replies never collide by accident).
#[allow(dead_code)]
pub fn burst_prompts(n: usize, label: &str) -> Vec<String> {
    (0..n).map(|i| format!("{label} request number {i}: summarize the findings")).collect()
}

/// The target-only greedy continuation the engine must reproduce for a
/// text submission — the scenario seed is a pure function of the prompt,
/// exactly as the engine derives it internally.
#[allow(dead_code)]
pub fn oracle_tokens(text: &str, max_new: usize) -> Vec<u32> {
    let mut prompt = vec![BOS];
    prompt.extend(sim_encode(text));
    let mut req = Request::new(0, text, max_new);
    req.prompt = prompt.clone();
    let mut target = SimModel::target(Scenario::new(req.scenario_seed(), &req.category));
    let cfg = GenConfig { max_new, stop_at_eos: true, ..GenConfig::default() };
    let r = greedy(&mut target, &prompt, &cfg).unwrap();
    r.new_tokens().to_vec()
}

/// Await every response of a burst, in submission order.
#[allow(dead_code)]
pub fn collect(rxs: Vec<std::sync::mpsc::Receiver<Response>>) -> Vec<Response> {
    rxs.into_iter()
        .map(|rx| rx.recv_timeout(TIMEOUT).expect("response must arrive"))
        .collect()
}

/// Drain one streaming reply: (concatenated ids, concatenated text,
/// terminal response).
#[allow(dead_code)]
pub fn drain_stream(rx: std::sync::mpsc::Receiver<StreamEvent>) -> (Vec<u32>, String, Response) {
    let mut ids = Vec::new();
    let mut text = String::new();
    loop {
        match rx.recv_timeout(TIMEOUT).expect("stream must terminate") {
            StreamEvent::Tokens { ids: i, text: t, .. } => {
                ids.extend(i);
                text.push_str(&t);
            }
            StreamEvent::Done(resp) => return (ids, text, *resp),
        }
    }
}

/// Raw-TCP GET against a test server (always bound to port 0); returns
/// (status code, raw body).
#[allow(dead_code)]
pub fn http_get(addr: &str, path: &str) -> (u16, String) {
    let mut s = std::net::TcpStream::connect(addr).unwrap();
    write!(s, "GET {path} HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
    let mut buf = String::new();
    s.read_to_string(&mut buf).unwrap();
    parse_http(&buf)
}

/// Raw-TCP POST with a content-length framed body; returns (status code,
/// raw body).
#[allow(dead_code)]
pub fn http_post(addr: &str, path: &str, body: &str) -> (u16, String) {
    let mut s = std::net::TcpStream::connect(addr).unwrap();
    write!(
        s,
        "POST {path} HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )
    .unwrap();
    let mut buf = String::new();
    s.read_to_string(&mut buf).unwrap();
    parse_http(&buf)
}

/// Split a raw HTTP/1.1 response into (status code, body text).
#[allow(dead_code)]
pub fn parse_http(raw: &str) -> (u16, String) {
    let code: u16 = raw.split_whitespace().nth(1).and_then(|c| c.parse().ok()).unwrap_or(0);
    let body = raw.split("\r\n\r\n").skip(1).collect::<Vec<_>>().join("\r\n\r\n");
    (code, body)
}

/// Like [`http_get`], with the body parsed as JSON (`Json::Null` when
/// unparseable — asserting on a field then fails with context).
#[allow(dead_code)]
pub fn http_get_json(addr: &str, path: &str) -> (u16, Json) {
    let (code, body) = http_get(addr, path);
    (code, Json::parse(&body).unwrap_or(Json::Null))
}

/// Like [`http_post`], with the body parsed as JSON.
#[allow(dead_code)]
pub fn http_post_json(addr: &str, path: &str, body: &str) -> (u16, Json) {
    let (code, reply) = http_post(addr, path, body);
    (code, Json::parse(&reply).unwrap_or(Json::Null))
}
