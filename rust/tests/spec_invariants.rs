//! Integration + property tests over the speculative-decoding core using
//! the simulator backend (fast, deterministic, millions of tokens).
//!
//! The central invariant: **greedy speculative decoding is lossless** —
//! whatever the stop controller does, the committed output must equal the
//! target-only greedy continuation. Every method is run through that check
//! under randomized scenarios (mini-proptest, util::prop).

use tapout::engine::{PagePool, PrefixIndex};
use tapout::models::sim::{Scenario, SimModel};
use tapout::models::LanguageModel;
use tapout::signals::TokenSignals;
use tapout::spec::{
    accept_greedy, finish_check, generate, greedy, FinishReason, GenConfig, MethodSpec,
    SpecSession, StepOutcome, StopController, EOS,
};
use tapout::util::prop::forall;
use tapout::util::Rng;

fn sim_models(seed: u64, cat: &str, quality: f32) -> (SimModel, SimModel) {
    let sc = Scenario::new(seed, cat);
    (SimModel::draft(sc, quality, 0.05), SimModel::target(sc))
}

fn prompt(len: usize) -> Vec<u32> {
    (0..len).map(|i| 3 + (i % 29) as u32).collect()
}

fn run(
    seed: u64,
    cat: &str,
    quality: f32,
    method: &str,
    max_new: usize,
) -> (Vec<u32>, Vec<(usize, usize)>) {
    let (mut draft, mut target) = sim_models(seed, cat, quality);
    let mut ctrl = MethodSpec::parse(method, "artifacts").unwrap().build(64).unwrap();
    let mut rng = Rng::new(seed);
    let cfg = GenConfig { max_new, gamma_max: 64, stop_at_eos: false, collect_signals: false };
    let r = generate(&mut draft, &mut target, &mut ctrl, &mut rng, &prompt(16), &cfg).unwrap();
    let rounds = r.rounds.iter().map(|x| (x.drafted, x.accepted)).collect();
    (r.tokens, rounds)
}

fn oracle(seed: u64, cat: &str, max_new: usize) -> Vec<u32> {
    let sc = Scenario::new(seed, cat);
    let mut target = SimModel::target(sc);
    let cfg = GenConfig { max_new, gamma_max: 64, stop_at_eos: false, collect_signals: false };
    greedy(&mut target, &prompt(16), &cfg).unwrap().tokens
}

const METHODS: &[&str] = &[
    "static-1", "static-6", "static-17", "ada-edl", "svip", "max-conf",
    "logit-margin", "svip-diff", "seq-ucb1", "seq-ucb-tuned", "seq-ts",
    "token-ucb1", "token-ts", "seq-ucb1:rsimple", "seq-ucb1:multi",
];

#[test]
fn spec_decode_is_lossless_for_every_method() {
    // the oracle prefix must match regardless of the stopping method
    for (i, method) in METHODS.iter().enumerate() {
        let seed = 100 + i as u64;
        let want = oracle(seed, "qa", 40);
        let (got, _) = run(seed, "qa", 0.85, method, 40);
        let n = want.len().min(got.len()).min(16 + 40);
        assert_eq!(got[..n], want[..n], "method {method} diverged from greedy oracle");
    }
}

#[test]
fn prop_lossless_across_scenarios() {
    forall(
        42,
        60,
        |r, size| {
            (
                r.next_u64(),
                ["coding", "qa", "writing", "math"][r.below(4)],
                0.3 + 0.65 * r.f64() as f32,
                METHODS[r.below(METHODS.len())],
                8 + (40.0 * size) as usize,
            )
        },
        |&(seed, cat, q, method, max_new)| {
            let want = oracle(seed, cat, max_new);
            let (got, rounds) = run(seed, cat, q, method, max_new);
            let n = want.len().min(got.len());
            if got[..n] != want[..n] {
                return Err(format!("{method} diverged on {cat} (q={q})"));
            }
            for &(d, a) in &rounds {
                if a > d {
                    return Err(format!("accepted {a} > drafted {d}"));
                }
                if d == 0 {
                    return Err("empty draft session".into());
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_round_accounting() {
    // committed length == prompt + sum(accepted + 1 bonus per round)
    forall(
        7,
        40,
        |r, _| (r.next_u64(), METHODS[r.below(METHODS.len())]),
        |&(seed, method)| {
            let (got, rounds) = run(seed, "reasoning", 0.8, method, 32);
            let committed_new = got.len() - 16;
            let from_rounds: usize = rounds.iter().map(|(_, a)| a + 1).sum();
            if committed_new != from_rounds {
                return Err(format!("{committed_new} != {from_rounds}"));
            }
            Ok(())
        },
    );
}

#[test]
fn static_k_drafts_exactly_k() {
    let (_, rounds) = run(5, "qa", 0.9, "static-5", 40);
    // all rounds draft exactly 5 except possibly a tail capped by headroom
    for &(d, _) in &rounds[..rounds.len() - 1] {
        assert_eq!(d, 5);
    }
}

#[test]
fn gamma_max_is_respected() {
    let (mut draft, mut target) = sim_models(9, "coding", 0.99);
    // always-continue policy would draft forever without the cap
    let mut ctrl = StopController::always_continue();
    let mut rng = Rng::new(9);
    let cfg = GenConfig { max_new: 64, gamma_max: 11, stop_at_eos: false, collect_signals: false };
    let r = generate(&mut draft, &mut target, &mut ctrl, &mut rng, &prompt(8), &cfg).unwrap();
    assert!(r.rounds.iter().all(|x| x.drafted <= 11));
    assert!(r.rounds.iter().any(|x| x.drafted == 11), "cap should bind for a strong draft");
}

#[test]
fn step_api_is_equivalent_to_generate() {
    // the step-driven session (ARCHITECTURE.md §10) and the classic
    // run-to-completion loop must be the same decode: identical committed
    // tokens and per-round accounting, with the per-step commits
    // concatenating to exactly the generated suffix
    for (seed, method) in [(3u64, "seq-ucb1"), (7, "static-5"), (13, "svip")] {
        let cfg =
            GenConfig { max_new: 40, gamma_max: 32, stop_at_eos: false, collect_signals: false };

        let (mut draft, mut target) = sim_models(seed, "qa", 0.85);
        let mut ctrl = MethodSpec::parse(method, ".").unwrap().build(32).unwrap();
        let mut rng = Rng::new(seed);
        let want =
            generate(&mut draft, &mut target, &mut ctrl, &mut rng, &prompt(10), &cfg).unwrap();

        let (mut draft, mut target) = sim_models(seed, "qa", 0.85);
        let mut ctrl = MethodSpec::parse(method, ".").unwrap().build(32).unwrap();
        let mut rng = Rng::new(seed);
        let mut sess = SpecSession::new(
            &mut draft,
            &mut target,
            &mut ctrl,
            &mut rng,
            &prompt(10),
            &cfg,
        )
        .unwrap();
        let mut streamed: Vec<u32> = Vec::new();
        let reason = loop {
            match sess.step().unwrap() {
                StepOutcome::Round(c) => {
                    assert_eq!(c.accepted + 1, c.new_tokens.len(), "accepted + bonus");
                    streamed.extend_from_slice(&c.new_tokens);
                }
                StepOutcome::Finished(r) => break r,
            }
        };
        assert!(sess.is_finished());
        assert_eq!(reason, FinishReason::MaxNew, "{method}: EOS-free sim hits the budget");
        let got = sess.finish();
        assert_eq!(got.tokens, want.tokens, "{method}: step loop diverged from generate");
        assert_eq!(got.rounds.len(), want.rounds.len(), "{method}");
        assert_eq!(streamed, got.new_tokens(), "{method}: commits must concatenate exactly");
    }
}

#[test]
fn cursor_invariants_after_generation() {
    let (mut draft, mut target) = sim_models(11, "qa", 0.7);
    let mut ctrl = MethodSpec::parse("seq-ucb1", ".").unwrap().build(32).unwrap();
    let mut rng = Rng::new(11);
    let cfg = GenConfig { max_new: 48, gamma_max: 32, stop_at_eos: false, collect_signals: false };
    let r = generate(&mut draft, &mut target, &mut ctrl, &mut rng, &prompt(12), &cfg).unwrap();
    assert!(draft.cur() <= r.tokens.len());
    assert!(target.cur() <= r.tokens.len());
}

#[test]
fn online_bandit_state_persists_across_requests() {
    // run many requests through one Seq controller; the bandit must end up
    // with counts across requests (online learning) and a meaningful best arm
    let mut ctrl = MethodSpec::parse("seq-ucb1", ".").unwrap().build(64).unwrap();
    let mut rng = Rng::new(3);
    let cfg = GenConfig { max_new: 24, gamma_max: 64, stop_at_eos: false, collect_signals: false };
    let mut sessions = 0;
    for seed in 0..30 {
        let (mut draft, mut target) = sim_models(seed, "qa", 0.85);
        let r = generate(&mut draft, &mut target, &mut ctrl, &mut rng, &prompt(10), &cfg).unwrap();
        sessions += r.rounds.len();
    }
    let values = ctrl.arm_values().unwrap();
    assert_eq!(values.len(), 5);
    assert!(sessions > 50);
    assert!(values.iter().any(|&v| v > 0.0), "{values:?}");
}

#[test]
fn weak_draft_yields_lower_acceptance() {
    let acc = |q: f32| {
        let mut total = (0, 0);
        for seed in 0..20 {
            let (got, rounds) = {
                let (mut draft, mut target) = sim_models(seed, "qa", q);
                let mut ctrl = MethodSpec::Static(6).build(64).unwrap();
                let mut rng = Rng::new(seed);
                let cfg = GenConfig {
                    max_new: 32, gamma_max: 64, stop_at_eos: false, collect_signals: false,
                };
                let r = generate(&mut draft, &mut target, &mut ctrl, &mut rng, &prompt(12), &cfg)
                    .unwrap();
                (r.tokens, r.rounds)
            };
            let _ = got;
            for r in rounds {
                total.0 += r.accepted;
                total.1 += r.drafted;
            }
        }
        total.0 as f64 / total.1 as f64
    };
    let strong = acc(0.95);
    let weak = acc(0.4);
    assert!(strong > weak + 0.1, "strong {strong:.2} vs weak {weak:.2}");
}

// -- unit-level property tests over the shared decode primitives --------

/// A signal row whose argmax is `tok` (tiny 8-token vocab).
fn row(tok: u32) -> TokenSignals {
    let mut logits = vec![0.0f32; 8];
    logits[tok as usize] = 9.0;
    TokenSignals::from_logits(&logits)
}

#[test]
fn prop_accept_greedy_stops_at_the_first_mismatch() {
    // accept_greedy must accept exactly the agreeing proposal prefix and
    // hand back the verifier's own token at the first disagreement (or
    // the bonus row when everything agrees) — under a randomized window
    // offset (tc < c - 1 simulates a catch-up block)
    forall(
        19,
        150,
        |r, size| {
            let gamma = 1 + (10.0 * size) as usize;
            let tc = r.below(12);
            let c = tc + 1 + r.below(8);
            let proposals: Vec<u32> = (0..gamma).map(|_| r.below(6) as u32).collect();
            // verifier rows mostly agree so long accept prefixes occur
            let verify: Vec<u32> = proposals
                .iter()
                .map(|&t| if r.f64() < 0.75 { t } else { r.below(6) as u32 })
                .collect();
            let bonus = r.below(6) as u32;
            (tc, c, proposals, verify, bonus)
        },
        |(tc, c, proposals, verify, bonus)| {
            let off = c - 1 - tc;
            // rows below the offset belong to the catch-up region and must
            // never be consulted — fill them with an arbitrary token
            let mut vsig = vec![row(0); off];
            vsig.extend(verify.iter().map(|&t| row(t)));
            vsig.push(row(*bonus));
            let (accepted, got_bonus) = accept_greedy(&vsig, *tc, *c, proposals);
            if accepted > proposals.len() {
                return Err(format!("accepted {accepted} > drafted {}", proposals.len()));
            }
            for m in 0..accepted {
                if verify[m] != proposals[m] {
                    return Err(format!("accepted through a mismatch at {m}"));
                }
            }
            if accepted < proposals.len() && verify[accepted] == proposals[accepted] {
                return Err(format!("stopped at {accepted} although the verifier agreed"));
            }
            let want_bonus = if accepted < verify.len() { verify[accepted] } else { *bonus };
            if got_bonus != want_bonus {
                return Err(format!("bonus {got_bonus} != verifier token {want_bonus}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_finish_check_stops_in_priority_order() {
    // budget beats EOS beats KV headroom, and nothing else ever stops a
    // decode — the same rule in both the session and the engine stepper
    forall(
        57,
        200,
        |r, _| {
            let prompt_len = 1 + r.below(24);
            let new = r.below(40);
            let max_new = 1 + r.below(32);
            let slack = r.below(5); // KV headroom beyond the +2 safety margin
            let stop_at_eos = r.below(2) == 0;
            let last = match r.below(3) {
                0 => None,
                1 => Some(EOS),
                _ => Some(7u32),
            };
            (prompt_len, new, max_new, slack, stop_at_eos, last)
        },
        |&(prompt_len, new, max_new, slack, stop_at_eos, last)| {
            let committed = prompt_len + new;
            let max_seq = committed + 2 + slack;
            let cfg = GenConfig { max_new, gamma_max: 8, stop_at_eos, collect_signals: false };
            let got = finish_check(committed, prompt_len, last, &cfg, max_seq);
            let want = if new >= max_new {
                Some(FinishReason::MaxNew)
            } else if stop_at_eos && last == Some(EOS) {
                Some(FinishReason::Eos)
            } else if slack == 0 {
                Some(FinishReason::KvExhausted)
            } else {
                None
            };
            if got != want {
                return Err(format!(
                    "new {new}/{max_new}, eos {stop_at_eos}/{last:?}, slack {slack}: \
                     got {got:?}, want {want:?}"
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_page_pool_conserves_under_random_ops() {
    // any interleaving of checkout-path page ops keeps Σ refcounts == Σ
    // chain memberships and the free list exact — including deliberately
    // undersized arenas where extension saturates
    forall(
        23,
        80,
        |r, size| {
            let ops = 10 + (50.0 * size) as usize;
            (r.next_u64(), 1 + r.below(7), r.below(2) == 0, 2 + r.below(3), ops)
        },
        |&(seed, page_size, tight, slots, ops)| {
            let max_seq = 64usize;
            // a tight arena holds roughly half the zero-sharing demand
            let kv_pages = if tight { 1 + slots * max_seq.div_ceil(page_size) / 2 } else { 0 };
            let mut p = PagePool::new(page_size, kv_pages, slots, max_seq);
            let mut rng = Rng::new(seed);
            for step in 0..ops {
                let slot = rng.below(slots);
                match rng.below(5) {
                    0 => {
                        p.drop_chain(slot);
                    }
                    1 => {
                        p.evict_chain(slot);
                    }
                    2 => {
                        p.resize(slot, rng.below(max_seq + 1));
                    }
                    3 => {
                        // keep must stay within the resident chain
                        let keep = rng.below(p.chain_pages(slot) * page_size + 1);
                        p.reacquire(slot, keep, rng.below(max_seq + 1));
                    }
                    _ => {
                        let src = rng.below(slots);
                        if src != slot {
                            let shared = rng.below(p.chain_pages(src) * page_size + 1);
                            p.adopt(slot, src, shared, rng.below(max_seq + 1));
                        }
                    }
                }
                if let Some(e) = p.conservation_error() {
                    return Err(format!("step {step}: {e}"));
                }
                if p.shared_pages() > p.resident_pages() {
                    return Err(format!("step {step}: more shared than resident pages"));
                }
            }
            for s in 0..slots {
                p.drop_chain(s);
            }
            if p.resident_pages() != 0 || p.free_pages() != p.total_pages() {
                return Err(format!(
                    "dropping every chain must drain the arena: {} resident, {}/{} free",
                    p.resident_pages(),
                    p.free_pages(),
                    p.total_pages()
                ));
            }
            match p.conservation_error() {
                Some(e) => Err(e),
                None => Ok(()),
            }
        },
    );
}

#[test]
fn prop_prefix_index_tracks_registrations_and_finds_deepest_match() {
    // under random insert/remove churn the trie always reports the
    // verbatim registration per slot, best_match returns the true
    // maximum common prefix, and removing everything frees every node
    forall(
        31,
        80,
        |r, size| (r.next_u64(), 2 + r.below(4), 12 + (60.0 * size) as usize),
        |&(seed, slots, ops)| {
            let mut ix = PrefixIndex::new();
            let mut mirror: Vec<Option<Vec<u32>>> = vec![None; slots];
            let mut rng = Rng::new(seed);
            // a 3-token alphabet forces heavy prefix overlap between slots
            fn tok(rng: &mut Rng) -> u32 {
                1 + rng.below(3) as u32
            }
            fn lcp(a: &[u32], b: &[u32]) -> usize {
                a.iter().zip(b).take_while(|(x, y)| x == y).count()
            }
            for step in 0..ops {
                let slot = rng.below(slots);
                if rng.below(4) == 0 {
                    if let Some(pre) = mirror[slot].take() {
                        ix.remove(slot, &pre);
                    }
                } else {
                    let pre: Vec<u32> = (0..rng.below(8)).map(|_| tok(&mut rng)).collect();
                    ix.insert(slot, &pre);
                    mirror[slot] = if pre.is_empty() { None } else { Some(pre) };
                }
                for s in 0..slots {
                    if ix.registration(s) != mirror[s].as_deref() {
                        return Err(format!("step {step}: slot {s} registration drift"));
                    }
                }
                let probe: Vec<u32> = (0..rng.below(10)).map(|_| tok(&mut rng)).collect();
                let want = mirror.iter().flatten().map(|p| lcp(p, &probe)).max().unwrap_or(0);
                match ix.best_match(&probe) {
                    Some((s, n)) => {
                        if n != want {
                            return Err(format!("step {step}: match depth {n}, true LCP {want}"));
                        }
                        let Some(reg) = mirror[s].as_deref() else {
                            return Err(format!("step {step}: matched unregistered slot {s}"));
                        };
                        if lcp(reg, &probe) != n {
                            return Err(format!("step {step}: slot {s} does not share {n} tokens"));
                        }
                    }
                    None if want != 0 => {
                        return Err(format!("step {step}: no match, true LCP {want}"));
                    }
                    None => {}
                }
            }
            for s in 0..slots {
                if let Some(pre) = mirror[s].take() {
                    ix.remove(s, &pre);
                }
            }
            if ix.node_count() != 0 {
                return Err(format!("trie leaked {} nodes after full removal", ix.node_count()));
            }
            Ok(())
        },
    );
}

#[test]
fn drafter_pool_full_information_updates_and_abort_conservation() {
    // Hierarchical drafter layer (docs/ARCHITECTURE.md §17), randomized:
    // every verify must update ALL pooled drafter posteriors exactly once
    // (full-information "Not-a-Bandit" scoring), and the per-layer play
    // ledger must balance — begins == settles, Σ global plays == settles
    // == Σ per-tenant plays — including sessions a fault aborts mid-round.
    use tapout::bandit::{DrafterHook, SharedDrafters};
    use tapout::models::{FaultPlan, FaultyModel};

    forall(
        0xD4AF7,
        60,
        |r, size| {
            (
                r.below(100_000) as u64,                 // scenario seed
                1 + r.below(4),                          // pool size 1..=4
                0.55 + 0.35 * r.f64(),                   // draft quality
                6 + r.below((48.0 * size) as usize + 6), // max_new
                r.below(3) == 0,                         // inject one fault?
                ["", "tA", "tB"][r.below(3)].to_string(),
            )
        },
        |case| {
            let (seed, n, quality, max_new, fault, ref tenant) = *case;
            let sc = Scenario::new(seed, "qa");
            let pooled = SimModel::draft(sc, quality as f32, 0.05).with_drafters(n);
            let mut draft: Box<dyn LanguageModel> = if fault {
                // a single-kill error budget: at most one round aborts,
                // after which the model heals and the decode can finish
                Box::new(FaultyModel::new(
                    Box::new(pooled),
                    FaultPlan { seed, error_rate: 0.35, max_faults: 1, ..FaultPlan::default() },
                ))
            } else {
                Box::new(pooled)
            };
            let mut target = SimModel::target(sc);
            let mut ctrl = MethodSpec::parse("seq-ucb1", ".").unwrap().build(8).unwrap();
            let mut rng = Rng::new(seed);
            let cfg =
                GenConfig { max_new, gamma_max: 8, stop_at_eos: false, collect_signals: false };
            let shared = SharedDrafters::new(n);
            let mut sess = SpecSession::new(
                draft.as_mut(),
                &mut target,
                &mut ctrl,
                &mut rng,
                &prompt(16),
                &cfg,
            )
            .expect("session construction does no forwards");
            sess.set_drafter_hook(DrafterHook::new(
                shared.clone(),
                tenant.clone(),
                seed,
                "qa".to_string(),
            ));
            let (mut verifies, mut aborts) = (0u64, 0u64);
            let finished = loop {
                match sess.step() {
                    Ok(StepOutcome::Round(_)) => verifies += 1,
                    Ok(StepOutcome::Finished(_)) => break true,
                    Err(_) => {
                        aborts += 1;
                        break false;
                    }
                }
            };
            // per-layer play conservation, abort included
            if shared.sessions() != verifies + aborts {
                return Err(format!(
                    "begins {} != rounds {} + aborts {}",
                    shared.sessions(),
                    verifies,
                    aborts
                ));
            }
            if shared.updates() != shared.sessions() {
                return Err(format!(
                    "settles {} != begins {}",
                    shared.updates(),
                    shared.sessions()
                ));
            }
            if shared.plays().iter().sum::<u64>() != shared.updates() {
                return Err("Σ global plays != settles".into());
            }
            if shared.tenant_plays_total() != shared.updates() {
                return Err("Σ per-tenant plays != settles".into());
            }
            // full information: the tenant's posterior observed exactly
            // one update per verify, covering every pooled drafter
            let snap = shared.tenant_snapshot();
            if verifies + aborts > 0 {
                let t = snap
                    .iter()
                    .find(|t| &t.tenant == tenant)
                    .ok_or_else(|| format!("tenant {tenant:?} missing from snapshot"))?;
                if t.obs != verifies {
                    return Err(format!(
                        "tenant obs {} != verifies {verifies}: a verify must update the \
                         posterior exactly once",
                        t.obs
                    ));
                }
                if t.means.len() != n {
                    return Err(format!("posterior covers {} of {n} drafters", t.means.len()));
                }
                if !t.means.iter().all(|m| (0.0..=1.0).contains(m)) {
                    return Err(format!("agreement means out of range: {:?}", t.means));
                }
            }
            // lossless: a finished pooled decode equals target-only greedy
            if finished {
                let got = sess.finish();
                let want = oracle(seed, "qa", max_new);
                if got.tokens[..got.tokens.len().min(want.len())]
                    != want[..got.tokens.len().min(want.len())]
                {
                    return Err("pooled decode diverged from the greedy oracle".into());
                }
            }
            Ok(())
        },
    );
}
