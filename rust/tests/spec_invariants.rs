//! Integration + property tests over the speculative-decoding core using
//! the simulator backend (fast, deterministic, millions of tokens).
//!
//! The central invariant: **greedy speculative decoding is lossless** —
//! whatever the stop controller does, the committed output must equal the
//! target-only greedy continuation. Every method is run through that check
//! under randomized scenarios (mini-proptest, util::prop).

use tapout::models::sim::{Scenario, SimModel};
use tapout::models::LanguageModel;
use tapout::spec::{
    generate, greedy, FinishReason, GenConfig, MethodSpec, SpecSession, StepOutcome,
    StopController,
};
use tapout::util::prop::forall;
use tapout::util::Rng;

fn sim_models(seed: u64, cat: &str, quality: f32) -> (SimModel, SimModel) {
    let sc = Scenario::new(seed, cat);
    (SimModel::draft(sc, quality, 0.05), SimModel::target(sc))
}

fn prompt(len: usize) -> Vec<u32> {
    (0..len).map(|i| 3 + (i % 29) as u32).collect()
}

fn run(
    seed: u64,
    cat: &str,
    quality: f32,
    method: &str,
    max_new: usize,
) -> (Vec<u32>, Vec<(usize, usize)>) {
    let (mut draft, mut target) = sim_models(seed, cat, quality);
    let mut ctrl = MethodSpec::parse(method, "artifacts").unwrap().build(64).unwrap();
    let mut rng = Rng::new(seed);
    let cfg = GenConfig { max_new, gamma_max: 64, stop_at_eos: false, collect_signals: false };
    let r = generate(&mut draft, &mut target, &mut ctrl, &mut rng, &prompt(16), &cfg).unwrap();
    let rounds = r.rounds.iter().map(|x| (x.drafted, x.accepted)).collect();
    (r.tokens, rounds)
}

fn oracle(seed: u64, cat: &str, max_new: usize) -> Vec<u32> {
    let sc = Scenario::new(seed, cat);
    let mut target = SimModel::target(sc);
    let cfg = GenConfig { max_new, gamma_max: 64, stop_at_eos: false, collect_signals: false };
    greedy(&mut target, &prompt(16), &cfg).unwrap().tokens
}

const METHODS: &[&str] = &[
    "static-1", "static-6", "static-17", "ada-edl", "svip", "max-conf",
    "logit-margin", "svip-diff", "seq-ucb1", "seq-ucb-tuned", "seq-ts",
    "token-ucb1", "token-ts", "seq-ucb1:rsimple", "seq-ucb1:multi",
];

#[test]
fn spec_decode_is_lossless_for_every_method() {
    // the oracle prefix must match regardless of the stopping method
    for (i, method) in METHODS.iter().enumerate() {
        let seed = 100 + i as u64;
        let want = oracle(seed, "qa", 40);
        let (got, _) = run(seed, "qa", 0.85, method, 40);
        let n = want.len().min(got.len()).min(16 + 40);
        assert_eq!(got[..n], want[..n], "method {method} diverged from greedy oracle");
    }
}

#[test]
fn prop_lossless_across_scenarios() {
    forall(
        42,
        60,
        |r, size| {
            (
                r.next_u64(),
                ["coding", "qa", "writing", "math"][r.below(4)],
                0.3 + 0.65 * r.f64() as f32,
                METHODS[r.below(METHODS.len())],
                8 + (40.0 * size) as usize,
            )
        },
        |&(seed, cat, q, method, max_new)| {
            let want = oracle(seed, cat, max_new);
            let (got, rounds) = run(seed, cat, q, method, max_new);
            let n = want.len().min(got.len());
            if got[..n] != want[..n] {
                return Err(format!("{method} diverged on {cat} (q={q})"));
            }
            for &(d, a) in &rounds {
                if a > d {
                    return Err(format!("accepted {a} > drafted {d}"));
                }
                if d == 0 {
                    return Err("empty draft session".into());
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_round_accounting() {
    // committed length == prompt + sum(accepted + 1 bonus per round)
    forall(
        7,
        40,
        |r, _| (r.next_u64(), METHODS[r.below(METHODS.len())]),
        |&(seed, method)| {
            let (got, rounds) = run(seed, "reasoning", 0.8, method, 32);
            let committed_new = got.len() - 16;
            let from_rounds: usize = rounds.iter().map(|(_, a)| a + 1).sum();
            if committed_new != from_rounds {
                return Err(format!("{committed_new} != {from_rounds}"));
            }
            Ok(())
        },
    );
}

#[test]
fn static_k_drafts_exactly_k() {
    let (_, rounds) = run(5, "qa", 0.9, "static-5", 40);
    // all rounds draft exactly 5 except possibly a tail capped by headroom
    for &(d, _) in &rounds[..rounds.len() - 1] {
        assert_eq!(d, 5);
    }
}

#[test]
fn gamma_max_is_respected() {
    let (mut draft, mut target) = sim_models(9, "coding", 0.99);
    // always-continue policy would draft forever without the cap
    let mut ctrl = StopController::always_continue();
    let mut rng = Rng::new(9);
    let cfg = GenConfig { max_new: 64, gamma_max: 11, stop_at_eos: false, collect_signals: false };
    let r = generate(&mut draft, &mut target, &mut ctrl, &mut rng, &prompt(8), &cfg).unwrap();
    assert!(r.rounds.iter().all(|x| x.drafted <= 11));
    assert!(r.rounds.iter().any(|x| x.drafted == 11), "cap should bind for a strong draft");
}

#[test]
fn step_api_is_equivalent_to_generate() {
    // the step-driven session (ARCHITECTURE.md §10) and the classic
    // run-to-completion loop must be the same decode: identical committed
    // tokens and per-round accounting, with the per-step commits
    // concatenating to exactly the generated suffix
    for (seed, method) in [(3u64, "seq-ucb1"), (7, "static-5"), (13, "svip")] {
        let cfg =
            GenConfig { max_new: 40, gamma_max: 32, stop_at_eos: false, collect_signals: false };

        let (mut draft, mut target) = sim_models(seed, "qa", 0.85);
        let mut ctrl = MethodSpec::parse(method, ".").unwrap().build(32).unwrap();
        let mut rng = Rng::new(seed);
        let want =
            generate(&mut draft, &mut target, &mut ctrl, &mut rng, &prompt(10), &cfg).unwrap();

        let (mut draft, mut target) = sim_models(seed, "qa", 0.85);
        let mut ctrl = MethodSpec::parse(method, ".").unwrap().build(32).unwrap();
        let mut rng = Rng::new(seed);
        let mut sess = SpecSession::new(
            &mut draft,
            &mut target,
            &mut ctrl,
            &mut rng,
            &prompt(10),
            &cfg,
        )
        .unwrap();
        let mut streamed: Vec<u32> = Vec::new();
        let reason = loop {
            match sess.step().unwrap() {
                StepOutcome::Round(c) => {
                    assert_eq!(c.accepted + 1, c.new_tokens.len(), "accepted + bonus");
                    streamed.extend_from_slice(&c.new_tokens);
                }
                StepOutcome::Finished(r) => break r,
            }
        };
        assert!(sess.is_finished());
        assert_eq!(reason, FinishReason::MaxNew, "{method}: EOS-free sim hits the budget");
        let got = sess.finish();
        assert_eq!(got.tokens, want.tokens, "{method}: step loop diverged from generate");
        assert_eq!(got.rounds.len(), want.rounds.len(), "{method}");
        assert_eq!(streamed, got.new_tokens(), "{method}: commits must concatenate exactly");
    }
}

#[test]
fn cursor_invariants_after_generation() {
    let (mut draft, mut target) = sim_models(11, "qa", 0.7);
    let mut ctrl = MethodSpec::parse("seq-ucb1", ".").unwrap().build(32).unwrap();
    let mut rng = Rng::new(11);
    let cfg = GenConfig { max_new: 48, gamma_max: 32, stop_at_eos: false, collect_signals: false };
    let r = generate(&mut draft, &mut target, &mut ctrl, &mut rng, &prompt(12), &cfg).unwrap();
    assert!(draft.cur() <= r.tokens.len());
    assert!(target.cur() <= r.tokens.len());
}

#[test]
fn online_bandit_state_persists_across_requests() {
    // run many requests through one Seq controller; the bandit must end up
    // with counts across requests (online learning) and a meaningful best arm
    let mut ctrl = MethodSpec::parse("seq-ucb1", ".").unwrap().build(64).unwrap();
    let mut rng = Rng::new(3);
    let cfg = GenConfig { max_new: 24, gamma_max: 64, stop_at_eos: false, collect_signals: false };
    let mut sessions = 0;
    for seed in 0..30 {
        let (mut draft, mut target) = sim_models(seed, "qa", 0.85);
        let r = generate(&mut draft, &mut target, &mut ctrl, &mut rng, &prompt(10), &cfg).unwrap();
        sessions += r.rounds.len();
    }
    let values = ctrl.arm_values().unwrap();
    assert_eq!(values.len(), 5);
    assert!(sessions > 50);
    assert!(values.iter().any(|&v| v > 0.0), "{values:?}");
}

#[test]
fn weak_draft_yields_lower_acceptance() {
    let acc = |q: f32| {
        let mut total = (0, 0);
        for seed in 0..20 {
            let (got, rounds) = {
                let (mut draft, mut target) = sim_models(seed, "qa", q);
                let mut ctrl = MethodSpec::Static(6).build(64).unwrap();
                let mut rng = Rng::new(seed);
                let cfg = GenConfig {
                    max_new: 32, gamma_max: 64, stop_at_eos: false, collect_signals: false,
                };
                let r = generate(&mut draft, &mut target, &mut ctrl, &mut rng, &prompt(12), &cfg)
                    .unwrap();
                (r.tokens, r.rounds)
            };
            let _ = got;
            for r in rounds {
                total.0 += r.accepted;
                total.1 += r.drafted;
            }
        }
        total.0 as f64 / total.1 as f64
    };
    let strong = acc(0.95);
    let weak = acc(0.4);
    assert!(strong > weak + 0.1, "strong {strong:.2} vs weak {weak:.2}");
}
