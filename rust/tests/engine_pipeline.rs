//! Two-stage pipeline integration tests (docs/ARCHITECTURE.md §16): the
//! continuous stepper with `pipeline` on overlaps each verify forward
//! with a speculative pre-draft of the next round's catch-up row, and
//! none of it may be observable in the output bytes or the accounting:
//!
//!   * a staggered burst with pipelining on is byte-identical to the
//!     serialized continuous engine and the greedy oracle at slots
//!     {1, 4, 8}, while the `engine.pipeline` gauges observe the
//!     speculation that happened (and stay silent when it is off);
//!   * the flag is a no-op in Workers mode — identical bytes, zero
//!     pipeline rounds;
//!   * injected verify faults (errors and sticky crashes) discard the
//!     in-flight pre-draft with the chunk: every request still reaches
//!     an honest terminal, the engine heals, bandit plays settle exactly
//!     once, and at one row per chunk the adopt/discard ledger balances
//!     to the speculated-forward count even across the fault path;
//!   * page-refcount conservation holds with prefix cache + COW sharing
//!     on and a mid-decode cancel — discarded speculation never touches
//!     page refcounts.

mod common;

use std::sync::atomic::Ordering;
use std::time::Duration;

use common::{collect, oracle_tokens, MAX_NEW, TIMEOUT};
use tapout::engine::{Engine, EngineConfig, EngineMode, FinishStatus, Request, StreamEvent};
use tapout::models::FaultPlan;

/// Fault scenarios use short decodes: the interesting part is the
/// discard path, not the decode length.
const FAULT_MAX_NEW: usize = 16;

fn config(mode: EngineMode, workers: usize, slots: usize, pipeline: bool) -> EngineConfig {
    EngineConfig { mode, pipeline, ..common::sim_config(workers, slots) }
}

fn burst_prompts(n: usize) -> Vec<String> {
    common::burst_prompts(n, "pipelined decode")
}

#[test]
fn pipelined_continuous_is_byte_identical_to_serialized_and_oracle() {
    let prompts = burst_prompts(16);
    let mut saw_adopted = false;
    for slots in [1usize, 4, 8] {
        // the same staggered three-wave burst through a serialized and a
        // pipelined continuous engine (admissions land mid-flight)
        let run = |pipeline: bool| {
            let eng = Engine::start(config(EngineMode::Continuous, 0, slots, pipeline)).unwrap();
            let mut rxs = Vec::new();
            for wave in prompts.chunks(8) {
                for p in wave {
                    rxs.push(eng.submit(p, MAX_NEW));
                }
                std::thread::sleep(Duration::from_millis(3));
            }
            let out = collect(rxs);
            (eng, out)
        };
        let (base_eng, base) = run(false);
        let (pipe_eng, piped) = run(true);

        let mut rounds = 0u64;
        for (i, (b, p)) in base.iter().zip(&piped).enumerate() {
            assert!(b.is_ok(), "slots {slots} request {i} (serialized): {:?}", b.error);
            assert!(p.is_ok(), "slots {slots} request {i} (pipelined): {:?}", p.error);
            assert_eq!(
                p.result.new_tokens(),
                b.result.new_tokens(),
                "slots {slots} request {i}: pipelining moved a byte"
            );
            assert_eq!(
                p.result.new_tokens(),
                &oracle_tokens(&prompts[i], MAX_NEW)[..],
                "slots {slots} request {i}: pipelined output diverged from the greedy oracle"
            );
            rounds += p.result.rounds.len() as u64;
        }

        // serialized engines never touch the pipeline ledger, and the
        // metrics block stays absent (gated on rounds > 0)
        assert_eq!(base_eng.stats.pipeline.rounds.load(Ordering::Relaxed), 0, "slots {slots}");
        let bj = base_eng.metrics_json();
        assert!(
            bj.get("engine").and_then(|e| e.get("pipeline")).is_none(),
            "slots {slots}: pipeline gauges must be gated off when serialized"
        );

        // discarded speculation is reward-invisible: play conservation
        // holds exactly as in the serialized engine
        assert_eq!(pipe_eng.bandit_sessions(), rounds, "slots {slots}");
        assert_eq!(pipe_eng.bandit_updates(), rounds, "slots {slots}");
        let counts = pipe_eng.bandit_counts().expect("seq-ucb1 has a shared bandit");
        assert_eq!(counts.iter().sum::<u64>(), rounds, "slots {slots}: {counts:?}");

        // the pipeline observed its own execution
        let p = &pipe_eng.stats.pipeline;
        assert!(p.rounds.load(Ordering::Relaxed) > 0, "slots {slots}");
        let spec = p.spec_forwards.load(Ordering::Relaxed);
        let adopted = p.rows_adopted.load(Ordering::Relaxed);
        let discarded = p.rows_discarded.load(Ordering::Relaxed);
        assert!(spec > 0, "slots {slots}: the shadow pre-draft must actually run");
        if slots == 1 {
            // one row per chunk: every speculated row resolves exactly once
            assert_eq!(adopted + discarded, spec, "slots {slots}: pre-draft ledger imbalance");
        } else {
            assert!(adopted + discarded >= spec, "slots {slots}: rows can't under-resolve");
        }
        saw_adopted |= adopted > 0;
        let pj = pipe_eng.metrics_json();
        let gauges = pj
            .get("engine")
            .and_then(|e| e.get("pipeline"))
            .expect("pipeline gauges present after pipelined rounds");
        assert!(gauges.get("overlap_ratio").is_some());
        assert!(gauges.get("discard_rate").is_some());

        base_eng.shutdown();
        pipe_eng.shutdown();
    }
    assert!(saw_adopted, "full acceptance must adopt at least one pre-draft across slot counts");
}

#[test]
fn workers_mode_ignores_the_pipeline_flag() {
    let prompts = burst_prompts(8);
    let mut outs = Vec::new();
    for pipeline in [false, true] {
        let eng = Engine::start(config(EngineMode::Workers, 2, 2, pipeline)).unwrap();
        let out = collect(prompts.iter().map(|p| eng.submit(p, MAX_NEW)).collect());
        assert_eq!(
            eng.stats.pipeline.rounds.load(Ordering::Relaxed),
            0,
            "pipeline={pipeline}: Workers mode has no step loop to pipeline"
        );
        outs.push(out);
        eng.shutdown();
    }
    for (i, (a, b)) in outs[0].iter().zip(&outs[1]).enumerate() {
        assert!(a.is_ok() && b.is_ok(), "request {i}");
        assert_eq!(a.result.new_tokens(), b.result.new_tokens(), "request {i}: flag moved bytes");
        assert_eq!(a.result.new_tokens(), &oracle_tokens(&prompts[i], MAX_NEW)[..], "request {i}");
    }
}

#[test]
fn mid_verify_faults_discard_predrafts_and_settle_plays_once() {
    // error faults (forward dies under a live pre-draft) and sticky
    // crashes (the panic-equivalent) against a pipelined 1-slot engine
    let plans = [
        FaultPlan { seed: 11, error_rate: 1.0, max_faults: 2, ..FaultPlan::default() },
        FaultPlan { seed: 7, crash_rate: 1.0, max_faults: 1, ..FaultPlan::default() },
    ];
    for plan in plans {
        let mut cfg = config(EngineMode::Continuous, 0, 1, true);
        cfg.faults = plan;
        let eng = Engine::start(cfg).unwrap();

        let mut failed = 0usize;
        let mut last_ok = false;
        for i in 0..12 {
            let text = format!("pipelined fault probe {i}");
            let r = eng
                .submit(&text, FAULT_MAX_NEW)
                .recv_timeout(TIMEOUT)
                .unwrap_or_else(|_| panic!("request {i}: a fault must not hang the pipeline"));
            match r.status {
                FinishStatus::Failed => {
                    failed += 1;
                    last_ok = false;
                    assert!(r.error.is_some(), "request {i}: failures carry a reason");
                }
                FinishStatus::Done => {
                    last_ok = true;
                    assert_eq!(
                        r.result.new_tokens(),
                        &oracle_tokens(&text, FAULT_MAX_NEW)[..],
                        "request {i}: post-fault pipelined decode must stay byte-exact"
                    );
                }
                other => panic!("request {i}: unexpected status {other:?}"),
            }
        }
        assert!(failed >= 1, "rate-1.0 faults must fire at least once");
        assert!(last_ok, "the kill budget must exhaust and the pipelined engine heal");

        // a verify that dies mid-flight settles each chunk session's play
        // via on_abort exactly once — never zero (leak) or twice (mint)
        assert_eq!(
            eng.bandit_sessions(),
            eng.bandit_updates(),
            "aborted pipelined rounds must settle their bandit plays"
        );
        let counts = eng.bandit_counts().expect("seq-ucb1 has a shared bandit");
        assert_eq!(counts.iter().sum::<u64>(), eng.bandit_updates(), "{counts:?}");

        // 1 slot ⇒ one row per speculated chunk: adopt/discard balances
        // to the speculated-forward count even across the fault path
        // (a crashed verify discards its pre-draft, never drops it)
        let p = &eng.stats.pipeline;
        let spec = p.spec_forwards.load(Ordering::Relaxed);
        assert!(spec > 0, "healed decodes must have speculated");
        assert_eq!(
            p.rows_adopted.load(Ordering::Relaxed) + p.rows_discarded.load(Ordering::Relaxed),
            spec,
            "pre-draft ledger imbalance under faults"
        );
        eng.shutdown();
    }
}

#[test]
fn pipelined_decode_conserves_page_refcounts_under_sharing_and_cancel() {
    // COW page sharing + prefix cache on, a shared-prefix burst, and a
    // mid-decode cancel: adopted and discarded pre-drafts alike must
    // leave the page arena balanced (speculation never touches refcounts)
    let system = "shared system preamble for page sharing across the burst. ".repeat(3);
    let prompts: Vec<String> = (0..12).map(|i| format!("{system}user {i}: go")).collect();
    let mut cfg = config(EngineMode::Continuous, 0, 4, true);
    cfg.prefix_cache = true;
    cfg.page_sharing = true;
    let eng = Engine::start(cfg).unwrap();

    let req = Request::new(0, "pipelined decode to cancel midway", 3800);
    let flag = req.cancel_flag();
    let rx = eng.submit_request_streaming(req);
    let burst: Vec<_> = prompts.iter().map(|p| eng.submit(p, MAX_NEW)).collect();
    match rx.recv_timeout(TIMEOUT).expect("first stream event") {
        StreamEvent::Tokens { .. } => flag.cancel(),
        StreamEvent::Done(r) => panic!("cancel target finished early: {:?}", r.status),
    }
    loop {
        match rx.recv_timeout(TIMEOUT).expect("stream must terminate") {
            StreamEvent::Tokens { .. } => {}
            StreamEvent::Done(r) => {
                assert_eq!(r.status, FinishStatus::Cancelled);
                break;
            }
        }
    }
    for (i, r) in collect(burst).iter().enumerate() {
        assert!(r.is_ok(), "request {i}: {:?}", r.error);
        assert_eq!(
            r.result.new_tokens(),
            &oracle_tokens(&prompts[i], MAX_NEW)[..],
            "request {i}: sharing + pipelining moved a byte"
        );
    }

    assert_eq!(
        eng.page_conservation_error(),
        None,
        "discarded speculation must never touch page refcounts"
    );
    // at most the cancelled session's final aborted round is reward-less
    let counts = eng.bandit_counts().expect("seq-ucb1 has a shared bandit");
    assert_eq!(counts.iter().sum::<u64>(), eng.bandit_updates());
    assert!(eng.bandit_sessions() - eng.bandit_updates() <= 1);
    eng.shutdown();
}
