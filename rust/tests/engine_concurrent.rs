//! Concurrent-serving integration tests on the simulator backend — these
//! run everywhere (no artifacts needed) and pin down the multi-worker
//! engine's contract (DESIGN.md §2):
//!
//!   * a burst of requests against `workers >= 2` all get answered;
//!   * each reply is byte-identical to the single-worker engine's reply
//!     and to the target-only greedy oracle (greedy speculative decoding
//!     is lossless, so worker count must never change output);
//!   * one shared bandit accumulates updates from all workers — its play
//!     counts sum to the number of drafting sessions across the burst;
//!   * workers may outnumber KV slots (checkout blocks instead of
//!     panicking);
//!   * decode failures produce explicit error responses, not hangs.

mod common;

use common::{collect, oracle_tokens, sim_config, MAX_NEW, TIMEOUT};
use tapout::engine::{Engine, Policy};

fn burst_prompts(n: usize) -> Vec<String> {
    common::burst_prompts(n, "concurrent serving")
}

#[test]
fn multi_worker_burst_matches_sequential_engine_and_greedy_oracle() {
    let prompts = burst_prompts(16);

    // single-worker reference replies
    let seq = Engine::start(sim_config(1, 1)).unwrap();
    let seq_out: Vec<Vec<u32>> = prompts
        .iter()
        .map(|p| {
            let r = seq.submit(p, MAX_NEW).recv_timeout(TIMEOUT).unwrap();
            assert!(r.is_ok(), "{:?}", r.error);
            r.result.new_tokens().to_vec()
        })
        .collect();
    seq.shutdown();

    // concurrent burst
    let eng = Engine::start(sim_config(4, 4)).unwrap();
    let rxs: Vec<_> = prompts.iter().map(|p| eng.submit(p, MAX_NEW)).collect();
    let responses = collect(rxs);

    let mut total_sessions = 0u64;
    for (i, r) in responses.iter().enumerate() {
        assert!(r.is_ok(), "request {i} failed: {:?}", r.error);
        assert!(!r.result.new_tokens().is_empty());
        assert_eq!(
            r.result.new_tokens(),
            &seq_out[i][..],
            "request {i}: multi-worker output diverged from sequential engine"
        );
        assert_eq!(
            r.result.new_tokens(),
            &oracle_tokens(&prompts[i], MAX_NEW)[..],
            "request {i}: output diverged from the greedy oracle"
        );
        total_sessions += r.result.rounds.len() as u64;
    }

    {
        let m = eng.metrics.lock().unwrap();
        assert_eq!(m.completed, 16);
        assert_eq!(m.failed, 0);
        assert!(m.drafted > 0);
    }
    assert_eq!(eng.stats.total_requests(), 16);

    // one shared bandit absorbed every session from every worker
    assert_eq!(eng.bandit_sessions(), total_sessions);
    assert_eq!(eng.bandit_updates(), total_sessions);
    let counts = eng.bandit_counts().expect("seq-ucb1 has a shared bandit");
    assert_eq!(
        counts.iter().sum::<u64>(),
        total_sessions,
        "shared bandit counts must sum to the sessions across all workers: {counts:?}"
    );
    eng.shutdown();
}

#[test]
fn workers_may_exceed_slots_without_panicking() {
    // 4 workers contend for 2 KV slots: checkout blocks, everything
    // completes, and slot reuse shows up in the pool accounting
    let eng = Engine::start(sim_config(4, 2)).unwrap();
    let prompts = burst_prompts(16);
    let rxs: Vec<_> = prompts.iter().map(|p| eng.submit(p, MAX_NEW)).collect();
    for (i, r) in collect(rxs).iter().enumerate() {
        assert!(r.is_ok(), "request {i} failed: {:?}", r.error);
        assert_eq!(r.result.new_tokens(), &oracle_tokens(&prompts[i], MAX_NEW)[..]);
    }
    assert_eq!(eng.metrics.lock().unwrap().completed, 16);
    eng.shutdown();
}

#[test]
fn bandit_state_carries_over_between_bursts() {
    let eng = Engine::start(sim_config(2, 2)).unwrap();
    let first = burst_prompts(4);
    collect(first.iter().map(|p| eng.submit(p, MAX_NEW)).collect());
    let after_first = eng.bandit_sessions();
    assert!(after_first > 0);

    let second: Vec<String> = (0..4).map(|i| format!("second wave item {i}")).collect();
    collect(second.iter().map(|p| eng.submit(p, MAX_NEW)).collect());
    assert!(
        eng.bandit_sessions() > after_first,
        "the shared bandit must keep learning across bursts (online setting)"
    );
    eng.shutdown();
}

#[test]
fn decode_failure_yields_error_response_not_a_hang() {
    let eng = Engine::start(sim_config(2, 2)).unwrap();
    // the sim KV cache holds 4096 positions; this prompt cannot fit
    let oversized = "x".repeat(5000);
    let r = eng
        .submit(&oversized, 8)
        .recv_timeout(TIMEOUT)
        .expect("failed request must still be answered");
    assert!(!r.is_ok());
    assert!(
        r.error.as_deref().unwrap_or("").contains("prompt too long"),
        "error should explain the failure: {:?}",
        r.error
    );

    // the engine keeps serving afterwards
    let ok = eng.submit("small follow-up request", MAX_NEW).recv_timeout(TIMEOUT).unwrap();
    assert!(ok.is_ok());
    let m = eng.metrics.lock().unwrap();
    assert_eq!(m.failed, 1);
    assert_eq!(m.completed, 1);
    drop(m);
    eng.shutdown();
}

#[test]
fn sjf_scheduling_serves_all_requests() {
    let mut cfg = sim_config(2, 2);
    cfg.sched = Policy::Sjf;
    let eng = Engine::start(cfg).unwrap();
    // mixed sizes so SJF actually reorders
    let rx_big = eng.submit(&"long prompt ".repeat(40), 96);
    let rxs: Vec<_> = (0..8).map(|i| eng.submit(&format!("tiny {i}"), 16)).collect();
    assert!(rx_big.recv_timeout(TIMEOUT).unwrap().is_ok());
    for r in collect(rxs) {
        assert!(r.is_ok());
    }
    assert_eq!(eng.metrics.lock().unwrap().completed, 9);
    eng.shutdown();
}
