//! The deterministic scheduler: executes a [`SimPlan`] event by event
//! against the engine's real components, checking the shadow oracle
//! after every event.
//!
//! Everything is single-threaded and virtually clocked, so a plan always
//! replays to the identical trace: the plan's RNG decides which ready
//! session runs next (workers mode) or every live session steps in
//! lockstep (continuous mode); fault-injected latency advances the fake
//! clock instead of sleeping; and trace lines embed only virtual time.
//!
//! Plans with `replicas > 1` run a simulated fleet: each replica owns
//! its own pool/scheduler/bandit (exactly what one live `Engine` owns)
//! and submits route through the *same* [`RouterCore`] policy the live
//! `tapout route` tier uses, so replica kills and drains are replayable
//! and shrinkable like every other fault. Single-replica plans take the
//! identical code path and keep their legacy traces byte-for-byte.
//!
//! The per-session decode is the Algorithm-1 round of `spec/session.rs`
//! ([`sim_round`] mirrors `SpecSession::step` — the session type itself
//! holds model borrows for its whole lifetime, which a round-interleaved
//! simulator cannot, so the round is restated here over explicit state
//! and kept in sync with the invariants both share: commit-or-nothing
//! verification, cursors ≤ committed length, `on_abort` on any error
//! between `session_start` and `on_verify`).

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::bandit::{DrafterHook, SessionController, SharedController, SharedDrafters};
use crate::engine::{
    CancelFlag, EmitClip, FinishStatus, Lease, ReplicaView, Request, RouterCore, Scheduler, Slot,
    SlotPool,
};
use crate::models::{
    sim_encode, FaultPlan, FaultStats, FaultyModel, LanguageModel, Scenario, SimModel,
};
use crate::spec::{
    accept_greedy, finish_check, validate_prompt, DecodeControl, GenConfig, MethodSpec,
    StepCommit, StepOutcome, BOS,
};
use crate::util::{fnv1a, Rng};

use super::clock::SimClock;
use super::oracle::Oracle;
use super::plan::{SimOp, SimPlan};

/// Virtual cost of one drafted token (fake-clock fuel per round).
const DRAFT_TOKEN_NS: u64 = 500;
/// Virtual cost of one verification block.
const VERIFY_NS: u64 = 2_000;
/// Virtual cost of an idle micro-step (nothing live to run).
const IDLE_NS: u64 = 1_000;
/// Micro-step budget for the post-plan drain: if the engine cannot reach
/// quiescence within this many steps, something is starved or livelocked.
const DRAIN_BUDGET: usize = 100_000;

/// First invariant violation of a run: the event index (into
/// [`SimReport::trace`]) where it was detected, plus a description.
#[derive(Clone, Debug, PartialEq)]
pub struct Violation {
    /// trace position at detection time
    pub event: usize,
    /// what broke
    pub what: String,
}

/// One request's terminal record.
#[derive(Clone, Debug, PartialEq)]
pub struct Reply {
    /// terminal lifecycle stage
    pub status: FinishStatus,
    /// clipped reply tokens emitted before the end
    pub emitted: Vec<u32>,
}

/// Everything one simulator run produced.
#[derive(Clone, Debug)]
pub struct SimReport {
    /// the full deterministic event trace (one line per event)
    pub trace: Vec<String>,
    /// first invariant violation, if any
    pub violation: Option<Violation>,
    /// req id → terminal record, for every request that reached an end
    pub replies: BTreeMap<u64, Reply>,
    /// virtual time at the end of the run (the critical path under
    /// pipelining; identical to the flat sum when `plan.pipeline` is off)
    pub clock_ns: u64,
    /// total virtual time the draft lane spent busy
    pub draft_busy_ns: u64,
    /// total virtual time the verify lane spent busy
    pub verify_busy_ns: u64,
    /// verify latency hidden behind overlapped draft work (0 serialized)
    pub overlap_ns: u64,
    /// speculative pre-drafts issued under an in-flight verify
    pub spec_attempted: u64,
    /// speculative pre-drafts adopted by the following round
    pub spec_adopted: u64,
    /// speculative pre-drafts discarded (partial acceptance or the
    /// session ended before the next round could consume them)
    pub spec_discarded: u64,
    /// FNV-1a hash of the trace (the replay-equality fingerprint)
    pub trace_hash: u64,
    /// tenant → modal drafter (argmax of per-tenant plays summed across
    /// replicas); empty for runs that never settled a drafter round
    pub drafter_modes: BTreeMap<String, usize>,
}

impl SimReport {
    /// Count of replies with the given terminal status.
    pub fn count(&self, status: FinishStatus) -> usize {
        self.replies.values().filter(|r| r.status == status).count()
    }
}

/// One live decode: a checked-out slot plus the explicit session state
/// [`sim_round`] advances.
struct Live {
    req: Request,
    slot: Slot,
    committed: Vec<u32>,
    prompt_len: usize,
    clip: EmitClip,
    emitted: Vec<u32>,
    rng: Rng,
    max_seq: usize,
    /// pipelined runs only: the previous round fully accepted, so the
    /// speculative pre-draft issued under its verify is adoptable — this
    /// round's draft lane hides one token under the verify shadow
    primed: bool,
    /// drafter-layer handle for this session's (tenant, seed, category)
    hook: DrafterHook,
}

/// Engine state for one simulated replica — exactly what one live
/// `Engine` owns: its slot pool, admission scheduler, shared bandit,
/// per-slot session controllers, live decodes and fault counters, plus
/// the router-visible lifecycle bits (alive / draining).
struct ReplicaSim {
    pool: SlotPool,
    sched: Scheduler,
    shared: SharedController,
    /// drafter-layer controller (pool-of-one and fully inert for legacy
    /// plans: no RNG, selection always 0, counters still conserved)
    drafters: Arc<SharedDrafters>,
    ctrls: Vec<SessionController>,
    live: Vec<Live>,
    fault_stats: Vec<Arc<FaultStats>>,
    alive: bool,
    draining: bool,
}

struct Runner {
    plan: SimPlan,
    replicas: Vec<ReplicaSim>,
    core: RouterCore,
    clock: SimClock,
    rng: Rng,
    oracle: Oracle,
    trace: Vec<String>,
    replies: BTreeMap<u64, Reply>,
    flags: BTreeMap<u64, CancelFlag>,
    deadlines: BTreeMap<u64, u64>,
    drained_delay_ns: u64,
    violation: Option<Violation>,
    sabotaged: bool,
    max_seq: usize,
    spec_attempted: u64,
    spec_adopted: u64,
    spec_discarded: u64,
}

/// Execute a plan to completion (all ops, then a drain phase until every
/// request reaches a terminal state) and report the trace, the replies
/// and the first oracle violation, if any.
pub fn run_plan(plan: &SimPlan) -> SimReport {
    run_plan_pinned(plan, None)
}

/// [`run_plan`] with the drafter-layer selection pinned to a fixed pool
/// index on every replica ([`SharedDrafters::set_pin`]) — the bench /
/// debugging entry point for fixed-single-drafter baselines. `None` is
/// exactly `run_plan`; out-of-range pins clamp to the last drafter.
pub fn run_plan_pinned(plan: &SimPlan, pin: Option<usize>) -> SimReport {
    let mut r = Runner::build(plan.clone(), pin);
    for i in 0..r.plan.ops.len() {
        if r.violation.is_some() {
            break;
        }
        let op = r.plan.ops[i].clone();
        r.apply(&op);
    }
    let mut spent = 0usize;
    while r.violation.is_none() && !r.quiescent() {
        if spent >= DRAIN_BUDGET {
            r.fail(format!(
                "quiescence not reached within {DRAIN_BUDGET} micro-steps: \
                 {} live, {} queued (scheduler starvation?)",
                r.replicas.iter().map(|x| x.live.len()).sum::<usize>(),
                r.replicas.iter().map(|x| x.sched.len()).sum::<usize>()
            ));
            break;
        }
        r.micro_step();
        spent += 1;
    }
    if r.violation.is_none() {
        // quiescence reached: every session ended, so every speculative
        // pre-draft must have resolved as adopted or discarded
        if let Some(what) =
            Oracle::check_spec_conservation(r.spec_attempted, r.spec_adopted, r.spec_discarded)
        {
            r.fail(what);
        }
    }
    let trace_hash = fnv1a(r.trace.iter().flat_map(|l| l.bytes().map(u64::from).chain([10u64])));
    // per-tenant modal drafter: plays summed across replicas, argmax by
    // lowest index on ties (mirrors the selector's own tie rule)
    let mut tenant_plays: BTreeMap<String, Vec<u64>> = BTreeMap::new();
    for rs in &r.replicas {
        for t in rs.drafters.tenant_snapshot() {
            let acc =
                tenant_plays.entry(t.tenant.clone()).or_insert_with(|| vec![0; t.plays.len()]);
            for (d, p) in t.plays.iter().enumerate() {
                if d < acc.len() {
                    acc[d] += p;
                }
            }
        }
    }
    let drafter_modes = tenant_plays
        .into_iter()
        .filter_map(|(tenant, plays)| {
            let best = (0..plays.len()).max_by_key(|&d| (plays[d], std::cmp::Reverse(d)))?;
            (plays[best] > 0).then_some((tenant, best))
        })
        .collect();
    SimReport {
        violation: r.violation,
        replies: r.replies,
        clock_ns: r.clock.now_ns(),
        draft_busy_ns: r.clock.draft_busy_ns(),
        verify_busy_ns: r.clock.verify_busy_ns(),
        overlap_ns: r.clock.overlap_ns(),
        spec_attempted: r.spec_attempted,
        spec_adopted: r.spec_adopted,
        spec_discarded: r.spec_discarded,
        trace_hash,
        drafter_modes,
        trace: r.trace,
    }
}

impl Runner {
    fn build(plan: SimPlan, pin: Option<usize>) -> Runner {
        let quality = 0.9f32;
        let rel_cost = 1.0 / 20.0;
        let sc = Scenario::new(0, "qa");
        let faults = FaultPlan::moderate(plan.seed, plan.max_faults);
        let n_replicas = plan.replicas.max(1);
        let mut max_seq = 4096usize;
        let replicas: Vec<ReplicaSim> = (0..n_replicas)
            .map(|rep| {
                let mut fault_stats = Vec::new();
                let pairs: Vec<(Box<dyn LanguageModel>, Box<dyn LanguageModel>)> = (0..plan.slots)
                    .map(|i| {
                        // fault streams fork by *global* slot index so
                        // replica 0 replays the legacy single-engine
                        // streams byte-for-byte
                        let slot = (rep * plan.slots + i) as u64;
                        let mut d = SimModel::draft(sc, quality, rel_cost);
                        if plan.drafters > 1 {
                            d = d.with_drafters(plan.drafters);
                        }
                        let t = SimModel::target(sc);
                        if plan.faults {
                            let fd = FaultyModel::new(Box::new(d), faults.fork(2 * slot));
                            let ft = FaultyModel::new(Box::new(t), faults.fork(2 * slot + 1));
                            fault_stats.push(fd.stats());
                            fault_stats.push(ft.stats());
                            (
                                Box::new(fd) as Box<dyn LanguageModel>,
                                Box::new(ft) as Box<dyn LanguageModel>,
                            )
                        } else {
                            (
                                Box::new(d) as Box<dyn LanguageModel>,
                                Box::new(t) as Box<dyn LanguageModel>,
                            )
                        }
                    })
                    .collect();
                max_seq = pairs
                    .iter()
                    .map(|(d, t)| d.max_seq().min(t.max_seq()))
                    .min()
                    .unwrap_or(4096);
                // mirror the engine's boot order (server.rs): paging,
                // sharing, then the prefix cache
                let pool = SlotPool::from_pairs(pairs)
                    .with_paging(plan.page_size.max(1), plan.kv_pages)
                    .with_page_sharing(plan.sharing)
                    .with_prefix_cache(plan.cache);
                let method =
                    MethodSpec::parse(&plan.method, "artifacts").expect("plan method parses");
                let shared = SharedController::new(&method, plan.gamma_max);
                let ctrls = (0..plan.slots)
                    .map(|_| shared.session().expect("sim methods need no artifacts"))
                    .collect();
                let drafters = SharedDrafters::new(plan.drafters);
                drafters.set_pin(pin);
                ReplicaSim {
                    pool,
                    sched: Scheduler::new(crate::engine::Policy::Fcfs),
                    shared,
                    drafters,
                    ctrls,
                    live: Vec::new(),
                    fault_stats,
                    alive: true,
                    draining: false,
                }
            })
            .collect();
        let seq_bandit = plan.method.starts_with("seq-");
        let mut rng = Rng::new(plan.seed).fork(0xD0_5EED);
        let oracle = Oracle::new(plan.faults, seq_bandit);
        let task_rng = rng.fork(1);
        let core = RouterCore::new(n_replicas, plan.page_size.max(1), plan.affinity);
        Runner {
            plan,
            replicas,
            core,
            clock: SimClock::new(),
            rng: task_rng,
            oracle,
            trace: Vec::new(),
            replies: BTreeMap::new(),
            flags: BTreeMap::new(),
            deadlines: BTreeMap::new(),
            drained_delay_ns: 0,
            violation: None,
            sabotaged: false,
            max_seq,
            spec_attempted: 0,
            spec_adopted: 0,
            spec_discarded: 0,
        }
    }

    /// Pipelined rounds apply in continuous mode only — the workers
    /// interleave has no cross-session verify to overlap, so the flag is
    /// a documented no-op there (identical traces either way).
    fn pipelined(&self) -> bool {
        self.plan.pipeline && self.plan.mode == "continuous"
    }

    /// Every replica idle and every queue empty?
    fn quiescent(&self) -> bool {
        self.replicas.iter().all(|r| r.live.is_empty() && r.sched.is_empty())
    }

    /// Replica tag appended to trace lines — empty in single-replica
    /// runs so legacy traces (and their hashes) stay byte-identical.
    fn rtag(&self, rep: usize) -> String {
        if self.replicas.len() > 1 {
            format!(" replica={rep}")
        } else {
            String::new()
        }
    }

    /// Route one request through the shared [`RouterCore`] policy using
    /// each replica's live scheduler state as its probed view.
    fn route_of(&self, req: &Request) -> Option<usize> {
        let views: Vec<ReplicaView> = self
            .replicas
            .iter()
            .map(|r| ReplicaView {
                alive: r.alive,
                draining: r.draining,
                queue_wait: r.sched.queue_wait_estimate(self.plan.workers),
            })
            .collect();
        self.core.route(&req.prompt_text, &views).map(|d| d.replica)
    }

    fn log(&mut self, line: String) {
        self.trace.push(format!("t={} {line}", self.clock.now_ns()));
    }

    fn fail(&mut self, what: String) {
        if self.violation.is_none() {
            let event = self.trace.len();
            self.trace.push(format!("t={} VIOLATION {what}", self.clock.now_ns()));
            self.violation = Some(Violation { event, what });
        }
    }

    /// Run the engine-wide oracle checks on every replica (dead ones
    /// included — a kill must leave conserved state behind); record the
    /// first violation.
    fn check_engine(&mut self) {
        if self.violation.is_some() {
            return;
        }
        for rep in 0..self.replicas.len() {
            let rs = &self.replicas[rep];
            if let Some(what) = self.oracle.check_engine(
                &rs.pool,
                &rs.sched,
                rs.live.len(),
                &rs.shared,
                &rs.drafters,
            ) {
                if self.replicas.len() > 1 {
                    self.fail(format!("replica {rep}: {what}"));
                } else {
                    self.fail(what);
                }
                return;
            }
        }
    }

    fn apply(&mut self, op: &SimOp) {
        match op {
            SimOp::Submit { req, prompt, category, max_new, deadline_ns } => {
                let mut r = Request::new(*req, prompt.clone(), *max_new);
                r.category = category.clone();
                r.prompt = std::iter::once(BOS).chain(sim_encode(prompt)).collect();
                // tenants > 1 shards submits round-robin onto t0..t{n-1};
                // the default keeps the legacy global ("") tenant so every
                // checked-in trace replays byte-for-byte
                if self.plan.tenants > 1 {
                    r.tenant = format!("t{}", *req % self.plan.tenants as u64);
                }
                self.flags.insert(*req, r.cancel_flag());
                if let Some(d) = deadline_ns {
                    self.deadlines.insert(*req, self.clock.now_ns() + d);
                }
                self.oracle.expect_request(
                    *req,
                    &r.prompt,
                    r.scenario_seed(),
                    category,
                    *max_new,
                    self.plan.gamma_max,
                    self.max_seq,
                );
                match self.route_of(&r) {
                    None => {
                        self.log(format!(
                            "submit id={req} len={} cat={category} max_new={max_new} \
                             rejected (no routable replica)",
                            r.prompt.len(),
                        ));
                        let why = "no routable replica";
                        self.finish_queued(0, r, FinishStatus::Rejected, why, false);
                    }
                    Some(dest) => {
                        r.cached_hint = self.replicas[dest].pool.peek_reuse(&r.prompt);
                        self.log(format!(
                            "submit id={req} len={} cat={category} max_new={max_new} hint={} deadline={}{}",
                            r.prompt.len(),
                            r.cached_hint,
                            deadline_ns.map(|d| d.to_string()).unwrap_or_else(|| "-".into()),
                            self.rtag(dest),
                        ));
                        self.replicas[dest].sched.push(r);
                    }
                }
            }
            SimOp::Cancel { req } => {
                let known = self.flags.contains_key(req);
                if let Some(f) = self.flags.get(req) {
                    f.cancel();
                }
                self.log(format!("cancel id={req} known={known}"));
            }
            SimOp::Disconnect { req } => {
                // the HTTP layer turns a dropped stream into a cancel —
                // same engine-visible effect, distinct trace label
                let known = self.flags.contains_key(req);
                if let Some(f) = self.flags.get(req) {
                    f.cancel();
                }
                self.log(format!("disconnect id={req} known={known}"));
            }
            SimOp::Step { n } => {
                for _ in 0..*n {
                    if self.violation.is_some() {
                        return;
                    }
                    self.micro_step();
                }
            }
            SimOp::KillReplica { replica } => self.kill_replica(*replica),
            SimOp::DrainReplica { replica } => {
                let r = *replica;
                match self.replicas.get_mut(r) {
                    Some(rs) => {
                        rs.draining = true;
                        self.log(format!("drain replica={r}"));
                    }
                    None => self.log(format!("drain replica={r} (no-op: unknown)")),
                }
            }
        }
        self.check_engine();
    }

    /// Take a replica down: every live decode on it fails (the live
    /// router answers their streams with a `Failed` terminal), its
    /// queued work re-routes through the surviving replicas, and it
    /// never admits again. Idempotent on an already-dead replica.
    fn kill_replica(&mut self, r: usize) {
        if r >= self.replicas.len() || !self.replicas[r].alive {
            self.log(format!("kill replica={r} (no-op)"));
            return;
        }
        self.replicas[r].alive = false;
        self.log(format!(
            "kill replica={r} failing={} rerouting={}",
            self.replicas[r].live.len(),
            self.replicas[r].sched.len()
        ));
        while !self.replicas[r].live.is_empty() {
            let id = self.replicas[r].live[0].req.id;
            self.oracle.note_killed(id);
            self.finish_live(r, 0, FinishStatus::Failed, "replica killed");
        }
        let mut queued = Vec::new();
        while let Some(req) = self.replicas[r].sched.pop() {
            self.replicas[r].sched.note_done(req.sched_cost());
            queued.push(req);
        }
        for mut req in queued {
            match self.route_of(&req) {
                Some(dest) => {
                    req.cached_hint = self.replicas[dest].pool.peek_reuse(&req.prompt);
                    self.log(format!("reroute id={} replica={dest}", req.id));
                    self.replicas[dest].sched.push(req);
                }
                None => {
                    self.finish_queued(0, req, FinishStatus::Rejected, "no routable replica", false)
                }
            }
        }
    }

    /// One deterministic scheduler tick: reap dead queue entries, admit
    /// while capacity allows, run one (workers) or all (continuous)
    /// ready sessions for one round, bank fault latency into the clock,
    /// then run the oracle.
    fn micro_step(&mut self) {
        for rep in 0..self.replicas.len() {
            if !self.replicas[rep].alive {
                continue;
            }
            for r in self.replicas[rep].sched.drain_dead() {
                let status = if r.cancel.is_cancelled() {
                    FinishStatus::Cancelled
                } else {
                    FinishStatus::Expired
                };
                self.finish_queued(rep, r, status, "reaped in queue", false);
            }
            self.admit(rep);
        }
        if self.replicas.iter().all(|r| r.live.is_empty()) {
            self.clock.advance(IDLE_NS);
        } else {
            for rep in 0..self.replicas.len() {
                if self.violation.is_some() {
                    break;
                }
                if self.replicas[rep].live.is_empty() {
                    continue;
                }
                if self.plan.mode == "continuous" {
                    // lockstep: every live session advances one round per
                    // tick, the iteration-level interleave of the
                    // continuous engine
                    let mut i = 0;
                    while i < self.replicas[rep].live.len() && self.violation.is_none() {
                        if self.run_one(rep, i) {
                            i += 1;
                        }
                    }
                } else {
                    // workers interleave: the seeded RNG picks which
                    // ready session runs next
                    let i = self.rng.below(self.replicas[rep].live.len());
                    self.run_one(rep, i);
                }
            }
        }
        let injected: u64 = self
            .replicas
            .iter()
            .flat_map(|r| r.fault_stats.iter())
            .map(|s| s.delay_ns.load(std::sync::atomic::Ordering::Relaxed))
            .sum();
        self.clock.advance(injected - self.drained_delay_ns);
        self.drained_delay_ns = injected;
        self.check_engine();
    }

    /// Admission on one replica: pop while a slot and a concurrency seat
    /// are free. Draining replicas still admit — their queue was
    /// accepted before the drain; only the *router* stops feeding them.
    fn admit(&mut self, rep: usize) {
        let cap = if self.plan.mode == "continuous" {
            self.plan.slots
        } else {
            self.plan.workers.min(self.plan.slots)
        };
        while self.replicas[rep].live.len() < cap && self.violation.is_none() {
            if self.replicas[rep].pool.available() == 0 {
                return;
            }
            let req = match self.replicas[rep].sched.pop() {
                Some(r) => r,
                None => return,
            };
            if req.cancel.is_cancelled() {
                let why = "cancelled at admission";
                self.finish_queued(rep, req, FinishStatus::Cancelled, why, true);
                continue;
            }
            if self.deadline_passed(req.id) {
                self.finish_queued(rep, req, FinishStatus::Expired, "expired at admission", true);
                continue;
            }
            if let Err(e) = validate_prompt(&req.prompt, self.max_seq) {
                self.finish_queued(rep, req, FinishStatus::Failed, &format!("{e}"), true);
                continue;
            }
            let (slot, lease) = match self.replicas[rep].pool.try_acquire_for(&req.prompt) {
                Some(x) => x,
                None => {
                    // free count raced with paging pressure: requeue and
                    // keep the ledger balanced
                    self.replicas[rep].sched.note_done(req.sched_cost());
                    self.replicas[rep].sched.push(req);
                    return;
                }
            };
            self.start_decode(rep, req, slot, lease);
            if self.plan.sabotage && !self.sabotaged {
                self.sabotaged = true;
                self.replicas[rep].pool.with_pages_mut(|p| p.debug_leak_page());
                self.log("sabotage: leaked one page from the free-list accounting".to_string());
            }
        }
    }

    /// Checkout → adopt leased residency → resume-style guards → live.
    /// Mirrors the worker path (server.rs): residency is the min of what
    /// draft and target actually adopted, and a model that cannot cover
    /// the claimed prefix is a Failed decode, never a wrong one.
    fn start_decode(&mut self, rep: usize, req: Request, mut slot: Slot, lease: Lease) {
        let seed = req.scenario_seed();
        let rd = slot.draft.adopt_pages(seed, &req.category, lease.local, lease.shared);
        let rt = slot.target.adopt_pages(seed, &req.category, lease.local, lease.shared);
        let resident = rd.min(rt).min(req.prompt.len().saturating_sub(1));
        slot.draft.rollback(resident);
        slot.target.rollback(resident);
        if slot.draft.cur() != resident || slot.target.cur() != resident {
            slot.clear_prefix();
            let why = format!(
                "resident-prefix contract violated: draft {} / target {} vs {resident}",
                slot.draft.cur(),
                slot.target.cur()
            );
            self.replicas[rep].pool.release(slot);
            self.finish_queued(rep, req, FinishStatus::Failed, &why, true);
            return;
        }
        self.replicas[rep].ctrls[slot.id].reset_request();
        let max_seq = slot.draft.max_seq().min(slot.target.max_seq());
        let rng = Rng::new(self.plan.seed).fork(0xAC71F ^ req.id);
        self.log(format!(
            "admit id={} slot={} lease={}/{} resident={resident}{}",
            req.id,
            slot.id,
            lease.local,
            lease.shared,
            self.rtag(rep)
        ));
        let hook = DrafterHook::new(
            self.replicas[rep].drafters.clone(),
            req.tenant.clone(),
            seed,
            req.category.clone(),
        );
        self.replicas[rep].live.push(Live {
            committed: req.prompt.clone(),
            prompt_len: req.prompt.len(),
            clip: EmitClip::new(req.max_new),
            emitted: Vec::new(),
            rng,
            max_seq,
            primed: false,
            hook,
            req,
            slot,
        });
    }

    fn deadline_passed(&self, id: u64) -> bool {
        self.deadlines.get(&id).is_some_and(|&d| self.clock.now_ns() >= d)
    }

    /// Advance session `i` by one lifecycle check + decode round.
    /// Returns false when the session reached a terminal state (and was
    /// removed from the live set).
    fn run_one(&mut self, rep: usize, i: usize) -> bool {
        if self.replicas[rep].live[i].req.cancel.is_cancelled() {
            self.finish_live(rep, i, FinishStatus::Cancelled, "cancelled mid-decode");
            return false;
        }
        if self.deadline_passed(self.replicas[rep].live[i].req.id) {
            self.finish_live(rep, i, FinishStatus::Expired, "deadline mid-decode");
            return false;
        }
        let gamma_max = self.plan.gamma_max;
        let outcome = {
            let ReplicaSim { live, ctrls, .. } = &mut self.replicas[rep];
            let sess = &mut live[i];
            let ctrl = &mut ctrls[sess.slot.id];
            sim_round(
                sess.slot.draft.as_mut(),
                sess.slot.target.as_mut(),
                ctrl,
                &mut sess.rng,
                &mut sess.committed,
                sess.prompt_len,
                sess.req.max_new,
                gamma_max,
                sess.max_seq,
                Some(&mut sess.hook),
            )
        };
        match outcome {
            Err(e) => {
                self.finish_live(rep, i, FinishStatus::Failed, &format!("{e:#}"));
                false
            }
            Ok(StepOutcome::Finished(reason)) => {
                self.finish_live(rep, i, FinishStatus::Done, &format!("{reason:?}"));
                false
            }
            Ok(StepOutcome::Round(commit)) => {
                // two-lane round accounting (docs/ARCHITECTURE.md §16):
                // the draft lane works one token per drafted position, the
                // verify lane one block. Serialized, nothing overlaps and
                // the wall clock advances by the flat sum — byte-identical
                // to the legacy single-lane advance, so every checked-in
                // fixture replays unchanged. Pipelined, a round whose
                // predecessor fully accepted adopts the pre-draft issued
                // under that verify: one draft token rode in the verify
                // shadow, so the critical path shortens by its cost.
                let draft_ns = DRAFT_TOKEN_NS * commit.drafted as u64;
                let mut overlap = 0;
                if self.pipelined() {
                    if self.replicas[rep].live[i].primed {
                        overlap = DRAFT_TOKEN_NS.min(draft_ns);
                        self.spec_adopted += 1;
                    }
                    // a fresh speculation is issued under this round's
                    // verify; it is dead on arrival unless every proposal
                    // was accepted (the pre-drafted position only exists
                    // in the committed stream on full acceptance)
                    self.spec_attempted += 1;
                    let primed = commit.accepted == commit.drafted;
                    self.replicas[rep].live[i].primed = primed;
                    if !primed {
                        self.spec_discarded += 1;
                    }
                }
                self.clock.advance_round(draft_ns, VERIFY_NS, overlap);
                let (emit, determined) = {
                    let sess = &mut self.replicas[rep].live[i];
                    let (emit, determined) = sess.clip.clip(&commit.new_tokens);
                    sess.emitted.extend_from_slice(emit);
                    (emit.len(), determined)
                };
                let (id, drafted, accepted) =
                    (self.replicas[rep].live[i].req.id, commit.drafted, commit.accepted);
                // drafter tag only when a pool is configured, so legacy
                // single-drafter traces (and their hashes) never move
                let dtag = if self.plan.drafters > 1 {
                    format!(" drafter={}", self.replicas[rep].live[i].hook.drafter())
                } else {
                    String::new()
                };
                self.log(format!(
                    "round id={id} drafted={drafted} accepted={accepted} emitted={emit}{dtag}"
                ));
                if let Some(what) = self.oracle.check_stream(id, &self.replicas[rep].live[i].emitted)
                {
                    self.fail(what);
                    return true;
                }
                if determined {
                    // reply fully determined (budget or EOS inside the
                    // clip window) — same early stop as drive_session
                    self.finish_live(rep, i, FinishStatus::Done, "reply determined");
                    return false;
                }
                true
            }
        }
    }

    /// Terminal handling for a live session: prefix-cache bookkeeping,
    /// slot release, scheduler ledger release, oracle terminal check.
    fn finish_live(&mut self, rep: usize, i: usize, status: FinishStatus, why: &str) {
        let mut sess = self.replicas[rep].live.swap_remove(i);
        if sess.primed {
            // the session ends with an adoptable pre-draft outstanding —
            // nobody will consume it, so it resolves as discarded (the
            // conservation the oracle checks at end of run)
            self.spec_discarded += 1;
        }
        if self.replicas[rep].pool.prefix_cache_enabled() {
            let watermark = sess.slot.draft.cur().min(sess.slot.target.cur());
            if status == FinishStatus::Failed {
                sess.slot.clear_prefix();
            } else {
                let tokens = sess.committed.clone();
                sess.slot.record_prefix(&tokens, watermark);
            }
        }
        self.replicas[rep].pool.release(sess.slot);
        self.replicas[rep].sched.note_done(sess.req.sched_cost());
        self.log(format!(
            "end id={} status={} emitted={} ({why})",
            sess.req.id,
            status.label(),
            sess.emitted.len()
        ));
        if let Some(what) = self.oracle.check_terminal(sess.req.id, status, &sess.emitted) {
            self.fail(what);
        }
        self.replies.insert(sess.req.id, Reply { status, emitted: sess.emitted });
    }

    /// Terminal handling for a request that never started decoding.
    /// `popped` says whether it went through `Scheduler::pop` (and thus
    /// holds an in-flight ledger seat to release).
    fn finish_queued(
        &mut self,
        rep: usize,
        req: Request,
        status: FinishStatus,
        why: &str,
        popped: bool,
    ) {
        if popped {
            self.replicas[rep].sched.note_done(req.sched_cost());
        }
        self.log(format!("end id={} status={} emitted=0 ({why})", req.id, status.label()));
        if let Some(what) = self.oracle.check_terminal(req.id, status, &[]) {
            self.fail(what);
        }
        self.replies.insert(req.id, Reply { status, emitted: Vec::new() });
    }
}

/// One draft→verify→accept round over explicit session state — the
/// simulator's restatement of `SpecSession::step` (see the module docs
/// for why the session type itself cannot be held across interleaved
/// rounds). Invariants kept in lockstep with spec/session.rs:
///
/// * models only ever receive contiguous blocks at their cursor;
/// * verification is atomic — a round either commits fully or not at
///   all, so an `Err` leaves `committed` untouched;
/// * a model error between `session_start` and `on_verify` routes
///   through [`DecodeControl::on_abort`], keeping bandit play counts
///   conserved;
/// * the drafter layer (when a `hook` is supplied) plays at exactly the
///   policy bandit's cadence — one `begin_round` before `session_start`,
///   one settle after `on_verify` / `on_abort` — so rounds == policy
///   plays == drafter plays holds per layer;
/// * termination uses the shared [`finish_check`] / [`accept_greedy`]
///   helpers, so the stop boundary and accept rule *cannot* drift.
#[allow(clippy::too_many_arguments)]
pub fn sim_round(
    draft: &mut dyn LanguageModel,
    target: &mut dyn LanguageModel,
    ctrl: &mut dyn DecodeControl,
    rng: &mut Rng,
    committed: &mut Vec<u32>,
    prompt_len: usize,
    max_new: usize,
    gamma_max: usize,
    max_seq: usize,
    mut hook: Option<&mut DrafterHook>,
) -> anyhow::Result<StepOutcome> {
    let cfg = GenConfig { max_new, gamma_max, stop_at_eos: true, collect_signals: false };
    let last = committed.last().copied();
    if let Some(r) = finish_check(committed.len(), prompt_len, last, &cfg, max_seq) {
        return Ok(StepOutcome::Finished(r));
    }
    let c = committed.len();
    let gamma = gamma_max.min(max_seq.saturating_sub(c + 2)).max(1);
    if let Some(h) = hook.as_deref_mut() {
        let d = h.begin_round();
        draft.set_drafter(d);
        ctrl.set_context(h.tenant(), d);
    }
    ctrl.session_start(rng);
    let fallible = |draft: &mut dyn LanguageModel,
                    target: &mut dyn LanguageModel,
                    ctrl: &mut dyn DecodeControl,
                    rng: &mut Rng|
     -> anyhow::Result<(Vec<u32>, Vec<crate::signals::TokenSignals>, usize)> {
        let dc = draft.cur();
        let mut sig = draft.block(&committed[dc..], dc)?;
        let mut proposals: Vec<u32> = Vec::with_capacity(gamma);
        loop {
            let last = *sig.last().expect("block returns >=1 row");
            proposals.push(last.argmax);
            let idx = proposals.len() - 1;
            if proposals.len() >= gamma || ctrl.should_stop(&last, idx, rng) {
                break;
            }
            sig = draft.block(&[last.argmax], c + proposals.len() - 1)?;
        }
        let tc = target.cur();
        let mut inputs: Vec<u32> = committed[tc..].to_vec();
        inputs.extend_from_slice(&proposals);
        let vsig = target.block(&inputs, tc)?;
        Ok((proposals, vsig, tc))
    };
    let (proposals, vsig, tc) = match fallible(draft, target, ctrl, rng) {
        Ok(x) => x,
        Err(e) => {
            ctrl.on_abort();
            if let Some(h) = hook.as_deref() {
                h.settle_abort();
            }
            return Err(e);
        }
    };
    let (m, bonus) = accept_greedy(&vsig, tc, c, &proposals);
    committed.extend_from_slice(&proposals[..m]);
    committed.push(bonus);
    target.rollback(c + m);
    draft.rollback(c + m);
    ctrl.on_verify(m, proposals.len());
    if let Some(h) = hook.as_deref() {
        // full information: score every pooled drafter against the tokens
        // this verify committed (bonus included); rewards never touch the
        // emitted stream
        let scores = draft.score_drafters(h.seed(), h.category(), &committed[c..], c);
        h.settle_verify(&scores);
    }
    Ok(StepOutcome::Round(StepCommit {
        new_tokens: committed[c..].to_vec(),
        drafted: proposals.len(),
        accepted: m,
        arm: ctrl.current_arm(),
    }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::sim_pair;
    use crate::spec::{generate, StopController};

    /// The restated round must decode byte-identically to the canonical
    /// `SpecSession` loop — the sync contract in the `sim_round` docs.
    #[test]
    fn sim_round_matches_spec_session() {
        for seed in [1u64, 9, 77] {
            let prompt: Vec<u32> = [BOS, 5, 9, 4, 8, 11].to_vec();
            let cfg = GenConfig { max_new: 24, gamma_max: 5, ..GenConfig::default() };
            let (mut d, mut t) = sim_pair(seed, "qa", 0.9);
            let mut ctrl = StopController::always_continue();
            let mut rng = Rng::new(0);
            let want = generate(&mut d, &mut t, &mut ctrl, &mut rng, &prompt, &cfg).unwrap();

            let (mut d, mut t) = sim_pair(seed, "qa", 0.9);
            d.reset();
            t.reset();
            let mut ctrl = StopController::always_continue();
            let mut rng = Rng::new(0);
            let mut committed = prompt.clone();
            loop {
                let out = sim_round(
                    &mut d,
                    &mut t,
                    &mut ctrl,
                    &mut rng,
                    &mut committed,
                    prompt.len(),
                    24,
                    5,
                    4096,
                    None,
                )
                .unwrap();
                if matches!(out, StepOutcome::Finished(_)) {
                    break;
                }
            }
            assert_eq!(committed, want.tokens, "seed {seed}");
        }
    }

    #[test]
    fn trivial_plan_runs_clean_and_deterministically() {
        let plan = SimPlan {
            seed: 3,
            mode: "workers".into(),
            slots: 2,
            workers: 2,
            gamma_max: 4,
            method: "seq-ucb1".into(),
            cache: true,
            sharing: true,
            page_size: 8,
            kv_pages: 0,
            faults: false,
            max_faults: 0,
            sabotage: false,
            replicas: 1,
            affinity: true,
            pipeline: false,
            drafters: 1,
            tenants: 1,
            ops: vec![
                SimOp::Submit {
                    req: 0,
                    prompt: "hello world".into(),
                    category: "qa".into(),
                    max_new: 6,
                    deadline_ns: None,
                },
                SimOp::Step { n: 3 },
                SimOp::Submit {
                    req: 1,
                    prompt: "hello world again".into(),
                    category: "qa".into(),
                    max_new: 5,
                    deadline_ns: None,
                },
            ],
        };
        let a = run_plan(&plan);
        let b = run_plan(&plan);
        assert_eq!(a.violation, None, "trace:\n{}", a.trace.join("\n"));
        assert_eq!(a.trace, b.trace, "same plan ⇒ identical trace");
        assert_eq!(a.trace_hash, b.trace_hash);
        assert_eq!(a.count(FinishStatus::Done), 2);
    }

    fn fleet_plan(replicas: usize, ops: Vec<SimOp>) -> SimPlan {
        SimPlan {
            seed: 9,
            mode: "workers".into(),
            slots: 1,
            workers: 1,
            gamma_max: 4,
            method: "static-4".into(),
            cache: true,
            sharing: true,
            page_size: 16,
            kv_pages: 0,
            faults: false,
            max_faults: 0,
            sabotage: false,
            replicas,
            affinity: true,
            pipeline: false,
            drafters: 1,
            tenants: 1,
            ops,
        }
    }

    fn fleet_submit(req: u64, prompt: &str) -> SimOp {
        SimOp::Submit {
            req,
            prompt: prompt.into(),
            category: "qa".into(),
            max_new: 4,
            deadline_ns: None,
        }
    }

    #[test]
    fn replica_kill_fails_live_work_and_reroutes_the_queue() {
        let plan = fleet_plan(
            2,
            vec![
                fleet_submit(0, "alpha prompt one"),
                fleet_submit(1, "bravo prompt two"),
                fleet_submit(2, "charlie prompt three"),
                fleet_submit(3, "delta prompt four"),
                SimOp::Step { n: 2 },
                SimOp::KillReplica { replica: 0 },
                SimOp::Step { n: 4 },
            ],
        );
        let a = run_plan(&plan);
        assert_eq!(a.violation, None, "trace:\n{}", a.trace.join("\n"));
        assert_eq!(a.replies.len(), 4, "every request reached a terminal state");
        for (id, reply) in &a.replies {
            assert!(
                matches!(reply.status, FinishStatus::Done | FinishStatus::Failed),
                "req {id} ended {:?}",
                reply.status
            );
        }
        assert_eq!(run_plan(&plan).trace_hash, a.trace_hash, "kill plans replay");
    }

    #[test]
    fn draining_every_replica_rejects_new_submits() {
        let plan = fleet_plan(
            2,
            vec![
                SimOp::DrainReplica { replica: 0 },
                SimOp::DrainReplica { replica: 1 },
                fleet_submit(0, "late arrival"),
                SimOp::Step { n: 2 },
            ],
        );
        let a = run_plan(&plan);
        assert_eq!(a.violation, None, "trace:\n{}", a.trace.join("\n"));
        assert_eq!(a.replies[&0].status, FinishStatus::Rejected, "no routable replica");
    }

    #[test]
    fn pipelined_runs_keep_replies_and_shorten_the_clock() {
        let mut saw_adopted = false;
        for seed in [0u64, 5, 11, 23] {
            let mut plan = SimPlan::generate(seed, 60);
            plan.mode = "continuous".into();
            // strip deadlines: a deadline race is a function of virtual
            // *time*, and compressing the critical path is exactly the
            // point of the pipeline — with deadlines present the two runs
            // would legitimately diverge, which is not what this test
            // pins (the bench gate compares deadline-free plans too)
            for op in &mut plan.ops {
                if let SimOp::Submit { deadline_ns, .. } = op {
                    *deadline_ns = None;
                }
            }
            let base = run_plan(&plan);
            assert_eq!(base.violation, None, "seed {seed}:\n{}", base.trace.join("\n"));
            assert_eq!(base.overlap_ns, 0, "serialized runs hide nothing");
            assert!(base.draft_busy_ns > 0 && base.verify_busy_ns > 0, "lanes saw work");

            let mut piped = plan.clone();
            piped.pipeline = true;
            let p = run_plan(&piped);
            assert_eq!(p.violation, None, "seed {seed}:\n{}", p.trace.join("\n"));
            // lossless: every request ends in the identical terminal
            // state with the identical emitted tokens
            assert_eq!(p.replies, base.replies, "seed {seed}: outputs must not move");
            // conservation: every speculation resolved exactly once
            assert_eq!(p.spec_attempted, p.spec_adopted + p.spec_discarded, "seed {seed}");
            // critical path: the hidden time is exactly the clock saving
            assert_eq!(p.overlap_ns, base.clock_ns - p.clock_ns, "seed {seed}");
            if p.spec_adopted > 0 {
                saw_adopted = true;
                assert!(p.clock_ns < base.clock_ns, "seed {seed}: adopted rounds hide time");
            }
        }
        assert!(saw_adopted, "at least one seed exercises adoption");
    }

    #[test]
    fn pipeline_flag_is_a_noop_in_workers_mode() {
        let mut plan = SimPlan::generate(7, 50);
        plan.mode = "workers".into();
        let base = run_plan(&plan);
        let mut piped = plan.clone();
        piped.pipeline = true;
        let p = run_plan(&piped);
        assert_eq!(p.trace_hash, base.trace_hash, "workers traces are byte-identical");
        assert_eq!(p.spec_attempted, 0);
        assert_eq!(p.overlap_ns, 0);
    }

    #[test]
    fn multi_drafter_multi_tenant_plans_run_clean_and_replay() {
        for seed in [0u64, 4, 9] {
            let mut plan = SimPlan::generate(seed, 50);
            plan.drafters = 3;
            plan.tenants = 2;
            let a = run_plan(&plan);
            assert_eq!(a.violation, None, "seed {seed} trace:\n{}", a.trace.join("\n"));
            assert_eq!(run_plan(&plan).trace_hash, a.trace_hash, "seed {seed}");
            // pooled rounds tag the chosen drafter so regressions pin it
            assert!(
                a.trace.iter().any(|l| l.contains(" drafter=")),
                "seed {seed}: pooled rounds carry the drafter tag"
            );
        }
    }

    #[test]
    fn pool_of_one_plans_keep_legacy_traces_byte_identical() {
        // the drafter layer is live (begin/settle every round) but a pool
        // of one must not perturb a single trace byte vs the same plan
        // before the layer existed: no RNG draws, no extra trace lines
        for seed in [2u64, 13] {
            let plan = SimPlan::generate(seed, 40);
            assert_eq!(plan.drafters, 1, "generator never randomizes the pool");
            assert_eq!(plan.tenants, 1);
            let a = run_plan(&plan);
            assert_eq!(a.violation, None, "seed {seed}");
            assert!(
                a.trace.iter().all(|l| !l.contains("drafter=")),
                "seed {seed}: legacy traces carry no drafter tag"
            );
        }
    }

    #[test]
    fn multi_drafter_fault_plans_conserve_both_layers() {
        // faults force abort paths; the oracle (run after every event)
        // asserts begin == settle and per-tenant == global on each one
        let mut found = 0;
        for seed in 0..12u64 {
            let mut plan = SimPlan::generate(seed, 60);
            plan.faults = true;
            plan.max_faults = 4;
            plan.drafters = 2;
            plan.tenants = 2;
            let a = run_plan(&plan);
            assert_eq!(a.violation, None, "seed {seed} trace:\n{}", a.trace.join("\n"));
            if a.count(FinishStatus::Failed) > 0 {
                found += 1;
            }
        }
        assert!(found > 0, "at least one seed exercised a fault-aborted round");
    }

    #[test]
    fn pinned_runs_select_only_the_pin_and_stay_lossless() {
        // deadlines resolve against absolute virtual time, which a pin
        // legitimately shifts — strip them so reply comparison is
        // meaningful (same contract as the pipeline bench)
        let mut plan = SimPlan::generate(0, 50);
        plan.drafters = 3;
        plan.tenants = 2;
        for op in &mut plan.ops {
            if let SimOp::Submit { deadline_ns, .. } = op {
                *deadline_ns = None;
            }
        }
        let pinned = run_plan_pinned(&plan, Some(2));
        assert_eq!(pinned.violation, None, "trace:\n{}", pinned.trace.join("\n"));
        assert!(!pinned.drafter_modes.is_empty(), "pinned rounds still ledger plays");
        for d in pinned.drafter_modes.values() {
            assert_eq!(*d, 2, "a pinned run may only ever play the pin");
        }
        // selection routes work, never bytes: decodes completed under
        // both runs are byte-identical. (Cancel/deadline races resolve
        // against round progress, which a pin legitimately shifts, so
        // terminal *statuses* may differ — byte-equality of completed
        // output is the invariant.)
        let free = run_plan(&plan);
        assert_eq!(free.violation, None);
        assert_eq!(pinned.replies.len(), free.replies.len(), "every request still terminates");
        let mut compared = 0;
        for (req, a) in &pinned.replies {
            let b = &free.replies[req];
            if a.status == FinishStatus::Done && b.status == FinishStatus::Done {
                assert_eq!(a.emitted, b.emitted, "req {req}: pin moved an output byte");
                compared += 1;
            }
        }
        assert!(compared > 0, "the plan must complete at least one decode both ways");
    }

    #[test]
    fn generated_fleet_plans_replay_deterministically() {
        for seed in 0..6u64 {
            let plan = SimPlan::generate_fleet(seed, 60, 3);
            let a = run_plan(&plan);
            assert_eq!(a.violation, None, "seed {seed} trace:\n{}", a.trace.join("\n"));
            assert_eq!(run_plan(&plan).trace_hash, a.trace_hash, "seed {seed}");
        }
    }
}
