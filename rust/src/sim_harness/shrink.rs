//! Trace shrinking: greedy op-deletion to a minimal violating plan.
//!
//! When a seeded plan trips the oracle, the raw op list is usually far
//! larger than the failure needs. The shrinker repeatedly re-runs the
//! plan with one op deleted at a time, keeping any deletion that still
//! violates, until no single deletion preserves the failure — a
//! 1-minimal trace. Deleting an op always leaves a well-formed plan
//! (plan.rs: request ids are explicit, so a cancel aimed at a deleted
//! submit is just a no-op), which is what makes this safe.
//!
//! The result is what lands in `rust/tests/sim_regressions/` as a
//! replayable fixture: small enough to read, byte-stable under
//! [`SimPlan::to_json`], and still reproducing the original violation
//! class via [`run_plan`].

use super::plan::SimPlan;
use super::runner::run_plan;

/// Shrink a violating plan by greedy op-deletion. Returns the 1-minimal
/// plan (possibly the input itself) — or the input unchanged if it does
/// not actually violate. Each pass walks the op list front to back; the
/// loop re-passes until a fixed point, bounded by the op count.
pub fn shrink(plan: &SimPlan) -> SimPlan {
    if run_plan(plan).violation.is_none() {
        return plan.clone();
    }
    let mut best = plan.clone();
    loop {
        let mut shrunk = false;
        let mut i = 0;
        while i < best.ops.len() {
            let mut candidate = best.clone();
            candidate.ops.remove(i);
            if run_plan(&candidate).violation.is_some() {
                best = candidate;
                shrunk = true;
                // the op now at index i is new — retry the same index
            } else {
                i += 1;
            }
        }
        if !shrunk {
            return best;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A sabotage plan (deliberate page-accounting leak behind the
    /// test-only hook) must be caught by the oracle and shrink to a
    /// hand-checkable trace: the violation needs exactly one admitted
    /// request, so 1-minimality means a single-digit op count.
    #[test]
    fn sabotaged_plan_shrinks_to_minimal_trace() {
        let mut plan = SimPlan::generate(5, 40);
        plan.sabotage = true;
        plan.faults = false;
        let report = run_plan(&plan);
        assert!(report.violation.is_some(), "sabotage must be caught");
        let min = shrink(&plan);
        let r = run_plan(&min);
        assert!(r.violation.is_some(), "shrunk plan still violates");
        assert!(
            min.ops.len() <= 20,
            "1-minimal sabotage trace should be tiny, got {} ops",
            min.ops.len()
        );
        // 1-minimality: removing any single remaining op heals the plan
        for i in 0..min.ops.len() {
            let mut c = min.clone();
            c.ops.remove(i);
            assert!(
                run_plan(&c).violation.is_none(),
                "op {i} ({:?}) is deletable — not 1-minimal",
                min.ops[i]
            );
        }
    }

    #[test]
    fn healthy_plan_is_returned_unchanged() {
        let plan = SimPlan::generate(6, 30);
        assert_eq!(shrink(&plan), plan);
    }
}
