//! The simulator's shadow-state oracle (docs/TESTING.md).
//!
//! The oracle never mutates the engine — it mirrors just enough state to
//! assert, after **every** event, that the serving invariants hold:
//!
//! * **slot conservation** — live sessions + free slots == pool size, so
//!   a slot can neither be double-checked-out nor leaked;
//! * **page conservation** — [`crate::engine::PagePool`]'s refcount /
//!   chain-membership / free-list equalities, plus `peak_resident ≤
//!   total` ([`crate::engine::SlotPool::page_conservation_error`]);
//! * **scheduler ledger balance** — the in-flight ledger equals the live
//!   session count (a leak here silently skews SJF queue-wait
//!   estimates);
//! * **bandit play conservation** — every `session_start` is answered by
//!   exactly one `on_verify`/`on_abort` (sessions == updates), and for
//!   sequence-level bandits the per-arm counts sum to the same total;
//! * **drafter-layer conservation** — the hierarchical drafter bandit
//!   plays at the same cadence: every `begin` is answered by exactly one
//!   settle, the global per-drafter plays sum to the settle count, and
//!   the per-tenant ledgers sum to the identical total (no play may land
//!   in one scope but not the other);
//! * **greedy byte-equality** — every reply (after the serving clip:
//!   ≤ `max_new`, nothing past the first EOS) must be a prefix of a
//!   fault-free target-only greedy decode of the same request, and a
//!   `Done` reply must equal it exactly. This is the lossless-ness
//!   guarantee, checked per request under every cache / paging / mode /
//!   fault combination;
//! * **terminal-status correctness** — `Failed` may only appear under
//!   fault injection, for an oversize prompt, or for a request whose
//!   replica was killed ([`Oracle::note_killed`]); `Done` never carries
//!   a short reply, cancels/expiries carry a clean prefix.

use std::collections::{BTreeMap, BTreeSet};

use crate::bandit::{SharedController, SharedDrafters};
use crate::engine::{FinishStatus, Scheduler, SlotPool};
use crate::models::{Scenario, SimModel};
use crate::spec::{greedy, GenConfig, EOS};

/// The serving reply contract applied in one shot: truncate to `max_new`
/// generated tokens, then to (and including) the first EOS.
pub fn clip_reply(new_tokens: &[u32], max_new: usize) -> Vec<u32> {
    let mut v = new_tokens[..new_tokens.len().min(max_new)].to_vec();
    if let Some(p) = v.iter().position(|&t| t == EOS) {
        v.truncate(p + 1);
    }
    v
}

/// Shadow-state oracle for one simulator run. See the module docs for
/// the invariant catalog.
pub struct Oracle {
    faults_on: bool,
    seq_bandit: bool,
    /// req id → expected clipped reply (fault-free greedy decode)
    expected: BTreeMap<u64, Vec<u32>>,
    /// requests whose prompt exceeds the KV geometry (never decodable)
    oversize: BTreeSet<u64>,
    /// requests whose replica was killed mid-run (a `Failed` terminal is
    /// their legal outcome even without fault injection)
    killed: BTreeSet<u64>,
}

impl Oracle {
    /// A fresh oracle. `faults_on` relaxes the `Failed`-status rule;
    /// `seq_bandit` enables the per-arm count-sum check (sequence-level
    /// bandits only — token ladders legitimately take many plays per
    /// session).
    pub fn new(faults_on: bool, seq_bandit: bool) -> Oracle {
        Oracle {
            faults_on,
            seq_bandit,
            expected: BTreeMap::new(),
            oversize: BTreeSet::new(),
            killed: BTreeSet::new(),
        }
    }

    /// Record that this request was live (or queued) on a replica that a
    /// [`crate::sim_harness::SimOp::KillReplica`] op took down, so a
    /// `Failed` terminal is legal for it.
    pub fn note_killed(&mut self, id: u64) {
        self.killed.insert(id);
    }

    /// **Speculation conservation** (docs/ARCHITECTURE.md §16): once a
    /// run is quiescent, every speculative pre-draft the pipelined
    /// stepper issued must have resolved exactly once — adopted by the
    /// following round or discarded (partial acceptance, or the session
    /// ended first). An imbalance means discarded work leaked into (or
    /// vanished from) the accounting, the same class of bug the bandit
    /// play-count check catches for rewards. All-zero serialized runs
    /// pass trivially.
    pub fn check_spec_conservation(attempted: u64, adopted: u64, discarded: u64) -> Option<String> {
        if attempted != adopted + discarded {
            return Some(format!(
                "speculation conservation violated: {attempted} pre-drafts attempted \
                 but {adopted} adopted + {discarded} discarded"
            ));
        }
        None
    }

    /// Register a submitted request and precompute its expected reply by
    /// running a *fault-free* target-only greedy decode of the same
    /// scenario. `max_seq` is the engine's KV geometry; prompts that do
    /// not fit are recorded as oversize (their only legal end is a
    /// validation failure).
    #[allow(clippy::too_many_arguments)]
    pub fn expect_request(
        &mut self,
        id: u64,
        prompt: &[u32],
        seed: u64,
        category: &str,
        max_new: usize,
        gamma_max: usize,
        max_seq: usize,
    ) {
        if crate::spec::validate_prompt(prompt, max_seq).is_err() {
            self.oversize.insert(id);
            return;
        }
        let mut target = SimModel::target(Scenario::new(seed, category));
        // budget past max_new: the final speculative round may overshoot
        // (verification is atomic) — the clip makes both sides comparable
        let cfg = GenConfig {
            max_new: max_new + gamma_max + 2,
            stop_at_eos: true,
            ..GenConfig::default()
        };
        let r = greedy(&mut target, prompt, &cfg).expect("sim greedy decode is infallible");
        self.expected.insert(id, clip_reply(r.new_tokens(), max_new));
    }

    /// Is this request's prompt oversize (undecodable by construction)?
    pub fn is_oversize(&self, id: u64) -> bool {
        self.oversize.contains(&id)
    }

    /// The expected clipped reply for a request, if it was decodable.
    pub fn expected(&self, id: u64) -> Option<&Vec<u32>> {
        self.expected.get(&id)
    }

    /// Mid-stream check: the emitted (clipped) tokens so far must be a
    /// prefix of the expected reply.
    pub fn check_stream(&self, id: u64, emitted: &[u32]) -> Option<String> {
        match self.expected.get(&id) {
            None => (!emitted.is_empty())
                .then(|| format!("req {id}: oversize/unknown request emitted tokens")),
            Some(want) => {
                if emitted.len() > want.len() || emitted != &want[..emitted.len()] {
                    return Some(format!(
                        "req {id}: emitted stream diverged from greedy oracle\n  \
                         got {emitted:?}\n want {want:?}"
                    ));
                }
                None
            }
        }
    }

    /// Terminal check: status legality plus the byte-equality rule.
    pub fn check_terminal(
        &self,
        id: u64,
        status: FinishStatus,
        emitted: &[u32],
    ) -> Option<String> {
        if let Some(v) = self.check_stream(id, emitted) {
            return Some(v);
        }
        match status {
            FinishStatus::Done => {
                let want = match self.expected.get(&id) {
                    Some(w) => w,
                    None => return Some(format!("req {id}: oversize request finished Done")),
                };
                (emitted != &want[..]).then(|| {
                    format!(
                        "req {id}: Done reply != greedy oracle\n  got {emitted:?}\n want {want:?}"
                    )
                })
            }
            FinishStatus::Failed => {
                let legal =
                    self.faults_on || self.oversize.contains(&id) || self.killed.contains(&id);
                (!legal).then(|| {
                    format!(
                        "req {id}: Failed without fault injection, an oversize prompt, or a \
                         replica kill"
                    )
                })
            }
            // prefix rule (already checked) is all that cancels, expiries
            // and queue-shed rejections must satisfy
            FinishStatus::Cancelled | FinishStatus::Expired | FinishStatus::Rejected => None,
        }
    }

    /// **Drafter-layer conservation** (hierarchical bandit, both scopes):
    /// sessions == settles, Σ global per-drafter plays == settles, and the
    /// per-tenant ledgers sum to the same total. Checked after every
    /// event, so a leak is caught at the round that caused it.
    pub fn check_drafters(drafters: &SharedDrafters) -> Option<String> {
        let (sessions, updates) = (drafters.sessions(), drafters.updates());
        if sessions != updates {
            return Some(format!(
                "drafter play leak: {sessions} begins vs {updates} settles"
            ));
        }
        let global: u64 = drafters.plays().iter().sum();
        if global != updates {
            return Some(format!(
                "drafter count drift: Σ global plays {global} != {updates} settles"
            ));
        }
        let per_tenant = drafters.tenant_plays_total();
        if per_tenant != updates {
            return Some(format!(
                "drafter tenant drift: Σ per-tenant plays {per_tenant} != {updates} settles"
            ));
        }
        None
    }

    /// Engine-wide conservation checks, run after every event.
    pub fn check_engine(
        &self,
        pool: &SlotPool,
        sched: &Scheduler,
        live_sessions: usize,
        shared: &SharedController,
        drafters: &SharedDrafters,
    ) -> Option<String> {
        if let Some(e) = pool.page_conservation_error() {
            return Some(e);
        }
        if live_sessions + pool.available() != pool.total() {
            return Some(format!(
                "slot conservation broken: {live_sessions} live + {} free != {} total",
                pool.available(),
                pool.total()
            ));
        }
        if sched.in_flight() != live_sessions {
            return Some(format!(
                "scheduler ledger drift: in_flight {} != live sessions {live_sessions}",
                sched.in_flight()
            ));
        }
        let (sessions, updates) = (shared.sessions(), shared.updates());
        if sessions != updates {
            return Some(format!(
                "bandit play leak: {sessions} session_starts vs {updates} verify/abort updates"
            ));
        }
        if self.seq_bandit {
            if let Some(counts) = shared.arm_counts() {
                let total: u64 = counts.iter().sum();
                if total != updates {
                    return Some(format!(
                        "bandit count drift: Σ arm counts {total} != {updates} updates"
                    ));
                }
            }
        }
        Self::check_drafters(drafters)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spec::BOS;

    #[test]
    fn spec_conservation_balances() {
        assert!(Oracle::check_spec_conservation(0, 0, 0).is_none(), "serialized runs");
        assert!(Oracle::check_spec_conservation(7, 4, 3).is_none());
        assert!(Oracle::check_spec_conservation(7, 4, 2).is_some(), "leaked speculation");
        assert!(Oracle::check_spec_conservation(3, 2, 2).is_some(), "double-resolved");
    }

    #[test]
    fn drafter_conservation_catches_leaks_in_either_scope() {
        let d = SharedDrafters::new(2);
        assert!(Oracle::check_drafters(&d).is_none(), "fresh controller balances");
        let played = d.begin("t0");
        assert!(Oracle::check_drafters(&d).is_some(), "unsettled begin is a leak");
        d.settle_verify("t0", played, &[0.5, 0.9]);
        assert!(Oracle::check_drafters(&d).is_none(), "verify settles the play");
        let played = d.begin("t1");
        d.settle_abort("t1", played);
        assert!(Oracle::check_drafters(&d).is_none(), "abort settles too");
        // a settle that never had a begin is the opposite leak
        d.settle_abort("t1", 0);
        assert!(Oracle::check_drafters(&d).is_some());
    }

    #[test]
    fn clip_truncates_to_budget_then_eos() {
        assert_eq!(clip_reply(&[5, 6, 7, 8], 2), vec![5, 6]);
        assert_eq!(clip_reply(&[5, EOS, 7], 8), vec![5, EOS]);
        assert_eq!(clip_reply(&[5, 6, EOS], 2), vec![5, 6], "EOS beyond budget doesn't count");
    }

    #[test]
    fn stream_prefix_and_terminal_rules() {
        let mut o = Oracle::new(false, true);
        let prompt = [BOS, 5, 6, 7];
        o.expect_request(1, &prompt, 42, "qa", 6, 4, 4096);
        let want = o.expected(1).unwrap().clone();
        assert!(!want.is_empty());
        assert!(o.check_stream(1, &want[..1]).is_none(), "prefix ok");
        assert!(o.check_stream(1, &[99]).is_some(), "divergence caught");
        assert!(o.check_terminal(1, FinishStatus::Done, &want).is_none());
        assert!(
            o.check_terminal(1, FinishStatus::Done, &want[..1]).is_some(),
            "short Done caught"
        );
        assert!(
            o.check_terminal(1, FinishStatus::Cancelled, &want[..1]).is_none(),
            "cancel keeps prefix"
        );
        assert!(
            o.check_terminal(1, FinishStatus::Failed, &[]).is_some(),
            "Failed without faults is a violation"
        );
    }

    #[test]
    fn killed_replicas_legalize_failed_terminals() {
        let mut o = Oracle::new(false, false);
        o.expect_request(3, &[BOS, 5, 6], 7, "qa", 4, 4, 4096);
        assert!(o.check_terminal(3, FinishStatus::Failed, &[]).is_some());
        o.note_killed(3);
        assert!(o.check_terminal(3, FinishStatus::Failed, &[]).is_none());
        assert!(
            o.check_terminal(3, FinishStatus::Done, &[]).is_some(),
            "a kill does not excuse a short Done"
        );
    }

    #[test]
    fn oversize_requests_may_only_fail() {
        let mut o = Oracle::new(false, false);
        let prompt: Vec<u32> = (0..5000).map(|i| 3 + (i % 20) as u32).collect();
        o.expect_request(7, &prompt, 1, "qa", 8, 4, 4096);
        assert!(o.is_oversize(7));
        assert!(o.check_terminal(7, FinishStatus::Failed, &[]).is_none());
        assert!(o.check_terminal(7, FinishStatus::Done, &[]).is_some());
    }
}
