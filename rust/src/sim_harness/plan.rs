//! Seeded workload plans: the op vocabulary, the seeded generator, and
//! byte-stable JSON (de)serialization.
//!
//! A plan is a complete, self-contained description of one simulator run:
//! engine knobs (mode, slots, cache/paging, bandit method, fault
//! injection) plus an ordered op list. The op vocabulary is deliberately
//! tiny — submit / cancel / disconnect / step — and the *generator*
//! composes the interesting scenarios out of it: request bursts are
//! adjacent submits, shared-prefix floods are submits sharing a prompt
//! prefix, deadline races are submits with tight virtual deadlines,
//! starvation is a burst against a 1-slot pool, and cancels land
//! mid-prefill (right after the submit) or mid-decode (after steps).
//! Small vocabulary + explicit request ids is also what makes shrinking
//! trivial: deleting any op leaves a well-formed plan (cancels aimed at a
//! deleted request become no-ops).
//!
//! `SimPlan::generate(seed, steps)` is a pure function of its arguments,
//! and `to_json`/`from_json` round-trip exactly — so a failing seed can
//! be replayed byte-for-byte from either the seed or the serialized plan
//! (`rust/tests/sim_regressions/`).

use crate::util::{Json, Rng};

/// One event in a simulator plan.
#[derive(Clone, Debug, PartialEq)]
pub enum SimOp {
    /// Submit one generation request. `req` is the plan-scoped request id
    /// (stable under shrinking); `deadline_ns` is a *virtual* deadline
    /// relative to submission time, `None` for no deadline.
    Submit {
        /// plan-scoped request id (referenced by cancel/disconnect ops)
        req: u64,
        /// raw prompt text (sim-encoded by the runner, BOS included)
        prompt: String,
        /// workload category (drives the simulator's difficulty profile)
        category: String,
        /// decode budget
        max_new: usize,
        /// virtual deadline in ns after submission; `None` = none
        deadline_ns: Option<u64>,
    },
    /// Flip the request's cancel flag (client-initiated cancellation).
    Cancel {
        /// plan-scoped id of the request to cancel
        req: u64,
    },
    /// Stream disconnect: same engine-visible effect as a cancel (the
    /// HTTP layer flips the cancel flag on a dropped connection), kept as
    /// a distinct op so traces say what the client did.
    Disconnect {
        /// plan-scoped id of the request whose stream dropped
        req: u64,
    },
    /// Run `n` scheduler/decode micro-steps.
    Step {
        /// micro-steps to run
        n: usize,
    },
    /// Kill a replica: every session live on it fails, queued work
    /// re-routes, and the replica accepts nothing afterwards. The
    /// generator never kills the last alive replica.
    KillReplica {
        /// replica index to kill
        replica: usize,
    },
    /// Drain a replica: it finishes in-flight work but the router stops
    /// sending it new requests.
    DrainReplica {
        /// replica index to drain
        replica: usize,
    },
}

impl SimOp {
    fn to_json(&self) -> Json {
        let mut j = Json::obj();
        match self {
            SimOp::Submit { req, prompt, category, max_new, deadline_ns } => {
                j.set("op", "submit")
                    .set("req", *req as f64)
                    .set("prompt", prompt.as_str())
                    .set("category", category.as_str())
                    .set("max_new", *max_new);
                if let Some(d) = deadline_ns {
                    j.set("deadline_ns", *d as f64);
                }
            }
            SimOp::Cancel { req } => {
                j.set("op", "cancel").set("req", *req as f64);
            }
            SimOp::Disconnect { req } => {
                j.set("op", "disconnect").set("req", *req as f64);
            }
            SimOp::Step { n } => {
                j.set("op", "step").set("n", *n);
            }
            SimOp::KillReplica { replica } => {
                j.set("op", "kill_replica").set("replica", *replica);
            }
            SimOp::DrainReplica { replica } => {
                j.set("op", "drain_replica").set("replica", *replica);
            }
        }
        j
    }

    fn from_json(j: &Json) -> Result<SimOp, String> {
        let kind = j.get("op").and_then(|x| x.as_str()).ok_or("op without kind")?;
        let req = || -> Result<u64, String> {
            Ok(j.get("req").and_then(|x| x.as_f64()).ok_or("op without req")? as u64)
        };
        Ok(match kind {
            "submit" => SimOp::Submit {
                req: req()?,
                prompt: j
                    .get("prompt")
                    .and_then(|x| x.as_str())
                    .ok_or("submit without prompt")?
                    .to_string(),
                category: j
                    .get("category")
                    .and_then(|x| x.as_str())
                    .unwrap_or("qa")
                    .to_string(),
                max_new: j.get("max_new").and_then(|x| x.as_usize()).unwrap_or(8),
                deadline_ns: j.get("deadline_ns").and_then(|x| x.as_f64()).map(|d| d as u64),
            },
            "cancel" => SimOp::Cancel { req: req()? },
            "disconnect" => SimOp::Disconnect { req: req()? },
            "step" => SimOp::Step { n: j.get("n").and_then(|x| x.as_usize()).unwrap_or(1) },
            "kill_replica" => SimOp::KillReplica {
                replica: j.get("replica").and_then(|x| x.as_usize()).unwrap_or(0),
            },
            "drain_replica" => SimOp::DrainReplica {
                replica: j.get("replica").and_then(|x| x.as_usize()).unwrap_or(0),
            },
            other => return Err(format!("unknown op kind: {other}")),
        })
    }
}

/// A complete simulator run description: engine knobs + ordered ops.
#[derive(Clone, Debug, PartialEq)]
pub struct SimPlan {
    /// root seed: drives op generation, the runner's task-choice RNG, and
    /// (when `faults` is on) every fault stream
    pub seed: u64,
    /// execution-core flavor: `"workers"` (one random ready session per
    /// micro-step) or `"continuous"` (every live session each micro-step)
    pub mode: String,
    /// KV slots in the pool
    pub slots: usize,
    /// concurrent decodes admitted (workers mode; ≤ `slots`)
    pub workers: usize,
    /// max draft length γ
    pub gamma_max: usize,
    /// stop-rule / bandit method name (`spec::MethodSpec::parse`)
    pub method: String,
    /// cross-request prefix cache on?
    pub cache: bool,
    /// cross-slot page sharing on (needs `cache`)?
    pub sharing: bool,
    /// KV page granularity in tokens
    pub page_size: usize,
    /// page arena size (0 = auto-size so eviction never fires)
    pub kv_pages: usize,
    /// inject faults ([`crate::models::FaultPlan::moderate`])?
    pub faults: bool,
    /// fault kill cap (errors + crashes) per wrapped model
    pub max_faults: u64,
    /// deliberately corrupt page accounting mid-run (test-only hook for
    /// the oracle/shrinker pipeline itself — never set by the generator)
    pub sabotage: bool,
    /// simulated replica count behind the router tier (1 = the classic
    /// single-engine run; >1 routes submits through
    /// [`crate::engine::RouterCore`])
    pub replicas: usize,
    /// route by prefix-affinity hashing (`false` = round-robin); only
    /// meaningful when `replicas > 1`
    pub affinity: bool,
    /// run continuous-mode rounds through the overlapped draft/verify
    /// pipeline (docs/ARCHITECTURE.md §16) and account wall time on the
    /// simulator's two-lane clock. Decode outputs are identical pipeline
    /// on or off; only the virtual clock and lane gauges move. The
    /// generator always leaves this `false` (it is a CLI/CI overlay, not
    /// a random knob — flipping it draws no RNG, so every existing seed
    /// still generates the identical plan).
    pub pipeline: bool,
    /// drafter pool size (docs/ARCHITECTURE.md §17): every sim slot's
    /// draft model carries this many pooled drafters and the outer
    /// bandit selects one per round. 1 = the classic single-drafter run.
    /// A CLI/CI overlay like `pipeline` — the generator never randomizes
    /// it (no RNG draw), so every existing seed generates the identical
    /// plan.
    pub drafters: usize,
    /// synthetic tenant streams: submit ops are mapped round-robin onto
    /// `t0..t{n-1}` tenant keys by the runner (`<= 1` = every request on
    /// the global tenant, the exact pre-tenant path). Same overlay
    /// contract as `drafters`.
    pub tenants: usize,
    /// the ordered op list
    pub ops: Vec<SimOp>,
}

impl SimPlan {
    /// Generate a seeded random plan with `steps` ops. Pure function of
    /// `(seed, steps)`: the same pair always yields the identical plan.
    pub fn generate(seed: u64, steps: usize) -> SimPlan {
        let mut rng = Rng::new(seed).fork(0x51AB);
        let slots = 1 + rng.below(3);
        let methods = ["static-4", "seq-ucb1", "seq-ts", "token-ucb1"];
        let mut plan = SimPlan {
            seed,
            mode: if rng.bool(0.5) { "workers" } else { "continuous" }.to_string(),
            slots,
            workers: 1 + rng.below(slots),
            gamma_max: 2 + rng.below(7),
            method: methods[rng.below(methods.len())].to_string(),
            cache: rng.bool(0.6),
            sharing: rng.bool(0.7),
            page_size: [4, 8, 16][rng.below(3)],
            kv_pages: if rng.bool(0.8) { 0 } else { 64 + rng.below(64) },
            faults: false,
            max_faults: 1 + rng.below(8) as u64,
            sabotage: false,
            replicas: 1,
            affinity: true,
            pipeline: false,
            drafters: 1,
            tenants: 1,
            ops: Vec::new(),
        };
        let mut next_req: u64 = 0;
        let mut word = |rng: &mut Rng| -> String {
            (0..4 + rng.below(10)).map(|_| char::from(b'a' + rng.below(26) as u8)).collect()
        };
        let categories = ["qa", "coding", "math", "summarization"];
        while plan.ops.len() < steps {
            let mut submit = |rng: &mut Rng,
                              ops: &mut Vec<SimOp>,
                              next_req: &mut u64,
                              prompt: String,
                              deadline_ns: Option<u64>| {
                let req = *next_req;
                *next_req += 1;
                ops.push(SimOp::Submit {
                    req,
                    prompt,
                    category: categories[rng.below(categories.len())].to_string(),
                    max_new: 3 + rng.below(14),
                    deadline_ns,
                });
                req
            };
            match rng.weighted(&[3.0, 1.5, 1.0, 0.4, 0.8, 1.0, 1.0, 0.5, 3.0]) {
                // lone request
                0 => {
                    let p = format!("ask {} {}", next_req, word(&mut rng));
                    submit(&mut rng, &mut plan.ops, &mut next_req, p, None);
                }
                // burst: back-to-back submits (slot starvation on 1-slot
                // pools falls out of this + the tiny pool sizes above)
                1 => {
                    for _ in 0..2 + rng.below(3) {
                        let p = format!("burst {} {}", next_req, word(&mut rng));
                        submit(&mut rng, &mut plan.ops, &mut next_req, p, None);
                    }
                }
                // shared-prefix flood: exercises slot-affinity routing,
                // page sharing and copy-on-write under churn
                2 => {
                    let common = format!("shared {} context block", word(&mut rng));
                    for _ in 0..3 + rng.below(3) {
                        let p = format!("{common} {}", word(&mut rng));
                        submit(&mut rng, &mut plan.ops, &mut next_req, p, None);
                    }
                }
                // oversize prompt: must be rejected by prompt validation,
                // never decoded and never leaking its slot
                3 => {
                    let p = "x".repeat(4200);
                    submit(&mut rng, &mut plan.ops, &mut next_req, p, None);
                }
                // cancel mid-prefill: flag flips before any step runs
                4 => {
                    let p = format!("early-cancel {}", word(&mut rng));
                    let req = submit(&mut rng, &mut plan.ops, &mut next_req, p, None);
                    plan.ops.push(SimOp::Cancel { req });
                }
                // deadline race: tight virtual deadline vs decode time
                5 => {
                    let p = format!("deadline {}", word(&mut rng));
                    let d = 5_000 + rng.below(200_000) as u64;
                    submit(&mut rng, &mut plan.ops, &mut next_req, p, Some(d));
                }
                // cancel mid-decode: aimed at a random earlier request
                6 if next_req > 0 => {
                    plan.ops.push(SimOp::Cancel { req: rng.below(next_req as usize) as u64 });
                }
                // stream disconnect on a random earlier request
                7 if next_req > 0 => {
                    plan.ops.push(SimOp::Disconnect { req: rng.below(next_req as usize) as u64 });
                }
                // let the engine run
                _ => plan.ops.push(SimOp::Step { n: 1 + rng.below(4) }),
            }
        }
        plan.ops.truncate(steps);
        plan
    }

    /// Generate a seeded multi-replica plan: [`SimPlan::generate`] plus
    /// spliced-in [`SimOp::KillReplica`]/[`SimOp::DrainReplica`] faults.
    /// Pure function of `(seed, steps, replicas)`; never kills the last
    /// alive replica (the fleet always retains a routable target unless
    /// every survivor is draining). `replicas <= 1` degenerates to the
    /// classic single-engine plan.
    pub fn generate_fleet(seed: u64, steps: usize, replicas: usize) -> SimPlan {
        let mut plan = SimPlan::generate(seed, steps);
        if replicas <= 1 {
            return plan;
        }
        plan.replicas = replicas;
        let mut rng = Rng::new(seed).fork(0xF1EE7);
        plan.affinity = rng.bool(0.8);
        let mut alive: Vec<bool> = vec![true; replicas];
        for _ in 0..1 + rng.below(replicas) {
            let at = rng.below(plan.ops.len() + 1);
            if rng.bool(0.6) {
                // kill: pick among alive replicas, but only if at least
                // two are still standing
                let standing: Vec<usize> = (0..replicas).filter(|&r| alive[r]).collect();
                if standing.len() < 2 {
                    continue;
                }
                let r = standing[rng.below(standing.len())];
                alive[r] = false;
                plan.ops.insert(at, SimOp::KillReplica { replica: r });
            } else {
                plan.ops.insert(at, SimOp::DrainReplica { replica: rng.below(replicas) });
            }
        }
        plan
    }

    /// Total submit ops in the plan.
    pub fn submits(&self) -> usize {
        self.ops.iter().filter(|o| matches!(o, SimOp::Submit { .. })).count()
    }

    /// Serialize to JSON (round-trips exactly through
    /// [`SimPlan::from_json`]; seeds are stored as JSON numbers, so they
    /// must stay below 2^53 — generator and CLI seeds always do).
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("seed", self.seed as f64)
            .set("mode", self.mode.as_str())
            .set("slots", self.slots)
            .set("workers", self.workers)
            .set("gamma_max", self.gamma_max)
            .set("method", self.method.as_str())
            .set("cache", self.cache)
            .set("sharing", self.sharing)
            .set("page_size", self.page_size)
            .set("kv_pages", self.kv_pages)
            .set("faults", self.faults)
            .set("max_faults", self.max_faults as f64)
            .set("sabotage", self.sabotage)
            .set("replicas", self.replicas)
            .set("affinity", self.affinity)
            .set("pipeline", self.pipeline)
            .set("drafters", self.drafters)
            .set("tenants", self.tenants)
            .set("ops", self.ops.iter().map(|o| o.to_json()).collect::<Vec<Json>>());
        j
    }

    /// Parse a serialized plan ([`SimPlan::to_json`]).
    pub fn from_json(j: &Json) -> Result<SimPlan, String> {
        let num = |k: &str| j.get(k).and_then(|x| x.as_f64());
        let ops = j
            .get("ops")
            .and_then(|x| x.as_arr())
            .ok_or("plan without ops")?
            .iter()
            .map(SimOp::from_json)
            .collect::<Result<Vec<_>, _>>()?;
        Ok(SimPlan {
            seed: num("seed").ok_or("plan without seed")? as u64,
            mode: j.get("mode").and_then(|x| x.as_str()).unwrap_or("workers").to_string(),
            slots: num("slots").unwrap_or(2.0) as usize,
            workers: num("workers").unwrap_or(2.0) as usize,
            gamma_max: num("gamma_max").unwrap_or(4.0) as usize,
            method: j.get("method").and_then(|x| x.as_str()).unwrap_or("static-4").to_string(),
            cache: j.get("cache").and_then(|x| x.as_bool()).unwrap_or(false),
            sharing: j.get("sharing").and_then(|x| x.as_bool()).unwrap_or(true),
            page_size: num("page_size").unwrap_or(16.0) as usize,
            kv_pages: num("kv_pages").unwrap_or(0.0) as usize,
            faults: j.get("faults").and_then(|x| x.as_bool()).unwrap_or(false),
            max_faults: num("max_faults").unwrap_or(4.0) as u64,
            sabotage: j.get("sabotage").and_then(|x| x.as_bool()).unwrap_or(false),
            replicas: num("replicas").unwrap_or(1.0) as usize,
            affinity: j.get("affinity").and_then(|x| x.as_bool()).unwrap_or(true),
            // absent in fixtures checked in before the pipeline existed:
            // they replay serialized, exactly as they were recorded
            pipeline: j.get("pipeline").and_then(|x| x.as_bool()).unwrap_or(false),
            // same legacy-fixture contract for the drafter-pool fields
            drafters: num("drafters").unwrap_or(1.0) as usize,
            tenants: num("tenants").unwrap_or(1.0) as usize,
            ops,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generate_is_a_pure_function_of_seed() {
        let a = SimPlan::generate(11, 60);
        let b = SimPlan::generate(11, 60);
        assert_eq!(a, b, "same seed ⇒ identical plan");
        assert_ne!(a, SimPlan::generate(12, 60), "seeds decorrelate");
        assert_eq!(a.ops.len(), 60);
        assert!(a.submits() > 0, "plans contain work");
    }

    #[test]
    fn json_round_trip_is_exact() {
        for seed in 0..8 {
            let plan = SimPlan::generate(seed, 40);
            let text = plan.to_json().render();
            let back = SimPlan::from_json(&Json::parse(&text).unwrap()).unwrap();
            assert_eq!(plan, back, "seed {seed}");
            // and the serialized form itself is stable (BTreeMap keys)
            assert_eq!(text, back.to_json().render(), "seed {seed}");
        }
    }

    #[test]
    fn pipeline_defaults_off_for_legacy_plans() {
        // fixtures checked in before the pipeline field existed carry no
        // "pipeline" key: they must parse (to a serialized run) and
        // re-serialize with the key made explicit
        let text = r#"{"seed":1,"ops":[{"op":"step","n":2}]}"#;
        let plan = SimPlan::from_json(&Json::parse(text).unwrap()).unwrap();
        assert!(!plan.pipeline);
        assert!(plan.to_json().render().contains("\"pipeline\""));
        // and the generator never flips it on (no RNG draw for the field)
        assert!(!SimPlan::generate(9, 40).pipeline);
    }

    #[test]
    fn drafter_fields_default_to_one_for_legacy_plans() {
        // pre-pool fixtures carry neither key: they must parse to the
        // exact single-drafter global-tenant run and re-serialize with
        // the keys made explicit
        let text = r#"{"seed":1,"ops":[{"op":"step","n":2}]}"#;
        let plan = SimPlan::from_json(&Json::parse(text).unwrap()).unwrap();
        assert_eq!(plan.drafters, 1);
        assert_eq!(plan.tenants, 1);
        let out = plan.to_json().render();
        assert!(out.contains("\"drafters\"") && out.contains("\"tenants\""));
        // overlay contract: the generator draws no RNG for either field
        let g = SimPlan::generate(9, 40);
        assert_eq!((g.drafters, g.tenants), (1, 1));
    }

    #[test]
    fn generator_covers_the_scenario_mix() {
        // over a handful of seeds the generator must exercise every op
        // kind and the scripted scenario flavors
        let mut saw = (false, false, false, false); // cancel, disconnect, oversize, deadline
        for seed in 0..20 {
            for op in &SimPlan::generate(seed, 80).ops {
                match op {
                    SimOp::Cancel { .. } => saw.0 = true,
                    SimOp::Disconnect { .. } => saw.1 = true,
                    SimOp::Submit { prompt, deadline_ns, .. } => {
                        if prompt.len() > 4000 {
                            saw.2 = true;
                        }
                        if deadline_ns.is_some() {
                            saw.3 = true;
                        }
                    }
                    SimOp::Step { .. }
                    | SimOp::KillReplica { .. }
                    | SimOp::DrainReplica { .. } => {}
                }
            }
        }
        assert!(saw.0 && saw.1 && saw.2 && saw.3, "scenario coverage: {saw:?}");
    }

    #[test]
    fn fleet_plans_round_trip_and_never_kill_the_last_replica() {
        let mut saw_kill = false;
        let mut saw_drain = false;
        for seed in 0..24 {
            let plan = SimPlan::generate_fleet(seed, 60, 3);
            assert_eq!(plan, SimPlan::generate_fleet(seed, 60, 3), "pure in seed");
            assert_eq!(plan.replicas, 3);
            let text = plan.to_json().render();
            let back = SimPlan::from_json(&Json::parse(&text).unwrap()).unwrap();
            assert_eq!(plan, back, "seed {seed}");
            let mut alive = plan.replicas;
            for op in &plan.ops {
                match op {
                    SimOp::KillReplica { replica } => {
                        saw_kill = true;
                        assert!(*replica < plan.replicas);
                        alive -= 1;
                        assert!(alive >= 1, "seed {seed}: killed the last replica");
                    }
                    SimOp::DrainReplica { replica } => {
                        saw_drain = true;
                        assert!(*replica < plan.replicas);
                    }
                    _ => {}
                }
            }
            // single-replica fleet degenerates to the classic plan
            assert_eq!(SimPlan::generate_fleet(seed, 60, 1), SimPlan::generate(seed, 60));
        }
        assert!(saw_kill && saw_drain, "fleet fault coverage");
    }
}
