//! Deterministic engine simulator (docs/TESTING.md).
//!
//! A single-threaded, virtually-clocked harness that drives the engine's
//! *real* components — [`crate::engine::SlotPool`] (prefix cache + paged
//! KV arena), [`crate::engine::Scheduler`], the shared bandit
//! ([`crate::bandit::SharedController`]) and the Algorithm-1 round logic
//! (`spec/session.rs`) — under seeded workload plans with fault injection
//! at the [`crate::models::LanguageModel`] boundary
//! ([`crate::models::FaultyModel`]), while a shadow-state oracle checks
//! serving invariants after every event.
//!
//! The pieces:
//!
//! * [`clock`] — the fake nanosecond clock. Virtual time advances by
//!   analytic per-round costs plus whatever latency the fault layer
//!   injected ([`crate::models::FaultStats::delay_ns`]); nothing ever
//!   sleeps, so thousands of simulated requests run in milliseconds.
//!   Per-model *lanes* (draft / verify busy time) let pipelined rounds
//!   advance wall-clock by the critical path instead of the sum
//!   (docs/ARCHITECTURE.md §16).
//! * [`plan`] — seeded workload plans: a tiny op vocabulary (submit /
//!   cancel / disconnect / step / kill-replica / drain-replica) that the
//!   generator composes into request bursts, cancels mid-prefill and
//!   mid-decode, deadline races, shared-prefix floods, oversize prompts,
//!   slot starvation and stream disconnects;
//!   [`SimPlan::generate_fleet`] adds replica kill/drain faults for
//!   router-mode runs. Plans serialize to JSON, so any seed replays
//!   byte-for-byte and a failing seed becomes a checked-in fixture.
//! * [`runner`] — the deterministic scheduler: one event at a time, with
//!   the plan's RNG choosing which ready session runs next (workers mode)
//!   or stepping every live session in lockstep (continuous mode). Plans
//!   with `replicas > 1` drive a simulated fleet through the live
//!   router's own [`crate::engine::RouterCore`] placement policy.
//! * [`oracle`] — the shadow state: slot-checkout conservation, page
//!   refcount conservation, scheduler in-flight ledger balance, bandit
//!   play-count conservation, byte-equality of every reply against a
//!   fault-free target-only greedy decode, and terminal-status
//!   correctness under faults.
//! * [`shrink`] — greedy op-deletion: a violating plan is re-run with one
//!   op removed at a time until no single deletion preserves the
//!   violation, yielding a minimal replayable trace
//!   (`rust/tests/sim_regressions/`).
//!
//! CLI face: `tapout simulate --seed N --steps M [--replicas R]
//! [--pipeline]` (src/main.rs).

pub mod clock;
pub mod oracle;
pub mod plan;
pub mod runner;
pub mod shrink;

pub use clock::SimClock;
pub use oracle::Oracle;
pub use plan::{SimOp, SimPlan};
pub use runner::{run_plan, run_plan_pinned, SimReport, Violation};
pub use shrink::shrink;
