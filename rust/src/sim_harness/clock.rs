//! The simulator's fake clock: virtual nanoseconds, never a real sleep.
//!
//! All time the simulator reasons about — deadline races, fault-injected
//! slow steps, per-round decode cost — is *virtual*: the runner advances
//! this counter by analytic amounts and by the latency the fault layer
//! banked in [`crate::models::FaultStats::delay_ns`]. Trace lines embed
//! the virtual timestamp, so a plan replays to an identical trace no
//! matter how fast the host is.

/// Virtual-time clock for the deterministic simulator.
#[derive(Clone, Copy, Debug, Default)]
pub struct SimClock {
    now_ns: u64,
}

impl SimClock {
    /// A clock at t = 0.
    pub fn new() -> SimClock {
        SimClock::default()
    }

    /// Current virtual time in nanoseconds.
    pub fn now_ns(&self) -> u64 {
        self.now_ns
    }

    /// Advance virtual time by `ns`.
    pub fn advance(&mut self, ns: u64) {
        self.now_ns += ns;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn advances_monotonically() {
        let mut c = SimClock::new();
        assert_eq!(c.now_ns(), 0);
        c.advance(5);
        c.advance(0);
        c.advance(7);
        assert_eq!(c.now_ns(), 12);
    }
}
