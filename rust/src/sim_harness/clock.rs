//! The simulator's fake clock: virtual nanoseconds, never a real sleep.
//!
//! All time the simulator reasons about — deadline races, fault-injected
//! slow steps, per-round decode cost — is *virtual*: the runner advances
//! this counter by analytic amounts and by the latency the fault layer
//! banked in [`crate::models::FaultStats::delay_ns`]. Trace lines embed
//! the virtual timestamp, so a plan replays to an identical trace no
//! matter how fast the host is.
//!
//! **Per-model lanes (docs/ARCHITECTURE.md §16).** A pipelined round
//! overlaps draft work with an in-flight verify, so wall-clock is the
//! *critical path*, not the sum. [`SimClock::advance_round`] models this:
//! the draft and verify lanes each accumulate their own busy time, and
//! the wall clock advances by `draft + verify − hidden`, where `hidden`
//! is the overlap the round actually achieved (clamped to both lane
//! costs). `advance_round(d, v, 0)` degenerates to `advance(d + v)`, so
//! serialized plans — and every checked-in regression fixture — replay to
//! byte-identical clocks.

/// Virtual-time clock for the deterministic simulator, with independent
/// draft/verify lane accounting for pipelined rounds.
#[derive(Clone, Copy, Debug, Default)]
pub struct SimClock {
    now_ns: u64,
    draft_busy_ns: u64,
    verify_busy_ns: u64,
    overlap_ns: u64,
}

impl SimClock {
    /// A clock at t = 0 with idle lanes.
    pub fn new() -> SimClock {
        SimClock::default()
    }

    /// Current virtual time in nanoseconds (the critical path).
    pub fn now_ns(&self) -> u64 {
        self.now_ns
    }

    /// Total virtual time the draft lane spent busy.
    pub fn draft_busy_ns(&self) -> u64 {
        self.draft_busy_ns
    }

    /// Total virtual time the verify lane spent busy.
    pub fn verify_busy_ns(&self) -> u64 {
        self.verify_busy_ns
    }

    /// Total verify latency hidden behind overlapped draft work.
    pub fn overlap_ns(&self) -> u64 {
        self.overlap_ns
    }

    /// Advance virtual time by `ns` (lane-agnostic: queue waits, fault
    /// delays, idle ticks — anything that stalls the whole engine).
    pub fn advance(&mut self, ns: u64) {
        self.now_ns += ns;
    }

    /// Advance one decode round: the draft lane works `draft_ns`, the
    /// verify lane `verify_ns`, and up to `overlap_ns` of the shorter
    /// lane ran under the other's shadow. Wall time advances by the
    /// critical path `draft + verify − hidden`; `hidden` is clamped so a
    /// claimed overlap can never exceed either lane's actual work.
    pub fn advance_round(&mut self, draft_ns: u64, verify_ns: u64, overlap_ns: u64) {
        let hidden = overlap_ns.min(draft_ns).min(verify_ns);
        self.draft_busy_ns += draft_ns;
        self.verify_busy_ns += verify_ns;
        self.overlap_ns += hidden;
        self.now_ns += draft_ns + verify_ns - hidden;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn advances_monotonically() {
        let mut c = SimClock::new();
        assert_eq!(c.now_ns(), 0);
        c.advance(5);
        c.advance(0);
        c.advance(7);
        assert_eq!(c.now_ns(), 12);
    }

    #[test]
    fn zero_overlap_round_matches_flat_advance() {
        let mut flat = SimClock::new();
        let mut lanes = SimClock::new();
        flat.advance(2500);
        lanes.advance_round(500, 2000, 0);
        assert_eq!(lanes.now_ns(), flat.now_ns());
        assert_eq!(lanes.draft_busy_ns(), 500);
        assert_eq!(lanes.verify_busy_ns(), 2000);
        assert_eq!(lanes.overlap_ns(), 0);
    }

    #[test]
    fn overlap_shortens_wall_clock_by_hidden_time() {
        let mut c = SimClock::new();
        c.advance_round(500, 2000, 500);
        assert_eq!(c.now_ns(), 2000);
        assert_eq!(c.overlap_ns(), 500);
    }

    #[test]
    fn overlap_clamps_to_both_lanes() {
        let mut c = SimClock::new();
        // claimed overlap exceeds the draft lane's work: only 300 hides
        c.advance_round(300, 2000, 1000);
        assert_eq!(c.now_ns(), 2000);
        assert_eq!(c.overlap_ns(), 300);
        // and it can never exceed the verify lane either
        c.advance_round(800, 100, 1000);
        assert_eq!(c.now_ns(), 2000 + 800);
        assert_eq!(c.overlap_ns(), 300 + 100);
    }
}
