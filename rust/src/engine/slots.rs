//! KV slot pool — per-sequence device state (draft + target worlds) that
//! survives across requests. A slot owns one model pair; acquiring a slot
//! is O(1) because the contiguous-cursor protocol never needs the KV cache
//! cleared (stale entries beyond the cursor are dead by construction).
//!
//! The pool is shared by all decode workers (`&self` API behind a
//! mutex + condvar, DESIGN.md §2): checkout moves the `Slot` out of the
//! pool, so a checked-out slot is owned by exactly one worker with no
//! further synchronization. `acquire` blocks until a slot frees up, which
//! lets the worker count exceed the slot count without panicking — extra
//! workers simply queue at the checkout.
//!
//! **Prefix-reuse routing (docs/ARCHITECTURE.md §12).** Checkout is no
//! longer an anonymous pop: each slot carries *resident-prefix metadata*
//! (the token ids its KV covers below the cursor watermark, recorded by
//! the engine at release via [`Slot::record_prefix`]), and a
//! [`PrefixIndex`] over the free slots lives beside the free list. The
//! affinity checkout ([`SlotPool::try_acquire_for`],
//! [`SlotPool::acquire_for_timeout`]) routes a request to the free slot
//! sharing the longest token-id prefix with its prompt and reports how
//! many positions the caller may retain; reuse is capped at
//! `prompt_len − 1` so the last prompt token is always re-fed (every
//! decode round needs its signal row). The reset-vs-retain contract:
//!
//!   * **miss** (`reuse == 0`) — the caller must start the slot's
//!     sequence state fresh (`LanguageModel::retain_prefix` with
//!     `keep = 0`, which is a full reset). The pool discards the slot's
//!     stale recorded prefix, counting an eviction.
//!   * **hit** (`reuse > 0`) — the caller may roll both cursors back to
//!     `reuse` and prefill only the suffix; the pool guarantees the
//!     slot's recorded prefix matches the prompt token-for-token over
//!     those positions, and the recorded prefix never exceeds the
//!     cursor watermark the engine measured at release.
//!
//! Reuse is therefore deliberate, never accidental: a slot checked out
//! without an index match always resets, and a cache hit is an explicit
//! `(slot, reuse)` the engine threads through `retain_prefix` /
//! `SpecSession::resume`. With the cache disabled the pool behaves
//! exactly like the anonymous pool (every checkout reports `reuse 0`,
//! nothing is recorded).
//!
//! The continuous engine (docs/ARCHITECTURE.md §11) is the pool's sole
//! consumer in `Continuous` mode: the step loop admits with the
//! non-blocking affinity checkout (a free slot it observes cannot be
//! taken by anyone else) and releases at retire, so slot occupancy equals
//! its in-flight session count by construction. The slot's resident
//! models idle there — batched drafting/verification own the
//! per-sequence state, keyed by the slot `id` — but the `id`, the
//! recorded prefix, and the `served` counter still anchor sequence
//! identity and reuse accounting.

use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::models::sim::Scenario;
use crate::models::{LanguageModel, ModelAssets, PjrtModel, SimModel};

use super::cache::PrefixIndex;
use super::metrics::CacheStats;

/// Smallest prefix match that counts as a cache hit. Every encoded
/// prompt starts with BOS, so any two prompts trivially share one
/// leading token; treating that as a hit would make *every* checkout
/// "reuse" a slot (never resetting, never evicting) while saving a
/// single prefill row. Matches shorter than this are misses.
pub const MIN_REUSE: usize = 2;

/// One checked-out sequence state: a draft+target model pair whose KV
/// survives across requests. In the batched engine the slot `id` doubles
/// as the sequence key the verification batcher keys resident state on.
pub struct Slot {
    /// stable slot id (== `BatchItem::seq` in the batched engine)
    pub id: usize,
    /// the slot's resident draft model
    pub draft: Box<dyn LanguageModel>,
    /// the slot's resident target model (idle while the verification
    /// batcher is enabled — its geometry still drives headroom checks)
    pub target: Box<dyn LanguageModel>,
    /// requests served by this slot (reuse diagnostics)
    pub served: u64,
    /// token ids resident in this slot's sequence state below the cursor
    /// watermark (`prefix.len()` *is* the watermark — the engine records
    /// the tokens truncated to `min(draft cursor, target cursor)` at
    /// release, docs/ARCHITECTURE.md §12)
    prefix: Vec<u32>,
}

impl Slot {
    /// The resident token prefix recorded at the last release (empty for
    /// a fresh or reset slot).
    pub fn resident_prefix(&self) -> &[u32] {
        &self.prefix
    }

    /// Record this slot's resident sequence state for prefix-reuse
    /// routing: `tokens` is the committed sequence the slot's models just
    /// decoded, `watermark` the lowest of their cursor positions (KV at
    /// positions `< watermark` is resident and was computed from exactly
    /// these token ids). Call before [`SlotPool::release`]; the pool
    /// indexes whatever is recorded here.
    pub fn record_prefix(&mut self, tokens: &[u32], watermark: usize) {
        self.prefix.clear();
        self.prefix.extend_from_slice(&tokens[..watermark.min(tokens.len())]);
    }

    /// Forget the recorded prefix (a failed decode leaves the resident
    /// state untrusted — the next tenant must start fresh).
    pub fn clear_prefix(&mut self) {
        self.prefix.clear();
    }
}

struct PoolInner {
    free: Vec<Slot>,
    index: PrefixIndex,
}

/// The shared checkout pool of KV slots (blocking condvar checkout), with
/// optional prefix-reuse affinity routing over the free slots.
pub struct SlotPool {
    inner: Mutex<PoolInner>,
    freed: Condvar,
    total: usize,
    cache_on: bool,
    cache: CacheStats,
}

impl SlotPool {
    /// Pool over explicit (draft, target) model pairs (prefix cache off;
    /// see [`SlotPool::with_prefix_cache`]).
    pub fn from_pairs(pairs: Vec<(Box<dyn LanguageModel>, Box<dyn LanguageModel>)>) -> SlotPool {
        let total = pairs.len();
        let free = pairs
            .into_iter()
            .enumerate()
            .map(|(id, (draft, target))| Slot {
                id,
                draft,
                target,
                served: 0,
                prefix: Vec::new(),
            })
            .collect();
        SlotPool {
            inner: Mutex::new(PoolInner { free, index: PrefixIndex::new() }),
            freed: Condvar::new(),
            total,
            cache_on: false,
            cache: CacheStats::new(total, false),
        }
    }

    /// Enable (or explicitly disable) cross-request prefix reuse. With
    /// the cache off every checkout reports `reuse 0` and nothing is
    /// indexed — byte-identical to the anonymous pool.
    pub fn with_prefix_cache(mut self, enabled: bool) -> SlotPool {
        self.cache_on = enabled;
        self.cache = CacheStats::new(self.total, enabled);
        self
    }

    /// Is prefix-reuse routing enabled?
    pub fn prefix_cache_enabled(&self) -> bool {
        self.cache_on
    }

    /// The pool's cache gauges (the `/metrics` `engine.cache` source).
    pub fn cache_stats(&self) -> &CacheStats {
        &self.cache
    }

    /// `n` PJRT slots sharing one set of weights/executables.
    pub fn pjrt(
        draft_assets: &Arc<ModelAssets>,
        target_assets: &Arc<ModelAssets>,
        n: usize,
    ) -> Result<SlotPool> {
        let mut pairs: Vec<(Box<dyn LanguageModel>, Box<dyn LanguageModel>)> =
            Vec::with_capacity(n);
        for _ in 0..n {
            pairs.push((
                Box::new(PjrtModel::new(draft_assets.clone())?),
                Box::new(PjrtModel::new(target_assets.clone())?),
            ));
        }
        Ok(SlotPool::from_pairs(pairs))
    }

    /// `n` simulator slots; each request reseats the scenario via
    /// `LanguageModel::retain_prefix` / `LanguageModel::begin_request`.
    pub fn sim(quality: f32, rel_cost: f64, n: usize) -> SlotPool {
        let placeholder = Scenario::new(0, "qa");
        let pairs = (0..n)
            .map(|_| {
                (
                    Box::new(SimModel::draft(placeholder, quality, rel_cost))
                        as Box<dyn LanguageModel>,
                    Box::new(SimModel::target(placeholder)) as Box<dyn LanguageModel>,
                )
            })
            .collect();
        SlotPool::from_pairs(pairs)
    }

    /// The checkout core, under the pool mutex: affinity-match `prompt`
    /// against the free slots' recorded prefixes, fall back to the
    /// least-recently released un-prefixed slot (preserving other slots'
    /// cached prefixes) on a miss. Returns `(slot, reuse)`.
    fn checkout_locked(&self, inner: &mut PoolInner, prompt: &[u32]) -> Option<(Slot, usize)> {
        if inner.free.is_empty() {
            return None;
        }
        if !self.cache_on {
            return inner.free.pop().map(|s| (s, 0));
        }
        if let Some((sid, lcp)) = inner.index.best_match(prompt) {
            // always re-feed the last prompt token: its signal row seeds
            // the first draft proposal and the first verification block
            let reuse = lcp.min(prompt.len().saturating_sub(1));
            if reuse >= MIN_REUSE {
                let pos = inner
                    .free
                    .iter()
                    .position(|s| s.id == sid)
                    .expect("indexed slot is on the free list");
                let slot = inner.free.remove(pos);
                inner.index.remove(slot.id, &slot.prefix);
                self.cache.note_lookup(prompt.len(), reuse);
                return Some((slot, reuse));
            }
        }
        // miss: prefer a slot with no cached prefix; otherwise evict the
        // least-recently released one (front of the free list)
        let pick = inner.free.iter().position(|s| s.prefix.is_empty()).unwrap_or(0);
        let mut slot = inner.free.remove(pick);
        if !slot.prefix.is_empty() {
            inner.index.remove(slot.id, &slot.prefix);
            slot.prefix.clear();
            self.cache.note_eviction();
        }
        self.cache.note_lookup(prompt.len(), 0);
        Some((slot, 0))
    }

    /// Non-blocking affinity checkout: the free slot with the longest
    /// resident prefix matching `prompt`, plus how many positions the
    /// caller may retain (0 = start fresh). See the module docs for the
    /// reset-vs-retain contract.
    pub fn try_acquire_for(&self, prompt: &[u32]) -> Option<(Slot, usize)> {
        let mut inner = self.inner.lock().unwrap();
        self.checkout_locked(&mut inner, prompt)
    }

    /// Bounded blocking affinity checkout: like
    /// [`SlotPool::try_acquire_for`], but waits up to `timeout` for a
    /// slot to free. Decode workers poll this in a loop so a request that
    /// is cancelled or expires *while waiting for a slot* exits the
    /// lifecycle promptly instead of blocking until a slot frees
    /// (server.rs).
    ///
    /// Deadline edge: the free list is always re-checked *after* the
    /// final `wait_timeout` returns — a slot released exactly at the
    /// deadline instant is returned, not dropped for `None` (pinned by
    /// `release_at_deadline_instant_is_still_returned`).
    pub fn acquire_for_timeout(
        &self,
        prompt: &[u32],
        timeout: Duration,
    ) -> Option<(Slot, usize)> {
        let deadline = Instant::now() + timeout;
        let mut inner = self.inner.lock().unwrap();
        loop {
            // checkout before the deadline test: after the last wake (or
            // with the deadline already past at entry) a freed slot must
            // still win over the timeout
            if let Some(got) = self.checkout_locked(&mut inner, prompt) {
                return Some(got);
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            let (g, _res) = self.freed.wait_timeout(inner, deadline - now).unwrap();
            inner = g;
        }
    }

    /// Non-blocking anonymous checkout (no affinity; the slot still
    /// resets per the miss contract when the cache is on).
    pub fn try_acquire(&self) -> Option<Slot> {
        self.try_acquire_for(&[]).map(|(s, _)| s)
    }

    /// Blocking anonymous checkout: waits until another worker releases
    /// a slot.
    pub fn acquire(&self) -> Slot {
        let mut inner = self.inner.lock().unwrap();
        loop {
            if let Some((slot, _)) = self.checkout_locked(&mut inner, &[]) {
                return slot;
            }
            inner = self.freed.wait(inner).unwrap();
        }
    }

    /// Bounded blocking anonymous checkout ([`SlotPool::acquire`] with a
    /// timeout; same deadline-edge contract as
    /// [`SlotPool::acquire_for_timeout`]).
    pub fn acquire_timeout(&self, timeout: Duration) -> Option<Slot> {
        self.acquire_for_timeout(&[], timeout).map(|(s, _)| s)
    }

    /// Expected reuse (in prompt tokens) if a request with this prompt
    /// checked out right now — the scheduler's affinity placement hint
    /// (scheduler.rs subtracts it from the SJF service-cost estimate).
    /// Advisory only: the free set can change before the real checkout.
    pub fn peek_reuse(&self, prompt: &[u32]) -> usize {
        if !self.cache_on {
            return 0;
        }
        let inner = self.inner.lock().unwrap();
        inner
            .index
            .best_match(prompt)
            .map(|(_, lcp)| lcp.min(prompt.len().saturating_sub(1)))
            .filter(|&r| r >= MIN_REUSE)
            .unwrap_or(0)
    }

    /// Return a checked-out slot and wake one blocked `acquire`. With the
    /// prefix cache on, whatever [`Slot::record_prefix`] recorded is
    /// indexed for affinity routing; with it off the recorded prefix is
    /// dropped so reuse can never happen accidentally.
    pub fn release(&self, mut slot: Slot) {
        slot.served += 1;
        if self.cache_on {
            // mirror per-slot served into the cache gauges (the
            // `engine.cache` contract keeps every counter zero while
            // the cache is disabled; `Slot::served` stays authoritative)
            self.cache.note_served(slot.id);
        } else {
            slot.prefix.clear();
        }
        let mut inner = self.inner.lock().unwrap();
        if self.cache_on && !slot.prefix.is_empty() {
            inner.index.insert(slot.id, &slot.prefix);
        }
        inner.free.push(slot);
        self.freed.notify_one();
    }

    /// Slots currently free.
    pub fn available(&self) -> usize {
        self.inner.lock().unwrap().free.len()
    }

    /// Total slots the pool was built with.
    pub fn total(&self) -> usize {
        self.total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::Ordering;

    #[test]
    fn checkout_and_release_cycle() {
        let pool = SlotPool::sim(0.9, 0.05, 2);
        assert_eq!(pool.total(), 2);
        let a = pool.try_acquire().unwrap();
        let b = pool.try_acquire().unwrap();
        assert!(pool.try_acquire().is_none());
        assert_eq!(pool.available(), 0);
        pool.release(a);
        pool.release(b);
        assert_eq!(pool.available(), 2);
        let c = pool.try_acquire().unwrap();
        assert_eq!(c.served, 1, "release counts a completed checkout");
    }

    #[test]
    fn blocking_acquire_waits_for_release() {
        let pool = Arc::new(SlotPool::sim(0.9, 0.05, 1));
        let slot = pool.try_acquire().unwrap();
        let p = pool.clone();
        let waiter = std::thread::spawn(move || {
            let s = p.acquire(); // blocks until the main thread releases
            p.release(s);
        });
        std::thread::sleep(Duration::from_millis(20));
        pool.release(slot);
        waiter.join().unwrap();
        assert_eq!(pool.available(), 1);
    }

    #[test]
    fn acquire_timeout_gives_up_and_succeeds() {
        let pool = SlotPool::sim(0.9, 0.05, 1);
        let held = pool.try_acquire().unwrap();
        assert!(
            pool.acquire_timeout(Duration::from_millis(10)).is_none(),
            "no slot can free while we hold the only one"
        );
        pool.release(held);
        assert!(pool.acquire_timeout(Duration::from_millis(10)).is_some());
    }

    #[test]
    fn release_at_deadline_instant_is_still_returned() {
        // the deadline-edge contract: even with the deadline already in
        // the past, a slot on the free list wins over the timeout — the
        // free list is checked after the final wait, not before it
        let pool = SlotPool::sim(0.9, 0.05, 1);
        assert!(
            pool.acquire_timeout(Duration::ZERO).is_some(),
            "a free slot at the deadline instant must be returned"
        );
        // and with the slot held, the zero timeout gives up cleanly
        assert!(pool.acquire_timeout(Duration::ZERO).is_none());
    }

    #[test]
    fn more_workers_than_slots_all_make_progress() {
        let pool = Arc::new(SlotPool::sim(0.9, 0.05, 2));
        let mut handles = Vec::new();
        for _ in 0..6 {
            let p = pool.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..20 {
                    let s = p.acquire();
                    p.release(s);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(pool.available(), 2);
    }

    #[test]
    fn affinity_checkout_routes_to_longest_matching_prefix() {
        let pool = SlotPool::sim(0.9, 0.05, 3).with_prefix_cache(true);
        let mut a = pool.try_acquire().unwrap();
        let mut b = pool.try_acquire().unwrap();
        let c = pool.try_acquire().unwrap();
        a.record_prefix(&[1, 5, 6, 7, 8], 5);
        b.record_prefix(&[1, 5, 6, 9], 4);
        let (a_id, b_id) = (a.id, b.id);
        pool.release(a);
        pool.release(b);
        pool.release(c); // no prefix recorded

        // prompt matching slot a's prefix for 4 tokens, slot b's for 3
        let (slot, reuse) = pool.try_acquire_for(&[1, 5, 6, 7, 2, 2]).unwrap();
        assert_eq!(slot.id, a_id, "longest match wins");
        assert_eq!(reuse, 4);
        pool.release(slot);

        // full-prefix match is capped at prompt_len − 1 (the last prompt
        // token is always re-fed)
        let (slot, reuse) = pool.try_acquire_for(&[1, 5, 6, 9]).unwrap();
        assert_eq!(slot.id, b_id);
        assert_eq!(reuse, 3);
        pool.release(slot);

        let stats = pool.cache_stats();
        assert_eq!(stats.lookups.load(Ordering::Relaxed), 5, "3 anonymous + 2 affinity");
        assert_eq!(stats.hits.load(Ordering::Relaxed), 2);
        assert_eq!(stats.cached_tokens.load(Ordering::Relaxed), 7);
    }

    #[test]
    fn miss_prefers_unprefixed_slot_and_counts_evictions() {
        let pool = SlotPool::sim(0.9, 0.05, 2).with_prefix_cache(true);
        let mut a = pool.try_acquire().unwrap();
        let b = pool.try_acquire().unwrap();
        a.record_prefix(&[9, 9, 9], 3);
        let (a_id, b_id) = (a.id, b.id);
        pool.release(a);
        pool.release(b);

        // a miss takes the un-prefixed slot, preserving a's cached prefix
        let (slot, reuse) = pool.try_acquire_for(&[4, 4]).unwrap();
        assert_eq!((slot.id, reuse), (b_id, 0));
        assert_eq!(pool.cache_stats().evictions.load(Ordering::Relaxed), 0);
        // a second concurrent miss must now evict a's prefix
        let (slot2, reuse2) = pool.try_acquire_for(&[4, 4]).unwrap();
        assert_eq!((slot2.id, reuse2), (a_id, 0));
        assert!(slot2.resident_prefix().is_empty(), "miss checkout resets the record");
        assert_eq!(pool.cache_stats().evictions.load(Ordering::Relaxed), 1);
        // and the evicted prefix no longer matches anything
        pool.release(slot);
        pool.release(slot2);
        let (_, reuse3) = pool.try_acquire_for(&[9, 9, 9, 9]).unwrap();
        assert_eq!(reuse3, 0);
    }

    #[test]
    fn cache_off_never_reuses_or_records() {
        let pool = SlotPool::sim(0.9, 0.05, 1);
        let mut a = pool.try_acquire().unwrap();
        a.record_prefix(&[1, 2, 3], 3);
        pool.release(a);
        assert_eq!(pool.peek_reuse(&[1, 2, 3, 4]), 0);
        let (slot, reuse) = pool.try_acquire_for(&[1, 2, 3, 4]).unwrap();
        assert_eq!(reuse, 0, "disabled cache must never report reuse");
        assert!(slot.resident_prefix().is_empty(), "release dropped the record");
        assert_eq!(pool.cache_stats().lookups.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn peek_reuse_matches_subsequent_checkout() {
        let pool = SlotPool::sim(0.9, 0.05, 1).with_prefix_cache(true);
        let mut a = pool.try_acquire().unwrap();
        a.record_prefix(&[3, 4, 5, 6], 4);
        pool.release(a);
        let prompt = [3u32, 4, 5, 8, 8];
        assert_eq!(pool.peek_reuse(&prompt), 3);
        let (_, reuse) = pool.try_acquire_for(&prompt).unwrap();
        assert_eq!(reuse, 3);
    }
}
