//! KV slot pool — per-sequence device state (draft + target worlds) that
//! survives across requests. A slot owns one model pair; acquiring a slot
//! is O(1) because the contiguous-cursor protocol never needs the KV cache
//! cleared (stale entries beyond the cursor are dead by construction).
//!
//! The pool is shared by all decode workers (`&self` API behind a
//! mutex + condvar, DESIGN.md §2): checkout moves the `Slot` out of the
//! pool, so a checked-out slot is owned by exactly one worker with no
//! further synchronization. `acquire` blocks until a slot frees up, which
//! lets the worker count exceed the slot count without panicking — extra
//! workers simply queue at the checkout.
//!
//! The continuous engine (docs/ARCHITECTURE.md §11) is the pool's sole
//! consumer in `Continuous` mode: the step loop admits with the
//! non-blocking `try_acquire` (a free slot it observes cannot be taken
//! by anyone else) and releases at retire, so slot occupancy equals its
//! in-flight session count by construction. The slot's resident models
//! idle there — batched drafting/verification own the per-sequence
//! state, keyed by the slot `id` — but the `id` and the `served`
//! counter still anchor sequence identity and reuse accounting.

use std::sync::{Arc, Condvar, Mutex};

use anyhow::Result;

use crate::models::sim::Scenario;
use crate::models::{LanguageModel, ModelAssets, PjrtModel, SimModel};

/// One checked-out sequence state: a draft+target model pair whose KV
/// survives across requests. In the batched engine the slot `id` doubles
/// as the sequence key the verification batcher keys resident state on.
pub struct Slot {
    /// stable slot id (== `BatchItem::seq` in the batched engine)
    pub id: usize,
    /// the slot's resident draft model
    pub draft: Box<dyn LanguageModel>,
    /// the slot's resident target model (idle while the verification
    /// batcher is enabled — its geometry still drives headroom checks)
    pub target: Box<dyn LanguageModel>,
    /// requests served by this slot (reuse diagnostics)
    pub served: u64,
}

/// The shared checkout pool of KV slots (blocking condvar checkout).
pub struct SlotPool {
    free: Mutex<Vec<Slot>>,
    freed: Condvar,
    total: usize,
}

impl SlotPool {
    /// Pool over explicit (draft, target) model pairs.
    pub fn from_pairs(pairs: Vec<(Box<dyn LanguageModel>, Box<dyn LanguageModel>)>) -> SlotPool {
        let total = pairs.len();
        let free = pairs
            .into_iter()
            .enumerate()
            .map(|(id, (draft, target))| Slot { id, draft, target, served: 0 })
            .collect();
        SlotPool { free: Mutex::new(free), freed: Condvar::new(), total }
    }

    /// `n` PJRT slots sharing one set of weights/executables.
    pub fn pjrt(
        draft_assets: &Arc<ModelAssets>,
        target_assets: &Arc<ModelAssets>,
        n: usize,
    ) -> Result<SlotPool> {
        let mut pairs: Vec<(Box<dyn LanguageModel>, Box<dyn LanguageModel>)> =
            Vec::with_capacity(n);
        for _ in 0..n {
            pairs.push((
                Box::new(PjrtModel::new(draft_assets.clone())?),
                Box::new(PjrtModel::new(target_assets.clone())?),
            ));
        }
        Ok(SlotPool::from_pairs(pairs))
    }

    /// `n` simulator slots; each request reseats the scenario via
    /// `LanguageModel::begin_request`.
    pub fn sim(quality: f32, rel_cost: f64, n: usize) -> SlotPool {
        let placeholder = Scenario::new(0, "qa");
        let pairs = (0..n)
            .map(|_| {
                (
                    Box::new(SimModel::draft(placeholder, quality, rel_cost))
                        as Box<dyn LanguageModel>,
                    Box::new(SimModel::target(placeholder)) as Box<dyn LanguageModel>,
                )
            })
            .collect();
        SlotPool::from_pairs(pairs)
    }

    /// Non-blocking checkout.
    pub fn try_acquire(&self) -> Option<Slot> {
        self.free.lock().unwrap().pop()
    }

    /// Blocking checkout: waits until another worker releases a slot.
    pub fn acquire(&self) -> Slot {
        let mut free = self.free.lock().unwrap();
        loop {
            if let Some(slot) = free.pop() {
                return slot;
            }
            free = self.freed.wait(free).unwrap();
        }
    }

    /// Bounded blocking checkout: like [`SlotPool::acquire`], but gives
    /// up after `timeout`. Decode workers poll this in a loop so a
    /// request that is cancelled or expires *while waiting for a slot*
    /// exits the lifecycle promptly instead of blocking until a slot
    /// frees (server.rs).
    pub fn acquire_timeout(&self, timeout: std::time::Duration) -> Option<Slot> {
        let deadline = std::time::Instant::now() + timeout;
        let mut free = self.free.lock().unwrap();
        loop {
            if let Some(slot) = free.pop() {
                return Some(slot);
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return None;
            }
            let (g, _res) = self.freed.wait_timeout(free, deadline - now).unwrap();
            free = g;
        }
    }

    /// Return a checked-out slot and wake one blocked `acquire`.
    pub fn release(&self, mut slot: Slot) {
        slot.served += 1;
        self.free.lock().unwrap().push(slot);
        self.freed.notify_one();
    }

    /// Slots currently free.
    pub fn available(&self) -> usize {
        self.free.lock().unwrap().len()
    }

    /// Total slots the pool was built with.
    pub fn total(&self) -> usize {
        self.total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn checkout_and_release_cycle() {
        let pool = SlotPool::sim(0.9, 0.05, 2);
        assert_eq!(pool.total(), 2);
        let a = pool.try_acquire().unwrap();
        let b = pool.try_acquire().unwrap();
        assert!(pool.try_acquire().is_none());
        assert_eq!(pool.available(), 0);
        pool.release(a);
        pool.release(b);
        assert_eq!(pool.available(), 2);
        let c = pool.try_acquire().unwrap();
        assert_eq!(c.served, 1, "release counts a completed checkout");
    }

    #[test]
    fn blocking_acquire_waits_for_release() {
        let pool = Arc::new(SlotPool::sim(0.9, 0.05, 1));
        let slot = pool.try_acquire().unwrap();
        let p = pool.clone();
        let waiter = std::thread::spawn(move || {
            let s = p.acquire(); // blocks until the main thread releases
            p.release(s);
        });
        std::thread::sleep(Duration::from_millis(20));
        pool.release(slot);
        waiter.join().unwrap();
        assert_eq!(pool.available(), 1);
    }

    #[test]
    fn acquire_timeout_gives_up_and_succeeds() {
        let pool = SlotPool::sim(0.9, 0.05, 1);
        let held = pool.try_acquire().unwrap();
        assert!(
            pool.acquire_timeout(Duration::from_millis(10)).is_none(),
            "no slot can free while we hold the only one"
        );
        pool.release(held);
        assert!(pool.acquire_timeout(Duration::from_millis(10)).is_some());
    }

    #[test]
    fn more_workers_than_slots_all_make_progress() {
        let pool = Arc::new(SlotPool::sim(0.9, 0.05, 2));
        let mut handles = Vec::new();
        for _ in 0..6 {
            let p = pool.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..20 {
                    let s = p.acquire();
                    p.release(s);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(pool.available(), 2);
    }
}
