//! KV slot pool — per-sequence device state (draft + target worlds) that
//! survives across requests. A slot owns one `PjrtModel` pair; acquiring a
//! slot is O(1) because the contiguous-cursor protocol never needs the KV
//! cache cleared (stale entries beyond the cursor are dead by construction).

use std::sync::Arc;

use anyhow::Result;

use crate::models::{ModelAssets, PjrtModel};

pub struct Slot {
    pub id: usize,
    pub draft: PjrtModel,
    pub target: PjrtModel,
    /// requests served by this slot (reuse diagnostics)
    pub served: u64,
}

pub struct SlotPool {
    free: Vec<Slot>,
    total: usize,
}

impl SlotPool {
    pub fn new(
        draft_assets: &Arc<ModelAssets>,
        target_assets: &Arc<ModelAssets>,
        n: usize,
    ) -> Result<SlotPool> {
        let mut free = Vec::with_capacity(n);
        for id in 0..n {
            free.push(Slot {
                id,
                draft: PjrtModel::new(draft_assets.clone())?,
                target: PjrtModel::new(target_assets.clone())?,
                served: 0,
            });
        }
        Ok(SlotPool { free, total: n })
    }

    pub fn acquire(&mut self) -> Option<Slot> {
        self.free.pop()
    }

    pub fn release(&mut self, mut slot: Slot) {
        slot.served += 1;
        self.free.push(slot);
    }

    pub fn available(&self) -> usize {
        self.free.len()
    }

    pub fn total(&self) -> usize {
        self.total
    }
}
