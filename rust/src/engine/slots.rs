//! KV slot pool — per-sequence device state (draft + target worlds) that
//! survives across requests, allocated over a paged KV arena. A slot owns
//! one model pair; acquiring a slot is O(1) because the contiguous-cursor
//! protocol never needs the KV cache cleared (stale entries beyond the
//! cursor are dead by construction).
//!
//! The pool is shared by all decode workers (`&self` API behind a
//! mutex + condvar, DESIGN.md §2): checkout moves the `Slot` out of the
//! pool, so a checked-out slot is owned by exactly one worker with no
//! further synchronization. `acquire` blocks until a slot frees up, which
//! lets the worker count exceed the slot count without panicking — extra
//! workers simply queue at the checkout.
//!
//! **Paged prefix-reuse routing (docs/ARCHITECTURE.md §12–§13).**
//! Checkout is not an anonymous pop: each slot carries resident-prefix
//! metadata (the token ids its KV covers below the cursor watermark,
//! recorded by the engine at release via [`Slot::record_prefix`]), a
//! [`PrefixIndex`] routes prompts to matching residencies, and a
//! [`PagePool`] tracks which fixed-size KV pages each slot's residency
//! maps. The affinity checkout ([`SlotPool::try_acquire_for`],
//! [`SlotPool::acquire_for_timeout`]) returns a [`Lease`] describing two
//! reuse depths, both capped at `prompt_len − 1` so the last prompt token
//! is always re-fed (every decode round needs its signal row):
//!
//!   * `local` — positions of the checked-out slot's *own* resident
//!     state that match the prompt (PR-5 slot-affinity reuse: valid on
//!     every backend via the contiguous-cursor contract);
//!   * `shared ≥ local` — positions covered by token-matching pages,
//!     possibly computed under a *different, still-busy* slot and mapped
//!     in copy-on-write. Only offered when the pool is **adoptive** (its
//!     backends declare content-addressed KV via
//!     `LanguageModel::page_view`) and page sharing is enabled; on other
//!     pools `shared == local` always.
//!
//! The engine threads the lease through
//! `LanguageModel::adopt_pages(seed, category, local, shared)`: adoptive
//! backends take the full `shared` residency, others fall back to
//! `retain_prefix(local)` — so sharing degrades to slot-affinity reuse,
//! never to corruption. The reset-vs-retain contract is unchanged from
//! §12: a miss (`shared == 0`) starts the slot fresh and discards its
//! stale recorded prefix (counting an eviction); a hit rolls cursors to
//! the reuse depth and prefills only the suffix.
//!
//! **Busy-slot sharing.** With page sharing active, a slot's registration
//! is *not* dropped at checkout — the checkout re-registers the slot
//! under its new prompt, so a concurrent request sharing that prompt's
//! prefix hits immediately (the N-requests-one-system-prompt burst no
//! longer serializes on slot availability, and the pages are held ~once).
//! Without sharing (non-adoptive backends, `--no-page-sharing`, or cache
//! off) registrations exist only while the slot is free — exactly the
//! PR-5 behavior.
//!
//! **Eviction** is page-LRU over *cached* residencies: under arena
//! pressure the pool reclaims free slots' chains (least recently released
//! first) and never touches a checked-out slot's pages; with the default
//! auto-sized arena pressure cannot occur at all. With the cache disabled
//! the pool behaves exactly like the anonymous pool (every checkout
//! reports zero reuse, nothing is recorded, page gauges stay zero).
//!
//! The continuous engine (docs/ARCHITECTURE.md §11) is the pool's sole
//! consumer in `Continuous` mode: the step loop admits with the
//! non-blocking affinity checkout (a free slot it observes cannot be
//! taken by anyone else) and releases at retire, so slot occupancy equals
//! its in-flight session count by construction. The slot's resident
//! models idle there — batched drafting/verification own the
//! per-sequence state, keyed by the slot `id` — but the `id`, the
//! recorded prefix, and the `served` counter still anchor sequence
//! identity and reuse accounting.

use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::models::sim::Scenario;
use crate::models::{LanguageModel, ModelAssets, PjrtModel, SimModel};

use super::cache::PrefixIndex;
use super::metrics::{CacheStats, PageStats};
use super::paging::PagePool;

/// Smallest prefix match that counts as a cache hit. Every encoded
/// prompt starts with BOS, so any two prompts trivially share one
/// leading token; treating that as a hit would make *every* checkout
/// "reuse" a slot (never resetting, never evicting) while saving a
/// single prefill row. Matches shorter than this are misses.
pub const MIN_REUSE: usize = 2;

/// Default KV page granularity, in tokens (`serve --page-size`).
pub const DEFAULT_PAGE_SIZE: usize = 16;

/// What an affinity checkout grants the caller
/// (docs/ARCHITECTURE.md §13): how much of the prompt is already
/// resident, and on whose authority. `local` positions are vouched by
/// the checked-out slot's own sequence state (sound on every backend);
/// `shared ≥ local` positions are vouched by token-matching KV pages —
/// beyond `local` they were computed under a different slot and are only
/// taken by adoptive backends (`LanguageModel::adopt_pages`). A miss is
/// `Lease::default()` (both zero).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Lease {
    /// prompt positions matching this slot's own resident state
    pub local: usize,
    /// prompt positions covered by token-matching pages (≥ `local`)
    pub shared: usize,
}

/// One checked-out sequence state: a draft+target model pair whose KV
/// survives across requests. In the batched engine the slot `id` doubles
/// as the sequence key the verification batcher keys resident state on.
pub struct Slot {
    /// stable slot id (== `BatchItem::seq` in the batched engine)
    pub id: usize,
    /// the slot's resident draft model
    pub draft: Box<dyn LanguageModel>,
    /// the slot's resident target model (idle while the verification
    /// batcher is enabled — its geometry still drives headroom checks)
    pub target: Box<dyn LanguageModel>,
    /// requests served by this slot (reuse diagnostics)
    pub served: u64,
    /// token ids resident in this slot's sequence state below the cursor
    /// watermark (`prefix.len()` *is* the watermark — the engine records
    /// the tokens truncated to `min(draft cursor, target cursor)` at
    /// release, docs/ARCHITECTURE.md §12)
    prefix: Vec<u32>,
}

impl Slot {
    /// The resident token prefix recorded at the last release (empty for
    /// a fresh or reset slot).
    pub fn resident_prefix(&self) -> &[u32] {
        &self.prefix
    }

    /// Record this slot's resident sequence state for prefix-reuse
    /// routing: `tokens` is the committed sequence the slot's models just
    /// decoded, `watermark` the lowest of their cursor positions (KV at
    /// positions `< watermark` is resident and was computed from exactly
    /// these token ids). Call before [`SlotPool::release`]; the pool
    /// indexes whatever is recorded here.
    pub fn record_prefix(&mut self, tokens: &[u32], watermark: usize) {
        self.prefix.clear();
        self.prefix.extend_from_slice(&tokens[..watermark.min(tokens.len())]);
    }

    /// Forget the recorded prefix (a failed decode leaves the resident
    /// state untrusted — the next tenant must start fresh).
    pub fn clear_prefix(&mut self) {
        self.prefix.clear();
    }
}

struct PoolInner {
    free: Vec<Slot>,
    index: PrefixIndex,
    pages: PagePool,
}

/// The shared checkout pool of KV slots (blocking condvar checkout), with
/// optional paged prefix-reuse routing: same-slot affinity plus
/// copy-on-write page sharing against busy slots on adoptive backends.
pub struct SlotPool {
    inner: Mutex<PoolInner>,
    freed: Condvar,
    total: usize,
    cache_on: bool,
    /// do the slot models declare content-addressed (adoptable) KV?
    adoptive: bool,
    /// is cross-slot page sharing allowed? (config switch; only
    /// effective on adoptive pools)
    sharing: bool,
    page_size: usize,
    kv_pages: usize,
    max_seq: usize,
    cache: CacheStats,
    pages: PageStats,
}

impl SlotPool {
    /// Pool over explicit (draft, target) model pairs (prefix cache off;
    /// see [`SlotPool::with_prefix_cache`]). Paged-KV capability is
    /// probed from the models themselves: the pool is adoptive exactly
    /// when every slot's draft *and* target declare adoptive page views.
    pub fn from_pairs(pairs: Vec<(Box<dyn LanguageModel>, Box<dyn LanguageModel>)>) -> SlotPool {
        let total = pairs.len();
        let adoptive = !pairs.is_empty()
            && pairs.iter().all(|(d, t)| d.page_view().adoptive && t.page_view().adoptive);
        let max_seq =
            pairs.iter().map(|(d, t)| d.max_seq().max(t.max_seq())).max().unwrap_or(0);
        let free: Vec<Slot> = pairs
            .into_iter()
            .enumerate()
            .map(|(id, (draft, target))| Slot {
                id,
                draft,
                target,
                served: 0,
                prefix: Vec::new(),
            })
            .collect();
        SlotPool {
            inner: Mutex::new(PoolInner {
                free,
                index: PrefixIndex::new(),
                pages: PagePool::new(DEFAULT_PAGE_SIZE, 0, total, max_seq),
            }),
            freed: Condvar::new(),
            total,
            cache_on: false,
            adoptive,
            sharing: true,
            page_size: DEFAULT_PAGE_SIZE,
            kv_pages: 0,
            max_seq,
            cache: CacheStats::new(total, false),
            pages: PageStats::new(false),
        }
    }

    /// Enable (or explicitly disable) cross-request prefix reuse. With
    /// the cache off every checkout reports zero reuse and nothing is
    /// indexed — byte-identical to the anonymous pool, all cache and
    /// page gauges zero.
    pub fn with_prefix_cache(mut self, enabled: bool) -> SlotPool {
        self.cache_on = enabled;
        self.cache = CacheStats::new(self.total, enabled);
        self.pages = PageStats::new(enabled);
        if enabled {
            self.pages.sync(&self.inner.get_mut().unwrap().pages);
        }
        self
    }

    /// Set the KV page geometry: `page_size` tokens per page and
    /// `kv_pages` total pages (0 = auto:
    /// `slots × ceil(max_seq / page_size)`, at which eviction never
    /// fires). Rebuilds the arena, so call before serving traffic.
    pub fn with_paging(mut self, page_size: usize, kv_pages: usize) -> SlotPool {
        self.page_size = page_size.max(1);
        self.kv_pages = kv_pages;
        let inner = self.inner.get_mut().unwrap();
        inner.pages = PagePool::new(self.page_size, kv_pages, self.total, self.max_seq);
        if self.cache_on {
            self.pages = PageStats::new(true);
            self.pages.sync(&self.inner.get_mut().unwrap().pages);
        }
        self
    }

    /// Allow or forbid cross-slot copy-on-write page sharing (on by
    /// default; only effective on adoptive pools). With sharing off the
    /// pool reproduces PR-5 slot-affinity reuse exactly — the bench
    /// baseline.
    pub fn with_page_sharing(mut self, enabled: bool) -> SlotPool {
        self.sharing = enabled;
        self
    }

    /// Is prefix-reuse routing enabled?
    pub fn prefix_cache_enabled(&self) -> bool {
        self.cache_on
    }

    /// The pool's KV page granularity, in tokens (also the chunked
    /// prefill alignment unit — stepper.rs).
    pub fn page_size(&self) -> usize {
        self.page_size
    }

    /// Is cross-slot page sharing live (cache on + adoptive backends +
    /// sharing not disabled)? This is also the engine's signal for
    /// whether `Lease::shared` can exceed `Lease::local`.
    pub fn sharing_active(&self) -> bool {
        self.cache_on && self.adoptive && self.sharing
    }

    /// The pool's cache gauges (the `/metrics` `engine.cache` source).
    pub fn cache_stats(&self) -> &CacheStats {
        &self.cache
    }

    /// The pool's page gauges (the `/metrics` `engine.pages` source).
    pub fn page_stats(&self) -> &PageStats {
        &self.pages
    }

    /// Passthrough to [`PagePool::conservation_error`] on the pool's page
    /// arena — the sim harness's shadow oracle polls this after every event.
    pub fn page_conservation_error(&self) -> Option<String> {
        self.inner.lock().unwrap().pages.conservation_error()
    }

    /// Run `f` against the page arena. Test-only escape hatch so the sim
    /// harness can reach [`PagePool::debug_leak_page`] for deliberate
    /// violation-injection runs.
    #[doc(hidden)]
    pub fn with_pages_mut<R>(&self, f: impl FnOnce(&mut PagePool) -> R) -> R {
        f(&mut self.inner.lock().unwrap().pages)
    }

    /// Pages currently mapped by slot `slot`'s chain (tests/diagnostics).
    pub fn chain_pages(&self, slot: usize) -> usize {
        self.inner.lock().unwrap().pages.chain_pages(slot)
    }

    /// `n` PJRT slots sharing one set of weights/executables.
    pub fn pjrt(
        draft_assets: &Arc<ModelAssets>,
        target_assets: &Arc<ModelAssets>,
        n: usize,
    ) -> Result<SlotPool> {
        let mut pairs: Vec<(Box<dyn LanguageModel>, Box<dyn LanguageModel>)> =
            Vec::with_capacity(n);
        for _ in 0..n {
            pairs.push((
                Box::new(PjrtModel::new(draft_assets.clone())?),
                Box::new(PjrtModel::new(target_assets.clone())?),
            ));
        }
        Ok(SlotPool::from_pairs(pairs))
    }

    /// `n` simulator slots; each request reseats the scenario via
    /// `LanguageModel::adopt_pages` / `LanguageModel::begin_request`.
    pub fn sim(quality: f32, rel_cost: f64, n: usize) -> SlotPool {
        let placeholder = Scenario::new(0, "qa");
        let pairs = (0..n)
            .map(|_| {
                (
                    Box::new(SimModel::draft(placeholder, quality, rel_cost))
                        as Box<dyn LanguageModel>,
                    Box::new(SimModel::target(placeholder)) as Box<dyn LanguageModel>,
                )
            })
            .collect();
        SlotPool::from_pairs(pairs)
    }

    /// Reclaim cached (free-slot) page chains, least recently released
    /// first, until `fresh_pages` can be allocated or only live chains
    /// remain (then downstream extension saturates — a live session's
    /// pages are never touched). The bound is conservative: under real
    /// pressure evicting a cached residency early is the cheap outcome.
    fn ensure_headroom(&self, inner: &mut PoolInner, fresh_pages: usize) {
        while inner.pages.free_pages() < fresh_pages {
            let Some(pos) =
                (0..inner.free.len()).find(|&i| inner.pages.chain_pages(inner.free[i].id) > 0)
            else {
                break;
            };
            let sid = inner.free[pos].id;
            inner.free[pos].prefix.clear();
            if let Some(reg) = inner.index.registration(sid).map(|r| r.to_vec()) {
                inner.index.remove(sid, &reg);
            }
            inner.pages.evict_chain(sid);
            self.cache.note_eviction();
        }
    }

    /// The checkout core, under the pool mutex. Resolution order:
    /// deepest *free* match (same-slot reuse — identical result, no page
    /// copies), else deepest match overall (cross-slot page share, only
    /// with sharing active — the source is necessarily busy, or the free
    /// branch would have won), else miss on the least-recently released
    /// un-prefixed slot. Page chains are re-shaped here so the `engine.
    /// pages` gauges reflect the checkout before the decode starts.
    fn checkout_locked(&self, inner: &mut PoolInner, prompt: &[u32]) -> Option<(Slot, Lease)> {
        if inner.free.is_empty() {
            return None;
        }
        if !self.cache_on {
            return inner.free.pop().map(|s| (s, Lease::default()));
        }
        self.pages.note_lookup();
        let cap = prompt.len().saturating_sub(1);
        let ps = inner.pages.page_size();
        let free_ids: Vec<usize> = inner.free.iter().map(|s| s.id).collect();
        let local = inner
            .index
            .best_match_where(prompt, |s| free_ids.contains(&s))
            .map(|(sid, lcp)| (sid, lcp.min(cap)))
            .filter(|&(_, r)| r >= MIN_REUSE);
        let shared = if self.sharing_active() {
            inner
                .index
                .best_match(prompt)
                .map(|(sid, lcp)| (sid, lcp.min(cap)))
                .filter(|&(_, r)| r >= MIN_REUSE)
        } else {
            None
        };

        // same-slot reuse wins ties: same resident tokens, no page copies
        if let Some((sid, reuse)) = local {
            if !shared.is_some_and(|(_, rs)| rs > reuse) {
                let pos = inner
                    .free
                    .iter()
                    .position(|s| s.id == sid)
                    .expect("indexed free slot is on the free list");
                let slot = inner.free.remove(pos);
                let fresh = prompt.len().div_ceil(ps).saturating_sub(reuse.div_ceil(ps)) + 1;
                self.ensure_headroom(inner, fresh);
                inner.pages.reacquire(sid, reuse, prompt.len());
                if self.sharing_active() {
                    // stay registered while busy, under the new content
                    inner.index.insert(sid, prompt);
                } else {
                    inner.index.remove(sid, &slot.prefix);
                }
                self.cache.note_lookup(prompt.len(), reuse);
                self.pages.sync(&inner.pages);
                return Some((slot, Lease { local: reuse, shared: reuse }));
            }
        }

        if let Some((src, reuse)) = shared {
            // cross-slot page share: the matching residency is busy (a
            // free match this deep would have won above) — map its
            // prefix pages copy-on-write onto a victim slot instead of
            // waiting for the source to free
            let pick = inner.free.iter().position(|s| s.prefix.is_empty()).unwrap_or(0);
            let mut slot = inner.free.remove(pick);
            if !slot.prefix.is_empty() {
                inner.index.remove(slot.id, &slot.prefix);
                slot.prefix.clear();
                self.cache.note_eviction();
            }
            let fresh = prompt.len().div_ceil(ps).saturating_sub(reuse / ps) + 1;
            self.ensure_headroom(inner, fresh);
            inner.pages.adopt(slot.id, src, reuse, prompt.len());
            inner.index.insert(slot.id, prompt);
            self.cache.note_lookup(prompt.len(), reuse);
            self.pages.sync(&inner.pages);
            return Some((slot, Lease { local: 0, shared: reuse }));
        }

        // miss: prefer a slot with no cached prefix; otherwise evict the
        // least-recently released one (front of the free list)
        let pick = inner.free.iter().position(|s| s.prefix.is_empty()).unwrap_or(0);
        let mut slot = inner.free.remove(pick);
        if !slot.prefix.is_empty() {
            inner.index.remove(slot.id, &slot.prefix);
            slot.prefix.clear();
            self.cache.note_eviction();
        }
        self.ensure_headroom(inner, prompt.len().div_ceil(ps) + 1);
        inner.pages.reacquire(slot.id, 0, prompt.len());
        if self.sharing_active() {
            // register the prompt immediately: a same-wave request with
            // this prefix shares pages instead of re-prefilling (the
            // busy-slot contention win the paged allocator exists for)
            inner.index.insert(slot.id, prompt);
        }
        self.cache.note_lookup(prompt.len(), 0);
        self.pages.sync(&inner.pages);
        Some((slot, Lease::default()))
    }

    /// Non-blocking affinity checkout: the slot with the deepest valid
    /// reuse for `prompt` plus the [`Lease`] describing it (`default()` =
    /// start fresh). See the module docs for the reset-vs-retain
    /// contract.
    pub fn try_acquire_for(&self, prompt: &[u32]) -> Option<(Slot, Lease)> {
        let mut inner = self.inner.lock().unwrap();
        self.checkout_locked(&mut inner, prompt)
    }

    /// Bounded blocking affinity checkout: like
    /// [`SlotPool::try_acquire_for`], but waits up to `timeout` for a
    /// slot to free. Decode workers poll this in a loop so a request that
    /// is cancelled or expires *while waiting for a slot* exits the
    /// lifecycle promptly instead of blocking until a slot frees
    /// (server.rs).
    ///
    /// Deadline edge: the free list is always re-checked *after* the
    /// final `wait_timeout` returns — a slot released exactly at the
    /// deadline instant is returned, not dropped for `None` (pinned by
    /// `release_at_deadline_instant_is_still_returned`).
    pub fn acquire_for_timeout(&self, prompt: &[u32], timeout: Duration) -> Option<(Slot, Lease)> {
        let deadline = Instant::now() + timeout;
        let mut inner = self.inner.lock().unwrap();
        loop {
            // checkout before the deadline test: after the last wake (or
            // with the deadline already past at entry) a freed slot must
            // still win over the timeout
            if let Some(got) = self.checkout_locked(&mut inner, prompt) {
                return Some(got);
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            let (g, _res) = self.freed.wait_timeout(inner, deadline - now).unwrap();
            inner = g;
        }
    }

    /// Non-blocking anonymous checkout (no affinity; the slot still
    /// resets per the miss contract when the cache is on).
    pub fn try_acquire(&self) -> Option<Slot> {
        self.try_acquire_for(&[]).map(|(s, _)| s)
    }

    /// Blocking anonymous checkout: waits until another worker releases
    /// a slot.
    pub fn acquire(&self) -> Slot {
        let mut inner = self.inner.lock().unwrap();
        loop {
            if let Some((slot, _)) = self.checkout_locked(&mut inner, &[]) {
                return slot;
            }
            inner = self.freed.wait(inner).unwrap();
        }
    }

    /// Bounded blocking anonymous checkout ([`SlotPool::acquire`] with a
    /// timeout; same deadline-edge contract as
    /// [`SlotPool::acquire_for_timeout`]).
    pub fn acquire_timeout(&self, timeout: Duration) -> Option<Slot> {
        self.acquire_for_timeout(&[], timeout).map(|(s, _)| s)
    }

    /// Expected reuse (in prompt tokens) if a request with this prompt
    /// checked out right now — the scheduler's affinity placement hint
    /// (scheduler.rs subtracts it from the SJF service-cost estimate).
    /// Advisory only: the resident set can change before the real
    /// checkout, which is why the dispatcher's hint is re-resolved at
    /// checkout time and repriced (server.rs, stepper.rs). With page
    /// sharing active the index covers busy slots too, so the hint sees
    /// the same residencies a real checkout would.
    pub fn peek_reuse(&self, prompt: &[u32]) -> usize {
        if !self.cache_on {
            return 0;
        }
        let inner = self.inner.lock().unwrap();
        inner
            .index
            .best_match(prompt)
            .map(|(_, lcp)| lcp.min(prompt.len().saturating_sub(1)))
            .filter(|&r| r >= MIN_REUSE)
            .unwrap_or(0)
    }

    /// Return a checked-out slot and wake one blocked `acquire`. With the
    /// prefix cache on, whatever [`Slot::record_prefix`] recorded is
    /// indexed for affinity routing and the slot's page chain is resized
    /// to exactly the recorded residency; with it off the recorded prefix
    /// is dropped so reuse can never happen accidentally.
    pub fn release(&self, mut slot: Slot) {
        slot.served += 1;
        if self.cache_on {
            // mirror per-slot served into the cache gauges (the
            // `engine.cache` contract keeps every counter zero while
            // the cache is disabled; `Slot::served` stays authoritative)
            self.cache.note_served(slot.id);
        } else {
            slot.prefix.clear();
        }
        let mut inner = self.inner.lock().unwrap();
        if self.cache_on {
            inner.pages.resize(slot.id, slot.prefix.len());
            // re-registration short-circuits in O(1) when the prefix is
            // unchanged (release-then-reacquire of the same residency),
            // and clears the registration when the prefix is empty
            inner.index.insert(slot.id, &slot.prefix);
            self.pages.sync(&inner.pages);
        }
        inner.free.push(slot);
        self.freed.notify_one();
    }

    /// Slots currently free.
    pub fn available(&self) -> usize {
        self.inner.lock().unwrap().free.len()
    }

    /// Total slots the pool was built with.
    pub fn total(&self) -> usize {
        self.total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::Ordering;

    #[test]
    fn checkout_and_release_cycle() {
        let pool = SlotPool::sim(0.9, 0.05, 2);
        assert_eq!(pool.total(), 2);
        let a = pool.try_acquire().unwrap();
        let b = pool.try_acquire().unwrap();
        assert!(pool.try_acquire().is_none());
        assert_eq!(pool.available(), 0);
        pool.release(a);
        pool.release(b);
        assert_eq!(pool.available(), 2);
        let c = pool.try_acquire().unwrap();
        assert_eq!(c.served, 1, "release counts a completed checkout");
    }

    #[test]
    fn blocking_acquire_waits_for_release() {
        let pool = Arc::new(SlotPool::sim(0.9, 0.05, 1));
        let slot = pool.try_acquire().unwrap();
        let p = pool.clone();
        let waiter = std::thread::spawn(move || {
            let s = p.acquire(); // blocks until the main thread releases
            p.release(s);
        });
        std::thread::sleep(Duration::from_millis(20));
        pool.release(slot);
        waiter.join().unwrap();
        assert_eq!(pool.available(), 1);
    }

    #[test]
    fn acquire_timeout_gives_up_and_succeeds() {
        let pool = SlotPool::sim(0.9, 0.05, 1);
        let held = pool.try_acquire().unwrap();
        assert!(
            pool.acquire_timeout(Duration::from_millis(10)).is_none(),
            "no slot can free while we hold the only one"
        );
        pool.release(held);
        assert!(pool.acquire_timeout(Duration::from_millis(10)).is_some());
    }

    #[test]
    fn release_at_deadline_instant_is_still_returned() {
        // the deadline-edge contract: even with the deadline already in
        // the past, a slot on the free list wins over the timeout — the
        // free list is checked after the final wait, not before it
        let pool = SlotPool::sim(0.9, 0.05, 1);
        assert!(
            pool.acquire_timeout(Duration::ZERO).is_some(),
            "a free slot at the deadline instant must be returned"
        );
        // and with the slot held, the zero timeout gives up cleanly
        assert!(pool.acquire_timeout(Duration::ZERO).is_none());
    }

    #[test]
    fn more_workers_than_slots_all_make_progress() {
        let pool = Arc::new(SlotPool::sim(0.9, 0.05, 2));
        let mut handles = Vec::new();
        for _ in 0..6 {
            let p = pool.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..20 {
                    let s = p.acquire();
                    p.release(s);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(pool.available(), 2);
    }

    #[test]
    fn affinity_checkout_routes_to_longest_matching_prefix() {
        let pool = SlotPool::sim(0.9, 0.05, 3).with_prefix_cache(true);
        let mut a = pool.try_acquire().unwrap();
        let mut b = pool.try_acquire().unwrap();
        let c = pool.try_acquire().unwrap();
        a.record_prefix(&[1, 5, 6, 7, 8], 5);
        b.record_prefix(&[1, 5, 6, 9], 4);
        let (a_id, b_id) = (a.id, b.id);
        pool.release(a);
        pool.release(b);
        pool.release(c); // no prefix recorded

        // prompt matching slot a's prefix for 4 tokens, slot b's for 3
        let (slot, lease) = pool.try_acquire_for(&[1, 5, 6, 7, 2, 2]).unwrap();
        assert_eq!(slot.id, a_id, "longest match wins");
        assert_eq!(lease, Lease { local: 4, shared: 4 }, "same-slot reuse: local == shared");
        pool.release(slot);

        // full-prefix match is capped at prompt_len − 1 (the last prompt
        // token is always re-fed)
        let (slot, lease) = pool.try_acquire_for(&[1, 5, 6, 9]).unwrap();
        assert_eq!(slot.id, b_id);
        assert_eq!(lease.shared, 3);
        pool.release(slot);

        let stats = pool.cache_stats();
        assert_eq!(stats.lookups.load(Ordering::Relaxed), 5, "3 anonymous + 2 affinity");
        assert_eq!(stats.hits.load(Ordering::Relaxed), 2);
        assert_eq!(stats.cached_tokens.load(Ordering::Relaxed), 7);
    }

    #[test]
    fn miss_prefers_unprefixed_slot_and_counts_evictions() {
        let pool = SlotPool::sim(0.9, 0.05, 2).with_prefix_cache(true);
        let mut a = pool.try_acquire().unwrap();
        let b = pool.try_acquire().unwrap();
        a.record_prefix(&[9, 9, 9], 3);
        let (a_id, b_id) = (a.id, b.id);
        pool.release(a);
        pool.release(b);

        // a miss takes the un-prefixed slot, preserving a's cached prefix
        let (slot, lease) = pool.try_acquire_for(&[4, 4]).unwrap();
        assert_eq!((slot.id, lease.shared), (b_id, 0));
        assert_eq!(pool.cache_stats().evictions.load(Ordering::Relaxed), 0);
        // a second concurrent miss must now evict a's prefix
        let (slot2, lease2) = pool.try_acquire_for(&[4, 4]).unwrap();
        assert_eq!((slot2.id, lease2.shared), (a_id, 0));
        assert!(slot2.resident_prefix().is_empty(), "miss checkout resets the record");
        assert_eq!(pool.cache_stats().evictions.load(Ordering::Relaxed), 1);
        // and the evicted prefix no longer matches anything
        pool.release(slot);
        pool.release(slot2);
        let (_, lease3) = pool.try_acquire_for(&[9, 9, 9, 9]).unwrap();
        assert_eq!(lease3.shared, 0);
    }

    #[test]
    fn cache_off_never_reuses_or_records() {
        let pool = SlotPool::sim(0.9, 0.05, 1);
        let mut a = pool.try_acquire().unwrap();
        a.record_prefix(&[1, 2, 3], 3);
        pool.release(a);
        assert_eq!(pool.peek_reuse(&[1, 2, 3, 4]), 0);
        let (slot, lease) = pool.try_acquire_for(&[1, 2, 3, 4]).unwrap();
        assert_eq!(lease, Lease::default(), "disabled cache must never report reuse");
        assert!(slot.resident_prefix().is_empty(), "release dropped the record");
        assert_eq!(pool.cache_stats().lookups.load(Ordering::Relaxed), 0);
        assert_eq!(pool.page_stats().lookups.load(Ordering::Relaxed), 0);
        assert_eq!(pool.page_stats().total.load(Ordering::Relaxed), 0, "page gauges stay zero");
    }

    #[test]
    fn peek_reuse_matches_subsequent_checkout() {
        let pool = SlotPool::sim(0.9, 0.05, 1).with_prefix_cache(true);
        let mut a = pool.try_acquire().unwrap();
        a.record_prefix(&[3, 4, 5, 6], 4);
        pool.release(a);
        let prompt = [3u32, 4, 5, 8, 8];
        assert_eq!(pool.peek_reuse(&prompt), 3);
        let (_, lease) = pool.try_acquire_for(&prompt).unwrap();
        assert_eq!(lease.shared, 3);
    }

    #[test]
    fn busy_slot_share_maps_pages_copy_on_write() {
        // the contention case PR 5 could not serve: the matching
        // residency is checked out, but the prompt still hits via pages
        let pool =
            SlotPool::sim(0.9, 0.05, 2).with_paging(4, 0).with_prefix_cache(true);
        assert!(pool.sharing_active(), "sim pools are adoptive");
        let prompt_a: Vec<u32> = vec![1, 2, 3, 4, 5, 6, 7, 8, 9, 10];
        let (slot_a, lease_a) = pool.try_acquire_for(&prompt_a).unwrap();
        assert_eq!(lease_a, Lease::default(), "first checkout is a miss");

        // while slot A is busy, a prompt sharing its first 9 tokens hits
        let prompt_b: Vec<u32> = vec![1, 2, 3, 4, 5, 6, 7, 8, 9, 20, 21];
        let (slot_b, lease_b) = pool.try_acquire_for(&prompt_b).unwrap();
        assert_ne!(slot_b.id, slot_a.id);
        assert_eq!(lease_b, Lease { local: 0, shared: 9 }, "busy-slot page share");

        let st = pool.page_stats();
        assert_eq!(st.shared_hits.load(Ordering::Relaxed), 1);
        assert_eq!(st.adopted_tokens.load(Ordering::Relaxed), 9);
        // 9 shared tokens at page_size 4 = 2 full pages shared + 1 cow
        assert_eq!(st.shared.load(Ordering::Relaxed), 2);
        assert_eq!(st.cow_copies.load(Ordering::Relaxed), 1);
        // A holds ceil(10/4) = 3 pages; B's chain is 2 shared + 1 cow
        // boundary page covering tokens 8..11 -> 4 distinct resident pages
        assert_eq!(st.total.load(Ordering::Relaxed) - st.free.load(Ordering::Relaxed), 4);

        let served_total: u64 = prompt_a.len() as u64 + prompt_b.len() as u64;
        let cached = pool.cache_stats().cached_tokens.load(Ordering::Relaxed);
        assert_eq!(cached, 9, "the share skips 9 of {served_total} prompt tokens");
        pool.release(slot_a);
        pool.release(slot_b);
    }

    #[test]
    fn page_refcounts_conserve_through_the_pool_lifecycle() {
        // every cow/clone/release nets to zero leaked pages
        let pool =
            SlotPool::sim(0.9, 0.05, 3).with_paging(4, 0).with_prefix_cache(true);
        let shared: Vec<u32> = (1..=10).collect();
        let mut held = Vec::new();
        for i in 0..3u32 {
            let mut p = shared.clone();
            p.extend([40 + i, 50 + i]);
            held.push((pool.try_acquire_for(&p).unwrap().0, p));
        }
        assert!(pool.page_stats().shared.load(Ordering::Relaxed) > 0, "burst shares pages");
        for (mut slot, p) in held {
            slot.record_prefix(&p, p.len());
            pool.release(slot);
        }
        // all residencies are cached now; drain them via miss evictions —
        // hold all three slots at once so every cached chain is reclaimed
        // (a released empty slot would otherwise soak up further misses)
        let total = pool.page_stats().total.load(Ordering::Relaxed);
        let mut drained = Vec::new();
        for _ in 0..3 {
            let (mut s, _) = pool.try_acquire_for(&[29, 28, 27]).unwrap();
            s.clear_prefix();
            drained.push(s);
        }
        for s in drained {
            pool.release(s);
        }
        let st = pool.page_stats();
        assert_eq!(
            st.free.load(Ordering::Relaxed),
            total,
            "all pages returned to the free list — nothing leaked"
        );
    }

    #[test]
    fn eviction_under_pressure_never_reclaims_live_pages() {
        // 3 slots, tiny explicit arena (8 pages of 4 tokens): a live
        // checkout's chain survives while cached chains are reclaimed
        let pool =
            SlotPool::sim(0.9, 0.05, 3).with_paging(4, 8).with_prefix_cache(true);
        // A: live (checked out), 16 tokens = 4 pages
        let prompt_a: Vec<u32> = (101..=116).collect();
        let (slot_a, _) = pool.try_acquire_for(&prompt_a).unwrap();
        let live_pages = pool.chain_pages(slot_a.id);
        assert_eq!(live_pages, 4);
        // B: cached residency, 12 tokens = 3 pages, then released
        let prompt_b: Vec<u32> = (201..=212).collect();
        let (mut slot_b, _) = pool.try_acquire_for(&prompt_b).unwrap();
        let b_id = slot_b.id;
        slot_b.record_prefix(&prompt_b, prompt_b.len());
        pool.release(slot_b);
        assert_eq!(pool.page_stats().free.load(Ordering::Relaxed), 1);

        // C needs 4 pages: only B's cached chain can yield them
        let prompt_c: Vec<u32> = (301..=316).collect();
        let (slot_c, _) = pool.try_acquire_for(&prompt_c).unwrap();
        assert_eq!(pool.chain_pages(slot_a.id), 4, "live chain untouched");
        assert_eq!(pool.chain_pages(b_id), 0, "cached chain reclaimed");
        assert_eq!(pool.chain_pages(slot_c.id), 4);
        assert!(pool.page_stats().evictions.load(Ordering::Relaxed) >= 3);
        // B's registration is gone with its pages
        assert_eq!(pool.peek_reuse(&prompt_b), 0);
        pool.release(slot_a);
        pool.release(slot_c);
    }

    #[test]
    fn page_sharing_off_reproduces_slot_affinity_reuse() {
        // the PR-5 baseline the bench compares against: busy residencies
        // are invisible, only free slots can hit
        let pool = SlotPool::sim(0.9, 0.05, 2)
            .with_page_sharing(false)
            .with_prefix_cache(true);
        assert!(!pool.sharing_active());
        let prompt: Vec<u32> = (1..=10).collect();
        let (slot_a, lease_a) = pool.try_acquire_for(&prompt).unwrap();
        assert_eq!(lease_a, Lease::default());
        // identical prompt while the only residency is busy: guaranteed miss
        let (slot_b, lease_b) = pool.try_acquire_for(&prompt).unwrap();
        assert_eq!(lease_b, Lease::default(), "no busy-slot sharing without paging");
        assert_eq!(pool.page_stats().shared_hits.load(Ordering::Relaxed), 0);
        pool.release(slot_a);
        pool.release(slot_b);
    }
}
