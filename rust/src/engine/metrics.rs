//! Serving metrics: per-request records aggregated into the latency /
//! throughput report the end-to-end example prints (TTFT ≈ queue + prefill
//! + first verified commit; TPOT = decode time per generated token).
//!
//! Two sinks with different locking disciplines (DESIGN.md §2):
//!
//! * [`EngineMetrics`] — latency/throughput samples, guarded by one mutex
//!   that is taken **once per completed request** (never on the per-token
//!   decode hot path).
//! * [`EngineStats`] — queue depth, per-worker utilization and slot-wait
//!   counters, all atomics: workers update them lock-free while decoding
//!   and readers (`/metrics`, the bench harness) snapshot without
//!   stopping anyone.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

use crate::util::stats::Samples;
use crate::util::Json;

use super::request::{FinishStatus, Response};

/// Per-request latency/throughput samples (one mutex, taken once per
/// completed request).
#[derive(Debug, Default)]
pub struct EngineMetrics {
    /// requests answered successfully
    pub completed: u64,
    /// requests answered with an error `Response`
    pub failed: u64,
    /// generated tokens across completed requests
    pub new_tokens: u64,
    /// tokens proposed by the draft model
    pub drafted: u64,
    /// proposed tokens that survived verification
    pub accepted: u64,
    /// queueing delay samples (arrival → decode start), ms
    pub queue_ms: Samples,
    /// end-to-end latency samples (arrival → reply), ms
    pub total_ms: Samples,
    /// decode wall-time samples, ms
    pub decode_ms: Samples,
    /// time-per-output-token samples, ms
    pub tpot_ms: Samples,
    /// time-to-first-token samples (queue + first round), ms
    pub ttft_ms: Samples,
    /// wall-clock span covered by the record stream (throughput basis)
    pub span_ns: u64,
}

impl EngineMetrics {
    /// Fold one reply into the aggregates. Failures only bump `failed`;
    /// cancelled/expired/rejected replies are counted by the lock-free
    /// lifecycle counters ([`LifecycleStats`]) instead, so the latency
    /// distributions only ever describe complete decodes.
    pub fn record(&mut self, r: &Response) {
        match r.status {
            FinishStatus::Done => {}
            FinishStatus::Failed => {
                self.failed += 1;
                return;
            }
            FinishStatus::Cancelled | FinishStatus::Expired | FinishStatus::Rejected => return,
        }
        self.completed += 1;
        self.new_tokens += r.result.new_tokens().len() as u64;
        self.drafted += r.result.drafted() as u64;
        self.accepted += r.result.accepted() as u64;
        self.queue_ms.push(r.queue_ns as f64 / 1e6);
        self.total_ms.push(r.total_ns as f64 / 1e6);
        self.decode_ms.push(r.result.wall_ns as f64 / 1e6);
        let n = r.result.new_tokens().len().max(1) as f64;
        self.tpot_ms.push(r.result.wall_ns as f64 / 1e6 / n);
        // first commit ≈ first round (prefill + draft + verify) + queueing
        let first_round_ns = r
            .result
            .rounds
            .first()
            .map(|x| x.draft_ns + x.verify_ns)
            .unwrap_or(r.result.wall_ns);
        self.ttft_ms.push((r.queue_ns + first_round_ns) as f64 / 1e6);
    }

    /// Fraction of drafted tokens that verification accepted.
    pub fn acceptance_rate(&self) -> f64 {
        if self.drafted == 0 { 0.0 } else { self.accepted as f64 / self.drafted as f64 }
    }

    /// Generated tokens per second over the recorded span.
    pub fn throughput_tok_s(&self) -> f64 {
        if self.span_ns == 0 {
            return 0.0;
        }
        self.new_tokens as f64 / (self.span_ns as f64 / 1e9)
    }

    /// Human-readable latency table (the CLI / bench footer).
    pub fn report(&mut self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "requests: {}   failed: {}   generated tokens: {}   acceptance: {:.2}\n",
            self.completed,
            self.failed,
            self.new_tokens,
            self.acceptance_rate()
        ));
        if self.span_ns > 0 {
            s.push_str(&format!("throughput: {:.1} tok/s\n", self.throughput_tok_s()));
        }
        let mut line = |name: &str, smp: &mut Samples| {
            format!(
                "{name:<10} mean {:>8.2} ms   p50 {:>8.2}   p95 {:>8.2}   p99 {:>8.2}\n",
                smp.mean(),
                smp.percentile(50.0),
                smp.percentile(95.0),
                smp.percentile(99.0)
            )
        };
        let q = line("queue", &mut self.queue_ms);
        let t = line("ttft", &mut self.ttft_ms);
        let d = line("decode", &mut self.decode_ms);
        let p = line("tpot", &mut self.tpot_ms);
        let e = line("e2e", &mut self.total_ms);
        s.push_str(&q);
        s.push_str(&t);
        s.push_str(&d);
        s.push_str(&p);
        s.push_str(&e);
        s
    }

    /// JSON object for the top-level `/metrics` fields (see
    /// docs/OPERATIONS.md).
    pub fn to_json(&mut self) -> Json {
        let mut o = Json::obj();
        o.set("completed", self.completed as usize)
            .set("failed", self.failed as usize)
            .set("new_tokens", self.new_tokens as usize)
            .set("acceptance_rate", self.acceptance_rate())
            .set("throughput_tok_s", self.throughput_tok_s())
            .set("ttft_p50_ms", self.ttft_ms.percentile(50.0))
            .set("ttft_p95_ms", self.ttft_ms.percentile(95.0))
            .set("ttft_p99_ms", self.ttft_ms.percentile(99.0))
            .set("tpot_mean_ms", self.tpot_ms.mean())
            .set("tpot_p50_ms", self.tpot_ms.percentile(50.0))
            .set("tpot_p95_ms", self.tpot_ms.percentile(95.0))
            .set("tpot_p99_ms", self.tpot_ms.percentile(99.0))
            .set("e2e_p50_ms", self.total_ms.percentile(50.0))
            .set("e2e_p99_ms", self.total_ms.percentile(99.0));
        o
    }
}

/// Lock-free counters for one decode worker.
#[derive(Debug, Default)]
pub struct WorkerStats {
    /// requests this worker decoded (including failures)
    pub requests: AtomicU64,
    /// requests that ended in an error reply
    pub errors: AtomicU64,
    /// wall time spent inside `generate` (decode busy time)
    pub busy_ns: AtomicU64,
    /// wall time spent blocked waiting for a KV slot
    pub slot_wait_ns: AtomicU64,
}

impl WorkerStats {
    /// JSON object for one `engine.per_worker` entry.
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("requests", self.requests.load(Ordering::Relaxed) as usize)
            .set("errors", self.errors.load(Ordering::Relaxed) as usize)
            .set("busy_ms", self.busy_ns.load(Ordering::Relaxed) as f64 / 1e6)
            .set("slot_wait_ms", self.slot_wait_ns.load(Ordering::Relaxed) as f64 / 1e6);
        o
    }
}

/// Lock-free gauges for the verification batcher (batch occupancy and
/// pad waste — docs/ARCHITECTURE.md §4). Updated by the batcher thread
/// once per executed batch; snapshot by `/metrics` readers any time.
#[derive(Debug, Default)]
pub struct BatchStats {
    /// batched target forwards executed
    pub batches: AtomicU64,
    /// sessions coalesced across all batches (Σ occupancy)
    pub coalesced: AtomicU64,
    /// largest single-batch occupancy seen
    pub peak: AtomicUsize,
    /// real token rows verified through the batcher
    pub rows: AtomicU64,
    /// rows actually computed after shape-bucket padding
    pub padded_rows: AtomicU64,
    /// wall time spent waiting for sessions to coalesce
    pub fill_wait_ns: AtomicU64,
}

impl BatchStats {
    /// Record one executed batch of `n` coalesced sessions.
    pub fn note(&self, n: usize, rows: u64, padded_rows: u64, fill_ns: u64) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.coalesced.fetch_add(n as u64, Ordering::Relaxed);
        self.peak.fetch_max(n, Ordering::Relaxed);
        self.rows.fetch_add(rows, Ordering::Relaxed);
        self.padded_rows.fetch_add(padded_rows, Ordering::Relaxed);
        self.fill_wait_ns.fetch_add(fill_ns, Ordering::Relaxed);
    }

    /// Mean sessions per batched forward (1.0 = no cross-session
    /// coalescing happened).
    pub fn mean_occupancy(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            return 0.0;
        }
        self.coalesced.load(Ordering::Relaxed) as f64 / b as f64
    }

    /// Fraction of computed rows that were shape-bucket padding.
    pub fn pad_waste_frac(&self) -> f64 {
        let padded = self.padded_rows.load(Ordering::Relaxed);
        if padded == 0 {
            return 0.0;
        }
        1.0 - self.rows.load(Ordering::Relaxed) as f64 / padded as f64
    }

    /// JSON object for the `/metrics` `engine.batch` field.
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("batches", self.batches.load(Ordering::Relaxed) as usize)
            .set("coalesced_sessions", self.coalesced.load(Ordering::Relaxed) as usize)
            .set("mean_occupancy", self.mean_occupancy())
            .set("peak_occupancy", self.peak.load(Ordering::Relaxed))
            .set("pad_waste_frac", self.pad_waste_frac())
            .set("fill_wait_ms", self.fill_wait_ns.load(Ordering::Relaxed) as f64 / 1e6);
        o
    }
}

/// Lock-free gauges for *draft-side* forwards (docs/ARCHITECTURE.md
/// §11). Updated by decode workers (per-request cost deltas of the
/// slot's draft model) in Workers mode and by the continuous stepper
/// (per-micro-round deltas of the shared batched drafter) in Continuous
/// mode, so the two execution models are directly comparable: Continuous
/// coalesces every in-flight session's drafting into one forward per
/// micro-round, which is exactly a lower `forwards` count for the same
/// `rows`.
#[derive(Debug, Default)]
pub struct DraftStats {
    /// draft forwards dispatched (`ModelCost::calls` deltas)
    pub forwards: AtomicU64,
    /// per-session draft blocks served (Σ batch occupancy; == `forwards`
    /// in Workers mode, where every dispatch serves one session)
    pub sessions: AtomicU64,
    /// real draft token rows computed
    pub rows: AtomicU64,
    /// rows actually computed after shape-bucket padding
    pub padded_rows: AtomicU64,
}

impl DraftStats {
    /// Fold one draft-cost delta covering `sessions` per-session blocks.
    pub fn note(&self, sessions: usize, calls: u64, rows: u64, padded_rows: u64) {
        self.forwards.fetch_add(calls, Ordering::Relaxed);
        self.sessions.fetch_add(sessions as u64, Ordering::Relaxed);
        self.rows.fetch_add(rows, Ordering::Relaxed);
        self.padded_rows.fetch_add(padded_rows, Ordering::Relaxed);
    }

    /// Mean per-session blocks served per dispatched forward (1.0 = no
    /// cross-session draft coalescing).
    pub fn mean_occupancy(&self) -> f64 {
        let f = self.forwards.load(Ordering::Relaxed);
        if f == 0 {
            return 0.0;
        }
        self.sessions.load(Ordering::Relaxed) as f64 / f as f64
    }

    /// Fraction of computed draft rows that were shape-bucket padding.
    pub fn pad_waste_frac(&self) -> f64 {
        let padded = self.padded_rows.load(Ordering::Relaxed);
        if padded == 0 {
            return 0.0;
        }
        1.0 - self.rows.load(Ordering::Relaxed) as f64 / padded as f64
    }

    /// JSON object for the `/metrics` `engine.draft` field.
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("forwards", self.forwards.load(Ordering::Relaxed) as usize)
            .set("sessions", self.sessions.load(Ordering::Relaxed) as usize)
            .set("mean_occupancy", self.mean_occupancy())
            .set("rows", self.rows.load(Ordering::Relaxed) as usize)
            .set("pad_waste_frac", self.pad_waste_frac());
        o
    }
}

/// Size of the per-iteration in-flight histogram (buckets 0..=31 plus a
/// saturating 32+ bucket) — covers any realistic slot count.
pub const STEP_HIST_BUCKETS: usize = 33;

/// Lock-free gauges for the continuous-batching step loop
/// (docs/ARCHITECTURE.md §11): how many sessions each iteration held,
/// how admissions interleave with decoding, and what the batched draft
/// path is buying. Updated once per iteration by the stepper thread;
/// all zero in Workers mode (the `engine.step` object is only rendered
/// once an iteration has run).
#[derive(Debug)]
pub struct StepStats {
    /// step-loop iterations that drove at least one session
    pub steps: AtomicU64,
    /// requests admitted into KV slots by the stepper
    pub admitted: AtomicU64,
    /// sessions retired (finished / cancelled / expired / failed)
    pub retired: AtomicU64,
    /// per-iteration in-flight histogram: `inflight_hist[n]` counts
    /// iterations that stepped `n` sessions (last bucket saturates)
    pub inflight_hist: Vec<AtomicU64>,
    /// largest in-flight count any iteration stepped
    pub peak_inflight: AtomicUsize,
    /// hot-path scratch-buffer growths (row/index buffers reallocating
    /// instead of being refilled in place) — the allocation-churn gauge
    /// the bench asserts flat across warm identical bursts
    pub scratch_allocs: AtomicU64,
}

impl Default for StepStats {
    fn default() -> Self {
        StepStats {
            steps: AtomicU64::new(0),
            admitted: AtomicU64::new(0),
            retired: AtomicU64::new(0),
            inflight_hist: (0..STEP_HIST_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            peak_inflight: AtomicUsize::new(0),
            scratch_allocs: AtomicU64::new(0),
        }
    }
}

impl StepStats {
    /// Record one executed iteration that stepped `in_flight` sessions
    /// and admitted `admitted` new requests.
    pub fn note_step(&self, in_flight: usize, admitted: usize) {
        self.steps.fetch_add(1, Ordering::Relaxed);
        self.admitted.fetch_add(admitted as u64, Ordering::Relaxed);
        self.inflight_hist[in_flight.min(STEP_HIST_BUCKETS - 1)].fetch_add(1, Ordering::Relaxed);
        self.peak_inflight.fetch_max(in_flight, Ordering::Relaxed);
    }

    /// Mean admissions per executed iteration.
    pub fn admissions_per_step(&self) -> f64 {
        let s = self.steps.load(Ordering::Relaxed);
        if s == 0 {
            return 0.0;
        }
        self.admitted.load(Ordering::Relaxed) as f64 / s as f64
    }

    /// Mean sessions stepped per iteration.
    pub fn mean_inflight(&self) -> f64 {
        let s = self.steps.load(Ordering::Relaxed);
        if s == 0 {
            return 0.0;
        }
        let weighted: u64 = self
            .inflight_hist
            .iter()
            .enumerate()
            .map(|(n, c)| n as u64 * c.load(Ordering::Relaxed))
            .sum();
        weighted as f64 / s as f64
    }

    /// JSON object for the `/metrics` `engine.step` field. The draft
    /// occupancy/pad-waste gauges live in `draft` (the same numbers as
    /// `engine.draft`) because in Continuous mode every draft forward is
    /// a step-loop micro-round.
    pub fn to_json(&self, draft: &DraftStats) -> Json {
        // trim trailing empty buckets so the histogram stays readable
        let hist: Vec<u64> =
            self.inflight_hist.iter().map(|c| c.load(Ordering::Relaxed)).collect();
        let keep = hist.iter().rposition(|&c| c > 0).map(|p| p + 1).unwrap_or(1);
        let mut o = Json::obj();
        o.set("steps", self.steps.load(Ordering::Relaxed) as usize)
            .set("admitted", self.admitted.load(Ordering::Relaxed) as usize)
            .set("retired", self.retired.load(Ordering::Relaxed) as usize)
            .set("admissions_per_step", self.admissions_per_step())
            .set("mean_in_flight", self.mean_inflight())
            .set("peak_in_flight", self.peak_inflight.load(Ordering::Relaxed))
            .set("in_flight_hist", hist[..keep].iter().map(|&c| c as f64).collect::<Vec<f64>>())
            .set("draft_occupancy", draft.mean_occupancy())
            .set("draft_pad_waste_frac", draft.pad_waste_frac())
            .set("scratch_allocs", self.scratch_allocs.load(Ordering::Relaxed) as usize);
        o
    }
}

/// Lock-free gauges for the overlapped draft/verify pipeline
/// (docs/ARCHITECTURE.md §16): while a verify forward is in flight the
/// stepper speculatively pre-drafts the next micro-round, then either
/// adopts the rows (full acceptance) or discards them. Updated once per
/// pipelined verify round by the stepper thread; all zero while
/// `--pipeline` is off or in Workers mode (the `engine.pipeline` object
/// is only rendered once a pipelined round has run). Discarded work is
/// *observability only* — it never touches bandit plays, rewards, the
/// SJF ledger, or page refcounts.
#[derive(Debug, Default)]
pub struct PipelineStats {
    /// pipelined verify rounds driven (submit → speculate → wait)
    pub rounds: AtomicU64,
    /// speculative pre-draft forwards issued under an in-flight verify
    pub spec_forwards: AtomicU64,
    /// pre-drafted rows adopted on commit (session accepted everything)
    pub rows_adopted: AtomicU64,
    /// pre-drafted rows discarded on commit (partial acceptance, verify
    /// failure, or session retired before the rows were needed)
    pub rows_discarded: AtomicU64,
    /// next-round draft forwards that had to re-cover discarded rows
    pub redraft_forwards: AtomicU64,
    /// wall time the stepper spent blocked in `PendingBatch::wait`
    /// *after* speculation returned (the un-hidden verify tail)
    pub verify_stall_ns: AtomicU64,
    /// wall time spent pre-drafting between submit and wait (the verify
    /// latency actually hidden behind draft work)
    pub overlap_ns: AtomicU64,
}

impl PipelineStats {
    /// Record one pipelined verify round: whether a speculative forward
    /// ran, how long it overlapped the verify, and how long the stepper
    /// still stalled in `wait` afterwards.
    pub fn note_round(&self, speculated: bool, overlap_ns: u64, stall_ns: u64) {
        self.rounds.fetch_add(1, Ordering::Relaxed);
        if speculated {
            self.spec_forwards.fetch_add(1, Ordering::Relaxed);
        }
        self.overlap_ns.fetch_add(overlap_ns, Ordering::Relaxed);
        self.verify_stall_ns.fetch_add(stall_ns, Ordering::Relaxed);
    }

    /// Fraction of the verify-shadow wall time actually covered by
    /// speculative draft work: `overlap / (overlap + stall)`.
    pub fn overlap_ratio(&self) -> f64 {
        let overlap = self.overlap_ns.load(Ordering::Relaxed) as f64;
        let stall = self.verify_stall_ns.load(Ordering::Relaxed) as f64;
        if overlap + stall == 0.0 {
            return 0.0;
        }
        overlap / (overlap + stall)
    }

    /// Fraction of speculative rows thrown away on commit.
    pub fn discard_rate(&self) -> f64 {
        let a = self.rows_adopted.load(Ordering::Relaxed);
        let d = self.rows_discarded.load(Ordering::Relaxed);
        if a + d == 0 {
            return 0.0;
        }
        d as f64 / (a + d) as f64
    }

    /// JSON object for the `/metrics` `engine.pipeline` field.
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("rounds", self.rounds.load(Ordering::Relaxed) as usize)
            .set("spec_forwards", self.spec_forwards.load(Ordering::Relaxed) as usize)
            .set("rows_adopted", self.rows_adopted.load(Ordering::Relaxed) as usize)
            .set("rows_discarded", self.rows_discarded.load(Ordering::Relaxed) as usize)
            .set("discard_rate", self.discard_rate())
            .set("redraft_forwards", self.redraft_forwards.load(Ordering::Relaxed) as usize)
            .set("verify_stall_ms", self.verify_stall_ns.load(Ordering::Relaxed) as f64 / 1e6)
            .set("overlap_ms", self.overlap_ns.load(Ordering::Relaxed) as f64 / 1e6)
            .set("overlap_ratio", self.overlap_ratio());
        o
    }
}

/// Lock-free gauges for the cross-request prefix-reuse cache
/// (docs/ARCHITECTURE.md §12): how often affinity checkout found a free
/// slot with a matching resident prefix, how many prompt tokens the hits
/// skipped, and how recorded prefixes churn. Owned by the
/// [`SlotPool`](super::slots::SlotPool) (the pool is the cache) and
/// surfaced as the `engine.cache` object in `/metrics`
/// (docs/OPERATIONS.md). All counters stay zero while the cache is
/// disabled.
#[derive(Debug)]
pub struct CacheStats {
    /// is prefix reuse enabled on the owning pool?
    pub enabled: bool,
    /// affinity checkouts routed through the prefix index
    pub lookups: AtomicU64,
    /// checkouts that reused ≥ 1 cached prompt token
    pub hits: AtomicU64,
    /// prompt tokens whose prefill was skipped (Σ reuse length)
    pub cached_tokens: AtomicU64,
    /// prompt tokens across all looked-up requests (ratio denominator)
    pub prompt_tokens: AtomicU64,
    /// recorded prefixes discarded without being reused (a miss checkout
    /// resets a slot that had cached state)
    pub evictions: AtomicU64,
    /// requests served per slot id (the slot-affinity reuse footprint)
    pub served: Vec<AtomicU64>,
}

impl CacheStats {
    /// Fresh counters for a pool of `n_slots` slots.
    pub fn new(n_slots: usize, enabled: bool) -> CacheStats {
        CacheStats {
            enabled,
            lookups: AtomicU64::new(0),
            hits: AtomicU64::new(0),
            cached_tokens: AtomicU64::new(0),
            prompt_tokens: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            served: (0..n_slots).map(|_| AtomicU64::new(0)).collect(),
        }
    }

    /// Record one affinity checkout of a `prompt_len`-token prompt that
    /// reused `reuse` cached positions (0 = miss).
    pub fn note_lookup(&self, prompt_len: usize, reuse: usize) {
        self.lookups.fetch_add(1, Ordering::Relaxed);
        self.prompt_tokens.fetch_add(prompt_len as u64, Ordering::Relaxed);
        if reuse > 0 {
            self.hits.fetch_add(1, Ordering::Relaxed);
            self.cached_tokens.fetch_add(reuse as u64, Ordering::Relaxed);
        }
    }

    /// Record one recorded prefix discarded without reuse.
    pub fn note_eviction(&self) {
        self.evictions.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one completed checkout of slot `slot` (at release).
    pub fn note_served(&self, slot: usize) {
        if let Some(c) = self.served.get(slot) {
            c.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Fraction of lookups that reused cached state.
    pub fn hit_rate(&self) -> f64 {
        let l = self.lookups.load(Ordering::Relaxed);
        if l == 0 {
            return 0.0;
        }
        self.hits.load(Ordering::Relaxed) as f64 / l as f64
    }

    /// Fraction of looked-up prompt tokens whose prefill was skipped.
    pub fn cached_token_ratio(&self) -> f64 {
        let p = self.prompt_tokens.load(Ordering::Relaxed);
        if p == 0 {
            return 0.0;
        }
        self.cached_tokens.load(Ordering::Relaxed) as f64 / p as f64
    }

    /// JSON object for the `/metrics` `engine.cache` field.
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("enabled", self.enabled)
            .set("lookups", self.lookups.load(Ordering::Relaxed) as usize)
            .set("hits", self.hits.load(Ordering::Relaxed) as usize)
            .set("hit_rate", self.hit_rate())
            .set("cached_tokens", self.cached_tokens.load(Ordering::Relaxed) as usize)
            .set("prompt_tokens", self.prompt_tokens.load(Ordering::Relaxed) as usize)
            .set("cached_token_ratio", self.cached_token_ratio())
            .set("evictions", self.evictions.load(Ordering::Relaxed) as usize)
            .set(
                "served",
                self.served
                    .iter()
                    .map(|c| c.load(Ordering::Relaxed) as f64)
                    .collect::<Vec<f64>>(),
            );
        o
    }
}

/// Lock-free gauges over the paged KV allocator
/// (docs/ARCHITECTURE.md §13): how many pages exist, how many are
/// resident or shared, and how copy-on-write / eviction churn behaves
/// under load. Owned by the [`SlotPool`](super::slots::SlotPool), which
/// mirrors the mutex-guarded [`PagePool`](super::paging::PagePool)
/// bookkeeping into these atomics after every checkout/release so
/// `/metrics` readers (`engine.pages`, docs/OPERATIONS.md) never take
/// the checkout lock. All counters stay zero while the prefix cache is
/// disabled (no paging without reuse to account).
#[derive(Debug)]
pub struct PageStats {
    /// is paged prefix reuse enabled on the owning pool?
    pub enabled: bool,
    /// tokens per page
    pub page_size: AtomicU64,
    /// pages in the arena (`kv_pages`, or the auto-sized capacity)
    pub total: AtomicU64,
    /// pages on the free list right now
    pub free: AtomicU64,
    /// pages referenced by more than one slot chain (the sharing win)
    pub shared: AtomicU64,
    /// high-water mark of resident (non-free) pages
    pub peak_resident: AtomicU64,
    /// copy-on-write page duplications (partial boundary pages)
    pub cow_copies: AtomicU64,
    /// pages reclaimed from cached residencies under pressure
    pub evictions: AtomicU64,
    /// checkouts that adopted pages from a busy source slot
    pub shared_hits: AtomicU64,
    /// prompt tokens adopted via cross-slot page sharing
    pub adopted_tokens: AtomicU64,
    /// paged checkouts routed through the index (hit-rate denominator)
    pub lookups: AtomicU64,
}

impl PageStats {
    /// Fresh counters; `enabled` mirrors the pool's cache switch.
    pub fn new(enabled: bool) -> PageStats {
        PageStats {
            enabled,
            page_size: AtomicU64::new(0),
            total: AtomicU64::new(0),
            free: AtomicU64::new(0),
            shared: AtomicU64::new(0),
            peak_resident: AtomicU64::new(0),
            cow_copies: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            shared_hits: AtomicU64::new(0),
            adopted_tokens: AtomicU64::new(0),
            lookups: AtomicU64::new(0),
        }
    }

    /// Count one paged checkout (the shared-hit-rate denominator).
    pub fn note_lookup(&self) {
        self.lookups.fetch_add(1, Ordering::Relaxed);
    }

    /// Mirror the allocator's mutex-guarded bookkeeping into the
    /// lock-free gauges (called under the pool mutex; readers stay
    /// outside it).
    pub fn sync(&self, pool: &super::paging::PagePool) {
        self.page_size.store(pool.page_size() as u64, Ordering::Relaxed);
        self.total.store(pool.total_pages() as u64, Ordering::Relaxed);
        self.free.store(pool.free_pages() as u64, Ordering::Relaxed);
        self.shared.store(pool.shared_pages() as u64, Ordering::Relaxed);
        self.peak_resident.store(pool.peak_resident as u64, Ordering::Relaxed);
        self.cow_copies.store(pool.cow_copies, Ordering::Relaxed);
        self.evictions.store(pool.evicted_pages, Ordering::Relaxed);
        self.shared_hits.store(pool.shared_hits, Ordering::Relaxed);
        self.adopted_tokens.store(pool.adopted_tokens, Ordering::Relaxed);
    }

    /// Fraction of paged checkouts that adopted a busy slot's pages.
    pub fn shared_hit_rate(&self) -> f64 {
        let l = self.lookups.load(Ordering::Relaxed);
        if l == 0 {
            return 0.0;
        }
        self.shared_hits.load(Ordering::Relaxed) as f64 / l as f64
    }

    /// JSON object for the `/metrics` `engine.pages` field.
    pub fn to_json(&self) -> Json {
        let total = self.total.load(Ordering::Relaxed);
        let free = self.free.load(Ordering::Relaxed);
        let mut o = Json::obj();
        o.set("enabled", self.enabled)
            .set("page_size", self.page_size.load(Ordering::Relaxed) as usize)
            .set("total", total as usize)
            .set("free", free as usize)
            .set("resident", total.saturating_sub(free) as usize)
            .set("peak_resident", self.peak_resident.load(Ordering::Relaxed) as usize)
            .set("shared", self.shared.load(Ordering::Relaxed) as usize)
            .set("cow_copies", self.cow_copies.load(Ordering::Relaxed) as usize)
            .set("evictions", self.evictions.load(Ordering::Relaxed) as usize)
            .set("shared_hits", self.shared_hits.load(Ordering::Relaxed) as usize)
            .set("shared_hit_rate", self.shared_hit_rate())
            .set("adopted_tokens", self.adopted_tokens.load(Ordering::Relaxed) as usize)
            .set("lookups", self.lookups.load(Ordering::Relaxed) as usize);
        o
    }
}

/// Lock-free counters for the request lifecycle's non-completion exits
/// (docs/ARCHITECTURE.md §10): cancelled by the client, expired past the
/// deadline, shed by the admission controller. Surfaced as the
/// `engine.lifecycle` object in `/metrics` (docs/OPERATIONS.md).
#[derive(Debug, Default)]
pub struct LifecycleStats {
    /// requests the client cancelled (flag or disconnect), queued or
    /// mid-decode
    pub cancelled: AtomicU64,
    /// requests whose absolute deadline passed before completion
    pub expired: AtomicU64,
    /// requests shed by admission control (queue full → HTTP 429)
    pub rejected: AtomicU64,
}

impl LifecycleStats {
    /// JSON object for the `/metrics` `engine.lifecycle` field.
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("cancelled", self.cancelled.load(Ordering::Relaxed) as usize)
            .set("expired", self.expired.load(Ordering::Relaxed) as usize)
            .set("rejected", self.rejected.load(Ordering::Relaxed) as usize);
        o
    }
}

/// Front-end I/O gauges (reactor or blocking HTTP loop, and the router
/// data plane): connection and request counts, slow-loris timeouts,
/// SSE keep-alives, disconnect/write-failure cancellations. All
/// atomics; surfaced under `io` in `/metrics`.
#[derive(Debug)]
pub struct IoStats {
    /// which front end is serving: `"reactor"`, `"blocking"`, `"router"`
    pub mode: &'static str,
    /// I/O threads in the pool (0 = thread-per-connection)
    pub io_threads: usize,
    /// connections accepted since boot
    pub accepted: AtomicU64,
    /// connections currently open
    pub open: AtomicU64,
    /// high-water mark of open connections
    pub peak_open: AtomicU64,
    /// complete requests parsed off connections
    pub requests: AtomicU64,
    /// connections answered 408 (slow-loris read deadline)
    pub read_timeouts: AtomicU64,
    /// SSE keep-alive comments written on long-silent streams
    pub keepalives: AtomicU64,
    /// decodes cancelled because a response write failed (client gone)
    pub write_cancels: AtomicU64,
    /// decodes cancelled because the client disconnected mid-stream
    pub disconnects: AtomicU64,
}

impl IoStats {
    /// Fresh gauges for a front end of the given mode / pool size.
    pub fn new(mode: &'static str, io_threads: usize) -> IoStats {
        IoStats {
            mode,
            io_threads,
            accepted: AtomicU64::new(0),
            open: AtomicU64::new(0),
            peak_open: AtomicU64::new(0),
            requests: AtomicU64::new(0),
            read_timeouts: AtomicU64::new(0),
            keepalives: AtomicU64::new(0),
            write_cancels: AtomicU64::new(0),
            disconnects: AtomicU64::new(0),
        }
    }

    /// A connection opened: bump the open gauge and its high-water mark.
    pub fn conn_opened(&self) {
        let now = self.open.fetch_add(1, Ordering::Relaxed) + 1;
        self.peak_open.fetch_max(now, Ordering::Relaxed);
    }

    /// A connection closed.
    pub fn conn_closed(&self) {
        self.open.fetch_sub(1, Ordering::Relaxed);
    }

    /// JSON object for the `/metrics` `io` field.
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("mode", self.mode)
            .set("io_threads", self.io_threads)
            .set("accepted", self.accepted.load(Ordering::Relaxed) as usize)
            .set("open", self.open.load(Ordering::Relaxed) as usize)
            .set("peak_open", self.peak_open.load(Ordering::Relaxed) as usize)
            .set("requests", self.requests.load(Ordering::Relaxed) as usize)
            .set("read_timeouts", self.read_timeouts.load(Ordering::Relaxed) as usize)
            .set("keepalives", self.keepalives.load(Ordering::Relaxed) as usize)
            .set("write_cancels", self.write_cancels.load(Ordering::Relaxed) as usize)
            .set("disconnects", self.disconnects.load(Ordering::Relaxed) as usize);
        o
    }
}

/// Engine-wide atomics: updated by the dispatcher and every worker with
/// no shared lock; snapshot by readers at any time.
#[derive(Debug)]
pub struct EngineStats {
    /// per-worker counters, indexed by worker id
    pub workers: Vec<WorkerStats>,
    /// requests accepted by the dispatcher since boot
    pub submitted: AtomicU64,
    /// instantaneous scheduler queue depth
    pub queue_depth: AtomicUsize,
    /// high-water mark of the scheduler queue depth
    pub peak_queue_depth: AtomicUsize,
    /// verification-batcher occupancy / pad-waste gauges
    pub batch: BatchStats,
    /// draft-side forward gauges (both execution modes)
    pub draft: DraftStats,
    /// continuous step-loop gauges (Continuous mode only)
    pub step: StepStats,
    /// overlapped draft/verify pipeline gauges (`--pipeline` only)
    pub pipeline: PipelineStats,
    /// cancelled / expired / rejected lifecycle exits
    pub lifecycle: LifecycleStats,
}

impl EngineStats {
    /// Fresh counters for an engine with `n_workers` decode workers.
    pub fn new(n_workers: usize) -> EngineStats {
        EngineStats {
            workers: (0..n_workers).map(|_| WorkerStats::default()).collect(),
            submitted: AtomicU64::new(0),
            queue_depth: AtomicUsize::new(0),
            peak_queue_depth: AtomicUsize::new(0),
            batch: BatchStats::default(),
            draft: DraftStats::default(),
            step: StepStats::default(),
            pipeline: PipelineStats::default(),
            lifecycle: LifecycleStats::default(),
        }
    }

    /// Record the instantaneous queue depth (dispatcher after push,
    /// workers after pop).
    pub fn note_depth(&self, depth: usize) {
        self.queue_depth.store(depth, Ordering::Relaxed);
        self.peak_queue_depth.fetch_max(depth, Ordering::Relaxed);
    }

    /// Requests decoded across all workers.
    pub fn total_requests(&self) -> u64 {
        self.workers.iter().map(|w| w.requests.load(Ordering::Relaxed)).sum()
    }

    /// Mean decode-busy fraction across workers over `span_ns` of wall
    /// clock — the slot/worker utilization readout.
    pub fn utilization(&self, span_ns: u64) -> f64 {
        if span_ns == 0 || self.workers.is_empty() {
            return 0.0;
        }
        let busy: u64 = self.workers.iter().map(|w| w.busy_ns.load(Ordering::Relaxed)).sum();
        busy as f64 / (span_ns as f64 * self.workers.len() as f64)
    }

    /// JSON object for the `/metrics` `engine` field (see
    /// docs/OPERATIONS.md for the field-by-field reference).
    pub fn to_json(&self, span_ns: u64) -> Json {
        let mut o = Json::obj();
        o.set("workers", self.workers.len())
            .set("submitted", self.submitted.load(Ordering::Relaxed) as usize)
            .set("queue_depth", self.queue_depth.load(Ordering::Relaxed))
            .set("peak_queue_depth", self.peak_queue_depth.load(Ordering::Relaxed))
            .set("utilization", self.utilization(span_ns))
            .set("batch", self.batch.to_json())
            .set("draft", self.draft.to_json())
            .set("lifecycle", self.lifecycle.to_json());
        if self.step.steps.load(Ordering::Relaxed) > 0 {
            o.set("step", self.step.to_json(&self.draft));
        }
        if self.pipeline.rounds.load(Ordering::Relaxed) > 0 {
            o.set("pipeline", self.pipeline.to_json());
        }
        let per_worker: Vec<Json> = self.workers.iter().map(|w| w.to_json()).collect();
        o.set("per_worker", per_worker);
        o
    }

    /// Human-readable worker/batch summary (the CLI / bench footer).
    pub fn report(&self, span_ns: u64) -> String {
        let mut s = format!(
            "workers: {}   peak queue depth: {}   utilization: {:.0}%\n",
            self.workers.len(),
            self.peak_queue_depth.load(Ordering::Relaxed),
            self.utilization(span_ns) * 100.0
        );
        if self.batch.batches.load(Ordering::Relaxed) > 0 {
            s.push_str(&format!(
                "batched verify: {} forwards  mean occupancy {:.2}  peak {}  pad waste {:.0}%\n",
                self.batch.batches.load(Ordering::Relaxed),
                self.batch.mean_occupancy(),
                self.batch.peak.load(Ordering::Relaxed),
                self.batch.pad_waste_frac() * 100.0
            ));
        }
        if self.step.steps.load(Ordering::Relaxed) > 0 {
            s.push_str(&format!(
                "step loop: {} iterations  mean in-flight {:.2}  peak {}  \
                 admissions/step {:.2}  draft occupancy {:.2}\n",
                self.step.steps.load(Ordering::Relaxed),
                self.step.mean_inflight(),
                self.step.peak_inflight.load(Ordering::Relaxed),
                self.step.admissions_per_step(),
                self.draft.mean_occupancy(),
            ));
        }
        if self.pipeline.rounds.load(Ordering::Relaxed) > 0 {
            s.push_str(&format!(
                "pipeline: {} rounds  overlap {:.0}%  adopted {}  discarded {}  redrafts {}\n",
                self.pipeline.rounds.load(Ordering::Relaxed),
                self.pipeline.overlap_ratio() * 100.0,
                self.pipeline.rows_adopted.load(Ordering::Relaxed),
                self.pipeline.rows_discarded.load(Ordering::Relaxed),
                self.pipeline.redraft_forwards.load(Ordering::Relaxed),
            ));
        }
        for (i, w) in self.workers.iter().enumerate() {
            s.push_str(&format!(
                "  worker {i}: {} requests ({} errors)  busy {:.1} ms  slot-wait {:.1} ms\n",
                w.requests.load(Ordering::Relaxed),
                w.errors.load(Ordering::Relaxed),
                w.busy_ns.load(Ordering::Relaxed) as f64 / 1e6,
                w.slot_wait_ns.load(Ordering::Relaxed) as f64 / 1e6,
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spec::GenResult;

    fn resp(id: u64, tokens: usize, wall_ms: u64) -> Response {
        let mut result = GenResult::default();
        result.tokens = vec![0; tokens + 4];
        result.prompt_len = 4;
        result.wall_ns = wall_ms * 1_000_000;
        Response {
            id,
            text: String::new(),
            result,
            queue_ns: 1_000_000,
            total_ns: wall_ms * 1_000_000 + 1_000_000,
            status: FinishStatus::Done,
            error: None,
        }
    }

    #[test]
    fn aggregates_and_reports() {
        let mut m = EngineMetrics::default();
        m.record(&resp(1, 10, 20));
        m.record(&resp(2, 30, 30));
        m.span_ns = 1_000_000_000;
        assert_eq!(m.completed, 2);
        assert_eq!(m.new_tokens, 40);
        assert!((m.throughput_tok_s() - 40.0).abs() < 1e-9);
        let rep = m.report();
        assert!(rep.contains("requests: 2"));
        assert!(rep.contains("tpot"));
        let j = m.to_json();
        assert_eq!(j.get("completed").unwrap().as_usize().unwrap(), 2);
    }

    #[test]
    fn failures_counted_separately() {
        let mut m = EngineMetrics::default();
        m.record(&resp(1, 10, 20));
        m.record(&Response::failure(2, 1_000, 2_000, "boom".into()));
        assert_eq!(m.completed, 1);
        assert_eq!(m.failed, 1);
        // failed requests contribute no latency samples
        assert_eq!(m.new_tokens, 10);
        let j = m.to_json();
        assert_eq!(j.get("failed").unwrap().as_usize().unwrap(), 1);
    }

    #[test]
    fn lifecycle_exits_do_not_pollute_latency_samples() {
        let mut m = EngineMetrics::default();
        m.record(&resp(1, 10, 20));
        m.record(&Response::terminal(2, FinishStatus::Cancelled, 1_000, 2_000, "gone"));
        m.record(&Response::terminal(3, FinishStatus::Expired, 1_000, 2_000, "late"));
        m.record(&Response::terminal(4, FinishStatus::Rejected, 1_000, 1_000, "full"));
        assert_eq!(m.completed, 1);
        assert_eq!(m.failed, 0, "lifecycle exits are not decode failures");
        assert_eq!(m.total_ms.len(), 1, "only complete decodes sample latency");
        let j = m.to_json();
        assert!(j.get("ttft_p95_ms").is_some());
        assert!(j.get("tpot_p99_ms").is_some());
    }

    #[test]
    fn lifecycle_counters_render_in_engine_json() {
        let s = EngineStats::new(1);
        s.lifecycle.cancelled.fetch_add(2, Ordering::Relaxed);
        s.lifecycle.rejected.fetch_add(5, Ordering::Relaxed);
        let j = s.to_json(1_000);
        let l = j.get("lifecycle").expect("lifecycle object");
        assert_eq!(l.get("cancelled").unwrap().as_usize().unwrap(), 2);
        assert_eq!(l.get("expired").unwrap().as_usize().unwrap(), 0);
        assert_eq!(l.get("rejected").unwrap().as_usize().unwrap(), 5);
    }

    #[test]
    fn batch_stats_occupancy_and_pad_waste() {
        let s = EngineStats::new(1);
        s.batch.note(4, 20, 32, 1_000);
        s.batch.note(2, 10, 16, 500);
        assert_eq!(s.batch.batches.load(Ordering::Relaxed), 2);
        assert_eq!(s.batch.coalesced.load(Ordering::Relaxed), 6);
        assert_eq!(s.batch.peak.load(Ordering::Relaxed), 4);
        assert!((s.batch.mean_occupancy() - 3.0).abs() < 1e-12);
        assert!((s.batch.pad_waste_frac() - (1.0 - 30.0 / 48.0)).abs() < 1e-12);
        let j = s.to_json(1_000);
        let b = j.get("batch").unwrap();
        assert_eq!(b.get("batches").unwrap().as_usize().unwrap(), 2);
        assert_eq!(b.get("peak_occupancy").unwrap().as_usize().unwrap(), 4);
        assert!(s.report(1_000).contains("batched verify"));
    }

    #[test]
    fn step_stats_histogram_and_rates() {
        let s = EngineStats::new(1);
        s.step.note_step(4, 2);
        s.step.note_step(4, 0);
        s.step.note_step(1, 1);
        s.draft.note(9, 3, 18, 32);
        assert_eq!(s.step.steps.load(Ordering::Relaxed), 3);
        assert!((s.step.admissions_per_step() - 1.0).abs() < 1e-12);
        assert!((s.step.mean_inflight() - 3.0).abs() < 1e-12);
        assert_eq!(s.step.peak_inflight.load(Ordering::Relaxed), 4);
        assert!((s.draft.mean_occupancy() - 3.0).abs() < 1e-12);
        assert!((s.draft.pad_waste_frac() - (1.0 - 18.0 / 32.0)).abs() < 1e-12);
        let j = s.to_json(1_000);
        let step = j.get("step").expect("step object present once iterations ran");
        assert_eq!(step.get("steps").unwrap().as_usize().unwrap(), 3);
        assert_eq!(step.get("peak_in_flight").unwrap().as_usize().unwrap(), 4);
        let hist = step.get("in_flight_hist").unwrap().f64s();
        assert_eq!(hist.len(), 5, "trailing empty buckets trimmed");
        assert_eq!(hist[4] as u64, 2);
        assert_eq!(hist[1] as u64, 1);
        let draft = j.get("draft").expect("draft gauges always present");
        assert_eq!(draft.get("forwards").unwrap().as_usize().unwrap(), 3);
        assert!(s.report(1_000).contains("step loop"));
    }

    #[test]
    fn step_object_absent_in_workers_mode() {
        let s = EngineStats::new(1);
        s.draft.note(2, 2, 10, 10);
        let j = s.to_json(1_000);
        assert!(j.get("step").is_none(), "no iterations ran");
        assert!(j.get("draft").is_some());
        assert!((s.draft.mean_occupancy() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn pipeline_stats_rates_and_json_gating() {
        let s = EngineStats::new(1);
        assert!(s.to_json(1_000).get("pipeline").is_none(), "absent until a round runs");
        s.pipeline.note_round(true, 600, 400);
        s.pipeline.note_round(false, 0, 1_000);
        s.pipeline.rows_adopted.fetch_add(3, Ordering::Relaxed);
        s.pipeline.rows_discarded.fetch_add(1, Ordering::Relaxed);
        s.pipeline.redraft_forwards.fetch_add(1, Ordering::Relaxed);
        assert!((s.pipeline.overlap_ratio() - 0.3).abs() < 1e-12);
        assert!((s.pipeline.discard_rate() - 0.25).abs() < 1e-12);
        let j = s.to_json(1_000);
        let p = j.get("pipeline").expect("pipeline object once rounds ran");
        assert_eq!(p.get("rounds").unwrap().as_usize().unwrap(), 2);
        assert_eq!(p.get("spec_forwards").unwrap().as_usize().unwrap(), 1);
        assert_eq!(p.get("rows_adopted").unwrap().as_usize().unwrap(), 3);
        assert_eq!(p.get("rows_discarded").unwrap().as_usize().unwrap(), 1);
        assert_eq!(p.get("redraft_forwards").unwrap().as_usize().unwrap(), 1);
        assert!(s.report(1_000).contains("pipeline: 2 rounds"));
    }

    #[test]
    fn cache_stats_rates_and_json() {
        let c = CacheStats::new(2, true);
        c.note_lookup(10, 0);
        c.note_lookup(10, 6);
        c.note_lookup(20, 10);
        c.note_eviction();
        c.note_served(0);
        c.note_served(1);
        c.note_served(1);
        c.note_served(9); // out-of-range slot ids are ignored, not a panic
        assert!((c.hit_rate() - 2.0 / 3.0).abs() < 1e-12);
        assert!((c.cached_token_ratio() - 16.0 / 40.0).abs() < 1e-12);
        let j = c.to_json();
        assert_eq!(j.get("lookups").unwrap().as_usize().unwrap(), 3);
        assert_eq!(j.get("hits").unwrap().as_usize().unwrap(), 2);
        assert_eq!(j.get("cached_tokens").unwrap().as_usize().unwrap(), 16);
        assert_eq!(j.get("evictions").unwrap().as_usize().unwrap(), 1);
        let served = j.get("served").unwrap().f64s();
        assert_eq!(served, vec![1.0, 2.0]);
    }

    #[test]
    fn engine_stats_track_depth_and_utilization() {
        let s = EngineStats::new(2);
        s.note_depth(3);
        s.note_depth(7);
        s.note_depth(1);
        assert_eq!(s.queue_depth.load(Ordering::Relaxed), 1);
        assert_eq!(s.peak_queue_depth.load(Ordering::Relaxed), 7);
        s.workers[0].busy_ns.store(500, Ordering::Relaxed);
        s.workers[1].busy_ns.store(500, Ordering::Relaxed);
        assert!((s.utilization(1000) - 0.5).abs() < 1e-12);
        let j = s.to_json(1000);
        assert_eq!(j.get("workers").unwrap().as_usize().unwrap(), 2);
        assert!(s.report(1000).contains("worker 1"));
    }
}
