//! Serving metrics: per-request records aggregated into the latency /
//! throughput report the end-to-end example prints (TTFT ≈ queue + prefill
//! + first verified commit; TPOT = decode time per generated token).

use crate::util::stats::Samples;
use crate::util::Json;

use super::request::Response;

#[derive(Debug, Default)]
pub struct EngineMetrics {
    pub completed: u64,
    pub new_tokens: u64,
    pub drafted: u64,
    pub accepted: u64,
    pub queue_ms: Samples,
    pub total_ms: Samples,
    pub decode_ms: Samples,
    pub tpot_ms: Samples,
    pub ttft_ms: Samples,
    /// wall-clock span covered by the record stream (throughput basis)
    pub span_ns: u64,
}

impl EngineMetrics {
    pub fn record(&mut self, r: &Response) {
        self.completed += 1;
        self.new_tokens += r.result.new_tokens().len() as u64;
        self.drafted += r.result.drafted() as u64;
        self.accepted += r.result.accepted() as u64;
        self.queue_ms.push(r.queue_ns as f64 / 1e6);
        self.total_ms.push(r.total_ns as f64 / 1e6);
        self.decode_ms.push(r.result.wall_ns as f64 / 1e6);
        let n = r.result.new_tokens().len().max(1) as f64;
        self.tpot_ms.push(r.result.wall_ns as f64 / 1e6 / n);
        // first commit ≈ first round (prefill + draft + verify) + queueing
        let first_round_ns = r
            .result
            .rounds
            .first()
            .map(|x| x.draft_ns + x.verify_ns)
            .unwrap_or(r.result.wall_ns);
        self.ttft_ms.push((r.queue_ns + first_round_ns) as f64 / 1e6);
    }

    pub fn acceptance_rate(&self) -> f64 {
        if self.drafted == 0 { 0.0 } else { self.accepted as f64 / self.drafted as f64 }
    }

    pub fn throughput_tok_s(&self) -> f64 {
        if self.span_ns == 0 {
            return 0.0;
        }
        self.new_tokens as f64 / (self.span_ns as f64 / 1e9)
    }

    pub fn report(&mut self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "requests: {}   generated tokens: {}   acceptance: {:.2}\n",
            self.completed,
            self.new_tokens,
            self.acceptance_rate()
        ));
        if self.span_ns > 0 {
            s.push_str(&format!("throughput: {:.1} tok/s\n", self.throughput_tok_s()));
        }
        let mut line = |name: &str, smp: &mut Samples| {
            format!(
                "{name:<10} mean {:>8.2} ms   p50 {:>8.2}   p95 {:>8.2}   p99 {:>8.2}\n",
                smp.mean(),
                smp.percentile(50.0),
                smp.percentile(95.0),
                smp.percentile(99.0)
            )
        };
        let q = line("queue", &mut self.queue_ms);
        let t = line("ttft", &mut self.ttft_ms);
        let d = line("decode", &mut self.decode_ms);
        let p = line("tpot", &mut self.tpot_ms);
        let e = line("e2e", &mut self.total_ms);
        s.push_str(&q);
        s.push_str(&t);
        s.push_str(&d);
        s.push_str(&p);
        s.push_str(&e);
        s
    }

    pub fn to_json(&mut self) -> Json {
        let mut o = Json::obj();
        o.set("completed", self.completed as usize)
            .set("new_tokens", self.new_tokens as usize)
            .set("acceptance_rate", self.acceptance_rate())
            .set("throughput_tok_s", self.throughput_tok_s())
            .set("ttft_p50_ms", self.ttft_ms.percentile(50.0))
            .set("ttft_p99_ms", self.ttft_ms.percentile(99.0))
            .set("tpot_mean_ms", self.tpot_ms.mean())
            .set("e2e_p50_ms", self.total_ms.percentile(50.0))
            .set("e2e_p99_ms", self.total_ms.percentile(99.0));
        o
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spec::GenResult;

    fn resp(id: u64, tokens: usize, wall_ms: u64) -> Response {
        let mut result = GenResult::default();
        result.tokens = vec![0; tokens + 4];
        result.prompt_len = 4;
        result.wall_ns = wall_ms * 1_000_000;
        Response { id, text: String::new(), result, queue_ns: 1_000_000, total_ns: wall_ms * 1_000_000 + 1_000_000 }
    }

    #[test]
    fn aggregates_and_reports() {
        let mut m = EngineMetrics::default();
        m.record(&resp(1, 10, 20));
        m.record(&resp(2, 30, 30));
        m.span_ns = 1_000_000_000;
        assert_eq!(m.completed, 2);
        assert_eq!(m.new_tokens, 40);
        assert!((m.throughput_tok_s() - 40.0).abs() < 1e-9);
        let rep = m.report();
        assert!(rep.contains("requests: 2"));
        assert!(rep.contains("tpot"));
        let j = m.to_json();
        assert_eq!(j.get("completed").unwrap().as_usize().unwrap(), 2);
    }
}
