//! Request/response types for the serving engine.

use std::time::Instant;

use crate::spec::GenResult;

#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub prompt_text: String,
    /// pre-encoded prompt (BOS included); filled by the engine if empty
    pub prompt: Vec<u32>,
    pub category: String,
    pub max_new: usize,
    pub arrival: Instant,
}

impl Request {
    pub fn new(id: u64, prompt_text: impl Into<String>, max_new: usize) -> Request {
        Request {
            id,
            prompt_text: prompt_text.into(),
            prompt: Vec::new(),
            category: String::new(),
            max_new,
            arrival: Instant::now(),
        }
    }
}

#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub text: String,
    pub result: GenResult,
    /// queueing delay before decoding started
    pub queue_ns: u64,
    /// total time from arrival to completion
    pub total_ns: u64,
}

impl Response {
    pub fn tokens_per_sec(&self) -> f64 {
        let n = self.result.new_tokens().len() as f64;
        n / (self.result.wall_ns.max(1) as f64 / 1e9)
    }
}
