//! Request/response types and the per-request lifecycle for the serving
//! engine (docs/ARCHITECTURE.md §10).
//!
//! Every request moves through `Queued → Admitted → Decoding → {Done,
//! Cancelled, Expired, Rejected}` (plus `Failed` for decode errors). The
//! live stages are implicit in where the request sits (the scheduler
//! queue, a worker); the terminal stage is explicit on the reply as
//! [`FinishStatus`]. Two lifecycle controls ride on the request itself:
//!
//! * a shared [`CancelFlag`] — the submitter keeps a clone and can flip
//!   it at any time; workers honor it at every step boundary, slot-wait
//!   poll, and queue pop (the HTTP layer flips it on client disconnect);
//! * an absolute `deadline` — checked at the same boundaries, turning a
//!   too-slow request into an `Expired` reply instead of wasted decode.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::spec::{GenResult, EOS};

/// Shared cancellation flag: the submitter keeps one clone, the engine's
/// worker another. Setting it asks the engine to stop the request at the
/// next step boundary — committed tokens up to that point still come back
/// on the terminal reply.
#[derive(Clone, Debug, Default)]
pub struct CancelFlag(Arc<AtomicBool>);

impl CancelFlag {
    /// A fresh, un-cancelled flag.
    pub fn new() -> CancelFlag {
        CancelFlag::default()
    }

    /// Request cancellation (idempotent, thread-safe).
    pub fn cancel(&self) {
        self.0.store(true, Ordering::SeqCst);
    }

    /// Has cancellation been requested?
    pub fn is_cancelled(&self) -> bool {
        self.0.load(Ordering::SeqCst)
    }
}

/// Terminal lifecycle stage of one request (docs/ARCHITECTURE.md §10).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishStatus {
    /// decode ran to its natural end
    Done,
    /// decode failed with an error
    Failed,
    /// the client cancelled (explicit flag or disconnect)
    Cancelled,
    /// the absolute deadline passed before completion
    Expired,
    /// the admission controller shed the request (queue full)
    Rejected,
}

impl FinishStatus {
    /// Stable lowercase label (HTTP bodies, logs, metrics).
    pub fn label(&self) -> &'static str {
        match self {
            FinishStatus::Done => "done",
            FinishStatus::Failed => "failed",
            FinishStatus::Cancelled => "cancelled",
            FinishStatus::Expired => "expired",
            FinishStatus::Rejected => "rejected",
        }
    }
}

/// One queued generation request.
#[derive(Clone, Debug)]
pub struct Request {
    /// engine-assigned id (echoed in the reply)
    pub id: u64,
    /// raw prompt text
    pub prompt_text: String,
    /// pre-encoded prompt (BOS included); filled by the engine if empty
    pub prompt: Vec<u32>,
    /// workload category ("coding", "qa", ...; drives the simulator)
    pub category: String,
    /// tenant/domain key for the hierarchical bandit layers
    /// (docs/ARCHITECTURE.md §17); `""` is the global/default tenant.
    /// Never part of [`Request::scenario_seed`] — the tenant changes what
    /// the bandits *learn*, never what a prompt *decodes to*.
    pub tenant: String,
    /// decode budget
    pub max_new: usize,
    /// submission timestamp (queue/TTFT base)
    pub arrival: Instant,
    /// absolute completion deadline; `None` means no deadline (a server
    /// default may be applied at submit — server.rs)
    pub deadline: Option<Instant>,
    /// shared cancellation flag (clone it before submitting to keep a
    /// handle — [`Request::cancel_flag`])
    pub cancel: CancelFlag,
    /// prefix-cache placement hint: prompt tokens the dispatcher expects
    /// a slot-affinity checkout to reuse (docs/ARCHITECTURE.md §12).
    /// Stamped by the dispatcher from the pool's `peek_reuse` at
    /// admission; the SJF scheduler subtracts it from the service-cost
    /// estimate ([`Request::sched_cost`]). Advisory only — it never
    /// changes what decodes, just where the request sorts in the queue.
    pub cached_hint: usize,
}

impl Request {
    /// A text request with `arrival` stamped now.
    pub fn new(id: u64, prompt_text: impl Into<String>, max_new: usize) -> Request {
        Request {
            id,
            prompt_text: prompt_text.into(),
            prompt: Vec::new(),
            category: String::new(),
            tenant: String::new(),
            max_new,
            arrival: Instant::now(),
            deadline: None,
            cancel: CancelFlag::new(),
            cached_hint: 0,
        }
    }

    /// Set an absolute deadline `ms` milliseconds after arrival.
    pub fn with_deadline_ms(mut self, ms: u64) -> Request {
        self.deadline = Some(self.arrival + Duration::from_millis(ms));
        self
    }

    /// Key this request to a tenant (`""` keeps the global tenant).
    pub fn with_tenant(mut self, tenant: impl Into<String>) -> Request {
        self.tenant = tenant.into();
        self
    }

    /// A clone of the shared cancellation flag (keep it to cancel later).
    pub fn cancel_flag(&self) -> CancelFlag {
        self.cancel.clone()
    }

    /// Has this request's deadline passed?
    pub fn deadline_expired(&self) -> bool {
        self.deadline.is_some_and(|d| Instant::now() >= d)
    }

    /// Scheduling cost (SJF key): tokenized prompt length + decode budget.
    /// Before the engine has encoded the prompt, char count stands in for
    /// the token count (the tokenizer is char-level, manifest.rs).
    pub fn cost(&self) -> usize {
        let prompt_tokens = if self.prompt.is_empty() {
            self.prompt_text.chars().count()
        } else {
            self.prompt.len()
        };
        prompt_tokens + self.max_new
    }

    /// Scheduling cost net of the prefix-cache placement hint: the
    /// service estimate the SJF key and the scheduler's pending /
    /// in-flight ledgers use. Every [`crate::engine::Scheduler`] ledger
    /// release (`note_done`) must pass this same quantity so the ledgers
    /// conserve (scheduler.rs).
    pub fn sched_cost(&self) -> usize {
        self.cost().saturating_sub(self.cached_hint)
    }

    /// Deterministic per-request scenario seed (drives the simulator
    /// backend): a pure function of the prompt, so identical prompts
    /// decode identically on any worker of any engine.
    pub fn scenario_seed(&self) -> u64 {
        crate::util::fnv1a(
            self.prompt_text
                .bytes()
                .map(u64::from)
                .chain(self.prompt.iter().map(|&t| t as u64)),
        )
    }
}

/// The engine's reply to one request.
#[derive(Clone, Debug)]
pub struct Response {
    /// id of the request this answers
    pub id: u64,
    /// decoded text of the generated suffix
    pub text: String,
    /// full generation result (tokens + round stats); partial for
    /// cancelled/expired requests
    pub result: GenResult,
    /// queueing delay before decoding started
    pub queue_ns: u64,
    /// total time from arrival to completion
    pub total_ns: u64,
    /// terminal lifecycle stage this reply reports
    pub status: FinishStatus,
    /// decode failure or shed/cancel/expiry explanation — a failed
    /// request still gets a reply so clients never hang on a dropped
    /// channel
    pub error: Option<String>,
}

impl Response {
    /// An error reply carrying no generation result.
    pub fn failure(id: u64, queue_ns: u64, total_ns: u64, error: String) -> Response {
        Response {
            id,
            text: String::new(),
            result: GenResult::default(),
            queue_ns,
            total_ns,
            status: FinishStatus::Failed,
            error: Some(error),
        }
    }

    /// A terminal non-decode reply (rejected / cancelled-before-decode /
    /// expired-in-queue).
    pub fn terminal(
        id: u64,
        status: FinishStatus,
        queue_ns: u64,
        total_ns: u64,
        why: impl Into<String>,
    ) -> Response {
        Response {
            id,
            text: String::new(),
            result: GenResult::default(),
            queue_ns,
            total_ns,
            status,
            error: Some(why.into()),
        }
    }

    /// Did the decode run to its natural end?
    pub fn is_ok(&self) -> bool {
        self.status == FinishStatus::Done && self.error.is_none()
    }

    /// Decode throughput of this single request.
    pub fn tokens_per_sec(&self) -> f64 {
        let n = self.result.new_tokens().len() as f64;
        n / (self.result.wall_ns.max(1) as f64 / 1e9)
    }
}

/// One event on a streaming reply channel
/// ([`crate::engine::Engine::submit_request_streaming`]).
#[derive(Clone, Debug)]
pub enum StreamEvent {
    /// tokens committed by one decode round, already clipped to the
    /// serving contract (≤ max_new, nothing past the first EOS) — the
    /// concatenation over all events equals the non-streaming reply body
    Tokens {
        /// request id
        id: u64,
        /// newly committed token ids
        ids: Vec<u32>,
        /// decoded text of exactly those ids
        text: String,
    },
    /// terminal event carrying the full reply (always the last event)
    Done(Box<Response>),
}

/// Incremental enforcement of the serving reply contract: never more than
/// `budget` tokens, nothing past the first EOS. Feeding it each round's
/// committed tokens yields exactly the prefix the final (truncated) reply
/// contains, so streamed chunks concatenate to the non-streaming body —
/// and `done` tells the worker when further decode rounds can no longer
/// change the reply.
#[derive(Clone, Copy, Debug)]
pub struct EmitClip {
    budget: usize,
    emitted: usize,
    done: bool,
}

impl EmitClip {
    /// A clip window of `budget` (= the request's `max_new`) tokens.
    pub fn new(budget: usize) -> EmitClip {
        EmitClip { budget, emitted: 0, done: false }
    }

    /// Clip one round's committed tokens against the remaining budget and
    /// the first EOS. Returns the emittable slice and whether the reply
    /// is now fully determined.
    pub fn clip<'t>(&mut self, toks: &'t [u32]) -> (&'t [u32], bool) {
        if self.done || self.emitted >= self.budget {
            self.done = true;
            return (&toks[..0], true);
        }
        let mut take = toks.len().min(self.budget - self.emitted);
        if let Some(p) = toks[..take].iter().position(|&t| t == EOS) {
            take = p + 1;
            self.done = true;
        }
        self.emitted += take;
        if self.emitted >= self.budget {
            self.done = true;
        }
        (&toks[..take], self.done)
    }

    /// Tokens emitted so far.
    pub fn emitted(&self) -> usize {
        self.emitted
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The reply contract applied in one shot (what the worker does to
    /// the final result): truncate to max_new, then to the first EOS.
    fn oneshot(toks: &[u32], budget: usize) -> Vec<u32> {
        let mut v = toks[..toks.len().min(budget)].to_vec();
        if let Some(p) = v.iter().position(|&t| t == EOS) {
            v.truncate(p + 1);
        }
        v
    }

    #[test]
    fn clip_matches_oneshot_truncation_round_by_round() {
        // rounds with an EOS mid-stream and budget overshoot
        let rounds: Vec<Vec<u32>> = vec![
            vec![5, 6, 7],
            vec![8],
            vec![9, EOS, 11],
            vec![12, 13],
        ];
        for budget in [0, 1, 3, 4, 5, 6, 9, 50] {
            let flat: Vec<u32> = rounds.iter().flatten().copied().collect();
            let want = oneshot(&flat, budget);
            let mut clip = EmitClip::new(budget);
            let mut got = Vec::new();
            for r in &rounds {
                let (emit, done) = clip.clip(r);
                got.extend_from_slice(emit);
                if done {
                    break;
                }
            }
            assert_eq!(got, want, "budget {budget}");
            assert_eq!(clip.emitted(), want.len(), "budget {budget}");
        }
    }

    #[test]
    fn clip_eos_beyond_budget_does_not_count() {
        let mut clip = EmitClip::new(2);
        let (emit, done) = clip.clip(&[5, 6, EOS]);
        assert_eq!(emit, &[5, 6]);
        assert!(done, "budget reached");
    }

    #[test]
    fn cancel_flag_is_shared() {
        let req = Request::new(1, "x", 8);
        let flag = req.cancel_flag();
        assert!(!req.cancel.is_cancelled());
        flag.cancel();
        assert!(req.cancel.is_cancelled());
        let clone = req.clone();
        assert!(clone.cancel.is_cancelled(), "clones share the flag");
    }

    #[test]
    fn tenant_never_changes_the_scenario_seed() {
        let a = Request::new(1, "same prompt", 8);
        let b = Request::new(2, "same prompt", 8).with_tenant("code");
        assert_eq!(a.scenario_seed(), b.scenario_seed());
        assert_eq!(a.tenant, "");
        assert_eq!(b.tenant, "code");
    }

    #[test]
    fn deadline_expiry() {
        let req = Request::new(1, "x", 8);
        assert!(!req.deadline_expired(), "no deadline never expires");
        let req = req.with_deadline_ms(0);
        assert!(req.deadline_expired(), "0ms deadline is already past");
    }

    #[test]
    fn terminal_and_failure_statuses() {
        let r = Response::failure(3, 1, 2, "boom".into());
        assert_eq!(r.status, FinishStatus::Failed);
        assert!(!r.is_ok());
        let r = Response::terminal(4, FinishStatus::Rejected, 1, 1, "queue full");
        assert_eq!(r.status.label(), "rejected");
        assert!(!r.is_ok());
    }
}
