//! Request/response types for the serving engine.

use std::time::Instant;

use crate::spec::GenResult;

/// One queued generation request.
#[derive(Clone, Debug)]
pub struct Request {
    /// engine-assigned id (echoed in the reply)
    pub id: u64,
    /// raw prompt text
    pub prompt_text: String,
    /// pre-encoded prompt (BOS included); filled by the engine if empty
    pub prompt: Vec<u32>,
    /// workload category ("coding", "qa", ...; drives the simulator)
    pub category: String,
    /// decode budget
    pub max_new: usize,
    /// submission timestamp (queue/TTFT base)
    pub arrival: Instant,
}

impl Request {
    /// A text request with `arrival` stamped now.
    pub fn new(id: u64, prompt_text: impl Into<String>, max_new: usize) -> Request {
        Request {
            id,
            prompt_text: prompt_text.into(),
            prompt: Vec::new(),
            category: String::new(),
            max_new,
            arrival: Instant::now(),
        }
    }

    /// Scheduling cost (SJF key): tokenized prompt length + decode budget.
    /// Before the engine has encoded the prompt, char count stands in for
    /// the token count (the tokenizer is char-level, manifest.rs).
    pub fn cost(&self) -> usize {
        let prompt_tokens = if self.prompt.is_empty() {
            self.prompt_text.chars().count()
        } else {
            self.prompt.len()
        };
        prompt_tokens + self.max_new
    }

    /// Deterministic per-request scenario seed (drives the simulator
    /// backend): a pure function of the prompt, so identical prompts
    /// decode identically on any worker of any engine.
    pub fn scenario_seed(&self) -> u64 {
        crate::util::fnv1a(
            self.prompt_text
                .bytes()
                .map(u64::from)
                .chain(self.prompt.iter().map(|&t| t as u64)),
        )
    }
}

/// The engine's reply to one request.
#[derive(Clone, Debug)]
pub struct Response {
    /// id of the request this answers
    pub id: u64,
    /// decoded text of the generated suffix
    pub text: String,
    /// full generation result (tokens + round stats)
    pub result: GenResult,
    /// queueing delay before decoding started
    pub queue_ns: u64,
    /// total time from arrival to completion
    pub total_ns: u64,
    /// decode failure, if any — a failed request still gets a reply so
    /// clients never hang on a dropped channel
    pub error: Option<String>,
}

impl Response {
    /// An error reply carrying no generation result.
    pub fn failure(id: u64, queue_ns: u64, total_ns: u64, error: String) -> Response {
        Response {
            id,
            text: String::new(),
            result: GenResult::default(),
            queue_ns,
            total_ns,
            error: Some(error),
        }
    }

    /// Did the decode succeed?
    pub fn is_ok(&self) -> bool {
        self.error.is_none()
    }

    /// Decode throughput of this single request.
    pub fn tokens_per_sec(&self) -> f64 {
        let n = self.result.new_tokens().len() as f64;
        n / (self.result.wall_ns.max(1) as f64 / 1e9)
    }
}
