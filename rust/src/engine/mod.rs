//! Serving layer (the vLLM-router-shaped part of L3): request types,
//! admission scheduler, concurrent KV slot pool, the dispatcher + decode
//! worker pool sharing one online bandit, serving metrics, and a minimal
//! HTTP JSON API. See DESIGN.md §2 for the concurrency design.

pub mod http;
pub mod metrics;
pub mod request;
pub mod scheduler;
pub mod server;
pub mod slots;

pub use http::HttpServer;
pub use metrics::{EngineMetrics, EngineStats, WorkerStats};
pub use request::{Request, Response};
pub use scheduler::{Policy, Scheduler};
pub use server::{BackendKind, Engine, EngineConfig};
pub use slots::{Slot, SlotPool};
