//! Serving layer (the vLLM-router-shaped part of L3): request types,
//! admission scheduler, KV slot pool, the engine worker with persistent
//! online bandit state, serving metrics, and a minimal HTTP JSON API.

pub mod http;
pub mod metrics;
pub mod request;
pub mod scheduler;
pub mod server;
pub mod slots;

pub use http::HttpServer;
pub use metrics::EngineMetrics;
pub use request::{Request, Response};
pub use scheduler::{Policy, Scheduler};
pub use server::{Engine, EngineConfig};
pub use slots::{Slot, SlotPool};
