//! Serving layer (the vLLM-router-shaped part of L3): request types and
//! the per-request lifecycle (cancellation, deadlines, streaming),
//! admission scheduler + load shedding, concurrent KV slot pool, the
//! dispatcher + decode worker pool sharing one online bandit, the
//! cross-session verification batcher, serving metrics, and a minimal
//! HTTP JSON/SSE API. See docs/ARCHITECTURE.md §3–§5 for the concurrency
//! design and §10 for the request lifecycle (DESIGN.md keeps the legacy
//! section map).

pub mod batcher;
pub mod http;
pub mod metrics;
pub mod request;
pub mod scheduler;
pub mod server;
pub mod slots;

pub use batcher::{BatchConfig, BatchedTarget, Batcher, BatcherHandle};
pub use http::HttpServer;
pub use metrics::{BatchStats, EngineMetrics, EngineStats, LifecycleStats, WorkerStats};
pub use request::{CancelFlag, EmitClip, FinishStatus, Request, Response, StreamEvent};
pub use scheduler::{Policy, Scheduler};
pub use server::{BackendKind, Engine, EngineConfig};
pub use slots::{Slot, SlotPool};
