//! Serving layer (the vLLM-router-shaped part of L3): request types and
//! the per-request lifecycle (cancellation, deadlines, streaming),
//! admission scheduler + load shedding, concurrent KV slot pool, two
//! execution cores sharing one online bandit — the dispatcher + decode
//! worker pool with its cross-session verification batcher, and the
//! continuous-batching step loop ([`stepper`]) — serving metrics, and a
//! minimal HTTP JSON/SSE API. See docs/ARCHITECTURE.md §3–§5 for the
//! concurrency design, §10 for the request lifecycle, §11 for
//! continuous batching, §12 for the cross-request prefix-reuse KV
//! cache ([`cache`], slot-affinity checkout in [`slots`]) shared by both
//! execution modes, §13 for the paged KV allocator with
//! copy-on-write prefix sharing ([`paging`]) and chunked prefill, and
//! §15 for the nonblocking readiness-loop front end ([`reactor`]) and
//! the prefix-affinity multi-replica router ([`router`]), and §16 for
//! the overlapped draft/verify pipeline in the continuous stepper
//! (DESIGN.md keeps the legacy section map).

pub mod batcher;
pub mod cache;
pub mod http;
pub mod metrics;
pub mod paging;
pub mod reactor;
pub mod request;
pub mod router;
pub mod scheduler;
pub mod server;
pub mod slots;
pub mod stepper;

pub use batcher::{BatchConfig, BatchedTarget, Batcher, BatcherHandle};
pub use cache::PrefixIndex;
pub use http::{HttpConfig, HttpServer};
pub use metrics::{
    BatchStats, CacheStats, DraftStats, EngineMetrics, EngineStats, IoStats, LifecycleStats,
    PageStats, PipelineStats, StepStats, WorkerStats,
};
pub use reactor::{EventSource, Gateway, GenerateStart, Reactor, ReactorConfig, SourceEvent};
pub use router::{HashRing, ReplicaView, Router, RouterConfig, RouterCore};
pub use paging::{PageOp, PagePool};
pub use request::{CancelFlag, EmitClip, FinishStatus, Request, Response, StreamEvent};
pub use scheduler::{Policy, Scheduler};
pub use server::{BackendKind, Engine, EngineConfig, EngineMode};
pub use slots::{Lease, Slot, SlotPool, DEFAULT_PAGE_SIZE};
