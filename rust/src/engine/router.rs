//! Prefix-affinity multi-replica router (docs/ARCHITECTURE.md §15).
//!
//! Fronts N engine replicas behind one address. Placement is pure
//! policy, never correctness:
//!
//! * **Prefix affinity** — the routing key is the first KV *page* of the
//!   tokenized prompt (BOS + `sim_encode`, [`DEFAULT_PAGE_SIZE`]-token
//!   granularity, matching `PagePool`), consistent-hashed onto a vnode
//!   ring. Same-prefix bursts land on the replica that already holds
//!   the prefix in its PR 5/6 prefix cache and COW page arena, so cache
//!   hit-rates concentrate instead of diluting 1/N.
//! * **Shed-aware balancing** — each replica's SJF `queue_wait_estimate`
//!   (already exported under `sched.queue_wait_est_cost` in `/metrics`)
//!   is probed periodically; when the affinity target's queue is far
//!   above the fleet minimum, the request overflows to the least-loaded
//!   replica (locality is worthless if the hot replica is the
//!   bottleneck).
//! * **Health + draining + failover** — a prober thread polls each
//!   replica's `/health`; dead replicas leave the ring until they come
//!   back, draining replicas accept no new work but keep their in-flight
//!   streams. Requests not yet delivered upstream retry the next
//!   replica; once a request has been delivered, an upstream death is
//!   answered honestly (plain 502, or a synthesized terminal
//!   `status: "failed"` SSE event mid-stream) — never silently retried,
//!   because the decode may already be running.
//!
//! The decision logic lives in [`RouterCore`] with no I/O so the
//! deterministic sim harness (sim_harness/) drives the *same* routing
//! code under replica kill/drain fault plans. The live data plane runs
//! behind the same [`Reactor`](super::reactor::Reactor) event loop as
//! the engine front end; each routed generate gets a proxy thread that
//! relays upstream bytes into the connection's event queue.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, Sender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::Result;

use crate::models::sim_encode;
use crate::spec::BOS;
use crate::util::{fnv1a, Json};

use super::http;
use super::metrics::IoStats;
use super::reactor::{EventSource, Gateway, GenerateStart, Reactor, ReactorConfig, SourceEvent};
use super::request::FinishStatus;
use super::slots::DEFAULT_PAGE_SIZE;

/// Virtual nodes per replica on the consistent-hash ring: enough that
/// key ranges split evenly across a handful of replicas.
const VNODES: usize = 64;

/// Shed rule: overflow away from the affinity target when its probed
/// queue-wait exceeds `SHED_SLACK + SHED_FACTOR ×` the fleet minimum.
const SHED_FACTOR: f64 = 2.0;
/// Absolute queue-wait slack (scheduler cost units) below which affinity
/// always wins — small queues never trigger overflow.
const SHED_SLACK: f64 = 256.0;

/// Routing key: FNV-1a over the first page of the tokenized prompt
/// (BOS + `sim_encode`, `page_size`-token granularity). Two prompts
/// sharing their first KV page share their key — exactly the prefix the
/// replica's page arena can serve from cache.
pub fn prefix_key(prompt: &str, page_size: usize) -> u64 {
    let mut toks = vec![BOS];
    toks.extend(sim_encode(prompt));
    fnv1a(toks.into_iter().take(page_size.max(1)).map(u64::from))
}

/// Consistent-hash ring over replica *indices* ([`VNODES`] points each).
/// Index-keyed (not address-keyed) so the deterministic sim shares the
/// exact placement function with the live router.
pub struct HashRing {
    points: Vec<(u64, usize)>,
}

impl HashRing {
    /// Ring over `replicas` indices.
    pub fn new(replicas: usize) -> HashRing {
        let mut points = Vec::with_capacity(replicas * VNODES);
        for r in 0..replicas {
            for v in 0..VNODES {
                points.push((fnv1a([0x5EED, r as u64, v as u64]), r));
            }
        }
        points.sort_unstable();
        HashRing { points }
    }

    /// First usable replica at or clockwise of `key` — the stable owner,
    /// or its ring successor when the owner is dead/draining (so a
    /// replica outage moves only that replica's keys).
    pub fn lookup(&self, key: u64, usable: impl Fn(usize) -> bool) -> Option<usize> {
        if self.points.is_empty() {
            return None;
        }
        let start = self.points.partition_point(|&(p, _)| p < key);
        for i in 0..self.points.len() {
            let (_, r) = self.points[(start + i) % self.points.len()];
            if usable(r) {
                return Some(r);
            }
        }
        None
    }
}

/// One replica's routable state, as the decision logic sees it (the
/// live router fills these from probes; the sim fills them from its
/// in-process replicas).
#[derive(Clone, Copy, Debug, Default)]
pub struct ReplicaView {
    /// is the replica answering `/health` (sim: not killed)?
    pub alive: bool,
    /// draining: finish in-flight work, accept nothing new
    pub draining: bool,
    /// probed SJF queue-wait estimate (scheduler cost units)
    pub queue_wait: f64,
}

/// Where one request goes and why.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RouteDecision {
    /// chosen replica index
    pub replica: usize,
    /// true when consistent hashing placed it (prefix locality)
    pub affinity: bool,
    /// true when the shed rule overrode the affinity target
    pub shed: bool,
}

/// Pure routing policy: consistent-hash prefix affinity with shed-aware
/// overflow, or plain round-robin when affinity is off. No I/O — shared
/// verbatim by the live router and the deterministic sim harness.
pub struct RouterCore {
    ring: HashRing,
    /// prefix-key granularity in tokens (the pool's KV page size)
    pub page_size: usize,
    /// consistent-hash prefix affinity (true) vs round-robin (false)
    pub affinity: bool,
    rr: AtomicUsize,
}

impl RouterCore {
    /// Policy over `replicas` indices at `page_size`-token granularity.
    pub fn new(replicas: usize, page_size: usize, affinity: bool) -> RouterCore {
        RouterCore {
            ring: HashRing::new(replicas),
            page_size: page_size.max(1),
            affinity,
            rr: AtomicUsize::new(0),
        }
    }

    /// Place one prompt. `None` when no replica is alive and accepting
    /// (the caller answers 503 / `Rejected`).
    pub fn route(&self, prompt: &str, views: &[ReplicaView]) -> Option<RouteDecision> {
        let routable: Vec<usize> =
            (0..views.len()).filter(|&r| views[r].alive && !views[r].draining).collect();
        if routable.is_empty() {
            return None;
        }
        if !self.affinity {
            let i = self.rr.fetch_add(1, Ordering::Relaxed) % routable.len();
            return Some(RouteDecision { replica: routable[i], affinity: false, shed: false });
        }
        let key = prefix_key(prompt, self.page_size);
        let chosen = self.ring.lookup(key, |r| views[r].alive && !views[r].draining)?;
        let min_wait = routable
            .iter()
            .map(|&r| views[r].queue_wait)
            .fold(f64::INFINITY, f64::min)
            .max(0.0);
        if views[chosen].queue_wait > SHED_SLACK + SHED_FACTOR * min_wait {
            // the affinity target is the bottleneck: overflow to the
            // least-loaded routable replica (cold prefill beats queueing)
            let best = routable.into_iter().min_by(|&a, &b| {
                views[a]
                    .queue_wait
                    .partial_cmp(&views[b].queue_wait)
                    .unwrap_or(std::cmp::Ordering::Equal)
            })?;
            if best != chosen {
                return Some(RouteDecision { replica: best, affinity: false, shed: true });
            }
        }
        Some(RouteDecision { replica: chosen, affinity: true, shed: false })
    }
}

/// Router data-plane counters (`router` object in the fleet `/metrics`).
#[derive(Debug, Default)]
pub struct RouterStats {
    /// generate requests placed on a replica
    pub routed: AtomicU64,
    /// placements made by the consistent-hash prefix key
    pub affinity_hits: AtomicU64,
    /// placements where the shed rule overrode the affinity target
    pub shed_reroutes: AtomicU64,
    /// undelivered requests retried on the next replica
    pub failovers: AtomicU64,
    /// undelivered retries whose body was already *partially* written
    /// when the owning replica died — the chunked-delivery path proved
    /// the body incomplete (the replica cannot have parsed a short
    /// `Content-Length` body), so the re-dispatch is known safe
    pub partial_redispatches: AtomicU64,
    /// upstream deaths after delivery (502 / synthesized failed stream)
    pub upstream_errors: AtomicU64,
}

impl RouterStats {
    /// JSON object for the fleet `/metrics` `router` field.
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("routed", self.routed.load(Ordering::Relaxed) as usize)
            .set("affinity_hits", self.affinity_hits.load(Ordering::Relaxed) as usize)
            .set("shed_reroutes", self.shed_reroutes.load(Ordering::Relaxed) as usize)
            .set("failovers", self.failovers.load(Ordering::Relaxed) as usize)
            .set(
                "partial_redispatches",
                self.partial_redispatches.load(Ordering::Relaxed) as usize,
            )
            .set("upstream_errors", self.upstream_errors.load(Ordering::Relaxed) as usize);
        o
    }
}

/// Router construction knobs (`tapout route` maps its flags onto this).
#[derive(Clone, Debug)]
pub struct RouterConfig {
    /// replica addresses (`host:port`), index order fixes ring identity
    pub replicas: Vec<String>,
    /// prefix affinity on (consistent hashing) or off (round-robin)
    pub affinity: bool,
    /// prefix-key granularity in tokens; match the replicas' page size
    pub page_size: usize,
    /// health/metrics probe interval
    pub probe_ms: u64,
    /// reactor I/O threads for the client-facing front end
    pub io_threads: usize,
    /// slow-loris bound for client connections
    pub header_timeout_ms: u64,
    /// SSE keep-alive interval for client streams
    pub sse_keepalive_ms: u64,
    /// replica addresses that boot in the draining state
    pub drain: Vec<String>,
}

impl Default for RouterConfig {
    fn default() -> RouterConfig {
        RouterConfig {
            replicas: Vec::new(),
            affinity: true,
            page_size: DEFAULT_PAGE_SIZE,
            probe_ms: 200,
            io_threads: 4,
            header_timeout_ms: 10_000,
            sse_keepalive_ms: 15_000,
            drain: Vec::new(),
        }
    }
}

struct ReplicaState {
    addr: String,
    alive: AtomicBool,
    draining: AtomicBool,
    queue_wait_bits: AtomicU64,
    snapshot: Mutex<Option<Json>>,
}

fn views(states: &[ReplicaState]) -> Vec<ReplicaView> {
    states
        .iter()
        .map(|s| ReplicaView {
            alive: s.alive.load(Ordering::SeqCst),
            draining: s.draining.load(Ordering::SeqCst),
            queue_wait: f64::from_bits(s.queue_wait_bits.load(Ordering::Relaxed)),
        })
        .collect()
}

/// The running router: reactor front end + health prober + per-request
/// proxy data plane over N replicas.
pub struct Router {
    /// bound client-facing address
    pub addr: String,
    states: Arc<Vec<ReplicaState>>,
    reactor: Reactor,
    stop_probe: Arc<AtomicBool>,
    probe: Option<std::thread::JoinHandle<()>>,
}

impl Router {
    /// Bind `port` (0 picks a free port) and front `cfg.replicas`.
    pub fn start(cfg: RouterConfig, port: u16) -> Result<Router> {
        if cfg.replicas.is_empty() {
            anyhow::bail!("router needs at least one replica address");
        }
        let states: Arc<Vec<ReplicaState>> = Arc::new(
            cfg.replicas
                .iter()
                .map(|a| ReplicaState {
                    addr: a.clone(),
                    alive: AtomicBool::new(false),
                    draining: AtomicBool::new(cfg.drain.contains(a)),
                    queue_wait_bits: AtomicU64::new(0f64.to_bits()),
                    snapshot: Mutex::new(None),
                })
                .collect(),
        );
        let stats = Arc::new(RouterStats::default());
        let io = Arc::new(IoStats::new("router", cfg.io_threads.max(1)));
        let gateway: Arc<dyn Gateway> = Arc::new(RouterGateway {
            core: RouterCore::new(states.len(), cfg.page_size, cfg.affinity),
            states: states.clone(),
            stats,
            io: io.clone(),
        });
        let rcfg = ReactorConfig {
            io_threads: cfg.io_threads.max(1),
            header_timeout: Duration::from_millis(cfg.header_timeout_ms.max(1)),
            sse_keepalive: Duration::from_millis(cfg.sse_keepalive_ms.max(1)),
        };
        let reactor = Reactor::start(gateway, port, rcfg, io)?;
        let stop_probe = Arc::new(AtomicBool::new(false));
        let st = states.clone();
        let sp = stop_probe.clone();
        let probe_ms = cfg.probe_ms.max(10);
        let probe = std::thread::Builder::new()
            .name("tapout-probe".into())
            .spawn(move || probe_loop(&st, &sp, probe_ms))?;
        Ok(Router { addr: reactor.addr.clone(), states, reactor, stop_probe, probe: Some(probe) })
    }

    /// Mark replica `idx` draining (true) or accepting (false); in-flight
    /// work is untouched either way.
    pub fn drain(&self, idx: usize, on: bool) {
        if let Some(s) = self.states.get(idx) {
            s.draining.store(on, Ordering::SeqCst);
        }
    }

    /// Last probed liveness of replica `idx`.
    pub fn replica_alive(&self, idx: usize) -> bool {
        self.states.get(idx).map(|s| s.alive.load(Ordering::SeqCst)).unwrap_or(false)
    }

    /// Stop serving: sever client connections, join the I/O pool and the
    /// prober. In-flight proxy threads finish with their upstreams.
    pub fn stop(&mut self) {
        self.reactor.stop();
        self.stop_probe.store(true, Ordering::SeqCst);
        if let Some(h) = self.probe.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Router {
    fn drop(&mut self) {
        self.stop();
    }
}

fn probe_loop(states: &[ReplicaState], stop: &AtomicBool, probe_ms: u64) {
    let timeout = Duration::from_millis(250);
    loop {
        for st in states {
            if stop.load(Ordering::Relaxed) {
                return;
            }
            let ok = blocking_get(&st.addr, "/health", timeout)
                .map(|(code, j)| {
                    code == 200 && j.get("ok").and_then(|b| b.as_bool()).unwrap_or(false)
                })
                .unwrap_or(false);
            st.alive.store(ok, Ordering::SeqCst);
            if !ok {
                continue;
            }
            if let Some((200, m)) = blocking_get(&st.addr, "/metrics", timeout) {
                let qw = m
                    .get("sched")
                    .and_then(|s| s.get("queue_wait_est_cost"))
                    .and_then(|x| x.as_f64())
                    .unwrap_or(0.0);
                st.queue_wait_bits.store(qw.to_bits(), Ordering::Relaxed);
                *st.snapshot.lock().unwrap() = Some(m);
            }
        }
        // sleep in short slices so stop() returns promptly
        let mut left = probe_ms;
        while left > 0 && !stop.load(Ordering::Relaxed) {
            let step = left.min(25);
            std::thread::sleep(Duration::from_millis(step));
            left -= step;
        }
        if stop.load(Ordering::Relaxed) {
            return;
        }
    }
}

/// One-shot blocking GET with bounded connect/read time (prober only —
/// never runs on an I/O thread). Returns (status, parsed JSON body).
fn blocking_get(addr: &str, path: &str, timeout: Duration) -> Option<(u16, Json)> {
    let sa: std::net::SocketAddr = addr.parse().ok()?;
    let mut s = TcpStream::connect_timeout(&sa, timeout).ok()?;
    s.set_read_timeout(Some(timeout)).ok()?;
    s.set_write_timeout(Some(timeout)).ok()?;
    write!(s, "GET {path} HTTP/1.1\r\nHost: {addr}\r\nConnection: close\r\n\r\n").ok()?;
    let mut buf = String::new();
    s.read_to_string(&mut buf).ok()?;
    let code: u16 = buf.split_whitespace().nth(1)?.parse().ok()?;
    let body = buf.split_once("\r\n\r\n").map(|x| x.1).unwrap_or("");
    Some((code, Json::parse(body).ok()?))
}

// ---------------------------------------------------------------------------
// gateway (control plane)
// ---------------------------------------------------------------------------

struct RouterGateway {
    core: RouterCore,
    states: Arc<Vec<ReplicaState>>,
    stats: Arc<RouterStats>,
    io: Arc<IoStats>,
}

impl Gateway for RouterGateway {
    fn route(&self, method: &str, path: &str, body: &str) -> (u16, String) {
        match (method, path) {
            ("GET", "/health") => (200, self.fleet_health().render()),
            ("GET", "/metrics") => (200, self.fleet_metrics().render()),
            ("POST", "/admin/drain") => self.set_drain(body, true),
            ("POST", "/admin/undrain") => self.set_drain(body, false),
            _ => (404, http::err_body("not found")),
        }
    }

    fn generate(&self, body: &str, tenant: Option<&str>) -> GenerateStart {
        // identical client-error contract to a replica's own front end
        if let Err((code, j)) = http::parse_generate(body, tenant) {
            return GenerateStart::Immediate { code, body: j.render() };
        }
        let mut j = Json::parse(body).unwrap_or(Json::Null);
        // a header-borne tenant must survive the hop to the replica: the
        // relayed request carries only the body, so fold it in as the
        // `"tenant"` field (an existing body field wins, same precedence
        // as parse_generate)
        let forwarded;
        let body = match tenant {
            Some(t) if !t.is_empty() && j.get("tenant").and_then(|x| x.as_str()).is_none() => {
                j.set("tenant", t);
                forwarded = j.render();
                forwarded.as_str()
            }
            _ => body,
        };
        let prompt = j.get("prompt").and_then(|x| x.as_str()).unwrap_or("");
        let vs = views(&self.states);
        let Some(d) = self.core.route(prompt, &vs) else {
            return GenerateStart::Immediate { code: 503, body: http::err_body("no healthy replica") };
        };
        self.stats.routed.fetch_add(1, Ordering::Relaxed);
        if d.affinity {
            self.stats.affinity_hits.fetch_add(1, Ordering::Relaxed);
        }
        if d.shed {
            self.stats.shed_reroutes.fetch_add(1, Ordering::Relaxed);
        }
        // failover order: the decision, then the remaining routable
        // replicas by ascending probed queue-wait
        let mut order = vec![d.replica];
        let mut rest: Vec<usize> = (0..vs.len())
            .filter(|&r| r != d.replica && vs[r].alive && !vs[r].draining)
            .collect();
        rest.sort_by(|&a, &b| {
            vs[a].queue_wait.partial_cmp(&vs[b].queue_wait).unwrap_or(std::cmp::Ordering::Equal)
        });
        order.extend(rest);

        let (tx, rx) = std::sync::mpsc::channel();
        let cancel = Arc::new(AtomicBool::new(false));
        let states = self.states.clone();
        let stats = self.stats.clone();
        let c2 = cancel.clone();
        let body = body.to_string();
        // one relay thread per routed request; if the spawn fails the
        // dropped tx makes the source answer 502
        let _ = std::thread::Builder::new()
            .name("tapout-proxy".into())
            .spawn(move || proxy_request(&states, &order, &body, &tx, &c2, &stats));
        GenerateStart::Source(Box::new(ChannelSource { rx, cancel, started: false, finished: false }))
    }
}

impl RouterGateway {
    fn fleet_health(&self) -> Json {
        let vs = views(&self.states);
        let alive = vs.iter().filter(|v| v.alive).count();
        let mut o = Json::obj();
        o.set("ok", alive > 0)
            .set("role", "router")
            .set("replicas", self.states.len())
            .set("alive", alive)
            .set("affinity", self.core.affinity)
            .set("page_size", self.core.page_size);
        let fleet: Vec<Json> = self
            .states
            .iter()
            .zip(&vs)
            .map(|(s, v)| {
                let mut r = Json::obj();
                r.set("addr", s.addr.as_str())
                    .set("alive", v.alive)
                    .set("draining", v.draining)
                    .set("queue_wait", v.queue_wait);
                r
            })
            .collect();
        o.set("fleet", fleet);
        o
    }

    fn fleet_metrics(&self) -> Json {
        let vs = views(&self.states);
        let mut completed = 0usize;
        let mut new_tokens = 0usize;
        let mut cache_hits = 0usize;
        let mut cache_lookups = 0usize;
        let mut shared_hits = 0usize;
        let mut page_lookups = 0usize;
        let grab = |j: &Json, k: &str| j.get(k).and_then(|x| x.as_usize()).unwrap_or(0);
        let replicas: Vec<Json> = self
            .states
            .iter()
            .zip(&vs)
            .map(|(s, v)| {
                let mut r = Json::obj();
                r.set("addr", s.addr.as_str())
                    .set("alive", v.alive)
                    .set("draining", v.draining)
                    .set("queue_wait", v.queue_wait);
                if let Some(m) = s.snapshot.lock().unwrap().clone() {
                    completed += grab(&m, "completed");
                    new_tokens += grab(&m, "new_tokens");
                    if let Some(c) = m.get("engine").and_then(|e| e.get("cache")) {
                        cache_hits += grab(c, "hits");
                        cache_lookups += grab(c, "lookups");
                    }
                    if let Some(p) = m.get("engine").and_then(|e| e.get("pages")) {
                        shared_hits += grab(p, "shared_hits");
                        page_lookups += grab(p, "lookups");
                    }
                    r.set("metrics", m);
                }
                r
            })
            .collect();
        let rate = |h: usize, l: usize| if l == 0 { 0.0 } else { h as f64 / l as f64 };
        let mut cache = Json::obj();
        cache
            .set("hits", cache_hits)
            .set("lookups", cache_lookups)
            .set("hit_rate", rate(cache_hits, cache_lookups));
        let mut pages = Json::obj();
        pages
            .set("shared_hits", shared_hits)
            .set("lookups", page_lookups)
            .set("shared_hit_rate", rate(shared_hits, page_lookups));
        let mut fleet = Json::obj();
        fleet.set("completed", completed).set("new_tokens", new_tokens);
        fleet.set("cache", cache).set("pages", pages);
        let mut o = Json::obj();
        o.set("role", "router")
            .set("router", self.stats.to_json())
            .set("io", self.io.to_json())
            .set("fleet", fleet)
            .set("replicas", replicas);
        o
    }

    fn set_drain(&self, body: &str, on: bool) -> (u16, String) {
        let j = Json::parse(body).unwrap_or(Json::Null);
        let idx = j.get("replica").and_then(|sel| {
            sel.as_usize().or_else(|| {
                sel.as_str().and_then(|a| self.states.iter().position(|st| st.addr == a))
            })
        });
        let Some(i) = idx.filter(|&i| i < self.states.len()) else {
            return (400, http::err_body("missing or unknown replica"));
        };
        self.states[i].draining.store(on, Ordering::SeqCst);
        let mut o = Json::obj();
        o.set("ok", true).set("replica", i).set("draining", on);
        (200, o.render())
    }
}

// ---------------------------------------------------------------------------
// data plane (per-request proxy)
// ---------------------------------------------------------------------------

/// Reply-channel view of a proxy thread, polled by the reactor.
struct ChannelSource {
    rx: Receiver<SourceEvent>,
    cancel: Arc<AtomicBool>,
    started: bool,
    finished: bool,
}

impl EventSource for ChannelSource {
    fn poll_event(&mut self) -> Option<SourceEvent> {
        if self.finished {
            return None;
        }
        match self.rx.try_recv() {
            Ok(ev) => {
                match &ev {
                    SourceEvent::StreamStart => self.started = true,
                    SourceEvent::Reply { .. } | SourceEvent::End => self.finished = true,
                    SourceEvent::Data(_) => {}
                }
                Some(ev)
            }
            Err(TryRecvError::Empty) => None,
            Err(TryRecvError::Disconnected) => {
                // relay thread died without a terminal event
                self.finished = true;
                if self.started {
                    Some(SourceEvent::End)
                } else {
                    Some(SourceEvent::Reply { code: 502, body: http::err_body("upstream replica failed") })
                }
            }
        }
    }

    fn cancel(&mut self) {
        // the proxy thread observes this within its read-timeout tick and
        // drops its upstream connection, which cancels the decode there
        self.cancel.store(true, Ordering::SeqCst);
    }
}

/// Re-dispatch budget for *undelivered* requests: a replica death before
/// the body fully flushes may be retried on at most this many further
/// replicas beyond the routing decision, bounding worst-case client
/// latency (and duplicate connection attempts) under a cascade of dead
/// replicas. Delivered work is never retried, whatever the budget.
const REDISPATCH_BUDGET: usize = 2;

/// Upstream body chunk size: bodies larger than one write stream out in
/// slices, so a replica death mid-body is observed *mid-body* — the
/// request stays provably undelivered (a partial `Content-Length` body
/// never reaches the replica's parser) and therefore retryable.
const BODY_CHUNK: usize = 8 * 1024;

/// Outcome of one upstream delivery attempt.
enum Delivery {
    /// connected and the full body flushed — never retried from here
    Sent(TcpStream),
    /// the replica died before the body completed; `wrote` body bytes
    /// had gone out (0 = the connection or header write already failed)
    Undelivered { wrote: usize },
}

fn proxy_request(
    states: &[ReplicaState],
    order: &[usize],
    body: &str,
    tx: &Sender<SourceEvent>,
    cancel: &AtomicBool,
    stats: &RouterStats,
) {
    for (attempt, &idx) in order.iter().take(1 + REDISPATCH_BUDGET).enumerate() {
        if cancel.load(Ordering::SeqCst) {
            return;
        }
        let st = &states[idx];
        if attempt > 0 {
            stats.failovers.fetch_add(1, Ordering::Relaxed);
        }
        match open_upstream(&st.addr, body) {
            // delivered: from here every failure is answered, never
            // retried (the decode may already be running on the replica)
            Delivery::Sent(conn) => {
                relay_upstream(conn, st, tx, cancel, stats);
                return;
            }
            // the request never reached this replica as a complete body:
            // mark it dead and re-dispatch to the next-best pick
            Delivery::Undelivered { wrote } => {
                if wrote > 0 {
                    stats.partial_redispatches.fetch_add(1, Ordering::Relaxed);
                }
                st.alive.store(false, Ordering::SeqCst);
            }
        }
    }
    let _ = tx.send(SourceEvent::Reply { code: 503, body: http::err_body("no healthy replica") });
}

/// Connect and deliver the generate request, streaming the body in
/// [`BODY_CHUNK`] slices. [`Delivery::Undelivered`] means the replica
/// never saw a complete request (safe to retry elsewhere); once the last
/// body byte is handed to the socket the attempt counts as delivered —
/// `TcpStream::flush` is a no-op, so there is no later failure point
/// that could leave delivery ambiguous.
fn open_upstream(addr: &str, body: &str) -> Delivery {
    let fresh = Delivery::Undelivered { wrote: 0 };
    let Ok(sa) = addr.parse::<std::net::SocketAddr>() else {
        return fresh;
    };
    let Ok(mut s) = TcpStream::connect_timeout(&sa, Duration::from_millis(500)) else {
        return fresh;
    };
    let _ = s.set_nodelay(true);
    let head = format!(
        "POST /generate HTTP/1.1\r\nHost: {addr}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    if s.write_all(head.as_bytes()).is_err() {
        return fresh;
    }
    let bytes = body.as_bytes();
    let mut wrote = 0usize;
    while wrote < bytes.len() {
        let end = (wrote + BODY_CHUNK).min(bytes.len());
        if s.write_all(&bytes[wrote..end]).is_err() {
            return Delivery::Undelivered { wrote };
        }
        wrote = end;
    }
    let _ = s.flush();
    Delivery::Sent(s)
}

/// Relay one upstream response into the reply channel: plain replies
/// pass through (status + body), SSE streams are de-chunked and
/// re-emitted event by event. An upstream death mid-way is answered with
/// 502 (no response yet) or a synthesized terminal `failed` event
/// (stream already started), and the replica is marked dead for the
/// prober to re-admit.
fn relay_upstream(
    mut s: TcpStream,
    st: &ReplicaState,
    tx: &Sender<SourceEvent>,
    cancel: &AtomicBool,
    stats: &RouterStats,
) {
    let _ = s.set_read_timeout(Some(Duration::from_millis(100)));
    let died = |stats: &RouterStats| {
        st.alive.store(false, Ordering::SeqCst);
        stats.upstream_errors.fetch_add(1, Ordering::Relaxed);
    };
    let mut raw: Vec<u8> = Vec::new();
    let mut tmp = [0u8; 16 * 1024];
    // response head
    let head_end = loop {
        if let Some(p) = raw.windows(4).position(|w| w == b"\r\n\r\n") {
            break p;
        }
        if cancel.load(Ordering::SeqCst) {
            return; // dropping s disconnects the replica → its cancel path
        }
        match s.read(&mut tmp) {
            Ok(0) => {
                died(stats);
                let _ = tx.send(SourceEvent::Reply {
                    code: 502,
                    body: http::err_body("upstream replica failed"),
                });
                return;
            }
            Ok(n) => raw.extend_from_slice(&tmp[..n]),
            Err(e) if http::is_timeout(&e) => continue,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(_) => {
                died(stats);
                let _ = tx.send(SourceEvent::Reply {
                    code: 502,
                    body: http::err_body("upstream replica failed"),
                });
                return;
            }
        }
    };
    let head = String::from_utf8_lossy(&raw[..head_end]).to_string();
    let rest: Vec<u8> = raw.split_off(head_end + 4);
    let code: u16 =
        head.split_whitespace().nth(1).and_then(|c| c.parse().ok()).unwrap_or(502);
    let mut content_length = 0usize;
    let mut sse = false;
    for line in head.lines().skip(1) {
        if let Some((name, value)) = line.split_once(':') {
            let (name, value) = (name.trim(), value.trim());
            if name.eq_ignore_ascii_case("content-length") {
                content_length = value.parse().unwrap_or(0);
            } else if name.eq_ignore_ascii_case("content-type") {
                sse = value.eq_ignore_ascii_case("text/event-stream");
            }
        }
    }

    if !sse {
        // plain reply (unary result, pre-stream error, framing error):
        // pass it through verbatim
        let mut body = rest;
        while body.len() < content_length {
            if cancel.load(Ordering::SeqCst) {
                return;
            }
            match s.read(&mut tmp) {
                Ok(0) => {
                    died(stats);
                    let _ = tx.send(SourceEvent::Reply {
                        code: 502,
                        body: http::err_body("upstream replica failed"),
                    });
                    return;
                }
                Ok(n) => body.extend_from_slice(&tmp[..n]),
                Err(e) if http::is_timeout(&e) => continue,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(_) => {
                    died(stats);
                    let _ = tx.send(SourceEvent::Reply {
                        code: 502,
                        body: http::err_body("upstream replica failed"),
                    });
                    return;
                }
            }
        }
        body.truncate(content_length);
        let _ = tx.send(SourceEvent::Reply {
            code,
            body: String::from_utf8_lossy(&body).to_string(),
        });
        return;
    }

    // SSE stream: de-chunk, split events, re-emit
    if tx.send(SourceEvent::StreamStart).is_err() {
        return; // client gone; dropping s cancels the upstream decode
    }
    let mut dec = ChunkDecoder::default();
    let mut saw_done = false;
    if dec.feed(&rest).is_err() {
        stream_died(st, tx, stats, saw_done);
        return;
    }
    loop {
        for payload in dec.events() {
            saw_done |= Json::parse(&payload)
                .ok()
                .and_then(|j| j.get("done").and_then(|d| d.as_bool()))
                .unwrap_or(false);
            if tx.send(SourceEvent::Data(payload)).is_err() {
                return;
            }
        }
        if dec.terminal {
            let _ = tx.send(SourceEvent::End);
            return;
        }
        if cancel.load(Ordering::SeqCst) {
            return;
        }
        match s.read(&mut tmp) {
            Ok(0) => {
                stream_died(st, tx, stats, saw_done);
                return;
            }
            Ok(n) => {
                if dec.feed(&tmp[..n]).is_err() {
                    stream_died(st, tx, stats, saw_done);
                    return;
                }
            }
            Err(e) if http::is_timeout(&e) => continue,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(_) => {
                stream_died(st, tx, stats, saw_done);
                return;
            }
        }
    }
}

/// Upstream connection died mid-stream. If its terminal event already
/// went out, just end the chunking cleanly; otherwise synthesize the
/// honest terminal: `{"done": true, "status": "failed", ...}` so the
/// client sees a correct terminal status instead of a silent hangup.
fn stream_died(st: &ReplicaState, tx: &Sender<SourceEvent>, stats: &RouterStats, saw_done: bool) {
    st.alive.store(false, Ordering::SeqCst);
    stats.upstream_errors.fetch_add(1, Ordering::Relaxed);
    if !saw_done {
        let mut o = Json::obj();
        o.set("done", true)
            .set("id", 0usize)
            .set("status", FinishStatus::Failed.label())
            .set("error", "upstream replica failed mid-stream");
        let _ = tx.send(SourceEvent::Data(o.render()));
    }
    let _ = tx.send(SourceEvent::End);
}

/// Incremental HTTP-chunk decoder + SSE event splitter for the relay
/// path: wire bytes in, complete `data:` payloads out. Upstream SSE
/// comments (keep-alive pings) are dropped — the router's own front end
/// keeps the client connection warm.
#[derive(Default)]
struct ChunkDecoder {
    buf: Vec<u8>,
    data: String,
    terminal: bool,
}

impl ChunkDecoder {
    fn feed(&mut self, bytes: &[u8]) -> Result<(), ()> {
        self.buf.extend_from_slice(bytes);
        loop {
            if self.terminal {
                return Ok(());
            }
            let Some(pos) = self.buf.windows(2).position(|w| w == b"\r\n") else {
                return Ok(());
            };
            let size_str = std::str::from_utf8(&self.buf[..pos]).map_err(|_| ())?;
            let size = usize::from_str_radix(size_str.trim(), 16).map_err(|_| ())?;
            let need = pos + 2 + size + 2;
            if size == 0 {
                self.terminal = true;
                self.buf.clear();
                return Ok(());
            }
            if self.buf.len() < need {
                return Ok(());
            }
            self.data.push_str(&String::from_utf8_lossy(&self.buf[pos + 2..pos + 2 + size]));
            self.buf.drain(..need);
        }
    }

    fn events(&mut self) -> Vec<String> {
        let mut out = Vec::new();
        while let Some(p) = self.data.find("\n\n") {
            let ev: String = self.data.drain(..p + 2).collect();
            if let Some(payload) = ev.trim_end().strip_prefix("data: ") {
                out.push(payload.to_string());
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn live(n: usize) -> Vec<ReplicaView> {
        vec![ReplicaView { alive: true, draining: false, queue_wait: 0.0 }; n]
    }

    #[test]
    fn ring_lookup_is_deterministic_and_prefers_the_owner() {
        let ring = HashRing::new(3);
        let a = ring.lookup(42, |_| true).unwrap();
        let b = ring.lookup(42, |_| true).unwrap();
        assert_eq!(a, b);
        // with the owner dead, the key moves to a live successor
        let c = ring.lookup(42, |r| r != a).unwrap();
        assert_ne!(c, a);
        assert!(ring.lookup(42, |_| false).is_none());
    }

    #[test]
    fn same_prefix_page_routes_to_one_replica() {
        let core = RouterCore::new(3, 16, true);
        let head = "shared prefix head with plenty of tokens to fill one whole page of context";
        let views = live(3);
        let d1 = core.route(&format!("{head} tail one"), &views).unwrap();
        let d2 = core.route(&format!("{head} tail two"), &views).unwrap();
        assert_eq!(d1.replica, d2.replica);
        assert!(d1.affinity && d2.affinity);
        assert!(!d1.shed);
        // the prefix key really is page-granular
        assert_eq!(prefix_key(&format!("{head} tail one"), 16), prefix_key(&format!("{head} tail two"), 16));
    }

    #[test]
    fn round_robin_cycles_when_affinity_is_off() {
        let core = RouterCore::new(2, 16, false);
        let views = live(2);
        let picks: Vec<usize> =
            (0..4).map(|_| core.route("same prompt", &views).unwrap().replica).collect();
        assert_eq!(picks, vec![0, 1, 0, 1]);
        assert!(!core.route("same prompt", &views).unwrap().affinity);
    }

    #[test]
    fn shed_rule_overflows_a_hot_affinity_target() {
        let core = RouterCore::new(2, 16, true);
        let prompt = "a prompt whose page hashes somewhere fixed";
        let owner = core.route(prompt, &live(2)).unwrap().replica;
        let mut views = live(2);
        views[owner].queue_wait = 100_000.0;
        let d = core.route(prompt, &views).unwrap();
        assert_ne!(d.replica, owner);
        assert!(d.shed);
        assert!(!d.affinity);
        // below the slack threshold affinity wins even when non-zero
        views[owner].queue_wait = SHED_SLACK / 2.0;
        assert_eq!(core.route(prompt, &views).unwrap().replica, owner);
    }

    #[test]
    fn dead_and_draining_replicas_never_receive_work() {
        let core = RouterCore::new(3, 16, true);
        let mut views = live(3);
        views[0].alive = false;
        views[1].draining = true;
        for i in 0..10 {
            let d = core.route(&format!("prompt {i}"), &views).unwrap();
            assert_eq!(d.replica, 2);
        }
        views[2].alive = false;
        assert!(core.route("anything", &views).is_none());
    }

    #[test]
    fn chunk_decoder_reassembles_sse_events() {
        let mut dec = ChunkDecoder::default();
        let ev1 = "data: {\"ids\":[1,2]}\n\n";
        let frame1 = format!("{:X}\r\n{}\r\n", ev1.len(), ev1);
        // split the wire bytes at an awkward boundary
        let (a, b) = frame1.as_bytes().split_at(7);
        dec.feed(a).unwrap();
        assert!(dec.events().is_empty());
        dec.feed(b).unwrap();
        assert_eq!(dec.events(), vec!["{\"ids\":[1,2]}".to_string()]);
        // keep-alive comments are swallowed, terminal chunk is flagged
        let ping = ": ping\n\n";
        dec.feed(format!("{:X}\r\n{}\r\n", ping.len(), ping).as_bytes()).unwrap();
        assert!(dec.events().is_empty());
        dec.feed(b"0\r\n\r\n").unwrap();
        assert!(dec.terminal);
    }
}
