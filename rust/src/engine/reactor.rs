//! Nonblocking readiness-loop front end (docs/ARCHITECTURE.md §15).
//!
//! The blocking server (http.rs) pins one OS thread per connection — a
//! thread per idle SSE stream. This module multiplexes every connection
//! over a fixed pool of I/O threads instead: each thread runs a
//! [`sys::Poller`] (epoll on Linux via hand-declared FFI — the sealed
//! build image has no mio/tokio; elsewhere a portable `WouldBlock`-polling
//! fallback) and drives per-connection state machines:
//!
//! ```text
//! Read ──parse──▶ Generating ──Reply/End──▶ Closing ──flush──▶ closed
//!   │ header/body deadline → 408              │
//!   └── framing error → Closing               └ write error / read-0
//!                                               → EventSource::cancel
//! ```
//!
//! * **Read** accumulates the request until the headers + declared body
//!   are complete, enforcing the slow-loris bound: a client that trickles
//!   bytes past `header_timeout` gets a 408 and the connection back.
//! * **Generating** polls a [`EventSource`] (a non-blocking view of the
//!   engine's reply channel) every tick, queues rendered bytes on the
//!   connection's outbound buffer, and flushes on writability. Client
//!   disconnect (read-0 / EPOLLHUP) and write failure both map to
//!   [`EventSource::cancel`] — the engine sees the same `CancelFlag` the
//!   blocking path would have flipped. Streams silent for
//!   `sse_keepalive` get an SSE comment (`: ping`) so intermediaries
//!   don't reap the connection.
//! * **Closing** drains the outbound buffer, then shuts the socket down.
//!
//! What gets served is behind the [`Gateway`] trait, so the engine front
//! end (http.rs) and the multi-replica router (router.rs) share one
//! event loop. Responses are rendered by the same helpers as the
//! blocking path, byte for byte.

use std::collections::VecDeque;
use std::io::{ErrorKind, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use super::http::{self, MAX_BODY_BYTES};
use super::metrics::IoStats;

/// Largest header section accepted before the connection is refused
/// (the blocking path reads lines unbounded; the reactor must cap its
/// accumulation buffer).
pub const MAX_HEADER_BYTES: usize = 64 * 1024;

/// Outbound-buffer high-water mark: above this many queued bytes the
/// source is not polled (backpressure on a slow client) until the
/// socket drains.
const HIGH_WATER: usize = 256 * 1024;

/// Max source events rendered per connection per tick (fairness bound).
const EVENTS_PER_TICK: usize = 64;

const TOKEN_LISTENER: u64 = 0;
const TOKEN_WAKER: u64 = 1;
const TOKEN_CONN_BASE: u64 = 2;

/// One event from an [`EventSource`] — the non-blocking reply stream a
/// connection in the `Generating` phase consumes.
pub enum SourceEvent {
    /// A complete plain (non-SSE) HTTP reply: status code + JSON body.
    Reply {
        /// HTTP status code
        code: u16,
        /// rendered JSON body
        body: String,
    },
    /// Begin the SSE response (status line + chunked headers).
    StreamStart,
    /// One SSE `data:` payload (rendered JSON, unframed).
    Data(String),
    /// Terminal chunk: end the SSE stream and close.
    End,
}

/// A non-blocking reply source for one in-flight request. `poll_event`
/// must never block: `None` means "nothing yet, poll again next tick".
/// After `Reply` or `End` the reactor stops polling.
pub trait EventSource: Send {
    /// Next event, if one is ready.
    fn poll_event(&mut self) -> Option<SourceEvent>;
    /// The client is gone (disconnect or write failure): release the
    /// decode promptly (flip the request's `CancelFlag` or equivalent).
    fn cancel(&mut self);
}

/// What `Gateway::generate` produced for a parsed request.
pub enum GenerateStart {
    /// Reply immediately (parse error, admission error, …).
    Immediate {
        /// HTTP status code
        code: u16,
        /// rendered JSON body
        body: String,
    },
    /// A live request: poll this source until `Reply` or `End`.
    Source(Box<dyn EventSource>),
}

/// The application behind the reactor: routes plain requests and starts
/// generate requests. Implemented by the engine front end (http.rs) and
/// the multi-replica router (router.rs). Handlers run on I/O threads and
/// must not block.
pub trait Gateway: Send + Sync {
    /// Handle a non-generate request; returns (status, rendered body).
    fn route(&self, method: &str, path: &str, body: &str) -> (u16, String);
    /// Start a generate request from its raw body. `tenant` is the
    /// `X-Tapout-Tenant` request header when present — a `"tenant"`
    /// field inside the body wins over it (docs/OPERATIONS.md).
    fn generate(&self, body: &str, tenant: Option<&str>) -> GenerateStart;
    /// Does this (method, path) take the generate path (and its
    /// body-framing contract: 501/400/411 before the body arrives)?
    fn is_generate(&self, method: &str, path: &str) -> bool {
        method == "POST" && path == "/generate"
    }
}

/// Reactor tuning knobs (`HttpConfig` maps onto these).
#[derive(Clone, Debug)]
pub struct ReactorConfig {
    /// I/O threads in the pool (≥ 1); connection count is unbounded by it
    pub io_threads: usize,
    /// slow-loris bound: total time allowed to deliver headers + body
    pub header_timeout: Duration,
    /// SSE comment (`: ping`) interval on silent streams
    pub sse_keepalive: Duration,
}

impl Default for ReactorConfig {
    fn default() -> ReactorConfig {
        ReactorConfig {
            io_threads: 4,
            header_timeout: Duration::from_millis(10_000),
            sse_keepalive: Duration::from_millis(15_000),
        }
    }
}

/// The running event loop: a bound listener plus `io_threads` poller
/// threads. Dropping (or [`Reactor::stop`]) closes every connection and
/// joins the pool.
pub struct Reactor {
    /// bound address, e.g. `127.0.0.1:8077`
    pub addr: String,
    stop: Arc<AtomicBool>,
    threads: Vec<std::thread::JoinHandle<()>>,
    wakers: Vec<waker::WakerTx>,
}

impl Reactor {
    /// Bind `port` (0 picks a free port) and serve `gateway` from a pool
    /// of `cfg.io_threads` poller threads. `stats` receives the
    /// connection/timeout/keepalive gauges (surfaced in `/metrics`).
    pub fn start(
        gateway: Arc<dyn Gateway>,
        port: u16,
        cfg: ReactorConfig,
        stats: Arc<IoStats>,
    ) -> Result<Reactor> {
        let listener = TcpListener::bind(("127.0.0.1", port))?;
        listener.set_nonblocking(true)?;
        let addr = listener.local_addr()?.to_string();
        let n = cfg.io_threads.max(1);
        let stop = Arc::new(AtomicBool::new(false));

        let mut inboxes = Vec::with_capacity(n);
        let mut rx_side = Vec::with_capacity(n);
        let mut wakers = Vec::with_capacity(n);
        for _ in 0..n {
            let (tx, rx) = waker::pair()?;
            inboxes.push(Arc::new(Mutex::new(Vec::<TcpStream>::new())));
            wakers.push(tx);
            rx_side.push(rx);
        }
        // the accept thread (index 0) dispatches round-robin to every
        // thread's inbox, its own included
        let injectors: Vec<Injector> = inboxes
            .iter()
            .zip(wakers.iter())
            .map(|(inbox, w)| {
                Ok(Injector { inbox: inbox.clone(), waker: w.try_clone()? })
            })
            .collect::<std::io::Result<_>>()?;

        let mut threads = Vec::with_capacity(n);
        let mut listener = Some(listener);
        for (t, rx) in rx_side.into_iter().enumerate() {
            let gw = gateway.clone();
            let c = cfg.clone();
            let st = stats.clone();
            let sp = stop.clone();
            let inbox = inboxes[t].clone();
            let l = listener.take();
            let peers = if t == 0 { injectors.clone() } else { Vec::new() };
            threads.push(
                std::thread::Builder::new()
                    .name(format!("tapout-io-{t}"))
                    .spawn(move || io_loop(gw, c, st, sp, l, inbox, rx, peers))?,
            );
        }
        Ok(Reactor { addr, stop, threads, wakers })
    }

    /// Stop the loop: close the listener and every connection, then join
    /// the I/O threads. Idempotent.
    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        for w in &self.wakers {
            w.wake();
        }
        for h in self.threads.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Reactor {
    fn drop(&mut self) {
        self.stop();
    }
}

#[derive(Clone)]
struct Injector {
    inbox: Arc<Mutex<Vec<TcpStream>>>,
    waker: waker::WakerTx,
}

enum Phase {
    Read { deadline: Instant },
    Generating { source: Box<dyn EventSource>, sse: bool, last_event: Instant },
    Closing,
}

struct Conn {
    stream: TcpStream,
    buf: Vec<u8>,
    out: VecDeque<u8>,
    phase: Phase,
    wants_out: bool,
}

impl Conn {
    fn new(stream: TcpStream, deadline: Instant) -> Conn {
        Conn {
            stream,
            buf: Vec::new(),
            out: VecDeque::new(),
            phase: Phase::Read { deadline },
            wants_out: false,
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn io_loop(
    gateway: Arc<dyn Gateway>,
    cfg: ReactorConfig,
    stats: Arc<IoStats>,
    stop: Arc<AtomicBool>,
    listener: Option<TcpListener>,
    inbox: Arc<Mutex<Vec<TcpStream>>>,
    waker_rx: waker::WakerRx,
    peers: Vec<Injector>,
) {
    let Ok(mut poller) = sys::Poller::new() else { return };
    if let Some(l) = &listener {
        let _ = poller.add(listener_fd(l), TOKEN_LISTENER, false);
    }
    let _ = poller.add(waker_rx.fd(), TOKEN_WAKER, false);
    let mut conns: Vec<Option<Conn>> = Vec::new();
    let mut free: Vec<usize> = Vec::new();
    let mut rr = 0usize;
    let mut events: Vec<u64> = Vec::new();
    loop {
        if stop.load(Ordering::Relaxed) {
            // dropping the listener and the conns closes every socket
            return;
        }
        poller.wait(tick_timeout(&conns), &mut events);
        waker_rx.drain();

        // accept burst (thread 0 only): hand new connections round-robin
        // to the pool; the waker write cuts the target thread's sleep
        if let Some(l) = &listener {
            loop {
                match l.accept() {
                    Ok((s, _)) => {
                        let _ = s.set_nonblocking(true);
                        stats.accepted.fetch_add(1, Ordering::Relaxed);
                        let target = &peers[rr % peers.len()];
                        rr += 1;
                        target.inbox.lock().unwrap().push(s);
                        target.waker.wake();
                    }
                    Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                    Err(_) => break,
                }
            }
        }

        // adopt connections handed to this thread
        for s in std::mem::take(&mut *inbox.lock().unwrap()) {
            let idx = free.pop().unwrap_or_else(|| {
                conns.push(None);
                conns.len() - 1
            });
            let token = TOKEN_CONN_BASE + idx as u64;
            if poller.add(fd_of(&s), token, false).is_err() {
                free.push(idx);
                continue;
            }
            stats.conn_opened();
            conns[idx] = Some(Conn::new(s, Instant::now() + cfg.header_timeout));
        }

        // pump every connection: readiness events only cut the sleep
        // short — handlers use nonblocking I/O and tolerate WouldBlock,
        // so a uniform pump is correct on both poller backends
        for idx in 0..conns.len() {
            let Some(conn) = conns[idx].as_mut() else { continue };
            let keep = pump(conn, gateway.as_ref(), &cfg, &stats);
            if !keep {
                poller.del(fd_of(&conn.stream));
                stats.conn_closed();
                conns[idx] = None;
                free.push(idx);
                continue;
            }
            let want = !conn.out.is_empty();
            if want != conn.wants_out {
                let token = TOKEN_CONN_BASE + idx as u64;
                poller.modify(fd_of(&conn.stream), token, want);
                conn.wants_out = want;
            }
        }
    }
}

/// Poll timeout in ms: tight while any stream is generating (its events
/// arrive over an mpsc channel the poller cannot watch), relaxed while
/// connections are only reading (socket readiness wakes us), long idle.
fn tick_timeout(conns: &[Option<Conn>]) -> i32 {
    let mut any = false;
    for c in conns.iter().flatten() {
        match c.phase {
            Phase::Generating { .. } => return 2,
            _ => any = true,
        }
    }
    if any {
        25
    } else {
        200
    }
}

/// Advance one connection's state machine. Returns false when the
/// connection is finished (or dead) and must be dropped.
fn pump(conn: &mut Conn, gw: &dyn Gateway, cfg: &ReactorConfig, stats: &IoStats) -> bool {
    let now = Instant::now();
    let mut next_phase: Option<Phase> = None;
    match &mut conn.phase {
        Phase::Read { deadline } => {
            let mut eof = false;
            let mut tmp = [0u8; 16 * 1024];
            loop {
                match conn.stream.read(&mut tmp) {
                    Ok(0) => {
                        eof = true;
                        break;
                    }
                    Ok(n) => {
                        conn.buf.extend_from_slice(&tmp[..n]);
                        if conn.buf.len() > MAX_BODY_BYTES + MAX_HEADER_BYTES {
                            enqueue_plain(
                                &mut conn.out,
                                400,
                                &http::err_body("request exceeds the accepted size"),
                            );
                            next_phase = Some(Phase::Closing);
                            break;
                        }
                    }
                    Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                    Err(_) => return false,
                }
            }
            if next_phase.is_none() {
                match try_parse(&conn.buf, gw) {
                    ParseStep::Respond { code, body } => {
                        enqueue_plain(&mut conn.out, code, &body);
                        next_phase = Some(Phase::Closing);
                    }
                    ParseStep::Ready { method, path, body, tenant } => {
                        stats.requests.fetch_add(1, Ordering::Relaxed);
                        conn.buf.clear();
                        if gw.is_generate(&method, &path) {
                            match gw.generate(&body, tenant.as_deref()) {
                                GenerateStart::Immediate { code, body } => {
                                    enqueue_plain(&mut conn.out, code, &body);
                                    next_phase = Some(Phase::Closing);
                                }
                                GenerateStart::Source(source) => {
                                    next_phase = Some(Phase::Generating {
                                        source,
                                        sse: false,
                                        last_event: now,
                                    });
                                }
                            }
                        } else {
                            let (code, body) = gw.route(&method, &path, &body);
                            enqueue_plain(&mut conn.out, code, &body);
                            next_phase = Some(Phase::Closing);
                        }
                    }
                    ParseStep::Incomplete => {
                        if eof {
                            if conn.buf.is_empty() {
                                return false; // probe connection; nothing to answer
                            }
                            enqueue_plain(
                                &mut conn.out,
                                400,
                                &http::err_body("connection closed before the request completed"),
                            );
                            next_phase = Some(Phase::Closing);
                        } else if now >= *deadline {
                            // slow loris: the client had header_timeout to
                            // deliver the request; free the connection
                            stats.read_timeouts.fetch_add(1, Ordering::Relaxed);
                            enqueue_plain(
                                &mut conn.out,
                                408,
                                &http::err_body("request read timed out"),
                            );
                            next_phase = Some(Phase::Closing);
                        }
                    }
                }
            }
        }
        Phase::Generating { source, sse, last_event } => {
            // disconnect probe: a generating client sends nothing more,
            // so read-0 (or a hard error) means it hung up — cancel the
            // decode instead of streaming into the void
            let mut tmp = [0u8; 1024];
            loop {
                match conn.stream.read(&mut tmp) {
                    Ok(0) => {
                        source.cancel();
                        stats.disconnects.fetch_add(1, Ordering::Relaxed);
                        return false;
                    }
                    Ok(_) => continue, // pipelined bytes: ignored (Connection: close)
                    Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                    Err(_) => {
                        source.cancel();
                        stats.disconnects.fetch_add(1, Ordering::Relaxed);
                        return false;
                    }
                }
            }
            // render pending events while the outbound buffer has room
            // (backpressure: a slow client pauses the poll, not a thread)
            if conn.out.len() < HIGH_WATER {
                for _ in 0..EVENTS_PER_TICK {
                    match source.poll_event() {
                        None => break,
                        Some(SourceEvent::Reply { code, body }) => {
                            enqueue_plain(&mut conn.out, code, &body);
                            next_phase = Some(Phase::Closing);
                            break;
                        }
                        Some(SourceEvent::StreamStart) => {
                            conn.out.extend(http::SSE_HEADERS.bytes());
                            *sse = true;
                            *last_event = now;
                        }
                        Some(SourceEvent::Data(payload)) => {
                            conn.out.extend(http::sse_frame(&payload).into_bytes());
                            *last_event = now;
                        }
                        Some(SourceEvent::End) => {
                            conn.out.extend(b"0\r\n\r\n");
                            next_phase = Some(Phase::Closing);
                            break;
                        }
                    }
                }
            }
            if next_phase.is_none()
                && *sse
                && now.duration_since(*last_event) >= cfg.sse_keepalive
            {
                // SSE comment chunk: ignored by clients, resets idle
                // timers in intermediaries
                conn.out.extend(http::sse_comment_frame("ping").into_bytes());
                *last_event = now;
                stats.keepalives.fetch_add(1, Ordering::Relaxed);
            }
        }
        Phase::Closing => {}
    }
    if let Some(p) = next_phase {
        conn.phase = p;
    }

    // flush the outbound buffer until the socket pushes back
    while !conn.out.is_empty() {
        let (head, _) = conn.out.as_slices();
        match conn.stream.write(head) {
            Ok(0) => return flush_failed(conn, stats),
            Ok(n) => {
                conn.out.drain(..n);
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(_) => return flush_failed(conn, stats),
        }
    }
    if matches!(conn.phase, Phase::Closing) && conn.out.is_empty() {
        let _ = conn.stream.shutdown(std::net::Shutdown::Both);
        return false;
    }
    true
}

/// A write failed mid-response: if a decode is attached, cancel it
/// promptly so the engine frees the slot. Always drops the connection.
fn flush_failed(conn: &mut Conn, stats: &IoStats) -> bool {
    if let Phase::Generating { source, .. } = &mut conn.phase {
        source.cancel();
        stats.write_cancels.fetch_add(1, Ordering::Relaxed);
    }
    false
}

fn enqueue_plain(out: &mut VecDeque<u8>, code: u16, body: &str) {
    out.extend(http::plain_response(code, body).into_bytes());
}

enum ParseStep {
    Incomplete,
    Ready { method: String, path: String, body: String, tenant: Option<String> },
    Respond { code: u16, body: String },
}

/// Find the end of the header section: offset of the terminator and the
/// body start. Accepts `\r\n\r\n` and bare `\n\n` (the blocking path's
/// `read_line` + `trim` accepts both).
fn find_header_end(buf: &[u8]) -> Option<(usize, usize)> {
    for i in 0..buf.len() {
        if buf[i..].starts_with(b"\r\n\r\n") {
            return Some((i, i + 4));
        }
        if buf[i..].starts_with(b"\n\n") {
            return Some((i, i + 2));
        }
    }
    None
}

/// Incremental request parse over the accumulation buffer, mirroring the
/// blocking path's framing contract exactly (same checks, same order,
/// same error bodies): chunked generate → 501, unparseable
/// content-length → 400, generate without content-length → 411,
/// over-size body → 413.
fn try_parse(buf: &[u8], gw: &dyn Gateway) -> ParseStep {
    let Some((head_end, body_start)) = find_header_end(buf) else {
        if buf.len() > MAX_HEADER_BYTES {
            return ParseStep::Respond { code: 400, body: http::err_body("headers too large") };
        }
        return ParseStep::Incomplete;
    };
    let head = String::from_utf8_lossy(&buf[..head_end]).to_string();
    let mut lines = head.split('\n').map(|l| l.trim_end_matches('\r'));
    let mut first = lines.next().unwrap_or("").split_whitespace();
    let method = first.next().unwrap_or("").to_string();
    let path = first.next().unwrap_or("/").to_string();
    let mut content_length: Option<usize> = None;
    let mut bad_length: Option<String> = None;
    let mut chunked = false;
    let mut tenant: Option<String> = None;
    for h in lines {
        let h = h.trim();
        if let Some((name, value)) = h.split_once(':') {
            let (name, value) = (name.trim(), value.trim());
            if name.eq_ignore_ascii_case("content-length") {
                match value.parse() {
                    Ok(n) => content_length = Some(n),
                    Err(_) => bad_length = Some(value.to_string()),
                }
            } else if name.eq_ignore_ascii_case("transfer-encoding") {
                chunked = value.to_ascii_lowercase().contains("chunked");
            } else if name.eq_ignore_ascii_case("x-tapout-tenant") {
                tenant = Some(value.to_string());
            }
        }
    }
    if gw.is_generate(&method, &path) {
        if chunked {
            let (code, body) = http::framing_chunked();
            return ParseStep::Respond { code, body };
        }
        if let Some(bad) = bad_length {
            let (code, body) = http::framing_bad_length(&bad);
            return ParseStep::Respond { code, body };
        }
        if content_length.is_none() {
            let (code, body) = http::framing_length_required();
            return ParseStep::Respond { code, body };
        }
    }
    let len = content_length.unwrap_or(0);
    if len > MAX_BODY_BYTES {
        let (code, body) = http::framing_too_large(len);
        return ParseStep::Respond { code, body };
    }
    if buf.len() < body_start + len {
        return ParseStep::Incomplete;
    }
    let body = String::from_utf8_lossy(&buf[body_start..body_start + len]).to_string();
    ParseStep::Ready { method, path, body, tenant }
}

#[cfg(unix)]
fn fd_of(stream: &TcpStream) -> i64 {
    use std::os::unix::io::AsRawFd;
    stream.as_raw_fd() as i64
}
#[cfg(not(unix))]
fn fd_of(_stream: &TcpStream) -> i64 {
    -1
}

#[cfg(unix)]
fn listener_fd(l: &TcpListener) -> i64 {
    use std::os::unix::io::AsRawFd;
    l.as_raw_fd() as i64
}
#[cfg(not(unix))]
fn listener_fd(_l: &TcpListener) -> i64 {
    -1
}

/// Cross-thread wakeup: a nonblocking socketpair whose read end sits in
/// the poller. Writing one byte cuts the target thread's sleep short
/// (new connection handed over, or stop requested). On non-unix targets
/// the fallback poller's bounded sleep makes the waker unnecessary.
#[cfg(unix)]
mod waker {
    use std::io::{Read, Write};
    use std::os::unix::net::UnixStream;

    pub struct WakerTx(UnixStream);
    pub struct WakerRx(UnixStream);

    pub fn pair() -> std::io::Result<(WakerTx, WakerRx)> {
        let (a, b) = UnixStream::pair()?;
        a.set_nonblocking(true)?;
        b.set_nonblocking(true)?;
        Ok((WakerTx(a), WakerRx(b)))
    }

    impl WakerTx {
        pub fn wake(&self) {
            // a full pipe already means a wakeup is pending
            let _ = (&self.0).write(&[1u8]);
        }
        pub fn try_clone(&self) -> std::io::Result<WakerTx> {
            Ok(WakerTx(self.0.try_clone()?))
        }
    }

    impl WakerRx {
        pub fn drain(&self) {
            let mut buf = [0u8; 64];
            loop {
                match (&self.0).read(&mut buf) {
                    Ok(0) | Err(_) => break,
                    Ok(_) => continue,
                }
            }
        }
        pub fn fd(&self) -> i64 {
            use std::os::unix::io::AsRawFd;
            self.0.as_raw_fd() as i64
        }
    }
}

#[cfg(not(unix))]
mod waker {
    pub struct WakerTx;
    pub struct WakerRx;

    pub fn pair() -> std::io::Result<(WakerTx, WakerRx)> {
        Ok((WakerTx, WakerRx))
    }

    impl WakerTx {
        pub fn wake(&self) {}
        pub fn try_clone(&self) -> std::io::Result<WakerTx> {
            Ok(WakerTx)
        }
    }

    impl WakerRx {
        pub fn drain(&self) {}
        pub fn fd(&self) -> i64 {
            -1
        }
    }
}

/// Readiness poller. On Linux this is epoll over hand-declared FFI (std
/// already links libc, so the symbols resolve without any crate); the
/// `epoll_event` layout is packed on x86_64 per the kernel ABI.
/// Everywhere else a portable fallback sleeps a bounded tick and reports
/// every registered token ready — correct because every handler uses
/// nonblocking I/O and treats `WouldBlock` as "not actually ready".
#[cfg(target_os = "linux")]
mod sys {
    use std::io;

    #[cfg_attr(target_arch = "x86_64", repr(C, packed))]
    #[cfg_attr(not(target_arch = "x86_64"), repr(C))]
    #[derive(Clone, Copy)]
    struct EpollEvent {
        events: u32,
        data: u64,
    }

    extern "C" {
        fn epoll_create1(flags: i32) -> i32;
        fn epoll_ctl(epfd: i32, op: i32, fd: i32, event: *mut EpollEvent) -> i32;
        fn epoll_wait(epfd: i32, events: *mut EpollEvent, maxevents: i32, timeout: i32) -> i32;
        fn close(fd: i32) -> i32;
    }

    const EPOLL_CTL_ADD: i32 = 1;
    const EPOLL_CTL_DEL: i32 = 2;
    const EPOLL_CTL_MOD: i32 = 3;
    const EPOLLIN: u32 = 0x001;
    const EPOLLOUT: u32 = 0x004;
    const EPOLLRDHUP: u32 = 0x2000;
    const EPOLL_CLOEXEC: i32 = 0o2000000;

    pub struct Poller {
        ep: i32,
    }

    impl Poller {
        pub fn new() -> io::Result<Poller> {
            let ep = unsafe { epoll_create1(EPOLL_CLOEXEC) };
            if ep < 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(Poller { ep })
        }

        fn ctl(&mut self, op: i32, fd: i64, token: u64, writable: bool) {
            let mut ev = EpollEvent {
                events: EPOLLIN | EPOLLRDHUP | if writable { EPOLLOUT } else { 0 },
                data: token,
            };
            unsafe {
                epoll_ctl(self.ep, op, fd as i32, &mut ev);
            }
        }

        pub fn add(&mut self, fd: i64, token: u64, writable: bool) -> io::Result<()> {
            let mut ev = EpollEvent {
                events: EPOLLIN | EPOLLRDHUP | if writable { EPOLLOUT } else { 0 },
                data: token,
            };
            let r = unsafe { epoll_ctl(self.ep, EPOLL_CTL_ADD, fd as i32, &mut ev) };
            if r < 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(())
        }

        pub fn modify(&mut self, fd: i64, token: u64, writable: bool) {
            self.ctl(EPOLL_CTL_MOD, fd, token, writable);
        }

        pub fn del(&mut self, fd: i64) {
            self.ctl(EPOLL_CTL_DEL, fd, 0, false);
        }

        pub fn wait(&mut self, timeout_ms: i32, out: &mut Vec<u64>) {
            out.clear();
            let mut evs = [EpollEvent { events: 0, data: 0 }; 64];
            let n = unsafe { epoll_wait(self.ep, evs.as_mut_ptr(), evs.len() as i32, timeout_ms) };
            if n <= 0 {
                // n < 0: EINTR or a real failure — either way the caller's
                // uniform pump recovers next tick
                return;
            }
            for ev in evs.iter().take(n as usize) {
                out.push(ev.data);
            }
        }
    }

    impl Drop for Poller {
        fn drop(&mut self) {
            unsafe {
                close(self.ep);
            }
        }
    }
}

#[cfg(not(target_os = "linux"))]
mod sys {
    use std::collections::BTreeSet;
    use std::io;
    use std::time::Duration;

    /// Portable `WouldBlock`-polling fallback: no readiness facility at
    /// all — wait() sleeps a bounded tick and reports every registered
    /// token, and the nonblocking handlers discover actual readiness by
    /// attempting I/O.
    pub struct Poller {
        tokens: BTreeSet<u64>,
    }

    impl Poller {
        pub fn new() -> io::Result<Poller> {
            Ok(Poller { tokens: BTreeSet::new() })
        }

        pub fn add(&mut self, _fd: i64, token: u64, _writable: bool) -> io::Result<()> {
            self.tokens.insert(token);
            Ok(())
        }

        pub fn modify(&mut self, _fd: i64, _token: u64, _writable: bool) {}

        pub fn del(&mut self, _fd: i64) {}

        pub fn wait(&mut self, timeout_ms: i32, out: &mut Vec<u64>) {
            out.clear();
            std::thread::sleep(Duration::from_millis(timeout_ms.clamp(1, 5) as u64));
            out.extend(self.tokens.iter().copied());
        }
    }
}
