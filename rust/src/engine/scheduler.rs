//! Admission scheduler — decides which queued request decodes next.
//!
//! With single-sequence executables the "batching" decision is ordering +
//! admission (the paper's router layer); the KV slot pool (slots.rs) holds
//! per-sequence device state so interleaved execution never re-prefills.
//!
//! The queue is a binary heap keyed per policy, so `pop` is O(log n)
//! under load (the seed implementation scanned the whole queue per pop).
//! In the multi-worker engine (DESIGN.md §2) the scheduler sits behind
//! one short-lived mutex: workers lock, pop, and release before touching
//! any model state.
//!
//! **Ordering policy** (docs/ARCHITECTURE.md §5): SJF keys on each
//! request's *own* remaining service estimate (tokenized prompt length +
//! decode budget). Sessions already holding a slot shift every queued
//! request's absolute wait by the same amount, so they are deliberately
//! *excluded from the ordering key* — but they must not be excluded from
//! the *wait estimate*, which older revisions got wrong. The scheduler
//! therefore tracks in-flight cost separately (`note_done`,
//! `queue_wait_estimate`) and surfaces it in `/metrics`. Equal-cost
//! requests always pop in arrival order (`seq` tie-break), in-flight
//! load notwithstanding — pinned by `sjf_ties_stay_fifo` and
//! `in_flight_load_never_reorders_the_queue`.
//!
//! **Aging** (starvation fix): pure SJF starves a long request forever
//! under a sustained flood of short jobs — every newcomer outbids it.
//! The SJF key therefore ages by arrival index:
//! `key = cost + SJF_AGING_PER_ARRIVAL · seq` (saturating arithmetic —
//! see the private `sjf_key` helper). Keys stay static (heap
//! compatible) yet every later arrival is handicapped by how much
//! younger it is, so a queued request's *relative* priority rises with
//! every arrival it has waited through; once
//! `AGING · (seq_new − seq_old) > cost_old − cost_new` the oldest entry
//! wins regardless of cost. Cheap jobs still pop first among
//! near-contemporaries, and equal-key entries stay FIFO. Pinned by
//! `long_job_is_not_starved_under_short_job_flood`
//! (rust/tests/engine_lifecycle.rs).

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use super::request::Request;

/// SJF aging rate, in cost units of handicap per later arrival: a queued
/// request effectively gets this much cheaper relative to every request
/// that arrives after it, so a long job starved by a short-job flood is
/// guaranteed to pop within `cost / SJF_AGING_PER_ARRIVAL` further
/// arrivals (see the module docs). 16 ≈ one tiny request's cost, so
/// ordering among contemporaries is still effectively pure SJF.
pub const SJF_AGING_PER_ARRIVAL: u64 = 16;

/// The SJF heap key: service cost plus the arrival-index aging handicap,
/// in **saturating** arithmetic. On a long-lived server `seq` grows
/// without bound and a huge prompt can push `cost` near the type limit;
/// `cost + 16·seq` in plain arithmetic overflows there (a debug-build
/// panic, a silently *tiny* key — i.e. instant queue-jump — in release).
/// Saturation pins the worst case at `u64::MAX`, where the `seq`
/// tie-break keeps equal-key entries FIFO, so the failure mode degrades
/// to arrival order instead of inverted priorities. Pinned by
/// `aging_key_saturates_at_u64_boundaries`.
fn sjf_key(cost: u64, seq: u64) -> u64 {
    cost.saturating_add(SJF_AGING_PER_ARRIVAL.saturating_mul(seq))
}

/// Admission-ordering policy for queued requests.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Policy {
    /// first come, first served
    Fcfs,
    /// shortest (prompt + budget) job first — latency-optimal under load
    Sjf,
}

impl Policy {
    /// Parse a CLI policy name ("sjf"; anything else means FCFS).
    pub fn parse(s: &str) -> Policy {
        match s {
            "sjf" => Policy::Sjf,
            _ => Policy::Fcfs,
        }
    }
}

/// Heap entry: min-(key, seq) ordering via reversed `Ord`. `key` is 0
/// under FCFS (arrival order decides) and the request's decode cost plus
/// the arrival-index aging term under SJF; `seq` breaks ties by arrival
/// so equal-key jobs stay FIFO.
struct Entry {
    key: u64,
    seq: u64,
    /// request's own service-cost estimate (kept for both policies so
    /// pending/in-flight cost accounting is policy-independent)
    cost: u64,
    req: Request,
}

impl PartialEq for Entry {
    fn eq(&self, other: &Self) -> bool {
        self.key == other.key && self.seq == other.seq
    }
}

impl Eq for Entry {}

impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Entry {
    fn cmp(&self, other: &Self) -> Ordering {
        // reversed: BinaryHeap is a max-heap, we want the smallest
        // (key, seq) on top
        (other.key, other.seq).cmp(&(self.key, self.seq))
    }
}

/// The admission queue: a policy-keyed binary heap plus pending /
/// in-flight cost accounting for honest queue-wait estimates.
pub struct Scheduler {
    policy: Policy,
    queue: BinaryHeap<Entry>,
    next_seq: u64,
    admitted: u64,
    /// Σ cost of queued requests
    pending_cost: u64,
    /// Σ cost of requests popped but not yet reported done — the
    /// sessions already holding a slot, which shift every queued
    /// request's wait but never their relative order
    in_flight_cost: u64,
    /// number of popped-but-unfinished requests
    in_flight: usize,
}

impl std::fmt::Debug for Scheduler {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Scheduler")
            .field("policy", &self.policy)
            .field("queued", &self.queue.len())
            .field("admitted", &self.admitted)
            .finish()
    }
}

impl Scheduler {
    /// An empty queue under `policy`.
    pub fn new(policy: Policy) -> Scheduler {
        Scheduler {
            policy,
            queue: BinaryHeap::new(),
            next_seq: 0,
            admitted: 0,
            pending_cost: 0,
            in_flight_cost: 0,
            in_flight: 0,
        }
    }

    /// Enqueue a request (O(log n)). The SJF key carries the arrival-index
    /// aging term (module docs; saturating — see the `sjf_key` helper):
    /// older
    /// entries win against sufficiently newer ones no matter the cost
    /// gap, so no request starves. The cost is the request's
    /// [`Request::sched_cost`] — its service estimate net of the
    /// prefix-cache placement hint (docs/ARCHITECTURE.md §12), so a
    /// request whose prompt prefix is already resident in a slot sorts
    /// as the cheaper job it actually is. Ledger conservation follows
    /// from every [`Scheduler::note_done`] passing the same
    /// `sched_cost`.
    pub fn push(&mut self, req: Request) {
        let cost = req.sched_cost() as u64;
        let key = match self.policy {
            Policy::Fcfs => 0,
            Policy::Sjf => sjf_key(cost, self.next_seq),
        };
        let seq = self.next_seq;
        self.next_seq += 1;
        self.pending_cost += cost;
        self.queue.push(Entry { key, seq, cost, req });
    }

    /// Queued (not yet popped) request count.
    pub fn len(&self) -> usize {
        self.queue.len()
    }

    /// Is the queue empty?
    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Requests popped for decoding since construction.
    pub fn admitted(&self) -> u64 {
        self.admitted
    }

    /// Next request to decode, per policy. O(log n). The popped request
    /// moves from the pending-cost ledger to the in-flight ledger; the
    /// worker must pair it with [`Scheduler::note_done`] when the decode
    /// finishes.
    pub fn pop(&mut self) -> Option<Request> {
        let entry = self.queue.pop()?;
        self.admitted += 1;
        self.pending_cost -= entry.cost;
        self.in_flight_cost += entry.cost;
        self.in_flight += 1;
        Some(entry.req)
    }

    /// Remove queued requests that are already dead — cancelled or past
    /// their deadline — releasing their pending cost, and return them so
    /// the caller can answer their waiters. The admission controller
    /// (server.rs dispatcher) calls this before shedding a new arrival,
    /// so a dead entry never holds a `max_queue` seat that a live
    /// request could use (docs/ARCHITECTURE.md §10). Relative order of
    /// the surviving entries is preserved (`key`/`seq` are untouched).
    pub fn drain_dead(&mut self) -> Vec<Request> {
        if self
            .queue
            .iter()
            .all(|e| !e.req.cancel.is_cancelled() && !e.req.deadline_expired())
        {
            return Vec::new();
        }
        let mut dead = Vec::new();
        let mut live = BinaryHeap::with_capacity(self.queue.len());
        for e in std::mem::take(&mut self.queue) {
            if e.req.cancel.is_cancelled() || e.req.deadline_expired() {
                self.pending_cost -= e.cost;
                dead.push(e.req);
            } else {
                live.push(e);
            }
        }
        self.queue = live;
        dead
    }

    /// A previously popped request finished decoding (pass its
    /// `Request::sched_cost()` — the same quantity `push` charged, so
    /// the in-flight ledger conserves); releases it from the in-flight
    /// ledger.
    pub fn note_done(&mut self, cost: usize) {
        self.in_flight_cost = self.in_flight_cost.saturating_sub(cost as u64);
        self.in_flight = self.in_flight.saturating_sub(1);
    }

    /// An in-flight request's scheduling cost changed between `pop` and
    /// `note_done`: the checkout re-resolved its advisory `cached_hint`
    /// against the reuse the slot actually granted (server.rs,
    /// stepper.rs), so the in-flight ledger — charged with the stale
    /// `old` cost at pop — must now carry `new` for the matching
    /// `note_done(new)` to conserve. Queue *order* is untouched (the
    /// request already popped); only the wait-estimate ledger moves.
    pub fn reprice(&mut self, old: usize, new: usize) {
        self.in_flight_cost =
            self.in_flight_cost.saturating_sub(old as u64).saturating_add(new as u64);
    }

    /// Σ service cost of queued requests.
    pub fn pending_cost(&self) -> u64 {
        self.pending_cost
    }

    /// Σ service cost of requests currently decoding.
    pub fn in_flight_cost(&self) -> u64 {
        self.in_flight_cost
    }

    /// Requests currently decoding.
    pub fn in_flight(&self) -> usize {
        self.in_flight
    }

    /// Expected service cost ahead of a *newly arriving* request, in SJF
    /// cost units per worker: queued work plus the sessions already
    /// holding a slot. The in-flight term is what makes the estimate
    /// honest — it shifts every arrival's wait identically, which is
    /// exactly why it never participates in the ordering key (see the
    /// module docs).
    pub fn queue_wait_estimate(&self, workers: usize) -> f64 {
        (self.pending_cost + self.in_flight_cost) as f64 / workers.max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, plen: usize, max_new: usize) -> Request {
        let mut r = Request::new(id, "x".repeat(plen), max_new);
        r.category = "qa".into();
        r
    }

    #[test]
    fn fcfs_preserves_order() {
        let mut s = Scheduler::new(Policy::Fcfs);
        s.push(req(1, 10, 100));
        s.push(req(2, 1, 1));
        assert_eq!(s.pop().unwrap().id, 1);
        assert_eq!(s.pop().unwrap().id, 2);
        assert!(s.pop().is_none());
    }

    #[test]
    fn sjf_picks_cheapest() {
        let mut s = Scheduler::new(Policy::Sjf);
        s.push(req(1, 100, 200));
        s.push(req(2, 5, 10));
        s.push(req(3, 50, 50));
        assert_eq!(s.pop().unwrap().id, 2);
        assert_eq!(s.pop().unwrap().id, 3);
        assert_eq!(s.pop().unwrap().id, 1);
        assert_eq!(s.admitted(), 3);
    }

    #[test]
    fn sjf_costs_by_token_count_when_encoded() {
        // long text but few tokens must beat short text with many tokens
        let mut cheap = Request::new(1, "x".repeat(500), 10);
        cheap.prompt = vec![1, 3, 4]; // 3 tokens after encoding
        let mut costly = Request::new(2, "y", 10);
        costly.prompt = (0..400).map(|i| 3 + (i % 29)).collect();
        let mut s = Scheduler::new(Policy::Sjf);
        s.push(costly);
        s.push(cheap);
        assert_eq!(s.pop().unwrap().id, 1);
        assert_eq!(s.pop().unwrap().id, 2);
    }

    #[test]
    fn sjf_ties_stay_fifo() {
        let mut s = Scheduler::new(Policy::Sjf);
        for id in 1..=4 {
            s.push(req(id, 10, 10));
        }
        for id in 1..=4 {
            assert_eq!(s.pop().unwrap().id, id);
        }
    }

    #[test]
    fn in_flight_load_never_reorders_the_queue() {
        // pin the policy: sessions already holding a slot contribute to
        // the wait *estimate* but never to the ordering key — equal-cost
        // requests stay FIFO and cheaper requests still pop first, no
        // matter how much in-flight work there is
        let mut s = Scheduler::new(Policy::Sjf);
        s.push(req(1, 10, 10)); // cost 20
        let running = s.pop().unwrap();
        assert_eq!(running.id, 1);
        assert_eq!(s.in_flight(), 1);
        assert_eq!(s.in_flight_cost(), 20);

        s.push(req(2, 30, 30)); // cost 60
        s.push(req(3, 5, 5)); // cost 10
        s.push(req(4, 5, 5)); // cost 10, same as 3 -> FIFO after it
        assert_eq!(s.pending_cost(), 80);
        // estimate counts queued + in-flight work
        assert!((s.queue_wait_estimate(2) - 50.0).abs() < 1e-12);
        assert_eq!(s.pop().unwrap().id, 3, "cheapest first, in-flight load ignored");
        assert_eq!(s.pop().unwrap().id, 4, "equal cost stays arrival-ordered");
        assert_eq!(s.pop().unwrap().id, 2);

        // ledger conservation: everything popped is in flight until done
        assert_eq!(s.pending_cost(), 0);
        assert_eq!(s.in_flight(), 4);
        assert_eq!(s.in_flight_cost(), 100);
        for cost in [20, 60, 10, 10] {
            s.note_done(cost);
        }
        assert_eq!(s.in_flight(), 0);
        assert_eq!(s.in_flight_cost(), 0);
        assert!((s.queue_wait_estimate(4) - 0.0).abs() < 1e-12);
    }

    #[test]
    fn drain_dead_evicts_cancelled_and_expired_only() {
        let mut s = Scheduler::new(Policy::Sjf);
        assert!(s.drain_dead().is_empty(), "fast path on an all-live queue");
        let cancelled = req(2, 10, 10);
        cancelled.cancel.cancel();
        s.push(req(1, 10, 10));
        s.push(cancelled);
        s.push(Request::new(3, "xxxxx", 5).with_deadline_ms(0));
        assert_eq!(s.pending_cost(), 50);
        let dead = s.drain_dead();
        let mut dead_ids: Vec<u64> = dead.iter().map(|r| r.id).collect();
        dead_ids.sort_unstable();
        assert_eq!(dead_ids, vec![2, 3]);
        assert_eq!(s.len(), 1);
        assert_eq!(s.pending_cost(), 20, "evicted cost left the pending ledger");
        assert_eq!(s.pop().unwrap().id, 1, "live entries keep their order");
    }

    #[test]
    fn aging_promotes_old_entries_past_cheaper_newcomers() {
        let mut s = Scheduler::new(Policy::Sjf);
        s.push(req(1, 200, 200)); // cost 400, seq 0 -> key 400
        // newcomers of cost 20 outbid it only while their aging handicap
        // is below the cost gap: 20 + 16*seq < 400  =>  seq <= 23
        for id in 2..=40 {
            s.push(req(id, 10, 10));
        }
        let mut order = Vec::new();
        while let Some(r) = s.pop() {
            order.push(r.id);
        }
        let pos = order.iter().position(|&id| id == 1).unwrap();
        assert!(
            pos <= 24,
            "aged long job must pop once ~cost/AGING newer arrivals exist: popped at {pos}"
        );
        assert!(pos >= 5, "near-contemporaneous short jobs still beat it: popped at {pos}");
        // short jobs among themselves stay FIFO (equal cost, growing keys)
        let shorts: Vec<u64> = order.iter().copied().filter(|&id| id != 1).collect();
        let mut sorted = shorts.clone();
        sorted.sort_unstable();
        assert_eq!(shorts, sorted);
    }

    #[test]
    fn aging_key_saturates_at_u64_boundaries() {
        // a long-lived server's arrival index (or a huge prompt's cost)
        // can drive `cost + 16·seq` past u64::MAX; the key must saturate
        // — a debug-build panic or a wrapped (tiny) key would invert the
        // queue's priorities
        assert_eq!(sjf_key(u64::MAX, 0), u64::MAX);
        assert_eq!(sjf_key(u64::MAX - 10, 1_000_000), u64::MAX);
        assert_eq!(sjf_key(0, u64::MAX), u64::MAX, "aging product alone saturates");
        // u64::MAX/16 · 16 = u64::MAX − 15, so a cost of 100 overflows
        assert_eq!(sjf_key(100, u64::MAX / SJF_AGING_PER_ARRIVAL), u64::MAX);
        // well inside the range the key stays exact
        assert_eq!(sjf_key(100, 3), 100 + 3 * SJF_AGING_PER_ARRIVAL);
        // saturated keys are equal, so ordering falls back to the seq
        // tie-break (FIFO) instead of panicking or inverting
        let mut s = Scheduler::new(Policy::Sjf);
        s.next_seq = u64::MAX - 2;
        s.push(req(1, 50, 50));
        s.push(req(2, 1, 1));
        assert_eq!(s.pop().unwrap().id, 1, "saturated keys stay FIFO by seq");
        assert_eq!(s.pop().unwrap().id, 2);
    }

    #[test]
    fn cached_hint_discounts_the_sjf_cost() {
        // two equal-cost requests; the one whose prompt prefix is
        // expected to be resident in a slot sorts as the cheaper job
        let mut s = Scheduler::new(Policy::Sjf);
        let plain = req(1, 50, 10); // cost 60
        let mut hinted = req(2, 50, 10); // cost 60, 40 expected cached
        hinted.cached_hint = 40;
        assert_eq!(hinted.sched_cost(), 20);
        s.push(plain);
        s.push(hinted);
        assert_eq!(s.pending_cost(), 80, "ledger charges the discounted cost");
        assert_eq!(s.pop().unwrap().id, 2, "cache-hit request pops first");
        assert_eq!(s.pop().unwrap().id, 1);
        // conservation when note_done passes the same sched_cost
        s.note_done(20);
        s.note_done(60);
        assert_eq!(s.in_flight_cost(), 0);
        assert_eq!(s.in_flight(), 0);
    }

    #[test]
    fn reprice_keeps_the_in_flight_ledger_conserved() {
        // satellite regression: a request enqueued with a 40-token
        // placement hint pops carrying sched_cost 20; by checkout the
        // residency is gone, so the hint re-resolves to 0 and the cost
        // becomes 60. Without reprice, note_done(60) would underflow the
        // ledger by 40 (leaving phantom in-flight cost from every other
        // request, or a saturated zero hiding real load).
        let mut s = Scheduler::new(Policy::Sjf);
        let mut hinted = req(1, 50, 10); // cost 60, 40 expected cached
        hinted.cached_hint = 40;
        s.push(hinted); // charges sched_cost 20
        s.push(req(2, 50, 10)); // a bystander, cost 60
        let mut popped = s.pop().unwrap();
        assert_eq!(popped.id, 1);
        assert_eq!(s.in_flight_cost(), 20);

        // checkout finds the residency consumed: hint re-resolves to 0
        let stale = popped.sched_cost();
        popped.cached_hint = 0;
        s.reprice(stale, popped.sched_cost());
        assert_eq!(s.in_flight_cost(), 60, "ledger now carries the real cost");

        let bystander = s.pop().unwrap();
        s.note_done(popped.sched_cost()); // releases 60, not 20
        s.note_done(bystander.sched_cost());
        assert_eq!(s.in_flight_cost(), 0, "ledger conserves after reprice");
        assert_eq!(s.in_flight(), 0);

        // repricing in the cheap direction conserves too (a hint that
        // *appeared* between enqueue and checkout)
        s.push(req(3, 50, 10));
        let mut r = s.pop().unwrap();
        let stale = r.sched_cost();
        r.cached_hint = 40;
        s.reprice(stale, r.sched_cost());
        assert_eq!(s.in_flight_cost(), 20);
        s.note_done(r.sched_cost());
        assert_eq!(s.in_flight_cost(), 0);
    }

    #[test]
    fn interleaved_push_pop_keeps_heap_consistent() {
        let mut s = Scheduler::new(Policy::Sjf);
        s.push(req(1, 30, 30));
        s.push(req(2, 1, 1));
        assert_eq!(s.pop().unwrap().id, 2);
        s.push(req(3, 2, 2));
        assert_eq!(s.pop().unwrap().id, 3);
        assert_eq!(s.pop().unwrap().id, 1);
        assert!(s.is_empty());
        assert_eq!(s.admitted(), 3);
    }
}
