//! Admission scheduler — decides which queued request decodes next.
//!
//! With single-sequence executables the "batching" decision is ordering +
//! admission (the paper's router layer); the KV slot pool (slots.rs) holds
//! per-sequence device state so interleaved execution never re-prefills.
//!
//! The queue is a binary heap keyed per policy, so `pop` is O(log n)
//! under load (the seed implementation scanned the whole queue per pop).
//! In the multi-worker engine (DESIGN.md §2) the scheduler sits behind
//! one short-lived mutex: workers lock, pop, and release before touching
//! any model state.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use super::request::Request;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Policy {
    /// first come, first served
    Fcfs,
    /// shortest (prompt + budget) job first — latency-optimal under load
    Sjf,
}

impl Policy {
    pub fn parse(s: &str) -> Policy {
        match s {
            "sjf" => Policy::Sjf,
            _ => Policy::Fcfs,
        }
    }
}

/// Heap entry: min-(key, seq) ordering via reversed `Ord`. `key` is 0
/// under FCFS (arrival order decides) and the request's decode cost under
/// SJF; `seq` breaks ties by arrival so equal-cost jobs stay FIFO.
struct Entry {
    key: u64,
    seq: u64,
    req: Request,
}

impl PartialEq for Entry {
    fn eq(&self, other: &Self) -> bool {
        self.key == other.key && self.seq == other.seq
    }
}

impl Eq for Entry {}

impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Entry {
    fn cmp(&self, other: &Self) -> Ordering {
        // reversed: BinaryHeap is a max-heap, we want the smallest
        // (key, seq) on top
        (other.key, other.seq).cmp(&(self.key, self.seq))
    }
}

pub struct Scheduler {
    policy: Policy,
    queue: BinaryHeap<Entry>,
    next_seq: u64,
    admitted: u64,
}

impl std::fmt::Debug for Scheduler {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Scheduler")
            .field("policy", &self.policy)
            .field("queued", &self.queue.len())
            .field("admitted", &self.admitted)
            .finish()
    }
}

impl Scheduler {
    pub fn new(policy: Policy) -> Scheduler {
        Scheduler {
            policy,
            queue: BinaryHeap::new(),
            next_seq: 0,
            admitted: 0,
        }
    }

    pub fn push(&mut self, req: Request) {
        let key = match self.policy {
            Policy::Fcfs => 0,
            Policy::Sjf => req.cost() as u64,
        };
        let seq = self.next_seq;
        self.next_seq += 1;
        self.queue.push(Entry { key, seq, req });
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    pub fn admitted(&self) -> u64 {
        self.admitted
    }

    /// Next request to decode, per policy. O(log n).
    pub fn pop(&mut self) -> Option<Request> {
        let entry = self.queue.pop()?;
        self.admitted += 1;
        Some(entry.req)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, plen: usize, max_new: usize) -> Request {
        let mut r = Request::new(id, "x".repeat(plen), max_new);
        r.category = "qa".into();
        r
    }

    #[test]
    fn fcfs_preserves_order() {
        let mut s = Scheduler::new(Policy::Fcfs);
        s.push(req(1, 10, 100));
        s.push(req(2, 1, 1));
        assert_eq!(s.pop().unwrap().id, 1);
        assert_eq!(s.pop().unwrap().id, 2);
        assert!(s.pop().is_none());
    }

    #[test]
    fn sjf_picks_cheapest() {
        let mut s = Scheduler::new(Policy::Sjf);
        s.push(req(1, 100, 200));
        s.push(req(2, 5, 10));
        s.push(req(3, 50, 50));
        assert_eq!(s.pop().unwrap().id, 2);
        assert_eq!(s.pop().unwrap().id, 3);
        assert_eq!(s.pop().unwrap().id, 1);
        assert_eq!(s.admitted(), 3);
    }

    #[test]
    fn sjf_costs_by_token_count_when_encoded() {
        // long text but few tokens must beat short text with many tokens
        let mut cheap = Request::new(1, "x".repeat(500), 10);
        cheap.prompt = vec![1, 3, 4]; // 3 tokens after encoding
        let mut costly = Request::new(2, "y", 10);
        costly.prompt = (0..400).map(|i| 3 + (i % 29)).collect();
        let mut s = Scheduler::new(Policy::Sjf);
        s.push(costly);
        s.push(cheap);
        assert_eq!(s.pop().unwrap().id, 1);
        assert_eq!(s.pop().unwrap().id, 2);
    }

    #[test]
    fn sjf_ties_stay_fifo() {
        let mut s = Scheduler::new(Policy::Sjf);
        for id in 1..=4 {
            s.push(req(id, 10, 10));
        }
        for id in 1..=4 {
            assert_eq!(s.pop().unwrap().id, id);
        }
    }

    #[test]
    fn interleaved_push_pop_keeps_heap_consistent() {
        let mut s = Scheduler::new(Policy::Sjf);
        s.push(req(1, 30, 30));
        s.push(req(2, 1, 1));
        assert_eq!(s.pop().unwrap().id, 2);
        s.push(req(3, 2, 2));
        assert_eq!(s.pop().unwrap().id, 3);
        assert_eq!(s.pop().unwrap().id, 1);
        assert!(s.is_empty());
        assert_eq!(s.admitted(), 3);
    }
}
