//! Admission scheduler — decides which queued request decodes next.
//!
//! With single-sequence executables the "batching" decision is ordering +
//! admission (the paper's router layer); the KV slot pool (slots.rs) holds
//! per-sequence device state so interleaved execution never re-prefills.

use std::collections::VecDeque;

use super::request::Request;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Policy {
    /// first come, first served
    Fcfs,
    /// shortest (prompt + budget) job first — latency-optimal under load
    Sjf,
}

impl Policy {
    pub fn parse(s: &str) -> Policy {
        match s {
            "sjf" => Policy::Sjf,
            _ => Policy::Fcfs,
        }
    }
}

#[derive(Debug)]
pub struct Scheduler {
    policy: Policy,
    queue: VecDeque<Request>,
    admitted: u64,
}

impl Scheduler {
    pub fn new(policy: Policy) -> Scheduler {
        Scheduler { policy, queue: VecDeque::new(), admitted: 0 }
    }

    pub fn push(&mut self, req: Request) {
        self.queue.push_back(req);
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    pub fn admitted(&self) -> u64 {
        self.admitted
    }

    /// Next request to decode, per policy.
    pub fn pop(&mut self) -> Option<Request> {
        if self.queue.is_empty() {
            return None;
        }
        let idx = match self.policy {
            Policy::Fcfs => 0,
            Policy::Sjf => {
                let mut best = 0;
                let mut best_cost = usize::MAX;
                for (i, r) in self.queue.iter().enumerate() {
                    let cost = r.prompt_text.len() + r.max_new;
                    if cost < best_cost {
                        best_cost = cost;
                        best = i;
                    }
                }
                best
            }
        };
        self.admitted += 1;
        self.queue.remove(idx)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, plen: usize, max_new: usize) -> Request {
        let mut r = Request::new(id, "x".repeat(plen), max_new);
        r.category = "qa".into();
        r
    }

    #[test]
    fn fcfs_preserves_order() {
        let mut s = Scheduler::new(Policy::Fcfs);
        s.push(req(1, 10, 100));
        s.push(req(2, 1, 1));
        assert_eq!(s.pop().unwrap().id, 1);
        assert_eq!(s.pop().unwrap().id, 2);
        assert!(s.pop().is_none());
    }

    #[test]
    fn sjf_picks_cheapest() {
        let mut s = Scheduler::new(Policy::Sjf);
        s.push(req(1, 100, 200));
        s.push(req(2, 5, 10));
        s.push(req(3, 50, 50));
        assert_eq!(s.pop().unwrap().id, 2);
        assert_eq!(s.pop().unwrap().id, 3);
        assert_eq!(s.pop().unwrap().id, 1);
        assert_eq!(s.admitted(), 3);
    }
}
