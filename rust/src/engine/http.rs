//! Minimal HTTP/1.1 JSON API over std::net (offline stand-in for a web
//! framework). Routes:
//!
//!   GET  /health              -> {"ok": true, ...}
//!   GET  /metrics             -> serving metrics + per-worker stats +
//!                                lifecycle counters + shared-bandit state
//!   POST /generate            -> {"prompt": "...", "max_new": 64,
//!                                 "stream": false, "deadline_ms": 0}
//!
//! One thread per connection; decoding parallelism comes from the
//! engine's worker pool (server.rs). Error contract (docs/OPERATIONS.md):
//! decode failures are a 500 with an error body, an over-size body is a
//! 413, a POST without a `Content-Length` header is a 411 (header names
//! match case-insensitively per RFC 9110), a chunked request body is a
//! 501 (not implemented here), a shed request (admission control) is a
//! 429 carrying the queue-wait estimate, and a request that outlives its
//! deadline is a 504.
//!
//! With `"stream": true` the reply is a chunked `text/event-stream`: one
//! `data:` event per committed decode round (ids + text) and a final
//! `data:` event with `"done": true` and the request summary. A client
//! that disconnects mid-stream cancels the request at the next round
//! boundary — its KV slot, batch seat, and queue entry are released
//! (docs/ARCHITECTURE.md §10).

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

use anyhow::Result;

use crate::util::Json;

use super::request::{FinishStatus, Request, StreamEvent};
use super::server::Engine;

/// Largest request body accepted before answering 413 (the JSON body of
/// a generate call is tiny; anything near this is a client bug).
pub const MAX_BODY_BYTES: usize = 1 << 20;

/// The background HTTP listener (one thread per connection).
pub struct HttpServer {
    /// bound address, e.g. `127.0.0.1:8077`
    pub addr: String,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl HttpServer {
    /// Bind and serve in background threads. Port 0 picks a free port.
    pub fn start(engine: Arc<Engine>, port: u16) -> Result<HttpServer> {
        let listener = TcpListener::bind(("127.0.0.1", port))?;
        let addr = listener.local_addr()?.to_string();
        let handle = std::thread::Builder::new()
            .name("tapout-http".into())
            .spawn(move || {
                for stream in listener.incoming() {
                    let Ok(stream) = stream else { continue };
                    let eng = engine.clone();
                    std::thread::spawn(move || {
                        let _ = handle_conn(stream, &eng);
                    });
                }
            })?;
        Ok(HttpServer { addr, handle: Some(handle) })
    }
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        // listener thread exits with the process; detach
        if let Some(h) = self.handle.take() {
            drop(h);
        }
    }
}

fn handle_conn(stream: TcpStream, engine: &Engine) -> Result<()> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let mut parts = line.split_whitespace();
    let method = parts.next().unwrap_or("").to_string();
    let path = parts.next().unwrap_or("/").to_string();

    // headers — field names are matched case-insensitively per RFC 9110
    // §5.1 (clients legitimately send `content-length`, `Content-Length`,
    // or any mix; an exact-case match silently drops their body length)
    let mut content_length: Option<usize> = None;
    let mut bad_length: Option<String> = None;
    let mut chunked = false;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h)?;
        let h = h.trim();
        if h.is_empty() {
            break;
        }
        if let Some((name, value)) = h.split_once(':') {
            let (name, value) = (name.trim(), value.trim());
            if name.eq_ignore_ascii_case("content-length") {
                match value.parse() {
                    Ok(n) => content_length = Some(n),
                    // present but unparseable is a framing error (400),
                    // distinct from the header being absent (411)
                    Err(_) => bad_length = Some(value.to_string()),
                }
            } else if name.eq_ignore_ascii_case("transfer-encoding") {
                chunked = value.to_ascii_lowercase().contains("chunked");
            }
        }
    }

    // body-framing contract for routes that need a body (RFC 9110):
    // chunked transfer coding is not implemented here — a chunked body
    // read as `content-length` bytes would be garbage, so refuse it
    // explicitly with 501; a POST with no length at all is 411 Length
    // Required, not a misleading "bad json" 400 over an empty body
    if method == "POST" && path == "/generate" {
        if chunked {
            let mut o = Json::obj();
            o.set("error", "chunked transfer-encoding not supported: send content-length");
            return respond(stream, 501, &o.render());
        }
        if let Some(bad) = bad_length {
            let mut o = Json::obj();
            o.set("error", format!("invalid content-length header: {bad:?}"));
            return respond(stream, 400, &o.render());
        }
        if content_length.is_none() {
            let mut o = Json::obj();
            o.set("error", "missing content-length header (chunked bodies unsupported)");
            return respond(stream, 411, &o.render());
        }
    }
    let content_length = content_length.unwrap_or(0);

    // over-size bodies are refused up front — never silently truncated
    // into confusing JSON decode errors (docs/OPERATIONS.md)
    if content_length > MAX_BODY_BYTES {
        let mut o = Json::obj();
        o.set(
            "error",
            format!("body too large: {content_length} bytes (max {MAX_BODY_BYTES})"),
        );
        return respond(stream, 413, &o.render());
    }

    // read the full declared body; read_exact loops over short reads, so
    // a body split across TCP segments reassembles correctly, and a
    // connection that closes early is an explicit 400 instead of a
    // truncated-JSON decode error
    let mut body = vec![0u8; content_length];
    if content_length > 0 {
        if let Err(e) = reader.read_exact(&mut body) {
            let mut o = Json::obj();
            o.set("error", format!("body ended before content-length ({content_length}): {e}"));
            return respond(stream, 400, &o.render());
        }
    }
    let body = String::from_utf8_lossy(&body).to_string();

    // streaming generate owns the raw stream (chunked SSE writes)
    if method == "POST" && path == "/generate" {
        match parse_generate(&body) {
            Ok((req, stream_mode)) => {
                return if stream_mode {
                    stream_generate(stream, engine, req)
                } else {
                    let (status, payload) = unary_generate(engine, req);
                    respond(stream, status, &payload.render())
                };
            }
            Err((status, payload)) => return respond(stream, status, &payload.render()),
        }
    }

    let (status, payload) = route(engine, &method, &path);
    respond(stream, status, &payload.render())
}

fn route(engine: &Engine, method: &str, path: &str) -> (u16, Json) {
    match (method, path) {
        ("GET", "/health") => {
            let mut o = Json::obj();
            o.set("ok", true)
                .set("pair", engine.config.pair.as_str())
                .set("method", engine.config.method.as_str())
                .set("backend", engine.config.backend.label())
                .set("mode", engine.config.mode.label())
                .set("workers", engine.config.workers)
                .set("slots", engine.config.slots)
                .set("max_batch", engine.config.verify_batch.max_batch)
                .set("max_queue", engine.config.max_queue)
                .set("prefix_cache", engine.config.prefix_cache);
            (200, o)
        }
        ("GET", "/metrics") => (200, engine.metrics_json()),
        _ => {
            let mut o = Json::obj();
            o.set("error", "not found");
            (404, o)
        }
    }
}

/// Parse a /generate body into a ready-to-submit request plus the
/// client's streaming preference.
fn parse_generate(body: &str) -> std::result::Result<(Request, bool), (u16, Json)> {
    let j = Json::parse(body).map_err(|e| {
        let mut o = Json::obj();
        o.set("error", format!("bad json: {e}"));
        (400, o)
    })?;
    let prompt = j.get("prompt").and_then(|x| x.as_str()).unwrap_or("");
    if prompt.is_empty() {
        let mut o = Json::obj();
        o.set("error", "missing prompt");
        return Err((400, o));
    }
    let max_new = j.get("max_new").and_then(|x| x.as_usize()).unwrap_or(96);
    let mut req = Request::new(0, prompt, max_new.min(256));
    let deadline_ms = j.get("deadline_ms").and_then(|x| x.as_usize()).filter(|&ms| ms > 0);
    if let Some(ms) = deadline_ms {
        req = req.with_deadline_ms(ms as u64);
    }
    let stream_mode = j.get("stream").and_then(|x| x.as_bool()).unwrap_or(false);
    Ok((req, stream_mode))
}

/// Map a terminal response to its HTTP status (docs/OPERATIONS.md).
fn status_code(status: FinishStatus) -> u16 {
    match status {
        FinishStatus::Done => 200,
        FinishStatus::Rejected => 429,
        FinishStatus::Expired => 504,
        FinishStatus::Failed | FinishStatus::Cancelled => 500,
    }
}

fn unary_generate(engine: &Engine, req: Request) -> (u16, Json) {
    let cancel = req.cancel_flag();
    let rx = engine.submit_request(req);
    match rx.recv_timeout(std::time::Duration::from_secs(120)) {
        Ok(resp) if resp.is_ok() => {
            let mut o = Json::obj();
            o.set("id", resp.id as usize)
                .set("status", resp.status.label())
                .set("text", resp.text.as_str())
                .set("new_tokens", resp.result.new_tokens().len())
                .set("mean_accepted", resp.result.mean_accepted())
                .set("acceptance_rate", resp.result.acceptance_rate())
                .set("decode_ms", resp.result.wall_ns as f64 / 1e6)
                .set("tokens_per_sec", resp.tokens_per_sec());
            (200, o)
        }
        Ok(resp) => {
            // explicit terminal state: rejected/expired/failed replies
            // carry their reason instead of dropping the waiter
            let mut o = Json::obj();
            o.set("id", resp.id as usize)
                .set("status", resp.status.label())
                .set("error", resp.error.as_deref().unwrap_or("decode failed"));
            (status_code(resp.status), o)
        }
        Err(_) => {
            // give up on the decode, not just the reply: without the
            // cancel the worker would keep burning its KV slot on a
            // request nobody is waiting for
            cancel.cancel();
            let mut o = Json::obj();
            o.set("error", "generation timed out or failed");
            (500, o)
        }
    }
}

/// Serve one streaming generate: chunked transfer, one SSE `data:` event
/// per committed round, a final `data:` event with the summary. A write
/// failure (client gone) cancels the request via its shared flag.
///
/// The status line is held back until the first engine event: a request
/// that terminates before any tokens (shed, expired in queue, failed)
/// gets the documented plain-JSON error reply (429/504/500) instead of
/// a 200 SSE stream. Once tokens have flowed, the terminal status
/// arrives in-band in the final `data:` event.
fn stream_generate(mut stream: TcpStream, engine: &Engine, req: Request) -> Result<()> {
    let cancel = req.cancel_flag();
    let rx = engine.submit_request_streaming(req);
    let first = match rx.recv() {
        Ok(ev) => ev,
        Err(_) => {
            let mut o = Json::obj();
            o.set("error", "engine unavailable");
            return respond(stream, 500, &o.render());
        }
    };
    if let StreamEvent::Done(resp) = &first {
        if resp.status != FinishStatus::Done {
            let mut o = Json::obj();
            o.set("id", resp.id as usize)
                .set("status", resp.status.label())
                .set("error", resp.error.as_deref().unwrap_or("request did not complete"));
            return respond(stream, status_code(resp.status), &o.render());
        }
    }
    write!(
        stream,
        "HTTP/1.1 200 OK\r\nContent-Type: text/event-stream\r\nCache-Control: no-cache\r\n\
         Transfer-Encoding: chunked\r\nConnection: close\r\n\r\n"
    )?;
    let mut pending = Some(first);
    loop {
        let event = match pending.take() {
            Some(ev) => Ok(ev),
            None => rx.recv(),
        };
        match event {
            Ok(StreamEvent::Tokens { ids, text, .. }) => {
                let mut o = Json::obj();
                o.set("ids", ids.iter().map(|&t| Json::from(t)).collect::<Vec<Json>>())
                    .set("text", text);
                if write_sse_chunk(&mut stream, &o.render()).is_err() {
                    // client disconnected: cancel and stop reading; the
                    // worker sees the flag at the next round boundary
                    cancel.cancel();
                    return Ok(());
                }
            }
            Ok(StreamEvent::Done(resp)) => {
                let mut o = Json::obj();
                o.set("done", true)
                    .set("id", resp.id as usize)
                    .set("status", resp.status.label())
                    .set("new_tokens", resp.result.new_tokens().len())
                    .set("mean_accepted", resp.result.mean_accepted())
                    .set("acceptance_rate", resp.result.acceptance_rate())
                    .set("decode_ms", resp.result.wall_ns as f64 / 1e6);
                if let Some(e) = resp.error.as_deref() {
                    o.set("error", e);
                }
                let _ = write_sse_chunk(&mut stream, &o.render());
                // terminating zero-length chunk ends the response
                let _ = stream.write_all(b"0\r\n\r\n");
                let _ = stream.flush();
                return Ok(());
            }
            Err(_) => {
                // engine side hung up without a Done event (shutdown)
                let _ = stream.write_all(b"0\r\n\r\n");
                return Ok(());
            }
        }
    }
}

/// Write one SSE event (`data: <json>\n\n`) as a single HTTP chunk.
fn write_sse_chunk(stream: &mut TcpStream, payload: &str) -> std::io::Result<()> {
    let data = format!("data: {payload}\n\n");
    write!(stream, "{:X}\r\n{}\r\n", data.len(), data)?;
    stream.flush()
}

fn respond(mut stream: TcpStream, status: u16, body: &str) -> Result<()> {
    let reason = match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        411 => "Length Required",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        501 => "Not Implemented",
        504 => "Gateway Timeout",
        _ => "Internal Server Error",
    };
    write!(
        stream,
        "HTTP/1.1 {status} {reason}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    )?;
    stream.flush()?;
    Ok(())
}
