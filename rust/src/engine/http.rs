//! Minimal HTTP/1.1 JSON API over std::net (offline stand-in for a web
//! framework). Routes:
//!
//!   GET  /health              -> {"ok": true, ...}
//!   GET  /metrics             -> serving metrics + per-worker stats +
//!                                lifecycle counters + shared-bandit state
//!                                + front-end I/O gauges
//!   POST /generate            -> {"prompt": "...", "max_new": 64,
//!                                 "stream": false, "deadline_ms": 0}
//!
//! Two front ends share every renderer in this module byte for byte
//! (docs/ARCHITECTURE.md §15):
//!
//! * **reactor** (default): the nonblocking readiness loop in
//!   reactor.rs — a fixed pool of `io_threads` I/O threads multiplexes
//!   every connection, so thousands of concurrent SSE streams cost no
//!   threads beyond the pool.
//! * **blocking** (`HttpConfig::io_threads == 0`): the legacy
//!   thread-per-connection loop, kept as the parity baseline.
//!
//! Decoding parallelism comes from the engine's worker pool (server.rs)
//! either way. Error contract (docs/OPERATIONS.md): decode failures are
//! a 500 with an error body, an over-size body is a 413, a POST without
//! a `Content-Length` header is a 411 (header names match
//! case-insensitively per RFC 9110), a chunked request body is a 501
//! (not implemented here), a shed request (admission control) is a 429
//! carrying the queue-wait estimate, a request that outlives its
//! deadline is a 504, and a client that has not delivered its complete
//! request within `header_timeout_ms` (slow loris) is a 408.
//!
//! With `"stream": true` the reply is a chunked `text/event-stream`: one
//! `data:` event per committed decode round (ids + text) and a final
//! `data:` event with `"done": true` and the request summary; streams
//! silent for `sse_keepalive_ms` carry an SSE comment (`: ping`) so
//! intermediaries don't reap the connection. A client that disconnects
//! mid-stream cancels the request at the next round boundary — its KV
//! slot, batch seat, and queue entry are released
//! (docs/ARCHITECTURE.md §10).

use std::collections::VecDeque;
use std::io::{BufRead, BufReader, ErrorKind, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{Receiver, TryRecvError};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::util::Json;

use super::metrics::IoStats;
use super::reactor::{EventSource, Gateway, GenerateStart, Reactor, ReactorConfig, SourceEvent};
use super::request::{CancelFlag, FinishStatus, Request, Response, StreamEvent};
use super::server::Engine;

/// Largest request body accepted before answering 413 (the JSON body of
/// a generate call is tiny; anything near this is a client bug).
pub const MAX_BODY_BYTES: usize = 1 << 20;

/// How long a unary generate may run before the front end gives up,
/// cancels the decode, and answers 500.
const UNARY_TIMEOUT: Duration = Duration::from_secs(120);

/// Front-end tuning: which I/O model serves connections and the
/// slow-loris / keep-alive clocks.
#[derive(Clone, Debug)]
pub struct HttpConfig {
    /// I/O threads for the reactor front end; `0` selects the legacy
    /// blocking thread-per-connection loop
    pub io_threads: usize,
    /// slow-loris bound: a connection that has not delivered its full
    /// request within this window is answered 408 and freed
    pub header_timeout_ms: u64,
    /// SSE comment (`: ping`) interval on streams with no events
    pub sse_keepalive_ms: u64,
}

impl Default for HttpConfig {
    fn default() -> HttpConfig {
        HttpConfig { io_threads: 4, header_timeout_ms: 10_000, sse_keepalive_ms: 15_000 }
    }
}

enum Inner {
    Blocking { stop: Arc<AtomicBool>, handle: Option<std::thread::JoinHandle<()>> },
    Reactor(Reactor),
}

/// The background HTTP listener: a reactor I/O pool by default, the
/// legacy blocking loop when `io_threads == 0`.
pub struct HttpServer {
    /// bound address, e.g. `127.0.0.1:8077`
    pub addr: String,
    /// front-end I/O gauges (also surfaced under `io` in `/metrics`)
    pub stats: Arc<IoStats>,
    inner: Inner,
}

impl HttpServer {
    /// Bind and serve with the default front end (reactor, 4 I/O
    /// threads). Port 0 picks a free port.
    pub fn start(engine: Arc<Engine>, port: u16) -> Result<HttpServer> {
        HttpServer::start_with(engine, port, HttpConfig::default())
    }

    /// Bind and serve with explicit front-end tuning.
    pub fn start_with(engine: Arc<Engine>, port: u16, cfg: HttpConfig) -> Result<HttpServer> {
        if cfg.io_threads == 0 {
            return HttpServer::start_blocking(engine, port, cfg);
        }
        let stats = Arc::new(IoStats::new("reactor", cfg.io_threads));
        let gateway: Arc<dyn Gateway> =
            Arc::new(EngineGateway { engine, stats: stats.clone() });
        let rcfg = ReactorConfig {
            io_threads: cfg.io_threads,
            header_timeout: Duration::from_millis(cfg.header_timeout_ms.max(1)),
            sse_keepalive: Duration::from_millis(cfg.sse_keepalive_ms.max(1)),
        };
        let reactor = Reactor::start(gateway, port, rcfg, stats.clone())?;
        Ok(HttpServer { addr: reactor.addr.clone(), stats, inner: Inner::Reactor(reactor) })
    }

    fn start_blocking(engine: Arc<Engine>, port: u16, cfg: HttpConfig) -> Result<HttpServer> {
        let listener = TcpListener::bind(("127.0.0.1", port))?;
        let addr = listener.local_addr()?.to_string();
        let stats = Arc::new(IoStats::new("blocking", 0));
        let stop = Arc::new(AtomicBool::new(false));
        let st = stats.clone();
        let sp = stop.clone();
        let handle = std::thread::Builder::new()
            .name("tapout-http".into())
            .spawn(move || {
                for stream in listener.incoming() {
                    if sp.load(Ordering::Relaxed) {
                        break;
                    }
                    let Ok(stream) = stream else { continue };
                    st.accepted.fetch_add(1, Ordering::Relaxed);
                    let eng = engine.clone();
                    let cst = st.clone();
                    let c = cfg.clone();
                    std::thread::spawn(move || {
                        cst.conn_opened();
                        let _ = handle_conn(stream, &eng, &cst, &c);
                        cst.conn_closed();
                    });
                }
            })?;
        Ok(HttpServer {
            addr,
            stats,
            inner: Inner::Blocking { stop, handle: Some(handle) },
        })
    }

    /// Stop serving: close the listener and (reactor mode) sever every
    /// open connection, then join the I/O threads. Idempotent. In-flight
    /// decodes keep running in the engine; only their reply paths die.
    pub fn stop(&mut self) {
        match &mut self.inner {
            Inner::Reactor(r) => r.stop(),
            Inner::Blocking { stop, handle } => {
                stop.store(true, Ordering::SeqCst);
                // unblock the accept loop so it observes the flag
                let woke = TcpStream::connect(&self.addr).is_ok();
                if let Some(h) = handle.take() {
                    if woke {
                        let _ = h.join();
                    }
                    // if the wake-up connect failed the listener thread
                    // stays parked on accept; detaching it is the legacy
                    // behavior and it exits with the process
                }
            }
        }
    }
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        self.stop();
    }
}

// ---------------------------------------------------------------------------
// shared renderers — the blocking loop, the reactor gateway, and the
// router (router.rs) all emit these exact bytes
// ---------------------------------------------------------------------------

/// Standard reason phrase for the status codes this stack emits.
pub(crate) fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        408 => "Request Timeout",
        411 => "Length Required",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        501 => "Not Implemented",
        502 => "Bad Gateway",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "Internal Server Error",
    }
}

/// Render a complete plain HTTP response (status line, JSON headers,
/// content-length framed body).
pub(crate) fn plain_response(status: u16, body: &str) -> String {
    format!(
        "HTTP/1.1 {status} {}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        reason(status),
        body.len()
    )
}

/// The SSE response preamble (status line + chunked headers).
pub(crate) const SSE_HEADERS: &str = "HTTP/1.1 200 OK\r\nContent-Type: text/event-stream\r\nCache-Control: no-cache\r\nTransfer-Encoding: chunked\r\nConnection: close\r\n\r\n";

/// Frame one SSE event (`data: <json>\n\n`) as a single HTTP chunk.
pub(crate) fn sse_frame(payload: &str) -> String {
    let data = format!("data: {payload}\n\n");
    format!("{:X}\r\n{}\r\n", data.len(), data)
}

/// Frame an SSE comment (`: <note>\n\n`) as a single HTTP chunk —
/// ignored by SSE clients, resets intermediaries' idle timers.
pub(crate) fn sse_comment_frame(note: &str) -> String {
    let data = format!(": {note}\n\n");
    format!("{:X}\r\n{}\r\n", data.len(), data)
}

/// Render `{"error": msg}`.
pub(crate) fn err_body(msg: impl Into<Json>) -> String {
    let mut o = Json::obj();
    o.set("error", msg);
    o.render()
}

/// 501 for a chunked generate body (chunked transfer coding is not
/// implemented here; reading it as content-length bytes would be
/// garbage).
pub(crate) fn framing_chunked() -> (u16, String) {
    (501, err_body("chunked transfer-encoding not supported: send content-length"))
}

/// 400 for a present-but-unparseable content-length header (distinct
/// from the header being absent, which is 411).
pub(crate) fn framing_bad_length(bad: &str) -> (u16, String) {
    (400, err_body(format!("invalid content-length header: {bad:?}")))
}

/// 411 for a generate POST with no content-length at all.
pub(crate) fn framing_length_required() -> (u16, String) {
    (411, err_body("missing content-length header (chunked bodies unsupported)"))
}

/// 413 for a declared body size over [`MAX_BODY_BYTES`] — refused up
/// front, never silently truncated into confusing JSON decode errors.
pub(crate) fn framing_too_large(declared: usize) -> (u16, String) {
    (413, err_body(format!("body too large: {declared} bytes (max {MAX_BODY_BYTES})")))
}

/// Route a non-generate request; `io` carries the serving front end's
/// gauges into `/metrics`.
pub(crate) fn route(engine: &Engine, method: &str, path: &str, io: Option<&IoStats>) -> (u16, Json) {
    match (method, path) {
        ("GET", "/health") => {
            let mut o = Json::obj();
            o.set("ok", true)
                .set("pair", engine.config.pair.as_str())
                .set("method", engine.config.method.as_str())
                .set("backend", engine.config.backend.label())
                .set("mode", engine.config.mode.label())
                .set("workers", engine.config.workers)
                .set("slots", engine.config.slots)
                .set("max_batch", engine.config.verify_batch.max_batch)
                .set("max_queue", engine.config.max_queue)
                .set("prefix_cache", engine.config.prefix_cache);
            (200, o)
        }
        ("GET", "/metrics") => {
            let mut m = engine.metrics_json();
            if let Some(io) = io {
                m.set("io", io.to_json());
            }
            (200, m)
        }
        _ => {
            let mut o = Json::obj();
            o.set("error", "not found");
            (404, o)
        }
    }
}

/// Parse a /generate body into a ready-to-submit request plus the
/// client's streaming preference.
///
/// `header_tenant` is the `X-Tapout-Tenant` request header, the
/// out-of-band way to key the drafter/policy bandits per tenant
/// (docs/OPERATIONS.md). A `"tenant"` field in the JSON body wins over
/// the header; absent both, the request decodes under the global tenant
/// (the empty string — the exact pre-tenant path).
pub(crate) fn parse_generate(
    body: &str,
    header_tenant: Option<&str>,
) -> std::result::Result<(Request, bool), (u16, Json)> {
    let j = Json::parse(body).map_err(|e| {
        let mut o = Json::obj();
        o.set("error", format!("bad json: {e}"));
        (400, o)
    })?;
    let prompt = j.get("prompt").and_then(|x| x.as_str()).unwrap_or("");
    if prompt.is_empty() {
        let mut o = Json::obj();
        o.set("error", "missing prompt");
        return Err((400, o));
    }
    let max_new = j.get("max_new").and_then(|x| x.as_usize()).unwrap_or(96);
    let mut req = Request::new(0, prompt, max_new.min(256));
    let tenant = j
        .get("tenant")
        .and_then(|x| x.as_str())
        .or(header_tenant)
        .unwrap_or("");
    if !tenant.is_empty() {
        req = req.with_tenant(tenant);
    }
    let deadline_ms = j.get("deadline_ms").and_then(|x| x.as_usize()).filter(|&ms| ms > 0);
    if let Some(ms) = deadline_ms {
        req = req.with_deadline_ms(ms as u64);
    }
    let stream_mode = j.get("stream").and_then(|x| x.as_bool()).unwrap_or(false);
    Ok((req, stream_mode))
}

/// Map a terminal response to its HTTP status (docs/OPERATIONS.md).
fn status_code(status: FinishStatus) -> u16 {
    match status {
        FinishStatus::Done => 200,
        FinishStatus::Rejected => 429,
        FinishStatus::Expired => 504,
        FinishStatus::Failed | FinishStatus::Cancelled => 500,
    }
}

/// The successful-unary reply body.
fn unary_reply(resp: &Response) -> (u16, Json) {
    if resp.is_ok() {
        let mut o = Json::obj();
        o.set("id", resp.id as usize)
            .set("status", resp.status.label())
            .set("text", resp.text.as_str())
            .set("new_tokens", resp.result.new_tokens().len())
            .set("mean_accepted", resp.result.mean_accepted())
            .set("acceptance_rate", resp.result.acceptance_rate())
            .set("decode_ms", resp.result.wall_ns as f64 / 1e6)
            .set("tokens_per_sec", resp.tokens_per_sec());
        (200, o)
    } else {
        // explicit terminal state: rejected/expired/failed replies carry
        // their reason instead of dropping the waiter
        let mut o = Json::obj();
        o.set("id", resp.id as usize)
            .set("status", resp.status.label())
            .set("error", resp.error.as_deref().unwrap_or("decode failed"));
        (status_code(resp.status), o)
    }
}

/// One streaming tokens event body.
fn tokens_payload(ids: &[u32], text: &str) -> Json {
    let mut o = Json::obj();
    o.set("ids", ids.iter().map(|&t| Json::from(t)).collect::<Vec<Json>>()).set("text", text);
    o
}

/// The terminal streaming event body (`"done": true` + summary).
fn done_payload(resp: &Response) -> Json {
    let mut o = Json::obj();
    o.set("done", true)
        .set("id", resp.id as usize)
        .set("status", resp.status.label())
        .set("new_tokens", resp.result.new_tokens().len())
        .set("mean_accepted", resp.result.mean_accepted())
        .set("acceptance_rate", resp.result.acceptance_rate())
        .set("decode_ms", resp.result.wall_ns as f64 / 1e6);
    if let Some(e) = resp.error.as_deref() {
        o.set("error", e);
    }
    o
}

/// The plain-JSON reply for a stream that terminated before any tokens
/// (shed, expired in queue, failed) — sent instead of a 200 SSE stream.
fn pre_stream_reply(resp: &Response) -> Json {
    let mut o = Json::obj();
    o.set("id", resp.id as usize)
        .set("status", resp.status.label())
        .set("error", resp.error.as_deref().unwrap_or("request did not complete"));
    o
}

// ---------------------------------------------------------------------------
// reactor gateway — the engine behind the readiness loop
// ---------------------------------------------------------------------------

/// [`Gateway`] impl serving one engine (reactor front end).
struct EngineGateway {
    engine: Arc<Engine>,
    stats: Arc<IoStats>,
}

impl Gateway for EngineGateway {
    fn route(&self, method: &str, path: &str, _body: &str) -> (u16, String) {
        let (code, j) = route(&self.engine, method, path, Some(&self.stats));
        (code, j.render())
    }

    fn generate(&self, body: &str, tenant: Option<&str>) -> GenerateStart {
        match parse_generate(body, tenant) {
            Err((code, j)) => GenerateStart::Immediate { code, body: j.render() },
            Ok((req, stream_mode)) => {
                let cancel = req.cancel_flag();
                if stream_mode {
                    let rx = self.engine.submit_request_streaming(req);
                    GenerateStart::Source(Box::new(StreamSource {
                        rx,
                        cancel,
                        started: false,
                        finished: false,
                        queued: VecDeque::new(),
                    }))
                } else {
                    let rx = self.engine.submit_request(req);
                    GenerateStart::Source(Box::new(UnarySource {
                        rx,
                        cancel,
                        deadline: Instant::now() + UNARY_TIMEOUT,
                        finished: false,
                    }))
                }
            }
        }
    }
}

/// Non-blocking view of a unary reply channel: one `Reply` event when
/// the response (or the front-end timeout) arrives.
struct UnarySource {
    rx: Receiver<Response>,
    cancel: CancelFlag,
    deadline: Instant,
    finished: bool,
}

impl EventSource for UnarySource {
    fn poll_event(&mut self) -> Option<SourceEvent> {
        if self.finished {
            return None;
        }
        match self.rx.try_recv() {
            Ok(resp) => {
                self.finished = true;
                let (code, j) = unary_reply(&resp);
                Some(SourceEvent::Reply { code, body: j.render() })
            }
            Err(TryRecvError::Empty) => {
                if Instant::now() < self.deadline {
                    return None;
                }
                // give up on the decode, not just the reply: without the
                // cancel the worker would keep burning its KV slot on a
                // request nobody is waiting for
                self.finished = true;
                self.cancel.cancel();
                Some(SourceEvent::Reply {
                    code: 500,
                    body: err_body("generation timed out or failed"),
                })
            }
            Err(TryRecvError::Disconnected) => {
                // same reply the blocking path's recv_timeout Err arm gives
                self.finished = true;
                self.cancel.cancel();
                Some(SourceEvent::Reply {
                    code: 500,
                    body: err_body("generation timed out or failed"),
                })
            }
        }
    }

    fn cancel(&mut self) {
        self.cancel.cancel();
    }
}

/// Non-blocking view of a streaming reply channel. The status line is
/// held back until the first engine event: a request that terminates
/// before any tokens (shed, expired in queue, failed) yields a plain
/// `Reply` (429/504/500) instead of a 200 SSE stream — exactly the
/// blocking path's contract.
struct StreamSource {
    rx: Receiver<StreamEvent>,
    cancel: CancelFlag,
    started: bool,
    finished: bool,
    queued: VecDeque<SourceEvent>,
}

impl StreamSource {
    fn push_event(&mut self, ev: StreamEvent) {
        match ev {
            StreamEvent::Tokens { ids, text, .. } => {
                self.queued.push_back(SourceEvent::Data(tokens_payload(&ids, &text).render()));
            }
            StreamEvent::Done(resp) => {
                self.queued.push_back(SourceEvent::Data(done_payload(&resp).render()));
                self.queued.push_back(SourceEvent::End);
                self.finished = true;
            }
        }
    }
}

impl EventSource for StreamSource {
    fn poll_event(&mut self) -> Option<SourceEvent> {
        if let Some(ev) = self.queued.pop_front() {
            return Some(ev);
        }
        if self.finished {
            return None;
        }
        match self.rx.try_recv() {
            Ok(ev) => {
                if !self.started {
                    if let StreamEvent::Done(resp) = &ev {
                        if resp.status != FinishStatus::Done {
                            self.finished = true;
                            return Some(SourceEvent::Reply {
                                code: status_code(resp.status),
                                body: pre_stream_reply(resp).render(),
                            });
                        }
                    }
                    self.started = true;
                    self.push_event(ev);
                    return Some(SourceEvent::StreamStart);
                }
                self.push_event(ev);
                self.queued.pop_front()
            }
            Err(TryRecvError::Empty) => None,
            Err(TryRecvError::Disconnected) => {
                self.finished = true;
                if self.started {
                    // engine side hung up without a Done event (shutdown)
                    Some(SourceEvent::End)
                } else {
                    Some(SourceEvent::Reply { code: 500, body: err_body("engine unavailable") })
                }
            }
        }
    }

    fn cancel(&mut self) {
        self.cancel.cancel();
    }
}

// ---------------------------------------------------------------------------
// blocking front end (parity baseline)
// ---------------------------------------------------------------------------

/// Is this read error a socket read-timeout (slow-loris deadline on the
/// blocking path, relay tick on the router's proxy path)?
pub(crate) fn is_timeout(e: &std::io::Error) -> bool {
    matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut)
}

/// Arm the socket's read timeout with the time left until `deadline`;
/// false when the window is already spent.
fn arm_deadline(stream: &TcpStream, deadline: Instant) -> bool {
    let rem = deadline.saturating_duration_since(Instant::now());
    !rem.is_zero() && stream.set_read_timeout(Some(rem)).is_ok()
}

fn handle_conn(
    stream: TcpStream,
    engine: &Engine,
    stats: &IoStats,
    cfg: &HttpConfig,
) -> Result<()> {
    // slow-loris bound: the whole request (headers + body) must arrive
    // within header_timeout_ms, enforced via the socket read timeout
    let deadline = Instant::now() + Duration::from_millis(cfg.header_timeout_ms.max(1));
    let timed_out = |stats: &IoStats, stream: TcpStream| {
        stats.read_timeouts.fetch_add(1, Ordering::Relaxed);
        respond(stream, 408, &err_body("request read timed out"))
    };
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut line = String::new();
    if !arm_deadline(&stream, deadline) {
        return timed_out(stats, stream);
    }
    match reader.read_line(&mut line) {
        Ok(_) => {}
        Err(e) if is_timeout(&e) => return timed_out(stats, stream),
        Err(e) => return Err(e.into()),
    }
    let mut parts = line.split_whitespace();
    let method = parts.next().unwrap_or("").to_string();
    let path = parts.next().unwrap_or("/").to_string();

    // headers — field names are matched case-insensitively per RFC 9110
    // §5.1 (clients legitimately send `content-length`, `Content-Length`,
    // or any mix; an exact-case match silently drops their body length)
    let mut content_length: Option<usize> = None;
    let mut bad_length: Option<String> = None;
    let mut chunked = false;
    let mut header_tenant: Option<String> = None;
    loop {
        let mut h = String::new();
        if !arm_deadline(&stream, deadline) {
            return timed_out(stats, stream);
        }
        match reader.read_line(&mut h) {
            Ok(_) => {}
            Err(e) if is_timeout(&e) => return timed_out(stats, stream),
            Err(e) => return Err(e.into()),
        }
        let h = h.trim();
        if h.is_empty() {
            break;
        }
        if let Some((name, value)) = h.split_once(':') {
            let (name, value) = (name.trim(), value.trim());
            if name.eq_ignore_ascii_case("content-length") {
                match value.parse() {
                    Ok(n) => content_length = Some(n),
                    // present but unparseable is a framing error (400),
                    // distinct from the header being absent (411)
                    Err(_) => bad_length = Some(value.to_string()),
                }
            } else if name.eq_ignore_ascii_case("transfer-encoding") {
                chunked = value.to_ascii_lowercase().contains("chunked");
            } else if name.eq_ignore_ascii_case("x-tapout-tenant") {
                header_tenant = Some(value.to_string());
            }
        }
    }

    // body-framing contract for routes that need a body (RFC 9110):
    // chunked transfer coding is not implemented here — a chunked body
    // read as `content-length` bytes would be garbage, so refuse it
    // explicitly with 501; a POST with no length at all is 411 Length
    // Required, not a misleading "bad json" 400 over an empty body
    if method == "POST" && path == "/generate" {
        if chunked {
            let (code, body) = framing_chunked();
            return respond(stream, code, &body);
        }
        if let Some(bad) = bad_length {
            let (code, body) = framing_bad_length(&bad);
            return respond(stream, code, &body);
        }
        if content_length.is_none() {
            let (code, body) = framing_length_required();
            return respond(stream, code, &body);
        }
    }
    let content_length = content_length.unwrap_or(0);

    // over-size bodies are refused up front — never silently truncated
    // into confusing JSON decode errors (docs/OPERATIONS.md)
    if content_length > MAX_BODY_BYTES {
        let (code, body) = framing_too_large(content_length);
        return respond(stream, code, &body);
    }

    // read the full declared body; read_exact loops over short reads, so
    // a body split across TCP segments reassembles correctly, and a
    // connection that closes early is an explicit 400 instead of a
    // truncated-JSON decode error
    let mut body = vec![0u8; content_length];
    if content_length > 0 {
        if !arm_deadline(&stream, deadline) {
            return timed_out(stats, stream);
        }
        match reader.read_exact(&mut body) {
            Ok(()) => {}
            Err(e) if is_timeout(&e) => return timed_out(stats, stream),
            Err(e) => {
                let mut o = Json::obj();
                o.set(
                    "error",
                    format!("body ended before content-length ({content_length}): {e}"),
                );
                return respond(stream, 400, &o.render());
            }
        }
    }
    let _ = stream.set_read_timeout(None);
    let body = String::from_utf8_lossy(&body).to_string();
    stats.requests.fetch_add(1, Ordering::Relaxed);

    // streaming generate owns the raw stream (chunked SSE writes)
    if method == "POST" && path == "/generate" {
        match parse_generate(&body, header_tenant.as_deref()) {
            Ok((req, stream_mode)) => {
                return if stream_mode {
                    stream_generate(stream, engine, req, stats, cfg)
                } else {
                    let (status, payload) = unary_generate(engine, req);
                    respond(stream, status, &payload.render())
                };
            }
            Err((status, payload)) => return respond(stream, status, &payload.render()),
        }
    }

    let (status, payload) = route(engine, &method, &path, Some(stats));
    respond(stream, status, &payload.render())
}

fn unary_generate(engine: &Engine, req: Request) -> (u16, Json) {
    let cancel = req.cancel_flag();
    let rx = engine.submit_request(req);
    match rx.recv_timeout(UNARY_TIMEOUT) {
        Ok(resp) => unary_reply(&resp),
        Err(_) => {
            // give up on the decode, not just the reply: without the
            // cancel the worker would keep burning its KV slot on a
            // request nobody is waiting for
            cancel.cancel();
            let mut o = Json::obj();
            o.set("error", "generation timed out or failed");
            (500, o)
        }
    }
}

/// Serve one streaming generate: chunked transfer, one SSE `data:` event
/// per committed round, a final `data:` event with the summary. A write
/// failure (client gone) cancels the request via its shared flag.
///
/// The status line is held back until the first engine event: a request
/// that terminates before any tokens (shed, expired in queue, failed)
/// gets the documented plain-JSON error reply (429/504/500) instead of
/// a 200 SSE stream. Once tokens have flowed, the terminal status
/// arrives in-band in the final `data:` event.
fn stream_generate(
    mut stream: TcpStream,
    engine: &Engine,
    req: Request,
    stats: &IoStats,
    cfg: &HttpConfig,
) -> Result<()> {
    let cancel = req.cancel_flag();
    let rx = engine.submit_request_streaming(req);
    let first = match rx.recv() {
        Ok(ev) => ev,
        Err(_) => {
            return respond(stream, 500, &err_body("engine unavailable"));
        }
    };
    if let StreamEvent::Done(resp) = &first {
        if resp.status != FinishStatus::Done {
            return respond(stream, status_code(resp.status), &pre_stream_reply(resp).render());
        }
    }
    stream.write_all(SSE_HEADERS.as_bytes())?;
    let keepalive = Duration::from_millis(cfg.sse_keepalive_ms.max(1));
    let mut pending = Some(first);
    loop {
        let event = match pending.take() {
            Some(ev) => Ok(ev),
            None => match rx.recv_timeout(keepalive) {
                Ok(ev) => Ok(ev),
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
                    // long-silent stream: SSE comment so intermediaries
                    // don't reap the connection
                    stats.keepalives.fetch_add(1, Ordering::Relaxed);
                    if write_chunk(&mut stream, &sse_comment_frame("ping")).is_err() {
                        cancel.cancel();
                        stats.write_cancels.fetch_add(1, Ordering::Relaxed);
                        return Ok(());
                    }
                    continue;
                }
                Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => Err(()),
            },
        };
        match event {
            Ok(StreamEvent::Tokens { ids, text, .. }) => {
                let frame = sse_frame(&tokens_payload(&ids, &text).render());
                if write_chunk(&mut stream, &frame).is_err() {
                    // client disconnected: cancel and stop reading; the
                    // worker sees the flag at the next round boundary
                    cancel.cancel();
                    stats.write_cancels.fetch_add(1, Ordering::Relaxed);
                    return Ok(());
                }
            }
            Ok(StreamEvent::Done(resp)) => {
                let frame = sse_frame(&done_payload(&resp).render());
                let _ = write_chunk(&mut stream, &frame);
                // terminating zero-length chunk ends the response
                let _ = stream.write_all(b"0\r\n\r\n");
                let _ = stream.flush();
                return Ok(());
            }
            Err(()) => {
                // engine side hung up without a Done event (shutdown)
                let _ = stream.write_all(b"0\r\n\r\n");
                return Ok(());
            }
        }
    }
}

/// Write one pre-framed HTTP chunk and flush it.
fn write_chunk(stream: &mut TcpStream, frame: &str) -> std::io::Result<()> {
    stream.write_all(frame.as_bytes())?;
    stream.flush()
}

fn respond(mut stream: TcpStream, status: u16, body: &str) -> Result<()> {
    stream.write_all(plain_response(status, body).as_bytes())?;
    stream.flush()?;
    Ok(())
}
