//! Minimal HTTP/1.1 JSON API over std::net (offline stand-in for a web
//! framework). Routes:
//!
//!   GET  /health              -> {"ok": true, ...}
//!   GET  /metrics             -> serving metrics + per-worker stats +
//!                                shared-bandit state
//!   POST /generate            -> {"prompt": "...", "max_new": 64}
//!
//! One thread per connection; decoding parallelism comes from the
//! engine's worker pool (server.rs), and decode failures surface as a
//! 500 with an error body.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

use anyhow::Result;

use crate::util::Json;

use super::server::Engine;

/// The background HTTP listener (one thread per connection).
pub struct HttpServer {
    /// bound address, e.g. `127.0.0.1:8077`
    pub addr: String,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl HttpServer {
    /// Bind and serve in background threads. Port 0 picks a free port.
    pub fn start(engine: Arc<Engine>, port: u16) -> Result<HttpServer> {
        let listener = TcpListener::bind(("127.0.0.1", port))?;
        let addr = listener.local_addr()?.to_string();
        let handle = std::thread::Builder::new()
            .name("tapout-http".into())
            .spawn(move || {
                for stream in listener.incoming() {
                    let Ok(stream) = stream else { continue };
                    let eng = engine.clone();
                    std::thread::spawn(move || {
                        let _ = handle_conn(stream, &eng);
                    });
                }
            })?;
        Ok(HttpServer { addr, handle: Some(handle) })
    }
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        // listener thread exits with the process; detach
        if let Some(h) = self.handle.take() {
            drop(h);
        }
    }
}

fn handle_conn(stream: TcpStream, engine: &Engine) -> Result<()> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let mut parts = line.split_whitespace();
    let method = parts.next().unwrap_or("").to_string();
    let path = parts.next().unwrap_or("/").to_string();

    // headers
    let mut content_length = 0usize;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h)?;
        let h = h.trim();
        if h.is_empty() {
            break;
        }
        if let Some(v) = h.to_ascii_lowercase().strip_prefix("content-length:") {
            content_length = v.trim().parse().unwrap_or(0);
        }
    }
    let mut body = vec![0u8; content_length.min(1 << 20)];
    if content_length > 0 {
        reader.read_exact(&mut body)?;
    }
    let body = String::from_utf8_lossy(&body).to_string();

    let (status, payload) = route(engine, &method, &path, &body);
    respond(stream, status, &payload.render())
}

fn route(engine: &Engine, method: &str, path: &str, body: &str) -> (u16, Json) {
    match (method, path) {
        ("GET", "/health") => {
            let mut o = Json::obj();
            o.set("ok", true)
                .set("pair", engine.config.pair.as_str())
                .set("method", engine.config.method.as_str())
                .set("backend", engine.config.backend.label())
                .set("workers", engine.config.workers)
                .set("slots", engine.config.slots)
                .set("max_batch", engine.config.verify_batch.max_batch);
            (200, o)
        }
        ("GET", "/metrics") => (200, engine.metrics_json()),
        ("POST", "/generate") => match Json::parse(body) {
            Ok(req) => {
                let prompt = req.get("prompt").and_then(|x| x.as_str()).unwrap_or("");
                if prompt.is_empty() {
                    let mut o = Json::obj();
                    o.set("error", "missing prompt");
                    return (400, o);
                }
                let max_new = req.get("max_new").and_then(|x| x.as_usize()).unwrap_or(96);
                let rx = engine.submit(prompt, max_new.min(256));
                match rx.recv_timeout(std::time::Duration::from_secs(120)) {
                    Ok(resp) if resp.is_ok() => {
                        let mut o = Json::obj();
                        o.set("id", resp.id as usize)
                            .set("text", resp.text.as_str())
                            .set("new_tokens", resp.result.new_tokens().len())
                            .set("mean_accepted", resp.result.mean_accepted())
                            .set("acceptance_rate", resp.result.acceptance_rate())
                            .set("decode_ms", resp.result.wall_ns as f64 / 1e6)
                            .set("tokens_per_sec", resp.tokens_per_sec());
                        (200, o)
                    }
                    Ok(resp) => {
                        // explicit decode failure: the worker replied with
                        // an error body instead of dropping the waiter
                        let mut o = Json::obj();
                        o.set("id", resp.id as usize)
                            .set("error", resp.error.as_deref().unwrap_or("decode failed"));
                        (500, o)
                    }
                    Err(_) => {
                        let mut o = Json::obj();
                        o.set("error", "generation timed out or failed");
                        (500, o)
                    }
                }
            }
            Err(e) => {
                let mut o = Json::obj();
                o.set("error", format!("bad json: {e}"));
                (400, o)
            }
        },
        _ => {
            let mut o = Json::obj();
            o.set("error", "not found");
            (404, o)
        }
    }
}

fn respond(mut stream: TcpStream, status: u16, body: &str) -> Result<()> {
    let reason = match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        _ => "Internal Server Error",
    };
    write!(
        stream,
        "HTTP/1.1 {status} {reason}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    )?;
    stream.flush()?;
    Ok(())
}
