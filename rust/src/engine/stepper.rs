//! Continuous-batching execution core (docs/ARCHITECTURE.md §11).
//!
//! The Workers engine is thread-per-request: each decode owns its slot's
//! draft model, so draft forwards — the majority of kernel dispatches in
//! Algorithm 1 — never batch across sessions, and concurrency is capped
//! by worker threads. This module replaces that pool with one
//! iteration-level step loop (the vLLM-style execution model BanditSpec
//! and Not-a-Bandit evaluate inside): a single thread owns every
//! in-flight session and, each iteration,
//!
//! ```text
//!   ┌─▶ retire    finished / cancelled / expired / failed sessions
//!   │             (terminal reply, slot freed, ledger released)
//!   │   admit     scheduler → free KV slots, mid-flight
//!   │   draft     batched micro-rounds over ALL drafting sessions:
//!   │             one `draft_batch` per proposal position; sessions
//!   │             drop out as their arm's stop rule fires (ragged)
//!   │   verify    one window-free `block_batch` over every session —
//!   │             the step loop IS the batching window
//!   └── commit    accept/bonus per session, stream, bandit reward
//! ```
//!
//! **Correctness.** Each session's round is the exact round of
//! [`SpecSession::step`](crate::spec::SpecSession::step), re-sequenced
//! across sessions: the stop decisions (`DecodeControl::should_stop`
//! after every drafted token, short-circuited at γ), the accept rule
//! ([`spec::accept_greedy`](crate::spec::accept_greedy)), the
//! termination check ([`spec::finish_check`](crate::spec::finish_check)),
//! and the cursor protocol (catch-up to `c`, k−1 single-token feeds,
//! rollback to `c+m`) are the same code or the same formulas, and
//! batched rows are byte-identical to sequential rows (models/sim.rs,
//! models/pjrt.rs). Greedy speculative decoding is lossless, so outputs
//! match the Workers engine and the greedy oracle byte-for-byte at any
//! slot count — pinned by `rust/tests/engine_continuous.rs`.
//!
//! **Bandit accounting.** One `session_start` (select) and one
//! `on_verify` (reward) per session per round, exactly as in Workers
//! mode, so shared-bandit play-count conservation holds across execution
//! modes. Controllers are per *slot* here (one decode thread), not per
//! worker. The drafter-pool layer (docs/ARCHITECTURE.md §17) rides the
//! same cadence: one `DrafterHook::begin_round` right before each
//! policy select, one `settle_verify` (full-information scores over the
//! round's accepted tokens) right after each policy reward, and a
//! `settle_abort` wherever the policy layer absorbs an abort — so
//! rounds == policy plays == drafter plays in every configuration.
//!
//! **Lifecycle.** Cancellation flags, deadlines, and gone stream
//! receivers are observed at iteration boundaries — the same round
//! granularity the Workers engine polls at — and a retiring session
//! frees its KV slot within one iteration.
//!
//! **Pipelining (docs/ARCHITECTURE.md §16).** With `--pipeline`, each
//! verify chunk is *submitted* ([`LanguageModel::submit_batch`]) and,
//! while the forward is in flight, the stepper speculatively pre-drafts
//! every chunk session's next catch-up position under the
//! full-acceptance assumption ([`LanguageModel::speculate_batch`], one
//! row per session: the last proposal fed at the draft cursor). On
//! commit the pre-draft is *adopted* — the draft cursor advances to
//! `c+k`, so the next round's catch-up feeds one fewer token — exactly
//! when verification accepted every proposal (`m == k`); otherwise it is
//! *discarded* and the normal cursor rollback makes the next catch-up
//! re-draft the position. The speculative rows' values are never read
//! (the serialized loop ignores that row too: only the catch-up's final
//! row seeds a proposal), so outputs are byte-identical pipeline on or
//! off, and discarded work never touches bandit plays, rewards, the SJF
//! ledger, or page refcounts — it is visible only in `engine.pipeline`.

use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::bandit::{DrafterHook, SessionController};
use crate::models::{BatchItem, LanguageModel, ModelCost};
use crate::spec::{
    accept_greedy, finish_check, validate_prompt, DecodeControl, GenConfig, GenResult, RoundStat,
};
use crate::util::Rng;

use super::metrics::{EngineMetrics, EngineStats};
use super::request::{EmitClip, FinishStatus, Request, Response};
use super::server::{finish_response, note_lifecycle, EngineShared, ResponseSink};
use super::slots::Slot;

/// Pages per chunked-prefill feed (docs/ARCHITECTURE.md §13): a session
/// whose remaining catch-up exceeds this many pages streams one
/// page-aligned chunk through the batched executors per iteration
/// instead of joining a decode round, so one long prompt never stalls
/// every other session behind a monolithic prefill. The fed rows are
/// discarded (prefill only populates KV), so outputs are byte-identical
/// to the monolithic catch-up; chunked iterations are *not* speculation
/// rounds — no bandit select or reward fires (play-count conservation).
const PREFILL_CHUNK_PAGES: usize = 8;

/// One in-flight decode held by the step loop: the request, its KV slot,
/// and the session state [`SpecSession`](crate::spec::SpecSession) would
/// keep — plus the per-round scratch the phased (draft-batch / verify)
/// execution needs between micro-rounds.
struct ActiveSession {
    req: Request,
    sink: ResponseSink,
    slot: Slot,
    cfg: GenConfig,
    clip: EmitClip,
    /// cached `Request::scenario_seed` (a prompt hash — computed once,
    /// stamped on every `BatchItem`)
    seed: u64,
    /// drafter-pool selection handle (docs/ARCHITECTURE.md §17), bound
    /// to this request's tenant; settles exactly one play per round
    hook: DrafterHook,
    /// the drafter `hook.begin_round` selected for the current round —
    /// stamped on every draft `BatchItem` (verify rows ignore it)
    drafter: usize,
    /// arrival → decode start (admission), the reply's queue_ns
    queue_ns: u64,
    /// decode start (wall_ns base)
    t_decode: Instant,
    committed: Vec<u32>,
    prompt_len: usize,
    rounds: Vec<RoundStat>,
    /// mirrored draft-model cursor (the contiguous-cursor protocol,
    /// docs/ARCHITECTURE.md §6, tracked engine-side exactly like
    /// `BatchedTarget` does for the verify side). Starts at the
    /// cache-hit reuse length (docs/ARCHITECTURE.md §12), so the first
    /// catch-up block prefills only the prompt suffix.
    draft_cur: usize,
    /// mirrored target/verifier cursor (same cache-hit starting point)
    target_cur: usize,
    /// prompt positions skipped via prefix reuse (reply accounting)
    cached: usize,
    max_seq: usize,
    /// reply fully determined (natural finish or clip window closed)
    done: bool,
    /// decode error — retired as a `Failed` reply next iteration
    failed: Option<String>,
    // --- per-round scratch ---
    /// committed length at round start (`c` in SpecSession::step)
    round_c: usize,
    /// this round's draft cap γ (per-arm raggedness comes from stop
    /// rules firing at different positions per session)
    gamma: usize,
    proposals: Vec<u32>,
    /// last drafted token (the next micro-round's single-token input)
    last_tok: u32,
    draft_ns: u64,
    verify_ns: u64,
}

/// Terminal state a session retires with (priority-ordered: an errored
/// round beats everything; a fully determined reply beats a cancel that
/// landed in the same iteration, matching `drive_session`).
enum SessionExit {
    Failed(String),
    Complete,
    Cancelled,
    Expired,
}

fn exit_of(s: &ActiveSession) -> Option<SessionExit> {
    if let Some(e) = &s.failed {
        return Some(SessionExit::Failed(e.clone()));
    }
    if s.done {
        return Some(SessionExit::Complete);
    }
    if s.req.cancel.is_cancelled() {
        return Some(SessionExit::Cancelled);
    }
    if s.req.deadline_expired() {
        return Some(SessionExit::Expired);
    }
    None
}

/// The continuous-batching step loop: runs on one dedicated thread
/// (`tapout-stepper`) for the life of the engine. `controllers` is
/// indexed by slot id; `verify_cap` caps one verify `block_batch` (0 =
/// per-session verification, the batching-off oracle); `pipeline`
/// enables the overlapped draft/verify path (docs/ARCHITECTURE.md §16).
#[allow(clippy::too_many_arguments)]
pub(crate) fn step_loop(
    shared: Arc<EngineShared>,
    mut drafter: Box<dyn LanguageModel>,
    mut verifier: Box<dyn LanguageModel>,
    mut controllers: Vec<SessionController>,
    verify_cap: usize,
    pipeline: bool,
    metrics: Arc<Mutex<EngineMetrics>>,
    stats: Arc<EngineStats>,
) {
    let mut rng = Rng::new(0xE46C0DE ^ 0x57E9);
    let mut sessions: Vec<ActiveSession> = Vec::new();
    let mut scratch = RoundScratch::default();
    let max_seq = drafter.max_seq().min(verifier.max_seq());

    loop {
        retire(&mut sessions, &shared, &metrics, &stats);
        let admitted = admit(&mut sessions, &shared, &metrics, &stats, max_seq);

        if sessions.is_empty() {
            // park until new work arrives; queued work drains even after
            // shutdown is flagged (same contract as the worker pool)
            let mut q = shared.q.lock().unwrap();
            loop {
                if !q.sched.is_empty() {
                    break;
                }
                if q.shutdown {
                    return;
                }
                q = shared.cv.wait(q).unwrap();
            }
            continue;
        }

        let t_busy = Instant::now();
        let stepped = run_round(
            &mut sessions,
            &mut controllers,
            drafter.as_mut(),
            verifier.as_mut(),
            verify_cap,
            pipeline,
            &mut rng,
            &shared,
            &stats,
            &mut scratch,
        );
        stats.workers[0]
            .busy_ns
            .fetch_add(t_busy.elapsed().as_nanos() as u64, Ordering::Relaxed);
        if scratch.allocs > 0 {
            stats.step.scratch_allocs.fetch_add(scratch.allocs, Ordering::Relaxed);
            scratch.allocs = 0;
        }
        if stepped > 0 || admitted > 0 {
            stats.step.note_step(stepped, admitted);
        }
    }
}

/// Answer and unwind every session that reached a terminal state:
/// terminal reply through its sink, KV slot back to the pool, scheduler
/// ledger released — all within one iteration of the exit condition.
fn retire(
    sessions: &mut Vec<ActiveSession>,
    shared: &EngineShared,
    metrics: &Mutex<EngineMetrics>,
    stats: &EngineStats,
) {
    if sessions.iter().all(|s| exit_of(s).is_none()) {
        return;
    }
    let mut keep = Vec::with_capacity(sessions.len());
    for s in sessions.drain(..) {
        match exit_of(&s) {
            None => keep.push(s),
            Some(exit) => finalize(s, exit, shared, metrics, stats),
        }
    }
    *sessions = keep;
}

fn finalize(
    s: ActiveSession,
    exit: SessionExit,
    shared: &EngineShared,
    metrics: &Mutex<EngineMetrics>,
    stats: &EngineStats,
) {
    let ActiveSession {
        req,
        sink,
        mut slot,
        committed,
        prompt_len,
        rounds,
        t_decode,
        queue_ns,
        draft_cur,
        target_cur,
        cached,
        ..
    } = s;
    let result = GenResult {
        tokens: committed,
        prompt_len,
        rounds,
        wall_ns: t_decode.elapsed().as_nanos() as u64,
        cached_prefix: cached,
    };
    // record the slot's resident prefix for affinity routing
    // (docs/ARCHITECTURE.md §12): the committed sequence truncated to the
    // lower mirrored cursor — exactly what the shared executors' resident
    // worlds for this slot id cover. A failed session leaves that state
    // untrusted, so the record is cleared and the next tenant resets.
    // With the cache off nothing records — release would drop it anyway.
    if shared.pool.prefix_cache_enabled() {
        match &exit {
            SessionExit::Failed(_) => slot.clear_prefix(),
            _ => slot.record_prefix(&result.tokens, draft_cur.min(target_cur)),
        }
    }
    shared.q.lock().unwrap().sched.note_done(req.sched_cost());
    stats.step.retired.fetch_add(1, Ordering::Relaxed);
    stats.workers[0].requests.fetch_add(1, Ordering::Relaxed);
    let resp = match exit {
        SessionExit::Complete => {
            finish_response(shared, &req, result, FinishStatus::Done, None, queue_ns)
        }
        SessionExit::Cancelled => {
            note_lifecycle(stats, FinishStatus::Cancelled);
            finish_response(
                shared,
                &req,
                result,
                FinishStatus::Cancelled,
                Some("cancelled mid-decode".into()),
                queue_ns,
            )
        }
        SessionExit::Expired => {
            note_lifecycle(stats, FinishStatus::Expired);
            finish_response(
                shared,
                &req,
                result,
                FinishStatus::Expired,
                Some("deadline expired mid-decode".into()),
                queue_ns,
            )
        }
        SessionExit::Failed(e) => {
            eprintln!("[engine] request {} failed: {e}", req.id);
            stats.workers[0].errors.fetch_add(1, Ordering::Relaxed);
            Response::failure(req.id, queue_ns, req.arrival.elapsed().as_nanos() as u64, e)
        }
    };
    {
        let mut m = metrics.lock().unwrap();
        m.record(&resp);
        m.span_ns = shared.started.lock().unwrap().elapsed().as_nanos() as u64;
    }
    sink.send_final(resp);
    shared.pool.release(slot);
}

/// Pop scheduled requests into free KV slots — iteration-level admission
/// straight from the scheduler, so a request admitted mid-flight joins
/// the very next round (its first round is its prefill). Returns the
/// number of sessions admitted.
fn admit(
    sessions: &mut Vec<ActiveSession>,
    shared: &EngineShared,
    metrics: &Mutex<EngineMetrics>,
    stats: &EngineStats,
    max_seq: usize,
) -> usize {
    let mut admitted = 0;
    // the stepper is the pool's only consumer, so a free slot observed
    // here cannot be taken by anyone else
    while shared.pool.available() > 0 {
        let popped = {
            let mut q = shared.q.lock().unwrap();
            match q.sched.pop() {
                Some(req) => {
                    stats.note_depth(q.sched.len());
                    let sink = q.waiters.remove(&req.id);
                    Some((req, sink))
                }
                None => None,
            }
        };
        let Some((mut req, sink)) = popped else { break };
        let Some(sink) = sink else {
            // no waiter registered (should not happen) — release the
            // scheduler's in-flight ledger entry
            shared.q.lock().unwrap().sched.note_done(req.sched_cost());
            continue;
        };
        // lifecycle checks before occupying a slot (same exits as the
        // worker pool's slot-wait loop)
        let now_ns = req.arrival.elapsed().as_nanos() as u64;
        if req.cancel.is_cancelled() {
            shared.q.lock().unwrap().sched.note_done(req.sched_cost());
            note_lifecycle(stats, FinishStatus::Cancelled);
            sink.send_final(Response::terminal(
                req.id,
                FinishStatus::Cancelled,
                now_ns,
                now_ns,
                "cancelled before decode",
            ));
            continue;
        }
        if req.deadline_expired() {
            shared.q.lock().unwrap().sched.note_done(req.sched_cost());
            note_lifecycle(stats, FinishStatus::Expired);
            sink.send_final(Response::terminal(
                req.id,
                FinishStatus::Expired,
                now_ns,
                now_ns,
                "deadline expired before decode",
            ));
            continue;
        }
        // prompt validation — the same spec::validate_prompt the worker
        // path hits inside SpecSession::new, so a rejected prompt fails
        // with the identical message in both execution modes
        if let Err(e) = validate_prompt(&req.prompt, max_seq) {
            let msg = format!("{e:#}");
            shared.q.lock().unwrap().sched.note_done(req.sched_cost());
            stats.workers[0].errors.fetch_add(1, Ordering::Relaxed);
            let resp = Response::failure(req.id, now_ns, now_ns, msg);
            {
                let mut m = metrics.lock().unwrap();
                m.record(&resp);
            }
            sink.send_final(resp);
            continue;
        }
        // affinity checkout (docs/ARCHITECTURE.md §12–§13): route to the
        // slot with the deepest leased residency for this prompt — the
        // slot's own resident prefix, or (page sharing) another, still
        // busy slot's prefix pages mapped copy-on-write. In continuous
        // mode the resident per-sequence state lives with the shared
        // batched drafter/verifier keyed by the slot id, so the leased
        // depth simply seeds both mirrored cursors — the first catch-up /
        // verification blocks then start at the divergence point and the
        // executors align their resident worlds to it. `lease.shared`
        // exceeds `lease.local` only when the pool probed the backend as
        // adoptive (content-addressed KV), exactly when the shared
        // executors can resume at positions another sequence computed.
        let (slot, lease) =
            shared.pool.try_acquire_for(&req.prompt).expect("available slot observed above");
        let resident = lease.shared;
        // the dispatcher's `cached_hint` was advisory — re-resolve it
        // against the granted lease and reprice the SJF in-flight ledger
        // so the retire-time `note_done` releases exactly what is charged
        if req.cached_hint != resident {
            let stale = req.sched_cost();
            req.cached_hint = resident;
            shared.q.lock().unwrap().sched.reprice(stale, req.sched_cost());
        }
        let queue_ns = req.arrival.elapsed().as_nanos() as u64;
        let cfg = GenConfig {
            max_new: req.max_new,
            gamma_max: shared.gamma_max,
            stop_at_eos: true,
            collect_signals: false,
        };
        let clip = EmitClip::new(req.max_new);
        let committed = req.prompt.clone();
        let prompt_len = committed.len();
        let seed = req.scenario_seed();
        let hook = DrafterHook::new(
            shared.drafters.clone(),
            req.tenant.clone(),
            seed,
            req.category.clone(),
        );
        sessions.push(ActiveSession {
            req,
            sink,
            slot,
            cfg,
            clip,
            seed,
            hook,
            drafter: 0,
            queue_ns,
            t_decode: Instant::now(),
            committed,
            prompt_len,
            rounds: Vec::new(),
            draft_cur: resident,
            target_cur: resident,
            cached: resident,
            max_seq,
            done: false,
            failed: None,
            round_c: 0,
            gamma: 0,
            proposals: Vec::new(),
            last_tok: 0,
            draft_ns: 0,
            verify_ns: 0,
        });
        admitted += 1;
    }
    admitted
}

/// Mark every listed session failed — one backend error inside a batched
/// forward answers every participating session explicitly, exactly as
/// the worker engine's batcher does.
fn fail_all(sessions: &mut [ActiveSession], idxs: &[usize], msg: &str) {
    for &i in idxs {
        sessions[i].failed = Some(msg.to_string());
    }
}

fn note_draft(stats: &EngineStats, after: ModelCost, before: ModelCost, n_sessions: usize) {
    stats.draft.note(
        n_sessions,
        after.calls.saturating_sub(before.calls),
        after.rows.saturating_sub(before.rows),
        after.padded_rows.saturating_sub(before.padded_rows),
    );
}

/// Reusable hot-path buffers for [`run_round`], living across iterations
/// in [`step_loop`] so a steady-state engine refills rather than
/// reallocates: `BatchItem` rows keep their token `Vec`s and `category`
/// `String`s, index vectors keep their capacity. `allocs` counts actual
/// buffer growths (the churn gauge flushed into
/// `StepStats::scratch_allocs`, asserted flat across warm identical
/// bursts by the bench).
#[derive(Default)]
struct RoundScratch {
    /// batch rows for catch-up / micro-round / prefill / verify feeds
    items: Vec<BatchItem>,
    /// batch rows for the speculative pre-draft (built while `items`
    /// still holds the submitted verify chunk)
    spec_items: Vec<BatchItem>,
    /// sessions in this round (index into `sessions`)
    live: Vec<usize>,
    /// sessions still drafting this micro-round
    drafting: Vec<usize>,
    /// next micro-round's `drafting` (double buffer)
    still: Vec<usize>,
    /// non-failed round participants headed into verify
    verifying: Vec<usize>,
    /// sessions streaming a chunked-prefill feed this iteration
    chunking: Vec<usize>,
    /// per-session flag: prefilled this iteration, skips the round
    in_prefill: Vec<bool>,
    /// buffer growths since the last flush into `StepStats`
    allocs: u64,
}

/// Make `buf[..n]` valid reusable rows, growing (and counting the
/// growth) only when this iteration needs more rows than any before.
fn ensure_items(buf: &mut Vec<BatchItem>, n: usize, allocs: &mut u64) {
    if n > buf.len() {
        *allocs += 1;
        buf.resize_with(n, || BatchItem {
            seq: 0,
            seed: 0,
            category: String::new(),
            tokens: Vec::new(),
            start: 0,
            drafter: 0,
        });
    }
}

/// Refill one reusable row in place: scalar fields overwritten, the
/// token buffer cleared and refilled from `blocks` (growths counted),
/// the category `String` reused byte-for-byte when unchanged.
fn fill_item(
    item: &mut BatchItem,
    s: &ActiveSession,
    start: usize,
    blocks: &[&[u32]],
    allocs: &mut u64,
) {
    item.seq = s.slot.id;
    item.seed = s.seed;
    item.start = start;
    item.drafter = s.drafter;
    if item.category != s.req.category {
        item.category.clear();
        item.category.push_str(&s.req.category);
    }
    let cap = item.tokens.capacity();
    item.tokens.clear();
    for b in blocks {
        item.tokens.extend_from_slice(b);
    }
    if item.tokens.capacity() != cap {
        *allocs += 1;
    }
}

/// Run one speculation round for every live session: batched drafting
/// micro-rounds, then window-free batched verification — pipelined when
/// enabled: each chunk's verify is *submitted*, the next round's
/// micro-round 0 is speculatively pre-drafted under it, then the commit
/// adopts or discards the pre-draft (docs/ARCHITECTURE.md §16) — then
/// per-session commit/stream/reward. Returns how many sessions stepped.
#[allow(clippy::too_many_arguments)]
fn run_round(
    sessions: &mut [ActiveSession],
    controllers: &mut [SessionController],
    drafter: &mut dyn LanguageModel,
    verifier: &mut dyn LanguageModel,
    verify_cap: usize,
    pipeline: bool,
    rng: &mut Rng,
    shared: &EngineShared,
    stats: &EngineStats,
    scratch: &mut RoundScratch,
) -> usize {
    // --- chunked prefill (docs/ARCHITECTURE.md §13): stream one
    // page-aligned prompt chunk per iteration for sessions still far
    // from caught up; they skip this iteration's decode round ----------
    chunked_prefill(sessions, drafter, verifier, verify_cap, shared, stats, scratch);
    let RoundScratch {
        items,
        spec_items,
        live,
        drafting,
        still,
        verifying,
        in_prefill,
        allocs,
        ..
    } = scratch;

    // --- round begin: termination check + bandit select per session ----
    live.clear();
    for (i, s) in sessions.iter_mut().enumerate() {
        if in_prefill[i] {
            continue; // still streaming its prompt — no round, no bandit
        }
        if s.done || s.failed.is_some() {
            continue; // retires next iteration
        }
        if s.req.cancel.is_cancelled() || s.req.deadline_expired() {
            continue; // observed at the round boundary, retires next
        }
        if finish_check(
            s.committed.len(),
            s.prompt_len,
            s.committed.last().copied(),
            &s.cfg,
            s.max_seq,
        )
        .is_some()
        {
            s.done = true;
            continue;
        }
        let c = s.committed.len();
        s.round_c = c;
        s.gamma = s.cfg.gamma_max.min(s.max_seq.saturating_sub(c + 2));
        s.proposals.clear();
        s.draft_ns = 0;
        s.verify_ns = 0;
        // drafter-pool selection first (docs/ARCHITECTURE.md §17): one
        // begin per round, and the policy select below runs against the
        // (tenant, drafter) posterior the round actually decodes under
        s.drafter = s.hook.begin_round();
        controllers[s.slot.id].set_context(s.hook.tenant(), s.drafter);
        // one select per session per round — the bandit atomicity
        // contract of bandit/shared.rs, unchanged by the re-sequencing
        controllers[s.slot.id].session_start(rng);
        live.push(i);
    }
    let prefilled = in_prefill.iter().filter(|&&p| p).count();
    if live.is_empty() {
        return prefilled;
    }

    // --- draft micro-round 0: every session's committed catch-up (the
    // ragged one — prefills mix with 1–2 token decode catch-ups). Rows
    // are refilled in place from the iteration-persistent scratch, so
    // the steady-state hot path allocates only when a batch outgrows
    // every prior one (`StepStats::scratch_allocs`) --------------------
    let t0 = Instant::now();
    let n0 = live.len();
    ensure_items(items, n0, allocs);
    for (item, &i) in items.iter_mut().zip(live.iter()) {
        let s = &sessions[i];
        fill_item(item, s, s.draft_cur, &[&s.committed[s.draft_cur..]], allocs);
    }
    let before = drafter.cost();
    let rows = match drafter.draft_batch(&items[..n0]) {
        Ok(r) => r,
        Err(e) => {
            // every live session's play was opened by session_start above
            // and will never see on_verify — absorb the aborts so bandit
            // counts stay conserved (DecodeControl::on_abort). Reseat the
            // shared drafter so a wedged device (sticky-broken under
            // fault injection) costs one iteration, not the engine.
            drafter.reset();
            for &i in live.iter() {
                controllers[sessions[i].slot.id].on_abort();
                sessions[i].hook.settle_abort();
            }
            fail_all(sessions, live, &format!("batched draft failed: {e:#}"));
            return live.len();
        }
    };
    note_draft(stats, drafter.cost(), before, n0);
    let dt = t0.elapsed().as_nanos() as u64;
    drafting.clear();
    for (r, &i) in rows.iter().zip(live.iter()) {
        let s = &mut sessions[i];
        let sid = s.slot.id;
        s.draft_ns += dt;
        s.draft_cur = s.round_c; // catch-up advanced the cursor to c
        let last = *r.last().expect("draft_batch returns >=1 row per item");
        s.proposals.push(last.argmax);
        s.last_tok = last.argmax;
        // the stop check short-circuits at γ, exactly as SpecSession::step
        let stopped =
            s.proposals.len() >= s.gamma || controllers[sid].should_stop(&last, 0, rng);
        if !stopped {
            drafting.push(i);
        }
    }

    // --- subsequent micro-rounds: one token per still-drafting session;
    // the batch shrinks as per-arm stop rules fire (γ raggedness) ------
    while !drafting.is_empty() {
        let t = Instant::now();
        let n = drafting.len();
        ensure_items(items, n, allocs);
        for (item, &i) in items.iter_mut().zip(drafting.iter()) {
            let s = &sessions[i];
            let start = s.round_c + s.proposals.len() - 1;
            fill_item(item, s, start, &[std::slice::from_ref(&s.last_tok)], allocs);
        }
        let before = drafter.cost();
        let rows = match drafter.draft_batch(&items[..n]) {
            Ok(r) => r,
            Err(e) => {
                // only this micro-round's participants fail; sessions
                // that already stopped drafting still verify. Reseat the
                // shared drafter (see the catch-up error arm above).
                drafter.reset();
                for &i in drafting.iter() {
                    controllers[sessions[i].slot.id].on_abort();
                    sessions[i].hook.settle_abort();
                }
                fail_all(sessions, drafting, &format!("batched draft failed: {e:#}"));
                break;
            }
        };
        note_draft(stats, drafter.cost(), before, n);
        let dt = t.elapsed().as_nanos() as u64;
        still.clear();
        for (r, &i) in rows.iter().zip(drafting.iter()) {
            let s = &mut sessions[i];
            let sid = s.slot.id;
            s.draft_ns += dt;
            let last = *r.last().expect("draft_batch returns >=1 row per item");
            s.proposals.push(last.argmax);
            s.last_tok = last.argmax;
            let idx = s.proposals.len() - 1;
            let stopped =
                s.proposals.len() >= s.gamma || controllers[sid].should_stop(&last, idx, rng);
            if !stopped {
                still.push(i);
            }
        }
        std::mem::swap(drafting, still);
    }
    // the draft cursor after k proposals: catch-up left it at c, then
    // k−1 single-token feeds — mirror of the sequential session
    for &i in live.iter() {
        let s = &mut sessions[i];
        if s.failed.is_none() {
            s.draft_cur = s.round_c + s.proposals.len() - 1;
        }
    }

    // --- verify: the step loop is the window — every live session's
    // target block coalesces into one block_batch (capped by the
    // configured max_batch; 0 = per-session, the batching-off oracle).
    // Pipelined: the chunk's verify is submitted, the next round's
    // micro-round 0 is speculatively pre-drafted while it is in flight,
    // and the commit adopts or discards the pre-draft per session ------
    verifying.clear();
    verifying.extend(live.iter().copied().filter(|&i| sessions[i].failed.is_none()));
    let cap = if verify_cap == 0 { 1 } else { verify_cap };
    for chunk in verifying.chunks(cap) {
        let t = Instant::now();
        let n = chunk.len();
        ensure_items(items, n, allocs);
        for (item, &i) in items.iter_mut().zip(chunk.iter()) {
            let s = &sessions[i];
            let blocks = [&s.committed[s.target_cur..], s.proposals.as_slice()];
            fill_item(item, s, s.target_cur, &blocks, allocs);
        }
        let before = verifier.cost();
        let pending = verifier.submit_batch(&items[..n]);
        // --- speculative pre-draft under the verify shadow (§16): one
        // row per chunk session — the last proposal fed at the draft
        // cursor, i.e. next round's catch-up under full acceptance. The
        // forward is bracketed with its own cost reads and reported to
        // PipelineStats only: speculative work never reaches note_draft,
        // the bandit, or the SJF ledger, whether adopted or discarded --
        let mut spec_ok = false;
        let mut overlap_ns = 0u64;
        if pipeline {
            let t_spec = Instant::now();
            ensure_items(spec_items, n, allocs);
            for (item, &i) in spec_items.iter_mut().zip(chunk.iter()) {
                let s = &sessions[i];
                fill_item(item, s, s.draft_cur, &[std::slice::from_ref(&s.last_tok)], allocs);
            }
            // an Err here is absorbed: the round proceeds exactly as if
            // speculation never ran (no drafter reset — speculate_batch
            // draws no fault randomness, so there is nothing to heal)
            spec_ok = drafter.speculate_batch(&spec_items[..n]).is_ok();
            overlap_ns = t_spec.elapsed().as_nanos() as u64;
        }
        let t_wait = Instant::now();
        let vrows = match pending.wait() {
            Ok(r) => r,
            Err(e) => {
                // these sessions' plays never see on_verify — conserve.
                // Reseat the shared verifier so a wedged device fails one
                // chunk, not every future iteration. The pre-draft dies
                // with the verify: its rows are discarded, and the aborts
                // above settle each session's play exactly once.
                verifier.reset();
                for &i in chunk {
                    controllers[sessions[i].slot.id].on_abort();
                    sessions[i].hook.settle_abort();
                }
                if pipeline {
                    let stall = t_wait.elapsed().as_nanos() as u64;
                    stats.pipeline.note_round(spec_ok, overlap_ns, stall);
                    if spec_ok {
                        stats.pipeline.rows_discarded.fetch_add(n as u64, Ordering::Relaxed);
                    }
                }
                fail_all(sessions, chunk, &format!("batched verification failed: {e:#}"));
                continue;
            }
        };
        if pipeline {
            let stall = t_wait.elapsed().as_nanos() as u64;
            stats.pipeline.note_round(spec_ok, overlap_ns, stall);
        }
        let after = verifier.cost();
        stats.batch.note(
            chunk.len(),
            after.rows.saturating_sub(before.rows),
            after.padded_rows.saturating_sub(before.padded_rows),
            0, // no fill wait: the step loop is the window
        );
        let vt = t.elapsed().as_nanos() as u64;

        // --- commit/stream/reward per session ---------------------------
        for (r, &i) in vrows.iter().zip(chunk) {
            let s = &mut sessions[i];
            let sid = s.slot.id;
            s.verify_ns += vt;
            let k = s.proposals.len();
            let (m, bonus) = accept_greedy(r, s.target_cur, s.round_c, &s.proposals);
            s.committed.extend_from_slice(&s.proposals[..m]);
            s.committed.push(bonus);
            // rollback both mirrored cursors to the committed boundary
            s.target_cur = s.round_c + m;
            s.draft_cur = s.draft_cur.min(s.round_c + m);
            // one reward per session per round (conservation)
            controllers[sid].on_verify(m, k);
            // full-information drafter reward (docs/ARCHITECTURE.md
            // §17): score EVERY pooled drafter against this round's
            // accepted tokens (proposals[..m] + bonus) — pure
            // bookkeeping on the shared drafter, no cursor/cost/fault
            // effects, so outputs and fault schedules are untouched
            let scores = drafter.score_drafters(
                s.seed,
                &s.req.category,
                &s.committed[s.round_c..],
                s.round_c,
            );
            s.hook.settle_verify(&scores);
            let arm = controllers[sid].current_arm();
            s.rounds.push(RoundStat {
                drafted: k,
                accepted: m,
                arm,
                draft_ns: s.draft_ns,
                verify_ns: s.verify_ns,
                signals: Vec::new(),
            });
            // stream this round's committed tokens through the clip
            let new_tokens: Vec<u32> = s.committed[s.round_c..].to_vec();
            let (emit, reply_done) = s.clip.clip(&new_tokens);
            let send_failed = !emit.is_empty()
                && s.sink.wants_tokens()
                && !s.sink.send_tokens(s.req.id, emit, shared.codec.decode(emit));
            if send_failed {
                // stream receiver gone: client disconnected — flag the
                // request; it retires as Cancelled next iteration. The
                // disconnect outranks a same-round clip close, exactly as
                // drive_session returns Cancelled without consulting the
                // clip, so both modes report the identical event the same
                s.req.cancel.cancel();
            } else if reply_done {
                // the reply can no longer change: stop decoding now, so
                // post-EOS / post-budget rounds are never run
                s.done = true;
            }
            // --- adopt or discard the speculative pre-draft (§16) -------
            // Adopted exactly when this session accepted every proposal:
            // the speculative row fed `proposals[k-1]` at `c+k-1`, which
            // is committed content iff m == k, so the drafter's resident
            // world validly extends to c+k and the next catch-up feeds
            // one fewer token (just the bonus). The row's VALUE is never
            // read — the serialized loop discards that row too — so
            // outputs are byte-identical either way. On a partial accept
            // the cursor rollback above already re-drafts the position.
            if pipeline && spec_ok {
                if m == k {
                    s.draft_cur = s.round_c + k;
                    stats.pipeline.rows_adopted.fetch_add(1, Ordering::Relaxed);
                } else {
                    stats.pipeline.rows_discarded.fetch_add(1, Ordering::Relaxed);
                    if !s.done && !s.req.cancel.is_cancelled() {
                        // the next round's catch-up re-covers the position
                        stats.pipeline.redraft_forwards.fetch_add(1, Ordering::Relaxed);
                    }
                }
            }
        }
    }
    live.len() + prefilled
}

/// Advance every far-from-caught-up session by one page-aligned prompt
/// chunk through the batched drafter and verifier, filling
/// `scratch.in_prefill` with a per-session flag for who prefilled (those
/// sessions skip this iteration's round). Both mirrored cursors advance
/// together; the remainder left for the real round's catch-up always
/// keeps the final committed token (whose signal row seeds the first
/// proposal), so the round code is untouched and outputs stay
/// byte-identical. After an *adopted* speculative pre-draft the cursors
/// are equal too (`draft_cur == target_cur == c+k`), so the
/// cursor-agreement invariant below holds with pipelining on or off.
fn chunked_prefill(
    sessions: &mut [ActiveSession],
    drafter: &mut dyn LanguageModel,
    verifier: &mut dyn LanguageModel,
    verify_cap: usize,
    shared: &EngineShared,
    stats: &EngineStats,
    scratch: &mut RoundScratch,
) {
    let RoundScratch { items, chunking, in_prefill, allocs, .. } = scratch;
    in_prefill.clear();
    in_prefill.resize(sessions.len(), false);
    let ps = shared.pool.page_size().max(1);
    let chunk_tokens = PREFILL_CHUNK_PAGES * ps;
    // end of one chunk from `cur`: the next page boundary
    // PREFILL_CHUNK_PAGES pages out (callers clamp to len − 1 so the
    // final committed token is never consumed by a prefill chunk)
    let chunk_end = |cur: usize| ((cur / ps) + PREFILL_CHUNK_PAGES) * ps;
    chunking.clear();
    chunking.extend(
        sessions
            .iter()
            .enumerate()
            .filter(|(_, s)| {
                s.failed.is_none()
                    && !s.done
                    && !s.req.cancel.is_cancelled()
                    && !s.req.deadline_expired()
                    && s.committed.len().saturating_sub(1).saturating_sub(s.draft_cur)
                        > chunk_tokens
            })
            .map(|(i, _)| i),
    );
    if chunking.is_empty() {
        return;
    }
    for &i in chunking.iter() {
        in_prefill[i] = true;
        debug_assert_eq!(
            sessions[i].draft_cur, sessions[i].target_cur,
            "cursors diverge only inside rounds, where catch-up is small"
        );
    }

    // one batched draft feed over every chunking session (rows discarded
    // — this only advances the drafter's resident KV)
    let t0 = Instant::now();
    let n0 = chunking.len();
    ensure_items(items, n0, allocs);
    for (item, &i) in items.iter_mut().zip(chunking.iter()) {
        let s = &sessions[i];
        let end = chunk_end(s.draft_cur).min(s.committed.len() - 1);
        fill_item(item, s, s.draft_cur, &[&s.committed[s.draft_cur..end]], allocs);
    }
    let before = drafter.cost();
    match drafter.draft_batch(&items[..n0]) {
        Ok(_) => {}
        Err(e) => {
            // no bandit play is open during prefill (rounds start later),
            // so only reseat the shared drafter and fail the chunkers
            drafter.reset();
            fail_all(sessions, chunking, &format!("chunked prefill (draft) failed: {e:#}"));
            return;
        }
    }
    note_draft(stats, drafter.cost(), before, n0);
    let dt = t0.elapsed().as_nanos() as u64;

    // the matching verifier feed, in verify-cap slices like a round
    let cap = if verify_cap == 0 { 1 } else { verify_cap };
    for chunk in chunking.chunks(cap) {
        let t = Instant::now();
        let n = chunk.len();
        ensure_items(items, n, allocs);
        for (item, &i) in items.iter_mut().zip(chunk.iter()) {
            let s = &sessions[i];
            let end = chunk_end(s.target_cur).min(s.committed.len() - 1);
            fill_item(item, s, s.target_cur, &[&s.committed[s.target_cur..end]], allocs);
        }
        let before = verifier.cost();
        match verifier.block_batch(&items[..n]) {
            Ok(_) => {}
            Err(e) => {
                verifier.reset();
                fail_all(sessions, chunk, &format!("chunked prefill (verify) failed: {e:#}"));
                continue;
            }
        }
        let after = verifier.cost();
        stats.batch.note(
            chunk.len(),
            after.rows.saturating_sub(before.rows),
            after.padded_rows.saturating_sub(before.padded_rows),
            0,
        );
        let vt = t.elapsed().as_nanos() as u64;
        for &i in chunk {
            let s = &mut sessions[i];
            if s.failed.is_some() {
                continue;
            }
            let end = chunk_end(s.draft_cur).min(s.committed.len() - 1);
            s.draft_cur = end;
            s.target_cur = end;
            s.draft_ns += dt;
            s.verify_ns += vt;
        }
    }
}
