//! Paged KV allocator with copy-on-write prefix sharing
//! (docs/ARCHITECTURE.md §13).
//!
//! Through PR 5 KV memory was slot-granular: one contiguous `max_seq`
//! region per slot, so N concurrent requests sharing a system prompt held
//! N copies and a prefix could only be reused when the slot holding it
//! was *free*. [`PagePool`] breaks KV into fixed-size pages (`page_size`
//! tokens, default 16) with ref-counted ownership: each slot maps a
//! *chain* of page ids covering its resident tokens, and two chains may
//! reference the same page. A prefix hit against a busy slot no longer
//! waits — the new tenant's chain simply references the source chain's
//! fully-covered prefix pages (refcount + 1) and *copies* the partial
//! boundary page (copy-on-write: a shared page is never written, so the
//! page containing the divergence point is duplicated before the suffix
//! prefill overwrites it).
//!
//! **Bookkeeping, not storage.** The actual KV tensors live in the
//! backends (`LanguageModel`); the pool tracks which token ranges are
//! resident where, what is shared, and what memory that translates to.
//! That split is deliberate: the simulator's signal rows are pure
//! functions of (scenario, position), so "sharing a page" costs nothing
//! and adoption is exact (`LanguageModel::adopt_pages`), while the PJRT
//! backend keeps per-slot resident worlds and cannot map another slot's
//! pages — it reports itself non-adoptive and the pool never offers it a
//! cross-slot hit. Either way the pool's arithmetic — refcounts,
//! residency, copy-on-write, eviction — is real and is what the
//! `engine.pages` gauges report.
//!
//! Capacity: `kv_pages` bounds the pool; the default (0) auto-sizes to
//! `slots × ceil(max_seq / page_size)`, enough for every slot to hold a
//! full sequence with zero sharing. Under an explicit smaller arena,
//! eviction only ever targets *cached* residencies of free slots (the
//! [`SlotPool`](super::slots::SlotPool) drives that, LRU first) and
//! extension saturates — a live session's pages are never reclaimed.
//! Page sharing only lowers occupancy, never raises it.

/// Outcome counters of one allocator operation, folded into the pool's
/// cumulative stats by the caller's gauge mirror.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PageOp {
    /// pages newly allocated (fresh or copy-on-write)
    pub allocated: usize,
    /// pages released back to the free list (refcount reached 0)
    pub freed: usize,
    /// copy-on-write duplications performed (subset of `allocated`)
    pub cow: usize,
}

/// Ref-counted fixed-size-page KV bookkeeping: per-slot page chains over
/// a bounded page arena. All methods run under the owning
/// [`SlotPool`](super::slots::SlotPool)'s checkout mutex.
#[derive(Debug)]
pub struct PagePool {
    /// tokens per page
    page_size: usize,
    /// refcount per page id; 0 = on the free list
    refs: Vec<u32>,
    /// free page ids (stack — order is irrelevant, pages are abstract)
    free: Vec<usize>,
    /// page chain per slot id; `chains[s][i]` covers token positions
    /// `[i * page_size, (i + 1) * page_size)` of slot `s`'s sequence
    chains: Vec<Vec<usize>>,
    /// cumulative copy-on-write duplications
    pub cow_copies: u64,
    /// cumulative pages reclaimed from cached (free-slot) residencies
    pub evicted_pages: u64,
    /// cumulative cross-slot (busy-source) page-sharing checkouts
    pub shared_hits: u64,
    /// cumulative prompt tokens adopted via cross-slot sharing
    pub adopted_tokens: u64,
    /// high-water mark of resident (non-free) pages
    pub peak_resident: usize,
}

impl PagePool {
    /// A pool of `kv_pages` pages of `page_size` tokens for `slots`
    /// slots whose sequences are at most `max_seq` tokens. `kv_pages = 0`
    /// auto-sizes to `slots × ceil(max_seq / page_size)` — enough for
    /// every slot to hold a full sequence with zero sharing, so eviction
    /// never fires at the default. An explicit smaller arena is honored
    /// (pressure testing, deliberate oversubscription): the SlotPool
    /// evicts cached residencies first and extension saturates rather
    /// than ever reclaiming a live session's pages.
    pub fn new(page_size: usize, kv_pages: usize, slots: usize, max_seq: usize) -> PagePool {
        let page_size = page_size.max(1);
        let auto = slots * max_seq.div_ceil(page_size);
        let total = if kv_pages == 0 { auto } else { kv_pages };
        PagePool {
            page_size,
            refs: vec![0; total],
            free: (0..total).rev().collect(),
            chains: vec![Vec::new(); slots],
            cow_copies: 0,
            evicted_pages: 0,
            shared_hits: 0,
            adopted_tokens: 0,
            peak_resident: 0,
        }
    }

    /// Tokens per page.
    pub fn page_size(&self) -> usize {
        self.page_size
    }

    /// Total pages in the arena.
    pub fn total_pages(&self) -> usize {
        self.refs.len()
    }

    /// Pages currently on the free list.
    pub fn free_pages(&self) -> usize {
        self.free.len()
    }

    /// Pages currently mapped by at least one chain.
    pub fn resident_pages(&self) -> usize {
        self.refs.len() - self.free.len()
    }

    /// Pages mapped by more than one chain (the sharing win).
    pub fn shared_pages(&self) -> usize {
        self.refs.iter().filter(|&&r| r > 1).count()
    }

    /// Length of slot `slot`'s chain, in pages.
    pub fn chain_pages(&self, slot: usize) -> usize {
        self.chains[slot].len()
    }

    fn pages_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.page_size)
    }

    fn alloc(&mut self, op: &mut PageOp) -> Option<usize> {
        let p = self.free.pop()?;
        debug_assert_eq!(self.refs[p], 0, "free page must have refcount 0");
        self.refs[p] = 1;
        op.allocated += 1;
        self.peak_resident = self.peak_resident.max(self.resident_pages());
        Some(p)
    }

    fn deref(&mut self, page: usize, op: &mut PageOp) {
        debug_assert!(self.refs[page] > 0, "deref of a free page");
        self.refs[page] -= 1;
        if self.refs[page] == 0 {
            self.free.push(page);
            op.freed += 1;
        }
    }

    /// Drop slot `slot`'s whole chain (failed decode, cache-off release,
    /// or eviction — the caller decides which counter it feeds).
    pub fn drop_chain(&mut self, slot: usize) -> PageOp {
        let mut op = PageOp::default();
        let chain = std::mem::take(&mut self.chains[slot]);
        for page in chain {
            self.deref(page, &mut op);
        }
        op
    }

    /// Reclaim a *cached* residency (a free slot's chain) under page
    /// pressure; counts the pages actually returned to the free list as
    /// evictions (pages another chain still references are not freed).
    pub fn evict_chain(&mut self, slot: usize) -> PageOp {
        let op = self.drop_chain(slot);
        self.evicted_pages += op.freed as u64;
        op
    }

    /// Re-shape slot `slot`'s chain for a same-slot checkout: keep the
    /// first `keep` tokens of its resident state, then cover `want`
    /// tokens total with exclusive pages. Pages wholly beyond `keep` are
    /// dereferenced (the cursor rolls back over them); the partial
    /// boundary page (when `keep` is not page-aligned) is duplicated if
    /// shared, since the suffix prefill will write into it.
    pub fn reacquire(&mut self, slot: usize, keep: usize, want: usize) -> PageOp {
        let mut op = PageOp::default();
        let keep_pages = self.pages_for(keep);
        while self.chains[slot].len() > keep_pages {
            let page = self.chains[slot].pop().unwrap();
            self.deref(page, &mut op);
        }
        debug_assert!(
            self.chains[slot].len() >= keep_pages,
            "keep must be within the resident chain"
        );
        // copy-on-write the partially-kept boundary page
        if keep % self.page_size != 0 {
            let last = keep_pages - 1;
            if last < self.chains[slot].len() && self.refs[self.chains[slot][last]] > 1 {
                let old = self.chains[slot][last];
                if let Some(fresh) = self.alloc(&mut op) {
                    op.cow += 1;
                    self.cow_copies += 1;
                    self.chains[slot][last] = fresh;
                    self.deref(old, &mut op);
                }
            }
        }
        self.extend(slot, want, &mut op);
        op
    }

    /// Map slot `dst`'s chain onto the first `shared` tokens of slot
    /// `src`'s chain (copy-on-write prefix sharing), then cover `want`
    /// tokens total with exclusive pages. Fully-covered prefix pages are
    /// referenced (refcount + 1); the partial boundary page is *copied*
    /// (the suffix prefill writes into it), counting one copy-on-write.
    /// `dst`'s previous chain is dropped first.
    pub fn adopt(&mut self, dst: usize, src: usize, shared: usize, want: usize) -> PageOp {
        debug_assert_ne!(dst, src, "adopt is cross-slot; same-slot reuse is reacquire()");
        let mut op = self.drop_chain(dst);
        let full = shared / self.page_size;
        // `full` is clamped to the source chain: under a saturated arena
        // the source's bookkeeping may cover fewer pages than its
        // registered tokens — sharing degrades, correctness does not
        // (the shared depth is vouched by token content, not by pages)
        for i in 0..full.min(self.chains[src].len()) {
            let page = self.chains[src][i];
            self.refs[page] += 1;
            self.chains[dst].push(page);
        }
        if shared % self.page_size != 0 {
            // the boundary page holds both shared tokens and positions
            // the new suffix will overwrite — copy, never reference
            if let Some(fresh) = self.alloc(&mut op) {
                op.cow += 1;
                self.cow_copies += 1;
                self.chains[dst].push(fresh);
            }
        }
        self.shared_hits += 1;
        self.adopted_tokens += shared as u64;
        self.extend(dst, want, &mut op);
        op
    }

    /// Resize slot `slot`'s chain to cover exactly `tokens` resident
    /// tokens (the release path: extend over the decode's new tokens, or
    /// shrink to the recorded watermark). No copy-on-write is needed —
    /// nothing below `tokens` is written after release.
    pub fn resize(&mut self, slot: usize, tokens: usize) -> PageOp {
        let mut op = PageOp::default();
        let want_pages = self.pages_for(tokens);
        while self.chains[slot].len() > want_pages {
            let page = self.chains[slot].pop().unwrap();
            self.deref(page, &mut op);
        }
        self.extend(slot, tokens, &mut op);
        op
    }

    fn extend(&mut self, slot: usize, want_tokens: usize, op: &mut PageOp) {
        let want_pages = self.pages_for(want_tokens);
        while self.chains[slot].len() < want_pages {
            // best-effort: the SlotPool evicts cached residencies before
            // extending, so running dry here means the arena was
            // exhausted by live chains alone — bookkeeping saturates
            // rather than failing the decode (the backends hold the
            // real KV)
            match self.alloc(op) {
                Some(p) => self.chains[slot].push(p),
                None => break,
            }
        }
    }

    /// Σ refcounts == Σ chain lengths and free list complements resident
    /// pages — the conservation invariant the refcount tests and the sim
    /// harness's shadow oracle check after every event. `None` = healthy;
    /// `Some(msg)` describes the first violated equality.
    pub fn conservation_error(&self) -> Option<String> {
        let total_refs: u64 = self.refs.iter().map(|&r| r as u64).sum();
        let total_chain: u64 = self.chains.iter().map(|c| c.len() as u64).sum();
        if total_refs != total_chain {
            return Some(format!(
                "page refcount leak: Σ refs {total_refs} != Σ chain memberships {total_chain}"
            ));
        }
        let free_by_refs = self.refs.iter().filter(|&&r| r == 0).count();
        if free_by_refs != self.free.len() {
            return Some(format!(
                "free-list drift: {} zero-ref pages but {} free-listed",
                free_by_refs,
                self.free.len()
            ));
        }
        if self.peak_resident > self.total_pages() {
            return Some(format!(
                "peak_resident {} exceeds the arena ({} pages)",
                self.peak_resident,
                self.total_pages()
            ));
        }
        None
    }

    #[cfg(test)]
    fn check_conservation(&self) {
        if let Some(e) = self.conservation_error() {
            panic!("{e}");
        }
    }

    /// Test-only sabotage hook for the sim harness (docs/TESTING.md): leak
    /// one free page from the accounting so [`PagePool::conservation_error`]
    /// trips. Exists so the oracle+shrinker pipeline itself is testable —
    /// never called outside deliberate violation-injection runs.
    #[doc(hidden)]
    pub fn debug_leak_page(&mut self) {
        self.free.pop();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chains_share_full_pages_and_cow_the_boundary() {
        // page_size 4: a 10-token prefix = 2 full pages + 2 tokens into
        // the third
        let mut p = PagePool::new(4, 0, 3, 64);
        p.reacquire(0, 0, 12); // slot 0 prefills 12 tokens -> 3 pages
        assert_eq!(p.chain_pages(0), 3);
        assert_eq!(p.resident_pages(), 3);
        p.check_conservation();

        // slot 1 adopts a 10-token shared prefix and prefills to 16
        let op = p.adopt(1, 0, 10, 16);
        assert_eq!(op.cow, 1, "the partial third page is copied, not shared");
        assert_eq!(p.chain_pages(1), 4);
        assert_eq!(p.shared_pages(), 2, "exactly the two full prefix pages are shared");
        // 3 (slot 0) + 2 shared + 1 cow + 1 fresh tail = 7 resident? no:
        // shared pages are counted once -> 3 + (4 - 2 referenced) = 5
        assert_eq!(p.resident_pages(), 5);
        assert_eq!(p.shared_hits, 1);
        assert_eq!(p.adopted_tokens, 10);
        p.check_conservation();

        // a page-aligned adoption shares everything, no copy
        let op = p.adopt(2, 0, 8, 8);
        assert_eq!(op.cow, 0);
        assert_eq!(p.chain_pages(2), 2);
        p.check_conservation();
    }

    #[test]
    fn refcounts_conserve_across_cow_clone_and_release() {
        // satellite: every cow/clone/release nets to zero leaked pages
        let mut p = PagePool::new(4, 0, 4, 64);
        p.reacquire(0, 0, 13);
        p.adopt(1, 0, 13, 20); // cow on the partial page
        p.adopt(2, 0, 12, 12); // aligned, pure sharing
        p.reacquire(3, 0, 7);
        p.check_conservation();

        p.resize(0, 17); // slot 0 decoded 4 more tokens
        p.resize(1, 9); // slot 1 rolled back
        p.check_conservation();

        // same-slot reacquire keeping a shared prefix: the kept boundary
        // page is shared (slot 2 references it) only if unaligned — here
        // slot 0 keeps 10 of its 17, boundary inside page 2 which slot 1
        // no longer shares; exercise the cow path explicitly via slot 2
        p.adopt(1, 0, 10, 10); // re-share slot 0's first 2 pages + cow
        p.reacquire(0, 10, 14); // slot 0 itself keeps 10, cow if shared
        p.check_conservation();

        for s in 0..4 {
            p.drop_chain(s);
        }
        assert_eq!(p.resident_pages(), 0, "all pages returned");
        assert_eq!(p.free_pages(), p.total_pages());
        p.check_conservation();
        assert!(p.peak_resident > 0 && p.peak_resident <= p.total_pages());
    }

    #[test]
    fn same_slot_reacquire_cows_a_page_another_chain_shares() {
        let mut p = PagePool::new(4, 0, 2, 64);
        p.reacquire(0, 0, 8); // 2 pages
        p.adopt(1, 0, 6, 6); // shares page 0 fully, cows page 1's half
        assert_eq!(p.shared_pages(), 1);
        let before = p.cow_copies;
        // slot 0 comes back keeping 6 tokens: its boundary page (tokens
        // 4..8) is exclusively its own (slot 1 copied), so no cow
        p.reacquire(0, 6, 12);
        assert_eq!(p.cow_copies, before, "exclusive boundary page needs no copy");
        // now make the boundary genuinely shared: aligned share of both
        // pages, then slot 0 keeps an unaligned 6 -> must copy
        p.resize(0, 8);
        p.adopt(1, 0, 8, 8);
        assert_eq!(p.shared_pages(), 2);
        p.reacquire(0, 6, 12);
        assert_eq!(p.cow_copies, before + 1, "shared boundary page is copied before write");
        p.check_conservation();
    }

    #[test]
    fn eviction_reclaims_cached_chains_and_counts_pages() {
        let mut p = PagePool::new(4, 0, 2, 16); // floor: 2 * 4 = 8 pages
        assert_eq!(p.total_pages(), 8);
        p.reacquire(0, 0, 16); // 4 pages
        p.reacquire(1, 0, 8); // 2 pages
        assert_eq!(p.free_pages(), 2);
        let op = p.evict_chain(1);
        assert_eq!(op.freed, 2);
        assert_eq!(p.evicted_pages, 2);
        assert_eq!(p.free_pages(), 4);
        // evicting a shared chain only frees what nothing else references
        p.adopt(1, 0, 16, 16); // pure share: 4 pages, all refcount 2
        assert_eq!(p.free_pages(), 4, "pure sharing allocates nothing");
        let op = p.evict_chain(1);
        assert_eq!(op.freed, 0, "slot 0 still holds every page");
        assert_eq!(p.resident_pages(), 4);
        p.check_conservation();
    }

    #[test]
    fn capacity_floor_and_saturating_extend() {
        // kv_pages = 0 auto-sizes to 2 slots * ceil(16/8) = 4; an
        // explicit arena is honored as given (oversubscription allowed)
        let p = PagePool::new(8, 0, 2, 16);
        assert_eq!(p.total_pages(), 4);
        let p = PagePool::new(8, 1, 2, 16);
        assert_eq!(p.total_pages(), 1);
        // exhausting the arena saturates instead of panicking
        let mut p = PagePool::new(8, 0, 1, 16); // 2 pages
        p.reacquire(0, 0, 16);
        assert_eq!(p.chain_pages(0), 2);
        let op = p.resize(0, 32); // beyond the arena: best-effort
        assert_eq!(op.allocated, 0);
        assert_eq!(p.chain_pages(0), 2, "chain saturates at the arena bound");
        p.check_conservation();
    }
}
