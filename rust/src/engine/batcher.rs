//! Cross-session verification batcher (docs/ARCHITECTURE.md §4).
//!
//! PR 1's engine gave every worker a private `block()` call, so at N
//! workers the backend saw N sequential single-sequence forwards. The
//! batcher closes that gap: decode workers *submit* their target steps
//! (catch-up + proposals, one job per verification round) and *await* the
//! scattered signal rows, while one batcher thread coalesces whatever
//! sessions are in flight within a small wait window into a single
//! [`LanguageModel::block_batch`] forward:
//!
//! ```text
//!   worker 0 ── submit ──▶ ┌──────────┐      block_batch(&[item; B])
//!   worker 1 ── submit ──▶ │ batcher  │ ──▶  one target forward
//!   worker N ── submit ──▶ │ (window) │ ◀──  B × signal rows
//!              ◀─ await ── └──────────┘      scatter to each session
//! ```
//!
//! Correctness: each job carries a self-describing [`BatchItem`]
//! (sequence key, scenario seed, contiguous token block), the backend's
//! batched rows are byte-identical to its sequential rows, and each
//! session blocks until its own rows return — so per-request output stays
//! a pure function of the prompt at every worker count and batch window
//! (pinned by `rust/tests/engine_batched.rs`).
//!
//! Latency: the window only applies while *more* sessions could join —
//! the batcher stops waiting as soon as it holds one job per in-flight
//! decode, so a single-worker engine never pays the window at all.
//!
//! Cancellation (docs/ARCHITECTURE.md §10): a job may carry its
//! request's [`CancelFlag`]. The batcher drops a cancelled session's
//! pending seat instead of verifying it — the job is answered with an
//! error immediately (unblocking the worker so it can report
//! `Cancelled` and release its KV slot), it never occupies a batch row,
//! and the fill wait is sliced so a session that stops submitting after
//! cancellation can only stall the window by one slice, not the whole
//! `window_us`.
//!
//! Relation to the §16 pipeline: this submit/await shape is the
//! *thread-level* analogue of the trait-level split the continuous
//! stepper uses ([`LanguageModel::submit_batch`] →
//! `PendingBatch::wait`). The batcher overlaps *sessions* across worker
//! threads behind one blocking forward; the pipelined stepper overlaps
//! *stages* (next-round pre-draft under the in-flight verify) on a
//! single thread. Workers mode keeps using the batcher unchanged — the
//! `--pipeline` flag is a no-op here.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::models::{BatchItem, LanguageModel, ModelCost, PageView};
use crate::signals::TokenSignals;

use super::metrics::EngineStats;
use super::request::CancelFlag;

/// Upper bound on one slice of the fill wait: between slices the batcher
/// re-checks the in-flight count and sheds cancelled seats, so a vanished
/// session stalls a filling batch by at most this long.
const FILL_SLICE: Duration = Duration::from_millis(5);

/// Verification-batching knobs (`EngineConfig::verify_batch`).
#[derive(Clone, Copy, Debug)]
pub struct BatchConfig {
    /// maximum sessions coalesced into one target forward; 0 disables
    /// the batcher entirely (per-slot direct verification, the PR 1
    /// engine)
    pub max_batch: usize,
    /// how long one batch waits for more in-flight sessions, in
    /// microseconds. Only paid while fewer jobs than in-flight decodes
    /// are held; size it to the backend's per-block latency (sub-ms for
    /// the simulator, ~ms for PJRT).
    pub window_us: u64,
}

impl Default for BatchConfig {
    fn default() -> Self {
        BatchConfig { max_batch: 8, window_us: 100 }
    }
}

impl BatchConfig {
    /// Is the batcher active at all?
    pub fn enabled(&self) -> bool {
        self.max_batch >= 1
    }

    /// Direct per-slot verification (no batcher thread).
    pub fn off() -> BatchConfig {
        BatchConfig { max_batch: 0, window_us: 0 }
    }
}

/// One submitted verification step: the item plus its reply channel.
/// Errors cross the channel as strings because one backend error answers
/// every job of the batch.
struct BatchJob {
    item: BatchItem,
    /// the owning request's cancellation flag, when the session wants its
    /// seat dropped on cancel (engine decode path)
    cancel: Option<CancelFlag>,
    reply: Sender<Result<Vec<TokenSignals>, String>>,
}

impl BatchJob {
    fn is_cancelled(&self) -> bool {
        self.cancel.as_ref().is_some_and(|c| c.is_cancelled())
    }

    /// Answer a cancelled job without verifying it (the worker translates
    /// this into a `Cancelled` terminal reply, not a decode failure).
    fn drop_seat(self) {
        let _ = self.reply.send(Err("verification dropped: request cancelled".into()));
    }
}

enum BatchMsg {
    Run(BatchJob),
    Shutdown,
}

/// Cloneable submit-side handle held by every decode worker.
#[derive(Clone)]
pub struct BatcherHandle {
    tx: Sender<BatchMsg>,
    in_flight: Arc<AtomicUsize>,
    /// does the backing verifier declare content-addressed (adoptable)
    /// KV? Probed once at spawn; [`BatchedTarget`] mirrors it so paged
    /// cross-slot sharing (docs/ARCHITECTURE.md §13) works identically
    /// through the batcher and the direct path.
    adoptive: bool,
}

impl BatcherHandle {
    /// Can sequences behind this batcher adopt shared KV pages?
    pub fn adoptive(&self) -> bool {
        self.adoptive
    }

    /// A request decode is starting: one more session may submit jobs.
    /// The batcher uses the in-flight count to stop waiting early (a lone
    /// session never pays the window).
    pub fn note_decode_start(&self) {
        self.in_flight.fetch_add(1, Ordering::SeqCst);
    }

    /// The matching end of [`BatcherHandle::note_decode_start`].
    pub fn note_decode_end(&self) {
        self.in_flight.fetch_sub(1, Ordering::SeqCst);
    }

    /// Submit one verification step and block until its rows scatter
    /// back (the session-side await). A `cancel` flag lets the batcher
    /// drop this session's seat instead of verifying it once the flag is
    /// set.
    fn submit(&self, item: BatchItem, cancel: Option<CancelFlag>) -> Result<Vec<TokenSignals>> {
        let (rtx, rrx) = channel();
        self.tx
            .send(BatchMsg::Run(BatchJob { item, cancel, reply: rtx }))
            .map_err(|_| anyhow::anyhow!("verification batcher is gone"))?;
        match rrx.recv() {
            Ok(Ok(rows)) => Ok(rows),
            Ok(Err(msg)) => Err(anyhow::anyhow!(msg)),
            Err(_) => Err(anyhow::anyhow!("verification batcher dropped the reply")),
        }
    }

    /// Ask the batcher thread to exit once current jobs are answered.
    pub fn shutdown(&self) {
        let _ = self.tx.send(BatchMsg::Shutdown);
    }
}

/// The batcher: one thread owning the batch-capable verifier model.
pub struct Batcher {
    handle: BatcherHandle,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl Batcher {
    /// Spawn the batcher thread over a batch-capable target model (the
    /// sim target or a `PjrtBatchVerifier`).
    pub fn spawn(
        verifier: Box<dyn LanguageModel>,
        cfg: BatchConfig,
        stats: Arc<EngineStats>,
    ) -> Result<Batcher> {
        anyhow::ensure!(cfg.enabled(), "Batcher::spawn with max_batch 0");
        let (tx, rx) = channel();
        let in_flight = Arc::new(AtomicUsize::new(0));
        let adoptive = verifier.page_view().adoptive;
        let handle = BatcherHandle { tx, in_flight: in_flight.clone(), adoptive };
        let thread = std::thread::Builder::new()
            .name("tapout-batcher".into())
            .spawn(move || batcher_loop(rx, verifier, cfg, in_flight, stats))?;
        Ok(Batcher { handle, thread: Some(thread) })
    }

    /// The submit-side handle workers clone.
    pub fn handle(&self) -> BatcherHandle {
        self.handle.clone()
    }

    /// Stop the thread and wait for it (queued jobs are still answered).
    pub fn shutdown(mut self) {
        self.handle.shutdown();
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

fn batcher_loop(
    rx: Receiver<BatchMsg>,
    mut verifier: Box<dyn LanguageModel>,
    cfg: BatchConfig,
    in_flight: Arc<AtomicUsize>,
    stats: Arc<EngineStats>,
) {
    let window = Duration::from_micros(cfg.window_us);
    loop {
        // pull the first live job; cancelled seats are dropped on arrival
        let first = loop {
            match rx.recv() {
                Ok(BatchMsg::Run(job)) if job.is_cancelled() => job.drop_seat(),
                Ok(BatchMsg::Run(job)) => break job,
                Ok(BatchMsg::Shutdown) | Err(_) => return,
            }
        };
        let mut jobs = vec![first];
        let mut stop_after = false;
        let t_fill = Instant::now();
        let deadline = t_fill + window;
        while jobs.len() < cfg.max_batch {
            // every in-flight decode already has a job here: executing
            // now beats waiting for sessions that are still drafting.
            // Re-checked every fill slice, so a session that exits
            // (cancelled / expired) releases the window promptly.
            if jobs.len() >= in_flight.load(Ordering::Relaxed) {
                break;
            }
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout((deadline - now).min(FILL_SLICE)) {
                Ok(BatchMsg::Run(job)) if job.is_cancelled() => job.drop_seat(),
                Ok(BatchMsg::Run(job)) => jobs.push(job),
                Ok(BatchMsg::Shutdown) => {
                    stop_after = true;
                    break;
                }
                // a slice timeout just loops back to re-check the fill
                // conditions; a real window expiry exits above
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        // final sweep: a seat whose request was cancelled while the
        // window filled is dropped rather than verified
        let (live, dead): (Vec<_>, Vec<_>) = jobs.into_iter().partition(|j| !j.is_cancelled());
        for job in dead {
            job.drop_seat();
        }
        let jobs = live;
        if jobs.is_empty() {
            if stop_after {
                return;
            }
            continue;
        }
        let fill_ns = t_fill.elapsed().as_nanos() as u64;

        let items: Vec<BatchItem> = jobs.iter().map(|j| j.item.clone()).collect();
        let before = verifier.cost();
        let out = verifier.block_batch(&items);
        let after = verifier.cost();

        match out {
            Ok(rows) => {
                // gauges count *successful* forwards only, so occupancy /
                // pad-waste stay meaningful under backend errors
                stats.batch.note(
                    jobs.len(),
                    delta(after, before, |c| c.rows),
                    delta(after, before, |c| c.padded_rows),
                    fill_ns,
                );
                debug_assert_eq!(rows.len(), jobs.len(), "backend scattered a wrong-size batch");
                for (job, r) in jobs.into_iter().zip(rows) {
                    let _ = job.reply.send(Ok(r));
                }
            }
            Err(e) => {
                // reseat the verifier before the next batch: a failed
                // forward may leave the device wedged (sticky-broken in
                // fault injection); batch rows are a pure function of the
                // items, so dropping verifier-local state is safe — one
                // bad forward must cost one batch, not the whole engine
                verifier.reset();
                let msg = format!("batched verification failed: {e:#}");
                for job in jobs {
                    let _ = job.reply.send(Err(msg.clone()));
                }
            }
        }
        if stop_after {
            return;
        }
    }
}

fn delta(after: ModelCost, before: ModelCost, f: impl Fn(&ModelCost) -> u64) -> u64 {
    f(&after).saturating_sub(f(&before))
}

/// Per-slot target-model stand-in that routes every `block` through the
/// batcher — the submit/await side of docs/ARCHITECTURE.md §4.
///
/// Implements [`LanguageModel`], so `spec::generate` drives it exactly
/// like a resident target: the handle keeps the sequence cursor and
/// enforces the contiguity invariant locally, while the resident KV (if
/// the backend has any) lives with the batcher's verifier, keyed by this
/// handle's slot id.
pub struct BatchedTarget {
    handle: BatcherHandle,
    seq: usize,
    seed: u64,
    category: String,
    cur: usize,
    max_seq: usize,
    rel_cost: f64,
    cost: ModelCost,
    cancel: Option<CancelFlag>,
    /// mirrored from the handle: the backing verifier's page adoptivity
    adoptive: bool,
    /// tokens this handle adopted from shared pages (gauge mirror)
    adopted: u64,
}

impl BatchedTarget {
    /// A handle for the sequence resident in slot `seq`. `max_seq` and
    /// `rel_cost` mirror the backing target model's geometry so session
    /// headroom checks behave identically to the direct path.
    pub fn new(seq: usize, handle: BatcherHandle, max_seq: usize, rel_cost: f64) -> BatchedTarget {
        let adoptive = handle.adoptive();
        BatchedTarget {
            handle,
            seq,
            seed: 0,
            category: String::new(),
            cur: 0,
            max_seq,
            rel_cost,
            cost: ModelCost::default(),
            cancel: None,
            adoptive,
            adopted: 0,
        }
    }

    /// Attach the owning request's cancellation flag so the batcher can
    /// drop this session's pending seat once the flag is set.
    pub fn with_cancel(mut self, flag: CancelFlag) -> BatchedTarget {
        self.cancel = Some(flag);
        self
    }
}

impl LanguageModel for BatchedTarget {
    fn name(&self) -> String {
        format!("batched-target(slot {})", self.seq)
    }

    fn reset(&mut self) {
        self.cur = 0;
    }

    fn begin_request(&mut self, seed: u64, category: &str) {
        self.seed = seed;
        self.category = category.to_string();
        self.cur = 0;
    }

    /// Prefix reuse through the batcher (docs/ARCHITECTURE.md §12): the
    /// handle holds no KV itself — the resident state lives with the
    /// batcher's verifier, keyed by this handle's slot id — so retaining
    /// is a *mirror* operation: place the local cursor at `keep` so the
    /// first submitted block starts at the divergence point. The engine
    /// only routes a cache hit to a slot whose resident verifier state
    /// covers `keep` matching positions (slots.rs); on the PJRT backend
    /// the verifier's `align` additionally guards that the resident
    /// world's cursor really reaches `start` before executing.
    fn retain_prefix(&mut self, seed: u64, category: &str, keep: usize) -> usize {
        self.seed = seed;
        self.category = category.to_string();
        self.cur = keep;
        keep
    }

    fn page_view(&self) -> PageView {
        PageView { adoptive: self.adoptive, resident: self.cur, adopted_tokens: self.adopted }
    }

    /// Paged adoption through the batcher (docs/ARCHITECTURE.md §13):
    /// like `retain_prefix`, this is a cursor mirror — the resident KV
    /// lives with the batcher's verifier. When that verifier is adoptive
    /// (content-addressed KV, e.g. the simulator) the cursor jumps to the
    /// page-vouched `shared` depth even past positions this handle never
    /// submitted; otherwise it degrades to same-slot retention at
    /// `local`, exactly the trait's default.
    fn adopt_pages(&mut self, seed: u64, category: &str, local: usize, shared: usize) -> usize {
        if self.adoptive {
            debug_assert!(local <= shared, "shared residency covers the local prefix");
            self.seed = seed;
            self.category = category.to_string();
            self.adopted += shared.saturating_sub(local) as u64;
            self.cur = shared;
            shared
        } else {
            self.retain_prefix(seed, category, local)
        }
    }

    fn block(&mut self, tokens: &[u32], start: usize) -> Result<Vec<TokenSignals>> {
        anyhow::ensure!(start == self.cur, "non-contiguous block: start {start} cur {}", self.cur);
        anyhow::ensure!(!tokens.is_empty(), "empty block");
        anyhow::ensure!(
            start + tokens.len() <= self.max_seq,
            "KV overflow: {start}+{} > {}",
            tokens.len(),
            self.max_seq
        );
        let rows = self.handle.submit(
            BatchItem {
                seq: self.seq,
                seed: self.seed,
                category: self.category.clone(),
                tokens: tokens.to_vec(),
                start,
                // verification rows always run on the target model,
                // which never pools drafters
                drafter: 0,
            },
            self.cancel.clone(),
        )?;
        anyhow::ensure!(
            rows.len() == tokens.len(),
            "batcher returned {} rows for {} tokens",
            rows.len(),
            tokens.len()
        );
        self.cur = start + tokens.len();
        self.cost.calls += 1;
        self.cost.rows += tokens.len() as u64;
        self.cost.padded_rows += tokens.len() as u64;
        Ok(rows)
    }

    fn cur(&self) -> usize {
        self.cur
    }

    fn rollback(&mut self, to: usize) {
        self.cur = self.cur.min(to);
    }

    fn max_seq(&self) -> usize {
        self.max_seq
    }

    fn cost(&self) -> ModelCost {
        self.cost
    }

    fn rel_cost(&self) -> f64 {
        self.rel_cost
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{Scenario, SimModel};
    use std::sync::Barrier;

    fn spawn_sim_batcher(cfg: BatchConfig) -> (Batcher, Arc<EngineStats>) {
        let stats = Arc::new(EngineStats::new(1));
        let verifier = Box::new(SimModel::target(Scenario::new(0, "qa")));
        (Batcher::spawn(verifier, cfg, stats.clone()).unwrap(), stats)
    }

    #[test]
    fn scattered_rows_match_direct_slot_model() {
        let (batcher, stats) = spawn_sim_batcher(BatchConfig { max_batch: 4, window_us: 200_000 });
        let barrier = Arc::new(Barrier::new(4));
        let mut threads = Vec::new();
        for t in 0..4usize {
            let handle = batcher.handle();
            let barrier = barrier.clone();
            threads.push(std::thread::spawn(move || {
                let mut target = BatchedTarget::new(t, handle.clone(), 4096, 1.0);
                target.begin_request(42 + t as u64, "coding");
                target.reset();
                handle.note_decode_start();
                barrier.wait();
                let rows = target.block(&[3, 4, 5], 0).unwrap();
                handle.note_decode_end();
                (t, rows)
            }));
        }
        for th in threads {
            let (t, rows) = th.join().unwrap();
            let mut solo = SimModel::target(Scenario::new(42 + t as u64, "coding"));
            let want = solo.block(&[3, 4, 5], 0).unwrap();
            assert_eq!(rows, want, "thread {t} got wrong rows");
        }
        // all four synchronized submissions coalesced into one forward
        let batches = stats.batch.batches.load(Ordering::Relaxed);
        let coalesced = stats.batch.coalesced.load(Ordering::Relaxed);
        assert_eq!(coalesced, 4);
        assert_eq!(batches, 1, "4 synchronized sessions should form one batch");
        assert_eq!(stats.batch.peak.load(Ordering::Relaxed), 4);
        assert!(stats.batch.padded_rows.load(Ordering::Relaxed) >= coalesced);
        batcher.shutdown();
    }

    #[test]
    fn lone_session_skips_the_window() {
        let (batcher, stats) =
            spawn_sim_batcher(BatchConfig { max_batch: 8, window_us: 2_000_000 });
        let handle = batcher.handle();
        let mut target = BatchedTarget::new(0, handle.clone(), 4096, 1.0);
        target.begin_request(7, "qa");
        handle.note_decode_start();
        let t0 = Instant::now();
        target.block(&[3, 3], 0).unwrap();
        assert!(
            t0.elapsed() < Duration::from_millis(500),
            "a lone in-flight session must not wait out the 2s window"
        );
        handle.note_decode_end();
        assert_eq!(stats.batch.batches.load(Ordering::Relaxed), 1);
        batcher.shutdown();
    }

    #[test]
    fn handle_enforces_contiguity_and_sizes() {
        let (batcher, _stats) = spawn_sim_batcher(BatchConfig { max_batch: 1, window_us: 0 });
        let mut target = BatchedTarget::new(0, batcher.handle(), 16, 1.0);
        target.begin_request(1, "qa");
        assert!(target.block(&[3], 5).is_err(), "non-contiguous start must fail");
        assert!(target.block(&[3; 17], 0).is_err(), "KV overflow must fail");
        let rows = target.block(&[3, 4], 0).unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(target.cur(), 2);
        target.rollback(1);
        assert_eq!(target.cur(), 1);
        batcher.shutdown();
    }

    #[test]
    fn cancelled_seat_is_dropped_without_stalling_the_window() {
        let (batcher, stats) = spawn_sim_batcher(BatchConfig { max_batch: 4, window_us: 500_000 });
        let handle = batcher.handle();
        handle.note_decode_start();
        handle.note_decode_start(); // a second decode is nominally in flight

        let flag = CancelFlag::new();
        flag.cancel();
        let mut dead = BatchedTarget::new(0, handle.clone(), 4096, 1.0).with_cancel(flag);
        dead.begin_request(1, "qa");
        let t0 = Instant::now();
        let err = dead.block(&[3], 0);
        assert!(err.is_err(), "a cancelled seat must not be verified");
        assert!(
            t0.elapsed() < Duration::from_millis(400),
            "dropping the seat must not wait out the 500ms window"
        );
        assert_eq!(stats.batch.batches.load(Ordering::Relaxed), 0, "no forward ran");
        handle.note_decode_end(); // the cancelled decode exits

        // a live session still verifies correctly afterwards
        let mut live = BatchedTarget::new(1, handle.clone(), 4096, 1.0);
        live.begin_request(2, "qa");
        let rows = live.block(&[3, 4], 0).unwrap();
        let mut solo = SimModel::target(Scenario::new(2, "qa"));
        assert_eq!(rows, solo.block(&[3, 4], 0).unwrap());
        handle.note_decode_end();
        batcher.shutdown();
    }

    #[test]
    fn submit_after_shutdown_errors_instead_of_hanging() {
        let (batcher, _stats) = spawn_sim_batcher(BatchConfig { max_batch: 2, window_us: 0 });
        let handle = batcher.handle();
        batcher.shutdown();
        let mut target = BatchedTarget::new(0, handle, 4096, 1.0);
        target.begin_request(1, "qa");
        assert!(target.block(&[3], 0).is_err());
    }
}
