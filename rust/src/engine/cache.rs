//! Cross-request prefix-reuse index (docs/ARCHITECTURE.md §12–§13).
//!
//! Serving workloads repeat prompt prefixes constantly — system prompts,
//! few-shot templates, chat history — and every repeat pays prefill twice
//! (draft + target). The contiguous-cursor slot protocol (slots.rs,
//! models/traits.rs) already keeps per-sequence KV resident across
//! requests; the only missing piece is *routing*: when a request arrives,
//! send it to the slot whose resident sequence shares the longest
//! token-id prefix with the request's prompt, roll the slot's cursors
//! back to the divergence point, and prefill only the suffix.
//!
//! [`PrefixIndex`] is that routing structure: a token-id trie over the
//! registered resident prefixes of a
//! [`SlotPool`](super::slots::SlotPool)'s slots. Through PR 5 only *free*
//! slots were registered (a hit had to seize the matching slot); the
//! paged allocator (paging.rs) registers busy slots too, because a page
//! hit copies refcounted page mappings instead of seizing the source
//! slot — [`PrefixIndex::best_match_where`] lets the pool ask the same
//! trie both questions ("deepest *free* match" for slot-affinity reuse,
//! "deepest match at all" for copy-on-write page sharing). Every slot's
//! prefix is inserted as a root path and the slot id is marked on each
//! node along it, so a lookup is one walk down the query prompt: the
//! deepest reachable node holds exactly the slots whose longest common
//! prefix with the prompt equals that depth.
//!
//! The index stores token ids only — whether reuse is *valid* is the
//! slot pool's contract (a slot's recorded prefix never exceeds its
//! models' cursor watermark, slots.rs), and whether it is *safe* is the
//! backend's (`LanguageModel::retain_prefix` /
//! `LanguageModel::adopt_pages`). The trie itself is exact: a match is a
//! literal token-for-token prefix equality, so routing can never
//! introduce an approximate hit.
//!
//! Each slot's current registration is also kept verbatim (`registered`),
//! which buys two things: [`PrefixIndex::insert`] short-circuits the
//! identical-prefix case in O(1) — release-then-reacquire of the same
//! slot with an unchanged prefix no longer re-walks the full trie — and
//! re-registration is a single call (insert unlinks the previous path
//! itself).
//!
//! Sizing: one node per distinct (depth, token) pair across registered
//! prefixes — bounded by Σ prefix lengths ≤ slots × max_seq, a few tens
//! of thousands of small nodes at the defaults. Nodes are arena-allocated
//! and recycled on removal, so a long-lived server does not leak trie
//! nodes as prefixes churn.

use std::collections::HashMap;

/// One trie node: outgoing token edges plus the ids of the slots whose
/// registered prefix passes through this node.
#[derive(Debug, Default)]
struct Node {
    children: HashMap<u32, usize>,
    slots: Vec<usize>,
}

/// A token-id trie over the registered resident prefixes of KV slots,
/// answering "which slot shares the longest prefix with this prompt?"
/// in one walk. Maintained by [`SlotPool`](super::slots::SlotPool) under
/// its checkout mutex.
#[derive(Debug)]
pub struct PrefixIndex {
    /// arena of nodes; index 0 is the root (never recycled)
    nodes: Vec<Node>,
    /// recycled node indexes (removal prunes emptied paths)
    spare: Vec<usize>,
    /// each slot's current registration, verbatim — the identical-prefix
    /// short-circuit and the one-call re-registration both read this
    registered: HashMap<usize, Vec<u32>>,
}

impl Default for PrefixIndex {
    fn default() -> Self {
        PrefixIndex::new()
    }
}

impl PrefixIndex {
    /// An empty index.
    pub fn new() -> PrefixIndex {
        PrefixIndex {
            nodes: vec![Node::default()],
            spare: Vec::new(),
            registered: HashMap::new(),
        }
    }

    fn alloc(&mut self) -> usize {
        match self.spare.pop() {
            Some(i) => i,
            None => {
                self.nodes.push(Node::default());
                self.nodes.len() - 1
            }
        }
    }

    /// Register slot `slot` as holding resident KV for `prefix`,
    /// replacing any previous registration. Returns whether the index
    /// changed: re-registering the exact current prefix is an O(1)
    /// no-op (`false`) — no trie walk, no node churn — so the
    /// release-then-reacquire hot path stops paying for an unchanged
    /// prefix. An empty `prefix` clears the registration (nothing to
    /// match against).
    pub fn insert(&mut self, slot: usize, prefix: &[u32]) -> bool {
        if self.registered.get(&slot).map(Vec::as_slice) == Some(prefix) {
            return false;
        }
        if let Some(old) = self.registered.remove(&slot) {
            self.unlink(slot, &old);
        } else if prefix.is_empty() {
            return false; // nothing registered, nothing to register
        }
        if prefix.is_empty() {
            return true;
        }
        let mut at = 0;
        for &tok in prefix {
            let next = match self.nodes[at].children.get(&tok).copied() {
                Some(n) => n,
                None => {
                    let n = self.alloc();
                    self.nodes[at].children.insert(tok, n);
                    n
                }
            };
            self.nodes[next].slots.push(slot);
            at = next;
        }
        self.registered.insert(slot, prefix.to_vec());
        true
    }

    /// Remove slot `slot`'s registration for `prefix` (the exact prefix
    /// passed to [`PrefixIndex::insert`]), pruning nodes that no longer
    /// carry any slot. Unknown registrations are ignored.
    pub fn remove(&mut self, slot: usize, prefix: &[u32]) {
        if self.registered.get(&slot).map(Vec::as_slice) == Some(prefix) {
            self.registered.remove(&slot);
        }
        self.unlink(slot, prefix);
    }

    /// The slot's current registration, if any.
    pub fn registration(&self, slot: usize) -> Option<&[u32]> {
        self.registered.get(&slot).map(Vec::as_slice)
    }

    /// Unmark `slot` along `prefix`'s path and prune emptied nodes. Stops
    /// early (a no-op for the untraversed tail) if the path does not
    /// exist — a longer-than-registered prefix never corrupts the trie.
    fn unlink(&mut self, slot: usize, prefix: &[u32]) {
        let mut at = 0;
        // (parent, token, node) for each step of the path
        let mut path = Vec::with_capacity(prefix.len());
        for &tok in prefix {
            let Some(&next) = self.nodes[at].children.get(&tok) else { return };
            path.push((at, tok, next));
            at = next;
        }
        let mut pruned_from = None;
        for (i, &(parent, tok, node)) in path.iter().enumerate() {
            let slots = &mut self.nodes[node].slots;
            if let Some(p) = slots.iter().position(|&s| s == slot) {
                slots.swap_remove(p);
            }
            // once a node on the path is emptied, this slot was the only
            // one passing through it — everything deeper on the path is
            // emptied too, so unlink the whole tail from its parent
            if pruned_from.is_none() && self.nodes[node].slots.is_empty() {
                self.nodes[parent].children.remove(&tok);
                pruned_from = Some(i);
            }
        }
        if let Some(from) = pruned_from {
            for &(_, _, node) in &path[from..] {
                self.nodes[node].children.clear();
                self.nodes[node].slots.clear();
                self.spare.push(node);
            }
        }
    }

    /// The slot sharing the longest token-id prefix with `prompt`, as
    /// `(slot id, common prefix length)`. `None` when no registered slot
    /// matches even the first token.
    pub fn best_match(&self, prompt: &[u32]) -> Option<(usize, usize)> {
        self.best_match_where(prompt, |_| true)
    }

    /// The slot sharing the longest token-id prefix with `prompt` *among
    /// slots satisfying `pred`*, as `(slot id, common prefix length)`.
    /// One walk down the prompt, then a deepest-first scan back up: the
    /// first node holding a `pred` slot wins, and that slot's LCP is
    /// exactly that node's depth (a longer match would have placed it on
    /// the deeper node too). The pool uses this to ask for the deepest
    /// *free* match (slot-affinity reuse) separately from the deepest
    /// match overall (copy-on-write page sharing).
    pub fn best_match_where<F>(&self, prompt: &[u32], pred: F) -> Option<(usize, usize)>
    where
        F: Fn(usize) -> bool,
    {
        let mut at = 0;
        let mut path = Vec::new(); // nodes at depth 1.. along the prompt
        for &tok in prompt {
            match self.nodes[at].children.get(&tok) {
                Some(&n) => {
                    at = n;
                    path.push(n);
                }
                None => break,
            }
        }
        for (i, &node) in path.iter().enumerate().rev() {
            if let Some(&s) = self.nodes[node].slots.iter().find(|&&s| pred(s)) {
                return Some((s, i + 1));
            }
        }
        None
    }

    /// Number of live (non-root, non-recycled) trie nodes — a leak guard
    /// for tests and diagnostics.
    pub fn node_count(&self) -> usize {
        self.nodes.len() - 1 - self.spare.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn longest_match_wins_and_exact_tokens_required() {
        let mut ix = PrefixIndex::new();
        ix.insert(0, &[1, 2, 3]);
        ix.insert(1, &[1, 2, 9, 9]);
        assert_eq!(ix.best_match(&[1, 2, 3, 7]), Some((0, 3)));
        assert_eq!(ix.best_match(&[1, 2, 9, 9, 5]), Some((1, 4)));
        // diverging at depth 2: either slot matches with LCP 2
        let (slot, lcp) = ix.best_match(&[1, 2, 4]).unwrap();
        assert_eq!(lcp, 2);
        assert!(slot == 0 || slot == 1);
        assert_eq!(ix.best_match(&[8, 1, 2]), None, "no first-token match");
    }

    #[test]
    fn remove_prunes_nodes_and_recycles_them() {
        let mut ix = PrefixIndex::new();
        ix.insert(0, &[1, 2, 3, 4]);
        ix.insert(1, &[1, 2]);
        assert_eq!(ix.node_count(), 4);
        ix.remove(0, &[1, 2, 3, 4]);
        // nodes for [1] and [1,2] survive (slot 1 passes through), the
        // [1,2,3] / [1,2,3,4] tail is pruned and recycled
        assert_eq!(ix.node_count(), 2);
        assert_eq!(ix.best_match(&[1, 2, 3, 4]), Some((1, 2)));
        ix.remove(1, &[1, 2]);
        assert_eq!(ix.node_count(), 0);
        assert_eq!(ix.best_match(&[1, 2]), None);
        // recycled nodes are reused, not leaked
        ix.insert(2, &[5, 6]);
        assert_eq!(ix.node_count(), 2);
        assert_eq!(ix.best_match(&[5, 6, 7]), Some((2, 2)));
    }

    #[test]
    fn identical_prefixes_coexist() {
        let mut ix = PrefixIndex::new();
        ix.insert(0, &[4, 4, 4]);
        ix.insert(1, &[4, 4, 4]);
        let (first, lcp) = ix.best_match(&[4, 4, 4]).unwrap();
        assert_eq!(lcp, 3);
        ix.remove(first, &[4, 4, 4]);
        let (second, lcp) = ix.best_match(&[4, 4, 4]).unwrap();
        assert_eq!(lcp, 3);
        assert_ne!(first, second);
        ix.remove(second, &[4, 4, 4]);
        assert_eq!(ix.best_match(&[4, 4, 4]), None);
        assert_eq!(ix.node_count(), 0);
    }

    #[test]
    fn empty_prefix_and_unknown_removals_are_noops() {
        let mut ix = PrefixIndex::new();
        ix.insert(0, &[]);
        assert_eq!(ix.node_count(), 0);
        assert_eq!(ix.best_match(&[1, 2]), None);
        ix.remove(3, &[7, 7]); // never inserted
        ix.insert(1, &[7]);
        ix.remove(1, &[7, 8]); // longer than the registration
        assert_eq!(ix.best_match(&[7]), Some((1, 1)));
    }

    #[test]
    fn identical_reinsert_short_circuits_without_churn() {
        // the release-then-reacquire hot path: re-registering the exact
        // current prefix must not re-walk or rebuild the trie
        let mut ix = PrefixIndex::new();
        assert!(ix.insert(0, &[1, 2, 3]), "first registration changes the index");
        let nodes = ix.node_count();
        assert!(!ix.insert(0, &[1, 2, 3]), "identical re-insert is a no-op");
        assert_eq!(ix.node_count(), nodes, "no node churn on the short-circuit");
        assert_eq!(ix.best_match(&[1, 2, 3]), Some((0, 3)));

        // a changed prefix re-registers in one call (old path unlinked)
        assert!(ix.insert(0, &[1, 2, 7]));
        assert_eq!(ix.best_match(&[1, 2, 3]), Some((0, 2)), "old tail is gone");
        assert_eq!(ix.best_match(&[1, 2, 7]), Some((0, 3)));
        assert_eq!(ix.registration(0), Some(&[1, 2, 7][..]));

        // clearing via an empty prefix unregisters
        assert!(ix.insert(0, &[]));
        assert_eq!(ix.node_count(), 0);
        assert_eq!(ix.registration(0), None);
        assert!(!ix.insert(0, &[]), "already clear");
    }

    #[test]
    fn best_match_where_filters_by_predicate() {
        let mut ix = PrefixIndex::new();
        ix.insert(0, &[1, 2, 3, 4]); // think: busy slot, deep match
        ix.insert(1, &[1, 2]); // think: free slot, shallow match
        // unrestricted: the deep registration wins
        assert_eq!(ix.best_match(&[1, 2, 3, 4, 9]), Some((0, 4)));
        // restricted to slot 1 (the "free set"): the shallow match wins
        assert_eq!(ix.best_match_where(&[1, 2, 3, 4, 9], |s| s == 1), Some((1, 2)));
        // no slot satisfies the predicate
        assert_eq!(ix.best_match_where(&[1, 2, 3], |_| false), None);
    }
}
