//! Cross-request prefix-reuse index (docs/ARCHITECTURE.md §12).
//!
//! Serving workloads repeat prompt prefixes constantly — system prompts,
//! few-shot templates, chat history — and every repeat pays prefill twice
//! (draft + target). The contiguous-cursor slot protocol (slots.rs,
//! models/traits.rs) already keeps per-sequence KV resident across
//! requests; the only missing piece is *routing*: when a request arrives,
//! send it to the free slot whose resident sequence shares the longest
//! token-id prefix with the request's prompt, roll the slot's cursors
//! back to the divergence point, and prefill only the suffix.
//!
//! [`PrefixIndex`] is that routing structure: a token-id trie over the
//! resident prefixes of the *free* slots of a
//! [`SlotPool`](super::slots::SlotPool). Every slot's prefix is
//! inserted as a root path and the slot
//! id is marked on each node along it, so a lookup is one walk down the
//! query prompt: the deepest reachable node holds exactly the free slots
//! whose longest common prefix with the prompt equals that depth.
//!
//! The index stores token ids only — whether reuse is *valid* is the
//! slot pool's contract (a slot's recorded prefix never exceeds its
//! models' cursor watermark, slots.rs), and whether it is *safe* is the
//! backend's (`LanguageModel::retain_prefix`). The trie itself is exact:
//! a match is a literal token-for-token prefix equality, so routing can
//! never introduce an approximate hit.
//!
//! Sizing: one node per distinct (depth, token) pair across free-slot
//! prefixes — bounded by Σ prefix lengths ≤ slots × max_seq, a few tens
//! of thousands of small nodes at the defaults. Nodes are arena-allocated
//! and recycled on removal, so a long-lived server does not leak trie
//! nodes as prefixes churn.

use std::collections::HashMap;

/// One trie node: outgoing token edges plus the ids of the free slots
/// whose resident prefix passes through this node.
#[derive(Debug, Default)]
struct Node {
    children: HashMap<u32, usize>,
    slots: Vec<usize>,
}

/// A token-id trie over the resident prefixes of free KV slots, answering
/// "which free slot shares the longest prefix with this prompt?" in one
/// walk. Maintained by [`SlotPool`](super::slots::SlotPool) under its
/// checkout mutex: insert at release, remove at checkout.
#[derive(Debug)]
pub struct PrefixIndex {
    /// arena of nodes; index 0 is the root (never recycled)
    nodes: Vec<Node>,
    /// recycled node indexes (removal prunes emptied paths)
    spare: Vec<usize>,
}

impl Default for PrefixIndex {
    fn default() -> Self {
        PrefixIndex::new()
    }
}

impl PrefixIndex {
    /// An empty index.
    pub fn new() -> PrefixIndex {
        PrefixIndex { nodes: vec![Node::default()], spare: Vec::new() }
    }

    fn alloc(&mut self) -> usize {
        match self.spare.pop() {
            Some(i) => i,
            None => {
                self.nodes.push(Node::default());
                self.nodes.len() - 1
            }
        }
    }

    /// Register free slot `slot` as holding resident KV for `prefix`.
    /// An empty prefix is a no-op (nothing to match against).
    pub fn insert(&mut self, slot: usize, prefix: &[u32]) {
        let mut at = 0;
        for &tok in prefix {
            let next = match self.nodes[at].children.get(&tok).copied() {
                Some(n) => n,
                None => {
                    let n = self.alloc();
                    self.nodes[at].children.insert(tok, n);
                    n
                }
            };
            self.nodes[next].slots.push(slot);
            at = next;
        }
    }

    /// Remove slot `slot`'s registration for `prefix` (the exact prefix
    /// passed to [`PrefixIndex::insert`]), pruning nodes that no longer
    /// carry any slot. Unknown registrations are ignored.
    pub fn remove(&mut self, slot: usize, prefix: &[u32]) {
        let mut at = 0;
        // (parent, token, node) for each step of the path
        let mut path = Vec::with_capacity(prefix.len());
        for &tok in prefix {
            let Some(&next) = self.nodes[at].children.get(&tok) else { return };
            path.push((at, tok, next));
            at = next;
        }
        let mut pruned_from = None;
        for (i, &(parent, tok, node)) in path.iter().enumerate() {
            let slots = &mut self.nodes[node].slots;
            if let Some(p) = slots.iter().position(|&s| s == slot) {
                slots.swap_remove(p);
            }
            // once a node on the path is emptied, this slot was the only
            // one passing through it — everything deeper on the path is
            // emptied too, so unlink the whole tail from its parent
            if pruned_from.is_none() && self.nodes[node].slots.is_empty() {
                self.nodes[parent].children.remove(&tok);
                pruned_from = Some(i);
            }
        }
        if let Some(from) = pruned_from {
            for &(_, _, node) in &path[from..] {
                self.nodes[node].children.clear();
                self.nodes[node].slots.clear();
                self.spare.push(node);
            }
        }
    }

    /// The free slot sharing the longest token-id prefix with `prompt`,
    /// as `(slot id, common prefix length)`. `None` when no free slot
    /// matches even the first token.
    pub fn best_match(&self, prompt: &[u32]) -> Option<(usize, usize)> {
        let mut at = 0;
        let mut depth = 0;
        for &tok in prompt {
            match self.nodes[at].children.get(&tok) {
                Some(&n) => {
                    at = n;
                    depth += 1;
                }
                None => break,
            }
        }
        if depth == 0 {
            return None;
        }
        // every surviving node carries ≥1 slot (remove() prunes), and
        // every slot here has LCP exactly `depth`: a longer match would
        // have let the walk descend further
        self.nodes[at].slots.first().map(|&s| (s, depth))
    }

    /// Number of live (non-root, non-recycled) trie nodes — a leak guard
    /// for tests and diagnostics.
    pub fn node_count(&self) -> usize {
        self.nodes.len() - 1 - self.spare.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn longest_match_wins_and_exact_tokens_required() {
        let mut ix = PrefixIndex::new();
        ix.insert(0, &[1, 2, 3]);
        ix.insert(1, &[1, 2, 9, 9]);
        assert_eq!(ix.best_match(&[1, 2, 3, 7]), Some((0, 3)));
        assert_eq!(ix.best_match(&[1, 2, 9, 9, 5]), Some((1, 4)));
        // diverging at depth 2: either slot matches with LCP 2
        let (slot, lcp) = ix.best_match(&[1, 2, 4]).unwrap();
        assert_eq!(lcp, 2);
        assert!(slot == 0 || slot == 1);
        assert_eq!(ix.best_match(&[8, 1, 2]), None, "no first-token match");
    }

    #[test]
    fn remove_prunes_nodes_and_recycles_them() {
        let mut ix = PrefixIndex::new();
        ix.insert(0, &[1, 2, 3, 4]);
        ix.insert(1, &[1, 2]);
        assert_eq!(ix.node_count(), 4);
        ix.remove(0, &[1, 2, 3, 4]);
        // nodes for [1] and [1,2] survive (slot 1 passes through), the
        // [1,2,3] / [1,2,3,4] tail is pruned and recycled
        assert_eq!(ix.node_count(), 2);
        assert_eq!(ix.best_match(&[1, 2, 3, 4]), Some((1, 2)));
        ix.remove(1, &[1, 2]);
        assert_eq!(ix.node_count(), 0);
        assert_eq!(ix.best_match(&[1, 2]), None);
        // recycled nodes are reused, not leaked
        ix.insert(2, &[5, 6]);
        assert_eq!(ix.node_count(), 2);
        assert_eq!(ix.best_match(&[5, 6, 7]), Some((2, 2)));
    }

    #[test]
    fn identical_prefixes_coexist() {
        let mut ix = PrefixIndex::new();
        ix.insert(0, &[4, 4, 4]);
        ix.insert(1, &[4, 4, 4]);
        let (first, lcp) = ix.best_match(&[4, 4, 4]).unwrap();
        assert_eq!(lcp, 3);
        ix.remove(first, &[4, 4, 4]);
        let (second, lcp) = ix.best_match(&[4, 4, 4]).unwrap();
        assert_eq!(lcp, 3);
        assert_ne!(first, second);
        ix.remove(second, &[4, 4, 4]);
        assert_eq!(ix.best_match(&[4, 4, 4]), None);
        assert_eq!(ix.node_count(), 0);
    }

    #[test]
    fn empty_prefix_and_unknown_removals_are_noops() {
        let mut ix = PrefixIndex::new();
        ix.insert(0, &[]);
        assert_eq!(ix.node_count(), 0);
        assert_eq!(ix.best_match(&[1, 2]), None);
        ix.remove(3, &[7, 7]); // never inserted
        ix.insert(1, &[7]);
        ix.remove(1, &[7, 8]); // longer than the registration
        assert_eq!(ix.best_match(&[7]), Some((1, 1)));
    }
}
