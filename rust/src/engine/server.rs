//! The serving engine: a worker thread owning the PJRT models, a TapOut
//! controller with *persistent online bandit state across requests*, an
//! admission scheduler, and the metrics sink. Requests go in over a
//! channel; each caller gets a private response channel.

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::models::{Manifest, ModelAssets};
use crate::runtime::Runtime;
use crate::spec::{generate, GenConfig, MethodSpec, BOS};
use crate::util::Rng;

use super::metrics::EngineMetrics;
use super::request::{Request, Response};
use super::scheduler::{Policy, Scheduler};
use super::slots::SlotPool;

#[derive(Clone, Debug)]
pub struct EngineConfig {
    pub artifacts: PathBuf,
    pub pair: String,
    pub method: String,
    pub gamma_max: usize,
    pub sched: Policy,
    pub slots: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            artifacts: PathBuf::from("artifacts"),
            pair: "pair-a".into(),
            method: "seq-ucb1".into(),
            gamma_max: 128,
            sched: Policy::Fcfs,
            slots: 2,
        }
    }
}

enum Job {
    Run(Request, Sender<Response>),
    Shutdown,
}

pub struct Engine {
    tx: Sender<Job>,
    handle: Option<JoinHandle<()>>,
    next_id: AtomicU64,
    pub metrics: Arc<Mutex<EngineMetrics>>,
    pub config: EngineConfig,
}

impl Engine {
    /// Boot the engine: loads artifacts, warms up the hot-path executables,
    /// spawns the decode worker.
    pub fn start(config: EngineConfig) -> Result<Engine> {
        let metrics = Arc::new(Mutex::new(EngineMetrics::default()));
        let (tx, rx) = channel::<Job>();

        let manifest = Manifest::load(&config.artifacts)?;
        let runtime = Runtime::cpu().context("PJRT client")?;
        let (dspec, tspec) = manifest.pair(&config.pair)?;
        let (dname, tname) = (dspec.name.clone(), tspec.name.clone());
        let draft_assets = ModelAssets::load(&runtime, &manifest, &dname)?;
        let target_assets = ModelAssets::load(&runtime, &manifest, &tname)?;
        let method = MethodSpec::parse(&config.method, &config.artifacts.display().to_string())
            .map_err(|e| anyhow::anyhow!(e))?;

        let cfg = config.clone();
        let m = metrics.clone();
        let handle = std::thread::Builder::new()
            .name("tapout-engine".into())
            .spawn(move || {
                if let Err(e) = worker(cfg, manifest, draft_assets, target_assets, method, rx, m)
                {
                    eprintln!("[engine] worker failed: {e:#}");
                }
            })?;

        Ok(Engine {
            tx,
            handle: Some(handle),
            next_id: AtomicU64::new(1),
            metrics,
            config,
        })
    }

    /// Submit a text prompt; returns a receiver for the response.
    pub fn submit(&self, prompt: &str, max_new: usize) -> Receiver<Response> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let req = Request::new(id, prompt, max_new);
        self.submit_request(req)
    }

    pub fn submit_request(&self, req: Request) -> Receiver<Response> {
        let (rtx, rrx) = channel();
        let _ = self.tx.send(Job::Run(req, rtx));
        rrx
    }

    pub fn shutdown(mut self) {
        let _ = self.tx.send(Job::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn worker(
    cfg: EngineConfig,
    manifest: Manifest,
    draft_assets: Arc<ModelAssets>,
    target_assets: Arc<ModelAssets>,
    method: MethodSpec,
    rx: Receiver<Job>,
    metrics: Arc<Mutex<EngineMetrics>>,
) -> Result<()> {
    // warm up the step + common verify buckets so first-request latency is
    // not dominated by XLA compilation
    draft_assets.exes.warmup(&[1, 4, 128, 256])?;
    target_assets.exes.warmup(&[1, 8, 16, 128, 256])?;

    let mut pool = SlotPool::new(&draft_assets, &target_assets, cfg.slots.max(1))?;
    let mut sched = Scheduler::new(cfg.sched);
    let mut waiters: std::collections::HashMap<u64, Sender<Response>> = Default::default();
    let mut ctrl = method.build(cfg.gamma_max).map_err(|e| anyhow::anyhow!("{e}"))?;
    let mut rng = Rng::new(0xE46);
    let started = Instant::now();

    loop {
        // drain everything that has arrived, then schedule
        loop {
            match rx.try_recv() {
                Ok(Job::Run(mut req, reply)) => {
                    if req.prompt.is_empty() {
                        req.prompt = vec![BOS];
                        req.prompt.extend(manifest.encode(&req.prompt_text));
                    }
                    waiters.insert(req.id, reply);
                    sched.push(req);
                }
                Ok(Job::Shutdown) => return Ok(()),
                Err(std::sync::mpsc::TryRecvError::Empty) => break,
                Err(std::sync::mpsc::TryRecvError::Disconnected) => return Ok(()),
            }
        }

        let Some(req) = sched.pop() else {
            // idle: block for the next job
            match rx.recv_timeout(Duration::from_millis(50)) {
                Ok(Job::Run(mut req, reply)) => {
                    if req.prompt.is_empty() {
                        req.prompt = vec![BOS];
                        req.prompt.extend(manifest.encode(&req.prompt_text));
                    }
                    waiters.insert(req.id, reply);
                    sched.push(req);
                }
                Ok(Job::Shutdown) => return Ok(()),
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {}
                Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => return Ok(()),
            }
            continue;
        };

        let mut slot = pool.acquire().expect("sequential worker always has a slot");
        let queue_ns = req.arrival.elapsed().as_nanos() as u64;
        let gen_cfg = GenConfig {
            max_new: req.max_new,
            gamma_max: cfg.gamma_max,
            stop_at_eos: true,
            collect_signals: false,
        };
        let outcome = generate(
            &mut slot.draft,
            &mut slot.target,
            &mut ctrl,
            &mut rng,
            &req.prompt,
            &gen_cfg,
        );
        pool.release(slot);

        match outcome {
            Ok(result) => {
                let resp = Response {
                    id: req.id,
                    text: manifest.decode(result.new_tokens()),
                    queue_ns,
                    total_ns: req.arrival.elapsed().as_nanos() as u64,
                    result,
                };
                {
                    let mut m = metrics.lock().unwrap();
                    m.record(&resp);
                    m.span_ns = started.elapsed().as_nanos() as u64;
                }
                if let Some(tx) = waiters.remove(&req.id) {
                    let _ = tx.send(resp);
                }
            }
            Err(e) => {
                eprintln!("[engine] request {} failed: {e:#}", req.id);
                waiters.remove(&req.id);
            }
        }
    }
}
