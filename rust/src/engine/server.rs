//! The serving engine: a dispatcher thread feeding either a pool of
//! decode workers (one per KV slot by default, `EngineMode::Workers`) or
//! a single continuous-batching step loop over every in-flight session
//! (`EngineMode::Continuous`, engine/stepper.rs,
//! docs/ARCHITECTURE.md §11), all updating a single shared TapOut
//! controller with *persistent online bandit state across requests and
//! workers* (DESIGN.md §2). Requests go in over a channel; each caller
//! gets a private response channel — unary or streaming — and failures
//! are answered explicitly rather than dropped.
//!
//! Concurrency layout (Workers mode; Continuous replaces the worker pool
//! and the batcher thread with one stepper thread owning every slot):
//!
//!   submit() ──ch──▶ dispatcher ──sched──▶ worker 0 ─┐
//!                      (encode,   (mutex +  worker 1 ─┼─▶ SlotPool ──▶
//!                       admit/429) condvar) worker N ─┘   (checkout)
//!                                                 │
//!                              verification batcher (batcher.rs):
//!                              workers submit target steps, one thread
//!                              coalesces in-flight sessions into one
//!                              block_batch forward and scatters rows
//!
//! Request lifecycle (docs/ARCHITECTURE.md §10): the dispatcher is the
//! admission controller (a full queue sheds arrivals with `Rejected`);
//! workers drive each decode through the resumable [`SpecSession`] step
//! API, so every round boundary checks the request's cancellation flag
//! and absolute deadline, streams the round's committed tokens into the
//! caller's sink, and stops as soon as the reply is fully determined.
//!
//!   * scheduler + waiter map: one mutex, held for queue ops only;
//!   * KV slots: blocking checkout (slots.rs) — workers may outnumber
//!     slots;
//!   * target forwards: routed through the per-backend batcher when
//!     `verify_batch` is enabled (docs/ARCHITECTURE.md §4); drafting
//!     stays per-slot;
//!   * bandit: shared select/update via `SharedController`
//!     (bandit/shared.rs); the per-token stop path is lock-free for
//!     sequence-granularity methods (token-granularity bandits take a
//!     short shared lock per drafted token — see bandit/shared.rs);
//!     verify rewards land when each batch scatters, i.e. asynchronously
//!     per batch rather than per private forward;
//!   * metrics: per-request samples under one mutex, per-worker counters,
//!     queue depth and batch occupancy/pad-waste as atomics (metrics.rs).

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::bandit::{DrafterHook, SessionController, SharedController, SharedDrafters};
use crate::models::{
    sim_decode, sim_encode, FaultyModel, LanguageModel, Manifest, ModelAssets, PjrtBatchVerifier,
    Scenario, SimModel,
};
use crate::runtime::Runtime;
use crate::spec::{GenConfig, MethodSpec, SpecSession, StepOutcome, BOS};
use crate::util::{Json, Rng};

use super::batcher::{BatchConfig, BatchedTarget, Batcher, BatcherHandle};
use super::metrics::{EngineMetrics, EngineStats};
use super::request::{EmitClip, FinishStatus, Request, Response, StreamEvent};
use super::scheduler::{Policy, Scheduler};
use super::slots::SlotPool;

/// How often a slot-waiting worker re-checks its request's cancellation
/// flag and deadline (the slot wait is real queueing — it must stay
/// interruptible, docs/ARCHITECTURE.md §10).
const SLOT_POLL: Duration = Duration::from_millis(10);

/// Which model backend the engine decodes with.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum BackendKind {
    /// real tiny LMs via PJRT artifacts (requires `make artifacts`)
    Pjrt,
    /// synthetic correlated draft/target pairs (models/sim.rs) — runs
    /// anywhere, used by the concurrency tests and scaling benches
    Sim { quality: f32, rel_cost: f64 },
}

/// Which execution model drives decoding (docs/ARCHITECTURE.md §2 / §11).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineMode {
    /// thread-per-request decode workers over the slot pool, with the
    /// cross-session verification batcher (the PR 1–3 engine; kept as
    /// the differential oracle for the continuous path)
    Workers,
    /// one continuous-batching step loop over every in-flight session:
    /// iteration-level admission into free KV slots, batched drafting
    /// micro-rounds, and window-free batched verification
    /// (`engine/stepper.rs`)
    Continuous,
}

impl EngineMode {
    /// Short name for banners and `/health`.
    pub fn label(&self) -> &'static str {
        match self {
            EngineMode::Workers => "workers",
            EngineMode::Continuous => "continuous",
        }
    }
}

impl BackendKind {
    /// Strict: an unknown backend name is an error, not a silent PJRT
    /// fallback (which would surface as a misleading artifacts failure).
    pub fn parse(s: &str) -> Result<BackendKind, String> {
        match s {
            "pjrt" => Ok(BackendKind::Pjrt),
            "sim" => Ok(BackendKind::sim_default()),
            other => Err(format!("unknown backend: {other} (expected pjrt|sim)")),
        }
    }

    /// The default simulator pair (quality 0.9, 16x cheaper draft).
    pub fn sim_default() -> BackendKind {
        BackendKind::Sim { quality: 0.9, rel_cost: 1.0 / 16.0 }
    }

    /// Short name for banners and `/health`.
    pub fn label(&self) -> &'static str {
        match self {
            BackendKind::Pjrt => "pjrt",
            BackendKind::Sim { .. } => "sim",
        }
    }
}

/// Everything `Engine::start` needs to boot a serving engine.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    /// artifact directory (PJRT backend only)
    pub artifacts: PathBuf,
    /// manifest pair name ("pair-a", ...)
    pub pair: String,
    /// stop-method spec (`MethodSpec::parse` names, e.g. "seq-ucb1")
    pub method: String,
    /// max draft length γ per session
    pub gamma_max: usize,
    /// admission-ordering policy
    pub sched: Policy,
    /// KV slots (resident sequence states)
    pub slots: usize,
    /// decode worker threads; may exceed `slots` (they queue at checkout)
    pub workers: usize,
    /// model backend the engine decodes with
    pub backend: BackendKind,
    /// cross-session verification batching (docs/ARCHITECTURE.md §4);
    /// `BatchConfig::off()` restores per-slot direct verification
    pub verify_batch: BatchConfig,
    /// admission control: maximum queued (not yet decoding) requests
    /// before the dispatcher sheds new arrivals with a `Rejected` reply
    /// (HTTP 429). 0 = unbounded queue (docs/ARCHITECTURE.md §10).
    pub max_queue: usize,
    /// default per-request deadline in milliseconds, applied at submit to
    /// requests that carry none. 0 = no default deadline.
    pub default_deadline_ms: u64,
    /// execution model: thread-per-request decode workers (the
    /// differential oracle) or the continuous-batching step loop
    /// (docs/ARCHITECTURE.md §11). In `Continuous` mode `workers` is
    /// ignored — concurrency is bounded by `slots` — and `verify_batch`
    /// only gates *whether* verification batches (`max_batch == 0`
    /// disables coalescing); the step loop itself is the batching window.
    pub mode: EngineMode,
    /// cross-request prefix-reuse KV cache (docs/ARCHITECTURE.md §12):
    /// admission routes each request to the free slot sharing the
    /// longest resident token prefix with its prompt and prefills only
    /// the suffix. Lossless — outputs are byte-identical with the cache
    /// on or off; it only removes redundant prefill forwards. Applies to
    /// both execution modes. Off by default (CLI `serve --prefix-cache`).
    pub prefix_cache: bool,
    /// KV page granularity in tokens (docs/ARCHITECTURE.md §13, CLI
    /// `serve --page-size`). Only meaningful with the prefix cache on.
    pub page_size: usize,
    /// KV arena size in pages; 0 auto-sizes to
    /// `slots × ceil(max_seq / page_size)`, at which page eviction never
    /// fires (CLI `serve --kv-pages`).
    pub kv_pages: usize,
    /// cross-slot copy-on-write page sharing (docs/ARCHITECTURE.md §13):
    /// with the prefix cache on and an adoptive backend, a prompt can
    /// reuse a *busy* slot's prefix pages instead of waiting for the
    /// matching slot to free. Lossless, on by default; disabling it
    /// restores PR-5 slot-affinity-only reuse (the bench baseline).
    pub page_sharing: bool,
    /// overlapped draft/verify pipeline in the continuous stepper
    /// (docs/ARCHITECTURE.md §16, CLI `serve --pipeline`): each verify
    /// chunk is submitted asynchronously and the next round's first
    /// micro-round is speculatively pre-drafted under it, adopted on
    /// full acceptance. Lossless — outputs, bandit plays, and page
    /// refcounts are byte-identical pipeline on or off. No-op in
    /// Workers mode. Off by default.
    pub pipeline: bool,
    /// fault injection at the `LanguageModel` boundary (sim backend only;
    /// docs/TESTING.md): when active, every slot model plus the batcher's
    /// verifier and the stepper's drafter are wrapped in
    /// `models::FaultyModel` with decorrelated fault streams. Default:
    /// inactive (zero rates) — production configs are untouched.
    pub faults: crate::models::FaultPlan,
    /// drafter pool size (docs/ARCHITECTURE.md §17, CLI `serve
    /// --drafters`): the engine hosts this many pooled draft models per
    /// target and an online full-information bandit selects one per
    /// round, keyed by the request's tenant. 1 (the default) keeps the
    /// selection layer inert and every output byte-identical to the
    /// pre-pool engine. Currently sim-backend only for > 1 — the PJRT
    /// path loads exactly one draft executor per pair.
    pub drafters: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            artifacts: PathBuf::from("artifacts"),
            pair: "pair-a".into(),
            method: "seq-ucb1".into(),
            gamma_max: 128,
            sched: Policy::Fcfs,
            slots: 2,
            workers: 2,
            backend: BackendKind::Pjrt,
            verify_batch: BatchConfig::default(),
            max_queue: 0,
            default_deadline_ms: 0,
            mode: EngineMode::Workers,
            prefix_cache: false,
            page_size: super::slots::DEFAULT_PAGE_SIZE,
            kv_pages: 0,
            page_sharing: true,
            pipeline: false,
            faults: crate::models::FaultPlan::default(),
            drafters: 1,
        }
    }
}

/// Prompt/text codec — the manifest tokenizer on PJRT, the fixed byte map
/// on the simulator.
pub(crate) enum Codec {
    Manifest(Box<Manifest>),
    Sim,
}

impl Codec {
    fn encode_prompt(&self, text: &str) -> Vec<u32> {
        let mut p = vec![BOS];
        match self {
            Codec::Manifest(m) => p.extend(m.encode(text)),
            Codec::Sim => p.extend(sim_encode(text)),
        }
        p
    }

    pub(crate) fn decode(&self, tokens: &[u32]) -> String {
        match self {
            Codec::Manifest(m) => m.decode(tokens),
            Codec::Sim => sim_decode(tokens),
        }
    }
}

/// Where one request's replies go: a unary response channel, or a
/// streaming channel that sees each round's committed tokens before the
/// terminal [`StreamEvent::Done`].
pub(crate) enum ResponseSink {
    Unary(Sender<Response>),
    Stream(Sender<StreamEvent>),
}

impl ResponseSink {
    /// Does this sink consume per-round token events? Unary sinks don't,
    /// so callers can skip building them (text decode per round).
    pub(crate) fn wants_tokens(&self) -> bool {
        matches!(self, ResponseSink::Stream(_))
    }

    /// Emit one round's clipped tokens (no-op for unary sinks). Returns
    /// `false` when the receiver is gone — the worker treats that as a
    /// client disconnect and cancels the request.
    pub(crate) fn send_tokens(&self, id: u64, ids: &[u32], text: String) -> bool {
        match self {
            ResponseSink::Unary(_) => true,
            ResponseSink::Stream(tx) => {
                tx.send(StreamEvent::Tokens { id, ids: ids.to_vec(), text }).is_ok()
            }
        }
    }

    /// Deliver the terminal reply (consumes the sink — exactly one
    /// terminal event per request).
    pub(crate) fn send_final(self, resp: Response) {
        match self {
            ResponseSink::Unary(tx) => {
                let _ = tx.send(resp);
            }
            ResponseSink::Stream(tx) => {
                let _ = tx.send(StreamEvent::Done(Box::new(resp)));
            }
        }
    }
}

enum Job {
    Run(Request, ResponseSink),
    Shutdown,
}

pub(crate) struct QueueState {
    pub(crate) sched: Scheduler,
    pub(crate) waiters: HashMap<u64, ResponseSink>,
    pub(crate) shutdown: bool,
}

/// State shared by the dispatcher and every decode driver (the worker
/// pool in Workers mode, the step loop in Continuous mode).
pub(crate) struct EngineShared {
    pub(crate) q: Mutex<QueueState>,
    pub(crate) cv: Condvar,
    pub(crate) pool: SlotPool,
    pub(crate) codec: Codec,
    pub(crate) gamma_max: usize,
    /// decode parallelism (divisor of the admission queue-wait estimate):
    /// worker threads in Workers mode, KV slots in Continuous mode
    pub(crate) n_workers: usize,
    /// admission bound on queued requests; 0 = unbounded
    pub(crate) max_queue: usize,
    /// submit side of the verification batcher; `None` when
    /// `verify_batch` is off (workers verify on their slot's own target)
    /// and always in Continuous mode (the step loop batches directly)
    batcher: Option<BatcherHandle>,
    /// serving-span origin (throughput/utilization time base); reset by
    /// the dispatcher once warmup finishes so XLA compile time never
    /// deflates the reported throughput
    pub(crate) started: Mutex<Instant>,
    /// drafter-pool selection layer (docs/ARCHITECTURE.md §17): one
    /// engine-wide ledger shared by every decode driver; pool-of-one
    /// engines carry it too (it always selects 0) so the conservation
    /// accounting is mode-independent
    pub(crate) drafters: Arc<SharedDrafters>,
}

/// The serving engine handle: submit requests, read metrics, shut down.
pub struct Engine {
    tx: Sender<Job>,
    dispatcher: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    next_id: AtomicU64,
    /// per-request latency/throughput samples
    pub metrics: Arc<Mutex<EngineMetrics>>,
    /// lock-free queue/worker/batch gauges
    pub stats: Arc<EngineStats>,
    /// the (normalized) configuration the engine booted with
    pub config: EngineConfig,
    controller: SharedController,
    shared: Arc<EngineShared>,
    batcher: Option<Batcher>,
}

impl Engine {
    /// Boot the engine: loads artifacts (PJRT backend), builds the slot
    /// pool and the shared controller, then spawns the dispatcher plus
    /// either the decode-worker pool (`EngineMode::Workers`) or the
    /// continuous-batching step loop (`EngineMode::Continuous`,
    /// `engine/stepper.rs`).
    pub fn start(mut config: EngineConfig) -> Result<Engine> {
        // normalize once; every later read of config.workers/slots (http
        // /health, CLI banner, metrics) sees the effective values. In
        // Continuous mode there is one stepper thread and concurrency is
        // bounded by slots, so `workers` normalizes to the slot count
        // (it divides the admission queue-wait estimate).
        config.slots = config.slots.max(1);
        config.workers = match config.mode {
            EngineMode::Workers => config.workers.max(1),
            EngineMode::Continuous => config.slots,
        };
        let continuous = config.mode == EngineMode::Continuous;
        config.drafters = config.drafters.max(1);
        if config.drafters > 1 && matches!(config.backend, BackendKind::Pjrt) {
            // per-drafter PJRT executors are a documented follow-up
            // (docs/ARCHITECTURE.md §17); the manifest already validates
            // `pools`, but the runtime loads one draft executor per pair
            anyhow::bail!("--drafters > 1 requires the sim backend");
        }
        let n_workers = config.workers;
        let n_slots = config.slots;
        let metrics = Arc::new(Mutex::new(EngineMetrics::default()));
        // per-thread decode counters: one stepper thread in Continuous
        let stats = Arc::new(EngineStats::new(if continuous { 1 } else { n_workers }));
        let (tx, rx) = channel::<Job>();

        let method = MethodSpec::parse(&config.method, &config.artifacts.display().to_string())
            .map_err(|e| anyhow::anyhow!(e))?;
        let controller = SharedController::new(&method, config.gamma_max);

        let (pool, codec, warm_assets, verifier, drafter): (
            _,
            _,
            _,
            Box<dyn LanguageModel>,
            Box<dyn LanguageModel>,
        ) = match config.backend {
            BackendKind::Pjrt => {
                let manifest = Manifest::load(&config.artifacts)?;
                let runtime = Runtime::cpu().context("PJRT client")?;
                let (dspec, tspec) = manifest.pair(&config.pair)?;
                let (dname, tname) = (dspec.name.clone(), tspec.name.clone());
                let draft_assets = ModelAssets::load(&runtime, &manifest, &dname)?;
                let target_assets = ModelAssets::load(&runtime, &manifest, &tname)?;
                let pool = SlotPool::pjrt(&draft_assets, &target_assets, n_slots)?;
                let verifier = Box::new(PjrtBatchVerifier::new(target_assets.clone()));
                // the continuous engine drafts through the same
                // multi-sequence executor type, over the draft assets
                let drafter = Box::new(PjrtBatchVerifier::new(draft_assets.clone()));
                (
                    pool,
                    Codec::Manifest(Box::new(manifest)),
                    Some((draft_assets, target_assets)),
                    verifier,
                    drafter,
                )
            }
            BackendKind::Sim { quality, rel_cost } => {
                let sc = Scenario::new(0, "qa");
                let n_drafters = config.drafters;
                // drafter pools (docs/ARCHITECTURE.md §17): every draft
                // model carries the same pool so round-level selection is
                // a pure index switch; n_drafters == 1 builds the exact
                // pre-pool models (byte-identical engine outputs)
                let mk_draft = || -> Box<dyn LanguageModel> {
                    let m = SimModel::draft(sc, quality, rel_cost);
                    if n_drafters > 1 { Box::new(m.with_drafters(n_drafters)) } else { Box::new(m) }
                };
                // the sim models are stateless per position, so one
                // verifier/drafter serves every sequence's batch items
                let mut verifier: Box<dyn LanguageModel> = Box::new(SimModel::target(sc));
                let mut drafter: Box<dyn LanguageModel> = mk_draft();
                let pool = if config.faults.is_active() {
                    // fault injection (docs/TESTING.md): wrap every model
                    // that crosses the LanguageModel boundary, each with a
                    // decorrelated fault stream forked off the plan seed
                    let pairs = (0..n_slots)
                        .map(|i| {
                            (
                                FaultyModel::wrap(mk_draft(), config.faults.fork(2 * i as u64)),
                                FaultyModel::wrap(
                                    Box::new(SimModel::target(sc)),
                                    config.faults.fork(2 * i as u64 + 1),
                                ),
                            )
                        })
                        .collect();
                    verifier = FaultyModel::wrap(verifier, config.faults.fork(0x7E51F));
                    drafter = FaultyModel::wrap(drafter, config.faults.fork(0xD2AF7));
                    SlotPool::from_pairs(pairs)
                } else if n_drafters > 1 {
                    // SlotPool::sim builds single-drafter models; pooled
                    // slots are assembled pairwise like the fault path
                    let pairs = (0..n_slots)
                        .map(|_| (mk_draft(), Box::new(SimModel::target(sc)) as Box<dyn LanguageModel>))
                        .collect();
                    SlotPool::from_pairs(pairs)
                } else {
                    SlotPool::sim(quality, rel_cost, n_slots)
                };
                (pool, Codec::Sim, None, verifier, drafter)
            }
        };

        // prefix-reuse routing is a pool property: with it on, checkout
        // is affinity-matched and releases index the recorded resident
        // prefixes (slots.rs, docs/ARCHITECTURE.md §12). Page geometry
        // and sharing ride on top (docs/ARCHITECTURE.md §13) — the pool
        // only activates cross-slot sharing when the backend is adoptive.
        config.page_size = config.page_size.max(1);
        let pool = pool
            .with_paging(config.page_size, config.kv_pages)
            .with_page_sharing(config.page_sharing)
            .with_prefix_cache(config.prefix_cache);

        // the worker engine coalesces verification through the batcher
        // thread; the step loop keeps the verifier and batches directly
        // (it *is* the window)
        let (batcher, verifier) = if !continuous && config.verify_batch.enabled() {
            (Some(Batcher::spawn(verifier, config.verify_batch, stats.clone())?), None)
        } else {
            (None, Some(verifier))
        };

        let shared = Arc::new(EngineShared {
            q: Mutex::new(QueueState {
                sched: Scheduler::new(config.sched),
                waiters: HashMap::new(),
                shutdown: false,
            }),
            cv: Condvar::new(),
            pool,
            codec,
            gamma_max: config.gamma_max,
            n_workers,
            max_queue: config.max_queue,
            batcher: batcher.as_ref().map(|b| b.handle()),
            started: Mutex::new(Instant::now()),
            drafters: SharedDrafters::new(config.drafters),
        });

        // mint every per-thread (Workers) / per-slot (Continuous) session
        // controller up front so a controller build error (e.g. a missing
        // classifier file) fails `start` cleanly before any thread exists
        let n_sessions = if continuous { n_slots } else { n_workers };
        let mut sessions = Vec::with_capacity(n_sessions);
        for _ in 0..n_sessions {
            sessions.push(controller.session()?);
        }
        let mut workers = Vec::new();
        if continuous {
            let sh = shared.clone();
            let m = metrics.clone();
            let st = stats.clone();
            let verify_cap = config.verify_batch.max_batch;
            let pipeline = config.pipeline;
            let verifier = verifier.expect("continuous mode keeps its verifier");
            workers.push(
                std::thread::Builder::new()
                    .name("tapout-stepper".into())
                    .spawn(move || {
                        super::stepper::step_loop(
                            sh, drafter, verifier, sessions, verify_cap, pipeline, m, st,
                        )
                    })?,
            );
        } else {
            // workers draft on their slot's own model; with the batcher
            // off they also verify on their slot's own target
            drop(drafter);
            drop(verifier);
            for (i, session) in sessions.into_iter().enumerate() {
                let sh = shared.clone();
                let m = metrics.clone();
                let st = stats.clone();
                workers.push(
                    std::thread::Builder::new()
                        .name(format!("tapout-worker-{i}"))
                        .spawn(move || worker_loop(i, sh, session, m, st))?,
                );
            }
        }

        let sh = shared.clone();
        let st = stats.clone();
        let dispatcher = std::thread::Builder::new()
            .name("tapout-dispatch".into())
            .spawn(move || dispatcher_loop(sh, rx, st, warm_assets))?;

        Ok(Engine {
            tx,
            dispatcher: Some(dispatcher),
            workers,
            next_id: AtomicU64::new(1),
            metrics,
            stats,
            config,
            controller,
            shared,
            batcher,
        })
    }

    /// Submit a text prompt; returns a receiver for the response.
    pub fn submit(&self, prompt: &str, max_new: usize) -> Receiver<Response> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let req = Request::new(id, prompt, max_new);
        self.submit_request(req)
    }

    /// Submit a pre-built request (pre-encoded prompts, custom category,
    /// deadline, cancel flag). An id of 0 is replaced with a fresh
    /// engine-assigned id.
    pub fn submit_request(&self, req: Request) -> Receiver<Response> {
        let (rtx, rrx) = channel();
        self.dispatch(req, ResponseSink::Unary(rtx));
        rrx
    }

    /// Submit a pre-built request and stream its tokens: the receiver
    /// sees one [`StreamEvent::Tokens`] per committed decode round
    /// (already clipped to the reply contract) and a final
    /// [`StreamEvent::Done`] carrying the full response. Dropping the
    /// receiver mid-stream cancels the request at the next round.
    pub fn submit_request_streaming(&self, req: Request) -> Receiver<StreamEvent> {
        let (rtx, rrx) = channel();
        self.dispatch(req, ResponseSink::Stream(rtx));
        rrx
    }

    /// Common submit path: assign an id if needed, apply the server's
    /// default deadline, hand off to the dispatcher.
    fn dispatch(&self, mut req: Request, sink: ResponseSink) {
        if req.id == 0 {
            req.id = self.next_id.fetch_add(1, Ordering::Relaxed);
        }
        if req.deadline.is_none() && self.config.default_deadline_ms > 0 {
            req.deadline =
                Some(req.arrival + Duration::from_millis(self.config.default_deadline_ms));
        }
        let _ = self.tx.send(Job::Run(req, sink));
    }

    /// Graceful shutdown: queued requests drain, then all threads exit.
    /// The batcher stops last — draining workers still need it to answer
    /// their in-flight verification steps.
    pub fn shutdown(mut self) {
        let _ = self.tx.send(Job::Shutdown);
        if let Some(h) = self.dispatcher.take() {
            let _ = h.join();
        }
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
        if let Some(b) = self.batcher.take() {
            b.shutdown();
        }
    }

    /// The slot pool's prefix-cache gauges (the `/metrics` `engine.cache`
    /// source — docs/ARCHITECTURE.md §12).
    pub fn cache_stats(&self) -> &super::metrics::CacheStats {
        self.shared.pool.cache_stats()
    }

    /// The slot pool's paged-KV gauges (the `/metrics` `engine.pages`
    /// source — docs/ARCHITECTURE.md §13).
    pub fn page_stats(&self) -> &super::metrics::PageStats {
        self.shared.pool.page_stats()
    }

    /// Passthrough to [`super::SlotPool::page_conservation_error`] so
    /// integration suites can assert refcount / free-list balance on a
    /// live engine (the sim harness's oracle polls the same check).
    pub fn page_conservation_error(&self) -> Option<String> {
        self.shared.pool.page_conservation_error()
    }

    // --- shared-bandit readouts (the online-learning observability) ----

    /// Drafting sessions absorbed by the shared controller since boot —
    /// the inter-request / inter-worker carryover counter.
    pub fn bandit_sessions(&self) -> u64 {
        self.controller.sessions()
    }

    /// Verification outcomes absorbed by the shared controller since boot.
    pub fn bandit_updates(&self) -> u64 {
        self.controller.updates()
    }

    /// Per-arm play counts of the shared bandit (None for stateless
    /// methods).
    pub fn bandit_counts(&self) -> Option<Vec<u64>> {
        self.controller.arm_counts()
    }

    /// Per-arm value estimates of the shared bandit (None for stateless
    /// methods and token granularity).
    pub fn bandit_values(&self) -> Option<Vec<f64>> {
        self.controller.arm_values()
    }

    /// Drafter-pool selection ledger (docs/ARCHITECTURE.md §17): the
    /// engine-wide outer-layer bandit state. Always present — pool-of-one
    /// engines report n == 1 with every play on drafter 0. Tests and the
    /// bench harness also use this handle to pin a drafter.
    pub fn drafters(&self) -> Arc<SharedDrafters> {
        self.shared.drafters.clone()
    }

    /// Combined serving report: request samples + worker/queue stats +
    /// shared-bandit state.
    pub fn metrics_json(&self) -> Json {
        // one time base for the whole document: boot → last completed
        // request (what throughput uses); live uptime only before the
        // first completion
        let (mut o, mut span_ns) = {
            let mut m = self.metrics.lock().unwrap();
            (m.to_json(), m.span_ns)
        };
        if span_ns == 0 {
            span_ns = self.shared.started.lock().unwrap().elapsed().as_nanos() as u64;
        }
        let mut eng = self.stats.to_json(span_ns);
        // the pool owns the prefix-cache and paged-KV gauges (it is the
        // cache and the page table)
        eng.set("cache", self.shared.pool.cache_stats().to_json());
        eng.set("pages", self.shared.pool.page_stats().to_json());
        o.set("engine", eng);
        {
            // scheduler ledger: queued + in-flight work and the honest
            // queue-wait estimate (docs/ARCHITECTURE.md §5)
            let q = self.shared.q.lock().unwrap();
            let mut sj = Json::obj();
            sj.set("pending_cost", q.sched.pending_cost() as usize)
                .set("in_flight", q.sched.in_flight())
                .set("in_flight_cost", q.sched.in_flight_cost() as usize)
                .set("queue_wait_est_cost", q.sched.queue_wait_estimate(self.config.workers));
            o.set("sched", sj);
        }
        if self.controller.is_shared() {
            let mut b = Json::obj();
            b.set("method", self.controller.method_label())
                .set("sessions", self.controller.sessions() as usize)
                .set("updates", self.controller.updates() as usize);
            if let Some(counts) = self.controller.arm_counts() {
                b.set("arm_counts", counts.iter().map(|&c| c as f64).collect::<Vec<f64>>());
            }
            if let Some(values) = self.controller.arm_values() {
                b.set("arm_values", values);
            }
            if let Some(names) = self.controller.arm_names() {
                b.set("arm_names", names.iter().map(|n| Json::from(n.as_str())).collect::<Vec<Json>>());
            }
            // per-tenant policy posteriors (docs/OPERATIONS.md): nested
            // under the legacy flat fields, which keep reporting the
            // global-tenant view unchanged
            let tenants = self.controller.tenant_arm_snapshot();
            if !tenants.is_empty() {
                let mut tj = Json::obj();
                for (key, counts, values) in tenants {
                    let mut e = Json::obj();
                    e.set("arm_counts", counts.iter().map(|&c| c as f64).collect::<Vec<f64>>())
                        .set("arm_values", values);
                    tj.set(&key, e);
                }
                b.set("tenants", tj);
            }
            o.set("bandit", b);
        }
        {
            // drafter-layer gauges (docs/OPERATIONS.md `engine.drafters`):
            // outer-bandit ledger, always present
            let d = &self.shared.drafters;
            let mut dj = Json::obj();
            dj.set("n", d.n())
                .set("sessions", d.sessions() as usize)
                .set("updates", d.updates() as usize)
                .set("switches", d.switches() as usize)
                .set("plays", d.plays().iter().map(|&c| c as f64).collect::<Vec<f64>>())
                .set("means", d.means());
            let mut tj = Json::obj();
            for t in d.tenant_snapshot() {
                let mut e = Json::obj();
                e.set("plays", t.plays.iter().map(|&c| c as f64).collect::<Vec<f64>>())
                    .set("means", t.means)
                    .set("obs", t.obs as usize);
                // the global tenant's key is the empty string; render it
                // under a printable name
                let key = if t.tenant.is_empty() { "_global" } else { t.tenant.as_str() };
                tj.set(key, e);
            }
            dj.set("tenants", tj);
            o.set("drafters", dj);
        }
        o
    }
}

fn dispatcher_loop(
    shared: Arc<EngineShared>,
    rx: Receiver<Job>,
    stats: Arc<EngineStats>,
    warm_assets: Option<(Arc<ModelAssets>, Arc<ModelAssets>)>,
) {
    // warm up the step + common verify buckets so first-request latency is
    // not dominated by XLA compilation; failures fall back to lazy compile
    if let Some((draft, target)) = warm_assets {
        if let Err(e) = draft
            .exes
            .warmup(&[1, 4, 128, 256])
            .and_then(|_| target.exes.warmup(&[1, 8, 16, 128, 256]))
        {
            eprintln!("[engine] warmup failed (continuing lazily): {e:#}");
        }
        // serving span starts after compilation, as in the seed engine
        *shared.started.lock().unwrap() = Instant::now();
    }

    loop {
        match rx.recv() {
            Ok(Job::Run(mut req, sink)) => {
                if req.prompt.is_empty() {
                    req.prompt = shared.codec.encode_prompt(&req.prompt_text);
                }
                // affinity placement hint (docs/ARCHITECTURE.md §12):
                // tokens a slot checkout is expected to reuse, so the
                // SJF cost estimate can subtract the prefill the cache
                // will skip. Advisory — 0 with the cache off, and a
                // stale hint only perturbs queue order, never output.
                req.cached_hint = shared.pool.peek_reuse(&req.prompt);
                stats.submitted.fetch_add(1, Ordering::Relaxed);
                {
                    let mut q = shared.q.lock().unwrap();
                    // admission control (docs/ARCHITECTURE.md §10): a
                    // full queue sheds the arrival with an explicit
                    // Rejected reply (HTTP 429) instead of queueing
                    // unboundedly; the 429 carries the SJF ledger's
                    // queue-wait estimate so clients can back off
                    // intelligently. Before shedding, evict queued
                    // entries that are already dead (cancelled or past
                    // deadline) — they must not hold seats a live
                    // arrival could use.
                    if shared.max_queue > 0 && q.sched.len() >= shared.max_queue {
                        for dead in q.sched.drain_dead() {
                            let status = if dead.cancel.is_cancelled() {
                                FinishStatus::Cancelled
                            } else {
                                FinishStatus::Expired
                            };
                            note_lifecycle(&stats, status);
                            if let Some(dead_sink) = q.waiters.remove(&dead.id) {
                                let ns = dead.arrival.elapsed().as_nanos() as u64;
                                dead_sink.send_final(Response::terminal(
                                    dead.id,
                                    status,
                                    ns,
                                    ns,
                                    "evicted from queue: request no longer live",
                                ));
                            }
                        }
                    }
                    if shared.max_queue > 0 && q.sched.len() >= shared.max_queue {
                        let depth = q.sched.len();
                        let est = q.sched.queue_wait_estimate(shared.n_workers);
                        drop(q);
                        stats.lifecycle.rejected.fetch_add(1, Ordering::Relaxed);
                        let now_ns = req.arrival.elapsed().as_nanos() as u64;
                        sink.send_final(Response::terminal(
                            req.id,
                            FinishStatus::Rejected,
                            now_ns,
                            now_ns,
                            format!(
                                "queue full ({depth} queued, max {}): request shed; \
                                 queue-wait estimate {est:.0} cost units",
                                shared.max_queue
                            ),
                        ));
                        continue;
                    }
                    q.waiters.insert(req.id, sink);
                    q.sched.push(req);
                    stats.note_depth(q.sched.len());
                }
                shared.cv.notify_one();
            }
            Ok(Job::Shutdown) | Err(_) => {
                shared.q.lock().unwrap().shutdown = true;
                shared.cv.notify_all();
                return;
            }
        }
    }
}

/// How one step-driven decode ended (docs/ARCHITECTURE.md §10). The
/// cancelled/expired arms carry the partial result committed up to the
/// step boundary that observed the exit condition.
enum DecodeEnd {
    Complete(crate::spec::GenResult),
    Cancelled(crate::spec::GenResult),
    /// cancelled, but observed via a step *error* (e.g. a batcher seat
    /// dropped mid-round, or a backend failure racing the cancel): the
    /// reply is the same `Cancelled`, but the slot's resident sequence
    /// state did not stop at a clean round boundary and must not be
    /// recorded for prefix reuse (docs/ARCHITECTURE.md §12)
    CancelledDirty(crate::spec::GenResult),
    Expired(crate::spec::GenResult),
    Failed(anyhow::Error),
}

/// Drive one request's [`SpecSession`] to an end state: step through
/// draft→verify→accept rounds, stream each round's clipped tokens into
/// the sink, and honor the cancellation flag and deadline at every step
/// boundary. Decoding stops as soon as the reply is fully determined
/// (clip window closed), so post-EOS / post-budget rounds are never run.
///
/// `resident` is the cache-hit prefix both models already cover
/// (docs/ARCHITECTURE.md §12): the session resumes at that cursor and
/// prefills only the prompt suffix. 0 = fresh decode (the caller has
/// already reset the models via `retain_prefix`).
#[allow(clippy::too_many_arguments)]
fn drive_session(
    draft: &mut dyn LanguageModel,
    target: &mut dyn LanguageModel,
    session: &mut SessionController,
    rng: &mut Rng,
    req: &Request,
    sink: &ResponseSink,
    shared: &EngineShared,
    resident: usize,
) -> DecodeEnd {
    let gen_cfg = GenConfig {
        max_new: req.max_new,
        gamma_max: shared.gamma_max,
        stop_at_eos: true,
        collect_signals: false,
    };
    let mut sess =
        match SpecSession::resume(draft, target, session, rng, &req.prompt, &gen_cfg, resident) {
            Ok(s) => s,
            Err(e) => return DecodeEnd::Failed(e),
        };
    // drafter-pool routing (docs/ARCHITECTURE.md §17): every round picks
    // a drafter for this request's tenant and every verify settles the
    // full-information reward — byte-identical pass-through for a pool
    // of one
    sess.set_drafter_hook(DrafterHook::new(
        shared.drafters.clone(),
        req.tenant.clone(),
        req.scenario_seed(),
        req.category.clone(),
    ));
    let mut clip = EmitClip::new(req.max_new);
    loop {
        // lifecycle checks sit at the step boundary — the decode core
        // stays oblivious to cancellation and deadlines
        if req.cancel.is_cancelled() {
            return DecodeEnd::Cancelled(sess.finish());
        }
        if req.deadline_expired() {
            return DecodeEnd::Expired(sess.finish());
        }
        match sess.step() {
            Ok(StepOutcome::Finished(_)) => return DecodeEnd::Complete(sess.finish()),
            Ok(StepOutcome::Round(commit)) => {
                let (emit, done) = clip.clip(&commit.new_tokens);
                if !emit.is_empty()
                    && sink.wants_tokens()
                    && !sink.send_tokens(req.id, emit, shared.codec.decode(emit))
                {
                    // the stream receiver is gone: client disconnected —
                    // flag the request so the batcher drops any pending
                    // seat too, and exit as Cancelled
                    req.cancel.cancel();
                    return DecodeEnd::Cancelled(sess.finish());
                }
                if done {
                    return DecodeEnd::Complete(sess.finish());
                }
            }
            Err(e) => {
                // a batcher seat dropped on cancellation surfaces as a
                // step error; report it as the cancellation it is — but
                // flag the slot state as dirty (the error may equally be
                // a real backend failure racing the cancel)
                if req.cancel.is_cancelled() {
                    return DecodeEnd::CancelledDirty(sess.finish());
                }
                return DecodeEnd::Failed(e);
            }
        }
    }
}

fn worker_loop(
    worker_id: usize,
    shared: Arc<EngineShared>,
    mut session: SessionController,
    metrics: Arc<Mutex<EngineMetrics>>,
    stats: Arc<EngineStats>,
) {
    let mut rng = Rng::new(0xE46C0DE ^ ((worker_id as u64) << 8));
    loop {
        // pull the next request per scheduling policy (queued work drains
        // even after shutdown is flagged)
        let job = {
            let mut q = shared.q.lock().unwrap();
            loop {
                if let Some(req) = q.sched.pop() {
                    stats.note_depth(q.sched.len());
                    let reply = q.waiters.remove(&req.id);
                    break Some((req, reply));
                }
                if q.shutdown {
                    break None;
                }
                q = shared.cv.wait(q).unwrap();
            }
        };
        let Some((mut req, reply)) = job else { return };
        let Some(sink) = reply else {
            // no waiter registered (should not happen) — just release the
            // scheduler's in-flight ledger entry
            shared.q.lock().unwrap().sched.note_done(req.sched_cost());
            continue;
        };
        let wstats = &stats.workers[worker_id];

        // interruptible affinity slot checkout (docs/ARCHITECTURE.md
        // §12): the pool routes the request to the free slot sharing the
        // longest resident prefix with its prompt; a request that is
        // cancelled or expires while waiting for a KV slot exits here
        // without ever decoding (its seat frees instantly for the next
        // request)
        let t_wait = Instant::now();
        let mut got = None;
        let mut exit: Option<(FinishStatus, &'static str)> = None;
        loop {
            if req.cancel.is_cancelled() {
                exit = Some((FinishStatus::Cancelled, "cancelled before decode"));
                break;
            }
            if req.deadline_expired() {
                exit = Some((FinishStatus::Expired, "deadline expired before decode"));
                break;
            }
            if let Some(sr) = shared.pool.acquire_for_timeout(&req.prompt, SLOT_POLL) {
                got = Some(sr);
                break;
            }
        }
        wstats
            .slot_wait_ns
            .fetch_add(t_wait.elapsed().as_nanos() as u64, Ordering::Relaxed);

        if let Some((status, why)) = exit {
            shared.q.lock().unwrap().sched.note_done(req.sched_cost());
            note_lifecycle(&stats, status);
            let now_ns = req.arrival.elapsed().as_nanos() as u64;
            sink.send_final(Response::terminal(req.id, status, now_ns, now_ns, why));
            continue;
        }
        let (mut slot, lease) = got.expect("no exit implies a checked-out slot");

        // the dispatcher's `cached_hint` was advisory: the residency it
        // saw at enqueue can be consumed (or appear) before dispatch,
        // which would leave the SJF in-flight ledger charged for a
        // different discount than the checkout actually granted.
        // Re-resolve the hint against the lease and reprice the ledger so
        // the final `note_done` releases exactly what is now charged.
        if req.cached_hint != lease.shared {
            let stale = req.sched_cost();
            req.cached_hint = lease.shared;
            shared.q.lock().unwrap().sched.reprice(stale, req.sched_cost());
        }

        // queueing delay = arrival → decode start, *including* the slot
        // wait — under workers > slots contention that wait is real
        // queueing and must show up in queue/TTFT percentiles
        let queue_ns = req.arrival.elapsed().as_nanos() as u64;

        let seed = req.scenario_seed();
        let draft_before = slot.draft.cost();
        // reset-vs-adopt (slots.rs): a miss (empty lease) starts the
        // slot's sequence state fresh; a hit adopts the leased residency —
        // the full page-vouched `shared` depth on adoptive backends, the
        // slot's own `local` depth otherwise — and the session resumes at
        // min(draft, target) adopted positions
        let resident_draft =
            slot.draft.adopt_pages(seed, &req.category, lease.local, lease.shared);
        let t_busy = Instant::now();
        let (end, target_cur) = match &shared.batcher {
            Some(handle) => {
                // batched path (docs/ARCHITECTURE.md §4): target steps are
                // submitted to the batcher keyed by this slot's id; the
                // slot's own target stays resident but idle. The cancel
                // flag rides along so the batcher can drop this session's
                // pending seat without stalling the fill window.
                let mut target = BatchedTarget::new(
                    slot.id,
                    handle.clone(),
                    slot.target.max_seq(),
                    slot.target.rel_cost(),
                )
                .with_cancel(req.cancel.clone());
                let resident = resident_draft
                    .min(target.adopt_pages(seed, &req.category, lease.local, lease.shared));
                handle.note_decode_start();
                let r = drive_session(
                    slot.draft.as_mut(),
                    &mut target,
                    &mut session,
                    &mut rng,
                    &req,
                    &sink,
                    &shared,
                    resident,
                );
                handle.note_decode_end();
                (r, target.cur())
            }
            None => {
                let resident = resident_draft
                    .min(slot.target.adopt_pages(seed, &req.category, lease.local, lease.shared));
                let r = drive_session(
                    slot.draft.as_mut(),
                    slot.target.as_mut(),
                    &mut session,
                    &mut rng,
                    &req,
                    &sink,
                    &shared,
                    resident,
                );
                (r, slot.target.cur())
            }
        };
        wstats
            .busy_ns
            .fetch_add(t_busy.elapsed().as_nanos() as u64, Ordering::Relaxed);
        // draft-side dispatch accounting (`engine.draft`): this request's
        // cost delta on the slot's draft model, so Workers and Continuous
        // mode are comparable forward-for-forward (every workers-mode
        // dispatch serves exactly one session)
        let dc = slot.draft.cost();
        let calls = dc.calls.saturating_sub(draft_before.calls);
        stats.draft.note(
            calls as usize,
            calls,
            dc.rows.saturating_sub(draft_before.rows),
            dc.padded_rows.saturating_sub(draft_before.padded_rows),
        );
        // record the slot's resident prefix for affinity routing
        // (docs/ARCHITECTURE.md §12): the committed sequence truncated to
        // the lower of the two cursors. A failed (or error-cancelled)
        // decode leaves the resident state untrusted, so the record is
        // cleared and the next tenant starts fresh. With the cache off
        // nothing records — release would drop it anyway.
        if shared.pool.prefix_cache_enabled() {
            let watermark = slot.draft.cur().min(target_cur);
            match &end {
                DecodeEnd::Failed(_) | DecodeEnd::CancelledDirty(_) => slot.clear_prefix(),
                DecodeEnd::Complete(r) | DecodeEnd::Cancelled(r) | DecodeEnd::Expired(r) => {
                    slot.record_prefix(&r.tokens, watermark);
                }
            }
        }
        shared.pool.release(slot);
        wstats.requests.fetch_add(1, Ordering::Relaxed);
        // release this request from the scheduler's in-flight ledger so
        // the queue-wait estimate stays honest (scheduler.rs)
        shared.q.lock().unwrap().sched.note_done(req.sched_cost());

        let resp = match end {
            DecodeEnd::Complete(result) => {
                finish_response(&shared, &req, result, FinishStatus::Done, None, queue_ns)
            }
            DecodeEnd::Cancelled(result) | DecodeEnd::CancelledDirty(result) => {
                note_lifecycle(&stats, FinishStatus::Cancelled);
                finish_response(
                    &shared,
                    &req,
                    result,
                    FinishStatus::Cancelled,
                    Some("cancelled mid-decode".into()),
                    queue_ns,
                )
            }
            DecodeEnd::Expired(result) => {
                note_lifecycle(&stats, FinishStatus::Expired);
                finish_response(
                    &shared,
                    &req,
                    result,
                    FinishStatus::Expired,
                    Some("deadline expired mid-decode".into()),
                    queue_ns,
                )
            }
            DecodeEnd::Failed(e) => {
                eprintln!("[engine] request {} failed: {e:#}", req.id);
                wstats.errors.fetch_add(1, Ordering::Relaxed);
                Response::failure(
                    req.id,
                    queue_ns,
                    req.arrival.elapsed().as_nanos() as u64,
                    format!("{e:#}"),
                )
            }
        };
        {
            // span read under the metrics lock so a preempted worker can
            // never overwrite a later worker's larger span with a smaller
            // one (which would overstate throughput)
            let mut m = metrics.lock().unwrap();
            m.record(&resp);
            m.span_ns = shared.started.lock().unwrap().elapsed().as_nanos() as u64;
        }
        sink.send_final(resp);
    }
}

/// Bump the matching lifecycle counter for a non-completion exit.
pub(crate) fn note_lifecycle(stats: &EngineStats, status: FinishStatus) {
    match status {
        FinishStatus::Cancelled => &stats.lifecycle.cancelled,
        FinishStatus::Expired => &stats.lifecycle.expired,
        FinishStatus::Rejected => &stats.lifecycle.rejected,
        FinishStatus::Done | FinishStatus::Failed => return,
    }
    .fetch_add(1, Ordering::Relaxed);
}

/// Apply the serving reply contract to a (possibly partial) decode
/// result and build the terminal response. The contract: never more than
/// max_new tokens, nothing past the first EOS. The last verification
/// round may overshoot both (verification is atomic), and the overshoot
/// depends on which arm the bandit played — capping here makes the reply
/// a pure function of the prompt, identical across worker counts,
/// streaming modes, and batch windows. The cap is computed with the same
/// [`EmitClip`] that clipped the streamed chunks (one shot over the full
/// suffix == its round-by-round application, pinned by the EmitClip unit
/// tests), so the streamed-concatenation-equals-body guarantee has a
/// single implementation.
pub(crate) fn finish_response(
    shared: &EngineShared,
    req: &Request,
    mut result: crate::spec::GenResult,
    status: FinishStatus,
    error: Option<String>,
    queue_ns: u64,
) -> Response {
    let keep = {
        let mut clip = EmitClip::new(req.max_new);
        clip.clip(result.new_tokens()).0.len()
    };
    result.tokens.truncate(result.prompt_len + keep);
    Response {
        id: req.id,
        text: shared.codec.decode(result.new_tokens()),
        queue_ns,
        total_ns: req.arrival.elapsed().as_nanos() as u64,
        result,
        status,
        error,
    }
}
