//! `TokenSignals` — the L1 fused stop-signal head's per-token output.
//!
//! Mirrors python/compile/kernels/signals.py exactly: one 8-float row per
//! drafted position, read from the device out-region. Every stop policy
//! consumes only this struct, so the policies are backend-agnostic (PJRT
//! models and the simulator produce the same shape).

/// Floats per signal row (the L1 kernel's fixed output width).
pub const SIG_WIDTH: usize = 8;

/// One drafted position's stop-signal row.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TokenSignals {
    /// argmax token id (greedy proposal / greedy verification token)
    pub argmax: u32,
    /// p(top-1)
    pub top1: f32,
    /// p(top-2)
    pub top2: f32,
    /// top1 - top2
    pub margin: f32,
    /// H(p) in nats
    pub entropy: f32,
    /// sqrt(H(p)) — the SVIP statistic
    pub sqrt_entropy: f32,
    /// logsumexp of the logits
    pub logsumexp: f32,
    /// max logit
    pub max_logit: f32,
}

impl TokenSignals {
    /// Parse one 8-float device row.
    pub fn from_row(row: &[f32]) -> TokenSignals {
        debug_assert!(row.len() >= SIG_WIDTH);
        TokenSignals {
            argmax: row[0] as u32,
            top1: row[1],
            top2: row[2],
            margin: row[3],
            entropy: row[4],
            sqrt_entropy: row[5],
            logsumexp: row[6],
            max_logit: row[7],
        }
    }

    /// Compute signals from a raw logits row (host-side reference path;
    /// used by the simulator backend and unit tests).
    pub fn from_logits(logits: &[f32]) -> TokenSignals {
        assert!(logits.len() >= 2);
        let mut max = f32::NEG_INFINITY;
        let mut argmax = 0usize;
        for (i, &x) in logits.iter().enumerate() {
            if x > max {
                max = x;
                argmax = i;
            }
        }
        let mut sum = 0.0f64;
        let mut ex = 0.0f64; // sum e*(x-m)
        let mut max2 = f32::NEG_INFINITY;
        for (i, &x) in logits.iter().enumerate() {
            let e = ((x - max) as f64).exp();
            sum += e;
            ex += e * (x - max) as f64;
            if i != argmax && x > max2 {
                max2 = x;
            }
        }
        let lse = max as f64 + sum.ln();
        let top1 = (1.0 / sum) as f32; // exp(0)/sum
        let top2 = (((max2 - max) as f64).exp() / sum) as f32;
        // H = lse - E_p[x] = ln(sum) - ex/sum
        let ent = (sum.ln() - ex / sum).max(0.0) as f32;
        TokenSignals {
            argmax: argmax as u32,
            top1,
            top2,
            margin: top1 - top2,
            entropy: ent,
            sqrt_entropy: ent.sqrt(),
            logsumexp: lse as f32,
            max_logit: max,
        }
    }

    /// Serialize back to the 8-float device layout.
    pub fn to_row(&self) -> [f32; SIG_WIDTH] {
        [
            self.argmax as f32,
            self.top1,
            self.top2,
            self.margin,
            self.entropy,
            self.sqrt_entropy,
            self.logsumexp,
            self.max_logit,
        ]
    }

    /// Parse consecutive rows from a flat out-region slice.
    pub fn parse_rows(flat: &[f32], n: usize) -> Vec<TokenSignals> {
        (0..n)
            .map(|i| TokenSignals::from_row(&flat[i * SIG_WIDTH..(i + 1) * SIG_WIDTH]))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_logits_uniform() {
        let v = 96;
        let s = TokenSignals::from_logits(&vec![0.0; v]);
        assert!((s.top1 - 1.0 / v as f32).abs() < 1e-6);
        assert!((s.entropy - (v as f32).ln()).abs() < 1e-4);
        assert!(s.margin.abs() < 1e-6);
    }

    #[test]
    fn from_logits_peaked() {
        let mut x = vec![0.0f32; 50];
        x[17] = 50.0;
        let s = TokenSignals::from_logits(&x);
        assert_eq!(s.argmax, 17);
        assert!(s.top1 > 0.999);
        assert!(s.entropy < 1e-3);
    }

    #[test]
    fn row_roundtrip() {
        let s = TokenSignals::from_logits(&[1.0, 3.0, 2.0, -1.0]);
        let r = s.to_row();
        let s2 = TokenSignals::from_row(&r);
        assert_eq!(s, s2);
        let rows: Vec<f32> = [s.to_row(), s.to_row()].concat();
        assert_eq!(TokenSignals::parse_rows(&rows, 2), vec![s, s2]);
    }

    #[test]
    fn entropy_consistency_vs_direct() {
        // direct -sum p ln p on a random-ish row
        let x: Vec<f32> = (0..32).map(|i| ((i * 37 % 13) as f32) * 0.37 - 2.0).collect();
        let s = TokenSignals::from_logits(&x);
        let m = x.iter().cloned().fold(f32::MIN, f32::max);
        let es: Vec<f64> = x.iter().map(|&v| ((v - m) as f64).exp()).collect();
        let z: f64 = es.iter().sum();
        let h: f64 = -es.iter().map(|e| (e / z) * (e / z).ln()).sum::<f64>();
        assert!((s.entropy as f64 - h).abs() < 1e-5, "{} vs {h}", s.entropy);
        assert!((s.top1 + s.top2 - s.margin - 2.0 * s.top2).abs() < 1e-6);
    }
}
