//! SpecDec++ (Huang et al., 2025) — the training-based baseline of paper
//! Table 4. Inference re-implementation of the residual MLP trained at
//! build time by python/compile/train_classifier.py; weights come from
//! artifacts/specdecpp.json.
//!
//! Features (standardized): [top1, top2, margin, entropy, sqrt_entropy,
//! position/16, ema_accept]. Stops when p(accept) < threshold (0.7).

use crate::signals::TokenSignals;
use crate::util::Json;

use super::StopPolicy;

#[derive(Clone, Debug)]
struct Layer {
    w: Vec<Vec<f32>>, // [in][out]
    b: Vec<f32>,
}

/// SpecDec++ acceptance classifier (residual MLP, build-time trained).
#[derive(Clone, Debug)]
pub struct SpecDecPP {
    mean: Vec<f32>,
    std: Vec<f32>,
    layers: Vec<Layer>,
    /// stop when p(accept) falls below this
    pub threshold: f32,
    ema_accept: f32,
}

fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

impl SpecDecPP {
    /// Parse classifier weights from the artifact JSON document.
    pub fn from_json(j: &Json) -> Result<SpecDecPP, String> {
        let grab = |k: &str| -> Result<Vec<f32>, String> {
            Ok(j.get(k).ok_or(format!("missing {k}"))?.f64s().iter().map(|&x| x as f32).collect())
        };
        let mut layers = Vec::new();
        for lj in j.get("layers").and_then(|x| x.as_arr()).ok_or("missing layers")? {
            let w = lj
                .get("w")
                .and_then(|x| x.as_arr())
                .ok_or("missing w")?
                .iter()
                .map(|row| row.f64s().iter().map(|&x| x as f32).collect())
                .collect();
            let b = lj.get("b").ok_or("missing b")?.f64s().iter().map(|&x| x as f32).collect();
            layers.push(Layer { w, b });
        }
        Ok(SpecDecPP {
            mean: grab("mean")?,
            std: grab("std")?,
            layers,
            threshold: j.get("threshold").and_then(|x| x.as_f64()).unwrap_or(0.7) as f32,
            ema_accept: 0.7,
        })
    }

    /// Load classifier weights from `artifacts/specdecpp.json`.
    pub fn load(path: &std::path::Path) -> Result<SpecDecPP, String> {
        let text = std::fs::read_to_string(path).map_err(|e| e.to_string())?;
        SpecDecPP::from_json(&Json::parse(&text)?)
    }

    fn matvec(l: &Layer, x: &[f32]) -> Vec<f32> {
        let nout = l.b.len();
        let mut out = l.b.clone();
        for (i, &xi) in x.iter().enumerate() {
            let row = &l.w[i];
            for o in 0..nout {
                out[o] += xi * row[o];
            }
        }
        out
    }

    /// p(accept) for a drafted token.
    pub fn predict(&self, sig: &TokenSignals, idx: usize) -> f32 {
        let raw = [
            sig.top1,
            sig.top2,
            sig.margin,
            sig.entropy,
            sig.sqrt_entropy,
            idx as f32 / 16.0,
            self.ema_accept,
        ];
        let x: Vec<f32> = raw
            .iter()
            .zip(self.mean.iter().zip(&self.std))
            .map(|(&v, (&m, &s))| (v - m) / s)
            .collect();
        // input layer
        let mut h: Vec<f32> = Self::matvec(&self.layers[0], &x).iter().map(|&v| silu(v)).collect();
        // residual blocks
        for l in &self.layers[1..self.layers.len() - 1] {
            let y = Self::matvec(l, &h);
            for (hi, yi) in h.iter_mut().zip(y) {
                *hi += silu(yi);
            }
        }
        let logit = Self::matvec(&self.layers[self.layers.len() - 1], &h)[0];
        1.0 / (1.0 + (-logit).exp())
    }
}

impl StopPolicy for SpecDecPP {
    fn name(&self) -> String {
        format!("specdec++@{:.2}", self.threshold)
    }

    fn should_stop(&mut self, sig: &TokenSignals, idx: usize) -> bool {
        self.predict(sig, idx) < self.threshold
    }

    fn on_verify(&mut self, accepted: usize, drafted: usize) {
        if drafted > 0 {
            let r = accepted as f32 / drafted as f32;
            self.ema_accept = 0.9 * self.ema_accept + 0.1 * r;
        }
    }

    fn reset(&mut self) {
        self.ema_accept = 0.7;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Tiny hand-built classifier: p(accept) rises with margin.
    fn toy() -> SpecDecPP {
        let j = Json::parse(
            r#"{
              "mean": [0,0,0,0,0,0,0], "std": [1,1,1,1,1,1,1],
              "threshold": 0.5,
              "layers": [
                {"w": [[0,0],[0,0],[4,4],[0,0],[0,0],[0,0],[0,0]], "b": [0,0]},
                {"w": [[0,0],[0,0]], "b": [0,0]},
                {"w": [[1],[1]], "b": [0]}
              ]
            }"#,
        )
        .unwrap();
        SpecDecPP::from_json(&j).unwrap()
    }

    fn sig(margin: f32) -> TokenSignals {
        TokenSignals {
            argmax: 0, top1: 0.5, top2: 0.5 - margin, margin, entropy: 0.0,
            sqrt_entropy: 0.0, logsumexp: 0.0, max_logit: 0.0,
        }
    }

    #[test]
    fn monotone_in_strong_feature() {
        let c = toy();
        let lo = c.predict(&sig(-1.0), 0);
        let hi = c.predict(&sig(1.0), 0);
        assert!(hi > lo);
        assert!((0.0..=1.0).contains(&lo) && (0.0..=1.0).contains(&hi));
    }

    #[test]
    fn stop_decision_follows_threshold() {
        let mut c = toy();
        assert!(c.should_stop(&sig(-1.0), 0)); // low p(accept)
        assert!(!c.should_stop(&sig(1.0), 0)); // high p(accept)
    }

    #[test]
    fn ema_updates_and_resets() {
        let mut c = toy();
        c.on_verify(0, 8);
        assert!(c.ema_accept < 0.7);
        c.reset();
        assert_eq!(c.ema_accept, 0.7);
    }
}
