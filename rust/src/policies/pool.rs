//! Arm-pool construction for the TapOut bandit.
//!
//! `default_arms()` is the paper's Table 1 configuration: one arm per
//! training-free technique with its fixed (untuned) threshold.
//! `multi_threshold_arms()` is the App. A.2 ablation pool: several
//! thresholds per technique (found there to be ~12% *worse* overall).

use super::{AdaEdl, BoxedPolicy, LogitMargin, MaxConfidence, Svip, SvipDiff};

/// Paper Table 1: the five training-free arms with fixed thresholds.
pub fn default_arms() -> Vec<BoxedPolicy> {
    vec![
        Box::new(MaxConfidence::new(0.8)),
        Box::new(Svip::new(0.6)),
        Box::new(AdaEdl::default()),
        Box::new(SvipDiff::new(0.2)),
        Box::new(LogitMargin::new(0.2)),
    ]
}

/// Names of the Table 1 arms, in pool order.
pub fn arm_names() -> Vec<String> {
    default_arms().iter().map(|a| a.name()).collect()
}

/// App. A.2 ablation: 3 thresholds per thresholded technique (13 arms).
pub fn multi_threshold_arms() -> Vec<BoxedPolicy> {
    let mut arms: Vec<BoxedPolicy> = Vec::new();
    for h in [0.6, 0.8, 0.9] {
        arms.push(Box::new(MaxConfidence::new(h)));
    }
    for h in [0.2, 0.4, 0.6] {
        arms.push(Box::new(Svip::new(h)));
    }
    arms.push(Box::new(AdaEdl::default()));
    for h in [0.1, 0.2, 0.4] {
        arms.push(Box::new(SvipDiff::new(h)));
    }
    for h in [0.1, 0.2, 0.4] {
        arms.push(Box::new(LogitMargin::new(h)));
    }
    arms
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_pool_matches_table1() {
        let arms = default_arms();
        assert_eq!(arms.len(), 5);
        let names: Vec<String> = arms.iter().map(|a| a.name()).collect();
        assert!(names.iter().any(|n| n.starts_with("max-conf@0.80")));
        assert!(names.iter().any(|n| n.starts_with("svip@0.60")));
        assert!(names.iter().any(|n| n.starts_with("ada-edl")));
        assert!(names.iter().any(|n| n.starts_with("svip-diff@0.20")));
        assert!(names.iter().any(|n| n.starts_with("logit-margin@0.20")));
    }

    #[test]
    fn ablation_pool_is_larger_and_distinct() {
        let arms = multi_threshold_arms();
        assert_eq!(arms.len(), 13);
        let mut names: Vec<String> = arms.iter().map(|a| a.name()).collect();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), 13, "arm names must be unique");
    }
}
