//! SVIP-Difference (paper App. A.1, one of TapOut's two new arms): stop on
//! an entropy *spike* — sqrt(H_t) - sqrt(H_{t-1}) > h. Catches transitions
//! from confident runs into uncertain territory even when the absolute
//! entropy is still below a global threshold.

use super::StopPolicy;
use crate::signals::TokenSignals;

/// Stop on a sqrt-entropy *spike* larger than `h`.
#[derive(Clone, Debug)]
pub struct SvipDiff {
    /// spike threshold
    pub h: f32,
    prev: Option<f32>,
}

impl SvipDiff {
    /// Paper default threshold h = 0.2.
    pub fn new(h: f32) -> Self {
        SvipDiff { h, prev: None }
    }
}

impl Default for SvipDiff {
    fn default() -> Self {
        SvipDiff::new(0.2)
    }
}

impl StopPolicy for SvipDiff {
    fn name(&self) -> String {
        format!("svip-diff@{:.2}", self.h)
    }

    fn on_session_start(&mut self) {
        self.prev = None;
    }

    fn should_stop(&mut self, sig: &TokenSignals, _idx: usize) -> bool {
        let stop = match self.prev {
            Some(prev) => sig.sqrt_entropy - prev > self.h,
            None => false, // no spike measurable on the first token
        };
        self.prev = Some(sig.sqrt_entropy);
        stop
    }

    fn reset(&mut self) {
        self.prev = None;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sig(sq: f32) -> TokenSignals {
        TokenSignals {
            argmax: 0, top1: 0.5, top2: 0.1, margin: 0.4, entropy: sq * sq,
            sqrt_entropy: sq, logsumexp: 0.0, max_logit: 0.0,
        }
    }

    #[test]
    fn stops_on_spike_not_level() {
        let mut p = SvipDiff::new(0.2);
        p.on_session_start();
        assert!(!p.should_stop(&sig(1.0), 0)); // high but first token
        assert!(!p.should_stop(&sig(1.1), 1)); // drift, no spike
        assert!(p.should_stop(&sig(1.5), 2)); // spike of 0.4
    }

    #[test]
    fn session_start_clears_history() {
        let mut p = SvipDiff::new(0.2);
        p.on_session_start();
        assert!(!p.should_stop(&sig(0.1), 0));
        p.on_session_start();
        // would be a spike vs 0.1, but history was cleared
        assert!(!p.should_stop(&sig(0.9), 0));
    }
}
