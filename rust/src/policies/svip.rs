//! SVIP (Zhang et al., 2025; paper Table 1): stop when sqrt(H(p)) > h —
//! draft-model entropy as a self-verification signal.

use super::StopPolicy;
use crate::signals::TokenSignals;

/// Stop when sqrt-entropy exceeds `h`.
#[derive(Clone, Debug)]
pub struct Svip {
    /// sqrt-entropy threshold
    pub h: f32,
}

impl Svip {
    /// Paper default threshold h = 0.6.
    pub fn new(h: f32) -> Self {
        Svip { h }
    }
}

impl Default for Svip {
    fn default() -> Self {
        Svip::new(0.6)
    }
}

impl StopPolicy for Svip {
    fn name(&self) -> String {
        format!("svip@{:.2}", self.h)
    }

    fn should_stop(&mut self, sig: &TokenSignals, _idx: usize) -> bool {
        sig.sqrt_entropy > self.h
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sig(sqrt_entropy: f32) -> TokenSignals {
        TokenSignals {
            argmax: 0, top1: 0.5, top2: 0.1, margin: 0.4,
            entropy: sqrt_entropy * sqrt_entropy, sqrt_entropy,
            logsumexp: 0.0, max_logit: 0.0,
        }
    }

    #[test]
    fn stops_on_high_entropy() {
        let mut p = Svip::new(0.6);
        assert!(!p.should_stop(&sig(0.3), 0));
        assert!(p.should_stop(&sig(0.9), 1));
    }
}
