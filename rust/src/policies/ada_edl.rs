//! AdaEDL (Agrawal et al., 2024; paper App. A.1): entropy-based lower bound
//! on the token acceptance probability with an *adaptive* threshold λ.
//!
//! Stop when  1 - sqrt(γ_e · H(p)) < λ_t.
//! After each verification round with acceptance ratio r:
//!     accept_rate ← β1·accept_rate + (1-β1)·r
//!     λ ← β2·λ + (1-β2)·(λ + ε·sign(α - r))
//! i.e. λ creeps up (stop earlier) while acceptance runs below the target
//! α and creeps down when acceptance is comfortable.

use super::StopPolicy;
use crate::signals::TokenSignals;

/// AdaEDL: entropy lower bound on acceptance with adaptive threshold λ.
#[derive(Clone, Debug)]
pub struct AdaEdl {
    /// entropy scale γ_e (the paper overloads γ; this is AdaEDL's own
    /// scaling hyperparameter, not the draft length)
    pub gamma_e: f32,
    /// target acceptance ratio α
    pub alpha: f32,
    /// EMA factor of the tracked acceptance rate
    pub beta1: f32,
    /// EMA factor of the λ drift
    pub beta2: f32,
    /// λ drift step per verification round
    pub epsilon: f32,
    lambda0: f32,
    lambda: f32,
    accept_rate: f32,
}

impl AdaEdl {
    /// AdaEDL with entropy scale `gamma_e` and initial threshold `lambda0`.
    pub fn new(gamma_e: f32, lambda0: f32) -> Self {
        AdaEdl {
            gamma_e,
            alpha: 0.8,
            beta1: 0.9,
            beta2: 0.9,
            epsilon: 0.02,
            lambda0,
            lambda: lambda0,
            accept_rate: 0.8,
        }
    }

    /// Current adaptive threshold λ.
    pub fn lambda(&self) -> f32 {
        self.lambda
    }
}

impl Default for AdaEdl {
    fn default() -> Self {
        // gamma_e scaled for the char-level vocab (H up to ln 96 ≈ 4.6):
        // sqrt(0.15 * H) spans [0, 0.83] over realistic entropies.
        AdaEdl::new(0.15, 0.45)
    }
}

impl StopPolicy for AdaEdl {
    fn name(&self) -> String {
        format!("ada-edl@g{:.2}", self.gamma_e)
    }

    fn should_stop(&mut self, sig: &TokenSignals, _idx: usize) -> bool {
        // 1 - sqrt(γ_e·H) is the acceptance-probability lower bound
        1.0 - (self.gamma_e * sig.entropy).max(0.0).sqrt() < self.lambda
    }

    fn on_verify(&mut self, accepted: usize, drafted: usize) {
        if drafted == 0 {
            return;
        }
        let r = accepted as f32 / drafted as f32;
        self.accept_rate = self.beta1 * self.accept_rate + (1.0 - self.beta1) * r;
        let drift = self.epsilon * (self.alpha - r).signum();
        self.lambda = self.beta2 * self.lambda + (1.0 - self.beta2) * (self.lambda + drift);
        self.lambda = self.lambda.clamp(0.0, 0.95);
    }

    fn reset(&mut self) {
        self.lambda = self.lambda0;
        self.accept_rate = 0.8;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sig(entropy: f32) -> TokenSignals {
        TokenSignals {
            argmax: 0, top1: 0.5, top2: 0.1, margin: 0.4, entropy,
            sqrt_entropy: entropy.sqrt(), logsumexp: 0.0, max_logit: 0.0,
        }
    }

    #[test]
    fn stops_on_high_entropy_bound() {
        let mut p = AdaEdl::default();
        assert!(!p.should_stop(&sig(0.01), 0)); // bound ~0.96 > λ
        assert!(p.should_stop(&sig(4.0), 1)); // bound ~0.23 < λ
    }

    #[test]
    fn lambda_rises_on_rejections_falls_on_accepts() {
        let mut p = AdaEdl::default();
        let l0 = p.lambda();
        for _ in 0..20 {
            p.on_verify(0, 6); // everything rejected -> stop earlier
        }
        assert!(p.lambda() > l0, "{} !> {l0}", p.lambda());
        let l1 = p.lambda();
        for _ in 0..40 {
            p.on_verify(6, 6); // everything accepted -> draft longer
        }
        assert!(p.lambda() < l1);
    }

    #[test]
    fn reset_restores_initial_lambda() {
        let mut p = AdaEdl::default();
        let l0 = p.lambda();
        p.on_verify(0, 6);
        p.on_verify(0, 6);
        assert_ne!(p.lambda(), l0);
        p.reset();
        assert_eq!(p.lambda(), l0);
    }

    #[test]
    fn lambda_stays_clamped() {
        let mut p = AdaEdl::default();
        for _ in 0..5000 {
            p.on_verify(0, 6);
        }
        assert!(p.lambda() <= 0.95);
        for _ in 0..5000 {
            p.on_verify(6, 6);
        }
        assert!(p.lambda() >= 0.0);
    }
}
