//! Stop policies — the TapOut bandit's arm pool (paper Table 1 / App. A.1)
//! plus the Static-γ baseline and the training-based SpecDec++ classifier.
//!
//! A policy answers one question after each drafted token: *stop drafting
//! and verify now?* It sees only the L1 signal row for the token plus its
//! own per-request state. `on_verify` delivers the session outcome so
//! stateful policies (AdaEDL's λ, SpecDec++'s EMA feature) can adapt.

pub mod ada_edl;
pub mod logit_margin;
pub mod max_confidence;
pub mod pool;
pub mod specdecpp;
pub mod static_len;
pub mod svip;
pub mod svip_diff;

pub use ada_edl::AdaEdl;
pub use logit_margin::LogitMargin;
pub use max_confidence::MaxConfidence;
pub use specdecpp::SpecDecPP;
pub use static_len::{AlwaysContinue, StaticLen};
pub use svip::Svip;
pub use svip_diff::SvipDiff;

use crate::signals::TokenSignals;

/// A stop heuristic: one decision per drafted token.
pub trait StopPolicy: Send {
    /// Short stable identifier (used in reports and bandit arm labels).
    fn name(&self) -> String;

    /// Called once per drafting session before the first proposal.
    fn on_session_start(&mut self) {}

    /// Decide after drafting token `idx` (0-based within the session) with
    /// signal row `sig`: true = stop drafting, send for verification.
    fn should_stop(&mut self, sig: &TokenSignals, idx: usize) -> bool;

    /// Verification feedback: `accepted` of `drafted` proposals survived.
    fn on_verify(&mut self, _accepted: usize, _drafted: usize) {}

    /// Reset all per-request state (start of a new generation).
    fn reset(&mut self) {}
}

/// Boxed-policy convenience used by the arm pool and the controllers.
pub type BoxedPolicy = Box<dyn StopPolicy>;
