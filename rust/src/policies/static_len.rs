//! Static-k drafting (the vanilla speculative-decoding baseline; the
//! paper's Static-6 rows) and the AlwaysContinue probe used for trace
//! collection and the Fig. 2 entropy study.

use super::StopPolicy;
use crate::signals::TokenSignals;

/// Draft exactly `k` tokens per session, unconditionally.
#[derive(Clone, Debug)]
pub struct StaticLen {
    /// fixed draft length
    pub k: usize,
}

impl StaticLen {
    /// Static-k drafting (k >= 1).
    pub fn new(k: usize) -> Self {
        assert!(k >= 1);
        StaticLen { k }
    }
}

impl StopPolicy for StaticLen {
    fn name(&self) -> String {
        format!("static-{}", self.k)
    }

    fn should_stop(&mut self, _sig: &TokenSignals, idx: usize) -> bool {
        idx + 1 >= self.k
    }
}

/// Never stops on its own — the session's γ_max cap ends drafting. Used to
/// harvest full-length draft traces (classifier training, entropy studies).
#[derive(Clone, Debug, Default)]
pub struct AlwaysContinue;

impl StopPolicy for AlwaysContinue {
    fn name(&self) -> String {
        "always-continue".into()
    }

    fn should_stop(&mut self, _sig: &TokenSignals, _idx: usize) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sig() -> TokenSignals {
        TokenSignals {
            argmax: 0, top1: 1.0, top2: 0.0, margin: 1.0, entropy: 0.0,
            sqrt_entropy: 0.0, logsumexp: 0.0, max_logit: 0.0,
        }
    }

    #[test]
    fn static_k_stops_at_k() {
        let mut p = StaticLen::new(3);
        assert!(!p.should_stop(&sig(), 0));
        assert!(!p.should_stop(&sig(), 1));
        assert!(p.should_stop(&sig(), 2));
    }

    #[test]
    fn always_continue_never_stops() {
        let mut p = AlwaysContinue;
        for i in 0..1000 {
            assert!(!p.should_stop(&sig(), i));
        }
    }
}
