//! Max-Confidence (paper Table 1): stop when p(top-1) < h. The simplest
//! confidence heuristic — drafts while the draft model is sure of itself.

use super::StopPolicy;
use crate::signals::TokenSignals;

/// Stop when p(top-1) drops below `h`.
#[derive(Clone, Debug)]
pub struct MaxConfidence {
    /// confidence threshold
    pub h: f32,
}

impl MaxConfidence {
    /// Paper default threshold h = 0.8.
    pub fn new(h: f32) -> Self {
        MaxConfidence { h }
    }
}

impl Default for MaxConfidence {
    fn default() -> Self {
        MaxConfidence::new(0.8)
    }
}

impl StopPolicy for MaxConfidence {
    fn name(&self) -> String {
        format!("max-conf@{:.2}", self.h)
    }

    fn should_stop(&mut self, sig: &TokenSignals, _idx: usize) -> bool {
        sig.top1 < self.h
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sig(top1: f32) -> TokenSignals {
        TokenSignals {
            argmax: 0, top1, top2: 0.0, margin: top1, entropy: 0.0,
            sqrt_entropy: 0.0, logsumexp: 0.0, max_logit: 0.0,
        }
    }

    #[test]
    fn stops_below_threshold() {
        let mut p = MaxConfidence::new(0.8);
        assert!(!p.should_stop(&sig(0.95), 0));
        assert!(!p.should_stop(&sig(0.80), 1));
        assert!(p.should_stop(&sig(0.79), 2));
    }
}
