//! Logit Margin (paper App. A.1, TapOut's second new arm): stop when the
//! top-1/top-2 probability gap collapses — a two-way-race indicator that
//! fires even when entropy stays moderate.

use super::StopPolicy;
use crate::signals::TokenSignals;

/// Stop when the top-1/top-2 probability gap collapses below `h`.
#[derive(Clone, Debug)]
pub struct LogitMargin {
    /// margin threshold
    pub h: f32,
}

impl LogitMargin {
    /// Paper default threshold h = 0.2.
    pub fn new(h: f32) -> Self {
        LogitMargin { h }
    }
}

impl Default for LogitMargin {
    fn default() -> Self {
        LogitMargin::new(0.2)
    }
}

impl StopPolicy for LogitMargin {
    fn name(&self) -> String {
        format!("logit-margin@{:.2}", self.h)
    }

    fn should_stop(&mut self, sig: &TokenSignals, _idx: usize) -> bool {
        sig.margin <= self.h
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sig(margin: f32) -> TokenSignals {
        TokenSignals {
            argmax: 0, top1: 0.5, top2: 0.5 - margin, margin, entropy: 1.0,
            sqrt_entropy: 1.0, logsumexp: 0.0, max_logit: 0.0,
        }
    }

    #[test]
    fn stops_on_collapsed_margin() {
        let mut p = LogitMargin::new(0.2);
        assert!(!p.should_stop(&sig(0.5), 0));
        assert!(p.should_stop(&sig(0.2), 1)); // <= h stops
        assert!(p.should_stop(&sig(0.05), 2));
    }
}
