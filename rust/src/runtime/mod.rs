//! PJRT runtime — the only module that touches the `xla` crate.
//!
//! Loads HLO-text artifacts (see python/compile/aot.py), compiles them on
//! the CPU PJRT client, and provides typed helpers for the device-resident
//! world-buffer protocol: weights and KV worlds live on device as
//! `PjRtBuffer`s fed back through `execute_b`; the host only reads the tiny
//! signal out-region via offset `copy_raw_to_host_sync`.

use std::collections::HashMap;
use std::path::Path;
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

/// PJRT wrapper types hold raw pointers and are not `Send` by declaration,
/// but the CPU PJRT client is internally synchronized and we only ever use
/// each buffer/executable from one engine thread at a time (ownership moves
/// with the model instance). This wrapper documents and confines that
/// assumption.
pub struct SendWrap<T>(pub T);

// SAFETY: see type-level comment; all uses are single-threaded-at-a-time,
// moves between threads happen only at request-free points.
unsafe impl<T> Send for SendWrap<T> {}
unsafe impl<T> Sync for SendWrap<T> {}

#[derive(Clone)]
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO-text module (text, not proto — see
    /// /opt/xla-example/README.md on the 64-bit-id incompatibility).
    pub fn compile_hlo_file(&self, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))
    }

    pub fn f32_to_device(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }

    pub fn i32_to_device(&self, data: &[i32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }

    pub fn scalar_i32(&self, v: i32) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(&[v], &[], None)?)
    }
}

/// A compiled executable cache keyed by shape bucket, shared by all model
/// instances (serving slots) of one model.
pub struct ExecutableCache {
    runtime: Runtime,
    files: HashMap<usize, std::path::PathBuf>,
    compiled: Mutex<HashMap<usize, Arc<SendWrap<xla::PjRtLoadedExecutable>>>>,
}

impl ExecutableCache {
    pub fn new(runtime: Runtime, files: HashMap<usize, std::path::PathBuf>) -> Self {
        ExecutableCache { runtime, files, compiled: Mutex::new(HashMap::new()) }
    }

    pub fn buckets(&self) -> Vec<usize> {
        let mut ks: Vec<usize> = self.files.keys().copied().collect();
        ks.sort_unstable();
        ks
    }

    /// Smallest bucket >= n.
    pub fn bucket_for(&self, n: usize) -> Result<usize> {
        self.buckets()
            .into_iter()
            .find(|&k| k >= n)
            .ok_or_else(|| anyhow::anyhow!("no shape bucket >= {n}"))
    }

    /// Get (lazily compiling) the executable for bucket `k`.
    pub fn get(&self, k: usize) -> Result<Arc<SendWrap<xla::PjRtLoadedExecutable>>> {
        let mut map = self.compiled.lock().unwrap();
        if let Some(e) = map.get(&k) {
            return Ok(e.clone());
        }
        let path = self
            .files
            .get(&k)
            .ok_or_else(|| anyhow::anyhow!("no HLO file for bucket {k}"))?;
        let exe = Arc::new(SendWrap(self.runtime.compile_hlo_file(path)?));
        map.insert(k, exe.clone());
        Ok(exe)
    }

    /// Eagerly compile a set of buckets (engine warmup).
    pub fn warmup(&self, ks: &[usize]) -> Result<()> {
        for &k in ks {
            if self.files.contains_key(&k) {
                self.get(k)?;
            }
        }
        Ok(())
    }
}
