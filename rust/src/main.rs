//! `tapout` — CLI for the TapOut dynamic-speculative-decoding stack.
//!
//! Subcommands:
//!   generate  --pair pair-a --method seq-ucb1 --prompt "..." [--max-new N]
//!             [--stream]  (print tokens as each round commits)
//!   serve     --port 8077 --pair pair-a --method seq-ucb1 [--sched fcfs|sjf]
//!             [--workers N] [--slots N] [--backend pjrt|sim] [--continuous]
//!             [--max-queue N] [--deadline-ms MS] [--prefix-cache]
//!             [--page-size TOK] [--kv-pages N] [--no-page-sharing]
//!             [--pipeline] (continuous mode: overlap draft and verify)
//!             [--drafters N] (sim backend: pool N drafters per target,
//!             tenant-keyed bandit selection; docs/ARCHITECTURE.md §17)
//!             [--io-threads N] (0 = legacy blocking front end)
//!             [--header-timeout-ms MS] [--sse-keepalive-ms MS]
//!   route     --port 8080 --replicas host:p1,host:p2,... [--no-affinity]
//!             [--probe-ms MS] [--page-size TOK] [--io-threads N]
//!             [--header-timeout-ms MS] [--sse-keepalive-ms MS]
//!             [--drain host:p1,...]
//!             prefix-affinity router fronting N engine replicas: fleet
//!             /health + /metrics, POST /admin/drain|undrain
//!   exp       --id <table2|table3|table4|table5|fig2|fig3|fig4|fig5|fig6|abl-arms|tune|all>
//!             [--backend pjrt|sim] [--scale F] [--gamma N]
//!   simulate  --seed N --steps M [--faults] [--sabotage] [--mode workers|continuous]
//!             [--pipeline] [--replicas N] [--drafters N] [--tenants N]
//!             [--no-affinity] [--trace] [--replay plan.json] [--out shrunk.json]
//!             deterministic engine simulation against the shadow-state oracle
//!             (N>1 adds the router tier with kill/drain fault ops); on
//!             violation the plan is shrunk and written as a replay fixture
//!   selftest  verify the rust engine replays the python golden traces
//!             token-for-token (artifacts/golden/pair-a.json)

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{Context, Result};

use tapout::engine::{
    BackendKind, BatchConfig, Engine, EngineConfig, EngineMode, HttpConfig, HttpServer, Policy,
    Router, RouterConfig,
};
use tapout::harness::{run_experiment, ExpOpts};
use tapout::models::{Manifest, ModelAssets, PjrtModel};
use tapout::runtime::Runtime;
use tapout::spec::{generate, GenConfig, MethodSpec, SpecSession, StepOutcome};
use tapout::util::cli::Args;
use tapout::util::{Json, Rng};

fn main() {
    let args = Args::parse();
    let r = match args.subcommand.as_deref() {
        Some("generate") => cmd_generate(&args),
        Some("serve") => cmd_serve(&args),
        Some("route") => cmd_route(&args),
        Some("exp") => cmd_exp(&args),
        Some("simulate") => cmd_simulate(&args),
        Some("selftest") => cmd_selftest(&args),
        _ => {
            eprintln!(
                "usage: tapout <generate|serve|route|exp|simulate|selftest> [flags]\n\
                 see rust/src/main.rs header for flags"
            );
            std::process::exit(2);
        }
    };
    if let Err(e) = r {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn artifacts_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.str("artifacts", "artifacts"))
}

fn cmd_generate(args: &Args) -> Result<()> {
    let manifest = Manifest::load(&artifacts_dir(args))?;
    let runtime = Runtime::cpu()?;
    let pair = args.str("pair", "pair-a");
    let method = MethodSpec::parse(
        &args.str("method", "seq-ucb1"),
        &artifacts_dir(args).display().to_string(),
    )
    .map_err(|e| anyhow::anyhow!(e))?;
    let prompt_text = args.str("prompt", "q: where is alice? a:");
    let max_new = args.usize("max-new", 96);

    let (dspec, tspec) = manifest.pair(&pair)?;
    println!(
        "pair {pair}: draft={} ({} params) target={} ({} params), method {}",
        dspec.name,
        dspec.param_count,
        tspec.name,
        tspec.param_count,
        method.label()
    );
    let (dn, tn) = (dspec.name.clone(), tspec.name.clone());
    let mut draft = PjrtModel::new(ModelAssets::load(&runtime, &manifest, &dn)?)?;
    let mut target = PjrtModel::new(ModelAssets::load(&runtime, &manifest, &tn)?)?;

    let mut ctrl = method.build(args.usize("gamma", 128))?;
    let mut rng = Rng::new(args.usize("seed", 0) as u64);
    let mut prompt = vec![tapout::spec::BOS];
    prompt.extend(manifest.encode(&prompt_text));

    let cfg = GenConfig { max_new, ..GenConfig::default() };
    let r = if args.bool("stream") {
        // step-driven decode: print each round's committed tokens as they
        // land (the CLI face of the SpecSession API, ARCHITECTURE.md §10)
        use std::io::Write as _;
        print!("--- completion (streaming) ---\n{prompt_text}");
        std::io::stdout().flush().ok();
        let mut sess =
            SpecSession::new(&mut draft, &mut target, &mut ctrl, &mut rng, &prompt, &cfg)?;
        while let StepOutcome::Round(commit) = sess.step()? {
            print!("{}", manifest.decode(&commit.new_tokens));
            std::io::stdout().flush().ok();
        }
        println!();
        sess.finish()
    } else {
        let r = generate(&mut draft, &mut target, &mut ctrl, &mut rng, &prompt, &cfg)?;
        println!("--- completion ---\n{}{}", prompt_text, manifest.decode(r.new_tokens()));
        r
    };
    println!(
        "--- stats --- tokens {}  sessions {}  m {:.2}  accept {:.2}  {:.1} tok/s",
        r.new_tokens().len(),
        r.rounds.len(),
        r.mean_accepted(),
        r.acceptance_rate(),
        r.new_tokens().len() as f64 / (r.wall_ns as f64 / 1e9),
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let slots = args.usize("slots", 2);
    let cfg = EngineConfig {
        artifacts: artifacts_dir(args),
        pair: args.str("pair", "pair-a"),
        method: args.str("method", "seq-ucb1"),
        gamma_max: args.usize("gamma", 128),
        sched: Policy::parse(&args.str("sched", "fcfs")),
        slots,
        // default: one decode worker per KV slot
        workers: args.usize("workers", slots),
        backend: BackendKind::parse(&args.str("backend", "pjrt"))
            .map_err(|e| anyhow::anyhow!(e))?,
        // --batch 0 restores per-slot direct verification
        verify_batch: BatchConfig {
            max_batch: args.usize("batch", BatchConfig::default().max_batch),
            window_us: args.usize("batch-window-us", 100) as u64,
        },
        // --max-queue 0 = unbounded (no admission shedding)
        max_queue: args.usize("max-queue", 0),
        // --deadline-ms 0 = no default deadline
        default_deadline_ms: args.usize("deadline-ms", 0) as u64,
        // --continuous swaps the worker pool for the continuous-batching
        // step loop (docs/ARCHITECTURE.md §11)
        mode: if args.bool("continuous") { EngineMode::Continuous } else { EngineMode::Workers },
        // --prefix-cache enables cross-request prefix reuse with
        // slot-affinity routing (docs/ARCHITECTURE.md §12); lossless
        prefix_cache: args.bool("prefix-cache"),
        // paged KV arena knobs (docs/ARCHITECTURE.md §13): --page-size sets
        // the page granularity in tokens; --kv-pages 0 auto-sizes the arena
        // so eviction never fires; --no-page-sharing falls back to PR-5
        // slot-affinity routing (busy-slot residency invisible). All lossless.
        page_size: args.usize("page-size", tapout::engine::DEFAULT_PAGE_SIZE),
        kv_pages: args.usize("kv-pages", 0),
        page_sharing: !args.bool("no-page-sharing"),
        // --pipeline overlaps each verify with the next round's first
        // speculative draft feed (docs/ARCHITECTURE.md §16); continuous
        // mode only, lossless, off by default
        pipeline: args.bool("pipeline"),
        // --drafters N pools N draft models per target and lets the
        // tenant-keyed full-information bandit pick one per round
        // (docs/ARCHITECTURE.md §17); 1 = the plain single-drafter engine
        drafters: args.usize("drafters", 1),
        ..EngineConfig::default()
    };
    let port = args.usize("port", 8077) as u16;
    // --io-threads 0 restores the legacy blocking thread-per-connection
    // front end; the reactor (docs/ARCHITECTURE.md §15) is the default
    let http_cfg = HttpConfig {
        io_threads: args.usize("io-threads", HttpConfig::default().io_threads),
        header_timeout_ms: args.usize("header-timeout-ms", 10_000) as u64,
        sse_keepalive_ms: args.usize("sse-keepalive-ms", 15_000) as u64,
    };
    let engine = Arc::new(Engine::start(cfg).context("starting engine")?);
    let http = HttpServer::start_with(engine.clone(), port, http_cfg)?;
    println!(
        "tapout serving on http://{}  (POST /generate [stream:true for SSE], GET /health, \
         GET /metrics)  io={}x{} backend={} mode={} workers={} slots={} max_queue={} \
         deadline_ms={} prefix_cache={} page_size={} kv_pages={} page_sharing={} pipeline={} \
         drafters={}",
        http.addr,
        http.stats.mode,
        http.stats.io_threads,
        engine.config.backend.label(),
        engine.config.mode.label(),
        engine.config.workers,
        engine.config.slots,
        engine.config.max_queue,
        engine.config.default_deadline_ms,
        engine.config.prefix_cache,
        engine.config.page_size,
        engine.config.kv_pages,
        engine.config.page_sharing,
        engine.config.pipeline,
        engine.config.drafters,
    );
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

/// Prefix-affinity router fronting N engine replicas
/// (docs/ARCHITECTURE.md §15, docs/OPERATIONS.md): consistent hashing on
/// the first KV page of the tokenized prompt, shed-aware overflow on the
/// probed SJF queue-wait estimates, health-probed failover/draining, and
/// aggregated fleet `/health` + `/metrics`.
fn cmd_route(args: &Args) -> Result<()> {
    let split = |s: &str| -> Vec<String> {
        s.split(',').map(str::trim).filter(|a| !a.is_empty()).map(String::from).collect()
    };
    let replicas = split(&args.str("replicas", ""));
    anyhow::ensure!(
        !replicas.is_empty(),
        "route needs --replicas host:port[,host:port...]"
    );
    let cfg = RouterConfig {
        replicas,
        affinity: !args.bool("no-affinity"),
        page_size: args.usize("page-size", tapout::engine::DEFAULT_PAGE_SIZE),
        probe_ms: args.usize("probe-ms", 200) as u64,
        io_threads: args.usize("io-threads", RouterConfig::default().io_threads),
        header_timeout_ms: args.usize("header-timeout-ms", 10_000) as u64,
        sse_keepalive_ms: args.usize("sse-keepalive-ms", 15_000) as u64,
        drain: args.opt("drain").map(split).unwrap_or_default(),
    };
    let n = cfg.replicas.len();
    let affinity = cfg.affinity;
    let port = args.usize("port", 8080) as u16;
    let router = Router::start(cfg, port).context("starting router")?;
    println!(
        "tapout routing on http://{}  (POST /generate, GET /health, GET /metrics, \
         POST /admin/drain|undrain)  replicas={n} affinity={affinity}",
        router.addr,
    );
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn cmd_exp(args: &Args) -> Result<()> {
    let opts = ExpOpts {
        artifacts: artifacts_dir(args),
        results: PathBuf::from(args.str("results", "results")),
        backend: args.str("backend", "pjrt"),
        scale: args.f64("scale", 1.0),
        gamma_max: args.usize("gamma", 128),
    };
    let id = args.str("id", "all");
    run_experiment(&id, opts)
}

/// Deterministic engine simulation (docs/TESTING.md): generate (or replay)
/// a seeded workload plan, run it through the single-threaded simulator
/// against the shadow-state oracle, and on violation shrink the plan to a
/// 1-minimal replay fixture. Exit is nonzero iff the oracle fired, so CI
/// can fan out over fresh seeds and keep the shrunk trace as an artifact.
fn cmd_simulate(args: &Args) -> Result<()> {
    use tapout::engine::FinishStatus;
    use tapout::sim_harness::{run_plan, shrink, SimPlan};

    let mut plan = match args.opt("replay") {
        Some(path) => {
            let text = std::fs::read_to_string(path)
                .with_context(|| format!("reading plan {path}"))?;
            let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("plan json: {e}"))?;
            SimPlan::from_json(&j).map_err(|e| anyhow::anyhow!(e))?
        }
        None => SimPlan::generate_fleet(
            args.usize("seed", 0) as u64,
            args.usize("steps", 60),
            args.usize("replicas", 1),
        ),
    };
    if args.bool("faults") {
        plan.faults = true;
    }
    if args.bool("sabotage") {
        plan.sabotage = true;
    }
    if args.bool("no-affinity") {
        plan.affinity = false;
    }
    if let Some(mode) = args.opt("mode") {
        anyhow::ensure!(
            mode == "workers" || mode == "continuous",
            "--mode must be workers or continuous"
        );
        plan.mode = mode.to_string();
    }
    // --pipeline turns on the overlapped draft/verify stepper path and the
    // simulator's two-lane virtual clock; decode outputs are identical, so
    // replayed fixtures stay valid either way (docs/ARCHITECTURE.md §16)
    if args.bool("pipeline") {
        plan.pipeline = true;
    }
    // --drafters / --tenants overlay the drafter-pool size and the
    // number of synthetic tenant streams (docs/ARCHITECTURE.md §17); the
    // oracle then also checks two-layer play-count conservation
    if let Some(n) = args.opt("drafters") {
        plan.drafters = n.parse().map_err(|_| anyhow::anyhow!("--drafters wants a number"))?;
    }
    if let Some(n) = args.opt("tenants") {
        plan.tenants = n.parse().map_err(|_| anyhow::anyhow!("--tenants wants a number"))?;
    }

    let report = run_plan(&plan);
    if args.bool("trace") {
        for line in &report.trace {
            println!("{line}");
        }
    }
    println!(
        "sim seed={} mode={} method={} slots={} cache={} pages={} faults={} replicas={} ops={} \
         events={} clock={}ns hash={:016x}",
        plan.seed,
        plan.mode,
        plan.method,
        plan.slots,
        plan.cache,
        plan.kv_pages,
        plan.faults,
        plan.replicas,
        plan.ops.len(),
        report.trace.len(),
        report.clock_ns,
        report.trace_hash,
    );
    println!(
        "replies: {} done, {} failed, {} cancelled, {} expired, {} rejected",
        report.count(FinishStatus::Done),
        report.count(FinishStatus::Failed),
        report.count(FinishStatus::Cancelled),
        report.count(FinishStatus::Expired),
        report.count(FinishStatus::Rejected),
    );
    match report.violation {
        None => {
            println!("oracle: all invariants held");
            Ok(())
        }
        Some(v) => {
            eprintln!("oracle violation at event {}: {}", v.event, v.what);
            let min = shrink(&plan);
            eprintln!("shrunk {} ops -> {} ops", plan.ops.len(), min.ops.len());
            let out = args.str("out", "sim-shrunk-plan.json");
            std::fs::write(&out, min.to_json().render())
                .with_context(|| format!("writing {out}"))?;
            eprintln!("replay fixture written: tapout simulate --replay {out}");
            anyhow::bail!("simulator oracle violation (seed {})", plan.seed)
        }
    }
}

/// Replays the python reference decoder's golden traces through the rust
/// engine: committed tokens, per-round drafted/accepted counts must match
/// exactly (same HLO, same greedy rule) — the cross-language end-to-end
/// correctness check.
fn cmd_selftest(args: &Args) -> Result<()> {
    let dir = artifacts_dir(args);
    let manifest = Manifest::load(&dir)?;
    let runtime = Runtime::cpu()?;
    let text = std::fs::read_to_string(dir.join("golden/pair-a.json"))
        .context("reading golden traces (run `make artifacts`)")?;
    let golden = Json::parse(&text).map_err(|e| anyhow::anyhow!("golden json: {e}"))?;

    let pair = golden.get("pair").and_then(|x| x.as_str()).unwrap_or("pair-a");
    let stop_after = golden.get("stop_after").and_then(|x| x.as_usize()).unwrap_or(6);
    let max_new = golden.get("max_new").and_then(|x| x.as_usize()).unwrap_or(48);
    let (dspec, tspec) = manifest.pair(pair)?;
    let (dn, tn) = (dspec.name.clone(), tspec.name.clone());
    let mut draft = PjrtModel::new(ModelAssets::load(&runtime, &manifest, &dn)?)?;
    let mut target = PjrtModel::new(ModelAssets::load(&runtime, &manifest, &tn)?)?;

    let empty = Vec::new();
    let traces = golden.get("traces").and_then(|x| x.as_arr()).unwrap_or(&empty);
    anyhow::ensure!(!traces.is_empty(), "no golden traces");
    let mut ok = 0;
    for (i, t) in traces.iter().enumerate() {
        let prompt: Vec<u32> =
            t.get("prompt_ids").unwrap().f64s().iter().map(|&x| x as u32).collect();
        let want: Vec<u32> =
            t.get("committed").unwrap().f64s().iter().map(|&x| x as u32).collect();
        let want_drafted: Vec<usize> =
            t.get("drafted").unwrap().f64s().iter().map(|&x| x as usize).collect();
        let want_accepted: Vec<usize> =
            t.get("accepted").unwrap().f64s().iter().map(|&x| x as usize).collect();

        let mut ctrl = MethodSpec::Static(stop_after).build(128)?;
        let mut rng = Rng::new(0);
        let cfg = GenConfig { max_new, gamma_max: 128, stop_at_eos: true, collect_signals: false };
        let r = generate(&mut draft, &mut target, &mut ctrl, &mut rng, &prompt, &cfg)?;

        let got_drafted: Vec<usize> = r.rounds.iter().map(|x| x.drafted).collect();
        let got_accepted: Vec<usize> = r.rounds.iter().map(|x| x.accepted).collect();
        anyhow::ensure!(
            r.tokens == want,
            "trace {i}: token mismatch\n got {:?}\nwant {:?}",
            r.tokens,
            want
        );
        anyhow::ensure!(got_drafted == want_drafted, "trace {i}: drafted mismatch");
        anyhow::ensure!(got_accepted == want_accepted, "trace {i}: accepted mismatch");
        ok += 1;
        println!(
            "trace {i} ({}): OK — {} tokens, {} rounds",
            t.get("category").and_then(|x| x.as_str()).unwrap_or("?"),
            want.len(),
            want_drafted.len()
        );
    }
    println!("selftest: {ok}/{} golden traces replayed exactly", traces.len());
    Ok(())
}
