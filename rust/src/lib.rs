//! # TapOut — bandit-based dynamic speculative decoding
//!
//! Reproduction of *TapOut: A Bandit-Based Approach to Dynamic Speculative
//! Decoding* (Sridhar et al., 2025) as a three-layer rust + JAX + Pallas
//! serving stack (see `DESIGN.md` at the repo root; §2 covers the
//! concurrent engine, §4 the KV protocol):
//!
//! * **L3 (this crate)** — the speculative-decoding coordinator: bandit
//!   controllers ([`bandit`]), the training-free arm-policy pool
//!   ([`policies`]), the Algorithm-1 session loop ([`spec`]), a serving
//!   engine with a dispatcher + decode-worker pool sharing one online
//!   bandit, scheduler/slots/metrics/HTTP ([`engine`]), the PJRT
//!   runtime ([`runtime`]), model backends ([`models`]) and the experiment
//!   harness regenerating every paper table/figure ([`harness`]).
//! * **L2 (python/compile, build-time)** — tiny JAX transformer zoo, AOT
//!   lowered to HLO text under `artifacts/`.
//! * **L1 (python/compile/kernels)** — the fused Pallas stop-signal head
//!   whose per-token output is [`signals::TokenSignals`].

pub mod bandit;
pub mod engine;
pub mod harness;
pub mod models;
pub mod policies;
pub mod runtime;
pub mod signals;
pub mod spec;
pub mod util;
