//! # TapOut — bandit-based dynamic speculative decoding
//!
//! Reproduction of *TapOut: A Bandit-Based Approach to Dynamic Speculative
//! Decoding* (Sridhar et al., 2025) as a three-layer rust + JAX + Pallas
//! serving stack. The architecture book lives in `docs/ARCHITECTURE.md`
//! (§4 covers cross-session batched verification, §5 the scheduler and
//! KV protocol); `DESIGN.md` at the repo root keeps the legacy section
//! map that older code comments cite.
//!
//! * **L3 (this crate)** — the speculative-decoding coordinator: bandit
//!   controllers ([`bandit`]), the training-free arm-policy pool
//!   ([`policies`], cataloged in `docs/POLICIES.md`), the Algorithm-1
//!   session loop ([`spec`]), a serving engine with two execution cores
//!   sharing one online bandit — a dispatcher + decode worker pool with
//!   its cross-session verification batcher, and a continuous-batching
//!   step loop — plus scheduler/slots/metrics/HTTP ([`engine`]), the
//!   PJRT runtime ([`runtime`]), model backends ([`models`]) and the
//!   experiment harness regenerating every paper table/figure
//!   ([`harness`]).
//! * **L2 (python/compile, build-time)** — tiny JAX transformer zoo, AOT
//!   lowered to HLO text under `artifacts/`.
//! * **L1 (python/compile/kernels)** — the fused Pallas stop-signal head
//!   whose per-token output is [`signals::TokenSignals`].

#![warn(missing_docs)]

pub mod bandit;
pub mod engine;
// offline stand-in internals: module-level docs only, item-level rustdoc
// tracked as debt (docs/OPERATIONS.md "rustdoc gate")
#[allow(missing_docs)]
pub mod harness;
pub mod models;
pub mod policies;
#[allow(missing_docs)]
pub mod runtime;
pub mod signals;
pub mod sim_harness;
pub mod spec;
#[allow(missing_docs)]
pub mod util;
