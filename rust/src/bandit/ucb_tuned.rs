//! UCB-Tuned (Auer et al., 2002 §4) — variance-aware exploration, ablated
//! against UCB1 in paper §4.1.3 (Fig. 4):
//!   index = μ̂_a + sqrt( (ln t / N_a) · min(1/4, V_a(t)) )
//!   V_a(t) = σ̂²_a + sqrt(2 ln t / N_a)

use super::Bandit;
use crate::util::Rng;

/// UCB-Tuned state: per-arm sums, squared sums, and play counts.
#[derive(Clone, Debug)]
pub struct UcbTuned {
    sums: Vec<f64>,
    sumsq: Vec<f64>,
    counts: Vec<u64>,
    t: u64,
}

impl UcbTuned {
    /// A fresh learner over `n_arms` arms.
    pub fn new(n_arms: usize) -> Self {
        assert!(n_arms >= 1);
        UcbTuned {
            sums: vec![0.0; n_arms],
            sumsq: vec![0.0; n_arms],
            counts: vec![0; n_arms],
            t: 0,
        }
    }

    fn mean(&self, a: usize) -> f64 {
        self.sums[a] / self.counts[a] as f64
    }

    /// V_a(t): the empirical variance plus its exploration bonus.
    pub fn variance_bound(&self, a: usize) -> f64 {
        let n = self.counts[a] as f64;
        let mean = self.mean(a);
        let var = (self.sumsq[a] / n - mean * mean).max(0.0);
        var + (2.0 * (self.t.max(1) as f64).ln() / n).sqrt()
    }

    /// The UCB-Tuned index of `a` (infinite while unplayed).
    pub fn index(&self, a: usize) -> f64 {
        if self.counts[a] == 0 {
            return f64::INFINITY;
        }
        let n = self.counts[a] as f64;
        let lnt = (self.t.max(1) as f64).ln();
        self.mean(a) + (lnt / n * self.variance_bound(a).min(0.25)).sqrt()
    }
}

impl Bandit for UcbTuned {
    fn n_arms(&self) -> usize {
        self.counts.len()
    }

    fn select(&mut self, _rng: &mut Rng) -> usize {
        if let Some(a) = self.counts.iter().position(|&c| c == 0) {
            return a;
        }
        (0..self.n_arms())
            .max_by(|&a, &b| self.index(a).partial_cmp(&self.index(b)).unwrap())
            .unwrap()
    }

    fn update(&mut self, arm: usize, reward: f64) {
        self.t += 1;
        self.counts[arm] += 1;
        self.sums[arm] += reward;
        self.sumsq[arm] += reward * reward;
    }

    fn values(&self) -> Vec<f64> {
        self.sums
            .iter()
            .zip(&self.counts)
            .map(|(&s, &c)| if c == 0 { 0.0 } else { s / c as f64 })
            .collect()
    }

    fn counts(&self) -> Vec<u64> {
        self.counts.clone()
    }

    fn name(&self) -> String {
        "ucb-tuned".into()
    }

    fn reset(&mut self) {
        self.sums.iter_mut().for_each(|x| *x = 0.0);
        self.sumsq.iter_mut().for_each(|x| *x = 0.0);
        self.counts.iter_mut().for_each(|x| *x = 0);
        self.t = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn low_variance_arm_gets_smaller_bonus() {
        let mut b = UcbTuned::new(2);
        // arm 0: constant 0.5 (zero variance); arm 1: alternating 0/1.
        // needs enough plays that the sqrt(2 ln t / n) slack in V_a falls
        // below the 1/4 cap for the low-variance arm.
        for i in 0..2000 {
            b.update(0, 0.5);
            b.update(1, (i % 2) as f64);
        }
        let bonus0 = b.index(0) - 0.5;
        let bonus1 = b.index(1) - 0.5;
        assert!(
            bonus1 > bonus0,
            "high-variance arm should keep a larger bonus: {bonus0} vs {bonus1}"
        );
    }

    #[test]
    fn variance_bound_capped_at_quarter_in_index() {
        let mut b = UcbTuned::new(1);
        for i in 0..100 {
            b.update(0, (i % 2) as f64); // max-variance Bernoulli
        }
        // index uses min(1/4, V) — bonus must not exceed sqrt(ln t / n * 1/4)
        let lnt = (b.t as f64).ln();
        let cap = (lnt / 100.0 * 0.25).sqrt();
        assert!(b.index(0) - 0.5 <= cap + 1e-12);
    }
}
