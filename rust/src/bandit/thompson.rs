//! Thompson Sampling (paper §3.3):
//!   * `BetaTs` — Beta-Bernoulli posterior for the token-level bandit's
//!     binary accept/reject rewards;
//!   * `GaussianTs` — known-noise-variance Gaussian conjugate posterior for
//!     the sequence-level bandit's continuous r ∈ [0, 1] rewards.

use super::Bandit;
use crate::util::Rng;

/// Beta-Bernoulli Thompson sampling state.
#[derive(Clone, Debug)]
pub struct BetaTs {
    alpha: Vec<f64>,
    beta: Vec<f64>,
    counts: Vec<u64>,
}

impl BetaTs {
    /// A fresh Beta(1,1) posterior per arm.
    pub fn new(n_arms: usize) -> Self {
        BetaTs { alpha: vec![1.0; n_arms], beta: vec![1.0; n_arms], counts: vec![0; n_arms] }
    }
}

impl Bandit for BetaTs {
    fn n_arms(&self) -> usize {
        self.counts.len()
    }

    fn select(&mut self, rng: &mut Rng) -> usize {
        let mut best = 0;
        let mut best_v = f64::NEG_INFINITY;
        for a in 0..self.n_arms() {
            let v = rng.beta(self.alpha[a], self.beta[a]);
            if v > best_v {
                best_v = v;
                best = a;
            }
        }
        best
    }

    fn update(&mut self, arm: usize, reward: f64) {
        // fractional rewards are treated as soft Bernoulli evidence
        let r = reward.clamp(0.0, 1.0);
        self.alpha[arm] += r;
        self.beta[arm] += 1.0 - r;
        self.counts[arm] += 1;
    }

    fn values(&self) -> Vec<f64> {
        self.alpha
            .iter()
            .zip(&self.beta)
            .map(|(&a, &b)| a / (a + b))
            .collect()
    }

    fn counts(&self) -> Vec<u64> {
        self.counts.clone()
    }

    fn name(&self) -> String {
        "ts-beta".into()
    }

    fn reset(&mut self) {
        self.alpha.iter_mut().for_each(|x| *x = 1.0);
        self.beta.iter_mut().for_each(|x| *x = 1.0);
        self.counts.iter_mut().for_each(|x| *x = 0);
    }
}

/// Gaussian TS with known observation noise σ² and prior N(μ0, s0²).
/// Posterior after n observations with sum S:
///   precision  ρ = 1/s0² + n/σ²
///   mean       μ = (μ0/s0² + S/σ²) / ρ
#[derive(Clone, Debug)]
pub struct GaussianTs {
    mu0: f64,
    s0sq: f64,
    noise_sq: f64,
    sums: Vec<f64>,
    counts: Vec<u64>,
}

impl GaussianTs {
    /// A fresh N(0.5, 0.25) prior per arm.
    pub fn new(n_arms: usize) -> Self {
        // prior centred mid-range over the [0,1] reward; noise matched to
        // the empirical spread of r_blend
        GaussianTs {
            mu0: 0.5,
            s0sq: 0.25,
            noise_sq: 0.05,
            sums: vec![0.0; n_arms],
            counts: vec![0; n_arms],
        }
    }

    fn posterior(&self, a: usize) -> (f64, f64) {
        let rho = 1.0 / self.s0sq + self.counts[a] as f64 / self.noise_sq;
        let mu = (self.mu0 / self.s0sq + self.sums[a] / self.noise_sq) / rho;
        (mu, 1.0 / rho)
    }
}

impl Bandit for GaussianTs {
    fn n_arms(&self) -> usize {
        self.counts.len()
    }

    fn select(&mut self, rng: &mut Rng) -> usize {
        let mut best = 0;
        let mut best_v = f64::NEG_INFINITY;
        for a in 0..self.n_arms() {
            let (mu, var) = self.posterior(a);
            let v = rng.normal_scaled(mu, var.sqrt());
            if v > best_v {
                best_v = v;
                best = a;
            }
        }
        best
    }

    fn update(&mut self, arm: usize, reward: f64) {
        self.sums[arm] += reward;
        self.counts[arm] += 1;
    }

    fn values(&self) -> Vec<f64> {
        (0..self.n_arms()).map(|a| self.posterior(a).0).collect()
    }

    fn counts(&self) -> Vec<u64> {
        self.counts.clone()
    }

    fn name(&self) -> String {
        "ts-gaussian".into()
    }

    fn reset(&mut self) {
        self.sums.iter_mut().for_each(|x| *x = 0.0);
        self.counts.iter_mut().for_each(|x| *x = 0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn beta_posterior_mean_tracks_data() {
        let mut b = BetaTs::new(2);
        for _ in 0..100 {
            b.update(0, 1.0);
            b.update(1, 0.0);
        }
        let v = b.values();
        assert!(v[0] > 0.95 && v[1] < 0.05, "{v:?}");
    }

    #[test]
    fn gaussian_posterior_shrinks_towards_data() {
        let mut g = GaussianTs::new(1);
        let (mu_prior, var_prior) = g.posterior(0);
        assert!((mu_prior - 0.5).abs() < 1e-12);
        for _ in 0..200 {
            g.update(0, 0.9);
        }
        let (mu, var) = g.posterior(0);
        assert!((mu - 0.9).abs() < 0.02, "posterior mean {mu}");
        assert!(var < var_prior / 50.0, "posterior variance must shrink");
    }

    #[test]
    fn gaussian_ts_explores_under_uncertainty() {
        // with no data, selections should be spread across arms
        let mut g = GaussianTs::new(4);
        let mut rng = Rng::new(3);
        let mut seen = [0u32; 4];
        for _ in 0..400 {
            seen[g.select(&mut rng)] += 1;
        }
        assert!(seen.iter().all(|&c| c > 40), "{seen:?}");
    }
}
