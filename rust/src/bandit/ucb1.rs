//! UCB1 (Auer et al., 2002) — the paper's headline controller
//! ("TapOut - Seq UCB1"):  a_t = argmax_a  μ̂_a + sqrt(2 ln t / N_a).

use super::Bandit;
use crate::util::Rng;

/// UCB1 state: per-arm reward sums and play counts.
#[derive(Clone, Debug)]
pub struct Ucb1 {
    sums: Vec<f64>,
    counts: Vec<u64>,
    t: u64,
}

impl Ucb1 {
    /// A fresh learner over `n_arms` arms.
    pub fn new(n_arms: usize) -> Self {
        assert!(n_arms >= 1);
        Ucb1 { sums: vec![0.0; n_arms], counts: vec![0; n_arms], t: 0 }
    }

    /// The UCB index of `arm` (infinite while unplayed).
    pub fn ucb(&self, arm: usize) -> f64 {
        if self.counts[arm] == 0 {
            return f64::INFINITY;
        }
        let mean = self.sums[arm] / self.counts[arm] as f64;
        mean + (2.0 * (self.t.max(1) as f64).ln() / self.counts[arm] as f64).sqrt()
    }
}

impl Bandit for Ucb1 {
    fn n_arms(&self) -> usize {
        self.counts.len()
    }

    fn select(&mut self, _rng: &mut Rng) -> usize {
        // play each arm once first, then maximize the UCB index
        if let Some(a) = self.counts.iter().position(|&c| c == 0) {
            return a;
        }
        let mut best = 0;
        let mut best_v = f64::NEG_INFINITY;
        for a in 0..self.n_arms() {
            let v = self.ucb(a);
            if v > best_v {
                best_v = v;
                best = a;
            }
        }
        best
    }

    fn update(&mut self, arm: usize, reward: f64) {
        self.t += 1;
        self.counts[arm] += 1;
        self.sums[arm] += reward;
    }

    fn values(&self) -> Vec<f64> {
        self.sums
            .iter()
            .zip(&self.counts)
            .map(|(&s, &c)| if c == 0 { 0.0 } else { s / c as f64 })
            .collect()
    }

    fn counts(&self) -> Vec<u64> {
        self.counts.clone()
    }

    fn name(&self) -> String {
        "ucb1".into()
    }

    fn reset(&mut self) {
        self.sums.iter_mut().for_each(|x| *x = 0.0);
        self.counts.iter_mut().for_each(|x| *x = 0);
        self.t = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plays_every_arm_once_first() {
        let mut b = Ucb1::new(4);
        let mut rng = Rng::new(0);
        let mut seen = vec![false; 4];
        for _ in 0..4 {
            let a = b.select(&mut rng);
            assert!(!seen[a], "arm {a} repeated before all arms tried");
            seen[a] = true;
            b.update(a, 0.5);
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn exploration_bonus_decays_with_count() {
        let mut b = Ucb1::new(2);
        let mut rng = Rng::new(0);
        for _ in 0..2 {
            let a = b.select(&mut rng);
            b.update(a, 0.5);
        }
        let u0 = b.ucb(0);
        for _ in 0..50 {
            b.update(0, 0.5);
        }
        assert!(b.ucb(0) < u0, "bonus should shrink as N_a grows");
        // arm 1 (unplayed since) now has the larger index
        assert!(b.ucb(1) > b.ucb(0));
    }

    #[test]
    fn values_are_empirical_means() {
        let mut b = Ucb1::new(2);
        b.update(0, 1.0);
        b.update(0, 0.0);
        b.update(1, 0.25);
        let v = b.values();
        assert!((v[0] - 0.5).abs() < 1e-12);
        assert!((v[1] - 0.25).abs() < 1e-12);
    }
}
