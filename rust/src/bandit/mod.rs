//! Multi-armed bandits (paper §3) — UCB1, UCB-Tuned, Thompson Sampling —
//! and the sequence/token-level TapOut controllers that bind bandits to the
//! arm-policy pool.

pub mod controller;
pub mod drafters;
pub mod shared;
pub mod thompson;
pub mod ucb1;
pub mod ucb_tuned;

pub use controller::{Reward, SeqBandit, TokenBandit};
pub use drafters::{DrafterHook, DrafterTenantSnapshot, SharedDrafters};
pub use shared::{SessionController, SharedController};
pub use thompson::{BetaTs, GaussianTs};
pub use ucb1::Ucb1;
pub use ucb_tuned::UcbTuned;

use crate::util::Rng;

/// A stochastic multi-armed bandit over a fixed arm set.
pub trait Bandit: Send {
    /// Number of arms this learner plays over.
    fn n_arms(&self) -> usize;

    /// Choose an arm to play.
    fn select(&mut self, rng: &mut Rng) -> usize;

    /// Observe `reward` (in [0, 1]) for `arm`.
    fn update(&mut self, arm: usize, reward: f64);

    /// Interpretable per-arm value estimates (the paper's μ_i readout,
    /// Figs. 5-6). For TS this is the posterior mean.
    fn values(&self) -> Vec<f64>;

    /// Per-arm play counts.
    fn counts(&self) -> Vec<u64>;

    /// Short stable identifier (report labels).
    fn name(&self) -> String;

    /// Forget everything (fresh request stream).
    fn reset(&mut self);
}

/// Boxed-bandit convenience used by the controllers.
pub type BoxedBandit = Box<dyn Bandit>;

/// Factory used by the experiment harness ("ucb1" | "ucb-tuned" |
/// "ts-gaussian" | "ts-beta").
pub fn make_bandit(kind: &str, n_arms: usize) -> BoxedBandit {
    match kind {
        "ucb1" => Box::new(Ucb1::new(n_arms)),
        "ucb-tuned" => Box::new(UcbTuned::new(n_arms)),
        "ts-gaussian" => Box::new(GaussianTs::new(n_arms)),
        "ts-beta" => Box::new(BetaTs::new(n_arms)),
        other => panic!("unknown bandit kind: {other}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Shared regret smoke-test: every bandit should concentrate on the
    /// best of three Bernoulli arms (0.2 / 0.5 / 0.8).
    fn check_concentrates(mut b: BoxedBandit) {
        let ps = [0.2, 0.5, 0.8];
        let mut rng = Rng::new(7);
        for _ in 0..3000 {
            let a = b.select(&mut rng);
            let r = if rng.bool(ps[a]) { 1.0 } else { 0.0 };
            b.update(a, r);
        }
        let counts = b.counts();
        let best = counts[2];
        assert!(
            best > counts[0] * 2 && best > counts[1] * 2,
            "{}: counts {counts:?}",
            b.name()
        );
        let vals = b.values();
        assert!(vals[2] > vals[0], "{}: values {vals:?}", b.name());
    }

    #[test]
    fn all_bandits_concentrate_on_best_arm() {
        for kind in ["ucb1", "ucb-tuned", "ts-gaussian", "ts-beta"] {
            check_concentrates(make_bandit(kind, 3));
        }
    }

    #[test]
    fn reset_clears_state() {
        for kind in ["ucb1", "ucb-tuned", "ts-gaussian", "ts-beta"] {
            let mut b = make_bandit(kind, 2);
            let mut rng = Rng::new(1);
            for _ in 0..50 {
                let a = b.select(&mut rng);
                b.update(a, 1.0);
            }
            b.reset();
            assert_eq!(b.counts(), vec![0, 0], "{kind}");
        }
    }
}
