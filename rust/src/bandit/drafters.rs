//! Drafter-selection layer — the outer bandit of the hierarchical
//! controller (docs/ARCHITECTURE.md §17).
//!
//! Where the TapOut policy bandit picks a *stop policy* per round, this
//! layer picks *which pooled draft model* proposes the round's tokens.
//! Two properties make it cheaper than a stochastic bandit:
//!
//!   * **Full information** (Not-a-Bandit, PAPERS.md): the verify forward
//!     commits target tokens regardless of which drafter proposed, so
//!     every round can score *all* pooled drafters' hypothetical
//!     proposals against the committed tokens
//!     ([`LanguageModel::score_drafters`](crate::models::LanguageModel::score_drafters)).
//!     Selection is therefore a deterministic argmax over posterior
//!     means — no exploration bonus, **no RNG draw** — which is exactly
//!     what keeps a pool of one byte-identical to the pre-pool engine.
//!   * **Tenant keying with hierarchical priors**: state is kept per
//!     tenant (the request's `tenant` field; `""` is the global tenant)
//!     on top of a global aggregate. An unseen tenant's posterior *is*
//!     the global posterior (the tenant term contributes nothing), so
//!     cold tenants inherit fleet-wide knowledge and warm tenants drift
//!     to their own modal drafter.
//!
//! **Conservation contract** (checked by the sim oracle and
//! `engine_drafters.rs`): every [`SharedDrafters::begin`] is settled by
//! exactly one [`SharedDrafters::settle_verify`] or
//! [`SharedDrafters::settle_abort`], so
//! `sessions == updates == Σ global plays == Σ per-tenant plays`
//! at every quiescent point — the same ledger discipline the policy
//! layer's `SharedController` is pinned on, generalized per layer.

use std::collections::HashMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Weight of the global posterior in the blended per-tenant mean: the
/// global mean counts as this many pseudo-observations, so a tenant
/// needs a few rounds of its own evidence before it can leave the prior.
const PRIOR_W: f64 = 2.0;

/// Full-information posterior for one (tenant or global) scope: per-arm
/// play counts plus per-arm score sums over a shared observation count
/// (every verify scores *all* arms, so `obs` is scalar).
#[derive(Clone, Debug)]
struct ArmStats {
    /// rounds this scope actually routed through each drafter
    plays: Vec<u64>,
    /// full-information observations (verify settles; aborts don't score)
    obs: u64,
    /// Σ agreement-fraction per drafter over those observations
    score_sum: Vec<f64>,
}

impl ArmStats {
    fn new(n: usize) -> ArmStats {
        ArmStats { plays: vec![0; n], obs: 0, score_sum: vec![0.0; n] }
    }
}

/// Per-tenant state: posterior plus the last selection (switch counting).
#[derive(Clone, Debug)]
struct TenantState {
    stats: ArmStats,
    last: Option<usize>,
}

/// One tenant's readout for `/metrics` (`engine.drafters.tenants`).
#[derive(Clone, Debug)]
pub struct DrafterTenantSnapshot {
    /// tenant key (`""` = the global/default tenant)
    pub tenant: String,
    /// rounds routed through each drafter
    pub plays: Vec<u64>,
    /// posterior mean agreement per drafter (0 observations ⇒ 1.0)
    pub means: Vec<f64>,
    /// full-information observations backing those means
    pub obs: u64,
}

/// Shared drafter-selection controller — one per engine, used by every
/// worker/stepper session concurrently (module docs for the contract).
pub struct SharedDrafters {
    /// pool size (1 keeps the whole layer inert)
    n: usize,
    /// selections handed out ([`SharedDrafters::begin`] calls)
    sessions: AtomicU64,
    /// settles received (verify + abort)
    updates: AtomicU64,
    /// times a tenant's selection changed between consecutive rounds
    switches: AtomicU64,
    /// bench/debug override: ≥ 0 forces that drafter (plays still ledger)
    pin: AtomicI64,
    state: Mutex<DrafterStateInner>,
}

struct DrafterStateInner {
    global: ArmStats,
    tenants: HashMap<String, TenantState>,
}

impl SharedDrafters {
    /// Controller over a pool of `n.max(1)` drafters.
    pub fn new(n: usize) -> Arc<SharedDrafters> {
        let n = n.max(1);
        Arc::new(SharedDrafters {
            n,
            sessions: AtomicU64::new(0),
            updates: AtomicU64::new(0),
            switches: AtomicU64::new(0),
            pin: AtomicI64::new(-1),
            state: Mutex::new(DrafterStateInner {
                global: ArmStats::new(n),
                tenants: HashMap::new(),
            }),
        })
    }

    /// Pool size.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Force every selection to drafter `d` (benchmark baselines); `None`
    /// restores bandit selection. Settles are ledgered either way, so the
    /// conservation invariant holds for pinned runs too.
    pub fn set_pin(&self, d: Option<usize>) {
        self.pin.store(d.map(|x| x as i64).unwrap_or(-1), Ordering::Relaxed);
    }

    /// Select the drafter for one round of `tenant`'s session: the
    /// deterministic argmax (ties → lowest index, **no RNG**) of the
    /// blended mean `(PRIOR_W·global_mean + tenant_sum) / (PRIOR_W +
    /// tenant_obs)` — exactly the global posterior for an unseen tenant.
    /// Counts one session; the caller owes exactly one settle.
    pub fn begin(&self, tenant: &str) -> usize {
        self.sessions.fetch_add(1, Ordering::Relaxed);
        let mut st = self.state.lock().unwrap();
        let pin = self.pin.load(Ordering::Relaxed);
        let d = if pin >= 0 {
            (pin as usize).min(self.n - 1)
        } else if self.n == 1 {
            0
        } else {
            let mut best = 0usize;
            let mut best_v = f64::NEG_INFINITY;
            for a in 0..self.n {
                let g = &st.global;
                let gmean = if g.obs == 0 { 1.0 } else { g.score_sum[a] / g.obs as f64 };
                let (tobs, tsum) = st
                    .tenants
                    .get(tenant)
                    .map(|t| (t.stats.obs, t.stats.score_sum[a]))
                    .unwrap_or((0, 0.0));
                let v = (PRIOR_W * gmean + tsum) / (PRIOR_W + tobs as f64);
                if v > best_v {
                    best = a;
                    best_v = v;
                }
            }
            best
        };
        let n = self.n;
        let entry = st
            .tenants
            .entry(tenant.to_string())
            .or_insert_with(|| TenantState { stats: ArmStats::new(n), last: None });
        if let Some(last) = entry.last {
            if last != d {
                self.switches.fetch_add(1, Ordering::Relaxed);
            }
        }
        entry.last = Some(d);
        d
    }

    /// Settle one round that reached verify: ledger the played drafter
    /// `d` and feed the full-information `scores` (one agreement fraction
    /// per pooled drafter, from `score_drafters`) into **all** arms of
    /// both the tenant posterior and the global aggregate.
    pub fn settle_verify(&self, tenant: &str, d: usize, scores: &[f64]) {
        self.updates.fetch_add(1, Ordering::Relaxed);
        let n = self.n;
        let d = d.min(n - 1);
        let sc = |a: usize| scores.get(a).copied().unwrap_or(0.0).clamp(0.0, 1.0);
        let mut st = self.state.lock().unwrap();
        st.global.obs += 1;
        st.global.plays[d] += 1;
        for a in 0..n {
            st.global.score_sum[a] += sc(a);
        }
        let t = st
            .tenants
            .entry(tenant.to_string())
            .or_insert_with(|| TenantState { stats: ArmStats::new(n), last: None });
        t.stats.obs += 1;
        t.stats.plays[d] += 1;
        for a in 0..n {
            t.stats.score_sum[a] += sc(a);
        }
    }

    /// Settle one round that aborted before verify (draft/verify fault):
    /// the play is ledgered in both scopes — conservation — but no
    /// posterior moves, since no tokens were committed to score against.
    pub fn settle_abort(&self, tenant: &str, d: usize) {
        self.updates.fetch_add(1, Ordering::Relaxed);
        let n = self.n;
        let d = d.min(n - 1);
        let mut st = self.state.lock().unwrap();
        st.global.plays[d] += 1;
        let t = st
            .tenants
            .entry(tenant.to_string())
            .or_insert_with(|| TenantState { stats: ArmStats::new(n), last: None });
        t.stats.plays[d] += 1;
    }

    /// Selections handed out so far.
    pub fn sessions(&self) -> u64 {
        self.sessions.load(Ordering::Relaxed)
    }

    /// Settles received so far (verify + abort).
    pub fn updates(&self) -> u64 {
        self.updates.load(Ordering::Relaxed)
    }

    /// Times any tenant's selection changed between consecutive rounds.
    pub fn switches(&self) -> u64 {
        self.switches.load(Ordering::Relaxed)
    }

    /// Global per-drafter play counts (Σ equals [`updates`](Self::updates)
    /// at quiescence).
    pub fn plays(&self) -> Vec<u64> {
        self.state.lock().unwrap().global.plays.clone()
    }

    /// Global posterior mean agreement per drafter (0 obs ⇒ 1.0).
    pub fn means(&self) -> Vec<f64> {
        let st = self.state.lock().unwrap();
        let g = &st.global;
        (0..self.n)
            .map(|a| if g.obs == 0 { 1.0 } else { g.score_sum[a] / g.obs as f64 })
            .collect()
    }

    /// Σ over tenants of Σ per-drafter plays (the oracle cross-checks
    /// this against the global ledger).
    pub fn tenant_plays_total(&self) -> u64 {
        let st = self.state.lock().unwrap();
        st.tenants.values().map(|t| t.stats.plays.iter().sum::<u64>()).sum()
    }

    /// Per-tenant readout, sorted by tenant key so `/metrics` renders
    /// deterministically.
    pub fn tenant_snapshot(&self) -> Vec<DrafterTenantSnapshot> {
        let st = self.state.lock().unwrap();
        let mut out: Vec<DrafterTenantSnapshot> = st
            .tenants
            .iter()
            .map(|(k, t)| DrafterTenantSnapshot {
                tenant: k.clone(),
                plays: t.stats.plays.clone(),
                means: (0..self.n)
                    .map(|a| {
                        if t.stats.obs == 0 {
                            1.0
                        } else {
                            t.stats.score_sum[a] / t.stats.obs as f64
                        }
                    })
                    .collect(),
                obs: t.stats.obs,
            })
            .collect();
        out.sort_by(|a, b| a.tenant.cmp(&b.tenant));
        out
    }

    /// The drafter `tenant` has played most (ties → lowest index); `None`
    /// for an unseen tenant. The bench gate asserts two tenants with
    /// opposite acceptance profiles end up with different modes.
    pub fn modal_drafter(&self, tenant: &str) -> Option<usize> {
        let st = self.state.lock().unwrap();
        st.tenants.get(tenant).map(|t| {
            let mut best = 0;
            for a in 1..self.n {
                if t.stats.plays[a] > t.stats.plays[best] {
                    best = a;
                }
            }
            best
        })
    }
}

/// Per-session handle binding a [`SharedDrafters`] to one request's
/// (tenant, seed, category): the spec session / stepper calls
/// [`begin_round`](DrafterHook::begin_round) before drafting and exactly
/// one settle per round after verify or abort.
pub struct DrafterHook {
    shared: Arc<SharedDrafters>,
    tenant: String,
    seed: u64,
    category: String,
    drafter: usize,
}

impl DrafterHook {
    /// Hook for one request (`seed`/`category` key the scenario for
    /// `score_drafters`; `tenant` keys the posterior).
    pub fn new(shared: Arc<SharedDrafters>, tenant: String, seed: u64, category: String) -> DrafterHook {
        DrafterHook { shared, tenant, seed, category, drafter: 0 }
    }

    /// Select this round's drafter (counts one session; owe one settle).
    pub fn begin_round(&mut self) -> usize {
        self.drafter = self.shared.begin(&self.tenant);
        self.drafter
    }

    /// The drafter selected by the last [`begin_round`](Self::begin_round).
    pub fn drafter(&self) -> usize {
        self.drafter
    }

    /// Tenant key this hook settles under.
    pub fn tenant(&self) -> &str {
        &self.tenant
    }

    /// Scenario seed for `score_drafters`.
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Scenario category for `score_drafters`.
    pub fn category(&self) -> &str {
        &self.category
    }

    /// Settle the round with full-information `scores` (verify reached).
    pub fn settle_verify(&self, scores: &[f64]) {
        self.shared.settle_verify(&self.tenant, self.drafter, scores);
    }

    /// Settle the round as aborted (fault before commit).
    pub fn settle_abort(&self) {
        self.shared.settle_abort(&self.tenant, self.drafter);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pool_of_one_always_selects_zero_and_conserves() {
        let s = SharedDrafters::new(1);
        for i in 0..10 {
            let d = s.begin("");
            assert_eq!(d, 0);
            if i % 3 == 0 {
                s.settle_abort("", d);
            } else {
                s.settle_verify("", d, &[0.5]);
            }
        }
        assert_eq!(s.sessions(), 10);
        assert_eq!(s.updates(), 10);
        assert_eq!(s.plays().iter().sum::<u64>(), 10);
        assert_eq!(s.tenant_plays_total(), 10);
    }

    #[test]
    fn tenants_with_opposite_scores_diverge_and_unseen_falls_back() {
        let s = SharedDrafters::new(2);
        // tenant "code" sees drafter 1 agree, tenant "chat" sees drafter 0
        for _ in 0..30 {
            let d = s.begin("code");
            s.settle_verify("code", d, &[0.1, 0.9]);
            let d = s.begin("chat");
            s.settle_verify("chat", d, &[0.9, 0.1]);
        }
        assert_eq!(s.modal_drafter("code"), Some(1), "code tenant converges to drafter 1");
        assert_eq!(s.modal_drafter("chat"), Some(0), "chat tenant converges to drafter 0");
        // global aggregate is balanced (0.5 each), so an unseen tenant's
        // first pick is the global argmax — deterministic, lowest index
        // on ties, and critically identical across runs (no RNG)
        let first = s.begin("fresh");
        s.settle_abort("fresh", first);
        let s2_first = {
            let s2 = SharedDrafters::new(2);
            for _ in 0..30 {
                let d = s2.begin("code");
                s2.settle_verify("code", d, &[0.1, 0.9]);
                let d = s2.begin("chat");
                s2.settle_verify("chat", d, &[0.9, 0.1]);
            }
            let f = s2.begin("fresh");
            s2.settle_abort("fresh", f);
            f
        };
        assert_eq!(first, s2_first, "selection is a pure function of observed history");
    }

    #[test]
    fn conservation_holds_across_tenants_and_aborts() {
        let s = SharedDrafters::new(3);
        let tenants = ["", "a", "b"];
        let mut rounds = 0u64;
        for i in 0..60u64 {
            let t = tenants[(i % 3) as usize];
            let d = s.begin(t);
            if i % 5 == 0 {
                s.settle_abort(t, d);
            } else {
                s.settle_verify(t, d, &[0.2, 0.5, 0.8]);
            }
            rounds += 1;
        }
        assert_eq!(s.sessions(), rounds);
        assert_eq!(s.updates(), rounds);
        assert_eq!(s.plays().iter().sum::<u64>(), rounds, "global ledger conserves");
        assert_eq!(s.tenant_plays_total(), rounds, "per-tenant ledgers sum to global");
        let snap = s.tenant_snapshot();
        assert_eq!(snap.len(), 3);
        assert!(snap.windows(2).all(|w| w[0].tenant < w[1].tenant), "sorted readout");
    }

    #[test]
    fn pin_overrides_selection_but_still_ledgers() {
        let s = SharedDrafters::new(2);
        s.set_pin(Some(1));
        for _ in 0..5 {
            let d = s.begin("t");
            assert_eq!(d, 1);
            s.settle_verify("t", d, &[0.9, 0.1]);
        }
        s.set_pin(None);
        // with the pin lifted the posterior (which saw drafter 0 agree
        // more) takes over
        assert_eq!(s.begin("t"), 0);
        s.settle_abort("t", 0);
        assert_eq!(s.sessions(), s.updates());
        assert_eq!(s.plays(), vec![1, 5]);
    }

    #[test]
    fn switches_count_selection_changes() {
        let s = SharedDrafters::new(2);
        s.set_pin(Some(0));
        let d = s.begin("t");
        s.settle_verify("t", d, &[0.0, 1.0]);
        assert_eq!(s.switches(), 0, "first selection is not a switch");
        s.set_pin(Some(1));
        let d = s.begin("t");
        s.settle_verify("t", d, &[0.0, 1.0]);
        assert_eq!(s.switches(), 1);
        let d = s.begin("t");
        s.settle_verify("t", d, &[0.0, 1.0]);
        assert_eq!(s.switches(), 1, "repeat selection is not a switch");
    }

    #[test]
    fn hook_routes_settles_to_its_tenant() {
        let s = SharedDrafters::new(2);
        let mut h = DrafterHook::new(s.clone(), "code".into(), 7, "coding".into());
        assert_eq!(h.tenant(), "code");
        assert_eq!(h.seed(), 7);
        assert_eq!(h.category(), "coding");
        let d = h.begin_round();
        assert_eq!(d, h.drafter());
        h.settle_verify(&[0.1, 0.9]);
        h.begin_round();
        h.settle_abort();
        let snap = s.tenant_snapshot();
        assert_eq!(snap.len(), 1);
        assert_eq!(snap[0].tenant, "code");
        assert_eq!(snap[0].plays.iter().sum::<u64>(), 2);
        assert_eq!(snap[0].obs, 1, "abort does not move the posterior");
    }
}
